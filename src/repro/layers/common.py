"""Shared layer plumbing: initializers and param-tree helpers.

Params are plain nested dicts of jnp arrays (no flax): full control over
flattened path names, which the sharding rule engine (dist/sharding.py)
matches with regexes.
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp


def dense_init(key, shape, in_axis_size: int | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (the LLaMA/gemma default)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = fan_in ** -0.5
    return (std * jax.random.truncated_normal(key, -3, 3, shape)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (0.02 * jax.random.truncated_normal(key, -3, 3, shape)).astype(dtype)


def split_keys(key, n: int) -> Iterator[jax.Array]:
    return iter(jax.random.split(key, n))


def flatten_paths(tree, prefix: str = "") -> dict[str, jnp.ndarray]:
    """{'a/b/c': leaf} view of a nested-dict param tree."""
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten_paths(v, f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def param_count(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )
