from .attention import (
    GQAConfig, KVCache, MLAConfig, gqa_attention, init_gqa, init_mla,
    mla_attention, sdpa,
)
from .common import cast_tree, dense_init, embed_init, flatten_paths, param_count
from .embedding import (
    BagConfig, embed_tokens, embedding_bag, init_token_embedding,
    multi_field_lookup, unembed,
)
from .interactions import (
    FieldAttnConfig, dot_interaction, field_attention, fm_interaction,
    init_field_attention,
)
from .mlp import MLPConfig, dense_stack, init_dense_stack, init_mlp, mlp
from .moe import MoEConfig, init_moe, moe_layer
from .norm import layer_norm, rms_norm
from .rope import apply_rope, rope_freqs
from .segment import gather_scatter, sym_norm_weights

__all__ = [k for k in dir() if not k.startswith("_")]
