"""Attention layers: GQA (w/ qk-norm, sliding window, soft-cap) and MLA.

Functional, cache-aware, scan-friendly:

* ``window`` and ``rope_theta`` are *traced per-layer scalars* so a
  heterogeneous stack (gemma3's 5 local : 1 global pattern) lowers as one
  uniform ``lax.scan`` body — a local layer is just ``window > 0``.
* training / prefill call with ``cache=None`` (full causal self-attention);
  decode calls with a ``KVCache`` and a scalar position.
* the XLA einsum path is the default (it lowers on every backend and lets
  GSPMD insert the head-sharded collectives); the Pallas flash kernel is a
  config switch for real-TPU serving.

MLA (DeepSeek-V2): queries and KV are low-rank compressed; the cache stores
only the 512-dim latent + 64-dim shared rope key per token — the 93.3%
KV-cache reduction that lets deepseek-v2 serve 128k contexts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys
from .norm import rms_norm
from .rope import apply_rope

BIG_WINDOW = jnp.int32(2**30)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """Decode-time cache. GQA: k/v are (B, S_max, Hkv, dh).
    MLA: k stores the compressed latent (B, S_max, kv_lora), v the rope key
    (B, S_max, rope_dim)."""

    k: jnp.ndarray
    v: jnp.ndarray


# ---------------------------------------------------------------------------
# Masked softmax attention core (shared by GQA / MLA)
# ---------------------------------------------------------------------------

def _chunk_logits(qg, k_chunk, c0, *, causal, window, softcap, scale,
                  q_positions, kv_valid_len):
    """fp32 masked logits of one KV chunk: (B,Hkv,G,S,Tc)."""
    b, s = qg.shape[0], qg.shape[1]
    tc = k_chunk.shape[1]
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k_chunk,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = q_positions[:, None, None, :, None]      # (B,1,1,S,1)
    k_pos = c0 + jnp.arange(tc)[None, None, None, None, :]
    mask = jnp.ones((b, 1, 1, s, tc), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    w = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window), BIG_WINDOW)
    mask &= (q_pos - k_pos) < w
    if kv_valid_len is not None:
        mask &= k_pos < jnp.asarray(kv_valid_len).reshape(-1, 1, 1, 1, 1)
    return jnp.where(mask, logits, -1e30)


def sdpa(q, k, v, *, causal: bool, window, softcap: float, scale: float,
         q_positions, kv_valid_len=None, kv_chunk: int = 0) -> jnp.ndarray:
    """q: (B,S,Hq,dh) k/v: (B,T,Hkv,dh), Hq % Hkv == 0 -> (B,S,Hq,dv).

    GQA grouping happens INSIDE the einsums (q reshaped to
    (B,S,Hkv,G,dh)) — materializing repeat_kv forces GSPMD to all-gather
    the full KV cache when it is sequence-sharded (a 5.4 GB/layer gather
    on qwen3 decode_32k; §Perf iteration B). fp32 softmax. ``window`` is a
    traced scalar (<=0 disables); ``kv_valid_len`` masks the cache tail.

    ``kv_chunk > 0`` streams KV in chunks with an online softmax
    (flash-attention dataflow in XLA): the (S, T) fp32 logits tensor never
    materializes — 8.6 GB/layer on deepseek-v2 train_4k (§Perf A5).
    """
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    dv = v.shape[-1]
    qg = q.reshape(b, s, hkv, g, dh)
    kwargs = dict(causal=causal, window=window, softcap=softcap, scale=scale,
                  q_positions=q_positions, kv_valid_len=kv_valid_len)

    if kv_chunk > 0 and t > 2 * kv_chunk and t % kv_chunk == 0 and s > 1:
        nc = t // kv_chunk
        ks = k.reshape(b, nc, kv_chunk, hkv, dh).swapaxes(0, 1)
        vs = v.reshape(b, nc, kv_chunk, hkv, dv).swapaxes(0, 1)

        def body(carry, xs):
            m_prev, l_prev, acc = carry
            kc, vc, ci = xs
            lg = _chunk_logits(qg, kc, ci * kv_chunk, **kwargs)
            m_cur = jnp.maximum(m_prev, jnp.max(lg, axis=-1))
            alpha = jnp.exp(m_prev - m_cur)
            p = jnp.exp(lg - m_cur[..., None])
            l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(vc.dtype), vc)
            acc = acc * alpha[..., None].astype(acc.dtype) + pv
            return (m_cur, l_cur, acc), None

        m0 = jnp.full((b, hkv, g, s), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, s, dv), v.dtype)
        (m_f, l_f, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (ks, vs, jnp.arange(nc)))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None].astype(acc.dtype)
        out = jnp.moveaxis(out, 3, 1)            # (B,S,Hkv,G,dv)
        return out.reshape(b, s, hq, dv)

    logits = _chunk_logits(qg, k, 0, **kwargs)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v)
    return out.reshape(b, s, hq, dv)  # v dim != q dim under MLA


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GQAConfig:
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    qk_norm: bool = False
    softcap: float = 0.0
    causal: bool = True
    kv_chunk: int = 0   # stream KV in chunks (flash dataflow in XLA)


def init_gqa(key, cfg: GQAConfig) -> dict:
    ks = split_keys(key, 6)
    p = {
        "wq": dense_init(next(ks), (cfg.d_model, cfg.n_heads, cfg.d_head), cfg.d_model),
        "wk": dense_init(next(ks), (cfg.d_model, cfg.n_kv, cfg.d_head), cfg.d_model),
        "wv": dense_init(next(ks), (cfg.d_model, cfg.n_kv, cfg.d_head), cfg.d_model),
        "wo": dense_init(next(ks), (cfg.n_heads, cfg.d_head, cfg.d_model),
                         cfg.n_heads * cfg.d_head),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.d_head,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.d_head,), jnp.float32)
    return p


def gqa_attention(
    params: dict,
    x: jnp.ndarray,              # (B, S, D)
    cfg: GQAConfig,
    *,
    positions: jnp.ndarray,      # (B, S) absolute positions
    rope_theta,                  # traced ok
    window,                      # traced ok; <=0 => global
    cache: Optional[KVCache] = None,
    cache_pos=None,              # () int32: write offset during decode
    kv_valid_len=None,           # (B,) or () — valid cache length incl. new tokens
) -> tuple[jnp.ndarray, Optional[KVCache]]:
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    # NOTE (§Perf, refuted hypothesis): explicitly pinning head sharding
    # here FORCES a seq->head resharding all-to-all against the
    # sequence-parallel residual and cost gemma3 train_4k 10s/step of
    # collective time; GSPMD's inferred layout is better. Left unpinned.
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), cache_pos, axis=1)
        new_cache = KVCache(k=ck, v=cv)
        k, v = ck.astype(dt), cv.astype(dt)

    # KV chunking only on the cache (prefill/serve) path: for training the
    # scanned online softmax slowed the bwd and raised collective time
    # (§Perf, measured); the unchunked einsum is better there.
    out = sdpa(q, k, v, causal=cfg.causal, window=window, softcap=cfg.softcap,
               scale=cfg.d_head ** -0.5, q_positions=positions,
               kv_valid_len=kv_valid_len,
               kv_chunk=cfg.kv_chunk if cache is not None else 0)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    softcap: float = 0.0
    causal: bool = True
    kv_chunk: int = 0


def init_mla(key, cfg: MLAConfig) -> dict:
    ks = split_keys(key, 8)
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": dense_init(next(ks), (cfg.d_model, cfg.q_lora), cfg.d_model),
        "q_norm": jnp.ones((cfg.q_lora,), jnp.float32),
        "w_uq": dense_init(next(ks), (cfg.q_lora, h, dn + dr), cfg.q_lora),
        "w_dkv": dense_init(next(ks), (cfg.d_model, cfg.kv_lora), cfg.d_model),
        "kv_norm": jnp.ones((cfg.kv_lora,), jnp.float32),
        "w_uk": dense_init(next(ks), (cfg.kv_lora, h, dn), cfg.kv_lora),
        "w_uv": dense_init(next(ks), (cfg.kv_lora, h, dv), cfg.kv_lora),
        "w_kr": dense_init(next(ks), (cfg.d_model, dr), cfg.d_model),
        "wo": dense_init(next(ks), (h, dv, cfg.d_model), h * dv),
    }


def mla_attention(
    params: dict,
    x: jnp.ndarray,
    cfg: MLAConfig,
    *,
    positions: jnp.ndarray,
    rope_theta,
    window,  # accepted for scan uniformity; MLA layers are global
    cache: Optional[KVCache] = None,
    cache_pos=None,
    kv_valid_len=None,
) -> tuple[jnp.ndarray, Optional[KVCache]]:
    dt = x.dtype
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim

    cq = rms_norm(x @ params["w_dq"].astype(dt), params["q_norm"])
    q = jnp.einsum("bsl,lhk->bshk", cq, params["w_uq"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    ckv = rms_norm(x @ params["w_dkv"].astype(dt), params["kv_norm"])  # (B,S,kv_lora)
    k_rope = apply_rope(
        (x @ params["w_kr"].astype(dt))[:, :, None, :], positions, rope_theta
    )[:, :, 0, :]  # (B,S,dr) shared across heads

    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, ckv.astype(cache.k.dtype), cache_pos, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(cache.v, k_rope.astype(cache.v.dtype), cache_pos, axis=1)
        new_cache = KVCache(k=ck, v=cr)
        ckv, k_rope = ck.astype(dt), cr.astype(dt)

    k_nope = jnp.einsum("btl,lhk->bthk", ckv, params["w_uk"].astype(dt))
    v = jnp.einsum("btl,lhk->bthk", ckv, params["w_uv"].astype(dt))
    t = ckv.shape[1]
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (dr,))
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)

    out = sdpa(qf, k, v, causal=cfg.causal, window=window, softcap=cfg.softcap,
               scale=(dn + dr) ** -0.5, q_positions=positions,
               kv_valid_len=kv_valid_len,
               kv_chunk=cfg.kv_chunk if cache is not None else 0)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, new_cache
