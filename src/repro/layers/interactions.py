"""Recsys feature-interaction ops: dot (DLRM), concat (Wide&Deep), FM,
and multi-head self-attention over field embeddings (AutoInt)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys


def dot_interaction(feats: jnp.ndarray, keep_self: bool = False) -> jnp.ndarray:
    """DLRM pairwise dots. feats: (B, F, d) -> (B, F*(F-1)/2) upper triangle."""
    b, f, d = feats.shape
    dots = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(f, k=0 if keep_self else 1)
    return dots[:, iu, ju]


def fm_interaction(feats: jnp.ndarray) -> jnp.ndarray:
    """Factorization-machine 2nd-order term: 0.5*((sum v)^2 - sum v^2). (B,)"""
    s = jnp.sum(feats, axis=1)
    s2 = jnp.sum(feats * feats, axis=1)
    return 0.5 * jnp.sum(s * s - s2, axis=-1)


@dataclasses.dataclass(frozen=True)
class FieldAttnConfig:
    n_fields: int
    d_embed: int
    n_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32  # total attention width (split across heads)


def init_field_attention(key, cfg: FieldAttnConfig) -> dict:
    layers = []
    d_in = cfg.d_embed
    for _ in range(cfg.n_layers):
        ks = split_keys(key, 5)
        key = next(ks)
        layers.append({
            "wq": dense_init(next(ks), (d_in, cfg.d_attn), d_in),
            "wk": dense_init(next(ks), (d_in, cfg.d_attn), d_in),
            "wv": dense_init(next(ks), (d_in, cfg.d_attn), d_in),
            "w_res": dense_init(next(ks), (d_in, cfg.d_attn), d_in),
        })
        d_in = cfg.d_attn
    return {f"layer{i}": p for i, p in enumerate(layers)}


def field_attention(params: dict, feats: jnp.ndarray, cfg: FieldAttnConfig) -> jnp.ndarray:
    """AutoInt interacting layers. feats: (B, F, d) -> (B, F * d_attn)."""
    x = feats
    dh = cfg.d_attn // cfg.n_heads
    for i in range(cfg.n_layers):
        p = params[f"layer{i}"]
        dt = x.dtype
        q = (x @ p["wq"].astype(dt)).reshape(*x.shape[:2], cfg.n_heads, dh)
        k = (x @ p["wk"].astype(dt)).reshape(*x.shape[:2], cfg.n_heads, dh)
        v = (x @ p["wv"].astype(dt)).reshape(*x.shape[:2], cfg.n_heads, dh)
        logits = jnp.einsum("bfhd,bghd->bhfg", q, k).astype(jnp.float32)
        a = jax.nn.softmax(logits, axis=-1).astype(dt)
        o = jnp.einsum("bhfg,bghd->bfhd", a, v).reshape(*x.shape[:2], cfg.d_attn)
        x = jax.nn.relu(o + x @ p["w_res"].astype(dt))
    return x.reshape(x.shape[0], -1)
