"""Gated MLP (SwiGLU / GeGLU) — the dense FFN used by all five LM archs."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys

ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    act: str = "silu"
    gated: bool = True   # False -> classic 2-matrix FFN (starcoder2)


def init_mlp(key, cfg: MLPConfig) -> dict:
    ks = split_keys(key, 3)
    p = {
        "w_up": dense_init(next(ks), (cfg.d_model, cfg.d_ff), cfg.d_model),
        "w_down": dense_init(next(ks), (cfg.d_ff, cfg.d_model), cfg.d_ff),
    }
    if cfg.gated:
        p["w_gate"] = dense_init(next(ks), (cfg.d_model, cfg.d_ff), cfg.d_model)
    return p


def mlp(params: dict, x: jnp.ndarray, cfg: MLPConfig) -> jnp.ndarray:
    dt = x.dtype
    u = x @ params["w_up"].astype(dt)
    if cfg.gated:
        g = ACTS[cfg.act](x @ params["w_gate"].astype(dt))
        h = g * u
    else:
        h = ACTS[cfg.act](u)
    return h @ params["w_down"].astype(dt)


def init_dense_stack(key, dims: tuple[int, ...], act: str = "relu") -> dict:
    """Plain MLP tower (recsys): dims = (in, h1, ..., out)."""
    ks = split_keys(key, len(dims))
    return {
        f"w{i}": dense_init(next(ks), (dims[i], dims[i + 1]), dims[i])
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), jnp.float32)
        for i in range(len(dims) - 1)
    }


def dense_stack(params: dict, x: jnp.ndarray, n: int, act: str = "relu",
                final_act: bool = False) -> jnp.ndarray:
    dt = x.dtype
    for i in range(n):
        x = x @ params[f"w{i}"].astype(dt) + params[f"b{i}"].astype(dt)
        if i < n - 1 or final_act:
            x = ACTS[act](x)
    return x
