"""Segment ops: the GNN message-passing substrate.

JAX sparse is BCOO-only (no CSR SpMM), so message passing over an edge list
is gather (by source) -> transform -> ``segment_sum``/``segment_max`` scatter
(by destination). These wrappers add degree normalization and padding-edge
masking (-1 endpoints contribute nothing), which every GNN model here uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_scatter(
    node_feats: jnp.ndarray,  # (N, d)
    edge_src: jnp.ndarray,    # (E,) int32, -1 for padding
    edge_dst: jnp.ndarray,    # (E,) int32
    num_nodes: int,
    *,
    agg: str = "sum",         # sum | mean | max
    edge_weight: jnp.ndarray | None = None,  # (E,)
) -> jnp.ndarray:
    """Aggregate source features into destinations: one GNN message pass."""
    valid = (edge_src >= 0) & (edge_dst >= 0)
    src = jnp.where(valid, edge_src, 0)
    dst = jnp.where(valid, edge_dst, num_nodes)  # padding -> OOB segment (dropped)
    msg = jnp.take(node_feats, src, axis=0)
    if edge_weight is not None:
        msg = msg * edge_weight[:, None].astype(msg.dtype)
    msg = jnp.where(valid[:, None], msg, 0 if agg != "max" else -jnp.inf)
    if agg == "max":
        out = jax.ops.segment_max(msg, dst, num_segments=num_nodes + 1)[:num_nodes]
        return jnp.where(jnp.isfinite(out), out, 0)
    out = jax.ops.segment_sum(msg, dst, num_segments=num_nodes + 1)[:num_nodes]
    if agg == "mean":
        ones = jnp.where(valid, 1.0, 0.0)
        deg = jax.ops.segment_sum(ones, dst, num_segments=num_nodes + 1)[:num_nodes]
        out = out / jnp.maximum(deg, 1.0)[:, None]
    return out


def sym_norm_weights(edge_src, edge_dst, num_nodes: int) -> jnp.ndarray:
    """GCN symmetric normalization 1/sqrt(deg_src * deg_dst) (w/ self-loop +1)."""
    valid = (edge_src >= 0) & (edge_dst >= 0)
    ones = jnp.where(valid, 1.0, 0.0)
    src = jnp.where(valid, edge_src, 0)
    dst = jnp.where(valid, edge_dst, 0)
    deg = jax.ops.segment_sum(ones, dst, num_segments=num_nodes) + 1.0  # in-degree
    deg_out = jax.ops.segment_sum(ones, src, num_segments=num_nodes) + 1.0
    w = (deg_out[src] * deg[dst]) ** -0.5
    return jnp.where(valid, w, 0.0)
