"""RMSNorm / LayerNorm (fp32 statistics, cast back to input dtype)."""
from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
             unit_offset: bool = False) -> jnp.ndarray:
    """``unit_offset=True`` applies (1 + scale) — the gemma convention."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * (var + eps) ** -0.5
    w = (1.0 + scale.astype(jnp.float32)) if unit_offset else scale.astype(jnp.float32)
    return (y * w).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * (var + eps) ** -0.5
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)
