"""Rotary position embeddings.

``theta`` may be a traced scalar — gemma3 alternates 10k (local layers) and
1M (global layers) inside a scan-over-layers, so the frequency table is
computed on the fly from the per-layer theta rather than precomputed.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(positions: jnp.ndarray, d_head: int, theta) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(cos, sin) of shape positions.shape + (d_head // 2,)."""
    half = d_head // 2
    exponent = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = jnp.asarray(theta, jnp.float32) ** -exponent  # (half,)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta=10_000.0) -> jnp.ndarray:
    """x: (..., S, H, dh); positions: (..., S). Split-half convention."""
    dh = x.shape[-1]
    cos, sin = rope_freqs(positions, dh, theta)  # (..., S, dh/2)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
