"""Embeddings: LM token table and the recsys EmbeddingBag.

JAX has no native EmbeddingBag (torch parity gap) — we build it from
``jnp.take`` + ``jax.ops.segment_sum``, which is the TPU-native formulation
anyway (gather + segment-reduce both map to efficient XLA ops). This IS part
of the system, per the brief. The row-sharded distributed version wraps this
in shard_map (dist/embedding.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import embed_init


# ---------------------------------------------------------------------------
# LM token embedding
# ---------------------------------------------------------------------------

def init_token_embedding(key, vocab: int, d_model: int) -> jnp.ndarray:
    return embed_init(key, (vocab, d_model))


def embed_tokens(table: jnp.ndarray, tokens: jnp.ndarray, dtype, scale: bool = False):
    x = jnp.take(table, tokens, axis=0).astype(dtype)
    if scale:
        x = x * jnp.asarray(table.shape[1] ** 0.5, dtype)
    return x


def unembed(table: jnp.ndarray, x: jnp.ndarray, softcap: float = 0.0) -> jnp.ndarray:
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


# ---------------------------------------------------------------------------
# EmbeddingBag (multi-hot gather-reduce)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BagConfig:
    mode: str = "sum"  # sum | mean


def embedding_bag(
    table: jnp.ndarray,     # (V, d)
    indices: jnp.ndarray,   # (B, L) int32 ids, padded with -1 (or any <0)
    cfg: BagConfig = BagConfig(),
    dtype=jnp.float32,
) -> jnp.ndarray:
    """(B, d): per-bag reduction of table rows. Padded slots contribute 0."""
    b, l = indices.shape
    valid = indices >= 0
    safe = jnp.where(valid, indices, 0)
    rows = jnp.take(table, safe.reshape(-1), axis=0).astype(dtype)   # (B*L, d)
    rows = jnp.where(valid.reshape(-1, 1), rows, 0)
    seg = jnp.repeat(jnp.arange(b, dtype=jnp.int32), l)
    out = jax.ops.segment_sum(rows, seg, num_segments=b)
    if cfg.mode == "mean":
        n = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1)
        out = out / n.astype(dtype)
    return out


def multi_field_lookup(
    tables: jnp.ndarray,    # (F, V, d) one table per sparse field
    indices: jnp.ndarray,   # (B, F) one id per field (single-hot fields)
    dtype=jnp.float32,
) -> jnp.ndarray:
    """(B, F, d) single-id-per-field lookup (DLRM/AutoInt layout)."""
    f = tables.shape[0]
    out = jax.vmap(lambda tab, idx: jnp.take(tab, idx, axis=0), in_axes=(0, 1),
                   out_axes=1)(tables, indices)
    return out.astype(dtype)
