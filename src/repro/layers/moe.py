"""Mixture-of-Experts layer: top-k routing, shared experts, sort-based dispatch.

Covers deepseek-v2 (160 routed top-6 + 2 shared, fine-grained d_expert=1536)
and qwen2-moe (60 routed top-4 + 4 shared).

TPU dispatch: the usual CPU/GPU MoE uses ragged grouped GEMM. The fixed-shape
JAX formulation here is **sort-based capacity dispatch**:

  1. flatten (token, k) assignments; stable-sort by expert id;
  2. position-in-run arithmetic (max-scan over run starts) gives each
     assignment its slot within its expert's capacity C;
  3. scatter token activations into an (E*C, D) buffer (``mode="drop"``
     enforces capacity — dropped tokens fall back to the shared experts /
     residual, and the drop count is observable for monitoring);
  4. one batched einsum over (E, C, D) runs all experts on the MXU;
  5. gather back by slot and scatter-add weighted outputs per token.

The (E, C, D) buffer is what EP shards over the "model" axis. Aux
load-balance loss follows Switch (mean fraction x mean prob per expert).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..dist.sharding import DP, TP, shard_activation
from .common import dense_init, split_keys
from .mlp import ACTS


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    act: str = "silu"
    router_dtype: str = "float32"
    normalize_weights: bool = True  # qwen2-moe: False (norm_topk_prob)
    n_experts_alloc: int = 0        # physical rows (pad to the EP axis size;
                                    # qwen2-moe: 60 logical -> 64 allocated)
    n_groups: int = 1               # token groups for dispatch: sorts and
                                    # scatters become *batched* over groups,
                                    # which GSPMD partitions along the group
                                    # dim (a flat global scatter is
                                    # replicated). Production: = dp size.

    @property
    def e_alloc(self) -> int:
        return max(self.n_experts, self.n_experts_alloc)


def init_moe(key, cfg: MoEConfig) -> dict:
    ks = split_keys(key, 8)
    e, d, f = cfg.e_alloc, cfg.d_model, cfg.d_expert
    p = {
        "router": dense_init(next(ks), (d, cfg.n_experts), d),
        "w_gate": dense_init(next(ks), (e, d, f), d),
        "w_up": dense_init(next(ks), (e, d, f), d),
        "w_down": dense_init(next(ks), (e, f, d), f),
    }
    if cfg.n_shared > 0:
        fs = cfg.n_shared * f
        p["shared"] = {
            "w_gate": dense_init(next(ks), (d, fs), d),
            "w_up": dense_init(next(ks), (d, fs), d),
            "w_down": dense_init(next(ks), (fs, d), fs),
        }
    return p


def _position_in_run(sorted_e: jnp.ndarray) -> jnp.ndarray:
    """For a sorted id array, the index of each element within its run."""
    m = sorted_e.shape[0]
    idx = jnp.arange(m, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    return idx - run_start


def moe_layer(params: dict, x: jnp.ndarray, cfg: MoEConfig,
              capacity: int | None = None):
    """x: (B, S, D) -> (y, aux) where aux = {aux_loss, dropped_frac}."""
    dt = x.dtype
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e, k = cfg.n_experts, cfg.top_k

    logits = (xt.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    top_w, top_i = jax.lax.top_k(probs, k)   # (T, K)
    if cfg.normalize_weights:
        top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e (frac_tokens_e * mean_prob_e)
    assign_onehot = jax.nn.one_hot(top_i[:, 0], e)  # primary assignment
    frac = jnp.mean(assign_onehot, axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux_loss = e * jnp.sum(frac * mean_prob)

    ea = cfg.e_alloc  # physical expert rows (>= e; pad rows get no tokens)
    # group bypass at small T (decode: T=batch): grouped dispatch adds fixed
    # per-layer collectives that only amortize over many tokens (§Perf A6 —
    # fixed the 2.5x decode regression the grouped path introduced)
    groups = max(1, min(cfg.n_groups, t // 2048))
    tg = t // groups
    if t % groups:  # group-pad (padding tokens route nowhere: weight 0)
        pad = groups * (tg + 1) - t
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
        top_w = jnp.pad(top_w, ((0, pad), (0, 0)))
        top_i = jnp.pad(top_i, ((0, pad), (0, 0)))
        tg += 1
    if capacity is None:
        capacity = int(cfg.capacity_factor * tg * k / e) + 1
    c = capacity

    # ---- grouped sort-based dispatch ---------------------------------------
    # Per-GROUP sort/scatter (vmap over groups) rather than one flat global
    # scatter: GSPMD partitions batched scatters along the group dim, but
    # REPLICATES a flat scatter with data-dependent indices (a 161 GB
    # buffer at deepseek-v2 train_4k scale — EXPERIMENTS.md §Perf A).
    # NOTE (§Perf, refuted): sharding the group dim over the WHOLE mesh
    # (one group per chip, device-local dispatch) triggers SPMD
    # "involuntary full rematerialization" on the (G*tg, D) reshapes —
    # collective time exploded 79s -> 1532s. Groups shard over dp only;
    # with a single group (decode) constraints are skipped outright — a
    # dp-constraint on a size-1 dim replicates the whole dispatch.
    def _g(x):
        return shard_activation(x, DP, *([None] * (x.ndim - 1))) \
            if groups > 1 else x
    xg = _g(xt.reshape(groups, tg, d))
    wg = _g(top_w.reshape(groups, tg, k))
    ig = _g(top_i.reshape(groups, tg, k).astype(jnp.int32))

    def dispatch_group(xt_g, top_w_g, top_i_g):
        flat_e = top_i_g.reshape(-1)                            # (tg*K,)
        flat_t = jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)
        flat_w = top_w_g.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        sorted_t = flat_t[order]
        sorted_w = flat_w[order]
        pos = _position_in_run(sorted_e)
        keep = pos < c
        slot = jnp.where(keep, sorted_e * c + pos, ea * c)      # OOB == drop
        # .add, not .set: slots are unique by construction, and
        # scatter-add's backward is a gather — scatter-set's backward
        # materializes u32 winner-index maps (10 GB/layer here).
        buf = jnp.zeros((ea * c, d), dt).at[slot].add(
            xt_g[sorted_t], mode="drop")
        return buf.reshape(ea, c, d), (slot, sorted_t, sorted_w, keep)

    buf, (slot, sorted_t, sorted_w, keep) = jax.vmap(dispatch_group)(xg, wg, ig)
    if groups > 1:
        buf = shard_activation(buf, DP, TP, None, None)         # (G, ea, c, D)
    else:
        buf = shard_activation(buf, None, TP, None, None)

    # ---- expert compute (batched MXU einsums; experts sharded over tp) -----
    g = ACTS[cfg.act](jnp.einsum("Gecd,edf->Gecf", buf, params["w_gate"].astype(dt)))
    u = jnp.einsum("Gecd,edf->Gecf", buf, params["w_up"].astype(dt))
    yb = jnp.einsum("Gecf,efd->Gecd", g * u, params["w_down"].astype(dt))
    yb = shard_activation(yb, DP if groups > 1 else None, TP, None, None)

    # ---- combine ------------------------------------------------------------
    def combine_group(yb_g, slot_g, sorted_t_g, sorted_w_g, keep_g):
        flat = yb_g.reshape(ea * c, d)
        contrib = flat.at[slot_g, :].get(mode="fill", fill_value=0.0)
        contrib = contrib * sorted_w_g[:, None].astype(dt)
        return jnp.zeros((tg, d), dt).at[sorted_t_g].add(
            jnp.where(keep_g[:, None], contrib, 0.0))

    y = jax.vmap(combine_group)(yb, slot, sorted_t, sorted_w, keep)
    y = _g(y).reshape(groups * tg, d)[:t]
    y = shard_activation(y, DP, TP)

    if cfg.n_shared > 0:
        sp = params["shared"]
        sg = ACTS[cfg.act](xt @ sp["w_gate"].astype(dt))
        su = xt @ sp["w_up"].astype(dt)
        y = y + (sg * su) @ sp["w_down"].astype(dt)

    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y.reshape(b, s, d), {"aux_loss": aux_loss, "dropped_frac": dropped}
