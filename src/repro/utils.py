"""Small shared utilities: padding, pytree helpers, timing."""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel id used for padded slots in id arrays. We deliberately use a large
# positive int32 (not -1) so that ``jnp.take(..., mode="clip")`` and sorts keep
# padded entries at the *end* of ascending id orderings.
INVALID_ID = np.int32(2**31 - 1)
INF = np.float32(np.inf)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def next_pow2(x: int) -> int:
    if x <= 1:
        return 1
    return 1 << (int(x) - 1).bit_length()


def pad_rows(x: np.ndarray, target: int, fill) -> np.ndarray:
    """Pad axis 0 of ``x`` to ``target`` rows with ``fill``."""
    if x.shape[0] == target:
        return x
    pad = np.full((target - x.shape[0],) + x.shape[1:], fill, dtype=x.dtype)
    return np.concatenate([x, pad], axis=0)


def tree_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_count(tree: Any) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def block_until_ready(tree: Any) -> Any:
    return jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, tree)


def timeit(fn: Callable[[], Any], *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-clock seconds per call of ``fn`` (which must block)."""
    for _ in range(warmup):
        block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


@partial(jax.jit, static_argnames=("axis",))
def masked_min(x: jnp.ndarray, mask: jnp.ndarray, axis: int = -1):
    """Min over ``x`` where ``mask``; returns (value, index). Empty -> (+inf, 0)."""
    masked = jnp.where(mask, x, INF)
    idx = jnp.argmin(masked, axis=axis)
    val = jnp.min(masked, axis=axis)
    return val, idx


def stable_compact_indices(active: jnp.ndarray):
    """Indices that gather active rows to the front (stable), plus inverse.

    Returns (perm, inv_perm, n_active): ``x[perm]`` puts active rows first in
    original order; ``y[inv_perm]`` undoes it.
    """
    # argsort of (not active) is stable in jnp.argsort(kind default is stable
    # for integers); False(0) sorts before True(1) -> active rows first.
    perm = jnp.argsort(jnp.logical_not(active), stable=True)
    inv_perm = jnp.argsort(perm, stable=True)
    return perm, inv_perm, jnp.sum(active.astype(jnp.int32))
