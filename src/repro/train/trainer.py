"""Trainer: the fault-tolerant training loop.

* checkpoint/restart via CheckpointManager (atomic, keep-k, elastic);
* preemption-safe: SIGTERM/SIGINT triggers a final checkpoint before exit
  (the TPU-pod eviction contract);
* straggler/data-fault mitigation: a batch source that raises is skipped
  and logged (``max_data_retries``), keeping the step counter deterministic;
* JSONL metrics stream (one line per step — the thing dashboards tail);
* mesh-aware: when given a mesh + sharding rules it jits the train step
  with explicit in/out shardings and enters the activation-sharding scope.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from ..dist.sharding import activation_sharding, bind_shardings, spec_tree
from ..optim.adamw import AdamWConfig, init_adamw, make_train_step
from .checkpoint import CheckpointManager


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    metrics_path: Optional[str] = None
    max_data_retries: int = 3


class Trainer:
    def __init__(
        self,
        loss_fn: Callable,                  # (params, batch) -> (loss, metrics)
        params: Any,
        opt_cfg: AdamWConfig,
        cfg: TrainerConfig,
        *,
        mesh=None,
        param_rules=None,
        accum_steps: int = 1,
        grad_transform=None,
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts)
        self.params = params
        self.opt_state = init_adamw(params, opt_cfg)
        self.step = 0
        self._stop = False
        self._metrics_f = None

        step_fn = make_train_step(loss_fn, opt_cfg, accum_steps=accum_steps,
                                  grad_transform=grad_transform)
        if mesh is not None and param_rules is not None:
            specs = spec_tree(params, param_rules, mesh)
            self.param_shardings = bind_shardings(mesh, specs)
            opt_specs = {"m": specs, "v": specs, "step": ()}
            self.opt_shardings = bind_shardings(mesh, opt_specs)
            self.params = jax.device_put(self.params, self.param_shardings)
            self.opt_state = jax.device_put(self.opt_state, self.opt_shardings)
            self._step_fn = jax.jit(
                step_fn,
                in_shardings=(self.param_shardings, self.opt_shardings, None),
                out_shardings=(self.param_shardings, self.opt_shardings, None),
                donate_argnums=(0, 1),
            )
        else:
            self.param_shardings = None
            self.opt_shardings = None
            self._step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    # -- preemption ------------------------------------------------------
    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._stop = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not main thread

    # -- checkpointing -----------------------------------------------------
    def save(self):
        state = {"params": self.params, "opt": self.opt_state}
        path = self.ckpt.save(self.step, state, extra={"step": self.step})
        return path

    def maybe_restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        template = {"params": self.params, "opt": self.opt_state}
        shardings = None
        if self.param_shardings is not None:
            shardings = {"params": self.param_shardings, "opt": self.opt_shardings}
        state, step = self.ckpt.restore(template, shardings=shardings)
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step = step
        return True

    # -- metrics -----------------------------------------------------------
    def _log(self, metrics: dict):
        if self.cfg.metrics_path:
            if self._metrics_f is None:
                os.makedirs(os.path.dirname(self.cfg.metrics_path) or ".", exist_ok=True)
                self._metrics_f = open(self.cfg.metrics_path, "a")
            rec = {"step": self.step,
                   **{k: float(np.asarray(v)) for k, v in metrics.items()}}
            self._metrics_f.write(json.dumps(rec) + "\n")
            self._metrics_f.flush()

    # -- the loop ------------------------------------------------------------
    def fit(self, batches: Iterator, verbose: bool = False) -> dict:
        self._install_signal_handlers()
        scope = activation_sharding(self.mesh) if self.mesh is not None else _null()
        history = []
        with scope:
            while self.step < self.cfg.total_steps and not self._stop:
                batch = None
                for attempt in range(self.cfg.max_data_retries):
                    try:
                        batch = next(batches)
                        break
                    except StopIteration:
                        self._stop = True
                        break
                    except Exception as e:  # data fault: skip and log
                        self._log({"data_fault": 1.0})
                        if verbose:
                            print(f"[trainer] data fault (attempt {attempt}): {e}")
                if batch is None or self._stop:
                    break
                self.params, self.opt_state, metrics = self._step_fn(
                    self.params, self.opt_state, batch)
                self.step += 1
                if self.step % self.cfg.log_every == 0 or self.step == 1:
                    metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    history.append({"step": self.step, **metrics})
                    self._log(metrics)
                    if verbose:
                        print(f"[trainer] step {self.step}: " +
                              " ".join(f"{k}={v:.4g}" for k, v in metrics.items()))
                if self.step % self.cfg.ckpt_every == 0:
                    self.save()
        self.save()  # preemption / completion checkpoint
        if self._metrics_f:
            self._metrics_f.close()
            self._metrics_f = None
        return {"final_step": self.step, "history": history}


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
