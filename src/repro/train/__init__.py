from .checkpoint import CheckpointManager
from .trainer import Trainer, TrainerConfig

__all__ = ["CheckpointManager", "Trainer", "TrainerConfig"]
