"""Atomic, elastic checkpointing.

Fault-tolerance contract (DESIGN.md §5):

* **atomic** — writes go to ``step_XXXX.tmp/``, fsync'd, then renamed;
  a manifest.json written last marks the step complete. A crash mid-write
  leaves the previous checkpoint untouched and the partial dir ignored.
* **keep-k** — completed checkpoints beyond ``keep`` are deleted oldest-
  first.
* **elastic** — checkpoints store the *logical* arrays (gathered to host,
  one .npy per flattened tree path), never the device layout. Restore
  takes an optional mesh + sharding tree and ``device_put``s each leaf to
  its (possibly different) target sharding: a 512-chip checkpoint restores
  onto 256 chips, 8 chips, or CPU.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}.{k}" if prefix else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}.{i}" if prefix else str(i)))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: dict, template):
    def rec(t, prefix=""):
        if isinstance(t, dict):
            return {k: rec(v, f"{prefix}.{k}" if prefix else k) for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            vals = [rec(v, f"{prefix}.{i}" if prefix else str(i)) for i, v in enumerate(t)]
            return type(t)(vals)
        return flat[prefix]
    return rec(template)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------
    @staticmethod
    def _fsync_dir(path: str) -> None:
        """Flush directory metadata (file creations / the rename) to disk —
        without this, a power loss can forget a file that was itself
        fsynced, or the rename that published the checkpoint."""
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def save(self, step: int, state: dict, extra: Optional[dict] = None) -> str:
        """Durable on return: every payload ``.npy`` is fsynced, the
        manifest is fsynced, the tmp directory's entries are fsynced, and
        the atomic rename is fsynced in the parent — a crash or power loss
        at ANY point leaves either the previous checkpoint or this one,
        never a manifest pointing at a half-written leaf."""
        name = f"step_{step:010d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(os.path.join(final, "manifest.json")):
            return final  # this step is already durably checkpointed
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(state)
        for path, leaf in flat.items():
            with open(os.path.join(tmp, path + ".npy"), "wb") as f:
                np.save(f, np.asarray(leaf))
                f.flush()
                os.fsync(f.fileno())
        manifest = {
            "step": step,
            "time": time.time(),
            "paths": sorted(flat.keys()),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        self._fsync_dir(tmp)
        os.replace(tmp, final)  # atomic on POSIX
        self._fsync_dir(self.dir)  # make the rename itself durable
        self._gc()
        return final

    def _gc(self):
        done = self.completed_steps()
        for step in done[: max(0, len(done) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{step:010d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def completed_steps(self) -> list[int]:
        out = []
        for d in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, d)
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(full, "manifest.json")):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.completed_steps()
        return steps[-1] if steps else None

    def manifest(self, step: Optional[int] = None) -> dict:
        """The manifest of a completed step (paths + the ``extra`` metadata
        recorded at save time — e.g. the live index's static config)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no completed checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)

    def restore_flat(self, step: Optional[int] = None,
                     shardings: Optional[dict] = None,
                     mmap: Optional[Iterable[str]] = None) -> tuple[dict, dict]:
        """Template-free restore: ``({path: array}, manifest)``.

        For states whose *structure* is only known from the checkpoint
        itself (the live index rebuilds its wrapper from the manifest's
        ``extra``); ``restore`` below remains the template-shaped API.
        ``shardings`` is an optional flat ``{path: Sharding}`` dict.
        ``mmap`` names leaves returned as copy-on-write memory-mapped host
        arrays instead of device arrays (the tiered corpus restores its
        host row store this way — the raw rows never transit HBM)."""
        manifest = self.manifest(step)
        d = os.path.join(self.dir, f"step_{manifest['step']:010d}")
        mm = frozenset(mmap or ())
        flat = {}
        for path in manifest["paths"]:
            fp = os.path.join(d, path + ".npy")
            if path in mm:
                flat[path] = np.load(fp, mmap_mode="c")
                continue
            arr = np.load(fp)
            if shardings is not None and shardings.get(path) is not None:
                flat[path] = jax.device_put(arr, shardings[path])
            else:
                flat[path] = jnp.asarray(arr)
        return flat, manifest

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> tuple[dict, int]:
        """Load into ``template``'s structure; optionally reshard each leaf.

        ``shardings``: pytree of jax.sharding.Sharding matching template (or
        None for default placement). Returns (state, step).
        """
        flat_shard = _flatten(shardings) if shardings is not None else None
        flat, manifest = self.restore_flat(step, flat_shard)
        return _unflatten(flat, template), manifest["step"]
