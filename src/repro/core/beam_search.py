"""Batched graph beam search with range-retrieval extensions.

This implements the paper's Algorithms 1 (BeamSearch), 3/4 (EarlyStopping) and
5 (DoublingSearch) as a single fixed-shape ``jax.lax.while_loop``:

* The beam is a distance-sorted triple ``(ids, dists, expanded)`` of length
  ``max_beam`` (the hardware allocation), of which only the first
  ``active_width`` entries are *eligible for expansion* — ``active_width`` is
  the paper's beam size ``b``.
* **Multi-node expansion**: every loop iteration expands the closest
  ``expand_width`` unexpanded beam entries at once through the fused expand
  path (adjacency gather + vector gather + distance + one-pass tile dedup —
  ``kernels.expand``). This cuts the iteration count ~``expand_width``-fold,
  which is what makes the traversal accelerator-friendly: per-iteration
  fixed costs (sort, control flow, the vmapped-batch straggler effect)
  amortize over E expansions, and the E*R distance tile is one MXU matmul
  instead of E skinny ones.
* **Bitset visited filtering**: every node is marked in a packed per-query
  ``(W,) uint32`` bitset (``core.bitset``) when it first *enters the beam*
  (start points included), so the duplicate tests against the beam and
  against the visited log are one O(1) bit probe per candidate instead of
  O(max_beam + visit_cap) broadcasts. Above ``SearchConfig.bitset_cap_bits``
  the filter hash-buckets, keeping memory bounded at billion scale.
* **Rank-gather merge**: the candidate tile is merged into the
  already-sorted beam by broadcast rank counts over int-keyed distances and
  a one-hot gather — replacing the full float-keyed ``lax.sort`` over
  ``max_beam + E*R`` entries every iteration (see ``_merge_sorted`` for the
  profiling that drove this shape: vmapped scatters and float sort
  comparators are the expensive primitives, vectorized compares are not).
* **Doubling** (Alg. 5) is performed *in place*: when the active prefix is
  fully expanded and at least ``lam * b`` of it is in-range, ``b`` doubles
  (up to ``max_beam``) and the same loop continues. This is our TPU adaptation
  A1 (see DESIGN.md §2): it visits a superset of the restart-based variant's
  candidates with strictly fewer re-expansions.
* **Early stopping** (Algs. 3/4) is evaluated before each expansion using one
  of the paper's four metrics (``d_visited`` — the recommended one —
  ``d_top1``, ``d_top10``, or ``d_top10 / d_start``), on the *closest*
  candidate of the batch. A search that has already found an in-range
  candidate never early-stops (paper Sec. 4.3).
* Every expansion is appended to a visited log (capacity ``visit_cap``); the
  log is what Vamana's RobustPrune consumes at build time and what greedy
  range search seeds from. ``visit_cap`` remains a strict expansion budget:
  the last iteration expands only the remaining budget even if that is less
  than ``expand_width``.

Single-query semantics are written once and batched with ``jax.vmap``; the
vmapped while-loop steps all queries until every lane is done (lanes that
finish early are frozen by the batching rule — the query-compaction machinery
in ``range_search.py`` exists precisely to bound that straggler effect).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels.expand import expand_frontier, expand_frontier_1
from ..utils import INVALID_ID
from .bitset import (
    DEFAULT_BITSET_CAP_BITS,
    bitset_add,
    bitset_contains,
    bitset_exact,
    bitset_init,
    bitset_num_words,
    first_slot_occurrence,
)
from .corpus import CORPUS_DTYPES, corpus_size
from .distances import gather_dist
from .graph import Graph

# Early-stop metric selector (paper Sec. 4.3). Static ints so jit specializes.
ES_NONE = 0
ES_D_VISITED = 1   # distance to the node being visited (paper's best)
ES_D_TOP1 = 2      # distance to closest known neighbor
ES_D_TOP10 = 3     # distance to 10th closest known neighbor
ES_RATIO_TOP10 = 4 # d_top10 / d_start


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Static search hyper-parameters (hashable; a jit static argument)."""

    beam: int = 64            # initial beam width b (paper's B)
    max_beam: int = 64        # allocation; > beam enables in-place doubling
    visit_cap: int = 256      # max expansions == visited-log capacity
    lam: float = 1.0          # λ: in-range fraction of beam that triggers widening
    es_metric: int = ES_NONE  # early-stopping metric (ES_*)
    es_visit_limit: int = 20  # vl: expansions before early stop may trigger
    metric: str = "l2"
    # E: frontier nodes expanded per iteration. E >= 2 takes the fused
    # multi-node path (expand kernel + bitset + sorted merge); E == 1 runs
    # the paper-faithful single-node reference step (pre-fusion dataflow,
    # kept as the correctness/perf baseline — see _step_reference).
    expand_width: int = 4
    bitset_cap_bits: int = DEFAULT_BITSET_CAP_BITS  # seen-filter memory bound
    use_expand_kernel: bool = False  # Pallas expand kernel (real TPU only)
    # declared corpus storage dtype: "float32" | "bfloat16" | "int8". The
    # search itself dispatches on the corpus *value* (array vs
    # QuantizedCorpus); this knob is what deploy configs / builders consult
    # when materializing the corpus (engine.build, build_sharded, serve CLI).
    corpus_dtype: str = "float32"

    def __post_init__(self):
        if self.beam < 1 or self.max_beam < self.beam:
            raise ValueError("need 1 <= beam <= max_beam")
        if self.visit_cap < 1:
            raise ValueError("visit_cap must be >= 1")
        if self.expand_width < 1:
            raise ValueError("expand_width must be >= 1")
        if self.bitset_cap_bits < 32:
            raise ValueError("bitset_cap_bits must be >= 32")
        if self.corpus_dtype not in CORPUS_DTYPES:
            raise ValueError(
                f"corpus_dtype must be one of {CORPUS_DTYPES}")

    @property
    def eff_expand_width(self) -> int:
        """E clamped to the beam allocation (never more slots than exist)."""
        return min(self.expand_width, self.max_beam)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BeamState:
    """Per-query search state (batched by vmap on the leading axis)."""

    ids: jnp.ndarray        # (L,) int32, distance-sorted, INVALID_ID padded
    dists: jnp.ndarray      # (L,) float32, +inf padded
    expanded: jnp.ndarray   # (L,) bool
    active_width: jnp.ndarray  # () int32 — the paper's b
    n_visited: jnp.ndarray  # () int32
    d_visited: jnp.ndarray  # () float32 — farthest node expanded last step
    d_start: jnp.ndarray    # () float32 — distance to the search entry point
    visited_ids: jnp.ndarray    # (V,) int32 log of expanded nodes
    visited_dists: jnp.ndarray  # (V,) float32
    visited_bits: jnp.ndarray   # (W,) uint32 — discovered-node bitset
    n_dist: jnp.ndarray     # () int32 distance-computation counter
    es_stopped: jnp.ndarray # () bool — terminated by early stopping
    done: jnp.ndarray       # () bool


def _sorted_trunc(ids, dists, expanded, length: int):
    """Sort (dists, ids, expanded) ascending by distance; keep first `length`."""
    dists, ids, expanded = jax.lax.sort(
        (dists, ids, expanded.astype(jnp.int32)), num_keys=1, is_stable=True
    )
    return ids[:length], dists[:length], expanded[:length].astype(bool)


def _f32_ascending_key(x: jnp.ndarray) -> jnp.ndarray:
    """Monotone uint32 re-encoding of f32 (sign-flip trick; handles +-inf).

    XLA sorts integer keys several times faster than float keys (no
    NaN-aware total-order comparator), and the beam merge sits inside the
    traversal's hot loop — distances are finite-or-+inf, never NaN.
    """
    u = jax.lax.bitcast_convert_type(x, jnp.uint32)
    return u ^ (jnp.uint32(0x80000000) + (u >> 31) * jnp.uint32(0x7FFFFFFF))


def _f32_from_key(k: jnp.ndarray) -> jnp.ndarray:
    """Inverse of ``_f32_ascending_key``."""
    u = k ^ jnp.where(k >= jnp.uint32(0x80000000), jnp.uint32(0x80000000),
                      jnp.uint32(0xFFFFFFFF))
    return jax.lax.bitcast_convert_type(u, jnp.float32)


def _merge_sorted(b_ids, b_dists, b_exp, c_ids, c_dists, length: int):
    """Merge the candidate tile into the sorted beam; keep the closest
    ``length``. Returns ``(ids, dists, expanded, entrant)`` where
    ``entrant`` marks output slots filled from the candidate tile.

    No ``lax.sort`` over ``max_beam + E*R`` and no scatter: each element's
    merged *rank* is a broadcast count over the int-keyed distances
    (index-tiebreak makes it an exact permutation; the beam, being first in
    concat order, wins ties), and the output beam gathers from a rank
    one-hot. Profiling drove this shape: XLA lowers vmapped scatters to
    per-update loops and float sort comparators cost ~5x integer compares,
    so the O(M^2) vectorized compare matrix (M = max_beam + E*R, a few
    hundred) beats both a sort-based merge and a scatter placement on CPU,
    and maps onto plain VPU ops on TPU.
    """
    m = b_ids.shape[0] + c_ids.shape[0]
    keys = jnp.concatenate([_f32_ascending_key(b_dists),
                            _f32_ascending_key(c_dists)])
    ids = jnp.concatenate([b_ids, c_ids])
    idx = jnp.arange(m)
    rank = jnp.sum((keys[None, :] < keys[:, None])
                   | ((keys[None, :] == keys[:, None])
                      & (idx[None, :] < idx[:, None])), axis=1)
    hit = rank[None, :] == jnp.arange(length)[:, None]   # (length, M)
    src = jnp.argmax(hit, axis=1)                        # exact: rank is a perm
    out_ids = ids[src]
    out_dists = _f32_from_key(keys[src])
    from_beam = src < b_ids.shape[0]
    out_exp = jnp.where(from_beam, b_exp[jnp.minimum(src, b_ids.shape[0] - 1)],
                        False)
    return out_ids, out_dists, out_exp, ~from_beam


def init_state(
    points: jnp.ndarray,
    q: jnp.ndarray,
    start_ids: jnp.ndarray,
    cfg: SearchConfig,
) -> BeamState:
    """Seed the beam with the start points (usually the medoid)."""
    L, V = cfg.max_beam, cfg.visit_cap
    W = bitset_num_words(corpus_size(points), cfg.bitset_cap_bits)
    s = start_ids.astype(jnp.int32)
    sd = gather_dist(points, s, q, cfg.metric)
    # de-duplicate identical start slots (keep first). Slot-level equality ==
    # id-level equality in the exact-bitset regime; in the hashed regime it
    # additionally collapses colliding buckets, keeping bitset_add exact.
    slot = s % jnp.int32(W * 32)
    order = jnp.arange(s.shape[0])
    dup = (slot[:, None] == slot[None, :]) & (order[:, None] > order[None, :])
    is_dup = jnp.any(dup, axis=1)
    sd = jnp.where(is_dup, jnp.inf, sd)
    s = jnp.where(is_dup, INVALID_ID, s)
    bits = bitset_add(bitset_init(W), s, s != INVALID_ID)

    ids = jnp.full((L,), INVALID_ID, dtype=jnp.int32).at[: s.shape[0]].set(s)
    dists = jnp.full((L,), jnp.inf, dtype=jnp.float32).at[: s.shape[0]].set(sd)
    expanded = jnp.zeros((L,), dtype=bool)
    ids, dists, expanded = _sorted_trunc(ids, dists, expanded, L)
    return BeamState(
        ids=ids,
        dists=dists,
        expanded=expanded,
        active_width=jnp.asarray(cfg.beam, jnp.int32),
        n_visited=jnp.asarray(0, jnp.int32),
        d_visited=jnp.asarray(0.0, jnp.float32),
        d_start=jnp.min(sd),
        visited_ids=jnp.full((V,), INVALID_ID, dtype=jnp.int32),
        visited_dists=jnp.full((V,), jnp.inf, dtype=jnp.float32),
        visited_bits=bits,
        # charge only the distinct starts: duplicate slots were zeroed out
        # above, so a start list padded by repetition (per-lane entry-point
        # selection pads broad lanes with copies of the defaults) costs
        # exactly what the unpadded list does — bitwise-identical states
        n_dist=jnp.sum(s != INVALID_ID).astype(jnp.int32),
        es_stopped=jnp.asarray(False),
        done=jnp.asarray(False),
    )


def _es_value(st: BeamState, cand_dist, cfg: SearchConfig):
    if cfg.es_metric == ES_D_VISITED:
        return cand_dist
    if cfg.es_metric == ES_D_TOP1:
        return st.dists[0]
    if cfg.es_metric == ES_D_TOP10:
        return st.dists[jnp.minimum(9, st.active_width - 1)]
    if cfg.es_metric == ES_RATIO_TOP10:
        return st.dists[jnp.minimum(9, st.active_width - 1)] / jnp.maximum(st.d_start, 1e-30)
    return jnp.asarray(jnp.inf, jnp.float32)


def in_range_count(st: BeamState, r, width: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Number of in-range entries within the first `width` beam slots."""
    w = st.active_width if width is None else width
    pos_ok = jnp.arange(st.ids.shape[0]) < w
    return jnp.sum((st.dists <= r) & (st.ids != INVALID_ID) & pos_ok)


def _expand_tile(points, graph: Graph, frontier, q, cfg: SearchConfig,
                 point_norms=None):
    """Fused expansion of an (E,) frontier: (E*R,) ids/dists + n_dist.

    The Pallas kernel path is opt-in (real TPU; it computes norms in-VMEM);
    the XLA reference is the same fused dataflow and is what CPU CI and dry
    runs execute.
    """
    if cfg.use_expand_kernel:
        ids, dists, nd = expand_frontier(
            points, graph.neighbors, frontier[None], q[None],
            metric=cfg.metric, use_pallas=True, interpret=False)
        return ids[0], dists[0], nd[0]
    return expand_frontier_1(points, graph.neighbors, frontier, q, cfg.metric,
                             point_norms)


def _point_norms(points, cfg: SearchConfig):
    """Optional |x|^2 precompute for the matmul-form distances.

    Disabled (returns None): on CPU a vmapped (T, d) x (d,) matvec dispatches
    as a batched GEMM each iteration and measured *slower* than the fused
    diff-form elementwise pass; the Pallas kernel computes norms in VMEM
    itself, so nothing needs them. Kept as the single switch point should a
    future XLA backend prefer the norm form.
    """
    return None


def _step_reference(points, graph: Graph, q, r, es_radius, cfg: SearchConfig,
                    st: BeamState) -> BeamState:
    """The paper-faithful single-node step (``expand_width=1``).

    This is the pre-fusion dataflow kept verbatim as the correctness and
    performance baseline the fused multi-node path is measured against (the
    smoke gate A/Bs the two): one expansion per iteration, unfused
    ``out_neighbors`` + ``gather_dist``, duplicate suppression by three
    broadcast scans (intra-row, beam, visited log), and a full
    ``lax.sort`` over ``max_beam + R`` entries. The discovery bitset is
    carried through untouched.
    """
    L = cfg.max_beam
    pos = jnp.arange(L)
    eligible = (st.ids != INVALID_ID) & (~st.expanded) & (pos < st.active_width)
    has_frontier = jnp.any(eligible)

    saturated = in_range_count(st, r) >= jnp.ceil(cfg.lam * st.active_width.astype(jnp.float32)).astype(jnp.int32)
    can_widen = (st.active_width < cfg.max_beam) & saturated
    new_width = jnp.where(
        ~has_frontier & can_widen,
        jnp.minimum(st.active_width * 2, cfg.max_beam),
        st.active_width,
    )
    finished = ~has_frontier & ~can_widen

    idx = jnp.argmax(eligible)  # first eligible slot == closest unexpanded
    cand_id = st.ids[idx]
    cand_dist = st.dists[idx]
    found_any = st.dists[0] <= r
    es_on = cfg.es_metric != ES_NONE
    es_trigger = (
        es_on
        & has_frontier
        & (~found_any)
        & (st.n_visited >= cfg.es_visit_limit)
        & (_es_value(st, cand_dist, cfg) > es_radius)
    )

    do_expand = has_frontier & (~es_trigger)

    nbrs = graph.out_neighbors(cand_id)  # (R,)
    nd = gather_dist(points, nbrs, q, cfg.metric)  # (R,) +inf for invalid
    rr = jnp.arange(nbrs.shape[0])
    dup_in_row = jnp.any((nbrs[:, None] == nbrs[None, :]) & (rr[None, :] < rr[:, None]) & (nbrs[:, None] != INVALID_ID), axis=1)
    in_beam = jnp.any((nbrs[:, None] == st.ids[None, :]) & (nbrs[:, None] != INVALID_ID), axis=1)
    in_visited = jnp.any((nbrs[:, None] == st.visited_ids[None, :]) & (nbrs[:, None] != INVALID_ID), axis=1)
    fresh = (~dup_in_row) & (~in_beam) & (~in_visited)
    nd = jnp.where(fresh, nd, jnp.inf)
    nbr_ids = jnp.where(fresh, nbrs, INVALID_ID)

    expanded = st.expanded.at[idx].set(True)
    merged_ids = jnp.concatenate([st.ids, nbr_ids])
    merged_dists = jnp.concatenate([st.dists, nd])
    merged_exp = jnp.concatenate([expanded, jnp.zeros_like(fresh)])
    m_ids, m_dists, m_exp = _sorted_trunc(merged_ids, merged_dists, merged_exp, L)

    v_idx = jnp.minimum(st.n_visited, cfg.visit_cap - 1)
    visited_ids = st.visited_ids.at[v_idx].set(cand_id)
    visited_dists = st.visited_dists.at[v_idx].set(cand_dist)

    exp_state = BeamState(
        ids=m_ids,
        dists=m_dists,
        expanded=m_exp,
        active_width=new_width,
        n_visited=st.n_visited + 1,
        d_visited=cand_dist,
        d_start=st.d_start,
        visited_ids=visited_ids,
        visited_dists=visited_dists,
        visited_bits=st.visited_bits,
        n_dist=st.n_dist + jnp.sum(nbrs != INVALID_ID).astype(jnp.int32),
        es_stopped=st.es_stopped,
        done=(st.n_visited + 1) >= cfg.visit_cap,
    )

    keep_state = dataclasses.replace(
        st,
        active_width=new_width,
        es_stopped=st.es_stopped | es_trigger,
        done=finished | es_trigger,
    )

    return jax.tree.map(
        lambda a, b: jnp.where(do_expand, a, b), exp_state, keep_state
    )


def _step(points, graph: Graph, q, r, es_radius, cfg: SearchConfig, st: BeamState,
          point_norms=None) -> BeamState:
    if cfg.eff_expand_width == 1:
        return _step_reference(points, graph, q, r, es_radius, cfg, st)
    L = cfg.max_beam
    E = cfg.eff_expand_width
    pos = jnp.arange(L)
    eligible = (st.ids != INVALID_ID) & (~st.expanded) & (pos < st.active_width)
    num_elig = jnp.sum(eligible.astype(jnp.int32))
    has_frontier = num_elig > 0

    # -- frontier exhausted at current width: widen (Alg. 5) or finish -------
    saturated = in_range_count(st, r) >= jnp.ceil(cfg.lam * st.active_width.astype(jnp.float32)).astype(jnp.int32)
    can_widen = (st.active_width < cfg.max_beam) & saturated
    new_width = jnp.where(
        ~has_frontier & can_widen,
        jnp.minimum(st.active_width * 2, cfg.max_beam),
        st.active_width,
    )
    finished = ~has_frontier & ~can_widen

    # -- early stopping (Algs. 3/4), evaluated on the closest candidate ------
    idx = jnp.argmax(eligible)  # first eligible slot == closest unexpanded
    cand0_dist = st.dists[idx]
    found_any = st.dists[0] <= r  # never stop once an in-range candidate is known
    es_on = cfg.es_metric != ES_NONE
    es_trigger = (
        es_on
        & has_frontier
        & (~found_any)
        & (st.n_visited >= cfg.es_visit_limit)
        & (_es_value(st, cand0_dist, cfg) > es_radius)
    )

    do_expand = has_frontier & (~es_trigger)

    # -- select the closest E unexpanded slots (beam is sorted) --------------
    # (broadcast one-hots instead of scatters/argsorts throughout this path:
    # XLA lowers vmapped scatters to sequential per-update loops and sort
    # comparators cost ~5x a vectorized compare — both profiled hot spots)
    budget = jnp.asarray(cfg.visit_cap, jnp.int32) - st.n_visited
    e_cnt = jnp.minimum(jnp.minimum(num_elig, E), budget)
    lane = jnp.arange(E)
    lane_ok = lane < e_cnt
    ecum = jnp.cumsum(eligible.astype(jnp.int32))
    sel_hit = (eligible[:, None] & (ecum[:, None] == (lane + 1)[None, :])
               & lane_ok[None, :])                               # (L, E)
    sel = jnp.argmax(sel_hit, axis=0)  # position of the (e+1)-th eligible
    cand_ids = jnp.where(lane_ok, st.ids[sel], INVALID_ID)
    cand_dists = jnp.where(lane_ok, st.dists[sel], jnp.inf)

    # -- fused expansion + bitset seen filter --------------------------------
    nbr_ids, nd, nd_inc = _expand_tile(points, graph, cand_ids, q, cfg,
                                       point_norms)
    valid = nbr_ids != INVALID_ID
    seen = bitset_contains(st.visited_bits, jnp.where(valid, nbr_ids, 0)) & valid
    fresh = valid & ~seen
    nbr_ids = jnp.where(fresh, nbr_ids, INVALID_ID)
    nd = jnp.where(fresh, nd, jnp.inf)

    # -- merge the candidate tile into the sorted beam (rank gather) ---------
    expanded = st.expanded | jnp.any(sel_hit, axis=1)
    m_ids, m_dists, m_exp, entrant = _merge_sorted(
        st.ids, st.dists, expanded, nbr_ids, nd, L)

    # -- mark beam entrants in the seen bitset -------------------------------
    # A node is "seen" once it has ever held a beam slot (start points are
    # marked in init_state); expanded nodes stay marked forever, so no node
    # is expanded twice. Candidates truncated straight off the merge stay
    # unmarked and may be rediscovered — the unfused reference's semantics.
    mark = entrant & (m_ids != INVALID_ID)
    if not bitset_exact(corpus_size(points), st.visited_bits.shape[0]):
        # hashed regime: distinct ids may share a bucket; keep one per slot
        mark = first_slot_occurrence(st.visited_bits, m_ids, mark)
    bits = bitset_add(st.visited_bits, m_ids, mark)

    # -- visited log: one append per expanded node ---------------------------
    v_idx = jnp.where(lane_ok, st.n_visited + lane, cfg.visit_cap)
    v_hit = jnp.arange(cfg.visit_cap)[:, None] == v_idx[None, :]    # (V, E)
    v_any = jnp.any(v_hit, axis=1)
    v_lane = jnp.argmax(v_hit, axis=1)
    visited_ids = jnp.where(v_any, cand_ids[v_lane], st.visited_ids)
    visited_dists = jnp.where(v_any, cand_dists[v_lane], st.visited_dists)

    exp_state = BeamState(
        ids=m_ids,
        dists=m_dists,
        expanded=m_exp,
        active_width=new_width,
        n_visited=st.n_visited + e_cnt,
        d_visited=jnp.max(jnp.where(lane_ok, cand_dists, -jnp.inf)),
        d_start=st.d_start,
        visited_ids=visited_ids,
        visited_dists=visited_dists,
        visited_bits=bits,
        n_dist=st.n_dist + nd_inc,
        es_stopped=st.es_stopped,
        done=(st.n_visited + e_cnt) >= cfg.visit_cap,
    )

    keep_state = dataclasses.replace(
        st,
        active_width=new_width,
        es_stopped=st.es_stopped | es_trigger,
        done=finished | es_trigger,
    )

    return jax.tree.map(
        lambda a, b: jnp.where(do_expand, a, b), exp_state, keep_state
    )


def broadcast_radius(r, n: int, default: float = jnp.inf) -> jnp.ndarray:
    """Normalize a radius argument to a per-query ``(n,)`` float32 vector.

    Accepts ``None`` (-> ``default``, broadcast), a python/np scalar, a 0-d
    array (broadcast to every lane), or an ``(n,)`` vector (returned as-is).
    Every layer of the query path normalizes through here, so scalar call
    sites keep working and all-equal vectors are *the same program* as the
    scalar broadcast — the backbone of the oracle harness's bitwise
    scalar/vector equivalence check.
    """
    if r is None:
        r = default
    r = jnp.asarray(r, jnp.float32)
    if r.ndim == 0:
        return jnp.broadcast_to(r, (n,))
    if r.shape != (n,):
        raise ValueError(f"radius vector has shape {r.shape}, expected ({n},)")
    return r


@partial(jax.jit, static_argnames=("cfg",))
def beam_search(
    points: jnp.ndarray,
    graph: Graph,
    q: jnp.ndarray,
    start_ids: jnp.ndarray,
    r: jnp.ndarray,
    cfg: SearchConfig,
    es_radius: Optional[jnp.ndarray] = None,
) -> BeamState:
    """Run the search loop for one query (``r``/``es_radius`` are scalars;
    the batch entry point below carries them per-lane)."""
    esr = jnp.asarray(jnp.inf, jnp.float32) if es_radius is None else jnp.asarray(es_radius, jnp.float32)
    r = jnp.asarray(r, jnp.float32)
    pnorms = _point_norms(points, cfg)
    st = init_state(points, q, start_ids, cfg)
    st = jax.lax.while_loop(
        lambda s: ~s.done,
        lambda s: _step(points, graph, q, r, esr, cfg, s, pnorms),
        st,
    )
    return st


@partial(jax.jit, static_argnames=("cfg",))
def beam_search_batch(
    points: jnp.ndarray,
    graph: Graph,
    queries: jnp.ndarray,  # (Q, d)
    start_ids: jnp.ndarray,
    r: jnp.ndarray,        # scalar or (Q,) per-query radii
    cfg: SearchConfig,
    es_radius: Optional[jnp.ndarray] = None,  # scalar or (Q,)
) -> BeamState:
    """Batched search; ``r`` and ``es_radius`` are per-lane vmap axes, so a
    single micro-batch may mix radii freely (scalars broadcast).

    ``start_ids`` is shared ``(S,)`` or per-lane ``(Q, S)`` — the filtered
    compacted path seeds selective lanes with posting-list members while
    broad lanes pad the shared defaults by repetition (duplicates collapse
    in ``init_state``, so padding never perturbs the walk)."""
    n = queries.shape[0]
    rv = broadcast_radius(r, n)
    esv = broadcast_radius(es_radius, n)
    if start_ids.ndim == 2:
        fn = lambda q, s_, r_, es_: beam_search(points, graph, q, s_, r_,
                                                cfg, es_)
        return jax.vmap(fn)(queries, start_ids, rv, esv)
    fn = lambda q, r_, es_: beam_search(points, graph, q, start_ids, r_, cfg, es_)
    return jax.vmap(fn)(queries, rv, esv)


def topk_from_state(st: BeamState, k: int):
    """Top-k (ids, dists) from a finished search (standard ANNS answer)."""
    return st.ids[..., :k], st.dists[..., :k]
