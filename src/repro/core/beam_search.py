"""Batched graph beam search with range-retrieval extensions.

This implements the paper's Algorithms 1 (BeamSearch), 3/4 (EarlyStopping) and
5 (DoublingSearch) as a single fixed-shape ``jax.lax.while_loop``:

* The beam is a distance-sorted triple ``(ids, dists, expanded)`` of length
  ``max_beam`` (the hardware allocation), of which only the first
  ``active_width`` entries are *eligible for expansion* — ``active_width`` is
  the paper's beam size ``b``.
* **Doubling** (Alg. 5) is performed *in place*: when the active prefix is
  fully expanded and at least ``lam * b`` of it is in-range, ``b`` doubles
  (up to ``max_beam``) and the same loop continues. This is our TPU adaptation
  A1 (see DESIGN.md §2): it visits a superset of the restart-based variant's
  candidates with strictly fewer re-expansions.
* **Early stopping** (Algs. 3/4) is evaluated before each expansion using one
  of the paper's four metrics (``d_visited`` — the recommended one —
  ``d_top1``, ``d_top10``, or ``d_top10 / d_start``). A search that has
  already found an in-range candidate never early-stops (paper Sec. 4.3).
* Every expansion is appended to a visited log (capacity ``visit_cap``); the
  log is what Vamana's RobustPrune consumes at build time and what greedy
  range search seeds from.

Single-query semantics are written once and batched with ``jax.vmap``; the
vmapped while-loop steps all queries until every lane is done (lanes that
finish early are frozen by the batching rule — the query-compaction machinery
in ``range_search.py`` exists precisely to bound that straggler effect).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..utils import INVALID_ID
from .distances import gather_dist, point_dist
from .graph import Graph

# Early-stop metric selector (paper Sec. 4.3). Static ints so jit specializes.
ES_NONE = 0
ES_D_VISITED = 1   # distance to the node being visited (paper's best)
ES_D_TOP1 = 2      # distance to closest known neighbor
ES_D_TOP10 = 3     # distance to 10th closest known neighbor
ES_RATIO_TOP10 = 4 # d_top10 / d_start


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Static search hyper-parameters (hashable; a jit static argument)."""

    beam: int = 64            # initial beam width b (paper's B)
    max_beam: int = 64        # allocation; > beam enables in-place doubling
    visit_cap: int = 256      # max expansions == visited-log capacity
    lam: float = 1.0          # λ: in-range fraction of beam that triggers widening
    es_metric: int = ES_NONE  # early-stopping metric (ES_*)
    es_visit_limit: int = 20  # vl: expansions before early stop may trigger
    metric: str = "l2"

    def __post_init__(self):
        if self.beam < 1 or self.max_beam < self.beam:
            raise ValueError("need 1 <= beam <= max_beam")
        if self.visit_cap < 1:
            raise ValueError("visit_cap must be >= 1")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BeamState:
    """Per-query search state (batched by vmap on the leading axis)."""

    ids: jnp.ndarray        # (L,) int32, distance-sorted, INVALID_ID padded
    dists: jnp.ndarray      # (L,) float32, +inf padded
    expanded: jnp.ndarray   # (L,) bool
    active_width: jnp.ndarray  # () int32 — the paper's b
    n_visited: jnp.ndarray  # () int32
    d_visited: jnp.ndarray  # () float32 — last expanded node's distance
    d_start: jnp.ndarray    # () float32 — distance to the search entry point
    visited_ids: jnp.ndarray    # (V,) int32 log of expanded nodes
    visited_dists: jnp.ndarray  # (V,) float32
    n_dist: jnp.ndarray     # () int32 distance-computation counter
    es_stopped: jnp.ndarray # () bool — terminated by early stopping
    done: jnp.ndarray       # () bool


def _sorted_trunc(ids, dists, expanded, length: int):
    """Sort (dists, ids, expanded) ascending by distance; keep first `length`."""
    dists, ids, expanded = jax.lax.sort(
        (dists, ids, expanded.astype(jnp.int32)), num_keys=1, is_stable=True
    )
    return ids[:length], dists[:length], expanded[:length].astype(bool)


def init_state(
    points: jnp.ndarray,
    q: jnp.ndarray,
    start_ids: jnp.ndarray,
    cfg: SearchConfig,
) -> BeamState:
    """Seed the beam with the start points (usually the medoid)."""
    L, V = cfg.max_beam, cfg.visit_cap
    s = start_ids.astype(jnp.int32)
    sd = gather_dist(points, s, q, cfg.metric)
    # de-duplicate identical start ids (keep first)
    dup = (s[:, None] == s[None, :]) & (jnp.arange(s.shape[0])[:, None] > jnp.arange(s.shape[0])[None, :])
    is_dup = jnp.any(dup, axis=1)
    sd = jnp.where(is_dup, jnp.inf, sd)
    s = jnp.where(is_dup, INVALID_ID, s)

    ids = jnp.full((L,), INVALID_ID, dtype=jnp.int32).at[: s.shape[0]].set(s)
    dists = jnp.full((L,), jnp.inf, dtype=jnp.float32).at[: s.shape[0]].set(sd)
    expanded = jnp.zeros((L,), dtype=bool)
    ids, dists, expanded = _sorted_trunc(ids, dists, expanded, L)
    return BeamState(
        ids=ids,
        dists=dists,
        expanded=expanded,
        active_width=jnp.asarray(cfg.beam, jnp.int32),
        n_visited=jnp.asarray(0, jnp.int32),
        d_visited=jnp.asarray(0.0, jnp.float32),
        d_start=jnp.min(sd),
        visited_ids=jnp.full((V,), INVALID_ID, dtype=jnp.int32),
        visited_dists=jnp.full((V,), jnp.inf, dtype=jnp.float32),
        n_dist=jnp.asarray(s.shape[0], jnp.int32),
        es_stopped=jnp.asarray(False),
        done=jnp.asarray(False),
    )


def _es_value(st: BeamState, cand_dist, cfg: SearchConfig):
    if cfg.es_metric == ES_D_VISITED:
        return cand_dist
    if cfg.es_metric == ES_D_TOP1:
        return st.dists[0]
    if cfg.es_metric == ES_D_TOP10:
        return st.dists[jnp.minimum(9, st.active_width - 1)]
    if cfg.es_metric == ES_RATIO_TOP10:
        return st.dists[jnp.minimum(9, st.active_width - 1)] / jnp.maximum(st.d_start, 1e-30)
    return jnp.asarray(jnp.inf, jnp.float32)


def in_range_count(st: BeamState, r, width: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Number of in-range entries within the first `width` beam slots."""
    w = st.active_width if width is None else width
    pos_ok = jnp.arange(st.ids.shape[0]) < w
    return jnp.sum((st.dists <= r) & (st.ids != INVALID_ID) & pos_ok)


def _step(points, graph: Graph, q, r, es_radius, cfg: SearchConfig, st: BeamState) -> BeamState:
    L = cfg.max_beam
    pos = jnp.arange(L)
    eligible = (st.ids != INVALID_ID) & (~st.expanded) & (pos < st.active_width)
    has_frontier = jnp.any(eligible)

    # -- frontier exhausted at current width: widen (Alg. 5) or finish -------
    saturated = in_range_count(st, r) >= jnp.ceil(cfg.lam * st.active_width.astype(jnp.float32)).astype(jnp.int32)
    can_widen = (st.active_width < cfg.max_beam) & saturated
    new_width = jnp.where(
        ~has_frontier & can_widen,
        jnp.minimum(st.active_width * 2, cfg.max_beam),
        st.active_width,
    )
    finished = ~has_frontier & ~can_widen

    # -- early stopping (Algs. 3/4), evaluated before the expansion ----------
    idx = jnp.argmax(eligible)  # first eligible slot == closest unexpanded
    cand_id = st.ids[idx]
    cand_dist = st.dists[idx]
    found_any = st.dists[0] <= r  # never stop once an in-range candidate is known
    es_on = cfg.es_metric != ES_NONE
    es_trigger = (
        es_on
        & has_frontier
        & (~found_any)
        & (st.n_visited >= cfg.es_visit_limit)
        & (_es_value(st, cand_dist, cfg) > es_radius)
    )

    do_expand = has_frontier & (~es_trigger)

    # -- expansion ------------------------------------------------------------
    nbrs = graph.out_neighbors(cand_id)  # (R,)
    nd = gather_dist(points, nbrs, q, cfg.metric)  # (R,) +inf for invalid
    # intra-row duplicate suppression
    rr = jnp.arange(nbrs.shape[0])
    dup_in_row = jnp.any((nbrs[:, None] == nbrs[None, :]) & (rr[None, :] < rr[:, None]) & (nbrs[:, None] != INVALID_ID), axis=1)
    # duplicates against the beam and the visited log
    in_beam = jnp.any((nbrs[:, None] == st.ids[None, :]) & (nbrs[:, None] != INVALID_ID), axis=1)
    in_visited = jnp.any((nbrs[:, None] == st.visited_ids[None, :]) & (nbrs[:, None] != INVALID_ID), axis=1)
    fresh = (~dup_in_row) & (~in_beam) & (~in_visited)
    nd = jnp.where(fresh, nd, jnp.inf)
    nbr_ids = jnp.where(fresh, nbrs, INVALID_ID)

    expanded = st.expanded.at[idx].set(True)
    merged_ids = jnp.concatenate([st.ids, nbr_ids])
    merged_dists = jnp.concatenate([st.dists, nd])
    merged_exp = jnp.concatenate([expanded, jnp.zeros_like(fresh)])
    m_ids, m_dists, m_exp = _sorted_trunc(merged_ids, merged_dists, merged_exp, L)

    v_idx = jnp.minimum(st.n_visited, cfg.visit_cap - 1)
    visited_ids = st.visited_ids.at[v_idx].set(cand_id)
    visited_dists = st.visited_dists.at[v_idx].set(cand_dist)

    exp_state = BeamState(
        ids=m_ids,
        dists=m_dists,
        expanded=m_exp,
        active_width=new_width,
        n_visited=st.n_visited + 1,
        d_visited=cand_dist,
        d_start=st.d_start,
        visited_ids=visited_ids,
        visited_dists=visited_dists,
        n_dist=st.n_dist + jnp.sum(nbrs != INVALID_ID).astype(jnp.int32),
        es_stopped=st.es_stopped,
        done=(st.n_visited + 1) >= cfg.visit_cap,
    )

    keep_state = dataclasses.replace(
        st,
        active_width=new_width,
        es_stopped=st.es_stopped | es_trigger,
        done=finished | es_trigger,
    )

    return jax.tree.map(
        lambda a, b: jnp.where(do_expand, a, b), exp_state, keep_state
    )


@partial(jax.jit, static_argnames=("cfg",))
def beam_search(
    points: jnp.ndarray,
    graph: Graph,
    q: jnp.ndarray,
    start_ids: jnp.ndarray,
    r: jnp.ndarray,
    cfg: SearchConfig,
    es_radius: Optional[jnp.ndarray] = None,
) -> BeamState:
    """Run the search loop for one query. vmap over ``q`` for batches."""
    esr = jnp.asarray(jnp.inf, jnp.float32) if es_radius is None else jnp.asarray(es_radius, jnp.float32)
    r = jnp.asarray(r, jnp.float32)
    st = init_state(points, q, start_ids, cfg)
    st = jax.lax.while_loop(
        lambda s: ~s.done,
        lambda s: _step(points, graph, q, r, esr, cfg, s),
        st,
    )
    return st


@partial(jax.jit, static_argnames=("cfg",))
def beam_search_batch(
    points: jnp.ndarray,
    graph: Graph,
    queries: jnp.ndarray,  # (Q, d)
    start_ids: jnp.ndarray,
    r: jnp.ndarray,
    cfg: SearchConfig,
    es_radius: Optional[jnp.ndarray] = None,
) -> BeamState:
    esr = jnp.asarray(jnp.inf, jnp.float32) if es_radius is None else jnp.asarray(es_radius, jnp.float32)
    fn = lambda q: beam_search(points, graph, q, start_ids, jnp.asarray(r, jnp.float32), cfg, esr)
    return jax.vmap(fn)(queries)


def topk_from_state(st: BeamState, k: int):
    """Top-k (ids, dists) from a finished search (standard ANNS answer)."""
    return st.ids[..., :k], st.dists[..., :k]
