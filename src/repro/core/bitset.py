"""Packed per-query visited bitset for the search loops.

The traversal's "have I seen this node?" test used to be an
O(R * visit_cap) broadcast against the visited log plus an O(R * max_beam)
broadcast against the beam, *per expansion*. Marking every node at
**discovery** time (when it is first inserted into the beam — the standard
GPU graph-ANNS hash-table-visited semantics) collapses both tests into one
O(1)-per-candidate bit probe into a packed ``(W,) uint32`` array.

Sizing: ``W = ceil(min(N, cap_bits) / 32)`` words. Below ``cap_bits`` the
filter is **exact** (bit index == node id). Above it, ids are hash-bucketed
by ``id mod (W * 32)``, so memory stays bounded at billion scale
(``cap_bits`` defaults to 2^20 bits == 128 KiB per in-flight query) at the
cost of rare false-positive "seen" verdicts — a recall approximation, never
a correctness hazard (a false positive only skips a candidate).

All ops are branch-free jnp and vmap/while_loop friendly. ``bitset_add``
accumulates with a scatter-*add*, which is exact only when the marked slots
are unique and currently clear — callers dedup candidate tiles first (see
``first_slot_occurrence``) and only mark candidates that failed the
``bitset_contains`` probe.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..utils import cdiv

# Per-query filter memory bound: 2^20 bits == 128 KiB. Corpora beyond a
# million nodes hash-bucket into this (see module docstring).
DEFAULT_BITSET_CAP_BITS = 1 << 20


def bitset_num_words(n_nodes: int, cap_bits: int = DEFAULT_BITSET_CAP_BITS) -> int:
    """Number of uint32 words for a corpus of ``n_nodes`` points (static)."""
    return cdiv(min(max(int(n_nodes), 1), int(cap_bits)), 32)


def bitset_exact(n_nodes: int, num_words: int) -> bool:
    """True when every node id gets its own bit (no hash bucketing)."""
    return int(n_nodes) <= num_words * 32


def bitset_init(num_words: int) -> jnp.ndarray:
    return jnp.zeros((num_words,), jnp.uint32)


def _slots(bits: jnp.ndarray, ids: jnp.ndarray):
    nb = bits.shape[0] * 32
    slot = ids % nb  # identity when the filter is exact (ids < nb)
    return slot // 32, (slot % 32).astype(jnp.uint32)


def bitset_contains(bits: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Membership probe. ``ids`` must be non-negative; callers mask INVALID
    lanes themselves (an INVALID id probes a junk bucket)."""
    w, b = _slots(bits, ids)
    word = jnp.take(bits, w, axis=0)
    return ((word >> b) & jnp.uint32(1)).astype(bool)


# Below this many word*tile cells, marking uses a dense broadcast-OR
# (word-equality matrix x bitmask, summed per word) instead of a scatter.
# XLA lowers vmapped scatters to sequential per-update loops — on CPU that
# made scatter the single hottest op of the search loop; the broadcast is
# pure vectorized compare/sum. Scatter remains for huge hash-bucketed
# filters where the dense matrix would dwarf the tile.
_DENSE_ADD_CELLS = 1 << 22


def bitset_add(bits: jnp.ndarray, ids: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Set the bits of ``ids`` where ``mask``.

    Accumulates by addition (jnp has no scatter-or), which is exact iff
    masked slots are pairwise distinct and currently clear — the calling
    convention is: probe with ``bitset_contains`` first, dedup the tile,
    then add.
    """
    w, b = _slots(bits, ids)
    m = jnp.where(mask, jnp.uint32(1) << b, jnp.uint32(0))
    n_words = bits.shape[0]
    if n_words * ids.shape[0] <= _DENSE_ADD_CELLS:
        hit = w[None, :] == jnp.arange(n_words)[:, None]      # (W, T)
        return bits + jnp.sum(jnp.where(hit, m[None, :], 0), axis=1,
                              dtype=jnp.uint32)
    wi = jnp.where(mask, w, n_words)  # out-of-bounds -> dropped
    return bits.at[wi].add(m, mode="drop")


def first_slot_occurrence(bits: jnp.ndarray, ids: jnp.ndarray,
                          valid: jnp.ndarray) -> jnp.ndarray:
    """Mask of entries that are the first occurrence of their *slot* in the
    tile. Needed before ``bitset_add`` in the hash-bucketed regime, where two
    distinct ids can share a bucket (in the exact regime an id-level dedup
    implies slot uniqueness). Stable slot-sort, O(T log T): equal slots form
    runs in original order, each run's head is its first occurrence."""
    nb = bits.shape[0] * 32
    slot = jnp.where(valid, ids % nb, nb)  # invalid entries sort to the end
    order = jnp.argsort(slot, stable=True)
    sorted_slots = slot[order]
    head = jnp.concatenate([jnp.ones((1,), bool),
                            sorted_slots[1:] != sorted_slots[:-1]])
    return jnp.zeros_like(valid).at[order].set(head) & valid
