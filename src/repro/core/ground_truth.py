"""Exact (brute-force) range search and top-k — the oracle for everything.

Blocked over the database so memory stays bounded; the inner block distance is
a single matmul (MXU-shaped). ``kernels/rangescan`` is the Pallas version of
the same computation; this module is the reference and the CPU path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..utils import INVALID_ID, cdiv
from .distances import pairwise_dist


@partial(jax.jit, static_argnames=("metric", "cap", "block"))
def exact_range_search(
    points: jnp.ndarray,   # (N, d)
    queries: jnp.ndarray,  # (Q, d)
    r: jnp.ndarray,
    metric: str = "l2",
    cap: int = 4096,
    block: int = 8192,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (ids (Q, cap), dists (Q, cap), counts (Q,)).

    ``r`` is a scalar radius shared by the batch or a ``(Q,)`` vector of
    per-query radii. ``counts`` is exact even when it exceeds ``cap``;
    ids/dists keep the ``cap`` closest in-range points (sorted ascending).
    """
    n, d = points.shape
    q = queries.shape[0]
    r = jnp.asarray(r, jnp.float32)
    rb = r[:, None] if r.ndim == 1 else r  # (Q, 1) broadcasts against (Q, block)
    nb = cdiv(n, block)
    npad = nb * block
    pts = jnp.pad(points, ((0, npad - n), (0, 0)))

    def body(carry, bi):
        ids, dists, counts = carry
        start = bi * block
        blk = jax.lax.dynamic_slice_in_dim(pts, start, block, axis=0)
        bd = pairwise_dist(queries, blk, metric)  # (Q, block)
        bids = start + jnp.arange(block, dtype=jnp.int32)
        ok = (bd <= rb) & (bids[None, :] < n)
        counts = counts + jnp.sum(ok, axis=1).astype(jnp.int32)
        bd = jnp.where(ok, bd, jnp.inf)
        bi_ids = jnp.where(ok, bids[None, :], INVALID_ID)
        md = jnp.concatenate([dists, bd], axis=1)
        mi = jnp.concatenate([ids, jnp.broadcast_to(bi_ids, (q, block))], axis=1)
        md, mi = jax.lax.sort((md, mi), num_keys=1, is_stable=True)
        return (mi[:, :cap], md[:, :cap], counts), None

    ids0 = jnp.full((q, cap), INVALID_ID, jnp.int32)
    dists0 = jnp.full((q, cap), jnp.inf, jnp.float32)
    counts0 = jnp.zeros((q,), jnp.int32)
    (ids, dists, counts), _ = jax.lax.scan(body, (ids0, dists0, counts0), jnp.arange(nb))
    return ids, dists, counts


@partial(jax.jit, static_argnames=("metric", "k", "block"))
def exact_topk(
    points: jnp.ndarray,
    queries: jnp.ndarray,
    k: int = 10,
    metric: str = "l2",
    block: int = 8192,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact k nearest neighbors: (ids (Q, k), dists (Q, k))."""
    n, d = points.shape
    q = queries.shape[0]
    nb = cdiv(n, block)
    npad = nb * block
    pts = jnp.pad(points, ((0, npad - n), (0, 0)))

    def body(carry, bi):
        ids, dists = carry
        start = bi * block
        blk = jax.lax.dynamic_slice_in_dim(pts, start, block, axis=0)
        bd = pairwise_dist(queries, blk, metric)
        bids = start + jnp.arange(block, dtype=jnp.int32)
        valid = bids[None, :] < n
        bd = jnp.where(valid, bd, jnp.inf)
        md = jnp.concatenate([dists, bd], axis=1)
        mi = jnp.concatenate([ids, jnp.broadcast_to(jnp.where(valid, bids[None, :], INVALID_ID), (q, block))], axis=1)
        md, mi = jax.lax.sort((md, mi), num_keys=1, is_stable=True)
        return (mi[:, :k], md[:, :k]), None

    ids0 = jnp.full((q, k), INVALID_ID, jnp.int32)
    dists0 = jnp.full((q, k), jnp.inf, jnp.float32)
    (ids, dists), _ = jax.lax.scan(body, (ids0, dists0), jnp.arange(nb))
    return ids, dists


@partial(jax.jit, static_argnames=("metric", "block"))
def range_counts_at(
    points: jnp.ndarray,
    queries: jnp.ndarray,
    radii: jnp.ndarray,  # (G,) radius grid
    metric: str = "l2",
    block: int = 2048,
) -> jnp.ndarray:
    """(Q, G) exact match counts at each radius (Sec. 3 capture curves)."""
    n, _ = points.shape
    q = queries.shape[0]
    nb = cdiv(n, block)
    npad = nb * block
    pts = jnp.pad(points, ((0, npad - n), (0, 0)))

    def body(counts, bi):
        start = bi * block
        blk = jax.lax.dynamic_slice_in_dim(pts, start, block, axis=0)
        bd = pairwise_dist(queries, blk, metric)  # (Q, block)
        valid = (start + jnp.arange(block)) < n
        hit = (bd[:, :, None] <= radii[None, None, :]) & valid[None, :, None]
        return counts + jnp.sum(hit, axis=1).astype(jnp.int32), None

    counts0 = jnp.zeros((q, radii.shape[0]), jnp.int32)
    counts, _ = jax.lax.scan(body, counts0, jnp.arange(nb))
    return counts
