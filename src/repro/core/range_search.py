"""Range-retrieval algorithms on top of the beam search (paper Algs. 2/5/6).

Three modes, matching the paper:

* ``"beam"``     — the naive baseline: one beam search, filter the beam by r.
* ``"doubling"`` — Alg. 5 via in-place beam widening (``max_beam > beam``).
* ``"greedy"``   — Alg. 6: initial beam search; queries whose beam is
  saturated with in-range results continue with Alg. 2 (expand only in-range
  nodes, unbounded queue -> fixed-capacity result buffer + overflow counter).

Batched execution is two-phase with **query compaction** (DESIGN.md §2): the
uniform phase 1 runs over the whole batch; the irregular phase 2 runs only on
the compacted subset of queries that need it (bucketed to powers of two so jit
compiles O(log Q) variants). ``range_search_fused`` keeps everything in one
XLA program (no host sync) for dry-run lowering and single-dispatch serving.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..utils import INVALID_ID, next_pow2
from .beam_search import (
    BeamState,
    SearchConfig,
    _expand_tile,
    _f32_ascending_key,
    _f32_from_key,
    _point_norms,
    beam_search_batch,
    broadcast_radius,
    in_range_count,
)
from .bitset import (
    bitset_add,
    bitset_contains,
    bitset_exact,
    bitset_init,
    bitset_num_words,
    first_slot_occurrence,
)
from .corpus import QuantizedCorpus, corpus_size, upper_bound_dists
from .distances import gather_dist, point_dist
from .graph import Graph
from .labels import LabelFilter, label_match_matrix, labels_match


@dataclasses.dataclass(frozen=True)
class RangeConfig:
    """Static configuration for a range query batch."""

    search: SearchConfig = dataclasses.field(default_factory=SearchConfig)
    mode: str = "greedy"          # beam | doubling | greedy
    result_cap: int = 1024        # K_cap: per-query result buffer
    frontier_rounds: int = 4096   # greedy expansion budget (expansions/query)
    lam: float = 1.0              # λ threshold for entering phase 2
    # quantized-corpus two-pass: exact-rerank the guard-band boundary after
    # the approximate search (requires the corpus to carry raw vectors).
    # False keeps the guard-banded superset (keep band d_hat <= r + eps) —
    # the pre-rerank membership the oracle superset test pins down.
    rerank: bool = True
    # filtered retrieval: when a lane's predicate matches fewer than this
    # fraction of the corpus, the compacted path answers it by brute-
    # scanning the posting list with the exact kernel instead of walking
    # the graph (FilterGraph's _threshold dispatch). 0 disables the
    # fallback; the fused single-program path always walks (it has no host
    # sync to split lanes across programs).
    filter_threshold: float = 0.0

    def __post_init__(self):
        if self.mode not in ("beam", "doubling", "greedy"):
            raise ValueError(f"bad mode {self.mode!r}")
        if self.mode == "doubling" and self.search.max_beam <= self.search.beam:
            raise ValueError("doubling mode needs search.max_beam > search.beam")
        if not 0.0 <= self.filter_threshold <= 1.0:
            raise ValueError("filter_threshold must be in [0, 1]")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RangeResult:
    """Batched range-query output (all arrays INVALID/inf padded)."""

    ids: jnp.ndarray       # (Q, K) int32
    dists: jnp.ndarray     # (Q, K) float32
    count: jnp.ndarray     # (Q,) int32 — number of valid entries
    overflow: jnp.ndarray  # (Q,) bool — K_cap or budget exceeded
    n_visited: jnp.ndarray # (Q,) int32 — phase-1 expansions
    n_dist: jnp.ndarray    # (Q,) int32 — total distance computations
    es_stopped: jnp.ndarray  # (Q,) bool
    phase2: jnp.ndarray    # (Q,) bool — query took the second phase
    n_rerank: jnp.ndarray  # (Q,) int32 — guard-band candidates exact-reranked


# ---------------------------------------------------------------------------
# Greedy continuation (paper Alg. 2), fixed-shape form.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GreedyState:
    res_ids: jnp.ndarray    # (K,) int32 — every id here is in-range
    res_dists: jnp.ndarray  # (K,) float32
    res_count: jnp.ndarray  # () int32
    expand_ptr: jnp.ndarray # () int32
    rounds: jnp.ndarray     # () int32
    overflow: jnp.ndarray   # () bool
    n_dist: jnp.ndarray     # () int32
    seen_bits: jnp.ndarray  # (W,) uint32 — result-membership bitset


def _greedy_init(st: BeamState, r, cap: int, num_words: int,
                 exact_bits: bool) -> GreedyState:
    """Seed the result buffer with every in-range node whose exact distance is
    already known: the visited log plus unexpanded in-range beam entries
    (disjoint by construction — expanded beam nodes are in the log). The
    result membership is mirrored into a bitset so the per-expansion "already
    a result?" test is an O(1) probe, not an O(result_cap) broadcast."""
    v_ok = st.visited_dists <= r
    b_ok = (st.dists <= r) & (~st.expanded) & (st.ids != INVALID_ID)
    ids = jnp.concatenate([jnp.where(v_ok, st.visited_ids, INVALID_ID),
                           jnp.where(b_ok, st.ids, INVALID_ID)])
    dists = jnp.concatenate([jnp.where(v_ok, st.visited_dists, jnp.inf),
                             jnp.where(b_ok, st.dists, jnp.inf)])
    # pack in-range entries to the front, closest first (paper pops
    # closest-first; our FIFO expansion then visits in that order)
    _, ids, dists = jax.lax.sort((_f32_ascending_key(dists), ids, dists),
                                 num_keys=1, is_stable=True)
    k = min(cap, ids.shape[0])
    res_ids = jnp.full((cap,), INVALID_ID, jnp.int32).at[:k].set(ids[:k])
    res_dists = jnp.full((cap,), jnp.inf, jnp.float32).at[:k].set(dists[:k])
    total = jnp.sum(jnp.isfinite(dists))
    count = jnp.minimum(total, cap)
    bits = bitset_init(num_words)
    seed_ok = res_ids != INVALID_ID  # unique ids by construction
    if not exact_bits:  # hashed regime: collapse colliding buckets first
        seed_ok = first_slot_occurrence(bits, res_ids, seed_ok)
    bits = bitset_add(bits, res_ids, seed_ok)
    return GreedyState(
        res_ids=res_ids,
        res_dists=res_dists,
        res_count=count.astype(jnp.int32),
        expand_ptr=jnp.asarray(0, jnp.int32),
        rounds=jnp.asarray(0, jnp.int32),
        overflow=(total > cap),
        n_dist=jnp.asarray(0, jnp.int32),
        seen_bits=bits,
    )


def _greedy_step_reference(points, graph: Graph, q, r, cap: int,
                           scfg: SearchConfig, gs: GreedyState,
                           exact_bits: bool = False) -> GreedyState:
    """Single-node greedy step (``expand_width=1``): the pre-fusion dataflow,
    kept as the baseline the fused path is measured against.

    Membership testing has a fast path: when the discovery bitset is
    *exact* (one bit per corpus node — ``bitset_exact``), probing
    ``seen_bits`` is semantically identical to the original O(R * cap)
    broadcast against the result buffer, because ``_greedy_init`` seeds the
    bitset with exactly the buffer's members and this step mirrors every
    append into it. (Cap-dropped neighbors are marked too; re-encountering
    one under the broadcast would re-count it as "new" and re-drop it —
    same buffer, count, and overflow flag either way, since the buffer only
    grows. Verified by the E=1-vs-fused parity test in tests/test_oracle.py,
    which pins the two dataflows to identical result sets on both f32 and
    quantized corpora.) In the *hashed* regime distinct ids share buckets,
    where a probe could report false membership — there the reference keeps
    the paper-faithful broadcast, so ``expand_width=1`` stays a valid
    baseline at every corpus scale."""
    node = gs.res_ids[gs.expand_ptr]
    nbrs = graph.out_neighbors(node)  # (R,)
    nd = gather_dist(points, nbrs, q, scfg.metric)
    rr = jnp.arange(nbrs.shape[0])
    dup_in_row = jnp.any(
        (nbrs[:, None] == nbrs[None, :]) & (rr[None, :] < rr[:, None]) & (nbrs[:, None] != INVALID_ID),
        axis=1,
    )
    if exact_bits:
        seen = bitset_contains(gs.seen_bits,
                               jnp.where(nbrs != INVALID_ID, nbrs, 0))
    else:
        seen = jnp.any((nbrs[:, None] == gs.res_ids[None, :]) & (nbrs[:, None] != INVALID_ID), axis=1)
    new = (nd <= r) & (~dup_in_row) & (~seen) & (nbrs != INVALID_ID)
    pos = gs.res_count + jnp.cumsum(new.astype(jnp.int32)) - 1
    write_pos = jnp.where(new & (pos < cap), pos, cap)  # cap == OOB -> dropped
    res_ids = gs.res_ids.at[write_pos].set(nbrs, mode="drop")
    res_dists = gs.res_dists.at[write_pos].set(nd, mode="drop")
    n_new = jnp.sum(new.astype(jnp.int32))
    return GreedyState(
        res_ids=res_ids,
        res_dists=res_dists,
        res_count=jnp.minimum(gs.res_count + n_new, cap),
        expand_ptr=gs.expand_ptr + 1,
        rounds=gs.rounds + 1,
        overflow=gs.overflow | (gs.res_count + n_new > cap),
        n_dist=gs.n_dist + jnp.sum(nbrs != INVALID_ID).astype(jnp.int32),
        seen_bits=bitset_add(gs.seen_bits, nbrs, new) if exact_bits
        else gs.seen_bits,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class _PackedGreedyState:
    """Loop carry of the fused (E >= 2) greedy phase. ``res`` packs
    ``[id, uint32-distance-key]`` per row so the append is ONE bounded
    scatter instead of two (XLA scatter cost is per-update overhead, not
    bytes — the two-buffer form profiled as ~40% of the greedy loop; a
    batched ``dynamic_update_slice`` window write was also tried and lost,
    since a per-lane start index turns DUS into a whole-buffer scatter
    under vmap). Unpacked into ``GreedyState`` after the loop."""

    res: jnp.ndarray        # (K, 2) int32 — [id, dist key (bitcast)]
    res_count: jnp.ndarray  # () int32
    expand_ptr: jnp.ndarray # () int32
    rounds: jnp.ndarray     # () int32
    overflow: jnp.ndarray   # () bool
    n_dist: jnp.ndarray     # () int32
    seen_bits: jnp.ndarray  # (W,) uint32


def _pack_greedy(gs: GreedyState) -> _PackedGreedyState:
    key = jax.lax.bitcast_convert_type(_f32_ascending_key(gs.res_dists),
                                       jnp.int32)
    return _PackedGreedyState(
        res=jnp.stack([gs.res_ids, key], axis=1),
        res_count=gs.res_count, expand_ptr=gs.expand_ptr, rounds=gs.rounds,
        overflow=gs.overflow, n_dist=gs.n_dist, seen_bits=gs.seen_bits)


def _unpack_greedy(ps: _PackedGreedyState) -> GreedyState:
    return GreedyState(
        res_ids=ps.res[:, 0],
        res_dists=_f32_from_key(
            jax.lax.bitcast_convert_type(ps.res[:, 1], jnp.uint32)),
        res_count=ps.res_count, expand_ptr=ps.expand_ptr, rounds=ps.rounds,
        overflow=ps.overflow, n_dist=ps.n_dist, seen_bits=ps.seen_bits)


def _greedy_step(points, graph: Graph, q, r, cap: int, scfg: SearchConfig,
                 gs: _PackedGreedyState, point_norms=None) -> _PackedGreedyState:
    """Expand the next E result-buffer entries through the fused expand path
    (same kernel as phase 1), appending fresh in-range neighbors.

    The membership probe is the bitset — the reference path's O(R * cap)
    result-buffer broadcast is the dominant cost this replaces."""
    E = scfg.eff_expand_width
    lane = jnp.arange(E)
    e_cnt = jnp.minimum(jnp.asarray(E, jnp.int32), gs.res_count - gs.expand_ptr)
    lane_ok = lane < e_cnt
    ridx = jnp.minimum(gs.expand_ptr + lane, cap - 1)
    nodes = jnp.where(lane_ok, jnp.take(gs.res[:, 0], ridx), INVALID_ID)

    nbr_ids, nd, nd_inc = _expand_tile(points, graph, nodes, q, scfg,
                                       point_norms)
    valid = nbr_ids != INVALID_ID
    seen = bitset_contains(gs.seen_bits, jnp.where(valid, nbr_ids, 0)) & valid
    new = valid & ~seen & (nd <= r)
    if not bitset_exact(corpus_size(points), gs.seen_bits.shape[0]):
        new = first_slot_occurrence(gs.seen_bits, nbr_ids, new)

    pos = gs.res_count + jnp.cumsum(new.astype(jnp.int32)) - 1
    write_pos = jnp.where(new & (pos < cap), pos, cap)  # cap == OOB -> dropped
    key = jax.lax.bitcast_convert_type(_f32_ascending_key(nd), jnp.int32)
    rows = jnp.stack([nbr_ids, key], axis=1)             # (T, 2)
    res = gs.res.at[write_pos].set(rows, mode="drop")
    n_new = jnp.sum(new.astype(jnp.int32))
    return _PackedGreedyState(
        res=res,
        res_count=jnp.minimum(gs.res_count + n_new, cap),
        expand_ptr=gs.expand_ptr + e_cnt,
        rounds=gs.rounds + e_cnt,
        overflow=gs.overflow | (gs.res_count + n_new > cap),
        n_dist=gs.n_dist + nd_inc,
        # mark every fresh in-range neighbor, including cap-dropped ones (the
        # buffer only ever grows, so a dropped node could never land later)
        seen_bits=bitset_add(gs.seen_bits, nbr_ids, new),
    )


def _greedy_run(points, graph: Graph, q, r, gs: GreedyState, cap: int,
                stop_at, scfg: SearchConfig, active) -> GreedyState:
    """Advance one lane's greedy continuation until its frontier is empty or
    ``gs.rounds`` reaches ``stop_at`` (a traced per-lane value). This is the
    loop shared by the run-to-completion path (``greedy_search``) and the
    checkpoint/resume path (``greedy_resume_batch``): the carry is the full
    ``GreedyState``, so stopping at round s and re-entering later replays
    exactly the same expansion sequence as one uninterrupted run."""
    n_corpus = corpus_size(points)
    num_words = bitset_num_words(n_corpus, scfg.bitset_cap_bits)
    exact_bits = bitset_exact(n_corpus, num_words)
    if not isinstance(active, jnp.ndarray):
        active = jnp.asarray(active)
    stop_at = jnp.asarray(stop_at, jnp.int32)

    def cond(g):
        return active & (g.expand_ptr < g.res_count) & (g.rounds < stop_at)

    if scfg.eff_expand_width == 1:  # paper-faithful single-node reference
        return jax.lax.while_loop(
            cond,
            lambda g: _greedy_step_reference(points, graph, q, r, cap, scfg, g,
                                             exact_bits),
            gs)
    pnorms = _point_norms(points, scfg)
    ps = jax.lax.while_loop(
        cond,
        lambda g: _greedy_step(points, graph, q, r, cap, scfg, g, pnorms),
        _pack_greedy(gs))
    return _unpack_greedy(ps)


@partial(jax.jit, static_argnames=("cap", "rounds", "scfg"))
def greedy_search(
    points, graph: Graph, q, r, st: BeamState,
    cap: int, rounds: int, scfg: SearchConfig, active: bool | jnp.ndarray = True,
) -> GreedyState:
    """Paper Alg. 2 from a finished beam state. ``active=False`` lanes no-op.

    ``r`` is this query's own radius — a python scalar or a () float array
    (the batched callers vmap a (Q,) radius vector down to one scalar per
    lane; nothing here assumes the batch shares a radius).

    ``rounds`` stays an *expansion* budget: each iteration advances
    ``expand_ptr`` by up to ``scfg.expand_width`` and charges that many
    rounds (the last iteration may overshoot by at most E - 1).
    """
    r = jnp.asarray(r, jnp.float32)
    n_corpus = corpus_size(points)
    num_words = bitset_num_words(n_corpus, scfg.bitset_cap_bits)
    exact_bits = bitset_exact(n_corpus, num_words)
    gs = _greedy_init(st, r, cap, num_words, exact_bits)
    gs = _greedy_run(points, graph, q, r, gs, cap, rounds, scfg, active)
    gs = dataclasses.replace(gs, overflow=gs.overflow | (gs.expand_ptr < gs.res_count))
    return gs


# ---------------------------------------------------------------------------
# Checkpoint/resume greedy API (continuous-batching serving)
# ---------------------------------------------------------------------------
#
# ``GreedyState`` is a complete checkpoint of a lane's phase-2 search: the
# result buffer, expansion pointer, round counter, and discovery bitset
# together determine every future expansion. The pair below exposes that as
# a batched seed/advance surface so a serving scheduler can run phase 2 in
# bounded ``slice_rounds`` increments, rotating finished lanes out of the
# device batch while stragglers keep their state — the lane compaction of
# ``range_search_compacted`` generalized from one-shot to persistent.

@partial(jax.jit, static_argnames=("cap", "scfg"))
def greedy_seed_batch(corpus, st: BeamState, r, cap: int,
                      scfg: SearchConfig) -> GreedyState:
    """Checkpointable phase-2 seeds for a batch of finished beam states.

    Returns a batched ``GreedyState`` (one lane per query) identical to what
    ``greedy_search`` starts from; advance it with ``greedy_resume_batch``.
    """
    n_corpus = corpus_size(corpus)
    num_words = bitset_num_words(n_corpus, scfg.bitset_cap_bits)
    exact_bits = bitset_exact(n_corpus, num_words)
    rj = broadcast_radius(r, st.ids.shape[0])
    return jax.vmap(
        lambda st_, r_: _greedy_init(st_, r_, cap, num_words, exact_bits)
    )(st, rj)


@partial(jax.jit, static_argnames=("cap", "rounds", "slice_rounds", "scfg"))
def greedy_resume_batch(
    corpus, graph: Graph, queries: jnp.ndarray, r, gs: GreedyState,
    active: jnp.ndarray, cap: int, rounds: int, slice_rounds: int,
    scfg: SearchConfig,
) -> GreedyState:
    """Advance checkpointed greedy lanes by up to ``slice_rounds`` expansions.

    Each lane stops early when its frontier empties (``expand_ptr`` catches
    ``res_count``) or its lifetime budget ``rounds`` is spent; ``active``
    masks free scheduler slots to no-ops. Because the carry is the complete
    lane checkpoint, N resume calls compose to exactly one long
    ``greedy_search`` — slicing changes latency, never results. The final
    budget-exhausted overflow bit is NOT set here (a paused lane is not an
    overflowed one); callers apply it at retirement, see
    ``greedy_lane_done``."""
    rj = broadcast_radius(r, queries.shape[0])

    def one(q_, r_, g_, a_):
        stop_at = jnp.minimum(g_.rounds + slice_rounds, rounds)
        return _greedy_run(corpus, graph, q_, r_, g_, cap, stop_at, scfg, a_)

    return jax.vmap(one)(queries, rj, gs, active)


def greedy_lane_done(gs: GreedyState, rounds: int):
    """Host-side retirement test for resumed lanes.

    Returns ``(done, overflow)`` bool arrays: a lane is done when its
    frontier is exhausted or its lifetime expansion budget is spent; the
    overflow term matches ``greedy_search``'s end-of-run
    ``expand_ptr < res_count`` bit so sliced execution retires with the
    same flags as the one-shot path."""
    ptr = np.asarray(gs.expand_ptr)
    cnt = np.asarray(gs.res_count)
    rds = np.asarray(gs.rounds)
    done = (ptr >= cnt) | (rds >= rounds)
    return done, np.asarray(gs.overflow) | (done & (ptr < cnt))


def greedy_coverage(gs: GreedyState) -> np.ndarray:
    """Visited-frontier fraction per lane: ``expand_ptr / res_count``,
    clamped to [0, 1]. A deadline-truncated lane reports how much of its
    *discovered* result frontier it had expanded when finalized — the
    coverage estimate a certified-partial ``Response`` carries. A lane
    with an empty result set (or one never truncated) reports 1.0."""
    ptr = np.asarray(gs.expand_ptr, np.float64)
    cnt = np.asarray(gs.res_count, np.float64)
    return np.where(cnt > 0, np.minimum(ptr / np.maximum(cnt, 1.0), 1.0), 1.0)


# ---------------------------------------------------------------------------
# Result extraction
# ---------------------------------------------------------------------------

def _beam_results(st: BeamState, r, cap: int):
    """Paper baseline/doubling answer: in-range entries of the active beam."""
    pos = jnp.arange(st.ids.shape[0])
    ok = (st.dists <= r) & (st.ids != INVALID_ID) & (pos < st.active_width)
    dists = jnp.where(ok, st.dists, jnp.inf)
    ids = jnp.where(ok, st.ids, INVALID_ID)
    dists, ids = jax.lax.sort((dists, ids), num_keys=1, is_stable=True)
    k = min(cap, ids.shape[0])
    out_ids = jnp.full((cap,), INVALID_ID, jnp.int32).at[:k].set(ids[:k])
    out_dists = jnp.full((cap,), jnp.inf, jnp.float32).at[:k].set(dists[:k])
    count = jnp.minimum(jnp.sum(ok), cap).astype(jnp.int32)
    return out_ids, out_dists, count, jnp.sum(ok) > cap


def _needs_phase2(st: BeamState, r, lam: float) -> jnp.ndarray:
    """Paper Alg. 6 trigger: the size-b beam is λ-saturated with results."""
    thresh = jnp.ceil(lam * st.active_width.astype(jnp.float32)).astype(jnp.int32)
    return in_range_count(st, r) >= jnp.maximum(thresh, 1)


# ---------------------------------------------------------------------------
# Tombstone filtering (live indices — repro.live)
# ---------------------------------------------------------------------------
#
# Lazy deletes are a packed bitset over corpus slots. Deleted nodes keep
# their vectors and their edges, so the traversal routes THROUGH them
# exactly as before (phase-1 beam, widening triggers, and the greedy
# expansion frontier are all computed on the unfiltered sets — a tombstone
# never perturbs the walk); only at the result stage are dead candidates
# dropped. Applied BEFORE the quantized rerank so the exact pass never
# wastes gathers on dead candidates.

def _drop_dead_lane(tombstones: jnp.ndarray, ids: jnp.ndarray,
                    dists: jnp.ndarray):
    """Drop tombstoned ids from one query's result buffer (stable
    left-compaction, one bounded scatter — same shape as ``_rerank_lane``)."""
    k = ids.shape[0]
    valid = ids != INVALID_ID
    dead = bitset_contains(tombstones, jnp.where(valid, ids, 0)) & valid
    keep = valid & ~dead
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    wp = jnp.where(keep, pos, k)                                  # k == dropped
    out_ids = jnp.full((k,), INVALID_ID, jnp.int32).at[wp].set(ids, mode="drop")
    out_d = jnp.full((k,), jnp.inf, jnp.float32).at[wp].set(dists, mode="drop")
    return out_ids, out_d, jnp.sum(keep.astype(jnp.int32))


@jax.jit
def filter_tombstoned(tombstones: jnp.ndarray, res: RangeResult) -> RangeResult:
    """Remove tombstoned ids from a batched ``RangeResult`` and recount.

    ``tombstones`` is a packed ``(W,) uint32`` bitset over corpus slots
    (``core.bitset``); it must be EXACT (one bit per slot — the live index
    sizes it off its fixed capacity), since a false-positive probe here
    would silently drop a live result. ``overflow`` is left as-is: it
    reports buffer pressure during the search, where dead candidates
    legitimately occupied slots."""
    fn = lambda i_, d_: _drop_dead_lane(tombstones, i_, d_)
    ids, dists, count = jax.vmap(fn)(res.ids, res.dists)
    return dataclasses.replace(res, ids=ids, dists=dists, count=count)


# ---------------------------------------------------------------------------
# Label-predicate filtering (filtered range retrieval — core.labels)
# ---------------------------------------------------------------------------
#
# The per-query label predicate follows the tombstone template exactly:
# points failing the predicate keep their vectors and edges, so the
# traversal routes THROUGH them unchanged (phase-1 beam, λ-saturation
# triggers, and the greedy frontier all run on the unfiltered sets — a
# filtered-out point never perturbs the walk or its early-stop/termination
# heuristics); only at the result stage are unmatched candidates dropped
# and counts recomputed. That placement is what makes the oracle
# guarantees hold: an all-pass predicate is bitwise-identical to no
# predicate, and the filtered result equals the brute-force oracle
# post-filter wherever the unfiltered walk recovers the full radius ball.

def _drop_unmatched_lane(labels: jnp.ndarray, mask: jnp.ndarray, is_and,
                         ids: jnp.ndarray, dists: jnp.ndarray):
    """Drop predicate-failing ids from one query's result buffer (stable
    left-compaction, one bounded scatter — the ``_drop_dead_lane`` shape)."""
    k = ids.shape[0]
    valid = ids != INVALID_ID
    rows = jnp.take(labels, jnp.where(valid, ids, 0), axis=0)     # (K, W)
    keep = valid & labels_match(rows, mask, is_and)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    wp = jnp.where(keep, pos, k)                                  # k == dropped
    out_ids = jnp.full((k,), INVALID_ID, jnp.int32).at[wp].set(ids, mode="drop")
    out_d = jnp.full((k,), jnp.inf, jnp.float32).at[wp].set(dists, mode="drop")
    return out_ids, out_d, jnp.sum(keep.astype(jnp.int32))


@jax.jit
def filter_labeled(labels: jnp.ndarray, filt: LabelFilter,
                   res: RangeResult) -> RangeResult:
    """Drop results failing each lane's label predicate and recount.

    ``labels`` is the ``(N, W)`` uint32 per-point label rows
    (``core.labels.pack_labels``); ``filt`` the batched per-lane predicate.
    ``overflow`` is left as-is, mirroring the tombstone drop (buffer
    pressure happened during the search, where unmatched candidates
    legitimately occupied slots)."""
    fn = lambda m_, a_, i_, d_: _drop_unmatched_lane(labels, m_, a_, i_, d_)
    ids, dists, count = jax.vmap(fn)(filt.masks, filt.is_and,
                                     res.ids, res.dists)
    return dataclasses.replace(res, ids=ids, dists=dists, count=count)


# ---------------------------------------------------------------------------
# Quantized-corpus two-pass: certified-lower-bound search + boundary rerank
# ---------------------------------------------------------------------------
#
# The quantized distance paths return certified LOWER bounds of the true
# distances (core.corpus), so the search loop's plain ``dist <= r`` tests
# already keep a provable per-candidate superset at the caller's radius —
# no radius plumbing. The stage below recovers each kept candidate's upper
# bound: ``ub <= r`` proves membership, the rest are ambiguous and get one
# batched exact f32 gather.

def _rerank_lane(points: QuantizedCorpus, q, r, ids, dists, metric: str):
    """Exact-rerank one query's guard-band boundary.

    Kept candidates split by the recovered per-vector upper bound:
    ``ub <= r`` are provably in range and pass through untouched; the rest
    (the *ambiguous band*) get one batched f32 gather against the raw
    corpus and the exact test ``d <= r``. Survivors are stable-compacted to
    the front. Returns (ids, dists, count, n_ambiguous).
    """
    k = ids.shape[0]
    valid = ids != INVALID_ID
    safe = jnp.where(valid, ids, 0)
    ub = upper_bound_dists(points, safe, dists, q, metric)        # (K,)
    amb = valid & (ub > r)
    exact = gather_dist(points.raw, jnp.where(amb, ids, INVALID_ID), q, metric)
    keep = valid & jnp.where(amb, exact <= r, True)
    new_d = jnp.where(amb & keep, exact, dists)
    # stable left-compaction (one bounded scatter; positions are unique)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    wp = jnp.where(keep, pos, k)                                  # k == dropped
    out_ids = jnp.full((k,), INVALID_ID, jnp.int32).at[wp].set(ids, mode="drop")
    out_d = jnp.full((k,), jnp.inf, jnp.float32).at[wp].set(new_d, mode="drop")
    return (out_ids, out_d, jnp.sum(keep.astype(jnp.int32)),
            jnp.sum(amb.astype(jnp.int32)))


def _rerank_fused(points: QuantizedCorpus, queries, r: jnp.ndarray,
                  res: RangeResult, metric: str) -> RangeResult:
    """In-program rerank over the whole result buffer (the fused path has no
    host sync to compact through; the compacted QPS path reranks only the
    ambiguous (lane, slot) pairs — see ``_rerank_host``)."""
    fn = lambda q_, r_, i_, d_: _rerank_lane(points, q_, r_, i_, d_, metric)
    ids, dists, count, n_amb = jax.vmap(fn)(queries, r, res.ids, res.dists)
    return dataclasses.replace(
        res, ids=ids, dists=dists, count=count,
        n_dist=res.n_dist + n_amb, n_rerank=res.n_rerank + n_amb)


# ---------------------------------------------------------------------------
# Shared building blocks: phase 1, result-stage finalization
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def range_phase1(
    corpus, graph: Graph, queries: jnp.ndarray, start_ids: jnp.ndarray,
    r, cfg: RangeConfig, es_radius=None,
):
    """Phase 1 (uniform beam search) for a batch of queries.

    Returns ``(beam_state, beam_result, needs_phase2)``: the finished beam
    states (the seeds for ``greedy_seed_batch``), the beam-filtered
    ``RangeResult`` that answers lanes which stop here, and the per-lane
    λ-saturation mask (all-False for non-greedy modes). This is the uniform
    front half of ``range_search_compacted``, exposed so a continuous
    scheduler can admit new lanes mid-flight without re-running phase 1 for
    the whole device batch."""
    rj = broadcast_radius(r, queries.shape[0])
    st = beam_search_batch(corpus, graph, queries, start_ids, rj, cfg.search,
                           es_radius)
    ids, dists, count, over = jax.vmap(
        lambda st_, r_: _beam_results(st_, r_, cfg.result_cap))(st, rj)
    if cfg.mode == "greedy":
        need = jax.vmap(lambda st_, r_: _needs_phase2(st_, r_, cfg.lam))(st, rj)
    else:
        need = jnp.zeros_like(st.done)
    res = RangeResult(ids=ids, dists=dists, count=count, overflow=over,
                      n_visited=st.n_visited, n_dist=st.n_dist,
                      es_stopped=st.es_stopped, phase2=jnp.zeros_like(st.done),
                      n_rerank=jnp.zeros_like(st.n_visited))
    return st, res, need


@partial(jax.jit, static_argnames=("cfg",))
def finalize_results(corpus, queries: jnp.ndarray, r, res: RangeResult,
                     cfg: RangeConfig, tombstones=None, labels=None,
                     label_filter: Optional[LabelFilter] = None) -> RangeResult:
    """Result-stage post-processing shared by every execution path: the
    tombstone drop, then the label-predicate drop (both route the
    traversal through dropped nodes; results never include them), then the
    quantized guard-band exact rerank — in that order, so the exact pass
    never wastes gathers on candidates the filters already killed."""
    rj = broadcast_radius(r, queries.shape[0])
    if tombstones is not None:  # live index: drop dead results, keep routing
        res = filter_tombstoned(tombstones, res)
    if labels is not None and label_filter is not None:
        res = filter_labeled(labels, label_filter, res)
    if (isinstance(corpus, QuantizedCorpus) and cfg.rerank
            and corpus.raw is not None):
        res = _rerank_fused(corpus, queries, rj, res, cfg.search.metric)
    return res


# ---------------------------------------------------------------------------
# Fused single-program batch (used by dry-run lowering + single-dispatch serve)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def _range_search_fused(
    corpus,                       # (N, d) array or QuantizedCorpus
    graph: Graph,
    queries: jnp.ndarray,
    start_ids: jnp.ndarray,
    r: jnp.ndarray,               # scalar or (Q,) per-query radii
    cfg: RangeConfig,
    es_radius: Optional[jnp.ndarray] = None,  # scalar or (Q,)
    tombstones: Optional[jnp.ndarray] = None,  # (W,) uint32 dead-slot bitset
    labels: Optional[jnp.ndarray] = None,      # (N, W) uint32 label rows
    label_filter: Optional[LabelFilter] = None,
) -> RangeResult:
    r = broadcast_radius(r, queries.shape[0])
    # a quantized corpus searches on certified lower-bound distances, so
    # these r-threshold tests keep a per-candidate superset at the caller's
    # radius; the rerank stage below trims the boundary band exactly
    st = beam_search_batch(corpus, graph, queries, start_ids, r, cfg.search, es_radius)
    zeros = jnp.zeros_like(st.n_visited)

    if cfg.mode in ("beam", "doubling"):
        ids, dists, count, over = jax.vmap(
            lambda st_, r_: _beam_results(st_, r_, cfg.result_cap))(st, r)
        phase2 = (st.active_width > cfg.search.beam) if cfg.mode == "doubling" else jnp.zeros_like(st.done)
        res = RangeResult(ids=ids, dists=dists, count=count, overflow=over,
                          n_visited=st.n_visited, n_dist=st.n_dist,
                          es_stopped=st.es_stopped, phase2=phase2,
                          n_rerank=zeros)
    else:
        # greedy: phase 2 only for saturated lanes (masked, not compacted)
        active = jax.vmap(lambda st_, r_: _needs_phase2(st_, r_, cfg.lam))(st, r)
        gfn = lambda q_, r_, st_, a_: greedy_search(
            corpus, graph, q_, r_, st_, cfg.result_cap, cfg.frontier_rounds, cfg.search, a_
        )
        gs = jax.vmap(gfn)(queries, r, st, active)
        b_ids, b_dists, b_count, b_over = jax.vmap(
            lambda st_, r_: _beam_results(st_, r_, cfg.result_cap))(st, r)
        ids = jnp.where(active[:, None], gs.res_ids, b_ids)
        dists = jnp.where(active[:, None], gs.res_dists, b_dists)
        count = jnp.where(active, gs.res_count, b_count)
        over = jnp.where(active, gs.overflow, b_over)
        res = RangeResult(ids=ids, dists=dists, count=count, overflow=over,
                          n_visited=st.n_visited, n_dist=st.n_dist + jnp.where(active, gs.n_dist, 0),
                          es_stopped=st.es_stopped, phase2=active,
                          n_rerank=zeros)
    return finalize_results(corpus, queries, r, res, cfg, tombstones,
                            labels, label_filter)


# ---------------------------------------------------------------------------
# Two-phase pipeline with host-side query compaction (the QPS path)
# ---------------------------------------------------------------------------

def _tier_of(points):
    """The `TieredCorpus` wrapper, if ``points`` is one (duck-typed on the
    ``is_tiered`` marker — core never imports `repro.tier`)."""
    return points if getattr(points, "is_tiered", False) else None


def _exact_pairs_for(points, queries, ids_p, lanes_p, metric: str,
                     n_real=None):
    """Exact f32 pair distances for any exact-capable corpus view: resident
    raw rows go through `_exact_pairs`; a tiered corpus plans + fetches its
    host rows (`TieredCorpus.exact_pairs` — bit-identical by contract).
    ``n_real`` bounds the fetch planning to the unpadded pair prefix."""
    tier = _tier_of(points)
    if tier is not None:
        return tier.exact_pairs(queries, ids_p, lanes_p, metric,
                                n_real=n_real)
    raw = points.raw if isinstance(points, QuantizedCorpus) else points
    return _exact_pairs(raw, queries, ids_p, lanes_p, metric)


def _maybe_rerank_host(points, queries, rj: jnp.ndarray,
                       res: RangeResult, cfg: RangeConfig) -> RangeResult:
    """Host-compacted boundary rerank for the QPS path.

    The ambiguous band is collected as flat (lane, slot) pairs across the
    whole batch and padded to the next power of two, so the exact pass is
    ONE batched f32 gather whose size tracks the actual band population
    (O(log) compiled variants) — zero-band batches pay a single vectorized
    threshold test and no gather at all. A tiered corpus serves the gather
    from its host row store (dedup + cache + bucketed prefetch) with the
    same bits.
    """
    tier = _tier_of(points)
    qc = tier.device if tier is not None else points
    if not (isinstance(qc, QuantizedCorpus) and cfg.rerank
            and (tier is not None or qc.raw is not None)):
        return res
    metric = cfg.search.metric
    ids = np.array(jax.device_get(res.ids))
    dists = np.array(jax.device_get(res.dists))
    valid = ids != INVALID_ID
    safe = np.where(valid, ids, 0)
    ub = np.asarray(jax.vmap(
        lambda i_, d_, q_: upper_bound_dists(qc, i_, d_, q_, metric))(
            jnp.asarray(safe), jnp.asarray(dists), queries))
    amb = valid & (ub > np.asarray(rj)[:, None])
    n_rerank = amb.sum(axis=1).astype(np.int32)
    if not amb.any():
        return res
    lanes_p, slots_p = np.nonzero(amb)
    bucket = next_pow2(len(lanes_p))
    pad = bucket - len(lanes_p)
    ids_p = np.concatenate([ids[lanes_p, slots_p],
                            np.zeros(pad, np.int32)])
    lanes_pp = np.concatenate([lanes_p, np.zeros(pad, lanes_p.dtype)])
    exact_p = np.asarray(_exact_pairs_for(points, queries,
                                          jnp.asarray(ids_p, jnp.int32),
                                          jnp.asarray(lanes_pp, jnp.int32),
                                          metric, n_real=len(lanes_p)))
    rnp = np.asarray(rj)
    exact = np.full(ids.shape, np.inf, np.float32)
    exact[lanes_p, slots_p] = exact_p[:len(lanes_p)]
    keep = valid & np.where(amb, exact <= rnp[:, None], True)
    new_d = np.where(amb & keep, exact, dists)
    # stable left-compaction of the survivors, vectorized over lanes
    order = np.argsort(~keep, axis=1, kind="stable")
    out_ids = np.take_along_axis(np.where(keep, ids, INVALID_ID), order, axis=1)
    out_d = np.take_along_axis(np.where(keep, new_d, np.inf), order, axis=1)
    return dataclasses.replace(
        res,
        ids=jnp.asarray(out_ids),
        dists=jnp.asarray(out_d),
        count=jnp.asarray(keep.sum(axis=1).astype(np.int32)),
        n_dist=res.n_dist + jnp.asarray(n_rerank),
        n_rerank=res.n_rerank + jnp.asarray(n_rerank))


@partial(jax.jit, static_argnames=("metric",))
def _exact_pairs(raw, queries, ids_p, lanes_p, metric: str):
    """Exact f32 distances for flat (corpus id, query lane) pairs."""
    vecs = jnp.take(raw, ids_p, axis=0).astype(jnp.float32)
    qv = jnp.take(queries, lanes_p, axis=0).astype(jnp.float32)
    return point_dist(vecs, qv, metric)


def _walk_compacted(
    corpus,               # (N, d) array or QuantizedCorpus
    graph: Graph,
    queries: jnp.ndarray,
    start_ids: jnp.ndarray,  # shared (S,) or per-lane (Q, S')
    r,                    # scalar or (Q,) per-query radii
    cfg: RangeConfig,
    es_radius=None,       # scalar or (Q,)
    tombstones=None,      # (W,) uint32 dead-slot bitset (live indices)
    labels=None,          # (N, W) uint32 per-point label rows
    label_filter: Optional[LabelFilter] = None,
) -> RangeResult:
    # a tiered corpus walks on its device arm (codes + meta only); the
    # host-fetched rerank in finish() sees the full tier
    tier = _tier_of(corpus)
    points = tier.device if tier is not None else corpus
    rj = broadcast_radius(r, queries.shape[0])

    def finish(res: RangeResult) -> RangeResult:
        # result-stage tombstone + label-predicate drops (traversal above
        # ran unfiltered), then the quantized boundary rerank on survivors
        if tombstones is not None:
            res = filter_tombstoned(tombstones, res)
        if labels is not None and label_filter is not None:
            res = filter_labeled(labels, label_filter, res)
        return _maybe_rerank_host(corpus, queries, rj, res, cfg)

    esj = None if es_radius is None else broadcast_radius(es_radius, queries.shape[0])
    # phase 1 runs at the BASE beam for every mode (for doubling this is the
    # §Perf iteration C3 change: in-place widening inside the batched while
    # made every lane wait for the widest one — a 10x QPS straggler penalty;
    # the paper's restart-style doubling now runs on the compacted survivors
    # only, like greedy). A quantized corpus searches on certified
    # lower-bound distances (superset at rj); _maybe_rerank_host trims the
    # boundary band exactly.
    p1_search = cfg.search if cfg.mode != "doubling" else dataclasses.replace(
        cfg.search, max_beam=cfg.search.beam,
        visit_cap=min(cfg.search.visit_cap, 4 * cfg.search.beam))
    st = beam_search_batch(points, graph, queries, start_ids, rj, p1_search, esj)
    b_ids, b_dists, b_count, b_over = jax.vmap(
        lambda st_, r_: _beam_results(st_, r_, cfg.result_cap))(st, rj)
    base = RangeResult(ids=b_ids, dists=b_dists, count=b_count, overflow=b_over,
                       n_visited=st.n_visited, n_dist=st.n_dist,
                       es_stopped=st.es_stopped,
                       phase2=jnp.zeros_like(st.done),
                       n_rerank=jnp.zeros_like(st.n_visited))
    if cfg.mode == "beam":
        return finish(base)

    active = np.asarray(jax.vmap(lambda st_, r_: _needs_phase2(st_, r_, cfg.lam))(st, rj))
    n_active = int(active.sum())
    if n_active == 0:
        return finish(base)

    sel = np.nonzero(active)[0]
    bucket = next_pow2(n_active)
    pad = np.concatenate([sel, np.full(bucket - n_active, sel[0], dtype=sel.dtype)])
    sub_q = queries[pad]
    sub_r = rj[pad]
    sub_es = None if esj is None else esj[pad]
    lane_on = jnp.asarray(np.arange(bucket) < n_active)

    if cfg.mode == "doubling":
        # restart with widening enabled, survivors only (paper Alg. 5),
        # each at its own radius (per-lane starts subset with their lanes)
        sub_starts = start_ids if start_ids.ndim == 1 else start_ids[pad]
        st2 = beam_search_batch(points, graph, sub_q, sub_starts, sub_r,
                                cfg.search, sub_es)
        d_ids, d_dists, d_count, d_over = jax.vmap(
            lambda st_, r_: _beam_results(st_, r_, cfg.result_cap))(st2, sub_r)
        sub = (d_ids, d_dists, d_count, d_over, st2.n_dist)
    else:
        sub_st = jax.tree.map(lambda x: x[pad], st)
        gfn = lambda q_, r_, st_, a_: greedy_search(
            points, graph, q_, r_, st_, cfg.result_cap, cfg.frontier_rounds,
            cfg.search, a_)
        gs = jax.vmap(gfn)(sub_q, sub_r, sub_st, lane_on)
        sub = (gs.res_ids, gs.res_dists, gs.res_count, gs.overflow, gs.n_dist)

    # one batched transfer for everything the host-side merge needs (the
    # per-leaf np.array() calls each synced the device separately)
    ids, dists, count, over, ndist, s_ids, s_dists, s_count, s_over, s_nd = (
        jax.device_get((base.ids, base.dists, base.count, base.overflow,
                        base.n_dist) + sub))
    ids, dists, count, over, ndist = (
        np.array(ids), np.array(dists), np.array(count), np.array(over),
        np.array(ndist))  # device_get leaves may be read-only views
    ids[sel] = s_ids[:n_active]
    dists[sel] = s_dists[:n_active]
    count[sel] = s_count[:n_active]
    over[sel] = s_over[:n_active]
    ndist[sel] += s_nd[:n_active]
    phase2 = jnp.asarray(active)
    merged = RangeResult(ids=jnp.asarray(ids), dists=jnp.asarray(dists),
                         count=jnp.asarray(count), overflow=jnp.asarray(over),
                         n_visited=base.n_visited, n_dist=jnp.asarray(ndist),
                         es_stopped=base.es_stopped, phase2=phase2,
                         n_rerank=jnp.zeros_like(base.n_visited))
    return finish(merged)


# Below this fraction of the corpus, a filtered walk lane gets its default
# entry points augmented with members of its own posting list (the beam
# then starts inside the predicate's region instead of routing to it).
# Lanes at or above it keep the shared defaults untouched, so broad and
# all-pass predicates stay bitwise-identical to the unfiltered program.
ENTRY_SEED_FRAC = 0.25


def _fallback_scan(points, queries, rj_np, tombstones, match, fb_sel,
                   cap: int, metric: str):
    """Brute exact scan of each fallback lane's posting list.

    ``points`` is any exact-capable corpus view (raw array, quantized
    corpus with raw rows, or tiered corpus), ``match`` the host (Q, N)
    predicate matrix, ``fb_sel`` the lanes taking this path. All posting
    lists flatten into one pow2-padded ``_exact_pairs`` call (O(log)
    compiled variants, like the rerank band), then each lane keeps
    ``d <= r`` survivors sorted ascending — exactly the oracle's
    post-filtered answer, by construction. Tombstoned ids are excluded
    up front so the scan matches the walk's result-stage semantics."""
    m = len(fb_sel)
    out_ids = np.full((m, cap), INVALID_ID, np.int32)
    out_d = np.full((m, cap), np.inf, np.float32)
    count = np.zeros(m, np.int32)
    over = np.zeros(m, bool)
    ndist = np.zeros(m, np.int32)
    tomb = None if tombstones is None else np.asarray(tombstones)
    per_ids = []
    for j, lane in enumerate(fb_sel):
        pid = np.nonzero(match[lane])[0].astype(np.int32)
        if tomb is not None and pid.size:
            live = ((tomb[pid // 32] >> (pid % 32)) & np.uint32(1)) == 0
            pid = pid[live]
        per_ids.append(pid)
        ndist[j] = pid.size
    total = int(sum(p.size for p in per_ids))
    if total == 0:
        return out_ids, out_d, count, over, ndist
    lanes_p = np.concatenate([np.full(p.size, lane, np.int32)
                              for p, lane in zip(per_ids, fb_sel)])
    ids_p = np.concatenate(per_ids)
    bucket = next_pow2(total)
    pad = bucket - total
    d = np.asarray(_exact_pairs_for(
        points, queries,
        jnp.asarray(np.concatenate([ids_p, np.zeros(pad, np.int32)])),
        jnp.asarray(np.concatenate([lanes_p, np.zeros(pad, np.int32)])),
        metric, n_real=total))[:total]
    off = 0
    for j, pid in enumerate(per_ids):
        dj = d[off:off + pid.size]
        off += pid.size
        keep = dj <= rj_np[fb_sel[j]]
        kid, kd = pid[keep], dj[keep]
        order = np.argsort(kd, kind="stable")
        kid, kd = kid[order], kd[order]
        k = min(kid.size, cap)
        out_ids[j, :k] = kid[:k]
        out_d[j, :k] = kd[:k]
        count[j] = k
        over[j] = kid.size > cap
    return out_ids, out_d, count, over, ndist


def _range_search_compacted(
    corpus,
    graph: Graph,
    queries: jnp.ndarray,
    start_ids: jnp.ndarray,
    r,
    cfg: RangeConfig,
    es_radius=None,
    tombstones=None,
    labels=None,
    label_filter: Optional[LabelFilter] = None,
) -> RangeResult:
    """Compacted-path front door: per-lane selectivity dispatch.

    Unfiltered batches go straight to the two-phase walk. Filtered batches
    first measure each lane's predicate selectivity (posting-list size /
    corpus size) on the host:

    * lanes below ``cfg.filter_threshold`` skip the graph entirely and
      brute-scan their posting list with the exact kernel
      (``_fallback_scan`` — FilterGraph's ``_threshold`` dispatch);
    * surviving walk lanes below ``ENTRY_SEED_FRAC`` get their entry
      points augmented with posting-list members (filter-aware entry
      selection) — broad/all-pass lanes keep the shared defaults;
    * one micro-batch freely mixes both paths; walk lanes are compacted
      and pow2-padded exactly like the phase-2 survivors.

    The fallback needs exact vectors (a ``QuantizedCorpus`` without
    ``raw`` walks every lane instead)."""
    if labels is None or label_filter is None:
        return _walk_compacted(corpus, graph, queries, start_ids, r, cfg,
                               es_radius, tombstones)
    n_q = queries.shape[0]
    rj = broadcast_radius(r, n_q)
    esj = (None if es_radius is None
           else broadcast_radius(es_radius, n_q))
    n_corpus = corpus_size(corpus)
    match = np.asarray(label_match_matrix(labels, label_filter))   # (Q, N)
    counts = match.sum(axis=1)
    if _tier_of(corpus) is not None:
        has_exact = True  # host store serves the fallback's exact scan
    else:
        has_exact = (corpus.raw is not None
                     if isinstance(corpus, QuantizedCorpus) else True)
    fb = (counts < cfg.filter_threshold * n_corpus
          if cfg.filter_threshold > 0.0 and has_exact
          else np.zeros(n_q, bool))

    # filter-aware entry points: selective walk lanes start inside their
    # predicate's region (deterministic evenly-spaced posting-list sample
    # appended to the defaults; INVALID padding and duplicate collapse in
    # init_state keep unseeded lanes bitwise-identical to shared starts)
    seed = (~fb) & (counts > 0) & (counts < ENTRY_SEED_FRAC * n_corpus)
    if seed.any():
        s0 = np.asarray(start_ids).astype(np.int32)
        n_seed = s0.shape[0]
        sm = np.concatenate(
            [np.broadcast_to(s0, (n_q, n_seed)),
             np.full((n_q, n_seed), INVALID_ID, np.int32)], axis=1).copy()
        for lane in np.nonzero(seed)[0]:
            pid = np.nonzero(match[lane])[0]
            pick = pid[np.linspace(0, pid.size - 1,
                                   min(n_seed, pid.size)).astype(np.int64)]
            sm[lane, n_seed:n_seed + pick.size] = pick
        walk_starts = jnp.asarray(sm)
    else:
        walk_starts = start_ids

    if not fb.any():
        return _walk_compacted(corpus, graph, queries, walk_starts, rj, cfg,
                               esj, tombstones, labels, label_filter)

    cap = cfg.result_cap
    fb_sel = np.nonzero(fb)[0]
    w_sel = np.nonzero(~fb)[0]
    f_ids, f_d, f_cnt, f_over, f_nd = _fallback_scan(
        corpus, queries, np.asarray(rj), tombstones, match, fb_sel, cap,
        cfg.search.metric)

    ids = np.full((n_q, cap), INVALID_ID, np.int32)
    dists = np.full((n_q, cap), np.inf, np.float32)
    count = np.zeros(n_q, np.int32)
    over = np.zeros(n_q, bool)
    nvis = np.zeros(n_q, np.int32)
    ndist = np.zeros(n_q, np.int32)
    ess = np.zeros(n_q, bool)
    ph2 = np.zeros(n_q, bool)
    nrr = np.zeros(n_q, np.int32)
    ids[fb_sel], dists[fb_sel], count[fb_sel] = f_ids, f_d, f_cnt
    over[fb_sel], ndist[fb_sel] = f_over, f_nd

    if w_sel.size:
        bucket = next_pow2(w_sel.size)
        padw = np.concatenate(
            [w_sel, np.full(bucket - w_sel.size, w_sel[0], w_sel.dtype)])
        sub_starts = (walk_starts if walk_starts.ndim == 1
                      else walk_starts[padw])
        sub_filter = LabelFilter(masks=label_filter.masks[padw],
                                 is_and=label_filter.is_and[padw])
        wres = _walk_compacted(
            corpus, graph, queries[padw], sub_starts, rj[padw], cfg,
            None if esj is None else esj[padw], tombstones, labels,
            sub_filter)
        (w_ids, w_d, w_cnt, w_over, w_nvis, w_nd, w_es, w_ph2,
         w_nrr) = jax.device_get(
            (wres.ids, wres.dists, wres.count, wres.overflow, wres.n_visited,
             wres.n_dist, wres.es_stopped, wres.phase2, wres.n_rerank))
        k = w_sel.size
        ids[w_sel], dists[w_sel], count[w_sel] = w_ids[:k], w_d[:k], w_cnt[:k]
        over[w_sel], nvis[w_sel], ndist[w_sel] = (w_over[:k], w_nvis[:k],
                                                  w_nd[:k])
        ess[w_sel], ph2[w_sel], nrr[w_sel] = w_es[:k], w_ph2[:k], w_nrr[:k]

    return RangeResult(
        ids=jnp.asarray(ids), dists=jnp.asarray(dists),
        count=jnp.asarray(count), overflow=jnp.asarray(over),
        n_visited=jnp.asarray(nvis), n_dist=jnp.asarray(ndist),
        es_stopped=jnp.asarray(ess), phase2=jnp.asarray(ph2),
        n_rerank=jnp.asarray(nrr))


# ---------------------------------------------------------------------------
# Public entry points — one keyword surface, shared parameter order
# ---------------------------------------------------------------------------
#
# The batch entry points share the parameter order
# ``(corpus, graph, queries, start_ids, r, cfg, es_radius, tombstones,
# labels, label_filter)`` and take everything by keyword
# (``dist.sharded_range_search`` prepends its mesh;
# ``engine.range``/``LiveSnapshot.range`` bind corpus/graph/start_ids/labels
# from the object and keep the same tail).

def range_search_fused(*, corpus, graph, queries, start_ids, r, cfg,
                       es_radius=None, tombstones=None, labels=None,
                       label_filter=None) -> RangeResult:
    """Single-XLA-program batched range search (no host sync): phase 1 plus
    masked (not compacted) greedy phase 2, tombstone + label-predicate
    filters, and in-program quantized rerank. Keyword-only; see the module
    note on the shared parameter order. ``r``/``es_radius`` are a scalar or
    per-query ``(Q,)`` radii; ``tombstones`` a packed ``(W,) uint32``
    dead-slot bitset; ``labels``/``label_filter`` the per-point label rows
    and batched predicate (``core.labels``). The fused program always
    walks — the selectivity fallback needs a host dispatch and lives on the
    compacted path. A tiered corpus runs the program on its device arm
    (raw=None skips the in-program rerank after the tombstone/label drops)
    and reranks through the host store afterwards — same filter→rerank
    order, same bits as the resident program."""
    tier = _tier_of(corpus)
    if tier is not None:
        res = _range_search_fused(corpus=tier.device, graph=graph,
                                  queries=queries, start_ids=start_ids, r=r,
                                  cfg=cfg, es_radius=es_radius,
                                  tombstones=tombstones, labels=labels,
                                  label_filter=label_filter)
        rj = broadcast_radius(r, queries.shape[0])
        return _maybe_rerank_host(corpus, queries, rj, res, cfg)
    return _range_search_fused(corpus=corpus, graph=graph, queries=queries,
                               start_ids=start_ids, r=r, cfg=cfg,
                               es_radius=es_radius, tombstones=tombstones,
                               labels=labels, label_filter=label_filter)


def range_search_compacted(*, corpus, graph, queries, start_ids, r, cfg,
                           es_radius=None, tombstones=None, labels=None,
                           label_filter=None) -> RangeResult:
    """Two-phase batched range search with host-side query compaction (the
    QPS path): phase 1 over the whole batch, phase 2 over the pow2-padded
    survivor subset only (O(log Q) compiled variants — lanes with zero
    results never enter the expensive loop), each survivor carrying its own
    radius. With ``labels``/``label_filter`` set, lanes whose predicate
    selectivity falls below ``cfg.filter_threshold`` brute-scan their
    posting list instead of walking (per-lane dispatch; one micro-batch
    mixes both paths) and selective walk lanes get filter-aware entry
    points. Keyword-only; see the module note on the shared parameter
    order."""
    return _range_search_compacted(corpus=corpus, graph=graph, queries=queries,
                                   start_ids=start_ids, r=r, cfg=cfg,
                                   es_radius=es_radius, tombstones=tombstones,
                                   labels=labels, label_filter=label_filter)
