"""Range-retrieval algorithms on top of the beam search (paper Algs. 2/5/6).

Three modes, matching the paper:

* ``"beam"``     — the naive baseline: one beam search, filter the beam by r.
* ``"doubling"`` — Alg. 5 via in-place beam widening (``max_beam > beam``).
* ``"greedy"``   — Alg. 6: initial beam search; queries whose beam is
  saturated with in-range results continue with Alg. 2 (expand only in-range
  nodes, unbounded queue -> fixed-capacity result buffer + overflow counter).

Batched execution is two-phase with **query compaction** (DESIGN.md §2): the
uniform phase 1 runs over the whole batch; the irregular phase 2 runs only on
the compacted subset of queries that need it (bucketed to powers of two so jit
compiles O(log Q) variants). ``range_search_fused`` keeps everything in one
XLA program (no host sync) for dry-run lowering and single-dispatch serving.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..utils import INVALID_ID, next_pow2
from .beam_search import (
    BeamState,
    SearchConfig,
    _expand_tile,
    _f32_ascending_key,
    _f32_from_key,
    _point_norms,
    beam_search_batch,
    broadcast_radius,
    in_range_count,
)
from .bitset import (
    bitset_add,
    bitset_contains,
    bitset_exact,
    bitset_init,
    bitset_num_words,
    first_slot_occurrence,
)
from .distances import gather_dist
from .graph import Graph


@dataclasses.dataclass(frozen=True)
class RangeConfig:
    """Static configuration for a range query batch."""

    search: SearchConfig = dataclasses.field(default_factory=SearchConfig)
    mode: str = "greedy"          # beam | doubling | greedy
    result_cap: int = 1024        # K_cap: per-query result buffer
    frontier_rounds: int = 4096   # greedy expansion budget (expansions/query)
    lam: float = 1.0              # λ threshold for entering phase 2

    def __post_init__(self):
        if self.mode not in ("beam", "doubling", "greedy"):
            raise ValueError(f"bad mode {self.mode!r}")
        if self.mode == "doubling" and self.search.max_beam <= self.search.beam:
            raise ValueError("doubling mode needs search.max_beam > search.beam")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RangeResult:
    """Batched range-query output (all arrays INVALID/inf padded)."""

    ids: jnp.ndarray       # (Q, K) int32
    dists: jnp.ndarray     # (Q, K) float32
    count: jnp.ndarray     # (Q,) int32 — number of valid entries
    overflow: jnp.ndarray  # (Q,) bool — K_cap or budget exceeded
    n_visited: jnp.ndarray # (Q,) int32 — phase-1 expansions
    n_dist: jnp.ndarray    # (Q,) int32 — total distance computations
    es_stopped: jnp.ndarray  # (Q,) bool
    phase2: jnp.ndarray    # (Q,) bool — query took the second phase


# ---------------------------------------------------------------------------
# Greedy continuation (paper Alg. 2), fixed-shape form.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GreedyState:
    res_ids: jnp.ndarray    # (K,) int32 — every id here is in-range
    res_dists: jnp.ndarray  # (K,) float32
    res_count: jnp.ndarray  # () int32
    expand_ptr: jnp.ndarray # () int32
    rounds: jnp.ndarray     # () int32
    overflow: jnp.ndarray   # () bool
    n_dist: jnp.ndarray     # () int32
    seen_bits: jnp.ndarray  # (W,) uint32 — result-membership bitset


def _greedy_init(st: BeamState, r, cap: int, num_words: int,
                 exact_bits: bool) -> GreedyState:
    """Seed the result buffer with every in-range node whose exact distance is
    already known: the visited log plus unexpanded in-range beam entries
    (disjoint by construction — expanded beam nodes are in the log). The
    result membership is mirrored into a bitset so the per-expansion "already
    a result?" test is an O(1) probe, not an O(result_cap) broadcast."""
    v_ok = st.visited_dists <= r
    b_ok = (st.dists <= r) & (~st.expanded) & (st.ids != INVALID_ID)
    ids = jnp.concatenate([jnp.where(v_ok, st.visited_ids, INVALID_ID),
                           jnp.where(b_ok, st.ids, INVALID_ID)])
    dists = jnp.concatenate([jnp.where(v_ok, st.visited_dists, jnp.inf),
                             jnp.where(b_ok, st.dists, jnp.inf)])
    # pack in-range entries to the front, closest first (paper pops
    # closest-first; our FIFO expansion then visits in that order)
    _, ids, dists = jax.lax.sort((_f32_ascending_key(dists), ids, dists),
                                 num_keys=1, is_stable=True)
    k = min(cap, ids.shape[0])
    res_ids = jnp.full((cap,), INVALID_ID, jnp.int32).at[:k].set(ids[:k])
    res_dists = jnp.full((cap,), jnp.inf, jnp.float32).at[:k].set(dists[:k])
    total = jnp.sum(jnp.isfinite(dists))
    count = jnp.minimum(total, cap)
    bits = bitset_init(num_words)
    seed_ok = res_ids != INVALID_ID  # unique ids by construction
    if not exact_bits:  # hashed regime: collapse colliding buckets first
        seed_ok = first_slot_occurrence(bits, res_ids, seed_ok)
    bits = bitset_add(bits, res_ids, seed_ok)
    return GreedyState(
        res_ids=res_ids,
        res_dists=res_dists,
        res_count=count.astype(jnp.int32),
        expand_ptr=jnp.asarray(0, jnp.int32),
        rounds=jnp.asarray(0, jnp.int32),
        overflow=(total > cap),
        n_dist=jnp.asarray(0, jnp.int32),
        seen_bits=bits,
    )


def _greedy_step_reference(points, graph: Graph, q, r, cap: int,
                           scfg: SearchConfig, gs: GreedyState) -> GreedyState:
    """Single-node greedy step (``expand_width=1``): the pre-fusion dataflow,
    kept verbatim as the baseline (membership test is an O(R * cap)
    broadcast against the result buffer; ``seen_bits`` carried untouched)."""
    node = gs.res_ids[gs.expand_ptr]
    nbrs = graph.out_neighbors(node)  # (R,)
    nd = gather_dist(points, nbrs, q, scfg.metric)
    rr = jnp.arange(nbrs.shape[0])
    dup_in_row = jnp.any(
        (nbrs[:, None] == nbrs[None, :]) & (rr[None, :] < rr[:, None]) & (nbrs[:, None] != INVALID_ID),
        axis=1,
    )
    seen = jnp.any((nbrs[:, None] == gs.res_ids[None, :]) & (nbrs[:, None] != INVALID_ID), axis=1)
    new = (nd <= r) & (~dup_in_row) & (~seen) & (nbrs != INVALID_ID)
    pos = gs.res_count + jnp.cumsum(new.astype(jnp.int32)) - 1
    write_pos = jnp.where(new & (pos < cap), pos, cap)  # cap == OOB -> dropped
    res_ids = gs.res_ids.at[write_pos].set(nbrs, mode="drop")
    res_dists = gs.res_dists.at[write_pos].set(nd, mode="drop")
    n_new = jnp.sum(new.astype(jnp.int32))
    return GreedyState(
        res_ids=res_ids,
        res_dists=res_dists,
        res_count=jnp.minimum(gs.res_count + n_new, cap),
        expand_ptr=gs.expand_ptr + 1,
        rounds=gs.rounds + 1,
        overflow=gs.overflow | (gs.res_count + n_new > cap),
        n_dist=gs.n_dist + jnp.sum(nbrs != INVALID_ID).astype(jnp.int32),
        seen_bits=gs.seen_bits,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class _PackedGreedyState:
    """Loop carry of the fused (E >= 2) greedy phase. ``res`` packs
    ``[id, uint32-distance-key]`` per row so the append is ONE bounded
    scatter instead of two (XLA scatter cost is per-update overhead, not
    bytes — the two-buffer form profiled as ~40% of the greedy loop; a
    batched ``dynamic_update_slice`` window write was also tried and lost,
    since a per-lane start index turns DUS into a whole-buffer scatter
    under vmap). Unpacked into ``GreedyState`` after the loop."""

    res: jnp.ndarray        # (K, 2) int32 — [id, dist key (bitcast)]
    res_count: jnp.ndarray  # () int32
    expand_ptr: jnp.ndarray # () int32
    rounds: jnp.ndarray     # () int32
    overflow: jnp.ndarray   # () bool
    n_dist: jnp.ndarray     # () int32
    seen_bits: jnp.ndarray  # (W,) uint32


def _pack_greedy(gs: GreedyState) -> _PackedGreedyState:
    key = jax.lax.bitcast_convert_type(_f32_ascending_key(gs.res_dists),
                                       jnp.int32)
    return _PackedGreedyState(
        res=jnp.stack([gs.res_ids, key], axis=1),
        res_count=gs.res_count, expand_ptr=gs.expand_ptr, rounds=gs.rounds,
        overflow=gs.overflow, n_dist=gs.n_dist, seen_bits=gs.seen_bits)


def _unpack_greedy(ps: _PackedGreedyState) -> GreedyState:
    return GreedyState(
        res_ids=ps.res[:, 0],
        res_dists=_f32_from_key(
            jax.lax.bitcast_convert_type(ps.res[:, 1], jnp.uint32)),
        res_count=ps.res_count, expand_ptr=ps.expand_ptr, rounds=ps.rounds,
        overflow=ps.overflow, n_dist=ps.n_dist, seen_bits=ps.seen_bits)


def _greedy_step(points, graph: Graph, q, r, cap: int, scfg: SearchConfig,
                 gs: _PackedGreedyState, point_norms=None) -> _PackedGreedyState:
    """Expand the next E result-buffer entries through the fused expand path
    (same kernel as phase 1), appending fresh in-range neighbors.

    The membership probe is the bitset — the reference path's O(R * cap)
    result-buffer broadcast is the dominant cost this replaces."""
    E = scfg.eff_expand_width
    lane = jnp.arange(E)
    e_cnt = jnp.minimum(jnp.asarray(E, jnp.int32), gs.res_count - gs.expand_ptr)
    lane_ok = lane < e_cnt
    ridx = jnp.minimum(gs.expand_ptr + lane, cap - 1)
    nodes = jnp.where(lane_ok, jnp.take(gs.res[:, 0], ridx), INVALID_ID)

    nbr_ids, nd, nd_inc = _expand_tile(points, graph, nodes, q, scfg,
                                       point_norms)
    valid = nbr_ids != INVALID_ID
    seen = bitset_contains(gs.seen_bits, jnp.where(valid, nbr_ids, 0)) & valid
    new = valid & ~seen & (nd <= r)
    if not bitset_exact(points.shape[0], gs.seen_bits.shape[0]):
        new = first_slot_occurrence(gs.seen_bits, nbr_ids, new)

    pos = gs.res_count + jnp.cumsum(new.astype(jnp.int32)) - 1
    write_pos = jnp.where(new & (pos < cap), pos, cap)  # cap == OOB -> dropped
    key = jax.lax.bitcast_convert_type(_f32_ascending_key(nd), jnp.int32)
    rows = jnp.stack([nbr_ids, key], axis=1)             # (T, 2)
    res = gs.res.at[write_pos].set(rows, mode="drop")
    n_new = jnp.sum(new.astype(jnp.int32))
    return _PackedGreedyState(
        res=res,
        res_count=jnp.minimum(gs.res_count + n_new, cap),
        expand_ptr=gs.expand_ptr + e_cnt,
        rounds=gs.rounds + e_cnt,
        overflow=gs.overflow | (gs.res_count + n_new > cap),
        n_dist=gs.n_dist + nd_inc,
        # mark every fresh in-range neighbor, including cap-dropped ones (the
        # buffer only ever grows, so a dropped node could never land later)
        seen_bits=bitset_add(gs.seen_bits, nbr_ids, new),
    )


@partial(jax.jit, static_argnames=("cap", "rounds", "scfg"))
def greedy_search(
    points, graph: Graph, q, r, st: BeamState,
    cap: int, rounds: int, scfg: SearchConfig, active: bool | jnp.ndarray = True,
) -> GreedyState:
    """Paper Alg. 2 from a finished beam state. ``active=False`` lanes no-op.

    ``r`` is this query's own radius — a python scalar or a () float array
    (the batched callers vmap a (Q,) radius vector down to one scalar per
    lane; nothing here assumes the batch shares a radius).

    ``rounds`` stays an *expansion* budget: each iteration advances
    ``expand_ptr`` by up to ``scfg.expand_width`` and charges that many
    rounds (the last iteration may overshoot by at most E - 1).
    """
    r = jnp.asarray(r, jnp.float32)
    num_words = bitset_num_words(points.shape[0], scfg.bitset_cap_bits)
    gs = _greedy_init(st, r, cap, num_words,
                      bitset_exact(points.shape[0], num_words))
    if not isinstance(active, jnp.ndarray):
        active = jnp.asarray(active)

    def cond(g):
        return active & (g.expand_ptr < g.res_count) & (g.rounds < rounds)

    if scfg.eff_expand_width == 1:  # paper-faithful single-node reference
        gs = jax.lax.while_loop(
            cond,
            lambda g: _greedy_step_reference(points, graph, q, r, cap, scfg, g),
            gs)
    else:
        pnorms = _point_norms(points, scfg)
        ps = jax.lax.while_loop(
            cond,
            lambda g: _greedy_step(points, graph, q, r, cap, scfg, g, pnorms),
            _pack_greedy(gs))
        gs = _unpack_greedy(ps)
    gs = dataclasses.replace(gs, overflow=gs.overflow | (gs.expand_ptr < gs.res_count))
    return gs


# ---------------------------------------------------------------------------
# Result extraction
# ---------------------------------------------------------------------------

def _beam_results(st: BeamState, r, cap: int):
    """Paper baseline/doubling answer: in-range entries of the active beam."""
    pos = jnp.arange(st.ids.shape[0])
    ok = (st.dists <= r) & (st.ids != INVALID_ID) & (pos < st.active_width)
    dists = jnp.where(ok, st.dists, jnp.inf)
    ids = jnp.where(ok, st.ids, INVALID_ID)
    dists, ids = jax.lax.sort((dists, ids), num_keys=1, is_stable=True)
    k = min(cap, ids.shape[0])
    out_ids = jnp.full((cap,), INVALID_ID, jnp.int32).at[:k].set(ids[:k])
    out_dists = jnp.full((cap,), jnp.inf, jnp.float32).at[:k].set(dists[:k])
    count = jnp.minimum(jnp.sum(ok), cap).astype(jnp.int32)
    return out_ids, out_dists, count, jnp.sum(ok) > cap


def _needs_phase2(st: BeamState, r, lam: float) -> jnp.ndarray:
    """Paper Alg. 6 trigger: the size-b beam is λ-saturated with results."""
    thresh = jnp.ceil(lam * st.active_width.astype(jnp.float32)).astype(jnp.int32)
    return in_range_count(st, r) >= jnp.maximum(thresh, 1)


# ---------------------------------------------------------------------------
# Fused single-program batch (used by dry-run lowering + single-dispatch serve)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def range_search_fused(
    points: jnp.ndarray,
    graph: Graph,
    queries: jnp.ndarray,
    start_ids: jnp.ndarray,
    r: jnp.ndarray,               # scalar or (Q,) per-query radii
    cfg: RangeConfig,
    es_radius: Optional[jnp.ndarray] = None,  # scalar or (Q,)
) -> RangeResult:
    r = broadcast_radius(r, queries.shape[0])
    st = beam_search_batch(points, graph, queries, start_ids, r, cfg.search, es_radius)

    if cfg.mode in ("beam", "doubling"):
        ids, dists, count, over = jax.vmap(
            lambda st_, r_: _beam_results(st_, r_, cfg.result_cap))(st, r)
        phase2 = (st.active_width > cfg.search.beam) if cfg.mode == "doubling" else jnp.zeros_like(st.done)
        return RangeResult(ids=ids, dists=dists, count=count, overflow=over,
                           n_visited=st.n_visited, n_dist=st.n_dist,
                           es_stopped=st.es_stopped, phase2=phase2)

    # greedy: phase 2 only for saturated lanes (masked, not compacted)
    active = jax.vmap(lambda st_, r_: _needs_phase2(st_, r_, cfg.lam))(st, r)
    gfn = lambda q_, r_, st_, a_: greedy_search(
        points, graph, q_, r_, st_, cfg.result_cap, cfg.frontier_rounds, cfg.search, a_
    )
    gs = jax.vmap(gfn)(queries, r, st, active)
    b_ids, b_dists, b_count, b_over = jax.vmap(
        lambda st_, r_: _beam_results(st_, r_, cfg.result_cap))(st, r)
    ids = jnp.where(active[:, None], gs.res_ids, b_ids)
    dists = jnp.where(active[:, None], gs.res_dists, b_dists)
    count = jnp.where(active, gs.res_count, b_count)
    over = jnp.where(active, gs.overflow, b_over)
    return RangeResult(ids=ids, dists=dists, count=count, overflow=over,
                       n_visited=st.n_visited, n_dist=st.n_dist + jnp.where(active, gs.n_dist, 0),
                       es_stopped=st.es_stopped, phase2=active)


# ---------------------------------------------------------------------------
# Two-phase pipeline with host-side query compaction (the QPS path)
# ---------------------------------------------------------------------------

def range_search_compacted(
    points: jnp.ndarray,
    graph: Graph,
    queries: jnp.ndarray,
    start_ids: jnp.ndarray,
    r,                    # scalar or (Q,) per-query radii
    cfg: RangeConfig,
    es_radius=None,       # scalar or (Q,)
) -> RangeResult:
    """Phase 1 over the whole batch; phase 2 over the compacted survivors.

    The survivor subset is padded to the next power of two, so jit compiles at
    most O(log Q) phase-2 variants. This bounds the batched-while straggler
    effect: lanes with zero results never enter the expensive loop at all.
    Compaction carries each survivor's *own* radius (and early-stop radius)
    into phase 2, so a micro-batch may mix radii freely.
    """
    rj = broadcast_radius(r, queries.shape[0])
    esj = None if es_radius is None else broadcast_radius(es_radius, queries.shape[0])
    # phase 1 runs at the BASE beam for every mode (for doubling this is the
    # §Perf iteration C3 change: in-place widening inside the batched while
    # made every lane wait for the widest one — a 10x QPS straggler penalty;
    # the paper's restart-style doubling now runs on the compacted survivors
    # only, like greedy)
    p1_search = cfg.search if cfg.mode != "doubling" else dataclasses.replace(
        cfg.search, max_beam=cfg.search.beam,
        visit_cap=min(cfg.search.visit_cap, 4 * cfg.search.beam))
    st = beam_search_batch(points, graph, queries, start_ids, rj, p1_search, esj)
    b_ids, b_dists, b_count, b_over = jax.vmap(
        lambda st_, r_: _beam_results(st_, r_, cfg.result_cap))(st, rj)
    base = RangeResult(ids=b_ids, dists=b_dists, count=b_count, overflow=b_over,
                       n_visited=st.n_visited, n_dist=st.n_dist,
                       es_stopped=st.es_stopped,
                       phase2=jnp.zeros_like(st.done))
    if cfg.mode == "beam":
        return base

    active = np.asarray(jax.vmap(lambda st_, r_: _needs_phase2(st_, r_, cfg.lam))(st, rj))
    n_active = int(active.sum())
    if n_active == 0:
        return base

    sel = np.nonzero(active)[0]
    bucket = next_pow2(n_active)
    pad = np.concatenate([sel, np.full(bucket - n_active, sel[0], dtype=sel.dtype)])
    sub_q = queries[pad]
    sub_r = rj[pad]
    sub_es = None if esj is None else esj[pad]
    lane_on = jnp.asarray(np.arange(bucket) < n_active)

    if cfg.mode == "doubling":
        # restart with widening enabled, survivors only (paper Alg. 5),
        # each at its own radius
        st2 = beam_search_batch(points, graph, sub_q, start_ids, sub_r,
                                cfg.search, sub_es)
        d_ids, d_dists, d_count, d_over = jax.vmap(
            lambda st_, r_: _beam_results(st_, r_, cfg.result_cap))(st2, sub_r)
        sub = (d_ids, d_dists, d_count, d_over, st2.n_dist)
    else:
        sub_st = jax.tree.map(lambda x: x[pad], st)
        gfn = lambda q_, r_, st_, a_: greedy_search(
            points, graph, q_, r_, st_, cfg.result_cap, cfg.frontier_rounds,
            cfg.search, a_)
        gs = jax.vmap(gfn)(sub_q, sub_r, sub_st, lane_on)
        sub = (gs.res_ids, gs.res_dists, gs.res_count, gs.overflow, gs.n_dist)

    # one batched transfer for everything the host-side merge needs (the
    # per-leaf np.array() calls each synced the device separately)
    ids, dists, count, over, ndist, s_ids, s_dists, s_count, s_over, s_nd = (
        jax.device_get((base.ids, base.dists, base.count, base.overflow,
                        base.n_dist) + sub))
    ids, dists, count, over, ndist = (
        np.array(ids), np.array(dists), np.array(count), np.array(over),
        np.array(ndist))  # device_get leaves may be read-only views
    ids[sel] = s_ids[:n_active]
    dists[sel] = s_dists[:n_active]
    count[sel] = s_count[:n_active]
    over[sel] = s_over[:n_active]
    ndist[sel] += s_nd[:n_active]
    phase2 = jnp.asarray(active)
    return RangeResult(ids=jnp.asarray(ids), dists=jnp.asarray(dists),
                       count=jnp.asarray(count), overflow=jnp.asarray(over),
                       n_visited=base.n_visited, n_dist=jnp.asarray(ndist),
                       es_stopped=base.es_stopped, phase2=phase2)
