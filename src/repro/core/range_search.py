"""Range-retrieval algorithms on top of the beam search (paper Algs. 2/5/6).

Three modes, matching the paper:

* ``"beam"``     — the naive baseline: one beam search, filter the beam by r.
* ``"doubling"`` — Alg. 5 via in-place beam widening (``max_beam > beam``).
* ``"greedy"``   — Alg. 6: initial beam search; queries whose beam is
  saturated with in-range results continue with Alg. 2 (expand only in-range
  nodes, unbounded queue -> fixed-capacity result buffer + overflow counter).

Batched execution is two-phase with **query compaction** (DESIGN.md §2): the
uniform phase 1 runs over the whole batch; the irregular phase 2 runs only on
the compacted subset of queries that need it (bucketed to powers of two so jit
compiles O(log Q) variants). ``range_search_fused`` keeps everything in one
XLA program (no host sync) for dry-run lowering and single-dispatch serving.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..utils import INVALID_ID, next_pow2
from .beam_search import (
    BeamState,
    SearchConfig,
    beam_search_batch,
    in_range_count,
)
from .distances import gather_dist
from .graph import Graph


@dataclasses.dataclass(frozen=True)
class RangeConfig:
    """Static configuration for a range query batch."""

    search: SearchConfig = dataclasses.field(default_factory=SearchConfig)
    mode: str = "greedy"          # beam | doubling | greedy
    result_cap: int = 1024        # K_cap: per-query result buffer
    frontier_rounds: int = 4096   # greedy expansion budget (expansions/query)
    lam: float = 1.0              # λ threshold for entering phase 2

    def __post_init__(self):
        if self.mode not in ("beam", "doubling", "greedy"):
            raise ValueError(f"bad mode {self.mode!r}")
        if self.mode == "doubling" and self.search.max_beam <= self.search.beam:
            raise ValueError("doubling mode needs search.max_beam > search.beam")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RangeResult:
    """Batched range-query output (all arrays INVALID/inf padded)."""

    ids: jnp.ndarray       # (Q, K) int32
    dists: jnp.ndarray     # (Q, K) float32
    count: jnp.ndarray     # (Q,) int32 — number of valid entries
    overflow: jnp.ndarray  # (Q,) bool — K_cap or budget exceeded
    n_visited: jnp.ndarray # (Q,) int32 — phase-1 expansions
    n_dist: jnp.ndarray    # (Q,) int32 — total distance computations
    es_stopped: jnp.ndarray  # (Q,) bool
    phase2: jnp.ndarray    # (Q,) bool — query took the second phase


# ---------------------------------------------------------------------------
# Greedy continuation (paper Alg. 2), fixed-shape form.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GreedyState:
    res_ids: jnp.ndarray    # (K,) int32 — every id here is in-range
    res_dists: jnp.ndarray  # (K,) float32
    res_count: jnp.ndarray  # () int32
    expand_ptr: jnp.ndarray # () int32
    rounds: jnp.ndarray     # () int32
    overflow: jnp.ndarray   # () bool
    n_dist: jnp.ndarray     # () int32


def _greedy_init(st: BeamState, r, cap: int) -> GreedyState:
    """Seed the result buffer with every in-range node whose exact distance is
    already known: the visited log plus unexpanded in-range beam entries
    (disjoint by construction — expanded beam nodes are in the log)."""
    v_ok = st.visited_dists <= r
    b_ok = (st.dists <= r) & (~st.expanded) & (st.ids != INVALID_ID)
    ids = jnp.concatenate([jnp.where(v_ok, st.visited_ids, INVALID_ID),
                           jnp.where(b_ok, st.ids, INVALID_ID)])
    dists = jnp.concatenate([jnp.where(v_ok, st.visited_dists, jnp.inf),
                             jnp.where(b_ok, st.dists, jnp.inf)])
    # pack in-range entries to the front, closest first (paper pops
    # closest-first; our FIFO expansion then visits in that order)
    dists, ids = jax.lax.sort((dists, ids), num_keys=1, is_stable=True)
    k = min(cap, ids.shape[0])
    res_ids = jnp.full((cap,), INVALID_ID, jnp.int32).at[:k].set(ids[:k])
    res_dists = jnp.full((cap,), jnp.inf, jnp.float32).at[:k].set(dists[:k])
    total = jnp.sum(jnp.isfinite(dists))
    count = jnp.minimum(total, cap)
    return GreedyState(
        res_ids=res_ids,
        res_dists=res_dists,
        res_count=count.astype(jnp.int32),
        expand_ptr=jnp.asarray(0, jnp.int32),
        rounds=jnp.asarray(0, jnp.int32),
        overflow=(total > cap),
        n_dist=jnp.asarray(0, jnp.int32),
    )


def _greedy_step(points, graph: Graph, q, r, cap: int, metric: str, gs: GreedyState) -> GreedyState:
    node = gs.res_ids[gs.expand_ptr]
    nbrs = graph.out_neighbors(node)  # (R,)
    nd = gather_dist(points, nbrs, q, metric)
    rr = jnp.arange(nbrs.shape[0])
    dup_in_row = jnp.any(
        (nbrs[:, None] == nbrs[None, :]) & (rr[None, :] < rr[:, None]) & (nbrs[:, None] != INVALID_ID),
        axis=1,
    )
    seen = jnp.any((nbrs[:, None] == gs.res_ids[None, :]) & (nbrs[:, None] != INVALID_ID), axis=1)
    new = (nd <= r) & (~dup_in_row) & (~seen) & (nbrs != INVALID_ID)
    pos = gs.res_count + jnp.cumsum(new.astype(jnp.int32)) - 1
    write_pos = jnp.where(new & (pos < cap), pos, cap)  # cap == OOB -> dropped
    res_ids = gs.res_ids.at[write_pos].set(nbrs, mode="drop")
    res_dists = gs.res_dists.at[write_pos].set(nd, mode="drop")
    n_new = jnp.sum(new.astype(jnp.int32))
    return GreedyState(
        res_ids=res_ids,
        res_dists=res_dists,
        res_count=jnp.minimum(gs.res_count + n_new, cap),
        expand_ptr=gs.expand_ptr + 1,
        rounds=gs.rounds + 1,
        overflow=gs.overflow | (gs.res_count + n_new > cap),
        n_dist=gs.n_dist + jnp.sum(nbrs != INVALID_ID).astype(jnp.int32),
    )


@partial(jax.jit, static_argnames=("cap", "rounds", "metric"))
def greedy_search(
    points, graph: Graph, q, r, st: BeamState,
    cap: int, rounds: int, metric: str, active: bool | jnp.ndarray = True,
) -> GreedyState:
    """Paper Alg. 2 from a finished beam state. ``active=False`` lanes no-op."""
    gs = _greedy_init(st, r, cap)
    if not isinstance(active, jnp.ndarray):
        active = jnp.asarray(active)

    def cond(g: GreedyState):
        return active & (g.expand_ptr < g.res_count) & (g.rounds < rounds)

    gs = jax.lax.while_loop(cond, lambda g: _greedy_step(points, graph, q, r, cap, metric, g), gs)
    gs = dataclasses.replace(gs, overflow=gs.overflow | (gs.expand_ptr < gs.res_count))
    return gs


# ---------------------------------------------------------------------------
# Result extraction
# ---------------------------------------------------------------------------

def _beam_results(st: BeamState, r, cap: int):
    """Paper baseline/doubling answer: in-range entries of the active beam."""
    pos = jnp.arange(st.ids.shape[0])
    ok = (st.dists <= r) & (st.ids != INVALID_ID) & (pos < st.active_width)
    dists = jnp.where(ok, st.dists, jnp.inf)
    ids = jnp.where(ok, st.ids, INVALID_ID)
    dists, ids = jax.lax.sort((dists, ids), num_keys=1, is_stable=True)
    k = min(cap, ids.shape[0])
    out_ids = jnp.full((cap,), INVALID_ID, jnp.int32).at[:k].set(ids[:k])
    out_dists = jnp.full((cap,), jnp.inf, jnp.float32).at[:k].set(dists[:k])
    count = jnp.minimum(jnp.sum(ok), cap).astype(jnp.int32)
    return out_ids, out_dists, count, jnp.sum(ok) > cap


def _needs_phase2(st: BeamState, r, lam: float) -> jnp.ndarray:
    """Paper Alg. 6 trigger: the size-b beam is λ-saturated with results."""
    thresh = jnp.ceil(lam * st.active_width.astype(jnp.float32)).astype(jnp.int32)
    return in_range_count(st, r) >= jnp.maximum(thresh, 1)


# ---------------------------------------------------------------------------
# Fused single-program batch (used by dry-run lowering + single-dispatch serve)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def range_search_fused(
    points: jnp.ndarray,
    graph: Graph,
    queries: jnp.ndarray,
    start_ids: jnp.ndarray,
    r: jnp.ndarray,
    cfg: RangeConfig,
    es_radius: Optional[jnp.ndarray] = None,
) -> RangeResult:
    r = jnp.asarray(r, jnp.float32)
    st = beam_search_batch(points, graph, queries, start_ids, r, cfg.search, es_radius)

    if cfg.mode in ("beam", "doubling"):
        ids, dists, count, over = jax.vmap(partial(_beam_results, r=r, cap=cfg.result_cap))(st)
        phase2 = (st.active_width > cfg.search.beam) if cfg.mode == "doubling" else jnp.zeros_like(st.done)
        return RangeResult(ids=ids, dists=dists, count=count, overflow=over,
                           n_visited=st.n_visited, n_dist=st.n_dist,
                           es_stopped=st.es_stopped, phase2=phase2)

    # greedy: phase 2 only for saturated lanes (masked, not compacted)
    active = jax.vmap(partial(_needs_phase2, r=r, lam=cfg.lam))(st)
    gfn = lambda q_, st_, a_: greedy_search(
        points, graph, q_, r, st_, cfg.result_cap, cfg.frontier_rounds, cfg.search.metric, a_
    )
    gs = jax.vmap(gfn)(queries, st, active)
    b_ids, b_dists, b_count, b_over = jax.vmap(partial(_beam_results, r=r, cap=cfg.result_cap))(st)
    ids = jnp.where(active[:, None], gs.res_ids, b_ids)
    dists = jnp.where(active[:, None], gs.res_dists, b_dists)
    count = jnp.where(active, gs.res_count, b_count)
    over = jnp.where(active, gs.overflow, b_over)
    return RangeResult(ids=ids, dists=dists, count=count, overflow=over,
                       n_visited=st.n_visited, n_dist=st.n_dist + jnp.where(active, gs.n_dist, 0),
                       es_stopped=st.es_stopped, phase2=active)


# ---------------------------------------------------------------------------
# Two-phase pipeline with host-side query compaction (the QPS path)
# ---------------------------------------------------------------------------

def range_search_compacted(
    points: jnp.ndarray,
    graph: Graph,
    queries: jnp.ndarray,
    start_ids: jnp.ndarray,
    r: float,
    cfg: RangeConfig,
    es_radius: Optional[float] = None,
) -> RangeResult:
    """Phase 1 over the whole batch; phase 2 over the compacted survivors.

    The survivor subset is padded to the next power of two, so jit compiles at
    most O(log Q) phase-2 variants. This bounds the batched-while straggler
    effect: lanes with zero results never enter the expensive loop at all.
    """
    rj = jnp.asarray(r, jnp.float32)
    # phase 1 runs at the BASE beam for every mode (for doubling this is the
    # §Perf iteration C3 change: in-place widening inside the batched while
    # made every lane wait for the widest one — a 10x QPS straggler penalty;
    # the paper's restart-style doubling now runs on the compacted survivors
    # only, like greedy)
    p1_search = cfg.search if cfg.mode != "doubling" else dataclasses.replace(
        cfg.search, max_beam=cfg.search.beam,
        visit_cap=min(cfg.search.visit_cap, 4 * cfg.search.beam))
    st = beam_search_batch(points, graph, queries, start_ids, rj, p1_search, es_radius)
    b_ids, b_dists, b_count, b_over = jax.vmap(partial(_beam_results, r=rj, cap=cfg.result_cap))(st)
    base = RangeResult(ids=b_ids, dists=b_dists, count=b_count, overflow=b_over,
                       n_visited=st.n_visited, n_dist=st.n_dist,
                       es_stopped=st.es_stopped,
                       phase2=jnp.zeros_like(st.done))
    if cfg.mode == "beam":
        return base

    active = np.asarray(jax.vmap(partial(_needs_phase2, r=rj, lam=cfg.lam))(st))
    n_active = int(active.sum())
    if n_active == 0:
        return base

    sel = np.nonzero(active)[0]
    bucket = next_pow2(n_active)
    pad = np.concatenate([sel, np.full(bucket - n_active, sel[0], dtype=sel.dtype)])
    sub_q = queries[pad]
    lane_on = jnp.asarray(np.arange(bucket) < n_active)

    if cfg.mode == "doubling":
        # restart with widening enabled, survivors only (paper Alg. 5)
        st2 = beam_search_batch(points, graph, sub_q, start_ids, rj,
                                cfg.search, es_radius)
        s_ids, s_dists, s_count, s_over = jax.vmap(
            partial(_beam_results, r=rj, cap=cfg.result_cap))(st2)
        sub = (np.asarray(s_ids), np.asarray(s_dists), np.asarray(s_count),
               np.asarray(s_over), np.asarray(st2.n_dist))
    else:
        sub_st = jax.tree.map(lambda x: x[pad], st)
        gfn = lambda q_, st_, a_: greedy_search(
            points, graph, q_, rj, st_, cfg.result_cap, cfg.frontier_rounds,
            cfg.search.metric, a_)
        gs = jax.vmap(gfn)(sub_q, sub_st, lane_on)
        sub = (np.asarray(gs.res_ids), np.asarray(gs.res_dists),
               np.asarray(gs.res_count), np.asarray(gs.overflow),
               np.asarray(gs.n_dist))

    ids = np.array(base.ids)
    dists = np.array(base.dists)
    count = np.array(base.count)
    over = np.array(base.overflow)
    ndist = np.array(base.n_dist)
    ids[sel] = sub[0][:n_active]
    dists[sel] = sub[1][:n_active]
    count[sel] = sub[2][:n_active]
    over[sel] = sub[3][:n_active]
    ndist[sel] += sub[4][:n_active]
    phase2 = jnp.asarray(active)
    return RangeResult(ids=jnp.asarray(ids), dists=jnp.asarray(dists),
                       count=jnp.asarray(count), overflow=jnp.asarray(over),
                       n_visited=base.n_visited, n_dist=jnp.asarray(ndist),
                       es_stopped=base.es_stopped, phase2=phase2)
