"""Proximity-graph container.

The CPU reference (ParlayANN) stores per-node adjacency as dynamic vectors.
The TPU-native layout is a dense padded matrix:

    neighbors : (N, R) int32, row i = out-neighbors of node i,
                padded with INVALID_ID (sorts/clips to the end).

This is the layout every kernel and search loop consumes; it is also the
layout checkpointed to disk.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..utils import INVALID_ID


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """Padded fixed-degree adjacency."""

    neighbors: jnp.ndarray  # (N, R) int32, INVALID_ID padded

    @property
    def num_nodes(self) -> int:
        return self.neighbors.shape[0]

    @property
    def max_degree(self) -> int:
        return self.neighbors.shape[1]

    def degrees(self) -> jnp.ndarray:
        return jnp.sum(self.neighbors != INVALID_ID, axis=1)

    def out_neighbors(self, ids: jnp.ndarray) -> jnp.ndarray:
        """Gather adjacency rows; invalid ids yield all-INVALID rows."""
        n = self.num_nodes
        valid = ids < n
        safe = jnp.where(valid, ids, 0)
        rows = jnp.take(self.neighbors, safe, axis=0)
        return jnp.where(valid[..., None], rows, INVALID_ID)

    def lane_padded(self, multiple: int = 128) -> "Graph":
        """Copy with the degree axis INVALID-padded up to ``multiple``.

        The fused expand kernel maps adjacency rows onto (1, R) VMEM blocks;
        TPU lane tiling wants R to be a multiple of 128. Pad once at index
        load time (padding inside the jitted search loop would re-concat
        every iteration)."""
        r = self.max_degree
        r_pad = -(-r // multiple) * multiple
        if r_pad == r:
            return self
        pad = jnp.full((self.num_nodes, r_pad - r), INVALID_ID, jnp.int32)
        return Graph(neighbors=jnp.concatenate([self.neighbors, pad], axis=1))


def from_lists(lists: list[list[int]], max_degree: Optional[int] = None) -> Graph:
    """Build a Graph from python adjacency lists (testing convenience)."""
    r = max_degree if max_degree is not None else max((len(l) for l in lists), default=1)
    r = max(r, 1)
    out = np.full((len(lists), r), INVALID_ID, dtype=np.int32)
    for i, l in enumerate(lists):
        if len(l) > r:
            raise ValueError(f"node {i} has degree {len(l)} > max_degree {r}")
        out[i, : len(l)] = np.asarray(l, dtype=np.int32)
    return Graph(neighbors=jnp.asarray(out))


def random_regular(key: jax.Array, n: int, degree: int) -> Graph:
    """Random out-degree-``degree`` digraph (Vamana's initialization)."""
    nbrs = jax.random.randint(key, (n, degree), 0, n, dtype=jnp.int32)
    # avoid trivial self loops (shift by 1 mod n where equal to row id)
    row = jnp.arange(n, dtype=jnp.int32)[:, None]
    nbrs = jnp.where(nbrs == row, (nbrs + 1) % n, nbrs)
    return Graph(neighbors=nbrs)


def medoid(points: jnp.ndarray) -> jnp.ndarray:
    """Index of the point closest to the dataset centroid (search entry)."""
    c = jnp.mean(points, axis=0, keepdims=True)
    d = jnp.sum((points - c) ** 2, axis=-1)
    return jnp.argmin(d).astype(jnp.int32)


def start_points(points: jnp.ndarray, metric: str = "l2", k: int = 1) -> jnp.ndarray:
    """Search entry points.

    L2: the medoid plus k-1 *spread* points (k-means++-style farthest-point
    selection) — multiple well-separated entries make graph navigation
    robust to weakly-connected regions (beyond-paper robustness tweak,
    recorded in EXPERIMENTS.md).
    MIPS: the top-norm points (high-norm points dominate inner products).
    """
    if metric == "ip":
        norms = jnp.sum(points * points, axis=-1)
        _, idx = jax.lax.top_k(norms, k)
        return idx.astype(jnp.int32)
    starts = [medoid(points)]
    mind = None
    for _ in range(k - 1):
        ds = jnp.sum((points - points[starts[-1]]) ** 2, axis=-1)
        mind = ds if mind is None else jnp.minimum(mind, ds)
        starts.append(jnp.argmax(mind).astype(jnp.int32))
    return jnp.stack(starts).astype(jnp.int32)
