"""Accuracy metrics: average precision (paper Def. 2.2) and recall@k."""
from __future__ import annotations

import numpy as np

from ..utils import INVALID_ID


def _valid_rows(ids: np.ndarray, counts: np.ndarray) -> list[np.ndarray]:
    out = []
    for row, c in zip(ids, counts):
        row = row[: int(c)]
        out.append(row[row != INVALID_ID])
    return out


def average_precision(
    gt_ids: np.ndarray, gt_counts: np.ndarray,
    res_ids: np.ndarray, res_counts: np.ndarray,
) -> float:
    """sum_q |K ∩ K'| / sum_q |K|  (size-weighted, per the paper).

    ``gt_counts`` may exceed the ground-truth cap (``gt_ids`` row length); the
    denominator uses the true counts, so a capped GT understates nothing.
    """
    gt_ids = np.asarray(gt_ids)
    res_ids = np.asarray(res_ids)
    gt_counts = np.asarray(gt_counts)
    res_counts = np.asarray(res_counts)
    denom = int(gt_counts.sum())
    if denom == 0:
        return 1.0
    num = 0
    for g, res in zip(_valid_rows(gt_ids, np.minimum(gt_counts, gt_ids.shape[1])),
                      _valid_rows(res_ids, res_counts)):
        if len(g) == 0 or len(res) == 0:
            continue
        num += len(np.intersect1d(g, res, assume_unique=False))
    return num / denom


def recall_at_k(
    gt_ids: np.ndarray,   # (Q, k) exact top-k
    res_ids: np.ndarray,  # (Q, >=k) returned
    k: int,
) -> float:
    """Standard k@k recall for the top-k comparison experiment (Sec. 5)."""
    gt_ids = np.asarray(gt_ids)[:, :k]
    res_ids = np.asarray(res_ids)[:, :k]
    hits = 0
    for g, res in zip(gt_ids, res_ids):
        g = g[g != INVALID_ID]
        res = res[res != INVALID_ID]
        hits += len(np.intersect1d(g, res))
    return hits / max(1, gt_ids.shape[0] * k)


def zero_result_accuracy(gt_counts: np.ndarray, res_counts: np.ndarray) -> float:
    """Fraction of zero-result queries correctly answered with zero results."""
    gt_counts = np.asarray(gt_counts)
    res_counts = np.asarray(res_counts)
    mask = gt_counts == 0
    if mask.sum() == 0:
        return 1.0
    return float((res_counts[mask] == 0).mean())
