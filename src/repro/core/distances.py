"""Distance functions for the range-retrieval engine.

Two metrics, matching the paper (Sec. 2):

* ``"l2"``  — squared Euclidean distance. We use the *squared* form internally
  (monotone in true L2, and radii in the big-ann-benchmarks range track —
  e.g. SSNPP's 96237, BIGANN's 10000 — are already squared-L2 values).
* ``"ip"``  — negative inner product (maximum-inner-product search as a
  distance). Radii may be negative (e.g. Wikipedia's -10.5 means
  ``dot(p, q) >= 10.5``).

All functions support a blocked matmul formulation so the MXU does the work:
``||q - x||^2 = ||q||^2 + ||x||^2 - 2 q.x``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .corpus import QuantizedCorpus, quantized_gather_lb

METRICS = ("l2", "ip")


def _check(metric: str) -> None:
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}; expected one of {METRICS}")


def point_dist(x: jnp.ndarray, q: jnp.ndarray, metric: str = "l2") -> jnp.ndarray:
    """Distance between broadcastable point arrays along the last axis."""
    _check(metric)
    if metric == "l2":
        d = x - q
        return jnp.sum(d * d, axis=-1)
    return -jnp.sum(x * q, axis=-1)


def pairwise_dist(queries: jnp.ndarray, points: jnp.ndarray, metric: str = "l2") -> jnp.ndarray:
    """(Q, d) x (N, d) -> (Q, N) distance matrix via a single matmul."""
    _check(metric)
    dots = queries @ points.T
    if metric == "ip":
        return -dots
    qn = jnp.sum(queries * queries, axis=-1, keepdims=True)
    pn = jnp.sum(points * points, axis=-1, keepdims=True)
    return jnp.maximum(qn + pn.T - 2.0 * dots, 0.0)


@partial(jax.jit, static_argnames=("metric",))
def gather_dist(
    points,               # (N, d) database array, or a QuantizedCorpus
    ids: jnp.ndarray,     # (..., R) int32 candidate ids (may contain INVALID)
    q: jnp.ndarray,       # (..., d) query, broadcastable against ids' batch dims
    metric: str = "l2",
) -> jnp.ndarray:
    """Distances from q to points[ids]; padded/invalid ids get +inf.

    A ``QuantizedCorpus`` yields each candidate's *certified lower bound*
    (``core.corpus.lower_bound_dists``): the int8 rows dequantize
    in-register and the bound subtracts the row's own reconstruction error,
    so every downstream ``dist <= r`` test keeps a provable superset at the
    caller's original radius (the rerank stage trims the boundary band).
    """
    _check(metric)
    if isinstance(points, QuantizedCorpus):
        n = points.codes.shape[0]
        valid = ids < n
        safe = jnp.where(valid, ids, 0)
        d = quantized_gather_lb(points, safe, q, metric)
        return jnp.where(valid, d, jnp.inf)
    n = points.shape[0]
    valid = ids < n
    safe = jnp.where(valid, ids, 0)
    vecs = jnp.take(points, safe, axis=0)  # (..., R, d)
    # distance arithmetic in f32 regardless of corpus storage dtype — a
    # bf16-stored corpus halves the gather traffic (the engine's dominant
    # roofline term; EXPERIMENTS.md §Perf C) without moving the decision
    # boundary (error ~1e-3 relative, radii are O(1))
    d = point_dist(vecs.astype(jnp.float32), q.astype(jnp.float32)[..., None, :], metric)
    return jnp.where(valid, d, jnp.inf)
