"""Radius-selection methodology (paper Sec. 3).

Given a corpus + query sample, sweep a radius grid, compute the
percent-captured curve (Fig. 3) and the match-size frequency distribution
(Fig. 4), score the *robustness* of each candidate radius (local slope of the
capture curve in log-space — flat == robust to perturbation), and select a
radius hitting a target match profile (most queries zero results, a few large
outliers — the Pareto shape real range workloads follow).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .ground_truth import range_counts_at


@dataclasses.dataclass(frozen=True)
class RadiusProfile:
    radii: np.ndarray            # (G,) swept grid
    percent_captured: np.ndarray # (G,) mean fraction of DB inside the ball
    zero_frac: np.ndarray        # (G,) fraction of queries with 0 matches
    robustness: np.ndarray       # (G,) |d log10(captured) / d log10-ish step|, lower = more robust
    counts: np.ndarray           # (Q, G) per-query match counts


# Fig. 4 bucketing: 0, <=10, <=100, <=1e3, <=1e4, <=1e5
FIG4_BUCKETS = (0, 10, 100, 1_000, 10_000, 100_000)


def match_histogram(counts: np.ndarray) -> dict[str, int]:
    """Bucket per-query match counts exactly like the paper's Fig. 4 table.

    The terminal ``>1e5`` bucket catches heavy-tailed queries past the
    paper's last printed column, so the bucket sums always equal the number
    of queries (without it, a query with more than 1e5 matches silently
    vanished from the table)."""
    counts = np.asarray(counts)
    out = {"0": int((counts == 0).sum())}
    prev = 0
    for b in FIG4_BUCKETS[1:]:
        out[f"<=1e{int(np.log10(b))}"] = int(((counts > prev) & (counts <= b)).sum())
        prev = b
    out[f">1e{int(np.log10(FIG4_BUCKETS[-1]))}"] = int(
        (counts > FIG4_BUCKETS[-1]).sum())
    return out


def sweep(
    points,
    queries,
    radii,
    metric: str = "l2",
    block: int = 2048,
) -> RadiusProfile:
    radii = np.asarray(radii, np.float32)
    counts = np.asarray(range_counts_at(jnp.asarray(points), jnp.asarray(queries),
                                        jnp.asarray(radii), metric, block))
    n = points.shape[0]
    captured = counts.mean(axis=0) / n
    zero_frac = (counts == 0).mean(axis=0)
    # robustness: relative change of captured per grid step (flat == robust)
    eps = 1e-12
    lg = np.log10(np.maximum(captured, eps))
    # np.gradient needs >= 2 samples; a single-radius grid has no slope
    # information, so score it perfectly robust instead of crashing
    slope = np.abs(np.gradient(lg)) if lg.size >= 2 else np.zeros_like(lg)
    return RadiusProfile(radii=radii, percent_captured=captured,
                         zero_frac=zero_frac, robustness=slope, counts=counts)


def default_grid(points, queries, metric: str = "l2", num: int = 48) -> np.ndarray:
    """A grid spanning ~0% to ~100% capture, from a distance sample."""
    pts = np.asarray(points)
    qs = np.asarray(queries)
    sample = pts[np.random.default_rng(0).choice(pts.shape[0], size=min(2048, pts.shape[0]), replace=False)]
    if metric == "l2":
        d = ((qs[:, None, :] - sample[None, : min(512, sample.shape[0]), :]) ** 2).sum(-1)
    else:
        d = -(qs @ sample[: min(512, sample.shape[0])].T)
    lo, hi = np.quantile(d, 0.0005), np.quantile(d, 0.9995)
    if metric == "l2":
        lo = max(lo, 1e-9)
        return np.geomspace(lo, hi, num).astype(np.float32)
    return np.linspace(lo, hi, num).astype(np.float32)


def select_radius(
    profile: RadiusProfile,
    target_zero_frac: float = 0.95,
    robustness_weight: float = 1.0,
) -> tuple[float, int]:
    """Pick the radius whose zero-result fraction is closest to target,
    penalized by capture-curve steepness (the paper's robustness criterion).

    Returns (radius, grid_index). Raises ``ValueError`` when no grid point
    is feasible (every radius yields zero matches for every query): an
    all-inf score would otherwise argmin to index 0 and silently bless a
    vacuous benchmark radius."""
    score = np.abs(profile.zero_frac - target_zero_frac) + robustness_weight * profile.robustness
    # require at least one query with a match, else the benchmark is vacuous
    feasible = profile.zero_frac < 1.0
    if not feasible.any():
        raise ValueError(
            "no feasible radius in the swept grid: every candidate yields "
            "zero matches for every query — widen the grid (default_grid) "
            "or check the corpus/query scales")
    score = np.where(feasible, score, np.inf)
    gi = int(np.argmin(score))
    return float(profile.radii[gi]), gi
