"""RangeSearchEngine — the paper's contribution as one composable object.

One graph index serves both top-k and range queries (the paper's stated
goal). Single-shard here; ``repro.dist.sharded_engine.sharded_range_search``
runs the same fused search per shard under shard_map and union-merges the
per-shard results for the multi-shard production layout (one
``ShardedCorpus`` sub-index per model-axis shard, built by
``repro.dist.sharded_engine.build_sharded``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .beam_search import SearchConfig, beam_search_batch, broadcast_radius, topk_from_state
from .build import BuildConfig, build_vamana
from .corpus import Corpus, bytes_per_vector, corpus_cast, corpus_dim, corpus_dtype_name, corpus_size
from .graph import Graph, start_points
from .range_search import RangeConfig, RangeResult, range_search_compacted, range_search_fused


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RangeSearchEngine:
    """An in-memory graph index over a vector corpus.

    ``points`` is a ``Corpus``: a plain (N, d) array (f32/bf16 storage) or a
    ``QuantizedCorpus`` (int8 codes + scales + raw vectors) — the whole
    query path dispatches on the value. Graph *construction* always runs on
    exact f32 vectors; ``corpus_dtype`` controls only what the built engine
    stores and the search loop gathers.
    """

    points: Corpus         # (N, d) array or QuantizedCorpus
    graph: Graph
    start_ids: jnp.ndarray # (S,) search entry points (medoid by default)
    # (N, W) uint32 packed per-point label rows (core.labels.pack_labels),
    # or None for an unlabeled corpus. Labels gate only the result stage —
    # see range_search.filter_labeled — so attaching them never changes
    # unfiltered answers.
    labels: Optional[jnp.ndarray] = None
    metric: str = dataclasses.field(metadata=dict(static=True), default="l2")

    # -- construction -------------------------------------------------------
    @staticmethod
    def build(points: jnp.ndarray, build_cfg: Optional[BuildConfig] = None,
              metric: str = "l2", seed: int = 0,
              n_starts: int = 4,
              corpus_dtype: Optional[str] = None,
              labels: Optional[jnp.ndarray] = None,
              tier: bool = False,
              resident_mb: Optional[float] = None) -> "RangeSearchEngine":
        cfg = build_cfg or BuildConfig(metric=metric)
        graph = build_vamana(points, cfg, seed=seed)
        return RangeSearchEngine.from_graph(points, graph, metric=metric,
                                            n_starts=n_starts,
                                            corpus_dtype=corpus_dtype,
                                            labels=labels, tier=tier,
                                            resident_mb=resident_mb)

    @staticmethod
    def from_graph(points: jnp.ndarray, graph: Graph, metric: str = "l2",
                   n_starts: int = 4,
                   corpus_dtype: Optional[str] = None,
                   labels: Optional[jnp.ndarray] = None,
                   tier: bool = False,
                   resident_mb: Optional[float] = None) -> "RangeSearchEngine":
        starts = start_points(points, metric, n_starts)
        if tier:
            # deferred import: core stays importable without repro.tier;
            # only an engine explicitly built with tier=True touches it
            from ..tier import tiered_corpus
            points = tiered_corpus(points,
                                   corpus_dtype=corpus_dtype or "int8",
                                   resident_mb=resident_mb)
        elif corpus_dtype is not None:
            points = corpus_cast(points, corpus_dtype)
        if labels is not None:
            labels = jnp.asarray(labels, jnp.uint32)
            if labels.shape[0] != corpus_size(points):
                raise ValueError(
                    f"labels rows ({labels.shape[0]}) != corpus size "
                    f"({corpus_size(points)})")
        return RangeSearchEngine(points=points, graph=graph,
                                 start_ids=starts, labels=labels,
                                 metric=metric)

    # -- queries -------------------------------------------------------------
    def topk(self, queries: jnp.ndarray, k: int = 10,
             cfg: Optional[SearchConfig] = None):
        cfg = cfg or SearchConfig(beam=max(2 * k, 32), max_beam=max(2 * k, 32),
                                  visit_cap=max(4 * k, 128), metric=self.metric)
        st = beam_search_batch(self.points, self.graph, queries, self.start_ids,
                               jnp.asarray(jnp.inf, jnp.float32), cfg)
        return topk_from_state(st, k)

    def range(self, queries: jnp.ndarray, r, *,
              cfg: Optional[RangeConfig] = None,
              es_radius=None,
              compacted: bool = True,
              tombstones=None,
              filter=None) -> RangeResult:
        """Range search. ``r`` (and ``es_radius``) may be a scalar, applied
        to every query, or a ``(Q,)`` vector giving each query its own
        radius; scalars broadcast, so the two forms answer identically when
        all radii are equal. ``tombstones`` is the live subsystem's packed
        dead-slot bitset: deleted slots still route the traversal but never
        appear in results. ``filter`` is a per-query
        :class:`~repro.core.labels.LabelFilter` predicate over the engine's
        attached ``labels`` (required when filtering); filtered-out points
        likewise route but never answer. Everything past ``(queries, r)``
        is keyword-only (shared order with the ``range_search_*`` module
        entry points)."""
        cfg = cfg or RangeConfig(search=SearchConfig(metric=self.metric))
        if cfg.search.metric != self.metric:
            cfg = dataclasses.replace(cfg, search=dataclasses.replace(cfg.search, metric=self.metric))
        if filter is not None and self.labels is None:
            raise ValueError(
                "engine has no labels attached; build with labels= to use "
                "filtered range search")
        n = queries.shape[0]
        r = broadcast_radius(r, n)
        es_radius = None if es_radius is None else broadcast_radius(es_radius, n)
        fn = range_search_compacted if compacted else range_search_fused
        return fn(corpus=self.points, graph=self.graph, queries=queries,
                  start_ids=self.start_ids, r=r, cfg=cfg,
                  es_radius=es_radius, tombstones=tombstones,
                  labels=None if filter is None else self.labels,
                  label_filter=filter)

    # -- introspection -------------------------------------------------------
    def stats(self) -> dict:
        deg = np.asarray(self.graph.degrees())
        out = dict(
            num_points=corpus_size(self.points),
            dim=corpus_dim(self.points),
            max_degree=int(self.graph.max_degree),
            mean_degree=float(deg.mean()),
            min_degree=int(deg.min()),
            metric=self.metric,
            corpus_dtype=corpus_dtype_name(self.points),
            hot_bytes_per_vector=int(bytes_per_vector(self.points)),
        )
        if getattr(self.points, "is_tiered", False):
            out["tier"] = self.points.counters.as_dict()
            out["memory_budget"] = self.points.budget().as_dict()
        return out
