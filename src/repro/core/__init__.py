"""The paper's contribution: range retrieval on graph-based indices."""
from .beam_search import (
    ES_D_TOP1,
    ES_D_TOP10,
    ES_D_VISITED,
    ES_NONE,
    ES_RATIO_TOP10,
    BeamState,
    SearchConfig,
    beam_search,
    beam_search_batch,
    broadcast_radius,
    topk_from_state,
)
from .build import (
    BuildConfig,
    build_knn_graph,
    build_vamana,
    insert_batch_step,
    robust_prune,
)
from .corpus import (
    CORPUS_DTYPES,
    Corpus,
    QuantizedCorpus,
    bytes_per_vector,
    corpus_cast,
    corpus_dim,
    corpus_dtype_name,
    corpus_raw,
    corpus_set_rows,
    corpus_size,
    corpus_take_rows,
    corpus_with_capacity,
    lower_bound_dists,
    quantize_corpus,
    quantize_rows,
    query_quant_err,
    upper_bound_dists,
)
from .distances import gather_dist, pairwise_dist, point_dist
from .engine import RangeSearchEngine
from .graph import Graph, from_lists, medoid, random_regular
from .ground_truth import exact_range_search, exact_topk, range_counts_at
from .labels import (
    LabelFilter,
    all_pass_filter,
    label_match_counts,
    label_match_matrix,
    labels_match,
    make_label_filter,
    make_mask,
    num_label_words,
    pack_labels,
)
from .metrics import average_precision, recall_at_k, zero_result_accuracy
from .radius import RadiusProfile, default_grid, match_histogram, select_radius, sweep
from .range_search import (
    GreedyState,
    RangeConfig,
    RangeResult,
    filter_labeled,
    filter_tombstoned,
    finalize_results,
    greedy_lane_done,
    greedy_resume_batch,
    greedy_search,
    greedy_seed_batch,
    range_phase1,
    range_search_compacted,
    range_search_fused,
)

__all__ = [k for k in dir() if not k.startswith("_")]
