"""Per-point label sets and per-query label predicates (filtered retrieval).

Production range workloads (dedup, moderation, face search) scope every
query to a tenant or metadata slice. Labels here are the packed-bitset
form of that metadata: each corpus point carries a fixed-size ``(W,)``
uint32 row with one bit per label id (the same word-packing as
``core.bitset``, but per-point rows instead of one corpus-wide set), and
each query carries a predicate over those bits:

* **AND** (``is_and=True``): the point must carry *every* bit of the
  query's mask — ``(row & mask) == mask`` word-wise. A zero mask is
  vacuously true, so the canonical *all-pass* predicate is
  ``AND`` with an empty mask (``all_pass_filter``).
* **OR** (``is_and=False``): the point must carry *any* masked bit —
  ``(row & mask) != 0`` in some word. A zero-mask OR matches nothing.

The predicate is applied at the **result stage** of the range search
(next to the tombstone drop — see ``range_search.finalize_results``):
filtered-out points still route the traversal exactly as before, they
just never enter results or counts. That placement is what makes the
oracle guarantees provable — an all-pass filter is bitwise-identical to
no filter, and a coarser predicate's result set contains a finer one's
whenever the walk recovers the full radius ball.

Both predicate modes are evaluated branch-free per lane
(``jnp.where`` over the two tests), so one micro-batch freely mixes
AND- and OR-filtered queries with unfiltered ones.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..utils import cdiv


def num_label_words(num_labels: int) -> int:
    """Packed uint32 words per label row (>= 1 so shapes never degenerate)."""
    if num_labels < 1:
        raise ValueError("num_labels must be >= 1")
    return cdiv(num_labels, 32)


def pack_labels(
    labels: Union[Sequence[Iterable[int]], np.ndarray],
    num_labels: int,
) -> np.ndarray:
    """Pack per-point label sets into ``(N, W)`` uint32 rows.

    ``labels`` is either a sequence of per-point label-id iterables or an
    ``(N, num_labels)`` boolean membership matrix. Label ids live in
    ``[0, num_labels)``; packing is exact (no hashing — label vocabularies
    are small compared to corpora, so every id owns a bit)."""
    w = num_label_words(num_labels)
    arr = np.asarray(labels, dtype=object) if not isinstance(labels, np.ndarray) else labels
    if isinstance(arr, np.ndarray) and arr.dtype != object and arr.ndim == 2:
        if arr.shape[1] != num_labels:
            raise ValueError(
                f"membership matrix has {arr.shape[1]} columns, expected "
                f"{num_labels}")
        n = arr.shape[0]
        out = np.zeros((n, w), np.uint32)
        rows, ids = np.nonzero(arr)
        np.bitwise_or.at(out, (rows, ids // 32), np.uint32(1) << (ids % 32).astype(np.uint32))
        return out
    n = len(labels)
    out = np.zeros((n, w), np.uint32)
    for i, row in enumerate(labels):
        for lid in row:
            lid = int(lid)
            if not 0 <= lid < num_labels:
                raise ValueError(f"label id {lid} outside [0, {num_labels})")
            out[i, lid // 32] |= np.uint32(1) << np.uint32(lid % 32)
    return out


def make_mask(label_ids: Iterable[int], num_labels: int) -> np.ndarray:
    """One query predicate's ``(W,)`` uint32 bit mask."""
    return pack_labels([list(label_ids)], num_labels)[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LabelFilter:
    """Batched per-query label predicate (a pytree; rides jit untouched).

    ``masks`` is ``(Q, W)`` uint32 — one packed label mask per lane;
    ``is_and`` is ``(Q,)`` bool selecting AND (must carry all masked bits)
    vs OR (must carry any) per lane. The all-pass lane is AND with a zero
    mask."""

    masks: jnp.ndarray   # (Q, W) uint32
    is_and: jnp.ndarray  # (Q,) bool


def all_pass_filter(n_queries: int, num_labels: int) -> LabelFilter:
    """The identity predicate for every lane (AND over an empty mask)."""
    w = num_label_words(num_labels)
    return LabelFilter(masks=jnp.zeros((n_queries, w), jnp.uint32),
                       is_and=jnp.ones((n_queries,), bool))


def make_label_filter(
    label_ids: Sequence[Optional[Iterable[int]]],
    num_labels: int,
    modes: Union[str, Sequence[str]] = "and",
) -> LabelFilter:
    """Build a :class:`LabelFilter` from per-query label-id lists.

    ``label_ids[i] = None`` (or an empty list under AND) makes lane ``i``
    all-pass; ``modes`` is ``"and"``/``"or"`` shared or one mode per lane."""
    q = len(label_ids)
    if isinstance(modes, str):
        modes = [modes] * q
    if len(modes) != q:
        raise ValueError(f"{len(modes)} modes for {q} queries")
    w = num_label_words(num_labels)
    masks = np.zeros((q, w), np.uint32)
    is_and = np.zeros((q,), bool)
    for i, (ids, mode) in enumerate(zip(label_ids, modes)):
        if mode not in ("and", "or"):
            raise ValueError(f"bad filter mode {mode!r}")
        if ids is None:
            is_and[i] = True  # all-pass: AND over the empty mask
            continue
        masks[i] = make_mask(ids, num_labels)
        is_and[i] = mode == "and"
    return LabelFilter(masks=jnp.asarray(masks), is_and=jnp.asarray(is_and))


def labels_match(rows: jnp.ndarray, mask: jnp.ndarray,
                 is_and) -> jnp.ndarray:
    """Branch-free predicate test: ``rows`` is ``(..., W)`` packed label
    rows, ``mask`` a ``(W,)`` query mask, ``is_and`` the lane's mode.
    Returns a ``(...,)`` bool — both modes are computed and selected with
    ``where`` so the program is identical across lanes (vmap-friendly)."""
    hit = rows & mask
    and_ok = jnp.all(hit == mask, axis=-1)
    or_ok = jnp.any(hit != 0, axis=-1)
    return jnp.where(is_and, and_ok, or_ok)


@jax.jit
def label_match_counts(labels: jnp.ndarray, filt: LabelFilter) -> jnp.ndarray:
    """Per-lane posting-list sizes: how many corpus points satisfy each
    lane's predicate. This is the selectivity signal the compacted path's
    per-lane fallback dispatch thresholds on (``RangeConfig.filter_threshold``)."""
    fn = lambda m, a: jnp.sum(labels_match(labels, m, a).astype(jnp.int32))
    return jax.vmap(fn)(filt.masks, filt.is_and)


@jax.jit
def label_match_matrix(labels: jnp.ndarray, filt: LabelFilter) -> jnp.ndarray:
    """Dense ``(Q, N)`` predicate-satisfaction matrix (host-side dispatch:
    posting lists for the brute-scan fallback and seeded entry points)."""
    return jax.vmap(lambda m, a: labels_match(labels, m, a))(
        filt.masks, filt.is_and)
