"""Quantized corpus storage for the range engine (the two-pass pipeline).

The engine's dominant roofline term is gathering corpus vectors from HBM for
every distance in the search loop (``distances.gather_dist`` note, README
VMEM math). An int8 corpus cuts that term ~4x: the hot loop gathers
1-byte codes plus a 12-byte metadata row instead of ``4 d`` bytes, and all
in-loop range tests run on *approximate* distances. Range retrieval makes
this safe in a way top-k search cannot: the decision is a threshold test
against ``r``, so approximate distances suffice everywhere except inside a
**provable error band** around the radius boundary, and only that band is
reranked against the exact f32 vectors (``range_search`` two-pass stage).

Scheme — the per-row extension of the symmetric absmax quantizer in
``dist.compression``:

    codes[i]  = round(x[i] / scales[i]),  scales[i] = max|x[i]| / 127
    x_hat[i]  = codes[i] * scales[i]

Per-element error is at most ``scales[i] / 2`` (absmax scaling never
clips), bounding the row's L2 reconstruction error by ``scales[i] *
sqrt(d) / 2``. We store something ~1.7x tighter: the *actual* error

    err[i] = ||x[i] - x_hat[i]||_2   (computed exactly at quantize time)

which is itself a valid bound (it IS the error; ``_SLACK`` covers the f32
rounding of computing and applying it) — the worst-case half-step-
everywhere bound assumes an adversarial row, while real rows sit near the
``scale * sqrt(d/12)`` RMS.

**Guard band as lower-bound distances.** Rather than widening the radius,
the quantized distance paths return the per-candidate *certified lower
bound* of the true distance:

* **l2** (squared form, like the radii): with ``g_i = err[i] + err_q``
  (``err_q`` = the query-side quantization error of the backend that
  computed ``d_hat``: the int8 MXU kernels quantize the query and subtract
  their own exact ``err_q``; the XLA path keeps the query in f32, so its
  ``err_q`` is 0 and its band is ~2x narrower),

      |sqrt(d_true) - sqrt(d_hat)| <= g_i
      d_lb = max(sqrt(d_hat) - g_i, 0)^2        (lower_bound_dists)
      d_ub = (sqrt(d_lb) + 2 G_i)^2             (upper_bound_dists)

* **ip** (``d = -x.q``): ``|d_true - d_hat| <= eps_i = err[i] * ||q|| +
  ||x_hat[i]|| * err_q``, so ``d_lb = d_hat - eps_i``, ``d_ub = d_lb +
  2 Eps_i``.

The upper-bound recovery uses the *envelope* ``G_i = err[i] + err_q >=
g_i`` (worst case over backends), so one rerank covers results whose
distances came from either path — mixing ``gather_dist`` (XLA) and the
Pallas kernels inside one search stays sound, at the price of a slightly
conservative ambiguity test on the XLA path.

Then ``d_lb <= d_true <= d_ub`` always, and every existing threshold test
``dist <= r`` in the search loop — beam extraction, λ-saturation, greedy
in-range appends — becomes a *keep-band* test automatically, against the
caller's ORIGINAL radius: no false negatives (``d_true <= r`` implies
``d_lb <= r``), each candidate guarded by its own row's error rather than a
corpus-wide worst case. The rerank stage then splits kept candidates by the
recovered upper bound: ``d_ub <= r`` is a *sure* member (provably in
range), the rest are *ambiguous* and get one batched exact f32 gather —
zero false negatives inside the band, zero false positives after rerank.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from ..dist.compression import GUARD_SLACK as _SLACK, quantize_int8_rows

CORPUS_DTYPES = ("float32", "bfloat16", "int8")

# hot-loop metadata bytes gathered per int8 row: the (N, 3) f32
# [scale, |x_hat|^2, err] row. Single source of truth for every
# bytes-per-distance accounting site (bytes_per_vector here,
# analysis.roofline.corpus_bytes_per_distance, the README table).
META_BYTES = 12


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedCorpus:
    """Int8 corpus codes + per-vector metadata (+ optional exact vectors).

    ``meta`` packs ``[scale, |x_hat|^2, err]`` per row so kernels gather
    one 12-byte metadata row per candidate alongside the 1-byte/dim codes
    (one DMA, not three). ``raw`` is the exact corpus the rerank stage
    gathers from; ``raw=None`` disables reranking (capacity-constrained
    deployments keep the certified-superset semantics instead)."""

    codes: jnp.ndarray            # (N, d) int8
    meta: jnp.ndarray             # (N, 3) f32 — [scale, |x_hat|^2, err]
    raw: Optional[jnp.ndarray]    # (N, d) f32/bf16 exact vectors, or None

    @property
    def shape(self):
        # mirror ndarray so shape-only call sites need no dispatch
        return self.codes.shape

    @property
    def scales(self) -> jnp.ndarray:
        return self.meta[..., 0]

    @property
    def sqnorms(self) -> jnp.ndarray:
        return self.meta[..., 1]

    @property
    def errs(self) -> jnp.ndarray:
        return self.meta[..., 2]


# The third arm is `repro.tier.TieredCorpus` (duck-typed via its
# ``is_tiered`` marker rather than imported — core stays tier-free; the
# helpers below recurse into its device-resident arm).
Corpus = Union[jnp.ndarray, QuantizedCorpus, "TieredCorpus"]  # noqa: F821


def quantize_rows(vecs: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize (B, d) f32 rows -> (codes (B, d) int8, meta (B, 3) f32).

    The per-row [scale, |x_hat|^2, err] metadata matches ``quantize_corpus``
    exactly (err is the EXACT reconstruction L2, not a bound), so rows
    written into a live corpus one batch at a time carry the same certified
    guard band as rows quantized at build time."""
    vecs = jnp.asarray(vecs).astype(jnp.float32)
    codes, scales = quantize_int8_rows(vecs)
    deq = codes.astype(jnp.float32) * scales[:, None]
    sqnorms = jnp.sum(deq * deq, axis=-1)
    err = jnp.sqrt(jnp.sum((vecs - deq) ** 2, axis=-1))
    return codes, jnp.stack([scales, sqnorms, err], axis=-1)


def quantize_corpus(points: jnp.ndarray, keep_raw: bool = True) -> QuantizedCorpus:
    """Per-vector symmetric absmax int8 quantization of an (N, d) corpus."""
    points = jnp.asarray(points)
    codes, meta = quantize_rows(points)
    return QuantizedCorpus(
        codes=codes,
        meta=meta,
        raw=points if keep_raw else None,
    )


def corpus_cast(points: jnp.ndarray, corpus_dtype: str) -> Corpus:
    """Cast an f32 corpus to its storage dtype (the ``corpus_dtype`` knob)."""
    if corpus_dtype not in CORPUS_DTYPES:
        raise ValueError(
            f"corpus_dtype {corpus_dtype!r} not in {CORPUS_DTYPES}")
    if corpus_dtype == "int8":
        return quantize_corpus(points)
    return jnp.asarray(points).astype(jnp.dtype(corpus_dtype))


def corpus_dtype_name(points: Corpus) -> str:
    if getattr(points, "is_tiered", False):
        return corpus_dtype_name(points.device)
    if isinstance(points, QuantizedCorpus):
        return "int8"
    return str(jnp.asarray(points).dtype)


def corpus_size(points: Corpus) -> int:
    if getattr(points, "is_tiered", False):
        return corpus_size(points.device)
    return (points.codes if isinstance(points, QuantizedCorpus)
            else points).shape[0]


def corpus_dim(points: Corpus) -> int:
    if getattr(points, "is_tiered", False):
        return corpus_dim(points.device)
    return (points.codes if isinstance(points, QuantizedCorpus)
            else points).shape[-1]


def bytes_per_vector(points: Corpus) -> int:
    """Hot-loop HBM bytes gathered per distance (the roofline term)."""
    if getattr(points, "is_tiered", False):
        return bytes_per_vector(points.device)  # raw rows are host-side
    d = corpus_dim(points)
    if isinstance(points, QuantizedCorpus):
        return d + META_BYTES  # int8 codes + the f32 metadata row
    return d * jnp.dtype(points.dtype).itemsize


def query_quant_err(q: jnp.ndarray) -> jnp.ndarray:
    """Exact L2 query-side quantization error ``||q - q_hat||``.

    The int8 MXU kernels quantize the query with the same absmax scheme
    mirrored here, so this is *their* exact error; the XLA reference path
    keeps the query in f32 (error 0 <= this). The bound charges it
    unconditionally so one envelope covers both backends. Broadcasts over
    leading batch dims; reduces the trailing feature dim. Costs O(d) per
    query — loop-invariant, hoisted by XLA out of the search loop.
    """
    qf = q.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(qf), axis=-1), 1e-12) / 127.0
    q_hat = jnp.clip(jnp.round(qf / scale[..., None]), -127, 127) * scale[..., None]
    return jnp.sqrt(jnp.sum((qf - q_hat) ** 2, axis=-1))


def lower_bound_dists(meta: jnp.ndarray, d_hat: jnp.ndarray,
                      err_q: jnp.ndarray, q_norm: jnp.ndarray,
                      metric: str) -> jnp.ndarray:
    """Certified lower bound of the true distance from the approximate one.

    ``meta`` is the gathered (..., 3) metadata rows of the candidates,
    ``d_hat`` their (...,) approximate distances, ``err_q``/``q_norm`` the
    (broadcastable) query-side error and query L2 norm. The result is what
    the quantized search paths hand to every ``dist <= r`` test — see the
    module docstring for why that makes the plain radius a keep band."""
    if metric == "l2":
        g = (meta[..., 2] + err_q) * (1.0 + _SLACK)
        return jnp.maximum(jnp.sqrt(jnp.maximum(d_hat, 0.0)) - g, 0.0) ** 2
    eps = (meta[..., 2] * q_norm
           + jnp.sqrt(jnp.maximum(meta[..., 1], 0.0)) * err_q) * (1.0 + _SLACK)
    return d_hat - eps


def quantized_gather_lb(corpus: QuantizedCorpus, safe_ids: jnp.ndarray,
                        q: jnp.ndarray, metric: str) -> jnp.ndarray:
    """The XLA quantized hot path, shared by every reference backend:
    int8 row gather (the ~4x HBM saving) + in-register dequantization +
    certified lower bound. ``safe_ids`` is any (..., R) int32 pre-clamped
    to [0, N); ``q`` is (..., d), broadcastable against the ids' batch
    dims. The query stays exact f32 on this path, so ``err_q = 0`` (see
    the module docstring; the int8 MXU kernels subtract their own)."""
    codes = jnp.take(corpus.codes, safe_ids, axis=0)      # (..., R, d) int8
    meta = jnp.take(corpus.meta, safe_ids, axis=0)        # (..., R, 3)
    vecs = codes.astype(jnp.float32) * meta[..., 0:1]
    qf = q.astype(jnp.float32)
    if metric == "l2":
        diff = vecs - qf[..., None, :]
        d = jnp.sum(diff * diff, axis=-1)
    else:  # ip
        d = -jnp.sum(vecs * qf[..., None, :], axis=-1)
    return lower_bound_dists(
        d_hat=d, meta=meta, err_q=jnp.float32(0.0),
        q_norm=jnp.sqrt(jnp.sum(qf * qf, axis=-1))[..., None], metric=metric)


def upper_bound_dists(corpus: QuantizedCorpus, ids: jnp.ndarray,
                      d_lb: jnp.ndarray, q: jnp.ndarray,
                      metric: str) -> jnp.ndarray:
    """Certified upper bound recovered from a stored lower bound.

    ``ids`` (any int32 shape, pre-clamped to [0, N)) are one query's
    candidates and ``d_lb`` their ``lower_bound_dists`` values; ``q`` is
    that query. ``d_ub <= r`` proves membership (the sure-accept side of
    the band); the rest of the kept candidates are ambiguous and must be
    exact-reranked. Valid even where the l2 lower bound clamped to zero."""
    meta = jnp.take(corpus.meta, ids, axis=0)           # (..., 3)
    err_q = query_quant_err(q)
    if metric == "l2":
        g = (meta[..., 2] + err_q) * (1.0 + _SLACK)
        return (jnp.sqrt(jnp.maximum(d_lb, 0.0)) + 2.0 * g) ** 2
    q_norm = jnp.sqrt(jnp.sum(q.astype(jnp.float32) ** 2, axis=-1))
    eps = (meta[..., 2] * q_norm
           + jnp.sqrt(jnp.maximum(meta[..., 1], 0.0)) * err_q) * (1.0 + _SLACK)
    return d_lb + 2.0 * eps


# -- live-index row mutation helpers ----------------------------------------
#
# The live subsystem (repro.live) pre-allocates the corpus at a fixed
# capacity and fills rows behind a watermark; these helpers are the only
# places that write corpus rows after construction. All are functional
# (jnp ``.at[]`` updates) so every mutation batch yields a fresh snapshot.

def corpus_with_capacity(points: Corpus, capacity: int,
                         far: float = 1e30) -> Corpus:
    """Pre-allocate ``points`` up to ``capacity`` rows with unreachable
    sentinel rows (same convention as the sharded pad rows: no graph edge
    ever points at them, and their ``far`` coordinates rank last under l2
    even against a hypothetical scan)."""
    n = corpus_size(points)
    if capacity < n:
        raise ValueError(f"capacity {capacity} < corpus size {n}")
    if capacity == n:
        return points
    if isinstance(points, QuantizedCorpus):
        return pad_corpus_rows(points, capacity - n, far)
    d = points.shape[-1]
    return jnp.concatenate(
        [points, jnp.full((capacity - n, d), far, dtype=points.dtype)])


def corpus_set_rows(points: Corpus, slots: jnp.ndarray, vecs: jnp.ndarray,
                    active: jnp.ndarray) -> Corpus:
    """Write ``vecs`` (B, d) f32 into rows ``slots`` (B,) where ``active``.

    Inactive lanes are dropped (out-of-bounds scatter), so a fixed-width
    insert batch can be partially filled without recompiling. A quantized
    corpus quantizes the rows on the way in — int8 corpora stay int8, and
    each new row carries its own exact ``err`` metadata (same scheme as
    build-time quantization), so the certified guard band keeps holding
    under streaming inserts."""
    n = corpus_size(points)
    wp = jnp.where(active, slots, n)  # n == OOB -> dropped
    if isinstance(points, QuantizedCorpus):
        codes, meta = quantize_rows(vecs)
        raw = points.raw
        if raw is not None:
            raw = raw.at[wp].set(vecs.astype(raw.dtype), mode="drop")
        return QuantizedCorpus(
            codes=points.codes.at[wp].set(codes, mode="drop"),
            meta=points.meta.at[wp].set(meta, mode="drop"),
            raw=raw,
        )
    return points.at[wp].set(vecs.astype(points.dtype), mode="drop")


def corpus_take_rows(points: Corpus, idx: jnp.ndarray) -> Corpus:
    """Gather corpus rows (consolidation's live-set compaction)."""
    if isinstance(points, QuantizedCorpus):
        return QuantizedCorpus(
            codes=jnp.take(points.codes, idx, axis=0),
            meta=jnp.take(points.meta, idx, axis=0),
            raw=None if points.raw is None else jnp.take(points.raw, idx,
                                                         axis=0),
        )
    return jnp.take(points, idx, axis=0)


def corpus_raw(points: Corpus) -> jnp.ndarray:
    """The exact-vector view used by graph construction/mutation (build
    searches + RobustPrune always run on exact vectors). Quantized corpora
    must carry ``raw`` to be mutable. A tiered corpus materializes its host
    store on device — a mutation/consolidation cost, never a query cost."""
    if getattr(points, "is_tiered", False):
        return points.raw_array()
    if isinstance(points, QuantizedCorpus):
        if points.raw is None:
            raise ValueError(
                "a QuantizedCorpus without raw vectors cannot back graph "
                "mutation (build/insert need exact vectors); quantize with "
                "keep_raw=True")
        return points.raw
    return points


def pad_corpus_rows(corpus: QuantizedCorpus, n_pad: int,
                    far: float) -> QuantizedCorpus:
    """Append ``n_pad`` sentinel rows (sharding's short-last-shard padding).

    Pad rows get zero codes with zero scale and zero error (a ``far`` raw
    value would register a huge per-row error and place the row inside
    every rerank band) and a ``far`` stored sqnorm, which keeps the
    matmul-form distance defense; on the diff-form path pad rows rely on
    build_sharded's unreachability guarantee alone (no graph edge ever
    reaches them)."""
    if n_pad <= 0:
        return corpus
    n, d = corpus.codes.shape
    pad_meta = jnp.broadcast_to(jnp.asarray([0.0, far, 0.0], jnp.float32),
                                (n_pad, 3))
    return QuantizedCorpus(
        codes=jnp.concatenate(
            [corpus.codes, jnp.zeros((n_pad, d), jnp.int8)]),
        meta=jnp.concatenate([corpus.meta, pad_meta]),
        raw=None if corpus.raw is None else jnp.concatenate(
            [corpus.raw,
             jnp.full((n_pad, d), far, corpus.raw.dtype)]),
    )
