"""Graph construction: Vamana (DiskANN) batch build, pure JAX.

The paper's experiments use DiskANN's in-memory build (ParlayANN). We
implement the same algorithm as fixed-shape batched dataflow:

* prefix-doubling insertion batches (points inserted in random order; each
  batch searches the current graph, RobustPrunes its visited set, then pushes
  reverse edges which are themselves pruned when rows overflow);
* RobustPrune (α-domination) vectorized as a ``fori_loop`` of masked argmin
  selections;
* reverse-edge packing by sort-by-destination + position-in-run arithmetic
  (the fixed-shape replacement for per-node dynamic append).

Batches are padded to a fixed maximum so the whole build reuses two jitted
programs regardless of dataset size. Also provides a brute-force k-NN graph
builder (small benchmarks, and the GNN `range_graph` data source).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..utils import INVALID_ID
from .beam_search import SearchConfig, beam_search_batch
from .distances import gather_dist, point_dist
from .graph import Graph, medoid
from .ground_truth import exact_topk


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    max_degree: int = 32     # R
    beam: int = 64           # L_build
    alpha: float = 1.2
    insert_batch: int = 1024 # padded batch width (fixed shape)
    rev_cap: int = 8         # reverse-edge candidates accepted per node per batch
    two_pass: bool = False   # DiskANN's alpha=1.0 first pass
    metric: str = "l2"

    @property
    def search_cfg(self) -> SearchConfig:
        return SearchConfig(beam=self.beam, max_beam=self.beam,
                            visit_cap=max(2 * self.beam, 128), metric=self.metric)


# ---------------------------------------------------------------------------
# RobustPrune
# ---------------------------------------------------------------------------

def robust_prune(
    points: jnp.ndarray,
    p_vec: jnp.ndarray,       # (d,) the node being pruned
    cand_ids: jnp.ndarray,    # (C,) candidate ids (may contain INVALID/dups)
    cand_dists: jnp.ndarray,  # (C,) exact distances to p
    alpha: float,
    R: int,
    metric: str = "l2",
    self_id: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Vamana RobustPrune: returns (R,) selected out-neighbor ids."""
    C = cand_ids.shape[0]
    # drop invalid / self / duplicate candidates
    valid = cand_ids != INVALID_ID
    if self_id is not None:
        valid &= cand_ids != self_id
    order = jnp.arange(C)
    dup = jnp.any((cand_ids[:, None] == cand_ids[None, :]) & (order[None, :] < order[:, None]) & valid[:, None], axis=1)
    valid &= ~dup
    dists = jnp.where(valid, cand_dists, jnp.inf)
    n = points.shape[0]
    safe = jnp.where(valid, cand_ids, 0)
    cvecs = jnp.take(points, safe, axis=0)  # (C, d)

    def body(i, carry):
        mask, out = carry  # mask: still-candidate; out: (R,) selected
        d_masked = jnp.where(mask, dists, jnp.inf)
        j = jnp.argmin(d_masked)
        ok = jnp.isfinite(d_masked[j])
        sel_id = jnp.where(ok, cand_ids[j], INVALID_ID)
        out = out.at[i].set(sel_id)
        # α-domination: drop v with α·d(sel, v) <= d(p, v). The α scaling
        # assumes non-negative distances (squared L2); IP distances are
        # negative, so α degrades to plain domination there (the ParlayANN
        # MIPS convention).
        d_sel = point_dist(cvecs, cvecs[j], metric)  # (C,)
        a = alpha if metric == "l2" else 1.0
        dominated = a * d_sel <= dists
        mask = mask & ~dominated & ok
        mask = mask.at[j].set(False)
        return mask, out

    out0 = jnp.full((R,), INVALID_ID, jnp.int32)
    _, out = jax.lax.fori_loop(0, R, body, (valid, out0))
    return out


# ---------------------------------------------------------------------------
# Reverse-edge packing
# ---------------------------------------------------------------------------

def _pack_reverse(dst_flat: jnp.ndarray, src_flat: jnp.ndarray, rev_cap: int):
    """Group (dst, src) edge pairs by dst.

    Returns (unique_dst (U,), rev_srcs (U, rev_cap)) where U == len(dst_flat)
    (INVALID-padded). At most ``rev_cap`` sources are kept per dst per call.
    """
    order = jnp.argsort(dst_flat, stable=True)
    dst = dst_flat[order]
    src = src_flat[order]
    m = dst.shape[0]
    idx = jnp.arange(m)
    is_start = jnp.concatenate([jnp.array([True]), dst[1:] != dst[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    pos_in_run = idx - run_start
    # one row per run start
    uniq_dst = jnp.where(is_start & (dst != INVALID_ID), dst, INVALID_ID)
    # rev_srcs[u, k] = src at run_start(u) + k if within the run
    take = run_start[:, None] + jnp.arange(rev_cap)[None, :]
    take = jnp.minimum(take, m - 1)
    cand = src[take]
    same_run = dst[take] == dst[:, None]
    in_cap = pos_in_run[take] < rev_cap  # always true by construction
    ok = same_run & in_cap & is_start[:, None] & (dst[:, None] != INVALID_ID)
    return uniq_dst, jnp.where(ok, cand, INVALID_ID)


# ---------------------------------------------------------------------------
# Batch insert
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "alpha"))
def insert_batch_step(
    points: jnp.ndarray,
    nbr_rows: jnp.ndarray,      # (N, R) current adjacency
    batch_ids: jnp.ndarray,     # (B,) padded with INVALID
    start_ids: jnp.ndarray,     # (S,) search entry points
    cfg: BuildConfig,
    alpha: float,
) -> jnp.ndarray:
    """One fixed-shape Vamana insert batch: search + RobustPrune + reverse
    edges with overflow pruning. ``points`` must already hold the batch rows
    (exact f32 vectors). Shared by the offline build below and the live
    streaming-insert path (``repro.live``), which calls it against a
    pre-allocated capacity so every incremental step reuses one compiled
    program."""
    graph = Graph(neighbors=nbr_rows)
    R = cfg.max_degree
    n = points.shape[0]
    active = batch_ids != INVALID_ID
    safe_ids = jnp.where(active, batch_ids, 0)
    qs = jnp.take(points, safe_ids, axis=0)  # (B, d)

    # 1. search the current graph from the entry points (medoid at build)
    st = beam_search_batch(points, graph, qs, start_ids, jnp.asarray(jnp.inf, jnp.float32), cfg.search_cfg)

    # 2. RobustPrune over visited ∪ beam candidates
    cand_ids = jnp.concatenate([st.visited_ids, st.ids], axis=1)
    cand_dists = jnp.concatenate([st.visited_dists, st.dists], axis=1)
    prune = jax.vmap(partial(robust_prune, points, alpha=alpha, R=R, metric=cfg.metric))
    new_rows = prune(qs, cand_ids=cand_ids, cand_dists=cand_dists, self_id=safe_ids)
    new_rows = jnp.where(active[:, None], new_rows, INVALID_ID)
    nbr_rows = nbr_rows.at[safe_ids].set(jnp.where(active[:, None], new_rows, nbr_rows[safe_ids]))

    # 3. reverse edges: candidate (dst=new neighbor, src=inserted point)
    B = batch_ids.shape[0]
    dst_flat = new_rows.reshape(-1)
    src_flat = jnp.broadcast_to(batch_ids[:, None], (B, R)).reshape(-1)
    src_flat = jnp.where(dst_flat != INVALID_ID, src_flat, INVALID_ID)
    uniq_dst, rev_srcs = _pack_reverse(dst_flat, src_flat, cfg.rev_cap)

    # 4. merge + prune overflowing rows (chunked to bound memory)
    def fix_row(dst, revs):
        ok = dst != INVALID_ID
        dstv = jnp.where(ok, dst, 0)
        cur = nbr_rows[dstv]  # (R,)
        merged = jnp.concatenate([cur, revs])  # (R + rev_cap,)
        # dedup + drop self
        order = jnp.arange(merged.shape[0])
        m_valid = (merged != INVALID_ID) & (merged != dstv)
        dup = jnp.any((merged[:, None] == merged[None, :]) & (order[None, :] < order[:, None]) & m_valid[:, None], axis=1)
        m_valid &= ~dup
        merged = jnp.where(m_valid, merged, INVALID_ID)
        n_valid = jnp.sum(m_valid)
        pvec = points[dstv]
        dists = gather_dist(points, merged, pvec, cfg.metric)
        pruned = robust_prune(points, pvec, merged, dists, alpha, R, cfg.metric, self_id=dstv)
        # no overflow -> keep merged as-is (sorted: valid first)
        merged_sorted = jnp.sort(jnp.where(m_valid, merged, INVALID_ID))[:R]
        row = jnp.where(n_valid > R, pruned, merged_sorted)
        return jnp.where(ok, row, jnp.full((R,), INVALID_ID, jnp.int32)), dstv, ok

    rows, dstv, ok = jax.lax.map(lambda t: fix_row(*t), (uniq_dst, rev_srcs), batch_size=1024)
    nbr_rows = nbr_rows.at[dstv].set(jnp.where(ok[:, None], rows, nbr_rows[dstv]))
    return nbr_rows


def build_vamana(
    points: jnp.ndarray,
    cfg: BuildConfig = BuildConfig(),
    seed: int = 0,
    verbose: bool = False,
) -> Graph:
    """Prefix-doubling Vamana batch build (ParlayANN-style)."""
    n = points.shape[0]
    rng = np.random.default_rng(seed)
    order = rng.permutation(n).astype(np.int32)
    start = medoid(points)
    nbr_rows = jnp.full((n, cfg.max_degree), INVALID_ID, jnp.int32)
    # seed: connect the medoid to a few random points so the first searches move
    seed_ids = jnp.asarray(order[: cfg.max_degree], jnp.int32)
    nbr_rows = nbr_rows.at[start].set(jnp.where(seed_ids == start, INVALID_ID, seed_ids))

    passes = [1.0, cfg.alpha] if cfg.two_pass else [cfg.alpha]
    B = cfg.insert_batch
    for alpha in passes:
        done = 0
        bsize = max(1, min(64, B))
        while done < n:
            take = min(bsize, n - done, B)
            batch = np.full((B,), INVALID_ID, dtype=np.int32)
            batch[:take] = order[done : done + take]
            nbr_rows = insert_batch_step(points, nbr_rows, jnp.asarray(batch),
                                         start[None], cfg, alpha)
            done += take
            bsize = min(bsize * 2, B)
            if verbose:
                print(f"  [build α={alpha}] inserted {done}/{n}")
    return Graph(neighbors=nbr_rows)


def build_knn_graph(
    points: jnp.ndarray,
    k: int = 16,
    metric: str = "l2",
    mutual: bool = False,
) -> Graph:
    """Brute-force k-NN graph (small corpora, GNN data source)."""
    ids, _ = exact_topk(points, points, k=k + 1, metric=metric)
    # drop self column
    row = jnp.arange(points.shape[0], dtype=jnp.int32)[:, None]
    keep = ids != row
    # compact each row: move self (if present) to the end, then take k
    sort_key = jnp.where(keep, jnp.arange(k + 1)[None, :], k + 1)
    order = jnp.argsort(sort_key, axis=1)
    ids = jnp.take_along_axis(ids, order, axis=1)[:, :k]
    return Graph(neighbors=ids.astype(jnp.int32))
