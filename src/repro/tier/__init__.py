"""Tiered corpus: device-resident int8 codes + host-RAM raw-row store.

The subsystem that makes corpus size a host-RAM problem instead of an
HBM problem (ROADMAP item 1, leg 1). See `tier.corpus` for the parity
contract and `tier.store` for the DiskANN-style row layout.
"""
from .budget import MemoryBudget
from .cache import DeviceRowCache
from .corpus import TierCounters, TieredCorpus, tiered_corpus
from .planner import FetchPlan, plan_fetch
from .store import ROW_ALIGN, HostRowStore, TierFetchError

__all__ = [
    "MemoryBudget",
    "DeviceRowCache",
    "TierCounters",
    "TieredCorpus",
    "tiered_corpus",
    "FetchPlan",
    "plan_fetch",
    "ROW_ALIGN",
    "HostRowStore",
    "TierFetchError",
]
