"""Bounded device-side cache of hot raw rows.

The guard band is strongly query-correlated: consecutive batches over the
same corpus re-touch the same boundary points, so a small device-resident
cache of recently fetched raw rows absorbs most of the host traffic. The
cache is a fixed (capacity, d) f32 device buffer plus a host-side LRU map
slot→line; eviction recycles the least-recently-used line (a ring, once
full). Hit/miss/evict counts live in `TierCounters` on the owning
`TieredCorpus`.

The device buffer is the *only* device-resident raw-row storage of a
tiered corpus, so its capacity is exactly the knob `--resident-mb` turns.
Capacity 0 disables caching (every ambiguous row is streamed).
"""
from __future__ import annotations

from collections import OrderedDict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..utils import next_pow2


@partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(buf, lines, rows):
    # OOB line == capacity → mode="drop" makes padding a no-op
    return buf.at[lines].set(rows, mode="drop")


class DeviceRowCache:
    """LRU cache of raw f32 rows in a fixed device buffer."""

    def __init__(self, dim: int, capacity_rows: int):
        self.dim = int(dim)
        self.capacity = max(0, int(capacity_rows))
        self._buf = jnp.zeros((max(self.capacity, 1), self.dim), jnp.float32)
        self._lru: "OrderedDict[int, int]" = OrderedDict()  # slot -> line
        self._free = list(range(self.capacity - 1, -1, -1))

    @property
    def nbytes(self) -> int:
        return 0 if self.capacity == 0 else int(self._buf.nbytes)

    def __len__(self) -> int:
        return len(self._lru)

    def lookup(self, slots: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(hit_mask, lines) for unique ``slots``; hits become most-recent."""
        slots = np.asarray(slots, np.int64)
        hit = np.zeros(slots.shape, bool)
        lines = np.zeros(slots.shape, np.int32)
        if self.capacity:
            for i, s in enumerate(slots.tolist()):
                line = self._lru.get(s)
                if line is not None:
                    hit[i] = True
                    lines[i] = line
                    self._lru.move_to_end(s)
        return hit, lines

    def insert(self, slots: np.ndarray, rows) -> int:
        """Install freshly fetched rows; returns the number of evictions.

        ``rows`` is a device (m, d) array (the bucket just copied up), so
        installation is a device-side scatter, not another host copy."""
        slots = np.asarray(slots, np.int64)
        if self.capacity == 0 or slots.size == 0:
            return 0
        n_evicted = 0
        lines = np.empty(slots.shape, np.int32)
        for i, s in enumerate(slots.tolist()):
            if s in self._lru:  # racing duplicate insert: refresh in place
                lines[i] = self._lru[s]
                self._lru.move_to_end(s)
            elif self._free:
                lines[i] = self._free.pop()
                self._lru[s] = int(lines[i])
            else:
                _, line = self._lru.popitem(last=False)  # LRU out
                n_evicted += 1
                lines[i] = line
                self._lru[s] = int(line)
        m = next_pow2(slots.size)
        lines_p = np.full(m, self.capacity, np.int32)  # OOB pad → drop
        lines_p[: slots.size] = lines
        rows_p = jnp.zeros((m, self.dim), jnp.float32)
        rows_p = rows_p.at[: slots.size].set(rows)
        self._buf = _scatter_rows(self._buf, jnp.asarray(lines_p), rows_p)
        return n_evicted

    def invalidate(self, slots: np.ndarray) -> int:
        """Drop ``slots`` from the cache (rows rewritten in the host store
        — a stale line would break the bitwise-parity contract). Returns
        how many lines were actually dropped."""
        dropped = 0
        for s in np.asarray(slots, np.int64).tolist():
            line = self._lru.pop(s, None)
            if line is not None:
                self._free.append(int(line))
                dropped += 1
        return dropped

    def rows(self, lines: np.ndarray):
        """Device gather of cached rows by line."""
        return jnp.take(self._buf, jnp.asarray(lines, jnp.int32), axis=0)
