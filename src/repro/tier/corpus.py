"""`TieredCorpus`: device-resident codes, host-resident rerank rows.

The hot arm (`device`) is what the search loop sees: an int8
`QuantizedCorpus` whose ``raw`` field is None (codes + 12-byte meta only),
or — the degenerate f32/bf16 tier — the cast point array itself. The cold
arm is a `HostRowStore` of exact f32 rows, consumed exclusively by the
guard-band rerank through :meth:`TieredCorpus.exact_pairs`.

Bitwise-parity contract: ``exact_pairs`` returns the *same f32 bits* as
the resident ``_exact_pairs`` for every real (lane, slot) pair. It
assembles the deduplicated rows into a pow2-padded (U_pad, d) device
buffer and computes ``point_dist(take(rows, inverse), take(queries,
lanes))`` — identical per-pair shapes, identical f32 reduction order, so
cache state, fetch bucketing, and eviction history can never change a
result bit.

A `TieredCorpus` is deliberately NOT a pytree: it hashes by identity, so
it can ride in static fields (e.g. `ShardedCorpus.tiers`), and it must
never be passed into jit — public entry points unwrap ``tier.device``.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.corpus import (
    META_BYTES,
    QuantizedCorpus,
    corpus_cast,
    quantize_corpus,
)
from ..core.distances import point_dist
from ..utils import next_pow2
from .budget import MemoryBudget
from .cache import DeviceRowCache
from .planner import plan_fetch
from .store import HostRowStore

# CI memory-cap hook: forces a tiny resident cache (streaming + eviction
# paths) on every default-constructed tier without touching call sites.
_CACHE_ROWS_ENV = "REPRO_TIER_CACHE_ROWS"


@dataclasses.dataclass
class TierCounters:
    """Cumulative fetch-path telemetry for one tier (shared across
    ``with_device`` views, so sharded/live wrappers aggregate for free)."""

    pairs: int = 0            # (lane, slot) rerank pairs planned
    unique_rows: int = 0      # after dedup
    fetched_rows: int = 0     # host→device rows actually copied
    fetched_bytes: int = 0
    fetch_batches: int = 0    # pow2 buckets issued
    cache_hits: int = 0
    cache_misses: int = 0
    cache_evictions: int = 0

    @property
    def dedup_ratio(self) -> float:
        return self.pairs / max(1, self.unique_rows)

    @property
    def hit_rate(self) -> float:
        probes = self.cache_hits + self.cache_misses
        return self.cache_hits / max(1, probes)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["dedup_ratio"] = round(self.dedup_ratio, 4)
        d["cache_hit_rate"] = round(self.hit_rate, 4)
        return d


@partial(jax.jit, donate_argnums=(0,))
def _scatter_rows_at(dst, pos, rows):
    # OOB pos (== dst height) → mode="drop" makes padding a no-op
    return dst.at[pos].set(rows, mode="drop")


@partial(jax.jit, static_argnames=("metric",))
def _pair_dists(rows_u, inv_p, queries, lanes_p, metric: str):
    """Bit-for-bit the resident `_exact_pairs`, with the gather retargeted
    from the full (N, d) raw array to the assembled (U_pad, d) buffer."""
    vecs = jnp.take(rows_u, inv_p, axis=0).astype(jnp.float32)
    qv = jnp.take(queries, lanes_p, axis=0).astype(jnp.float32)
    return point_dist(vecs, qv, metric)


class TieredCorpus:
    """Two-tier corpus: device hot arm + host-RAM raw-row store."""

    is_tiered = True  # duck-typing marker (core never imports this module)

    def __init__(self, device: Any, store: HostRowStore,
                 cache: DeviceRowCache, counters: Optional[TierCounters] = None,
                 fetch_bucket: int = 1024):
        self.device = device
        self.store = store
        self.cache = cache
        self.counters = counters if counters is not None else TierCounters()
        self.fetch_bucket = int(fetch_bucket)

    # -- structure -----------------------------------------------------------
    def with_device(self, device: Any) -> "TieredCorpus":
        """A view with a different hot arm, SHARING store/cache/counters
        (sharded slicing, live snapshot updates)."""
        return TieredCorpus(device, self.store, self.cache, self.counters,
                            self.fetch_bucket)

    @property
    def n(self) -> int:
        return len(self.store)

    @property
    def dim(self) -> int:
        return self.store.dim

    @property
    def quantized(self) -> bool:
        return isinstance(self.device, QuantizedCorpus)

    def raw_array(self) -> jnp.ndarray:
        """Materialize the full host store on device (consolidation /
        checkpointing at test scale — never on the query path)."""
        if not self.quantized:
            return jnp.asarray(self.store.to_array())
        return jax.device_put(self.store.to_array())

    # -- accounting ----------------------------------------------------------
    def budget(self) -> MemoryBudget:
        device: dict = {}
        if self.device is None:
            # detached shard view: the hot arm lives in the ShardedCorpus
            # stack — only this tier's cache + store are attributable
            pass
        elif self.quantized:
            device["codes"] = int(self.device.codes.nbytes)
            device["meta"] = int(self.device.meta.nbytes)
        else:
            device["points"] = int(self.device.nbytes)
        device["row_cache"] = int(self.cache.nbytes)
        return MemoryBudget(device=device,
                            host={"row_store": int(self.store.nbytes)})

    # -- the rerank fetch path ----------------------------------------------
    def exact_pairs(self, queries, ids_p, lanes_p, metric: str,
                    n_real: Optional[int] = None) -> jnp.ndarray:
        """Exact f32 distances for flat pow2-padded (corpus id, lane) pairs.

        Only the first ``n_real`` pairs are planned/fetched (the tail is
        jit padding whose distances are discarded by the caller's keep
        mask); pad inverse entries point at unique 0 so shapes match."""
        ids_np = np.asarray(jax.device_get(ids_p)).astype(np.int64)
        n_pairs = ids_np.size if n_real is None else int(n_real)
        if not self.quantized:
            # degenerate f32/bf16 tier: the hot arm IS the raw data
            return _pair_dists(jnp.asarray(self.device), jnp.asarray(ids_p),
                               queries, jnp.asarray(lanes_p), metric)

        plan = plan_fetch(ids_np[:n_pairs], self.cache, self.fetch_bucket)
        c = self.counters
        if plan is None:  # all-padding call — nothing real to fetch
            u_pad = 1
            rows_u = jnp.zeros((u_pad, self.dim), jnp.float32)
            inv = np.zeros(ids_np.size, np.int32)
            return _pair_dists(rows_u, jnp.asarray(inv), queries,
                               jnp.asarray(lanes_p), metric)
        c.pairs += plan.n_pairs
        c.unique_rows += plan.n_unique
        c.cache_hits += int(plan.hit_mask.sum())
        c.cache_misses += plan.n_miss

        u_pad = next_pow2(plan.n_unique)
        rows_u = jnp.zeros((u_pad, self.dim), jnp.float32)

        def scatter(pos: np.ndarray, rows) -> None:
            nonlocal rows_u
            m = next_pow2(pos.size)
            pos_p = np.full(m, u_pad, np.int32)  # OOB → drop
            pos_p[: pos.size] = pos
            rows_p = jnp.zeros((m, self.dim), jnp.float32)
            rows_p = rows_p.at[: pos.size].set(rows)
            rows_u = _scatter_rows_at(rows_u, jnp.asarray(pos_p), rows_p)

        hit_pos = np.nonzero(plan.hit_mask)[0].astype(np.int32)
        if hit_pos.size:
            scatter(hit_pos, self.cache.rows(plan.hit_lines[plan.hit_mask]))

        # Double-buffered streaming of the miss buckets: the host→device
        # copy for bucket i+1 is issued (async dispatch) while bucket i's
        # device-side scatter runs. On CPU CI this is `jax.device_put`
        # overlap; the TPU path swaps in kernels/rerank_fetch's manual-DMA
        # pipeline against the same plan.
        miss_pos = np.nonzero(~plan.hit_mask)[0].astype(np.int32)
        chunks = plan.miss_chunks
        nxt = jax.device_put(self.store.gather(chunks[0])) if chunks else None
        done = 0
        for i, chunk in enumerate(chunks):
            cur = nxt
            if i + 1 < len(chunks):
                nxt = jax.device_put(self.store.gather(chunks[i + 1]))
            scatter(miss_pos[done:done + chunk.size], cur)
            done += chunk.size
            c.fetch_batches += 1
            c.fetched_rows += int(chunk.size)
            c.fetched_bytes += int(chunk.size) * self.dim * 4
            c.cache_evictions += self.cache.insert(chunk, cur)

        inv = np.zeros(ids_np.size, np.int32)
        inv[:n_pairs] = plan.inverse
        return _pair_dists(rows_u, jnp.asarray(inv), queries,
                           jnp.asarray(lanes_p), metric)


def tiered_corpus(points, *, corpus_dtype: str = "int8",
                  cache_rows: Optional[int] = None,
                  resident_mb: Optional[float] = None,
                  fetch_bucket: int = 1024) -> TieredCorpus:
    """Split ``points`` into a `TieredCorpus`.

    ``points`` is an (N, d) array or an already-quantized `QuantizedCorpus`
    (its raw rows move to the host store). For float dtypes the tier is
    degenerate — the hot arm is the cast array, the store exists only so
    insert/consolidate/checkpoint plumbing is uniform, and queries never
    fetch. ``resident_mb`` caps the device row cache in MB (wins over
    ``cache_rows``); with neither given, the default is n/8 rows, and the
    ``REPRO_TIER_CACHE_ROWS`` env var (CI memory-cap job) overrides it.
    """
    if isinstance(points, QuantizedCorpus):
        if points.raw is None:
            raise ValueError("tiered_corpus needs raw rows to populate the "
                             "host store (got QuantizedCorpus with raw=None)")
        raw = np.asarray(jax.device_get(points.raw), np.float32)
        device = dataclasses.replace(points, raw=None)
    elif corpus_dtype in ("int8", "quantized"):
        qc = quantize_corpus(jnp.asarray(points), keep_raw=True)
        raw = np.asarray(jax.device_get(qc.raw), np.float32)
        device = dataclasses.replace(qc, raw=None)
    else:
        arr = jnp.asarray(points)
        raw = np.asarray(jax.device_get(arr), np.float32)
        device = corpus_cast(arr, corpus_dtype)

    n, d = raw.shape
    store = HostRowStore(raw)
    if resident_mb is not None:
        cap = int(resident_mb * (1 << 20)) // max(1, d * 4)
    elif cache_rows is not None:
        cap = int(cache_rows)
    else:
        cap = int(os.environ.get(_CACHE_ROWS_ENV, max(1, n // 8)))
    cache = DeviceRowCache(d, cap)
    return TieredCorpus(device, store, cache, fetch_bucket=fetch_bucket)
