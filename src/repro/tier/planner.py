"""Batched fetch planning for the guard-band rerank.

`range_search_compacted`'s rerank band arrives as flat (lane, slot) pairs
with heavy duplication — the same boundary point is ambiguous for many
lanes at once. The planner turns that into the cheapest host traffic
possible: deduplicate to unique slots, sort ascending (sequential-ish
host reads over the row-aligned store), split cache hits from misses, and
chunk the misses into pow2-sized buckets that the double-buffered
prefetch path overlaps with compute.

Pure host-side numpy — unit-testable without a device.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from ..utils import next_pow2


@dataclasses.dataclass
class FetchPlan:
    """The host-gather schedule for one rerank band."""

    uniques: np.ndarray       # (U,) sorted unique slots
    inverse: np.ndarray       # (P,) pair -> index into uniques
    hit_mask: np.ndarray      # (U,) True where the row is cached
    hit_lines: np.ndarray     # (U,) cache line for hits (junk elsewhere)
    miss_chunks: List[np.ndarray]  # miss slots, pow2-bucketed, each sorted

    @property
    def n_pairs(self) -> int:
        return int(self.inverse.size)

    @property
    def n_unique(self) -> int:
        return int(self.uniques.size)

    @property
    def n_miss(self) -> int:
        return sum(int(c.size) for c in self.miss_chunks)


def plan_fetch(slots: np.ndarray, cache=None,
               bucket_rows: int = 1024) -> Optional[FetchPlan]:
    """Plan the host gathers for flat rerank ``slots`` (duplicates allowed).

    ``cache`` is an optional `DeviceRowCache`; its hits are served from the
    device buffer and never touch the host. Misses are chunked into
    buckets of at most ``bucket_rows`` rows; every bucket is padded up to
    a pow2 size by the fetch path, so bucket boundaries land on pow2
    totals and the jit cache stays O(log) in band size.
    """
    slots = np.asarray(slots).ravel()
    if slots.size == 0:
        return None
    uniques, inverse = np.unique(slots, return_inverse=True)
    if cache is not None and getattr(cache, "capacity", 0) > 0:
        hit_mask, hit_lines = cache.lookup(uniques)
    else:
        hit_mask = np.zeros(uniques.shape, bool)
        hit_lines = np.zeros(uniques.shape, np.int32)
    misses = uniques[~hit_mask]
    bucket = max(1, next_pow2(min(bucket_rows, max(1, misses.size))))
    miss_chunks = [misses[i:i + bucket] for i in range(0, misses.size, bucket)]
    return FetchPlan(uniques=uniques, inverse=inverse.astype(np.int32),
                     hit_mask=hit_mask, hit_lines=hit_lines,
                     miss_chunks=miss_chunks)
