"""Host-RAM raw-row store: the cold tier of a ``TieredCorpus``.

The PR 4 two-pass pipeline made the guard-band rerank the *sole* consumer
of exact f32 vectors — every other stage of the search loop runs on int8
codes + 12-byte metadata. That is exactly the DiskANN memory split: the
hot structures (codes, metadata, graph) stay device-resident, the raw rows
move to host RAM and are fetched on demand for the certified-ambiguous
band only. This module is the host side of that split.

Layout is DiskANN-style row-aligned: each row occupies a fixed stride
rounded up to ``ROW_ALIGN`` bytes in one C-contiguous buffer, so a row
fetch is a single aligned copy and a future TPU DMA path can compute the
source address as ``base + slot * stride`` without an indirection table.
"Pinned" here means the buffer is kept allocated and page-touched for the
store's lifetime; true device-registered pinning is a no-op on the CPU CI
backend (the TPU runtime would register this same buffer).

A failed fetch raises :class:`TierFetchError` — the fault fan-out
(``fault.degraded``) treats it like a lost shard (annotated coverage
degradation), never a crash. ``fail_next`` is the chaos-test hook that
scripts such failures deterministically.
"""
from __future__ import annotations

import numpy as np

ROW_ALIGN = 64  # bytes — row stride granularity (sector/DMA friendly)


class TierFetchError(RuntimeError):
    """A host-store row fetch failed (bad slot, torn mapping, or a scripted
    chaos-test fault). Handled like ``ShardFault``: the shard degrades with
    annotated coverage instead of crashing the batch."""


class HostRowStore:
    """Row-aligned host-RAM store of exact f32 rerank rows.

    ``rows`` may be any (N, d) float array; by default it is copied into an
    owned, stride-aligned buffer. ``copy=False`` wraps the array as-is
    (e.g. a memory-mapped checkpoint leaf restored copy-on-write) — writes
    then go through the caller-provided backing.
    """

    def __init__(self, rows: np.ndarray, *, copy: bool = True,
                 align: int = ROW_ALIGN):
        rows = np.asarray(rows, dtype=np.float32)
        if rows.ndim != 2:
            raise ValueError(f"store rows must be (N, d), got {rows.shape}")
        n, d = rows.shape
        self.n = int(n)
        self.dim = int(d)
        if copy:
            floats_per_row = max(1, -(-d * 4 // align) * align // 4)
            buf = np.zeros((n, floats_per_row), np.float32)
            buf[:, :d] = rows
            self._buf = buf
            self._rows = buf[:, :d]
        else:
            self._buf = rows
            self._rows = rows
        # chaos hook: the next N gathers raise TierFetchError
        self.fail_next = 0

    # -- introspection -------------------------------------------------------
    @property
    def nbytes(self) -> int:
        """Host bytes kept resident (including alignment padding)."""
        return int(self._buf.nbytes)

    def __len__(self) -> int:
        return self.n

    # -- access --------------------------------------------------------------
    def gather(self, slots: np.ndarray) -> np.ndarray:
        """Fetch rows by slot: one (m, d) f32 host gather.

        The returned array carries the exact bits of the stored rows — the
        bitwise-parity contract of the tiered rerank depends on it."""
        if self.fail_next > 0:
            self.fail_next -= 1
            raise TierFetchError(
                f"scripted host-store fetch failure ({np.size(slots)} rows)")
        slots = np.asarray(slots, np.int64)
        if slots.size and (slots.min() < 0 or slots.max() >= self.n):
            raise TierFetchError(
                f"host-store fetch out of range: slots in "
                f"[{slots.min()}, {slots.max()}] vs {self.n} rows")
        return self._rows[slots]

    def write(self, slots: np.ndarray, vecs: np.ndarray) -> None:
        """Write rows in place (live inserts: fresh slots only — slots past
        every published snapshot's watermark, so older snapshots never
        observe the mutation)."""
        self._rows[np.asarray(slots, np.int64)] = np.asarray(vecs, np.float32)

    def take(self, idx: np.ndarray) -> "HostRowStore":
        """A NEW store holding rows ``idx`` in order (consolidation's
        live-set compaction; the old store stays valid for old snapshots)."""
        return HostRowStore(self._rows[np.asarray(idx, np.int64)])

    def to_array(self) -> np.ndarray:
        """The (N, d) row view (no copy) — the checkpoint payload."""
        return self._rows
