"""Memory-budget accounting for the tiered corpus.

A `MemoryBudget` answers the question the tier exists to change: how many
bytes are resident on *device* (HBM on TPU) versus parked in *host* RAM,
broken down by component. Engine stats and `launch/serve.py --tier`
surface it so the f32-resident → int8-resident → tiered progression is a
number, not a narrative.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class MemoryBudget:
    """Bytes resident per component, split by residence."""

    device: Dict[str, int]
    host: Dict[str, int]

    @property
    def device_total(self) -> int:
        return int(sum(self.device.values()))

    @property
    def host_total(self) -> int:
        return int(sum(self.host.values()))

    def device_bytes_per_vector(self, n: int) -> float:
        return self.device_total / max(1, n)

    def as_dict(self) -> dict:
        return {
            "device": dict(self.device),
            "host": dict(self.host),
            "device_total": self.device_total,
            "host_total": self.host_total,
        }
