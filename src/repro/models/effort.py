"""Effort regressor: ``(query, radius) -> predicted match count``.

The serving layer's admission controller needs to know, *before* running a
range query, roughly how much work it will be: a point lookup touching a
handful of neighbors batches well at high width, while a dense-region query
returning hundreds of matches saturates the beam and wants the
doubling/phase-2 path. The paper's observation that range-query cost tracks
the output size (|S_r(q)|) makes the match count the natural effort proxy.

This is deliberately the smallest model that works: the recsys
``dense_stack`` tower over ``[q, log1p(r), ||q||]`` features, z-normalized
with statistics frozen at fit time, regressing ``log1p(count)`` under MSE.
It trains full-batch in a few hundred AdamW steps on the calibration sample
the server already has (queries it answered, counts it observed) and runs
as one fused matmul chain per admission batch.

Effort prediction is advisory only: it decides which execution path a
request takes, never what the answer is — both paths return exact
guard-banded results, so a mispredicted bucket costs latency, not recall.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..layers.mlp import dense_stack, init_dense_stack
from ..optim.adamw import AdamWConfig, init_adamw, make_train_step


@dataclasses.dataclass(frozen=True)
class EffortConfig:
    """Shape + training hyperparameters for the effort MLP."""
    dim: int                      # query dimensionality d (features are d+2)
    hidden: tuple = (32, 16)      # dense_stack hidden widths
    lr: float = 1e-2
    steps: int = 300
    weight_decay: float = 0.0

    @property
    def n_features(self) -> int:
        return self.dim + 2

    @property
    def n_layers(self) -> int:
        return len(self.hidden) + 1


def effort_features(queries, radii) -> jnp.ndarray:
    """(Q, d) queries + (Q,)/scalar radii -> (Q, d+2) raw feature rows:
    ``[q, log1p(r), ||q||]``. The radius enters through log1p because match
    counts grow polynomially in r; the query norm is the cheapest scalar
    summary of where q sits relative to the (often shell-like) corpus."""
    q = jnp.asarray(queries, jnp.float32)
    r = jnp.broadcast_to(jnp.asarray(radii, jnp.float32), (q.shape[0],))
    nrm = jnp.linalg.norm(q, axis=-1, keepdims=True)
    return jnp.concatenate([q, jnp.log1p(r)[:, None], nrm], axis=-1)


def init_effort(key, cfg: EffortConfig) -> dict:
    return init_dense_stack(key, (cfg.n_features,) + cfg.hidden + (1,))


def effort_forward(params: dict, feats: jnp.ndarray, cfg: EffortConfig,
                   mu: jnp.ndarray, sigma: jnp.ndarray) -> jnp.ndarray:
    """Normalized features -> predicted ``log1p(count)`` (Q,)."""
    x = (feats - mu) / sigma
    return dense_stack(params, x, cfg.n_layers)[:, 0]


def effort_loss(params, batch: dict, cfg: EffortConfig, mu, sigma):
    """MSE on log1p counts; ``(loss, metrics)`` shape for make_train_step."""
    pred = effort_forward(params, batch["feats"], cfg, mu, sigma)
    y = batch["log_count"]
    err = pred - y
    loss = jnp.mean(jnp.square(err))
    return loss, {"mae_log": jnp.mean(jnp.abs(err))}


class EffortPredictor:
    """A fitted effort model: feature stats + MLP params + a jitted forward.

    Build one with :meth:`fit` from (queries, radii, observed counts) — e.g.
    the warmup traffic a server has already answered — then call
    :meth:`predict` inside the admission path.
    """

    def __init__(self, cfg: EffortConfig, params: dict,
                 mu: jnp.ndarray, sigma: jnp.ndarray):
        self.cfg = cfg
        self.params = params
        self.mu = mu
        self.sigma = sigma
        self._fwd = jax.jit(
            lambda p, f: effort_forward(p, f, cfg, mu, sigma))

    @staticmethod
    def fit(queries, radii, counts, cfg: EffortConfig | None = None,
            seed: int = 0) -> "EffortPredictor":
        """Full-batch AdamW fit of log1p(count) on the calibration sample."""
        q = jnp.asarray(queries, jnp.float32)
        if cfg is None:
            cfg = EffortConfig(dim=int(q.shape[1]))
        feats = effort_features(q, radii)
        mu = jnp.mean(feats, axis=0)
        sigma = jnp.maximum(jnp.std(feats, axis=0), 1e-6)
        y = jnp.log1p(jnp.asarray(counts, jnp.float32).reshape(-1))
        batch = {"feats": feats, "log_count": y}

        params = init_effort(jax.random.PRNGKey(seed), cfg)
        opt_cfg = AdamWConfig(lr=cfg.lr, weight_decay=cfg.weight_decay,
                              schedule="cosine", warmup_steps=10,
                              total_steps=cfg.steps)
        opt_state = init_adamw(params, opt_cfg)
        step = jax.jit(make_train_step(
            lambda p, b: effort_loss(p, b, cfg, mu, sigma), opt_cfg))
        for _ in range(cfg.steps):
            params, opt_state, _ = step(params, opt_state, batch)
        return EffortPredictor(cfg, params, mu, sigma)

    def predict_log1p(self, queries, radii) -> jnp.ndarray:
        """(Q,) predicted log1p(match count)."""
        return self._fwd(self.params, effort_features(queries, radii))

    def predict(self, queries, radii) -> np.ndarray:
        """(Q,) predicted match counts (>= 0, host array)."""
        logc = np.asarray(self.predict_log1p(queries, radii))
        return np.maximum(np.expm1(logc), 0.0)
