from .effort import (
    EffortConfig, EffortPredictor, effort_features, effort_forward,
    effort_loss, init_effort,
)
from .gcn import GCNConfig, gcn_batched_graphs, gcn_forward, gcn_loss, init_gcn
from .recsys import (
    RecsysConfig, bce_loss, embed_items, init_recsys, recsys_forward,
    recsys_loss, retrieval_scores, retrieval_topk, two_tower_loss,
)
from .transformer import (
    TransformerConfig, cache_shapes, chunked_ce_loss, decode_step, forward,
    greedy_token, init_cache, init_transformer, logits_from_hidden, loss_fn,
    prefill,
)

__all__ = [k for k in dir() if not k.startswith("_")]
