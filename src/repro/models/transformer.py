"""Decoder-only transformer covering all five assigned LM architectures.

One config class spans dense GQA (gemma3/qwen3/starcoder2), MLA + MoE
(deepseek-v2) and GQA + MoE (qwen2-moe):

* **scan-over-layers** with stacked params; per-layer heterogeneity
  (gemma3's 5 local : 1 global sliding-window pattern, its dual rope
  thetas) rides along as *scanned scalar arrays*, so the loop body stays
  uniform and compiles once.
* leading dense layers (deepseek-v2's first layer) are unstacked and run
  before the scan.
* ``remat='full'`` checkpoints each scanned layer (the production default
  for the 27B/236B configs).
* KV caches are stacked (L, B, T, ...) pytrees scanned in lockstep with
  the layers; MLA caches the 512-dim latent + 64-dim rope key only.
* the LM loss is a **vocab-chunked** cross-entropy: logits are produced
  seq-chunk by seq-chunk inside a scan so the (B, S, V) tensor is never
  materialized (with V = 262k this is the difference between fitting and
  OOM at compile).

Activation sharding: residual stream is constrained to
``(dp, tp, None)`` — batch over data, *sequence over model* (Megatron-style
sequence parallelism); attention/FFN internals are head-/ffn-sharded over
``tp``. See dist/sharding.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import DP, TP, shard_activation
from ..layers.attention import (
    GQAConfig, KVCache, MLAConfig, gqa_attention, init_gqa, init_mla,
    mla_attention,
)
from ..layers.common import split_keys
from ..layers.embedding import embed_tokens, init_token_embedding, unembed
from ..layers.mlp import MLPConfig, init_mlp, mlp
from ..layers.moe import MoEConfig, init_moe, moe_layer
from ..layers.norm import rms_norm


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv: int = 2
    d_head: int = 64
    d_ff: int = 1024
    ffn_gated: bool = True
    ffn_act: str = "silu"
    vocab: int = 1000
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0    # gemma3 local layers use 10k vs 1M global
    qk_norm: bool = False
    attn_chunk: int = 0              # KV streaming chunk (flash-in-XLA)
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    window: int = 0                  # sliding window for local layers
    local_ratio: int = 0             # N local layers per global (gemma3: 5)
    attn_kind: str = "gqa"           # gqa | mla
    # MLA
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # MoE
    n_experts: int = 0
    n_experts_alloc: int = 0         # pad experts to the EP axis (qwen: 64)
    moe_groups: int = 1              # dispatch token groups (see layers/moe.py)
    n_shared: int = 0
    top_k: int = 0
    d_expert: int = 0
    first_dense: int = 0             # leading dense layers (deepseek-v2: 1)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.001
    # execution
    dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_unroll: bool = False  # unroll scan-over-layers: dry-run analysis
                               # lowering (XLA cost_analysis counts a while
                               # body once; unrolled HLO counts true FLOPs)
    embed_scale: bool = False        # gemma multiplies embeds by sqrt(D)
    sandwich_norm: bool = False      # gemma3 post-attn/post-ffn norms
    tie_embeddings: bool = True
    loss_chunk: int = 512

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_scanned(self) -> int:
        return self.n_layers - self.first_dense

    def attn_cfg(self):
        if self.attn_kind == "mla":
            return MLAConfig(d_model=self.d_model, n_heads=self.n_heads,
                             q_lora=self.q_lora, kv_lora=self.kv_lora,
                             qk_nope_dim=self.qk_nope_dim,
                             qk_rope_dim=self.qk_rope_dim,
                             v_head_dim=self.v_head_dim,
                             softcap=self.attn_softcap,
                             kv_chunk=self.attn_chunk)
        return GQAConfig(d_model=self.d_model, n_heads=self.n_heads,
                         n_kv=self.n_kv, d_head=self.d_head,
                         qk_norm=self.qk_norm, softcap=self.attn_softcap,
                         kv_chunk=self.attn_chunk)

    def moe_cfg(self) -> MoEConfig:
        return MoEConfig(d_model=self.d_model, n_experts=self.n_experts,
                         top_k=self.top_k, d_expert=self.d_expert,
                         n_shared=self.n_shared,
                         capacity_factor=self.capacity_factor,
                         n_experts_alloc=self.n_experts_alloc,
                         n_groups=self.moe_groups)

    def mlp_cfg(self) -> MLPConfig:
        return MLPConfig(d_model=self.d_model, d_ff=self.d_ff,
                         act=self.ffn_act, gated=self.ffn_gated)

    def layer_meta(self) -> tuple[np.ndarray, np.ndarray]:
        """(windows, thetas) per layer. Layer i is local iff the 5:1-style
        pattern says so (pattern position ``local_ratio`` is the global)."""
        L = self.n_layers
        windows = np.zeros((L,), np.int32)
        thetas = np.full((L,), self.rope_theta, np.float32)
        if self.window > 0 and self.local_ratio > 0:
            period = self.local_ratio + 1
            local = (np.arange(L) % period) != (period - 1)
            windows = np.where(local, self.window, 0).astype(np.int32)
            if self.rope_theta_local > 0:
                thetas = np.where(local, self.rope_theta_local,
                                  self.rope_theta).astype(np.float32)
        elif self.window > 0:
            windows[:] = self.window
        return windows, thetas


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: TransformerConfig, dense: bool) -> dict:
    ks = split_keys(key, 4)
    init_attn = init_mla if cfg.attn_kind == "mla" else init_gqa
    p = {
        "attn": init_attn(next(ks), cfg.attn_cfg()),
        "attn_norm": jnp.zeros((cfg.d_model,), jnp.float32) if cfg.sandwich_norm
        else jnp.ones((cfg.d_model,), jnp.float32),
        "ffn_norm": jnp.zeros((cfg.d_model,), jnp.float32) if cfg.sandwich_norm
        else jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.sandwich_norm:
        p["post_attn_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["post_ffn_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if cfg.is_moe and not dense:
        p["moe"] = init_moe(next(ks), cfg.moe_cfg())
    else:
        p["mlp"] = init_mlp(next(ks), cfg.mlp_cfg())
    return p


def init_transformer(key, cfg: TransformerConfig) -> dict:
    ks = split_keys(key, 4 + cfg.first_dense)
    params: dict = {"embed": init_token_embedding(next(ks), cfg.vocab, cfg.d_model)}
    params["final_norm"] = (jnp.zeros if cfg.sandwich_norm else jnp.ones)(
        (cfg.d_model,), jnp.float32)
    for i in range(cfg.first_dense):
        params[f"dense_layer{i}"] = _init_layer(next(ks), cfg, dense=True)
    layer_keys = jax.random.split(next(ks), cfg.n_scanned)
    params["layers"] = jax.vmap(lambda k: _init_layer(k, cfg, dense=False))(layer_keys)
    if not cfg.tie_embeddings:
        params["unembed"] = init_token_embedding(next(ks), cfg.vocab, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=None) -> KVCache:
    """Stacked (L, B, T, ...) cache covering scanned + leading dense layers."""
    dt = dtype or cfg.dtype
    L = cfg.n_layers
    if cfg.attn_kind == "mla":
        k = jnp.zeros((L, batch, max_len, cfg.kv_lora), dt)
        v = jnp.zeros((L, batch, max_len, cfg.qk_rope_dim), dt)
    else:
        k = jnp.zeros((L, batch, max_len, cfg.n_kv, cfg.d_head), dt)
        v = jnp.zeros_like(k)
    return KVCache(k=k, v=v)


def cache_shapes(cfg: TransformerConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or cfg.dtype
    L = cfg.n_layers
    if cfg.attn_kind == "mla":
        return (jax.ShapeDtypeStruct((L, batch, max_len, cfg.kv_lora), dt),
                jax.ShapeDtypeStruct((L, batch, max_len, cfg.qk_rope_dim), dt))
    shp = (L, batch, max_len, cfg.n_kv, cfg.d_head)
    return jax.ShapeDtypeStruct(shp, dt), jax.ShapeDtypeStruct(shp, dt)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _layer_fwd(lp: dict, x, cfg: TransformerConfig, *, positions, theta, window,
               cache: Optional[KVCache], cache_pos, kv_valid, dense: bool):
    attn_fn = mla_attention if cfg.attn_kind == "mla" else gqa_attention
    h = rms_norm(x, lp["attn_norm"], unit_offset=cfg.sandwich_norm)
    attn_out, new_cache = attn_fn(
        lp["attn"], h, cfg.attn_cfg(), positions=positions, rope_theta=theta,
        window=window, cache=cache, cache_pos=cache_pos, kv_valid_len=kv_valid)
    if cfg.sandwich_norm:
        attn_out = rms_norm(attn_out, lp["post_attn_norm"], unit_offset=True)
    x = x + attn_out
    x = shard_activation(x, DP, TP, None)
    h = rms_norm(x, lp["ffn_norm"], unit_offset=cfg.sandwich_norm)
    if cfg.is_moe and not dense:
        ffn_out, aux = moe_layer(lp["moe"], h, cfg.moe_cfg())
        aux_loss = aux["aux_loss"]
    else:
        ffn_out = mlp(lp["mlp"], h, cfg.mlp_cfg())
        aux_loss = jnp.zeros((), jnp.float32)
    if cfg.sandwich_norm:
        ffn_out = rms_norm(ffn_out, lp["post_ffn_norm"], unit_offset=True)
    x = x + ffn_out
    x = shard_activation(x, DP, TP, None)
    return x, new_cache, aux_loss


def forward(
    params: dict,
    tokens: jnp.ndarray,           # (B, S) int32
    cfg: TransformerConfig,
    *,
    cache: Optional[KVCache] = None,  # stacked (L, ...) or None
    cache_pos=None,                   # () int32 write offset (decode/prefill)
    kv_valid=None,                    # () or (B,) valid kv length
) -> tuple[jnp.ndarray, Optional[KVCache], jnp.ndarray]:
    """Returns (hidden (B,S,D) after final norm, new stacked cache, aux_loss)."""
    b, s = tokens.shape
    dt = cfg.dtype
    x = embed_tokens(params["embed"], tokens, dt, scale=cfg.embed_scale)
    x = shard_activation(x, DP, TP, None)
    base = jnp.zeros((), jnp.int32) if cache_pos is None else jnp.asarray(cache_pos, jnp.int32)
    positions = base[None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (b, s))

    windows_np, thetas_np = cfg.layer_meta()
    windows = jnp.asarray(windows_np)
    thetas = jnp.asarray(thetas_np)
    aux_total = jnp.zeros((), jnp.float32)

    # leading dense layers (unstacked)
    for i in range(cfg.first_dense):
        layer_cache = None
        if cache is not None:
            layer_cache = KVCache(k=cache.k[i], v=cache.v[i])
        x, nc, aux = _layer_fwd(
            params[f"dense_layer{i}"], x, cfg, positions=positions,
            theta=thetas[i], window=windows[i], cache=layer_cache,
            cache_pos=base, kv_valid=kv_valid, dense=True)
        if cache is not None:
            cache = KVCache(k=cache.k.at[i].set(nc.k), v=cache.v.at[i].set(nc.v))
        aux_total += aux

    # scanned layers
    def body(carry, xs):
        xc, aux_acc = carry
        lp, theta, window, ck, cv = xs
        layer_cache = KVCache(k=ck, v=cv) if cache is not None else None
        xo, nc, aux = _layer_fwd(lp, xc, cfg, positions=positions, theta=theta,
                                 window=window, cache=layer_cache,
                                 cache_pos=base, kv_valid=kv_valid, dense=False)
        out = (nc.k, nc.v) if nc is not None else (jnp.zeros((), dt),) * 2
        return (xo, aux_acc + aux), out

    body_fn = jax.checkpoint(body) if cfg.remat else body
    fd = cfg.first_dense
    if cache is not None:
        xs = (params["layers"], thetas[fd:], windows[fd:], cache.k[fd:], cache.v[fd:])
    else:
        zk = jnp.zeros((cfg.n_scanned,), dt)
        xs = (params["layers"], thetas[fd:], windows[fd:], zk, zk)
    (x, aux_total2), cache_out = jax.lax.scan(
        body_fn, (x, aux_total), xs,
        unroll=cfg.n_scanned if cfg.scan_unroll else 1)

    new_cache = None
    if cache is not None:
        nk, nv = cache_out
        new_cache = KVCache(
            k=jnp.concatenate([cache.k[:fd], nk], axis=0) if fd else nk,
            v=jnp.concatenate([cache.v[:fd], nv], axis=0) if fd else nv)

    x = rms_norm(x, params["final_norm"], unit_offset=cfg.sandwich_norm)
    return x, new_cache, aux_total2


def logits_from_hidden(params, x, cfg: TransformerConfig) -> jnp.ndarray:
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed(table, x, cfg.logit_softcap)


# ---------------------------------------------------------------------------
# Loss (seq-chunked CE so (B, S, V) never materializes)
# ---------------------------------------------------------------------------

def chunked_ce_loss(params, hidden, labels, mask, cfg: TransformerConfig):
    b, s, d = hidden.shape
    chunk = min(cfg.loss_chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // chunk
    hs = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)   # (nc, B, c, D)
    ls = labels.reshape(b, nc, chunk).swapaxes(0, 1)
    ms = mask.reshape(b, nc, chunk).swapaxes(0, 1)

    def body(acc, xs):
        h, l, m = xs
        logits = logits_from_hidden(params, h, cfg)        # (B, c, V) fp32
        logits = shard_activation(logits, DP, None, TP)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * m
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(m)), None

    (tot, n), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ls, ms),
                               unroll=nc if cfg.scan_unroll else 1)
    return tot / jnp.maximum(n, 1.0)


def loss_fn(params, batch: dict, cfg: TransformerConfig):
    """batch: tokens (B, S), labels (B, S) (-1 = masked), -> (loss, metrics)."""
    tokens, labels = batch["tokens"], batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe_labels = jnp.maximum(labels, 0)
    hidden, _, aux = forward(params, tokens, cfg)
    ce = chunked_ce_loss(params, hidden, safe_labels, mask, cfg)
    loss = ce + cfg.aux_loss_weight * aux
    return loss, {"ce": ce, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def prefill(params, tokens, cfg: TransformerConfig, max_len: int):
    """Process a prompt, returning (last-token logits, cache, kv_len)."""
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len)
    hidden, cache, _ = forward(params, tokens, cfg, cache=cache,
                               cache_pos=jnp.zeros((), jnp.int32),
                               kv_valid=jnp.asarray(s, jnp.int32))
    logits = logits_from_hidden(params, hidden[:, -1:], cfg)
    return logits, cache, jnp.asarray(s, jnp.int32)


def decode_step(params, token, cache: KVCache, pos, cfg: TransformerConfig):
    """One decode step: token (B, 1), pos () int32 -> (logits, new cache)."""
    hidden, cache, _ = forward(params, token, cfg, cache=cache, cache_pos=pos,
                               kv_valid=pos + 1)
    logits = logits_from_hidden(params, hidden, cfg)
    return logits, cache


def greedy_token(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
