"""RecSys models: two-tower retrieval, Wide&Deep, DLRM-RM2, AutoInt.

Shared substrate: a single (F, V, d) embedding-table array per model (one
row-block per sparse field — uniform V keeps the array dense and row-
shardable over the whole mesh), the take+segment_sum EmbeddingBag, and the
interaction ops in layers/interactions.py.

The two-tower model is the paper's home turf (DESIGN.md §6): its item tower
produces the embedding corpus the range engine indexes, and
``retrieval_cand`` is served either by brute force (the rangescan kernel) or
through the graph-based range engine.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..layers.common import embed_init, split_keys
from ..layers.interactions import (
    FieldAttnConfig, dot_interaction, field_attention, init_field_attention,
)
from ..layers.mlp import dense_stack, init_dense_stack


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str = "dlrm"
    kind: str = "dlrm"          # two_tower | wide_deep | dlrm | autoint
    n_dense: int = 0
    n_sparse: int = 26
    vocab: int = 100_000        # rows per field table
    d_embed: int = 64
    mlp_dims: tuple = (512, 256)          # deep/top tower hidden dims
    bot_mlp_dims: tuple = ()              # dlrm bottom mlp (dense features)
    # two-tower
    n_sparse_item: int = 0                # item-side fields (two_tower)
    d_out: int = 256                      # tower output dim
    # autoint
    attn_layers: int = 3
    attn_heads: int = 2
    d_attn: int = 32
    dtype: Any = jnp.float32

    def field_attn_cfg(self) -> FieldAttnConfig:
        return FieldAttnConfig(n_fields=self.n_sparse, d_embed=self.d_embed,
                               n_layers=self.attn_layers, n_heads=self.attn_heads,
                               d_attn=self.d_attn)


def _tables(key, f: int, v: int, d: int) -> jnp.ndarray:
    return embed_init(key, (f, v, d))


def init_recsys(key, cfg: RecsysConfig) -> dict:
    ks = split_keys(key, 8)
    p: dict = {}
    if cfg.kind == "two_tower":
        fu, fi = cfg.n_sparse, cfg.n_sparse_item or cfg.n_sparse
        p["user"] = {
            "tables": _tables(next(ks), fu, cfg.vocab, cfg.d_embed),
            "mlp": init_dense_stack(next(ks), (fu * cfg.d_embed,) + cfg.mlp_dims + (cfg.d_out,)),
        }
        p["item"] = {
            "tables": _tables(next(ks), fi, cfg.vocab, cfg.d_embed),
            "mlp": init_dense_stack(next(ks), (fi * cfg.d_embed,) + cfg.mlp_dims + (cfg.d_out,)),
        }
        return p
    p["tables"] = _tables(next(ks), cfg.n_sparse, cfg.vocab, cfg.d_embed)
    if cfg.kind == "wide_deep":
        p["wide"] = _tables(next(ks), cfg.n_sparse, cfg.vocab, 1)  # per-id weight
        p["deep"] = init_dense_stack(next(ks), (cfg.n_sparse * cfg.d_embed,) + cfg.mlp_dims + (1,))
    elif cfg.kind == "dlrm":
        n_inter = (cfg.n_sparse + 1) * cfg.n_sparse // 2  # pairs incl. dense vec
        p["bot"] = init_dense_stack(next(ks), (cfg.n_dense,) + cfg.bot_mlp_dims)
        top_in = n_inter + cfg.bot_mlp_dims[-1]
        p["top"] = init_dense_stack(next(ks), (top_in,) + cfg.mlp_dims + (1,))
    elif cfg.kind == "autoint":
        p["attn"] = init_field_attention(next(ks), cfg.field_attn_cfg())
        p["out"] = init_dense_stack(next(ks), (cfg.n_sparse * cfg.d_attn, 1))
    else:
        raise ValueError(cfg.kind)
    return p


def _lookup(tables: jnp.ndarray, sparse: jnp.ndarray, dtype) -> jnp.ndarray:
    """(F, V, d) x (B, F) -> (B, F, d). One id per field (multi-hot bags go
    through layers.embedding.embedding_bag; single-hot is the hot path)."""
    f = tables.shape[0]
    out = jax.vmap(lambda tab, idx: jnp.take(tab, idx, axis=0),
                   in_axes=(0, 1), out_axes=1)(tables, sparse)
    return out.astype(dtype)


def tower(params: dict, sparse: jnp.ndarray, cfg: RecsysConfig,
          n_mlp: int) -> jnp.ndarray:
    e = _lookup(params["tables"], sparse, cfg.dtype)
    x = e.reshape(e.shape[0], -1)
    x = dense_stack(params["mlp"], x, n_mlp)
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def recsys_forward(params: dict, batch: dict, cfg: RecsysConfig) -> jnp.ndarray:
    """CTR models -> logit (B,). two_tower -> (user_emb, item_emb)."""
    dt = cfg.dtype
    if cfg.kind == "two_tower":
        n_mlp = len(cfg.mlp_dims) + 1
        u = tower(params["user"], batch["user_sparse"], cfg, n_mlp)
        i = tower(params["item"], batch["item_sparse"], cfg, n_mlp)
        return u, i
    e = _lookup(params["tables"], batch["sparse"], dt)   # (B, F, d)
    if cfg.kind == "wide_deep":
        wide = jnp.sum(_lookup(params["wide"], batch["sparse"], dt)[..., 0], axis=1)
        deep = dense_stack(params["deep"], e.reshape(e.shape[0], -1),
                           len(cfg.mlp_dims) + 1)[:, 0]
        return wide + deep
    if cfg.kind == "dlrm":
        z = dense_stack(params["bot"], batch["dense"].astype(dt),
                        len(cfg.bot_mlp_dims), final_act=True)  # (B, d)
        feats = jnp.concatenate([z[:, None, :], e], axis=1)     # (B, F+1, d)
        inter = dot_interaction(feats)
        top_in = jnp.concatenate([z, inter], axis=-1)
        return dense_stack(params["top"], top_in, len(cfg.mlp_dims) + 1)[:, 0]
    if cfg.kind == "autoint":
        h = field_attention(params["attn"], e, cfg.field_attn_cfg())
        return dense_stack(params["out"], h, 1)[:, 0]
    raise ValueError(cfg.kind)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def bce_loss(params, batch: dict, cfg: RecsysConfig):
    logit = recsys_forward(params, batch, cfg)
    y = batch["label"].astype(jnp.float32)
    z = logit.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))
    return loss, {"mean_logit": jnp.mean(z)}


def two_tower_loss(params, batch: dict, cfg: RecsysConfig):
    """In-batch sampled softmax with logQ correction (Yi et al., RecSys'19)."""
    u, i = recsys_forward(params, batch, cfg)
    logits = (u @ i.T).astype(jnp.float32) / 0.05          # temperature
    logq = batch.get("log_q")                               # (B,) sampling prob
    if logq is not None:
        logits = logits - logq[None, :]
    labels = jnp.arange(u.shape[0])
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - ll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"in_batch_acc": acc}


def recsys_loss(params, batch, cfg: RecsysConfig):
    if cfg.kind == "two_tower":
        return two_tower_loss(params, batch, cfg)
    return bce_loss(params, batch, cfg)


# ---------------------------------------------------------------------------
# Retrieval scoring (retrieval_cand shape)
# ---------------------------------------------------------------------------

def embed_items(params: dict, item_sparse: jnp.ndarray, cfg: RecsysConfig):
    return tower(params["item"], item_sparse, cfg, len(cfg.mlp_dims) + 1)


def retrieval_scores(query_emb: jnp.ndarray, cand_emb: jnp.ndarray) -> jnp.ndarray:
    """(Q, d) x (N, d) -> (Q, N) inner-product scores (batched MXU matmul;
    the rangescan kernel serves the same shape with fused top-k on TPU)."""
    return query_emb @ cand_emb.T


def retrieval_topk(query_emb, cand_emb, k: int = 100):
    s = retrieval_scores(query_emb, cand_emb)
    vals, idx = jax.lax.top_k(s, k)
    return idx, vals
