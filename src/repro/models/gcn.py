"""GCN (Kipf & Welling) over edge lists — full-batch and sampled-minibatch.

Message passing is ``jax.ops.segment_sum`` over an edge index (JAX sparse is
BCOO-only; gather-scatter IS the system here, per the brief). Symmetric
normalization weights are computed once per graph. The ``minibatch_lg``
shape pairs this with the fanout neighbor sampler in data/graphs.py: the
model sees a padded sampled subgraph (layered edge blocks), identical code.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..layers.common import dense_init, split_keys
from ..layers.segment import gather_scatter, sym_norm_weights


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn"
    n_layers: int = 2
    d_feat: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    agg: str = "mean"       # paper config: aggregator=mean (sym-normalized)
    sym_norm: bool = True
    dropout: float = 0.0    # kept 0 for determinism
    dtype: object = jnp.float32


def init_gcn(key, cfg: GCNConfig) -> dict:
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    ks = split_keys(key, cfg.n_layers)
    return {
        f"w{i}": dense_init(next(ks), (dims[i], dims[i + 1]), dims[i])
        for i in range(cfg.n_layers)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), jnp.float32)
        for i in range(cfg.n_layers)
    }


def gcn_forward(params: dict, feats: jnp.ndarray, edge_src: jnp.ndarray,
                edge_dst: jnp.ndarray, cfg: GCNConfig) -> jnp.ndarray:
    """feats (N, d_feat), edges (E,) with -1 padding -> logits (N, n_classes)."""
    n = feats.shape[0]
    x = feats.astype(cfg.dtype)
    w = sym_norm_weights(edge_src, edge_dst, n) if cfg.sym_norm else None
    agg = "sum" if cfg.sym_norm else cfg.agg
    for i in range(cfg.n_layers):
        x = x @ params[f"w{i}"].astype(cfg.dtype) + params[f"b{i}"].astype(cfg.dtype)
        neigh = gather_scatter(x, edge_src, edge_dst, n, agg=agg, edge_weight=w)
        deg_self = 1.0  # self-loop contribution with sym norm folds into +x/deg
        x = neigh + x * deg_self
        if i < cfg.n_layers - 1:
            x = jax.nn.relu(x)
    return x.astype(jnp.float32)


def gcn_loss(params, batch: dict, cfg: GCNConfig):
    """batch: feats (N,d), edge_src/dst (E,), labels (N,), label_mask (N,)."""
    logits = gcn_forward(params, batch["feats"], batch["edge_src"],
                         batch["edge_dst"], cfg)
    labels = jnp.maximum(batch["labels"], 0)
    mask = (batch["labels"] >= 0).astype(jnp.float32) * batch.get(
        "label_mask", jnp.ones_like(labels, jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = (lse - ll) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"acc": acc}


def gcn_batched_graphs(params: dict, feats: jnp.ndarray, edge_src, edge_dst,
                       cfg: GCNConfig) -> jnp.ndarray:
    """molecule shape: feats (G, N, d), edges (G, E) -> graph logits (G, C)
    via mean-pool readout. vmapped single-graph forward."""
    node_logits = jax.vmap(lambda f, s, d: gcn_forward(params, f, s, d, cfg))(
        feats, edge_src, edge_dst)
    return jnp.mean(node_logits, axis=1)
