"""jax version compatibility shims.

The repo pins jax>=0.4.37. ``shard_map`` moved to the top-level ``jax``
namespace (and ``check_rep`` was renamed ``check_vma``) in later releases;
this wrapper presents the new-style keyword API on either version so call
sites and tests are written once against the current API.
"""
from __future__ import annotations

import inspect

try:  # newer jax: top-level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4/0.5: experimental
    from jax.experimental.shard_map import shard_map as _shard_map

# the top-level move and the check_rep->check_vma rename landed in DIFFERENT
# jax releases, so detect the kwarg from the signature, not the import path
_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """New-style ``jax.shard_map`` keyword API on any supported jax."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})
