"""Multi-shard range retrieval: the production layout of the paper's engine.

A corpus bigger than one device's HBM splits into contiguous shards, each
with its *own* sub-index (graph + entry points) — the standard multi-shard
decomposition of graph-ANN systems. Range search then fans out as one
``shard_map`` program:

* shards lay along the **model** axis (one or more sub-indices per device),
  query batches along the **data** axis;
* each device runs the fused single-program search
  (``core.range_search_fused``) of its query block against its local
  shard(s) and remaps shard-local ids to global ids via the shard offset;
* an all-gather along the model axis followed by a distance-sort
  **union-merge** produces the global ``RangeResult``: ids/dists are the
  ``result_cap`` closest in-range points across all shards, counts sum, and
  overflow flags OR (plus a union-level overflow when the merged count
  exceeds the cap).

Because the shards partition the corpus, per-shard result sets are disjoint
and the union needs no dedup — only the merge sort.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..core.beam_search import broadcast_radius
from ..core.corpus import corpus_cast, pad_corpus_rows
from ..core.graph import Graph
from ..core.labels import LabelFilter
from ..core.range_search import RangeConfig, RangeResult, range_search_fused
from ..utils import INVALID_ID, cdiv
from .compat import shard_map
from .sharding import _axis_size


def _points_leaf(points):
    """Representative array leaf of a corpus (works for stacked
    QuantizedCorpus pytrees and plain arrays alike)."""
    return jax.tree.leaves(points)[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedCorpus:
    """Stacked per-shard sub-indices (leading axis = shard).

    ``points`` is either a stacked (S, n, d) array or a stacked
    ``QuantizedCorpus`` whose every leaf carries the shard axis in front
    (codes (S, n, d), meta (S, n, 3), raw (S, n, d)) — each shard
    quantizes *locally*, so its guard band is as tight as its own
    per-vector errors allow."""

    points: Any     # (S, n, d) — shard blocks (pad rows edge-free/unreachable)
    neighbors: Any  # (S, n, R) int32 — per-shard graph adjacency
    start_ids: Any  # (S, k) int32 — per-shard entry points (shard-local ids)
    offsets: Any    # (S,) int32 — global id of each shard's row 0
    # true corpus size: required so pad-row ids (>= n_total) are droppable
    n_total: int = dataclasses.field(metadata=dict(static=True))
    # (S, n, W) uint32 — per-shard packed label rows (core.labels), or None
    # for an unlabeled corpus. Pad rows of a short last shard carry all-zero
    # label rows: they are unreachable anyway, and a zero row matches no
    # non-trivial AND/OR predicate.
    labels: Any = None
    # Tuple of per-shard ``repro.tier.TieredCorpus`` views (device=None —
    # the stacked ``points`` above IS the device arm; each tier contributes
    # its host row store + cache), or None for a fully-resident corpus.
    # Static: a TieredCorpus is identity-hashed and never enters jit; only
    # the host fan-out path (fault.fault_tolerant_sharded_search) composes
    # ``tiers[s].with_device(points[s])`` per shard.
    tiers: Any = dataclasses.field(default=None, metadata=dict(static=True))

    @property
    def n_shards(self) -> int:
        return _points_leaf(self.points).shape[0]

    @property
    def shard_size(self) -> int:
        return _points_leaf(self.points).shape[1]


# Sentinel coordinates for rows padding a short last shard. The value never
# decides correctness: pad rows are appended AFTER the sub-index is built on
# the real rows, so no graph edge and no entry point reaches them under any
# metric — they are unreachable, not merely distant. (Kept large so even a
# hypothetical brute-force pass over shard rows ranks them last under l2.)
_FAR = 1e30


def build_sharded(
    points,
    n_shards: int,
    build_fn: Callable,   # (shard_points (n, d)) -> (Graph, start_ids (k,))
    lane_pad: int = 0,
    corpus_dtype: str = "float32",
    labels=None,
    tier: bool = False,
    resident_mb: float = None,
) -> ShardedCorpus:
    """Partition ``points`` into ``n_shards`` contiguous blocks and build one
    sub-index per block with ``build_fn``. A short last block is padded to
    the common shard size only *after* its graph is built, so the pad rows
    have no incoming edges (search can never visit them, under any metric)
    and the stacked arrays stay rectangular.

    ``lane_pad > 0`` pads every sub-index's degree axis to that multiple
    (``Graph.lane_padded``) so the stacked adjacency is ready for the fused
    Pallas expand kernel (``SearchConfig.use_expand_kernel``), whose VMEM
    blocks want R on a 128-lane boundary — done once here rather than per
    search dispatch.

    ``corpus_dtype`` controls per-shard storage: graphs always build on the
    exact f32 block; "int8" then quantizes each shard *locally* (per-shard
    scales and guard-band maxima, computed before any pad rows are appended
    so sentinel values cannot widen the band).

    ``labels`` (optional) is the corpus-wide (N, W) uint32 packed label
    matrix (``core.labels.pack_labels``); it splits into the same contiguous
    blocks as the points, zero-padded to the common shard size (zero rows
    match no non-trivial predicate and are unreachable regardless).

    ``tier=True`` builds each shard as a tiered corpus: the stacked
    ``points`` keep only the device arm (int8 codes + meta for "int8";
    the cast block for float dtypes), while each shard's raw f32 rerank
    rows move into its own host row store (``ShardedCorpus.tiers``).
    ``resident_mb`` caps each shard's device row cache. Tiered sharded
    corpora are served by the host fan-out path only."""
    pts = np.asarray(points)
    n_total, d = pts.shape
    n = cdiv(n_total, n_shards)
    if labels is not None:
        labels = np.asarray(labels, np.uint32)
        if labels.shape[0] != n_total:
            raise ValueError(
                f"labels rows ({labels.shape[0]}) != corpus size ({n_total})")
    blocks, nbrs, starts, labs, tiers = [], [], [], [], []
    for s in range(n_shards):
        block = pts[s * n:(s + 1) * n]
        graph, start_ids = build_fn(jnp.asarray(block))
        if lane_pad:
            graph = graph.lane_padded(lane_pad)
        neighbors = np.asarray(graph.neighbors)
        n_pad = n - block.shape[0]
        stored = corpus_cast(jnp.asarray(block), corpus_dtype)
        if n_pad:  # pad points AND adjacency (INVALID = no edge)
            if corpus_dtype == "int8":
                stored = pad_corpus_rows(stored, n_pad, _FAR)
            else:
                stored = jnp.concatenate(
                    [stored,
                     jnp.full((n_pad, d), _FAR, dtype=stored.dtype)], axis=0)
            neighbors = np.concatenate(
                [neighbors,
                 np.full((n_pad, neighbors.shape[1]), INVALID_ID, np.int32)],
                axis=0)
        if tier:
            # split the (padded) shard: raw rows -> this shard's host store,
            # device arm -> the stacked points. The tier keeps device=None —
            # the stacked arm is sliced back in per search (with_device).
            from ..tier import tiered_corpus
            t = tiered_corpus(stored, corpus_dtype=corpus_dtype,
                              resident_mb=resident_mb)
            tiers.append(t.with_device(None))
            stored = t.device
        blocks.append(stored)
        nbrs.append(jnp.asarray(neighbors))
        starts.append(jnp.asarray(start_ids, jnp.int32).reshape(-1))
        if labels is not None:
            lab = labels[s * n:(s + 1) * n]
            if n_pad:
                lab = np.concatenate(
                    [lab, np.zeros((n_pad, lab.shape[1]), np.uint32)], axis=0)
            labs.append(jnp.asarray(lab))
    return ShardedCorpus(
        points=jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        neighbors=jnp.stack(nbrs),
        start_ids=jnp.stack(starts),
        offsets=jnp.arange(n_shards, dtype=jnp.int32) * n,
        n_total=n_total,
        labels=None if labels is None else jnp.stack(labs),
        tiers=tuple(tiers) if tier else None,
    )


def _remap_global(ids, offset, n_total: int):
    """Shard-local ids -> global ids. INVALID padding stays INVALID, and so
    does anything past ``n_total`` — defense in depth against pad rows of a
    short last shard (unreachable by construction in build_sharded)."""
    gids = jnp.where(ids == INVALID_ID, INVALID_ID, ids + offset)
    return jnp.where(gids < n_total, gids, INVALID_ID)


def union_merge(ids, dists, cap: int):
    """(Q, M) candidate ids/dists (INVALID/inf padded, disjoint across
    sources) -> the ``cap`` closest per query, distance-sorted."""
    dists, ids = jax.lax.sort((dists, ids), num_keys=1, is_stable=True)
    return ids[:, :cap], dists[:, :cap]


def sharded_range_search(
    *,
    mesh: Mesh,
    corpus: ShardedCorpus,
    queries,
    r,
    cfg: RangeConfig,
    es_radius: Optional[float] = None,
    tombstones=None,
    label_filter: Optional[LabelFilter] = None,
    model_axis="model",
    data_axis="data",
) -> RangeResult:
    """Union range search over every shard of ``corpus``; returns a global
    ``RangeResult`` (ids are corpus-global, counts summed across shards).

    Keyword-only: the parameter order matches the ``core.range_search``
    entry points with the mesh prepended —
    ``(mesh, corpus, queries, r, cfg, es_radius, tombstones,
    label_filter)``.

    ``r``/``es_radius`` are a shared scalar or per-query ``(Q,)`` vectors;
    radii shard along the data axis with their queries and broadcast to
    every shard along the model axis (each shard answers every query at
    that query's own radius).

    ``tombstones`` (optional) is a stacked ``(S, W)`` uint32 dead-slot
    bitset, one exact bitset per shard in shard-local slot space (the live
    subsystem's per-shard tombstones). Each shard's fused search filters its
    own dead slots at the result stage — deleted points still route the
    per-shard walk but never reach the union merge, so counts and the
    merged top-``result_cap`` are live-only.

    ``label_filter`` (optional) is a per-query
    :class:`~repro.core.labels.LabelFilter` over the corpus's attached
    ``labels`` (build_sharded(..., labels=)). Its mask rows shard along the
    data axis with their queries and broadcast to every shard; each shard
    evaluates the predicate locally at the result stage of its fused search
    (filtered-out points route the per-shard walk but never reach the union
    merge), so the merged result equals the post-filtered union."""
    if corpus.n_total <= 0:
        raise ValueError("ShardedCorpus.n_total must be the true corpus size")
    if getattr(corpus, "tiers", None) is not None:
        raise ValueError(
            "a tiered ShardedCorpus cannot run the collective shard_map "
            "program (host row fetches inside a collective would deadlock "
            "the mesh); use fault.fault_tolerant_sharded_search")
    if label_filter is not None and corpus.labels is None:
        raise ValueError(
            "corpus has no labels attached; build_sharded(..., labels=) to "
            "use filtered range search")
    s_total = corpus.n_shards
    n_model = mesh.shape[model_axis]
    if s_total % n_model:
        raise ValueError(
            f"{s_total} shards do not lay out on model axis of size {n_model}")
    s_loc = s_total // n_model
    cap = cfg.result_cap

    queries = jnp.asarray(queries)
    n_q = queries.shape[0]
    # normalize radii to (Q,) vectors so one shard_map signature serves both
    # forms (es None -> +inf, which never triggers early stopping)
    radii = broadcast_radius(r, n_q)
    es_vec = broadcast_radius(es_radius, n_q)
    has_filter = label_filter is not None
    masks = is_and = None
    if has_filter:
        masks = jnp.asarray(label_filter.masks, jnp.uint32)
        is_and = jnp.asarray(label_filter.is_and, bool)
        if masks.shape[0] != n_q:
            raise ValueError(
                f"label_filter covers {masks.shape[0]} lanes for {n_q} queries")
    dp_size = _axis_size(mesh, data_axis)
    q_pad = cdiv(n_q, dp_size) * dp_size
    if q_pad != n_q:  # replicate-pad the batch to the data-axis multiple
        queries = jnp.concatenate(
            [queries, jnp.broadcast_to(queries[:1],
                                       (q_pad - n_q,) + queries.shape[1:])])
        radii = jnp.concatenate(
            [radii, jnp.broadcast_to(radii[:1], (q_pad - n_q,))])
        es_vec = jnp.concatenate(
            [es_vec, jnp.broadcast_to(es_vec[:1], (q_pad - n_q,))])
        if has_filter:  # pad lanes ride with their replicated query
            masks = jnp.concatenate(
                [masks, jnp.broadcast_to(masks[:1],
                                         (q_pad - n_q, masks.shape[1]))])
            is_and = jnp.concatenate(
                [is_and, jnp.broadcast_to(is_and[:1], (q_pad - n_q,))])

    def local_fn(points, neighbors, start_ids, offsets, qs, rs, es,
                 *extra):
        # optional trailing args, ordered (tombs?, labs, mq, aq?) by the
        # closure flags — shard_map positional args cannot be keywords
        it = iter(extra)
        tombs = next(it) if tombstones is not None else None
        labs, mq, aq = (next(it), next(it), next(it)) if has_filter \
            else (None, None, None)
        filt = None if not has_filter else LabelFilter(masks=mq, is_and=aq)
        # points (s_loc, n, d) (or a stacked QuantizedCorpus), qs (q_loc, d),
        # rs/es (q_loc,): search every local shard at each query's own
        # radius. A quantized shard carries its own scales/guard maxima, so
        # the per-shard search guard-bands rs locally and reranks its own
        # boundary — the union merge then sees exact per-shard results.
        # tombs (s_loc, W): each shard filters its own dead slots inside the
        # fused search (result stage only), so the merge below is live-only.
        ids, dists, cnts, overs, nvis, ndis, ess, ph2, nrr = ([] for _ in range(9))
        for s in range(s_loc):
            shard_pts = jax.tree.map(lambda x: x[s], points)
            res = range_search_fused(
                corpus=shard_pts, graph=Graph(neighbors=neighbors[s]),
                queries=qs, start_ids=start_ids[s], r=rs, cfg=cfg,
                es_radius=es, tombstones=None if tombs is None else tombs[s],
                labels=None if labs is None else labs[s], label_filter=filt)
            gids = _remap_global(res.ids, offsets[s], corpus.n_total)
            ids.append(gids)
            dists.append(jnp.where(gids == INVALID_ID, jnp.inf, res.dists))
            # recount after the remap drop (result slots are exactly the
            # valid ids, so the surviving-id count IS the shard count)
            cnts.append(jnp.sum(gids != INVALID_ID, axis=1).astype(jnp.int32))
            overs.append(res.overflow)
            nvis.append(res.n_visited)
            ndis.append(res.n_dist)
            ess.append(res.es_stopped)
            ph2.append(res.phase2)
            nrr.append(res.n_rerank)
        ids = jnp.concatenate(ids, axis=1)      # (q_loc, s_loc*K)
        dists = jnp.concatenate(dists, axis=1)

        # union across the model axis: gather every shard's candidates
        ids = jax.lax.all_gather(ids, model_axis, axis=0)     # (n_model, q, M)
        dists = jax.lax.all_gather(dists, model_axis, axis=0)
        ids = jnp.moveaxis(ids, 0, 1).reshape(ids.shape[1], -1)
        dists = jnp.moveaxis(dists, 0, 1).reshape(dists.shape[1], -1)
        ids, dists = union_merge(ids, dists, cap)

        total = jax.lax.psum(sum(cnts), model_axis)           # (q_loc,)
        over = jax.lax.psum(sum(o.astype(jnp.int32) for o in overs),
                            model_axis) > 0
        return RangeResult(
            ids=ids,
            dists=dists,
            count=jnp.minimum(total, cap).astype(jnp.int32),
            overflow=over | (total > cap),
            n_visited=jax.lax.psum(sum(nvis), model_axis),
            n_dist=jax.lax.psum(sum(ndis), model_axis),
            es_stopped=jax.lax.psum(
                sum(e.astype(jnp.int32) for e in ess), model_axis) > 0,
            phase2=jax.lax.psum(
                sum(p.astype(jnp.int32) for p in ph2), model_axis) > 0,
            n_rerank=jax.lax.psum(sum(nrr), model_axis),
        )

    row = P(data_axis)
    mat = P(data_axis, None)
    # the corpus spec shards every leaf's leading (shard) axis along the
    # model axis — a tree of specs so a stacked QuantizedCorpus (leaves of
    # differing rank, incl. per-shard () guard maxima) lays out the same
    # way as a plain (S, n, d) array
    pts_spec = jax.tree.map(
        lambda leaf: P(model_axis, *([None] * (leaf.ndim - 1))),
        corpus.points)
    out_spec = RangeResult(ids=mat, dists=mat, count=row, overflow=row,
                           n_visited=row, n_dist=row, es_stopped=row,
                           phase2=row, n_rerank=row)
    base_specs = (pts_spec, P(model_axis, None, None),
                  P(model_axis, None), P(model_axis), mat, row, row)
    args = (corpus.points, corpus.neighbors, corpus.start_ids,
            corpus.offsets, queries, radii, es_vec)
    extra_specs, extra_args = [], []
    if tombstones is not None:
        extra_specs.append(P(model_axis, None))
        extra_args.append(jnp.asarray(tombstones, jnp.uint32))
    if has_filter:  # labels shard with the model axis, masks with queries
        extra_specs += [P(model_axis, None, None), mat, row]
        extra_args += [corpus.labels, masks, is_and]
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=base_specs + tuple(extra_specs),
                   out_specs=out_spec, check_vma=False)
    out = fn(*args, *extra_args)
    if q_pad != n_q:
        out = jax.tree.map(lambda x: x[:n_q], out)
    return out
