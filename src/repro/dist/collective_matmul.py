"""Decomposed collective matmuls (ring schedules inside shard_map).

XLA's GSPMD emits all-gather-then-matmul / matmul-then-reduce-scatter as
two serial ops. The ring decompositions here interleave one chunk of
compute with one ``ppermute`` hop per step, which is what lets the compiler
overlap transfer and MXU work (the async-collective-fusion pattern). Both
run inside ``shard_map`` over one mesh axis of size ``n``:

* ``allgather_matmul``   — x row-sharded, w replicated -> full (M, F)
  replicated output: each step multiplies the chunk currently held and
  passes it along the ring.
* ``matmul_reducescatter`` — x col-sharded, w row-sharded -> partial sums
  reduce-scattered over rows: each step adds the local contribution for one
  destination shard and forwards the accumulator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _ring(axis_name: str, n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def allgather_matmul(x, w, *, axis_name: str, n: int):
    """x local (M/n, K) row-shard, w (K, F) replicated -> (M, F) replicated.

    Equivalent to ``all_gather(x) @ w``, decomposed so chunk ``i``'s matmul
    overlaps the ring transfer of chunk ``i+1``.
    """
    idx = jax.lax.axis_index(axis_name)
    m = x.shape[0]
    out = jnp.zeros((n * m, w.shape[-1]), jnp.promote_types(x.dtype, w.dtype))
    chunk = x
    for step in range(n):
        src = (idx - step) % n  # ring: the shard this chunk originated on
        out = jax.lax.dynamic_update_slice_in_dim(out, chunk @ w, src * m,
                                                  axis=0)
        if step < n - 1:
            chunk = jax.lax.ppermute(chunk, axis_name, _ring(axis_name, n))
    return out


def matmul_reducescatter(x, w, *, axis_name: str, n: int):
    """x local (M, K/n), w local (K/n, F) -> (M/n, F) row-scattered.

    Equivalent to ``psum_scatter(x @ w)``: the local partial product is
    chunked over rows and ring-reduced so each shard ends with the fully
    summed chunk of its own rows.
    """
    idx = jax.lax.axis_index(axis_name)
    partial = x @ w                       # (M, F) partial sum over K
    m = partial.shape[0] // n

    def chunk_for(dest):
        return jax.lax.dynamic_slice_in_dim(partial, dest * m, m, axis=0)

    # destination visited at step t is (idx - t - 1) mod n; after n-1 hops
    # the accumulator sits on its destination shard with all n contributions.
    acc = chunk_for((idx - 1) % n)
    for t in range(1, n):
        acc = jax.lax.ppermute(acc, axis_name, _ring(axis_name, n))
        acc = acc + chunk_for((idx - t - 1) % n)
    return acc
