"""Rule-based PartitionSpec engine.

Model code never names mesh axes. It speaks two symbols:

* ``DP`` — the data-parallel direction: every mesh axis that is not the
  model axis (``"data"`` on the 16x16 mesh, ``("pod", "data")`` on the
  multi-pod 2x16x16 mesh).
* ``TP`` — the tensor-parallel direction: the ``"model"`` axis.

Two resolution paths consume the symbols:

* **params** — each architecture ships a table of ``Rule``s (regex over the
  pytree path -> symbolic spec for the *trailing* dims, so one rule covers
  both a stacked ``(L, D, H, dh)`` scan layer and its unstacked
  ``dense_layer0`` twin). ``spec_tree`` matches rules against a param tree
  and applies the **divisibility fallback**: a dim that does not divide its
  mesh axes is replicated instead (e.g. 3 kv heads on tp=4 -> KV
  replication), so one rule table serves every (arch x mesh) cell.
  ``bind_shardings`` turns the symbolic tree into ``NamedSharding``s.
* **activations** — ``shard_activation(x, DP, TP, None)`` inside an
  ``activation_sharding(mesh)`` scope becomes a
  ``with_sharding_constraint``; outside any scope (single-device tests,
  smoke runs) it is the identity, which is what keeps the model code
  mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
import re
import threading
from contextlib import contextmanager
from typing import Any, Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Symbolic axes. Plain strings on purpose: they show up readably in spec
# trees ("dp"/"tp"), compare by value, and can never collide with real mesh
# axis names (the meshes here use "pod"/"data"/"model").
DP = "dp"
TP = "tp"

AxisSym = Union[str, tuple, None]


@dataclasses.dataclass(frozen=True)
class Rule:
    """``pattern`` is a regex over the "/"-joined param path; ``spec`` is a
    symbolic PartitionSpec for the *trailing* dims of any matching leaf
    (leading dims — scan stacking, expert stacking — replicate)."""

    pattern: str
    spec: tuple

    def matches(self, path: str) -> bool:
        return re.fullmatch(self.pattern, path) is not None


# ---------------------------------------------------------------------------
# Mesh introspection
# ---------------------------------------------------------------------------

MODEL_AXIS = "model"


def mesh_axes(mesh: Mesh):
    """(dp, tp): tp is the model axis; dp is every other axis (a bare name
    for one axis, a tuple for several — directly usable as a P entry)."""
    names = tuple(mesh.axis_names)
    tp = MODEL_AXIS if MODEL_AXIS in names else names[-1]
    dp_axes = tuple(a for a in names if a != tp)
    dp = dp_axes[0] if len(dp_axes) == 1 else dp_axes
    return dp, tp


def _axis_size(mesh: Mesh, axes) -> int:
    axes = axes if isinstance(axes, tuple) else (axes,)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _resolve(sym: AxisSym, mesh: Mesh):
    """Symbolic entry -> concrete mesh axis name(s) (or None)."""
    if sym is None:
        return None
    dp, tp = mesh_axes(mesh)
    if isinstance(sym, tuple):
        out: list = []
        for s in sym:
            r = _resolve(s, mesh)
            if r is None:
                continue
            out.extend(r if isinstance(r, tuple) else (r,))
        return tuple(out) if out else None
    if sym == DP:
        return dp
    if sym == TP:
        return tp
    if sym in mesh.axis_names:
        return sym
    raise ValueError(f"unknown sharding axis {sym!r} for mesh {mesh.axis_names}")


# ---------------------------------------------------------------------------
# spec_tree: rules x params -> symbolic spec tree (divisibility fallback)
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _leaf_spec(path: str, leaf, rules: Sequence[Rule], mesh: Mesh) -> tuple:
    ndim = len(leaf.shape)
    spec: list = [None] * ndim
    for rule in rules:
        if not rule.matches(path):
            continue
        tail = tuple(rule.spec)[-ndim:] if ndim else ()
        for i, sym in enumerate(tail, start=ndim - len(tail)):
            if sym is None:
                continue
            size = _axis_size(mesh, _resolve(sym, mesh) or ())
            # divisibility fallback: replicate instead of shard
            if size > 1 and leaf.shape[i] % size == 0 and leaf.shape[i] > 0:
                spec[i] = sym
        break  # first matching rule wins
    return tuple(spec)


class Spec(tuple):
    """One leaf's symbolic PartitionSpec. A distinct type (not a bare tuple)
    so ``bind_shardings`` can tell a spec from a list/tuple pytree
    *container* of specs structurally rather than by content."""

    __slots__ = ()


def spec_tree(params: Any, rules: Sequence[Rule], mesh: Mesh) -> Any:
    """Symbolic spec tree matching ``params``: one ``Spec`` of DP/TP/None
    per leaf (full rank). Arrays and ShapeDtypeStructs both work as
    leaves."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: Spec(_leaf_spec(_path_str(path), leaf, rules, mesh)),
        params)


def _is_spec(node) -> bool:
    """Hand-written plain tuples/lists of symbols also count as specs
    (``()`` = fully replicated) — but never a container holding ``Spec``s."""
    if isinstance(node, Spec):
        return True
    return isinstance(node, (tuple, list)) and all(
        n is None or isinstance(n, str) or
        (isinstance(n, tuple) and not isinstance(n, Spec)
         and all(isinstance(s, str) for s in n))
        for n in node)


def bind_shardings(mesh: Mesh, specs: Any) -> Any:
    """Symbolic spec tree -> NamedSharding tree. ``Spec`` leaves (and plain
    tuples of symbols, e.g. ``()``) become NamedShardings; dicts and
    containers of specs recurse."""
    if _is_spec(specs):
        return NamedSharding(mesh, P(*[_resolve(s, mesh) for s in specs]))
    if isinstance(specs, dict):
        return {k: bind_shardings(mesh, v) for k, v in specs.items()}
    if isinstance(specs, (list, tuple)):
        return type(specs)(bind_shardings(mesh, v) for v in specs)
    raise TypeError(f"cannot bind shardings for {specs!r}")


# ---------------------------------------------------------------------------
# Activation sharding scope
# ---------------------------------------------------------------------------

class _Scope(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None


_SCOPE = _Scope()


@contextmanager
def activation_sharding(mesh: Mesh):
    """Within this scope, ``shard_activation`` pins layouts on ``mesh``."""
    prev, _SCOPE.mesh = _SCOPE.mesh, mesh
    try:
        yield mesh
    finally:
        _SCOPE.mesh = prev


def current_mesh() -> Optional[Mesh]:
    return _SCOPE.mesh


def shard_activation(x, *axes: AxisSym):
    """``with_sharding_constraint`` with symbolic axes + divisibility
    fallback; identity outside an ``activation_sharding`` scope. ``axes``
    cover the leading dims (trailing dims replicate)."""
    mesh = _SCOPE.mesh
    if mesh is None:
        return x
    spec = []
    for i, sym in enumerate(axes[: x.ndim]):
        r = _resolve(sym, mesh)
        if r is not None and x.shape[i] % _axis_size(mesh, r) != 0:
            r = None  # divisibility fallback: leave the dim unsharded
        spec.append(r)
    if not any(s is not None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# Rule tables (consumed by configs/*.py)
# ---------------------------------------------------------------------------

# LM params (models/transformer.py): FSDP over dp (d_model / reduction dims),
# Megatron TP over heads / ffn / experts / vocab. Norms and biases replicate
# via the catch-all. Stacked scan layers get their leading L dim replicated
# by trailing-dim alignment.
LM_RULES = [
    Rule(r".*attn/w[qkv]", (DP, TP, None)),          # (D, H|Hkv, dh)
    Rule(r".*attn/wo", (TP, None, DP)),              # (H, dh|dv, D)
    Rule(r".*attn/w_dq", (DP, TP)),                  # (D, q_lora)
    Rule(r".*attn/w_dkv", (DP, TP)),                 # (D, kv_lora)
    Rule(r".*attn/w_u[qkv]", (DP, TP, None)),        # (lora, H, d)
    Rule(r".*attn/w_kr", (DP, None)),                # (D, rope_dim): tiny
    Rule(r".*moe/router", (DP, None)),               # (D, E): E rarely /: tp
    Rule(r".*moe/shared/w_(gate|up)", (DP, TP)),     # (D, Fs)
    Rule(r".*moe/shared/w_down", (TP, DP)),          # (Fs, D)
    Rule(r".*moe/w_(gate|up)", (TP, DP, None)),      # (E, D, F): EP over tp
    Rule(r".*moe/w_down", (TP, None, DP)),           # (E, F, D)
    Rule(r".*mlp/w_(gate|up)", (DP, TP)),            # (D, F)
    Rule(r".*mlp/w_down", (TP, DP)),                 # (F, D)
    Rule(r".*(embed|unembed)", (TP, DP)),            # (V, D): vocab over tp
    Rule(r".*", ()),                                 # norms/biases replicate
]

# RecSys params (models/recsys.py): the (F, V, d) field tables row-shard V
# over the WHOLE mesh (the EmbeddingBag substrate); MLP towers are FSDP x TP.
RECSYS_RULES = [
    Rule(r".*tables|.*wide", (None, (DP, TP), None)),  # (F, V, d) row-sharded
    Rule(r"(.*/)?w\d+", (DP, TP)),                     # tower matmuls
    Rule(r".*", ()),                                   # biases etc.
]

# GNN params (models/gcn.py): tiny dense weights; shard where divisible,
# replicate otherwise (Cora's 1433-dim input column simply replicates).
GNN_RULES = [
    Rule(r"(.*/)?w\d+", (DP, TP)),
    Rule(r".*", ()),
]
