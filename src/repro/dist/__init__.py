"""repro.dist — the distribution layer.

Five modules, one contract: everything above this package (layers, models,
trainer, server, launch) speaks in *symbolic* axes (``DP``/``TP``) and rule
tables; everything below resolves them against a concrete ``jax`` mesh.

* ``sharding``          — Rule-based PartitionSpec engine: param rule tables
                          (``LM_RULES``/``RECSYS_RULES``/``GNN_RULES``),
                          ``spec_tree`` with divisibility fallback,
                          ``bind_shardings``, and the activation-sharding
                          scope used by the model code.
* ``sharded_engine``    — the multi-shard range-retrieval layout:
                          ``ShardedCorpus`` (one sub-index per model-axis
                          shard), ``build_sharded``, and
                          ``sharded_range_search`` (shard_map fan-out +
                          union merge with global id remapping).
* ``collective_matmul`` — decomposed ring collectives overlapped with
                          matmul (``allgather_matmul``,
                          ``matmul_reducescatter``).
* ``compression``       — int8-compressed gradient/embedding reductions.
* ``embedding``         — row-sharded EmbeddingBag lookup over the mesh.
"""
from .sharding import (  # noqa: F401
    DP,
    GNN_RULES,
    LM_RULES,
    RECSYS_RULES,
    TP,
    Rule,
    activation_sharding,
    bind_shardings,
    mesh_axes,
    shard_activation,
    spec_tree,
)
