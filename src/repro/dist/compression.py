"""Compressed cross-shard reductions.

``psum`` of fp32 gradients/activations is the bandwidth term of every
data-parallel step. The standard mitigation is symmetric int8 quantization
before the wire: each shard quantizes its block against its own absmax
scale, and the reduction runs over the dequantized values. The quantization
error is bounded by ``amax / 254`` per element, which the callers'
tolerances (gradient averaging, mean-pooled embeddings) absorb.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """(q int8, scale) with symmetric per-tensor absmax scaling."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(x, *, axis_name: str, n: int):
    """Mean of ``x`` over ``axis_name`` (size ``n``) with an int8 wire
    format: the collective moves int8 payloads + one scale per shard (the
    per-shard scales are why a direct int8 psum would be invalid), and each
    device dequantizes and sums locally. Shapes are local: (..., D/n) in,
    same out (replicated values).
    """
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)          # (n, ...) int8 on the wire
    scales = jax.lax.all_gather(scale, axis_name)  # (n,) fp32, negligible
    deq = qs.astype(jnp.float32) * scales.reshape((-1,) + (1,) * x.ndim)
    return jnp.sum(deq, axis=0) / n
