"""Compressed cross-shard reductions.

``psum`` of fp32 gradients/activations is the bandwidth term of every
data-parallel step. The standard mitigation is symmetric int8 quantization
before the wire: each shard quantizes its block against its own absmax
scale, and the reduction runs over the dequantized values. The quantization
error is bounded by ``amax / 254`` per element, which the callers'
tolerances (gradient averaging, mean-pooled embeddings) absorb.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Relative slack applied to the quantized-corpus guard-band error bounds
# (core.corpus and the Pallas int8 kernels — this module is importable from
# both without a cycle): the bounds are derived in real arithmetic but
# evaluated in f32 (~1e-7 relative rounding, plus kernel-vs-host reduction-
# order differences of the same magnitude). 1e-4 is orders of magnitude
# more than enough and costs a negligible band widening. The rerank's
# upper-bound recovery assumes every producer used AT LEAST this slack, so
# all lower-bound sites must share the constant.
GUARD_SLACK = 1e-4


def quantize_int8(x):
    """(q int8, scale) with symmetric per-tensor absmax scaling."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_int8_rows(x):
    """(q (N, d) int8, scales (N,) f32): the per-row extension of
    ``quantize_int8``. Each row carries its own absmax scale, so the
    element-wise error is bounded by ``scales[i] / 2`` *per row* — the
    bound the quantized-corpus guard band (``core.corpus``) is derived
    from. ``scale = amax / 127`` means no value clips: round(x/scale) is
    always within [-127, 127]."""
    amax = jnp.max(jnp.abs(x), axis=-1)
    scales = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scales[..., None]), -127, 127).astype(jnp.int8)
    return q, scales


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(x, *, axis_name: str, n: int):
    """Mean of ``x`` over ``axis_name`` (size ``n``) with an int8 wire
    format: the collective moves int8 payloads + one scale per shard (the
    per-shard scales are why a direct int8 psum would be invalid), and each
    device dequantizes and sums locally. Shapes are local: (..., D/n) in,
    same out (replicated values).
    """
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)          # (n, ...) int8 on the wire
    scales = jax.lax.all_gather(scale, axis_name)  # (n,) fp32, negligible
    deq = qs.astype(jnp.float32) * scales.reshape((-1,) + (1,) * x.ndim)
    return jnp.sum(deq, axis=0) / n
