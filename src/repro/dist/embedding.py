"""Row-sharded embedding lookup over the mesh.

The recsys (F, V, d) field tables are the largest arrays in the system
(two-tower: 16 fields x 10.5M rows). They shard over the *vocab* row axis
across the whole mesh; a lookup becomes: every device resolves the ids that
land in its row range and contributes zeros elsewhere, and one ``psum``
assembles the full (B, F, d) activation — the shard_map formulation of the
one-hot-matmul identity that GSPMD uses for sharded gathers.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map


def sharded_lookup(mesh: Mesh, tables, idx, *, axis=("data", "model")):
    """tables (F, V, d) row-sharded over ``axis``; idx (B, F) replicated
    -> (B, F, d) replicated. ``axis`` is one mesh axis name or a tuple
    (sharding V over their product, major-to-minor in tuple order)."""
    axes = axis if isinstance(axis, tuple) else (axis,)
    sizes = [mesh.shape[a] for a in axes]

    def local_fn(tab, ix):
        # linear shard index in PartitionSpec order
        lin = jnp.zeros((), jnp.int32)
        for a, s in zip(axes, sizes):
            lin = lin * s + jax.lax.axis_index(a)
        v_local = tab.shape[1]
        loc = ix - lin * v_local
        valid = (loc >= 0) & (loc < v_local)
        safe = jnp.where(valid, loc, 0)
        rows = jax.vmap(lambda t, i: jnp.take(t, i, axis=0),
                        in_axes=(0, 1), out_axes=1)(tab, safe)  # (B, F, d)
        rows = jnp.where(valid[..., None], rows, 0)
        return jax.lax.psum(rows, axes)

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P(None, axes, None), P(None, None)),
                   out_specs=P(None, None, None), check_vma=False)
    return fn(tables, jnp.asarray(idx, jnp.int32))
