"""Pallas TPU kernel: scalar-prefetch row gather + fused distance.

Beam expansion's memory pattern: for each (query, candidate-id) pair, fetch
``points[id]`` from HBM and reduce it against the query immediately —
never materializing the gathered ``(Q, R, d)`` tensor. On TPU this is the
paged-attention / embedding-lookup pattern: the candidate ids are *scalar
prefetch* operands, so the Pallas pipeline can issue the HBM->VMEM row DMA
for step i+1 while step i computes.

Grid: ``(Q*R / block_c,)`` over flattened candidates. The id list drives the
``index_map`` of the points BlockSpec at row granularity (block_c rows per
step via an id-sorted? no — one row per candidate, block_c candidates per
step each fetching its own row would need gather-DMA; instead we take
block_c = 1 row per grid step, which is the canonical scalar-prefetch
row-streaming formulation).

The ops wrapper flattens (Q, R) -> (Q*R,), clamps INVALID ids to 0 and
masks the outputs back to +inf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gatherdist_kernel(
    ids_ref,    # (C,) int32 scalar-prefetch: candidate row ids (clamped)
    qidx_ref,   # (C,) int32 scalar-prefetch: query index per candidate
    x_ref,      # (1, d) the gathered point row
    q_ref,      # (1, d) the query row
    out_ref,    # (1,) f32 distance
    *,
    metric: str,
):
    x = x_ref[0, :].astype(jnp.float32)
    q = q_ref[0, :].astype(jnp.float32)
    if metric == "l2":
        diff = x - q
        out_ref[0] = jnp.sum(diff * diff)
    else:
        out_ref[0] = -jnp.sum(x * q)


def gatherdist_pallas(
    points: jnp.ndarray,    # (N, d)
    ids: jnp.ndarray,       # (C,) int32, pre-clamped to [0, N)
    qidx: jnp.ndarray,      # (C,) int32 query row per candidate
    queries: jnp.ndarray,   # (Q, d)
    *,
    metric: str = "l2",
    interpret: bool = False,
) -> jnp.ndarray:
    c = ids.shape[0]
    d = points.shape[1]
    kernel = functools.partial(_gatherdist_kernel, metric=metric)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids_ref, qidx_ref: (ids_ref[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids_ref, qidx_ref: (qidx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, ids_ref, qidx_ref: (i,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=interpret,
    )(ids, qidx, points, queries)
