"""Pallas TPU kernel: scalar-prefetch row gather + fused distance.

Beam expansion's memory pattern: for each (query, candidate-id) pair, fetch
``points[id]`` from HBM and reduce it against the query immediately —
never materializing the gathered ``(Q, R, d)`` tensor. On TPU this is the
paged-attention / embedding-lookup pattern: the candidate ids are *scalar
prefetch* operands, so the Pallas pipeline can issue the HBM->VMEM row DMA
for step i+1 while step i computes.

Grid: ``(Q*R / block_c,)`` over flattened candidates. The id list drives the
``index_map`` of the points BlockSpec at row granularity (block_c rows per
step via an id-sorted? no — one row per candidate, block_c candidates per
step each fetching its own row would need gather-DMA; instead we take
block_c = 1 row per grid step, which is the canonical scalar-prefetch
row-streaming formulation).

The ops wrapper flattens (Q, R) -> (Q*R,), clamps INVALID ids to 0 and
masks the outputs back to +inf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...dist.compression import GUARD_SLACK


def _gatherdist_kernel(
    ids_ref,    # (C,) int32 scalar-prefetch: candidate row ids (clamped)
    qidx_ref,   # (C,) int32 scalar-prefetch: query index per candidate
    x_ref,      # (1, d) the gathered point row
    q_ref,      # (1, d) the query row
    out_ref,    # (1,) f32 distance
    *,
    metric: str,
):
    x = x_ref[0, :].astype(jnp.float32)
    q = q_ref[0, :].astype(jnp.float32)
    if metric == "l2":
        diff = x - q
        out_ref[0] = jnp.sum(diff * diff)
    else:
        out_ref[0] = -jnp.sum(x * q)


def _gatherdist_kernel_int8(
    ids_ref,    # (C,) int32 scalar-prefetch: candidate row ids (clamped)
    qidx_ref,   # (C,) int32 scalar-prefetch: query index per candidate
    x_ref,      # (1, d) the gathered int8 code row
    m_ref,      # (1, 3) the row's [scale, |x_hat|^2, err] metadata
    q_ref,      # (1, d) the query row (f32)
    out_ref,    # (1,) f32 distance
    *,
    metric: str,
):
    """Int8 variant: the row stream is 1-byte codes + a 12-byte metadata
    row (~4x less HBM per distance than f32 rows); the reduction is an int8
    x int8 MXU dot whose int32 accumulator is dequantized by
    ``row_scale * query_scale``, then lowered to the certified lower bound
    (``core.corpus.lower_bound_dists``) — same arithmetic as the int8
    expand kernel, so the two agree bitwise on shared candidates."""
    q = q_ref[0, :].astype(jnp.float32)
    q_scale = jnp.maximum(jnp.max(jnp.abs(q)), 1e-12) / 127.0
    qc_f = jnp.clip(jnp.round(q / q_scale), -127, 127)
    q_err = jnp.sqrt(jnp.sum((q - qc_f * q_scale) ** 2))
    idot = jax.lax.dot_general(
        x_ref[0:1, :], qc_f.astype(jnp.int8)[:, None],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )[0, 0]
    dots = idot.astype(jnp.float32) * (m_ref[0, 0] * q_scale)
    if metric == "l2":
        qn = jnp.sum((qc_f * q_scale) ** 2)
        d_hat = jnp.maximum(m_ref[0, 1] + qn - 2.0 * dots, 0.0)
        g = (m_ref[0, 2] + q_err) * (1.0 + GUARD_SLACK)
        out_ref[0] = jnp.maximum(jnp.sqrt(d_hat) - g, 0.0) ** 2
    else:
        q_norm = jnp.sqrt(jnp.sum(q * q))
        xnorm = jnp.sqrt(jnp.maximum(m_ref[0, 1], 0.0))
        eps = (m_ref[0, 2] * q_norm + xnorm * q_err) * (1.0 + GUARD_SLACK)
        out_ref[0] = -dots - eps


def gatherdist_pallas_int8(
    codes: jnp.ndarray,     # (N, d) int8
    meta: jnp.ndarray,      # (N, 3) f32 [scale, |x_hat|^2, err]
    ids: jnp.ndarray,       # (C,) int32, pre-clamped to [0, N)
    qidx: jnp.ndarray,      # (C,) int32 query row per candidate
    queries: jnp.ndarray,   # (Q, d) f32
    *,
    metric: str = "l2",
    interpret: bool = False,
) -> jnp.ndarray:
    c = ids.shape[0]
    d = codes.shape[1]
    kernel = functools.partial(_gatherdist_kernel_int8, metric=metric)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids_ref, qidx_ref: (ids_ref[i], 0)),
            pl.BlockSpec((1, 3), lambda i, ids_ref, qidx_ref: (ids_ref[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids_ref, qidx_ref: (qidx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, ids_ref, qidx_ref: (i,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=interpret,
    )(ids, qidx, codes, meta, queries)


def gatherdist_pallas(
    points: jnp.ndarray,    # (N, d)
    ids: jnp.ndarray,       # (C,) int32, pre-clamped to [0, N)
    qidx: jnp.ndarray,      # (C,) int32 query row per candidate
    queries: jnp.ndarray,   # (Q, d)
    *,
    metric: str = "l2",
    interpret: bool = False,
) -> jnp.ndarray:
    c = ids.shape[0]
    d = points.shape[1]
    kernel = functools.partial(_gatherdist_kernel, metric=metric)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids_ref, qidx_ref: (ids_ref[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids_ref, qidx_ref: (qidx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, ids_ref, qidx_ref: (i,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=interpret,
    )(ids, qidx, points, queries)
