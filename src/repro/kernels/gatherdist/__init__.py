from .kernel import gatherdist_pallas
from .ops import gatherdist
from .ref import gatherdist_ref

__all__ = ["gatherdist", "gatherdist_pallas", "gatherdist_ref"]
