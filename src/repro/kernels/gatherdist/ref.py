"""Pure-jnp oracle for the gatherdist kernel."""
from __future__ import annotations

import jax.numpy as jnp

from ...utils import INVALID_ID


def gatherdist_ref(points, ids, queries, *, metric: str = "l2"):
    """(Q, R) distances from queries[i] to points[ids[i, j]]; INVALID -> inf.

    ``points`` may be a quantized corpus (duck-typed via ``.codes``): rows
    dequantize in-register, the query stays f32, and the result is each
    candidate's certified lower bound (``core.corpus.lower_bound_dists``) —
    the same contract as the int8 kernel's quantized-query arithmetic."""
    quant = getattr(points, "codes", None) is not None
    n = (points.codes if quant else points).shape[0]
    valid = (ids != INVALID_ID) & (ids < n)
    safe = jnp.where(valid, ids, 0)
    if quant:
        from ...core.corpus import quantized_gather_lb
        d = quantized_gather_lb(points, safe, queries, metric)
        return jnp.where(valid, d, jnp.inf)
    vecs = jnp.take(points, safe, axis=0).astype(jnp.float32)  # (Q, R, d)
    q = queries.astype(jnp.float32)[:, None, :]
    if metric == "l2":
        diff = vecs - q
        d = jnp.sum(diff * diff, axis=-1)
    else:
        d = -jnp.sum(vecs * q, axis=-1)
    return jnp.where(valid, d, jnp.inf)
