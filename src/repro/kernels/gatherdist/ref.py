"""Pure-jnp oracle for the gatherdist kernel."""
from __future__ import annotations

import jax.numpy as jnp

from ...utils import INVALID_ID


def gatherdist_ref(points, ids, queries, *, metric: str = "l2"):
    """(Q, R) distances from queries[i] to points[ids[i, j]]; INVALID -> inf."""
    n = points.shape[0]
    valid = (ids != INVALID_ID) & (ids < n)
    safe = jnp.where(valid, ids, 0)
    vecs = jnp.take(points, safe, axis=0).astype(jnp.float32)  # (Q, R, d)
    q = queries.astype(jnp.float32)[:, None, :]
    if metric == "l2":
        diff = vecs - q
        d = jnp.sum(diff * diff, axis=-1)
    else:
        d = -jnp.sum(vecs * q, axis=-1)
    return jnp.where(valid, d, jnp.inf)
