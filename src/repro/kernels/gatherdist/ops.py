"""jit'd public wrapper for gatherdist (flatten + clamp + mask)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...utils import INVALID_ID
from .kernel import gatherdist_pallas, gatherdist_pallas_int8
from .ref import gatherdist_ref


@partial(jax.jit, static_argnames=("metric", "use_pallas", "interpret"))
def gatherdist(
    points,                # (N, d) array, or a core.corpus.QuantizedCorpus
    ids: jnp.ndarray,      # (Q, R) int32 (INVALID_ID-padded)
    queries: jnp.ndarray,  # (Q, d)
    *,
    metric: str = "l2",
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """(Q, R) fused gather+distance; invalid ids map to +inf.

    A quantized corpus (duck-typed via ``.codes``) routes to the int8
    kernel variant (int8 row stream + MXU int8 dot + accumulator dequant).
    """
    quant = getattr(points, "codes", None) is not None
    if not use_pallas:
        return gatherdist_ref(points, ids, queries, metric=metric)
    qn, r = ids.shape
    n = (points.codes if quant else points).shape[0]
    valid = (ids != INVALID_ID) & (ids < n)
    flat_ids = jnp.where(valid, ids, 0).reshape(-1)
    qidx = jnp.broadcast_to(jnp.arange(qn, dtype=jnp.int32)[:, None], (qn, r)).reshape(-1)
    if quant:
        d = gatherdist_pallas_int8(points.codes, points.meta, flat_ids, qidx,
                                   queries, metric=metric,
                                   interpret=interpret).reshape(qn, r)
    else:
        d = gatherdist_pallas(points, flat_ids, qidx, queries, metric=metric,
                              interpret=interpret).reshape(qn, r)
    return jnp.where(valid, d, jnp.inf)
