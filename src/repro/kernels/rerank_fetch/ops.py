"""Dispatch wrapper for the rerank-fetch kernel.

`use_pallas=False` (the CPU-CI default) runs the XLA reference;
`use_pallas=True, interpret=True` emulates the TPU kernel on CPU for the
parity suite. The tiered corpus's host path does not route through here —
on CPU CI the host→device copy is a `jax.device_put` — but on TPU this is
the fetch+distance stage the tier swaps in per miss bucket.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import fetch_rerank_dists_pallas
from .ref import fetch_rerank_dists_ref


@partial(jax.jit, static_argnames=("metric", "use_pallas", "tile", "interpret"))
def fetch_rerank_dists(
    raw,                  # (N, d) raw f32 rows
    ids,                  # (P,) int32 row ids (pad entries clamped in-range)
    qv,                   # (P, d) pre-gathered per-pair query rows
    *,
    metric: str = "l2",
    use_pallas: bool = False,
    tile: int = 16,
    interpret: bool = False,
) -> jnp.ndarray:
    ids = jnp.clip(jnp.asarray(ids, jnp.int32), 0, raw.shape[0] - 1)
    if not use_pallas:
        return fetch_rerank_dists_ref(raw, ids, qv, metric)
    return fetch_rerank_dists_pallas(raw, ids, qv, metric=metric,
                                     tile=tile, interpret=interpret)
