from .ops import fetch_rerank_dists
from .ref import fetch_rerank_dists_ref

__all__ = ["fetch_rerank_dists", "fetch_rerank_dists_ref"]
