"""Pallas TPU kernel: double-buffered raw-row gather for the rerank band.

The TPU half of the tiered rerank fetch. The host planner
(`tier.planner.plan_fetch`) has already deduplicated and pow2-bucketed the
guard-band (lane, slot) pairs; this kernel consumes one bucket at a time:
a tile of row ids arrives by scalar prefetch, the exact f32 rows are
DMA-gathered from the raw-row array (``pltpu.ANY`` — HBM on device, and
the drop-in source for a host-DMA pointer once single-controller host
memory is addressable), and the per-pair exact distances come out fused,
so the gathered rows never materialize as an XLA tensor.

Unlike the expand kernel's start/wait-per-row gather, the row DMAs here
are **double-buffered** (the guide's two-semaphore rotation): the copy for
row r+1 is issued while row r's copy is being waited on, hiding the
row-fetch latency behind itself — the pattern the tiered corpus mirrors
at bucket granularity on the host side with overlapped ``device_put``.

VMEM per grid step: row scratch ``tile*d*4`` B + query block the same +
one (1, tile) out row — for tile=16, d=128 that is ~16 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fetch_kernel(
    ids_ref,    # (P,) int32 scalar-prefetch: clamped row ids (pad -> 0)
    qv_ref,     # (tile, d) the tile's pre-gathered query rows
    raw_ref,    # (N, d) f32 raw rows, ANY/HBM — gathered by manual DMA
    out_ref,    # (1, tile) f32 out: exact distances
    vec_ref,    # (tile, d) f32 VMEM scratch: gathered rows
    sems,       # (2,) DMA semaphores — the double-buffer rotation
    *,
    tile: int,
    metric: str,
):
    t = pl.program_id(0)
    base = t * tile

    def row_copy(r):
        slot = jax.lax.rem(r, 2)
        return pltpu.make_async_copy(
            raw_ref.at[ids_ref[base + r]], vec_ref.at[r], sems.at[slot])

    # double-buffered gather: row r+1's DMA is in flight while row r's
    # completes, so consecutive row fetches overlap instead of serializing
    row_copy(0).start()

    def body(r, _):
        @pl.when(r + 1 < tile)
        def _start_next():
            row_copy(r + 1).start()

        row_copy(r).wait()
        return 0

    jax.lax.fori_loop(0, tile, body, 0, unroll=False)

    x = vec_ref[...].astype(jnp.float32)      # (tile, d)
    q = qv_ref[...].astype(jnp.float32)       # (tile, d)
    if metric == "l2":
        diff = x - q
        out_ref[0, :] = jnp.sum(diff * diff, axis=1)
    else:  # ip
        out_ref[0, :] = -jnp.sum(x * q, axis=1)


def fetch_rerank_dists_pallas(
    raw: jnp.ndarray,     # (N, d) f32 raw rows
    ids: jnp.ndarray,     # (P,) int32 row ids, P a multiple of tile
    qv: jnp.ndarray,      # (P, d) pre-gathered query rows
    *,
    metric: str = "l2",
    tile: int = 16,
    interpret: bool = False,
) -> jnp.ndarray:
    p, d = qv.shape
    assert p % tile == 0, f"pair count {p} not a multiple of tile {tile}"
    n_tiles = p // tile
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda t, ids_ref: (t, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda t, ids_ref: (t, 0)),
        scratch_shapes=[
            pltpu.VMEM((tile, d), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_fetch_kernel, tile=tile, metric=metric),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32), qv.astype(jnp.float32),
      raw.astype(jnp.float32))
    return out.reshape(p)
