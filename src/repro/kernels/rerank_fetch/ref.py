"""XLA reference for the rerank-fetch kernel: gather + per-pair distance."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.distances import point_dist


def fetch_rerank_dists_ref(raw: jnp.ndarray, ids: jnp.ndarray,
                           qv: jnp.ndarray, metric: str = "l2") -> jnp.ndarray:
    """Exact f32 distances for flat rerank pairs.

    ``raw`` is the (N, d) row source, ``ids`` the (P,) row ids, ``qv`` the
    (P, d) pre-gathered per-pair query rows. Same math as the core
    `_exact_pairs` seam, with the query gather already done by the caller.
    """
    vecs = jnp.take(raw, ids, axis=0).astype(jnp.float32)
    return point_dist(vecs, qv.astype(jnp.float32), metric)
