from .ops import expand_frontier
from .ref import expand_frontier_1, expand_frontier_ref

__all__ = ["expand_frontier", "expand_frontier_1", "expand_frontier_ref"]
