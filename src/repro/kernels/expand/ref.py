"""Pure-jnp oracle for the fused frontier-expand kernel.

Semantics (shared with the Pallas kernel):

* frontier entries that are INVALID_ID or out of range yield all-INVALID
  rows (no distances, no n_dist contribution);
* every valid adjacency entry gets a distance (this is what ``n_dist``
  counts — it is the number of distance computations performed, duplicates
  included, matching the unfused path's accounting);
* only the **first occurrence** of each neighbor id within the flattened
  E*R tile survives; later duplicates are masked to INVALID/+inf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...utils import INVALID_ID


def expand_frontier_1(
    points,                  # (N, d) corpus (any float dtype; math in f32)
                             # or a core.corpus.QuantizedCorpus (duck-typed
                             # via .codes to keep kernels import-cycle-free)
    neighbors: jnp.ndarray,  # (N, R) int32 adjacency, INVALID_ID padded
    frontier: jnp.ndarray,   # (E,) int32 nodes to expand (INVALID_ID padded)
    q: jnp.ndarray,          # (d,) query
    metric: str = "l2",
    point_norms: jnp.ndarray | None = None,  # (N,) precomputed |x|^2 (l2)
):
    """Single-query fused expansion -> (ids (E*R,), dists (E*R,), n_dist ()).

    Distances use the kernel's matmul form, ``|x|^2 + |q|^2 - 2 x.q``, when
    ``point_norms`` is supplied (the search loop precomputes them once per
    corpus): one (T, d) x (d,) GEMV plus a T-float norm gather replaces
    three elementwise passes over the gathered tile — the tile read is the
    loop's bandwidth floor, so passes over it are what matter.

    An int8 quantized corpus gathers 1-byte codes + a 12-byte metadata row
    per candidate (the ~4x HBM saving), dequantizes in-register, and
    returns each candidate's *certified lower-bound* distance
    (``core.corpus.lower_bound_dists``) so the search loop's ``dist <= r``
    tests keep a provable superset at the original radius.
    """
    quant = getattr(points, "codes", None) is not None
    n = (points.codes if quant else points).shape[0]
    f_ok = (frontier >= 0) & (frontier < n)
    rows = jnp.take(neighbors, jnp.where(f_ok, frontier, 0), axis=0)  # (E, R)
    flat = jnp.where(f_ok[:, None], rows, INVALID_ID).reshape(-1)     # (E*R,)

    valid = (flat >= 0) & (flat < n)
    safe = jnp.where(valid, flat, 0)
    qf = q.astype(jnp.float32)
    if quant:
        from ...core.corpus import quantized_gather_lb
        d = quantized_gather_lb(points, safe, qf, metric)
        dup = _first_occurrence_dup(flat, valid)
        keep = valid & ~dup
        ids = jnp.where(keep, flat, INVALID_ID)
        dists = jnp.where(keep, d, jnp.inf)
        return ids, dists, jnp.sum(valid).astype(jnp.int32)
    vecs = jnp.take(points, safe, axis=0).astype(jnp.float32)  # (E*R, d)
    if metric == "l2" and point_norms is not None:
        dots = vecs @ qf
        xn = jnp.take(point_norms, safe).astype(jnp.float32)
        d = jnp.maximum(xn + jnp.sum(qf * qf) - 2.0 * dots, 0.0)
    elif metric == "l2":
        diff = vecs - qf[None, :]
        d = jnp.sum(diff * diff, axis=-1)
    else:  # ip
        d = -(vecs @ qf)

    dup = _first_occurrence_dup(flat, valid)
    keep = valid & ~dup
    ids = jnp.where(keep, flat, INVALID_ID)
    dists = jnp.where(keep, d, jnp.inf)
    return ids, dists, jnp.sum(valid).astype(jnp.int32)


def _first_occurrence_dup(flat: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """First-occurrence dedup as one vectorized (T, T) compare — the same
    one-pass mask the kernel computes. (A sort-based O(T log T) dedup was
    tried and lost in-loop: XLA's sort comparator costs far more per
    element than a broadcast compare at tile sizes of a few hundred.)"""
    t = jnp.arange(flat.shape[0])
    return jnp.any(
        (flat[:, None] == flat[None, :])
        & (t[None, :] < t[:, None])
        & valid[None, :] & valid[:, None],
        axis=1,
    )


def expand_frontier_ref(points, neighbors, frontier, queries, *, metric: str = "l2"):
    """Batched oracle: frontier (Q, E), queries (Q, d) ->
    (ids (Q, E*R), dists (Q, E*R), n_dist (Q,))."""
    fn = lambda f, q: expand_frontier_1(points, neighbors, f, q, metric)
    return jax.vmap(fn)(frontier, queries)
