"""jit'd public wrapper for the expand kernel (clamp + dispatch).

``use_pallas=False`` routes to the pure-jnp oracle — the XLA path the search
loop uses on hosts where Pallas TPU custom calls do not lower (CPU CI, dry
runs). On a real TPU set ``use_pallas=True, interpret=False``; for kernel
unit tests ``interpret=True`` emulates the DMAs on CPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import expand_pallas, expand_pallas_int8
from .ref import expand_frontier_ref


@partial(jax.jit, static_argnames=("metric", "use_pallas", "interpret"))
def expand_frontier(
    points,                  # (N, d) array, or a core.corpus.QuantizedCorpus
    neighbors: jnp.ndarray,  # (N, R) int32 adjacency (INVALID_ID padded)
    frontier: jnp.ndarray,   # (Q, E) int32 nodes to expand (INVALID_ID padded)
    queries: jnp.ndarray,    # (Q, d)
    *,
    metric: str = "l2",
    use_pallas: bool = True,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused frontier expansion.

    Returns ``(ids (Q, E*R), dists (Q, E*R), n_dist (Q,))`` where each
    query's tile is first-occurrence-deduped and INVALID/+inf padded, and
    ``n_dist`` counts distances computed (pre-dedup).

    A quantized corpus (duck-typed via ``.codes``) routes to the int8
    kernel: int8 code gather + int8 MXU matmul + accumulator dequant. The
    kernel quantizes the query too, so its distances differ from the XLA
    reference's (which keeps the query in f32) by at most the
    ``query_quant_err`` term of the guard-band envelope.
    """
    quant = getattr(points, "codes", None) is not None
    if not use_pallas:
        return expand_frontier_ref(points, neighbors, frontier, queries,
                                   metric=metric)
    n = (points.codes if quant else points).shape[0]
    qn, e = frontier.shape
    f_ok = (frontier >= 0) & (frontier < n)
    fid = jnp.where(f_ok, frontier, 0).reshape(-1)
    fval = f_ok.astype(jnp.int32).reshape(-1)
    if quant:
        return expand_pallas_int8(
            points.codes, points.meta, neighbors, fid, fval, queries,
            expand_width=e, metric=metric, interpret=interpret,
        )
    ids, dists, cnts = expand_pallas(
        points, neighbors, fid, fval, queries,
        expand_width=e, metric=metric, interpret=interpret,
    )
    return ids, dists, cnts
