"""Pallas TPU kernel: fused multi-node frontier expansion.

One grid step expands one (query, frontier-node) pair: it pulls the node's
adjacency row into VMEM via a scalar-prefetch-driven BlockSpec, DMA-gathers
the R neighbor vectors straight from the corpus in HBM (``pltpu.ANY`` — the
corpus never materializes as a gathered (E, R, d) tensor in XLA), computes
all R distances in one MXU matmul against the query, and masks duplicate
neighbor ids against every earlier row of the same query's E*R tile in the
same pass. This fuses what the unfused path does as four XLA ops
(``out_neighbors`` gather + vector gather + distance + three broadcast
dedups) into a single pipelined kernel.

Layout:

* grid ``(Q, E)`` — E innermost, so the steps of one query run back to back
  and the per-query dedup tile in scratch is valid (the grid must stay
  sequential; do not mark these dimensions parallel).
* scalar prefetch: flattened frontier ids (clamped) + validity flags. The
  adjacency BlockSpec indexes rows directly off the prefetched ids, so the
  HBM->VMEM row DMA for step i+1 issues while step i computes.
* the neighbor-vector gather is a manual ``make_async_copy`` loop into a
  (R, d) VMEM scratch (the paged-attention pattern): BlockSpecs cannot
  express a data-dependent gather, DMAs can.
* distances: ``x @ q`` on the MXU (f32 accumulation), plus rank-1 norm
  corrections for L2. A bf16-stored corpus is gathered in bf16 (halving the
  dominant HBM term) and cast to f32 only in VMEM.
* dedup: the kernel keeps the tile's surviving ids in a persistent
  (E*R,) VMEM scratch; each row masks against all earlier rows plus itself
  (first occurrence wins), exactly matching ``ref.expand_frontier_ref``.

VMEM per step (f32 corpus, defaults E=4, R=64, d=128): adjacency row
``4R`` B + vector scratch ``R*d*4`` = 32 KiB + dedup tile ``E*R*4`` = 1 KiB
+ query row ``4d`` + out blocks ``8R`` — well under the 16 MiB budget; the
vector scratch dominates and scales as ``R*d*itemsize``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...dist.compression import GUARD_SLACK
from ...utils import INVALID_ID


def _expand_kernel(
    fid_ref,    # (Q*E,) int32 scalar-prefetch: clamped frontier ids
    fval_ref,   # (Q*E,) int32 scalar-prefetch: frontier validity flags
    adj_ref,    # (1, R) the frontier node's adjacency row
    pts_ref,    # (N, d) corpus, ANY/HBM — gathered by manual DMA
    q_ref,      # (1, d) the query row
    ids_ref,    # (1, R) int32 out: deduped neighbor ids
    dist_ref,   # (1, R) f32 out: distances (+inf where masked)
    cnt_ref,    # (1, 1) int32 out: distances computed (pre-dedup)
    vec_ref,    # (R, d) VMEM scratch: gathered neighbor vectors
    tile_ref,   # (E*R,) int32 VMEM scratch: per-query surviving-id tile
    sem,        # DMA semaphore
    *,
    n_nodes: int,
    expand_width: int,
    metric: str,
):
    qi = pl.program_id(0)
    e = pl.program_id(1)
    i = qi * expand_width + e

    @pl.when(e == 0)
    def _reset_tile():
        tile_ref[...] = jnp.full_like(tile_ref, INVALID_ID)

    adj = adj_ref[0, :]                       # (R,) neighbor ids
    n_ok = (adj >= 0) & (adj < n_nodes)
    safe = jnp.where(n_ok, adj, 0)

    def gather(r, _):
        cp = pltpu.make_async_copy(pts_ref.at[safe[r]], vec_ref.at[r], sem)
        cp.start()
        cp.wait()
        return 0

    jax.lax.fori_loop(0, adj.shape[0], gather, 0)

    x = vec_ref[...].astype(jnp.float32)      # (R, d)
    q = q_ref[0, :].astype(jnp.float32)       # (d,)
    dots = jax.lax.dot_general(
        x, q[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]                                   # (R,) MXU
    if metric == "l2":
        xn = jnp.sum(x * x, axis=1)
        qn = jnp.sum(q * q)
        dist = jnp.maximum(xn + qn - 2.0 * dots, 0.0)
    else:  # ip
        dist = -dots

    # dedup: earlier rows of this query's tile, then first-in-row wins
    prev = tile_ref[...]                      # (E*R,)
    seen_prev = jnp.any(adj[:, None] == prev[None, :], axis=1)
    rr = jnp.arange(adj.shape[0])
    dup_row = jnp.any(
        (adj[:, None] == adj[None, :]) & (rr[None, :] < rr[:, None])
        & n_ok[:, None] & n_ok[None, :],
        axis=1,
    )
    f_ok = fval_ref[i] > 0
    keep = n_ok & (~seen_prev) & (~dup_row) & f_ok

    kept = jnp.where(keep, adj, INVALID_ID)
    ids_ref[0, :] = kept
    dist_ref[0, :] = jnp.where(keep, dist, jnp.inf)
    cnt_ref[0, 0] = jnp.sum((n_ok & f_ok).astype(jnp.int32))
    tile_ref[pl.ds(e * adj.shape[0], adj.shape[0])] = kept


def _expand_kernel_int8(
    fid_ref,    # (Q*E,) int32 scalar-prefetch: clamped frontier ids
    fval_ref,   # (Q*E,) int32 scalar-prefetch: frontier validity flags
    adj_ref,    # (1, R) the frontier node's adjacency row
    codes_ref,  # (N, d) int8 corpus codes, ANY/HBM — gathered by manual DMA
    meta_ref,   # (N, 3) f32 [scale, |x_hat|^2, err] per row, ANY/HBM
    q_ref,      # (1, d) the query row (f32)
    ids_ref,    # (1, R) int32 out
    dist_ref,   # (1, R) f32 out
    cnt_ref,    # (1, 1) int32 out
    cvec_ref,   # (R, d) int8 VMEM scratch: gathered neighbor codes
    mvec_ref,   # (R, 3) f32 VMEM scratch: gathered neighbor metadata
    tile_ref,   # (E*R,) int32 VMEM scratch: per-query surviving-id tile
    sem,        # DMA semaphore
    *,
    n_nodes: int,
    expand_width: int,
    metric: str,
):
    """Int8 variant of ``_expand_kernel``: gathers 1-byte codes + a 12-byte
    metadata row per neighbor (quartering the dominant HBM gather term),
    quantizes the query once per step, runs the R distances as ONE int8 x
    int8 MXU matmul with an int32 accumulator, and dequantizes the
    accumulator by ``scale_row * scale_query``. The emitted distances are
    the certified lower bounds of ``core.corpus.lower_bound_dists`` — the
    per-row stored error plus this kernel's own exact query-quantization
    error — so the search loop's threshold tests stay supersets at the
    caller's radius, identically to the XLA reference path."""
    qi = pl.program_id(0)
    e = pl.program_id(1)
    i = qi * expand_width + e

    @pl.when(e == 0)
    def _reset_tile():
        tile_ref[...] = jnp.full_like(tile_ref, INVALID_ID)

    adj = adj_ref[0, :]                       # (R,) neighbor ids
    n_ok = (adj >= 0) & (adj < n_nodes)
    safe = jnp.where(n_ok, adj, 0)

    def gather(r, _):
        cp = pltpu.make_async_copy(codes_ref.at[safe[r]], cvec_ref.at[r], sem)
        cp.start()
        cp.wait()
        cm = pltpu.make_async_copy(meta_ref.at[safe[r]], mvec_ref.at[r], sem)
        cm.start()
        cm.wait()
        return 0

    jax.lax.fori_loop(0, adj.shape[0], gather, 0)

    # quantize the query (symmetric absmax, matching the corpus scheme)
    q = q_ref[0, :].astype(jnp.float32)       # (d,)
    q_scale = jnp.maximum(jnp.max(jnp.abs(q)), 1e-12) / 127.0
    qc_f = jnp.clip(jnp.round(q / q_scale), -127, 127)
    qc = qc_f.astype(jnp.int8)
    q_err = jnp.sqrt(jnp.sum((q - qc_f * q_scale) ** 2))  # exact err_q

    idot = jax.lax.dot_general(
        cvec_ref[...], qc[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )[:, 0]                                   # (R,) int32 MXU, exact
    scales = mvec_ref[:, 0]                   # (R,)
    errs = mvec_ref[:, 2]                     # (R,) per-row |x - x_hat|
    dots = idot.astype(jnp.float32) * (scales * q_scale)
    # certified lower bound (core.corpus.lower_bound_dists, inlined): the
    # in-kernel distance is between DEQUANTIZED row and query, so both the
    # row's stored error and this kernel's own query error are subtracted
    if metric == "l2":
        xn = mvec_ref[:, 1]
        qn = jnp.sum((qc_f * q_scale) ** 2)
        d_hat = jnp.maximum(xn + qn - 2.0 * dots, 0.0)
        g = (errs + q_err) * (1.0 + GUARD_SLACK)
        dist = jnp.maximum(jnp.sqrt(d_hat) - g, 0.0) ** 2
    else:  # ip
        q_norm = jnp.sqrt(jnp.sum(q * q))
        xnorm = jnp.sqrt(jnp.maximum(mvec_ref[:, 1], 0.0))
        eps = (errs * q_norm + xnorm * q_err) * (1.0 + GUARD_SLACK)
        dist = -dots - eps

    # dedup: earlier rows of this query's tile, then first-in-row wins
    prev = tile_ref[...]                      # (E*R,)
    seen_prev = jnp.any(adj[:, None] == prev[None, :], axis=1)
    rr = jnp.arange(adj.shape[0])
    dup_row = jnp.any(
        (adj[:, None] == adj[None, :]) & (rr[None, :] < rr[:, None])
        & n_ok[:, None] & n_ok[None, :],
        axis=1,
    )
    f_ok = fval_ref[i] > 0
    keep = n_ok & (~seen_prev) & (~dup_row) & f_ok

    kept = jnp.where(keep, adj, INVALID_ID)
    ids_ref[0, :] = kept
    dist_ref[0, :] = jnp.where(keep, dist, jnp.inf)
    cnt_ref[0, 0] = jnp.sum((n_ok & f_ok).astype(jnp.int32))
    tile_ref[pl.ds(e * adj.shape[0], adj.shape[0])] = kept


def expand_pallas_int8(
    codes: jnp.ndarray,      # (N, d) int8 corpus codes
    meta: jnp.ndarray,       # (N, 3) f32 [scale, |x_hat|^2, err]
    neighbors: jnp.ndarray,  # (N, R) int32
    fid: jnp.ndarray,        # (Q*E,) int32, pre-clamped to [0, N)
    fval: jnp.ndarray,       # (Q*E,) int32 validity flags
    queries: jnp.ndarray,    # (Q, d) f32
    *,
    expand_width: int,
    metric: str = "l2",
    interpret: bool = False,
):
    n, d = codes.shape
    r = neighbors.shape[1]
    qn = queries.shape[0]
    e = expand_width
    kernel = functools.partial(
        _expand_kernel_int8, n_nodes=n, expand_width=e, metric=metric
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(qn, e),
        in_specs=[
            pl.BlockSpec((1, r), lambda qi, ei, fid_ref, fval_ref:
                         (fid_ref[qi * e + ei], 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, d), lambda qi, ei, fid_ref, fval_ref: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, r), lambda qi, ei, fid_ref, fval_ref: (qi, ei)),
            pl.BlockSpec((1, r), lambda qi, ei, fid_ref, fval_ref: (qi, ei)),
            pl.BlockSpec((1, 1), lambda qi, ei, fid_ref, fval_ref: (qi, ei)),
        ],
        scratch_shapes=[
            pltpu.VMEM((r, d), jnp.int8),
            pltpu.VMEM((r, 3), jnp.float32),
            pltpu.VMEM((e * r,), jnp.int32),
            pltpu.SemaphoreType.DMA,
        ],
    )
    ids, dists, cnts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, e * r), jnp.int32),
            jax.ShapeDtypeStruct((qn, e * r), jnp.float32),
            jax.ShapeDtypeStruct((qn, e), jnp.int32),
        ],
        interpret=interpret,
    )(fid, fval, neighbors, codes, meta, queries)
    return ids, dists, jnp.sum(cnts, axis=1)


def expand_pallas(
    points: jnp.ndarray,     # (N, d)
    neighbors: jnp.ndarray,  # (N, R) int32
    fid: jnp.ndarray,        # (Q*E,) int32, pre-clamped to [0, N)
    fval: jnp.ndarray,       # (Q*E,) int32 validity flags
    queries: jnp.ndarray,    # (Q, d)
    *,
    expand_width: int,
    metric: str = "l2",
    interpret: bool = False,
):
    n, d = points.shape
    r = neighbors.shape[1]
    qn = queries.shape[0]
    e = expand_width
    kernel = functools.partial(
        _expand_kernel, n_nodes=n, expand_width=e, metric=metric
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(qn, e),
        in_specs=[
            pl.BlockSpec((1, r), lambda qi, ei, fid_ref, fval_ref:
                         (fid_ref[qi * e + ei], 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, d), lambda qi, ei, fid_ref, fval_ref: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, r), lambda qi, ei, fid_ref, fval_ref: (qi, ei)),
            pl.BlockSpec((1, r), lambda qi, ei, fid_ref, fval_ref: (qi, ei)),
            pl.BlockSpec((1, 1), lambda qi, ei, fid_ref, fval_ref: (qi, ei)),
        ],
        scratch_shapes=[
            pltpu.VMEM((r, d), points.dtype),
            pltpu.VMEM((e * r,), jnp.int32),
            pltpu.SemaphoreType.DMA,
        ],
    )
    ids, dists, cnts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, e * r), jnp.int32),
            jax.ShapeDtypeStruct((qn, e * r), jnp.float32),
            jax.ShapeDtypeStruct((qn, e), jnp.int32),
        ],
        interpret=interpret,
    )(fid, fval, neighbors, points, queries)
    return ids, dists, jnp.sum(cnts, axis=1)
