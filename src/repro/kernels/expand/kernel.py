"""Pallas TPU kernel: fused multi-node frontier expansion.

One grid step expands one (query, frontier-node) pair: it pulls the node's
adjacency row into VMEM via a scalar-prefetch-driven BlockSpec, DMA-gathers
the R neighbor vectors straight from the corpus in HBM (``pltpu.ANY`` — the
corpus never materializes as a gathered (E, R, d) tensor in XLA), computes
all R distances in one MXU matmul against the query, and masks duplicate
neighbor ids against every earlier row of the same query's E*R tile in the
same pass. This fuses what the unfused path does as four XLA ops
(``out_neighbors`` gather + vector gather + distance + three broadcast
dedups) into a single pipelined kernel.

Layout:

* grid ``(Q, E)`` — E innermost, so the steps of one query run back to back
  and the per-query dedup tile in scratch is valid (the grid must stay
  sequential; do not mark these dimensions parallel).
* scalar prefetch: flattened frontier ids (clamped) + validity flags. The
  adjacency BlockSpec indexes rows directly off the prefetched ids, so the
  HBM->VMEM row DMA for step i+1 issues while step i computes.
* the neighbor-vector gather is a manual ``make_async_copy`` loop into a
  (R, d) VMEM scratch (the paged-attention pattern): BlockSpecs cannot
  express a data-dependent gather, DMAs can.
* distances: ``x @ q`` on the MXU (f32 accumulation), plus rank-1 norm
  corrections for L2. A bf16-stored corpus is gathered in bf16 (halving the
  dominant HBM term) and cast to f32 only in VMEM.
* dedup: the kernel keeps the tile's surviving ids in a persistent
  (E*R,) VMEM scratch; each row masks against all earlier rows plus itself
  (first occurrence wins), exactly matching ``ref.expand_frontier_ref``.

VMEM per step (f32 corpus, defaults E=4, R=64, d=128): adjacency row
``4R`` B + vector scratch ``R*d*4`` = 32 KiB + dedup tile ``E*R*4`` = 1 KiB
+ query row ``4d`` + out blocks ``8R`` — well under the 16 MiB budget; the
vector scratch dominates and scales as ``R*d*itemsize``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...utils import INVALID_ID


def _expand_kernel(
    fid_ref,    # (Q*E,) int32 scalar-prefetch: clamped frontier ids
    fval_ref,   # (Q*E,) int32 scalar-prefetch: frontier validity flags
    adj_ref,    # (1, R) the frontier node's adjacency row
    pts_ref,    # (N, d) corpus, ANY/HBM — gathered by manual DMA
    q_ref,      # (1, d) the query row
    ids_ref,    # (1, R) int32 out: deduped neighbor ids
    dist_ref,   # (1, R) f32 out: distances (+inf where masked)
    cnt_ref,    # (1, 1) int32 out: distances computed (pre-dedup)
    vec_ref,    # (R, d) VMEM scratch: gathered neighbor vectors
    tile_ref,   # (E*R,) int32 VMEM scratch: per-query surviving-id tile
    sem,        # DMA semaphore
    *,
    n_nodes: int,
    expand_width: int,
    metric: str,
):
    qi = pl.program_id(0)
    e = pl.program_id(1)
    i = qi * expand_width + e

    @pl.when(e == 0)
    def _reset_tile():
        tile_ref[...] = jnp.full_like(tile_ref, INVALID_ID)

    adj = adj_ref[0, :]                       # (R,) neighbor ids
    n_ok = (adj >= 0) & (adj < n_nodes)
    safe = jnp.where(n_ok, adj, 0)

    def gather(r, _):
        cp = pltpu.make_async_copy(pts_ref.at[safe[r]], vec_ref.at[r], sem)
        cp.start()
        cp.wait()
        return 0

    jax.lax.fori_loop(0, adj.shape[0], gather, 0)

    x = vec_ref[...].astype(jnp.float32)      # (R, d)
    q = q_ref[0, :].astype(jnp.float32)       # (d,)
    dots = jax.lax.dot_general(
        x, q[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]                                   # (R,) MXU
    if metric == "l2":
        xn = jnp.sum(x * x, axis=1)
        qn = jnp.sum(q * q)
        dist = jnp.maximum(xn + qn - 2.0 * dots, 0.0)
    else:  # ip
        dist = -dots

    # dedup: earlier rows of this query's tile, then first-in-row wins
    prev = tile_ref[...]                      # (E*R,)
    seen_prev = jnp.any(adj[:, None] == prev[None, :], axis=1)
    rr = jnp.arange(adj.shape[0])
    dup_row = jnp.any(
        (adj[:, None] == adj[None, :]) & (rr[None, :] < rr[:, None])
        & n_ok[:, None] & n_ok[None, :],
        axis=1,
    )
    f_ok = fval_ref[i] > 0
    keep = n_ok & (~seen_prev) & (~dup_row) & f_ok

    kept = jnp.where(keep, adj, INVALID_ID)
    ids_ref[0, :] = kept
    dist_ref[0, :] = jnp.where(keep, dist, jnp.inf)
    cnt_ref[0, 0] = jnp.sum((n_ok & f_ok).astype(jnp.int32))
    tile_ref[pl.ds(e * adj.shape[0], adj.shape[0])] = kept


def expand_pallas(
    points: jnp.ndarray,     # (N, d)
    neighbors: jnp.ndarray,  # (N, R) int32
    fid: jnp.ndarray,        # (Q*E,) int32, pre-clamped to [0, N)
    fval: jnp.ndarray,       # (Q*E,) int32 validity flags
    queries: jnp.ndarray,    # (Q, d)
    *,
    expand_width: int,
    metric: str = "l2",
    interpret: bool = False,
):
    n, d = points.shape
    r = neighbors.shape[1]
    qn = queries.shape[0]
    e = expand_width
    kernel = functools.partial(
        _expand_kernel, n_nodes=n, expand_width=e, metric=metric
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(qn, e),
        in_specs=[
            pl.BlockSpec((1, r), lambda qi, ei, fid_ref, fval_ref:
                         (fid_ref[qi * e + ei], 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((1, d), lambda qi, ei, fid_ref, fval_ref: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, r), lambda qi, ei, fid_ref, fval_ref: (qi, ei)),
            pl.BlockSpec((1, r), lambda qi, ei, fid_ref, fval_ref: (qi, ei)),
            pl.BlockSpec((1, 1), lambda qi, ei, fid_ref, fval_ref: (qi, ei)),
        ],
        scratch_shapes=[
            pltpu.VMEM((r, d), points.dtype),
            pltpu.VMEM((e * r,), jnp.int32),
            pltpu.SemaphoreType.DMA,
        ],
    )
    ids, dists, cnts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, e * r), jnp.int32),
            jax.ShapeDtypeStruct((qn, e * r), jnp.float32),
            jax.ShapeDtypeStruct((qn, e), jnp.int32),
        ],
        interpret=interpret,
    )(fid, fval, neighbors, points, queries)
    return ids, dists, jnp.sum(cnts, axis=1)
