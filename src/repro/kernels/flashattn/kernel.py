"""Pallas TPU kernel: flash attention forward (GQA, sliding window, soft-cap).

The LM serving hot spot (prefill 32k, decode over 500k KV). Online-softmax
tiling (FlashAttention), with the features the assigned LM archs need:

* GQA head grouping (gemma3 32H/kv16, qwen3 40H/kv8, starcoder2 36H/kv4):
  the kv head index for query head h is ``h // (Hq // Hkv)`` — folded into
  the kv BlockSpec index_map so each query-head grid lane streams the right
  kv head with no materialized repeat.
* causal masking against absolute positions (supports ``q_offset`` for
  decode, where the query block sits at position ``kv_len - q_len``).
* sliding-window mask (gemma3 local layers: window 1024, 5:1 local:global).
* logit soft-cap ``cap * tanh(s / cap)`` (gemma-family).

Grid ``(B * Hq, nq, nk)`` with nk innermost; running max/denominator/accum
live in VMEM scratch across the kv sweep; the output block is written on the
final kv step. kv blocks beyond the causal frontier are masked (XLA-level
skip of fully-masked blocks is a real-TPU optimization left to the
``block_until`` index bound below).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,    # (1, bq, dh)
    k_ref,    # (1, bk, dh)
    v_ref,    # (1, bk, dh)
    o_ref,    # (1, bq, dh)
    m_ref,    # (bq,) scratch
    l_ref,    # (bq,) scratch
    acc_ref,  # (bq, dh) scratch
    *,
    scale: float,
    causal: bool,
    window: int,         # <=0 means no sliding window
    softcap: float,      # <=0 means no soft cap
    q_offset: int,       # absolute position of query row 0
    block_q: int,
    block_k: int,
    kv_len: int,
):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale       # (bq, dh)
    k = k_ref[0].astype(jnp.float32)               # (bk, dh)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = q_offset + pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    # fully-masked rows: keep p at 0 (exp(NEG_INF - m) underflows to 0 safely)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Hq, Sq, dh)
    k: jnp.ndarray,  # (B, Hkv, Skv, dh)
    v: jnp.ndarray,  # (B, Hkv, Skv, dh)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = dh ** -0.5 if scale is None else scale

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    sq_pad = -(-sq // bq) * bq
    skv_pad = -(-skv // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))

    qf = qp.reshape(b * hq, sq_pad, dh)
    kf = kp.reshape(b * hkv, skv_pad, dh)
    vf = vp.reshape(b * hkv, skv_pad, dh)

    grid = (b * hq, sq_pad // bq, skv_pad // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, block_q=bq, block_k=bk, kv_len=skv,
    )

    def kv_map(h, i, j):
        return (h // group) if group > 1 else h

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j: (kv_map(h, i, j), j, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j: (kv_map(h, i, j), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_pad, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq_pad, dh)[:, :, :sq]
