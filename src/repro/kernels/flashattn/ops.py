"""jit'd public wrapper for flash attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import flash_attention_ref


@partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "q_offset", "scale",
    "block_q", "block_k", "use_pallas", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # (B, Hq, Sq, dh)
    k: jnp.ndarray,  # (B, Hkv, Skv, dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: bool = True,
    interpret: bool = True,  # CPU default; set False on real TPU
) -> jnp.ndarray:
    if not use_pallas:
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap, q_offset=q_offset, scale=scale)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  softcap=softcap, q_offset=q_offset, scale=scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
