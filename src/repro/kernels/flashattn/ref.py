"""Pure-jnp oracle for flash attention (GQA + window + soft-cap)."""
from __future__ import annotations

import jax.numpy as jnp


def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: int = 0, softcap: float = 0.0,
    q_offset: int = 0, scale: float | None = None,
):
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = dh ** -0.5 if scale is None else scale
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
