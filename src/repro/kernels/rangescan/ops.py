"""jit'd public wrapper for the rangescan kernel (padding + dispatch)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...utils import round_up
from .kernel import rangescan_pallas
from .ref import rangescan_ref


@partial(jax.jit, static_argnames=("k", "block_q", "block_n", "metric", "use_pallas", "interpret"))
def rangescan(
    queries: jnp.ndarray,   # (Q, d)
    points: jnp.ndarray,    # (N, d)
    r: jnp.ndarray,
    *,
    k: int = 128,
    block_q: int = 128,
    block_n: int = 512,
    metric: str = "l2",
    use_pallas: bool = True,
    interpret: bool = True,  # CPU default; set False on real TPU
):
    """Fused exact range scan: (ids (Q,k), dists (Q,k), counts (Q,)).

    ``use_pallas=False`` routes to the pure-jnp oracle (the XLA path used for
    dry-run lowering, where Pallas TPU custom calls are unavailable on the
    CPU host platform).
    """
    if not use_pallas:
        return rangescan_ref(queries, points, r, k=k, metric=metric)
    qn, d = queries.shape
    n, _ = points.shape
    bq = min(block_q, max(8, qn))
    qp = round_up(qn, bq)
    np_ = round_up(n, block_n)
    q_pad = jnp.pad(queries, ((0, qp - qn), (0, 0)))
    x_pad = jnp.pad(points, ((0, np_ - n), (0, 0)))
    ids, dists, counts = rangescan_pallas(
        q_pad, x_pad, r, n_total=n, k=k, block_q=bq, block_n=block_n,
        metric=metric, interpret=interpret,
    )
    return ids[:qn], dists[:qn], counts[:qn]
