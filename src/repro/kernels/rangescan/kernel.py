"""Pallas TPU kernel: tiled exact range scan.

The paper's exact-distance hot spot: score every (query, point) pair, count
in-range matches, and keep the K closest in-range candidates. This is the
compute core of ground-truth generation, brute-force range search, and the
``retrieval_cand`` recsys shape (1 query x 1M candidates).

TPU mapping (DESIGN.md §7):

* grid ``(Q/bq, N/bn)`` — the N axis is innermost so each query tile's
  accumulators live in the *output blocks* across the N sweep (revisited
  blocks are kept in VMEM between grid steps on TPU).
* the distance tile is one MXU matmul: ``-2 * q @ x^T`` plus rank-1 norm
  corrections for L2 (skipped for IP, where distance is just ``-q @ x^T``).
* in-range count is a masked row-sum accumulated into ``counts``.
* the bounded top-K collect avoids sort/scatter (unsupported on the TPU
  vector unit) — it merges the running K-buffer with the tile's candidates
  via a ``fori_loop`` of argmin+one-hot-mask steps: every step extracts the
  current minimum and masks it with an iota comparison. O(K * (K + bn))
  comparisons per tile, all VPU-legal ops.

VMEM budget per grid step (f32): q tile ``bq*d``, x tile ``bn*d``, distance
tile ``bq*bn``, buffers ``2*bq*K``. Defaults (bq=128, bn=512, d<=1536,
K=128) stay well under 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...utils import INVALID_ID

NEG_INF = float("-inf")


def _merge_topk(buf_d, buf_i, cand_d, cand_i, k: int):
    """Merge (bq, K) buffer with (bq, bn) candidates -> new sorted-K buffer.

    Sort/scatter-free: K rounds of (argmin -> one-hot mask -> column write).
    All candidates with non-finite distance are ignored.
    """
    bq = buf_d.shape[0]
    merged_d = jnp.concatenate([buf_d, cand_d], axis=1)  # (bq, M)
    merged_i = jnp.concatenate([buf_i, cand_i], axis=1)
    m = merged_d.shape[1]
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (bq, m), 1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (bq, k), 1)

    def body(t, carry):
        taken, out_d, out_i = carry
        d = jnp.where(taken, jnp.inf, merged_d)
        j = jnp.argmin(d, axis=1)  # (bq,)
        dmin = jnp.min(d, axis=1)  # (bq,)
        onehot = iota_m == j[:, None]
        imin = jnp.sum(jnp.where(onehot, merged_i, 0), axis=1)
        imin = jnp.where(jnp.isfinite(dmin), imin, INVALID_ID)
        taken = taken | onehot
        col = iota_k == t
        out_d = jnp.where(col, dmin[:, None], out_d)
        out_i = jnp.where(col, imin[:, None], out_i)
        return taken, out_d, out_i

    taken0 = jnp.zeros((bq, m), dtype=jnp.bool_)
    out_d0 = jnp.full((bq, k), jnp.inf, jnp.float32)
    out_i0 = jnp.full((bq, k), INVALID_ID, jnp.int32)
    _, out_d, out_i = jax.lax.fori_loop(0, k, body, (taken0, out_d0, out_i0))
    return out_d, out_i


def _rangescan_kernel(
    r_ref,      # (1, 1) f32 in SMEM-like block: the radius
    q_ref,      # (bq, d)
    x_ref,      # (bn, d)
    counts_ref, # (bq,) int32 out, accumulated over the N sweep
    topd_ref,   # (bq, K) f32 out
    topi_ref,   # (bq, K) int32 out
    *,
    n_total: int,
    block_n: int,
    k: int,
    metric: str,
):
    j = pl.program_id(1)

    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    dots = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bn) MXU
    if metric == "l2":
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        xn = jnp.sum(x * x, axis=1, keepdims=True)
        dist = jnp.maximum(qn + xn.T - 2.0 * dots, 0.0)
    else:  # ip
        dist = -dots

    bq, bn = dist.shape
    col = j * block_n + jax.lax.broadcasted_iota(jnp.int32, (bq, bn), 1)
    valid = col < n_total
    r = r_ref[0, 0]
    ok = (dist <= r) & valid

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        topd_ref[...] = jnp.full_like(topd_ref, jnp.inf)
        topi_ref[...] = jnp.full_like(topi_ref, INVALID_ID)

    counts_ref[...] += jnp.sum(ok, axis=1).astype(jnp.int32)

    cand_d = jnp.where(ok, dist, jnp.inf)
    cand_i = jnp.where(ok, col, INVALID_ID)
    new_d, new_i = _merge_topk(topd_ref[...], topi_ref[...], cand_d, cand_i, k)
    topd_ref[...] = new_d
    topi_ref[...] = new_i


def rangescan_pallas(
    queries: jnp.ndarray,  # (Q, d)
    points: jnp.ndarray,   # (N, d); caller pads N to block_n multiple
    r: jnp.ndarray,        # () f32
    *,
    n_total: int,
    k: int = 128,
    block_q: int = 128,
    block_n: int = 512,
    metric: str = "l2",
    interpret: bool = False,
):
    qn, d = queries.shape
    n, _ = points.shape
    assert qn % block_q == 0 and n % block_n == 0
    grid = (qn // block_q, n // block_n)
    kernel = functools.partial(
        _rangescan_kernel, n_total=n_total, block_n=block_n, k=k, metric=metric
    )
    r_arr = jnp.asarray(r, jnp.float32).reshape(1, 1)
    counts, topd, topi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),       # radius
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),  # queries
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),  # points
        ],
        out_specs=[
            pl.BlockSpec((block_q,), lambda i, j: (i,)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn,), jnp.int32),
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ],
        interpret=interpret,
    )(r_arr, queries, points)
    return topi, topd, counts
