from .kernel import rangescan_pallas
from .ops import rangescan
from .ref import rangescan_ref

__all__ = ["rangescan", "rangescan_pallas", "rangescan_ref"]
