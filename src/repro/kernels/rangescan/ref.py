"""Pure-jnp oracle for the rangescan kernel."""
from __future__ import annotations

import jax.numpy as jnp

from ...utils import INVALID_ID


def rangescan_ref(queries, points, r, *, k: int = 128, metric: str = "l2"):
    """(ids (Q,k), dists (Q,k), counts (Q,)) — exact, unblocked."""
    q = queries.astype(jnp.float32)
    x = points.astype(jnp.float32)
    dots = q @ x.T
    if metric == "l2":
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        xn = jnp.sum(x * x, axis=1, keepdims=True)
        dist = jnp.maximum(qn + xn.T - 2.0 * dots, 0.0)
    else:
        dist = -dots
    ok = dist <= jnp.asarray(r, jnp.float32)
    counts = jnp.sum(ok, axis=1).astype(jnp.int32)
    masked = jnp.where(ok, dist, jnp.inf)
    idx = jnp.argsort(masked, axis=1, stable=True)[:, :k]
    d_sorted = jnp.take_along_axis(masked, idx, axis=1)
    ids = jnp.where(jnp.isfinite(d_sorted), idx.astype(jnp.int32), INVALID_ID)
    return ids, d_sorted, counts
