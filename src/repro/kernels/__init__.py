"""Pallas TPU kernels for the framework's compute hot spots.

Four kernels (DESIGN.md §7), each with ``kernel.py`` (pallas_call +
BlockSpec), ``ops.py`` (jit wrapper with an XLA fallback), ``ref.py``
(pure-jnp oracle):

* ``rangescan``  — tiled exact range scan (fused MXU distance + in-range
  count + bounded top-K collect). Ground truth, brute force,
  ``retrieval_cand``.
* ``gatherdist`` — scalar-prefetch row gather + fused distance (beam
  expansion's irregular memory pattern).
* ``expand``     — fused multi-node frontier expansion: adjacency gather +
  neighbor-vector DMA gather + MXU distances + one-pass tile dedup (the
  search loop's per-iteration hot path).
* ``flashattn``  — flash attention fwd with GQA, sliding window, soft-cap
  (LM serving).

CPU tests run ``interpret=True``; dry-run lowering uses the XLA fallback
(``use_pallas=False``) since Pallas TPU custom calls don't lower on the CPU
host platform.
"""
from .expand import expand_frontier, expand_frontier_ref
from .flashattn import flash_attention, flash_attention_ref
from .gatherdist import gatherdist, gatherdist_ref
from .rangescan import rangescan, rangescan_ref

__all__ = [
    "expand_frontier", "expand_frontier_ref",
    "flash_attention", "flash_attention_ref",
    "gatherdist", "gatherdist_ref",
    "rangescan", "rangescan_ref",
]
