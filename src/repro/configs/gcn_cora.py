"""gcn-cora [gnn] — 2 layers, d_hidden=16, aggregator=mean (symmetric
normalization), Cora geometry (2708 nodes, 1433 features, 7 classes).
[arXiv:1609.02907; paper]
"""

from ..dist.sharding import GNN_RULES
from ..models.gcn import GCNConfig
from ..optim.adamw import AdamWConfig
from .common import ArchSpec, gnn_shapes


def reduced() -> GCNConfig:
    return GCNConfig(name="gcn-smoke", n_layers=2, d_feat=32, d_hidden=16,
                     n_classes=5)


ARCH = ArchSpec(
    arch_id="gcn-cora",
    family="gnn",
    model_cfg=GCNConfig(name="gcn-cora", n_layers=2, d_feat=1433,
                        d_hidden=16, n_classes=7, agg="mean", sym_norm=True),
    shapes=gnn_shapes(),
    rules=GNN_RULES,
    opt_cfg=AdamWConfig(lr=1e-2, weight_decay=5e-4, total_steps=200,
                        warmup_steps=0, schedule="constant"),
    source="arXiv:1609.02907 (Kipf & Welling GCN); paper tier",
    technique_note=(
        "GNN: technique DIRECTLY applicable at the data level — "
        "data.graphs.range_graph_dataset builds the input graph with the "
        "paper's own k-NN/range engine (DESIGN.md §6)."),
    reduced=reduced,
)
