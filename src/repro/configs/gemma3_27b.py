"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144; 5:1 local:global sliding window (1024), dual rope theta
(10k local / 1M global), qk-norm, sandwich norms, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
import jax.numpy as jnp

from ..dist.sharding import LM_RULES
from ..models.transformer import TransformerConfig
from ..optim.adamw import AdamWConfig
from .common import ArchSpec, lm_shapes


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="gemma3-smoke", n_layers=6, d_model=64, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=512, window=16, local_ratio=5,
        rope_theta=1_000_000.0, rope_theta_local=10_000.0, qk_norm=True,
        sandwich_norm=True, embed_scale=True, dtype=jnp.float32,
        remat=False, loss_chunk=32)


ARCH = ArchSpec(
    arch_id="gemma3-27b",
    family="lm",
    model_cfg=TransformerConfig(
        name="gemma3-27b", n_layers=62, d_model=5376, n_heads=32, n_kv=16,
        d_head=128, d_ff=21504, vocab=262_144, window=1024, local_ratio=5,
        rope_theta=1_000_000.0, rope_theta_local=10_000.0, qk_norm=True,
        sandwich_norm=True, embed_scale=True, tie_embeddings=True,
        dtype=jnp.bfloat16, remat=True, loss_chunk=512,
        attn_chunk=1024),
    shapes=lm_shapes(),
    rules=LM_RULES,
    opt_cfg=AdamWConfig(lr=3e-4, total_steps=100_000, warmup_steps=2_000),
    source="hf:google/gemma-3 family (27b geometry); unverified tier",
    technique_note=(
        "LM: range engine applies as downstream embedding consumer only "
        "(DESIGN.md §6); long_500k runs as decode with the 5:1 local:global "
        "sub-quadratic pattern."),
    reduced=reduced,
)
