"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936; qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]
"""
import jax.numpy as jnp

from ..dist.sharding import LM_RULES
from ..models.transformer import TransformerConfig
from ..optim.adamw import AdamWConfig
from .common import ArchSpec, lm_shapes


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-smoke", n_layers=4, d_model=64, n_heads=8, n_kv=2,
        d_head=16, d_ff=160, vocab=512, qk_norm=True, tie_embeddings=False,
        dtype=jnp.float32, remat=False, loss_chunk=32)


ARCH = ArchSpec(
    arch_id="qwen3-14b",
    family="lm",
    model_cfg=TransformerConfig(
        name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40, n_kv=8,
        d_head=128, d_ff=17408, vocab=151_936, rope_theta=1_000_000.0,
        qk_norm=True, tie_embeddings=False, dtype=jnp.bfloat16, remat=True,
        loss_chunk=512, attn_chunk=1024),
    shapes=lm_shapes(),
    rules=LM_RULES,
    opt_cfg=AdamWConfig(lr=3e-4, total_steps=100_000, warmup_steps=2_000),
    source="hf:Qwen/Qwen3 family (14b geometry); hf tier",
    technique_note=(
        "LM: technique inapplicable inside the model (full attention, "
        "no retrieval structure); long_500k lowered as decode (O(kv) per "
        "step) — pure-full-attention caveat noted in DESIGN.md §6."),
    reduced=reduced,
)
