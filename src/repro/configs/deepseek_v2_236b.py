"""deepseek-v2-236b [moe] — 60L d_model=5120 128H, MLA (kv_lora=512,
q_lora=1536, rope_dim=64), MoE 160 routed top-6 + 2 shared (d_expert=1536),
first layer dense (d_ff=12288), vocab=102400. [arXiv:2405.04434; hf]

Memory note: 236B params train on 256 v5e chips only with bf16 parameter
storage (fp32 moments): 0.47 TB params + 1.9 TB moments + 0.47 TB grads =
~11 GB/chip — verified by the dry-run memory_analysis.
"""
import jax.numpy as jnp

from ..dist.sharding import LM_RULES
from ..models.transformer import TransformerConfig
from ..optim.adamw import AdamWConfig
from .common import ArchSpec, lm_shapes


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-smoke", n_layers=3, d_model=64, n_heads=4,
        attn_kind="mla", q_lora=32, kv_lora=16, qk_nope_dim=16,
        qk_rope_dim=8, v_head_dim=16, d_ff=128, n_experts=8, n_shared=2,
        top_k=2, d_expert=32, first_dense=1, vocab=512,
        capacity_factor=8.0,  # drop-free at smoke scale (decode parity)
        dtype=jnp.float32, remat=False, loss_chunk=32)


ARCH = ArchSpec(
    arch_id="deepseek-v2-236b",
    family="lm",
    model_cfg=TransformerConfig(
        name="deepseek-v2-236b", n_layers=60, d_model=5120, n_heads=128,
        attn_kind="mla", q_lora=1536, kv_lora=512, qk_nope_dim=128,
        qk_rope_dim=64, v_head_dim=128, d_ff=12288, n_experts=160,
        n_shared=2, top_k=6, d_expert=1536, first_dense=1, moe_groups=32,
        capacity_factor=1.25, vocab=102_400, rope_theta=10_000.0,
        tie_embeddings=False, dtype=jnp.bfloat16, remat=True, loss_chunk=512,
        attn_chunk=1024),
    shapes=lm_shapes(),
    rules=LM_RULES,
    param_dtype=jnp.bfloat16,
    accum_steps=4,
    opt_cfg=AdamWConfig(lr=2.4e-4, total_steps=100_000, warmup_steps=2_000,
                    moment_dtype=jnp.bfloat16, accum_dtype=jnp.bfloat16),
    source="arXiv:2405.04434 (DeepSeek-V2); hf tier",
    technique_note=(
        "MoE LM: expert top-k routing is a selection over 160 experts — "
        "unrelated scale to ANNS; technique inapplicable inside the model "
        "(DESIGN.md §6). MLA cache (512+64 dims/token) is what makes the "
        "long_500k decode cell cheap."),
    reduced=reduced,
)
