"""starcoder2-7b [dense] — 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152; GQA + RoPE, classic (non-gated) GELU FFN. [arXiv:2402.19173; hf]
"""
import jax.numpy as jnp

from ..dist.sharding import LM_RULES
from ..models.transformer import TransformerConfig
from ..optim.adamw import AdamWConfig
from .common import ArchSpec, lm_shapes


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="starcoder2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv=1,
        d_head=16, d_ff=256, ffn_gated=False, ffn_act="gelu", vocab=512,
        dtype=jnp.float32, remat=False, loss_chunk=32)


ARCH = ArchSpec(
    arch_id="starcoder2-7b",
    family="lm",
    model_cfg=TransformerConfig(
        name="starcoder2-7b", n_layers=32, d_model=4608, n_heads=36, n_kv=4,
        d_head=128, d_ff=18432, ffn_gated=False, ffn_act="gelu",
        vocab=49_152, rope_theta=100_000.0, tie_embeddings=True,
        dtype=jnp.bfloat16, remat=True, loss_chunk=512,
        attn_chunk=1024),
    shapes=lm_shapes(),
    rules=LM_RULES,
    opt_cfg=AdamWConfig(lr=3e-4, total_steps=100_000, warmup_steps=2_000),
    source="arXiv:2402.19173 (StarCoder2-7B); hf tier",
    technique_note=(
        "LM: technique inapplicable inside the model; code-embedding "
        "outputs are natural range-engine corpora (duplicate detection "
        "is a headline range-retrieval application)."),
    reduced=reduced,
)
