"""two-tower-retrieval [recsys] — embed_dim=256, tower_mlp=1024-512-256,
dot interaction, sampled-softmax retrieval. [RecSys'19 (YouTube); unverified]

THE paper's home-turf architecture: the item tower's embeddings are the
corpus the range engine indexes; ``retrieval_cand`` is served both by brute
force (rangescan kernel) and through the graph-based range engine — this
cell is one of the three hillclimb candidates (DESIGN.md §6).
"""

from ..dist.sharding import RECSYS_RULES
from ..models.recsys import RecsysConfig
from ..optim.adamw import AdamWConfig
from .common import ArchSpec, recsys_shapes


def reduced() -> RecsysConfig:
    return RecsysConfig(name="two-tower-smoke", kind="two_tower",
                        n_sparse=4, n_sparse_item=4, vocab=1_000,
                        d_embed=16, mlp_dims=(64, 32), d_out=32)


ARCH = ArchSpec(
    arch_id="two-tower-retrieval",
    family="recsys",
    model_cfg=RecsysConfig(
        name="two-tower-retrieval", kind="two_tower", n_sparse=16,
        n_sparse_item=16, vocab=10_485_760, d_embed=64,
        mlp_dims=(1024, 512), d_out=256),
    shapes=recsys_shapes(),
    rules=RECSYS_RULES,
    opt_cfg=AdamWConfig(lr=1e-3, total_steps=50_000, warmup_steps=1_000),
    source="Yi et al., RecSys'19 (YouTube two-tower); unverified tier",
    technique_note=(
        "DIRECT integration: item-tower output embeddings feed "
        "core.RangeSearchEngine; retrieval_cand = rangescan kernel "
        "(brute force) or graph engine (sub-linear)."),
    reduced=reduced,
)
