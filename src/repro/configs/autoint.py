"""autoint [recsys] — 39 sparse fields, embed_dim=16, 3 interacting
self-attention layers (2 heads, d_attn=32). [arXiv:1810.11921; paper]
"""

from ..dist.sharding import RECSYS_RULES
from ..models.recsys import RecsysConfig
from ..optim.adamw import AdamWConfig
from .common import ArchSpec, recsys_shapes


def reduced() -> RecsysConfig:
    return RecsysConfig(name="autoint-smoke", kind="autoint", n_sparse=6,
                        vocab=1_000, d_embed=8, attn_layers=2, attn_heads=2,
                        d_attn=16, mlp_dims=())


ARCH = ArchSpec(
    arch_id="autoint",
    family="recsys",
    model_cfg=RecsysConfig(
        name="autoint", kind="autoint", n_sparse=39, vocab=1_048_576,
        d_embed=16, attn_layers=3, attn_heads=2, d_attn=32, mlp_dims=()),
    shapes=recsys_shapes(),
    rules=RECSYS_RULES,
    opt_cfg=AdamWConfig(lr=1e-3, total_steps=50_000, warmup_steps=1_000),
    source="arXiv:1810.11921 (AutoInt); paper tier",
    technique_note="CTR scorer: technique inapplicable inside the model.",
    reduced=reduced,
)
