"""ArchSpec / ShapeSpec: the (architecture x input-shape) cell definitions.

Every assigned architecture ships one module in this package exporting
``ARCH`` (exact published config) and ``reduced()`` (CPU-smoke version of
the same family). ``launch/steps.py`` turns (ARCH, shape) into a concrete
jit-able step function + ShapeDtypeStruct inputs + shardings — the unit the
multi-pod dry-run lowers and the roofline analyses.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp

from ..optim.adamw import AdamWConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode | serve | bulk | retrieval |
                         # graph_full | graph_sampled | graph_batched
    seq_len: int = 0
    global_batch: int = 0
    n_candidates: int = 0
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: tuple = ()
    n_graphs: int = 0
    nodes_per_graph: int = 0
    edges_per_graph: int = 0
    notes: str = ""


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                        # lm | gnn | recsys
    model_cfg: Any
    shapes: dict[str, ShapeSpec]
    rules: Any                         # sharding Rule list
    param_dtype: Any = jnp.float32     # storage dtype (bf16 for the 236B)
    accum_steps: int = 1               # grad-accumulation microbatches for
                                       # train cells (fits-in-HBM knob; the
                                       # FSDP gathers repeat per microbatch,
                                       # so only set where memory demands)
    opt_cfg: AdamWConfig = AdamWConfig()
    source: str = ""
    technique_note: str = ""           # paper-technique applicability
    reduced: Optional[Callable[[], Any]] = None  # smoke-size config factory


# The four LM shapes shared by all five LM archs (brief).
def lm_shapes() -> dict[str, ShapeSpec]:
    return {
        "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32_768, global_batch=32),
        "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32_768, global_batch=128),
        "long_500k": ShapeSpec(
            "long_500k", "decode", seq_len=524_288, global_batch=1,
            notes="decode lowering: O(kv_len) per step for every attention "
                  "kind (DESIGN.md §6); gemma3 additionally has 5:1 "
                  "local:global sub-quadratic structure"),
    }


def recsys_shapes() -> dict[str, ShapeSpec]:
    return {
        "train_batch": ShapeSpec("train_batch", "train", global_batch=65_536),
        "serve_p99": ShapeSpec("serve_p99", "serve", global_batch=512),
        "serve_bulk": ShapeSpec("serve_bulk", "serve", global_batch=262_144),
        "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                    global_batch=1, n_candidates=1_000_000),
    }


def gnn_shapes() -> dict[str, ShapeSpec]:
    return {
        "full_graph_sm": ShapeSpec("full_graph_sm", "graph_full",
                                   n_nodes=2_708, n_edges=10_556, d_feat=1_433),
        "minibatch_lg": ShapeSpec("minibatch_lg", "graph_sampled",
                                  n_nodes=232_965, n_edges=114_615_892,
                                  batch_nodes=1_024, fanout=(15, 10), d_feat=602),
        "ogb_products": ShapeSpec("ogb_products", "graph_full",
                                  n_nodes=2_449_029, n_edges=61_859_140, d_feat=100),
        "molecule": ShapeSpec("molecule", "graph_batched", n_graphs=128,
                              nodes_per_graph=30, edges_per_graph=64),
    }
