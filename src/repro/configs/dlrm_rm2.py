"""dlrm-rm2 [recsys] — 13 dense + 26 sparse (embed_dim=64),
bot_mlp 13-512-256-64, top_mlp 512-512-256-1, dot interaction.
[arXiv:1906.00091; paper]
"""

from ..dist.sharding import RECSYS_RULES
from ..models.recsys import RecsysConfig
from ..optim.adamw import AdamWConfig
from .common import ArchSpec, recsys_shapes


def reduced() -> RecsysConfig:
    return RecsysConfig(name="dlrm-smoke", kind="dlrm", n_dense=4,
                        n_sparse=6, vocab=1_000, d_embed=8,
                        bot_mlp_dims=(16, 8), mlp_dims=(32, 16))


ARCH = ArchSpec(
    arch_id="dlrm-rm2",
    family="recsys",
    model_cfg=RecsysConfig(
        name="dlrm-rm2", kind="dlrm", n_dense=13, n_sparse=26,
        vocab=4_194_304, d_embed=64, bot_mlp_dims=(512, 256, 64),
        mlp_dims=(512, 512, 256)),
    shapes=recsys_shapes(),
    rules=RECSYS_RULES,
    opt_cfg=AdamWConfig(lr=1e-3, total_steps=50_000, warmup_steps=1_000),
    source="arXiv:1906.00091 (DLRM, RM2 geometry); paper tier",
    technique_note="CTR scorer: technique inapplicable inside the model; "
                   "row-sharded EmbeddingBag is the substrate exercised.",
    reduced=reduced,
)
