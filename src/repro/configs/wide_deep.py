"""wide-deep [recsys] — 40 sparse fields, embed_dim=32, deep MLP
1024-512-256, concat interaction + linear wide part. [arXiv:1606.07792; paper]
"""

from ..dist.sharding import RECSYS_RULES
from ..models.recsys import RecsysConfig
from ..optim.adamw import AdamWConfig
from .common import ArchSpec, recsys_shapes


def reduced() -> RecsysConfig:
    return RecsysConfig(name="wide-deep-smoke", kind="wide_deep",
                        n_sparse=6, vocab=1_000, d_embed=8,
                        mlp_dims=(32, 16))


ARCH = ArchSpec(
    arch_id="wide-deep",
    family="recsys",
    model_cfg=RecsysConfig(
        name="wide-deep", kind="wide_deep", n_sparse=40,
        vocab=2_097_152, d_embed=32, mlp_dims=(1024, 512, 256)),
    shapes=recsys_shapes(),
    rules=RECSYS_RULES,
    opt_cfg=AdamWConfig(lr=1e-3, total_steps=50_000, warmup_steps=1_000),
    source="arXiv:1606.07792 (Wide & Deep); paper tier",
    technique_note=(
        "CTR scorer: no ANN structure inside the model; retrieval_cand = "
        "bulk candidate scoring. Embedding-bag substrate is the "
        "system-relevant piece (DESIGN.md §6)."),
    reduced=reduced,
)
