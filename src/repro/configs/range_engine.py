"""range-engine — the paper's own system as a config (11th, bonus row).

A production range-retrieval deployment: corpus sharded over the model axis
(one Vamana sub-index per shard), query batches sharded over data — each
query carrying its *own* radius (the radii vector shards with the batch;
serving traffic mixes duplicate-detection-tight and recommendation-wide
thresholds in one micro-batch) — fused single-program search
(beam -> greedy) per cell, union merge. The dry-run lowers the shard_map
program on the 256/512-chip meshes — proving the paper's system itself
distributes, not just the ML architectures around it.
"""
import dataclasses

from ..core.beam_search import SearchConfig
from ..core.range_search import RangeConfig
from ..dist.sharding import Rule
from ..optim.adamw import AdamWConfig
from .common import ArchSpec, ShapeSpec


@dataclasses.dataclass(frozen=True)
class EngineDeployConfig:
    name: str = "range-engine"
    shard_corpus: int = 1_000_000     # points per model-axis shard
    dim: int = 128
    max_degree: int = 32
    metric: str = "l2"
    # corpus storage dtype. "int8" is the production setting for
    # billion-point shards: each shard quantizes locally (core.corpus) and
    # the query path runs the two-pass pipeline — guard-banded approximate
    # search on int8 codes (d + 12 hot bytes/vector vs 4d for f32), exact
    # f32 rerank of the radius boundary band only. (The earlier §Perf C
    # bf16 note still holds for the XLA path: a bare storage cast without
    # the fused kernels *raised* the memory term 1.4x; the int8 pipeline
    # avoids that by dequantizing in-register in both the XLA reference and
    # the Pallas int8 kernels.) Kept f32 here so the dry-run baseline stays
    # comparable across PRs; flip via replace() for the quantized deploy.
    corpus_dtype: str = "float32"
    range_cfg: RangeConfig = dataclasses.field(default_factory=lambda: RangeConfig(
        search=SearchConfig(beam=64, max_beam=64, visit_cap=256,
                            # multi-node frontier expansion; the TPU deploy
                            # additionally flips use_expand_kernel=True (left
                            # False here so the dry-run lowers on host
                            # devices, where Pallas TPU calls don't exist)
                            expand_width=4),
        mode="greedy", result_cap=1024, frontier_rounds=2048))

    def __post_init__(self):
        # keep the declarative SearchConfig knob in lockstep with the
        # deploy-level one (engine cells and builders consult either; the
        # server validates it against the corpus it actually serves). The
        # non-default side wins, so setting EITHER knob to "int8"/"bfloat16"
        # propagates; setting both to conflicting non-defaults is an error,
        # never a silent override.
        s = self.range_cfg.search.corpus_dtype
        if s != self.corpus_dtype:
            if s != "float32" and self.corpus_dtype != "float32":
                raise ValueError(
                    f"corpus_dtype={self.corpus_dtype!r} conflicts with "
                    f"range_cfg.search.corpus_dtype={s!r}")
            unified = s if self.corpus_dtype == "float32" else self.corpus_dtype
            object.__setattr__(self, "corpus_dtype", unified)
            object.__setattr__(self, "range_cfg", dataclasses.replace(
                self.range_cfg, search=dataclasses.replace(
                    self.range_cfg.search, corpus_dtype=unified)))

    def overrides(self, **kw) -> "EngineDeployConfig":
        """One explicit merge point for deploy-time knob changes.

        Each keyword is routed to the level that owns it — an
        ``EngineDeployConfig`` field, a ``RangeConfig`` field, or a
        ``SearchConfig`` field — and a new config is returned with
        everything else untouched. This replaces the scattered ad-hoc
        ``dataclasses.replace`` chains (and the deprecated
        ``ServerConfig.expand_width`` side channel): the deploy config is
        the single source of truth for what the engine serves with.

        Keys owned by two levels resolve top-down (deploy > range >
        search): ``lam`` sets the RangeConfig phase-2 trigger, and the
        cross-level contracts propagate — ``metric`` sets both the deploy
        field and ``search.metric``; ``corpus_dtype`` sets the deploy field
        and ``__post_init__`` syncs it into the search config. Unknown keys
        raise ``TypeError`` (a typo'd override must never silently no-op).
        """
        deploy_f = {f.name for f in dataclasses.fields(EngineDeployConfig)}
        range_f = {f.name for f in dataclasses.fields(RangeConfig)} - {"search"}
        search_f = {f.name for f in dataclasses.fields(SearchConfig)}
        d_kw, r_kw, s_kw = {}, {}, {}
        for k, v in kw.items():
            if k in deploy_f:
                d_kw[k] = v
                if k == "metric":
                    s_kw[k] = v
                if k == "corpus_dtype":
                    s_kw[k] = v  # keep both sides of the post_init contract
            elif k in range_f:
                r_kw[k] = v
            elif k in search_f:
                s_kw[k] = v
            else:
                raise TypeError(f"overrides() got unknown knob {k!r}")
        rc = d_kw.pop("range_cfg", self.range_cfg)
        if s_kw:
            rc = dataclasses.replace(rc, search=dataclasses.replace(
                rc.search, **s_kw))
        if r_kw:
            rc = dataclasses.replace(rc, **r_kw)
        return dataclasses.replace(self, range_cfg=rc, **d_kw)


def reduced() -> EngineDeployConfig:
    return EngineDeployConfig(
        name="range-engine-smoke", shard_corpus=2_000, dim=16, max_degree=8,
        range_cfg=RangeConfig(search=SearchConfig(beam=16, max_beam=16,
                                                  visit_cap=64,
                                                  expand_width=4),
                              mode="greedy", result_cap=128,
                              frontier_rounds=256))


ARCH = ArchSpec(
    arch_id="range-engine",
    family="engine",
    model_cfg=EngineDeployConfig(),
    shapes={
        "search_4k": ShapeSpec("search_4k", "range_search", global_batch=4096,
                               notes="batched online range queries"),
        "search_64k": ShapeSpec("search_64k", "range_search",
                                global_batch=65_536,
                                notes="bulk range search (Szilvasy-style)"),
    },
    rules=[Rule(r".*", ())],
    opt_cfg=AdamWConfig(),
    source="this paper",
    technique_note="the paper's contribution itself",
    reduced=reduced,
)
