"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) MoE 60 routed
top-4 + 4 shared (d_expert=1408), vocab=151936.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
import jax.numpy as jnp

from ..dist.sharding import LM_RULES
from ..models.transformer import TransformerConfig
from ..optim.adamw import AdamWConfig
from .common import ArchSpec, lm_shapes


def reduced() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2moe-smoke", n_layers=3, d_model=48, n_heads=4, n_kv=4,
        d_head=12, d_ff=96, n_experts=10, n_shared=4, top_k=4, d_expert=24,
        vocab=512, capacity_factor=8.0,  # drop-free at smoke scale
        dtype=jnp.float32, remat=False, loss_chunk=32,
        aux_loss_weight=0.001)


ARCH = ArchSpec(
    arch_id="qwen2-moe-a2.7b",
    family="lm",
    model_cfg=TransformerConfig(
        name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
        n_kv=16, d_head=128, d_ff=5632, n_experts=60, n_experts_alloc=64,
        moe_groups=32, n_shared=4, top_k=4,
        d_expert=1408, capacity_factor=1.25, vocab=151_936,
        rope_theta=1_000_000.0, tie_embeddings=False, dtype=jnp.bfloat16,
        remat=True, loss_chunk=512, attn_chunk=1024),
    shapes=lm_shapes(),
    rules=LM_RULES,
    opt_cfg=AdamWConfig(lr=3e-4, total_steps=100_000, warmup_steps=2_000),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf tier",
    technique_note="MoE LM: technique inapplicable inside the model "
                   "(DESIGN.md §6).",
    reduced=reduced,
)
