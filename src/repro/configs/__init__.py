"""Architecture registry: the 10 assigned archs + the paper's own engine."""
from . import (
    autoint, deepseek_v2_236b, dlrm_rm2, gcn_cora, gemma3_27b, qwen2_moe_a27b,
    qwen3_14b, range_engine, starcoder2_7b, two_tower_retrieval, wide_deep,
)
from .common import ArchSpec, ShapeSpec

_MODULES = [
    gemma3_27b, qwen3_14b, starcoder2_7b, deepseek_v2_236b, qwen2_moe_a27b,
    gcn_cora, two_tower_retrieval, wide_deep, dlrm_rm2, autoint,
    range_engine,
]

REGISTRY: dict[str, ArchSpec] = {m.ARCH.arch_id: m.ARCH for m in _MODULES}

# The 40 graded cells: 10 assigned archs x their own 4 shapes.
ASSIGNED = [m.ARCH.arch_id for m in _MODULES if m.ARCH.arch_id != "range-engine"]


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def list_archs() -> list[str]:
    return list(REGISTRY)


def all_cells(include_engine: bool = False) -> list[tuple[str, str]]:
    out = []
    for aid in (list(REGISTRY) if include_engine else ASSIGNED):
        for shape in REGISTRY[aid].shapes:
            out.append((aid, shape))
    return out
