"""Error-code taxonomy for degraded responses.

Every non-healthy ``Response`` carries exactly one of these codes so that
callers can branch on machine-readable strings instead of parsing
booleans scattered across fields:

- ``queue_full`` — the request was rejected at admission (bounded queue).
  ``op == "error"``, no results.
- ``deadline_expired`` — the request's deadline budget ran out. Either the
  request expired while still queued (``op == "error"``, no results) or its
  lane was force-finalized mid-search (``op == "range"``, certified partial
  results, ``complete=False``).
- ``shard_lost`` — one or more shards were permanently unavailable after
  retries; results cover only the surviving shards (``complete=False``,
  ``shards_ok < shards_total``).
- ``replica_lost`` — the answer is **complete** (every shard contributed:
  ``complete=True``, ``coverage == 1.0``) but one or more replicas of some
  shard are down or breaker-open, so redundancy is degraded. A health
  signal, not a correctness one; it never coexists with ``shard_lost``
  (shard loss wins when every replica of a shard is exhausted).
"""
from __future__ import annotations

QUEUE_FULL = "queue_full"
DEADLINE_EXPIRED = "deadline_expired"
SHARD_LOST = "shard_lost"
REPLICA_LOST = "replica_lost"

ERROR_CODES = frozenset({QUEUE_FULL, DEADLINE_EXPIRED, SHARD_LOST, REPLICA_LOST})
