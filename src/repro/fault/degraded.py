"""Fault-tolerant sharded range search: host fan-out with degradation.

The collective path (``dist.sharded_range_search``) assumes every shard
answers; one ``shard_map`` program either completes or fails as a unit.
This module is the serving-side alternative: shards are searched
independently from the host — concurrently, one worker thread per shard —
so a shard that times out, errors, or returns garbage degrades the answer
instead of destroying it.

Per shard: retry with jittered, capped exponential backoff for transient
faults, validate every answer against invariants no honest shard can
violate (ids inside the shard's global range, finite in-radius distances,
consistent counts), and on exhaustion mark the shard lost in a validity
mask. The union merge runs over surviving shards only, **in shard order**
regardless of thread completion order, so the merged result is bitwise
independent of scheduling. Because the shards partition the corpus and
each per-shard search is deterministic, the merged result over surviving
shards is **exact-mode-identical** to a healthy run restricted to those
shards — degradation truncates coverage, never corrupts results.

With replication (``fleet=``, see :mod:`repro.fault.replica`) the
per-shard worker additionally fails over across replicas, hedges slow
primaries, and respects per-replica circuit breakers; a shard is lost
only when *every* replica of it is exhausted.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.beam_search import broadcast_radius
from ..core.graph import Graph
from ..core.labels import LabelFilter
from ..core.range_search import RangeConfig, RangeResult, range_search_fused
from ..dist.sharded_engine import ShardedCorpus, _remap_global, union_merge
from ..tier import TierFetchError
from ..utils import INVALID_ID
from .errors import SHARD_LOST
from .injector import FaultInjector, ShardFault


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Transient-fault retry: ``max_attempts`` tries per shard, sleeping
    ``min(backoff_s * backoff_factor**attempt, backoff_max_s)`` between
    them (``backoff_s=0`` = no sleep, the right setting under test where
    faults are scripted, not timed). ``jitter > 0`` stretches each delay
    by a uniform factor in ``[1, 1 + jitter]`` drawn from a counter-based
    seeded stream (key = ``[seed, shard, attempt]``), so retries across
    shards de-synchronize deterministically instead of thundering-herding
    a recovering shard; the default ``jitter=0.0`` keeps delays exact.

    Also carries the result-validation tolerances (``atol``, ``rtol``)
    used by :func:`validate_shard_result` on this retry path: a distance
    is in-radius up to ``atol + rtol * r``. Distances scale with the
    radius, so a purely absolute tolerance mislabels honest large-radius
    int8 answers as garbage; the relative term tracks the float error
    actually accrued. Plumbed through ``RangeServer(retry=)``.
    """

    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_factor: float = 2.0
    backoff_max_s: float = 5.0
    jitter: float = 0.0
    seed: int = 0
    atol: float = 1e-4
    rtol: float = 1e-5

    def delay_s(self, attempt: int, key: int = 0) -> float:
        """Backoff before retrying ``attempt`` (0-based), for shard ``key``."""
        d = min(self.backoff_s * self.backoff_factor ** attempt,
                self.backoff_max_s)
        if self.jitter > 0.0 and d > 0.0:
            u = float(np.random.default_rng(
                [int(self.seed), int(key), int(attempt)]).random())
            d *= 1.0 + self.jitter * u
        return d


@dataclasses.dataclass
class DegradedResult:
    """A merged RangeResult plus the per-shard health that produced it."""

    result: RangeResult
    shard_ok: np.ndarray        # (S,) bool — shard's results present in the merge
    attempts: np.ndarray        # (S,) int32 — search attempts per shard
    faults: List[Optional[str]]  # last injected/observed fault kind per shard

    @property
    def shards_total(self) -> int:
        return int(self.shard_ok.shape[0])

    @property
    def shards_ok(self) -> int:
        return int(self.shard_ok.sum())

    @property
    def complete(self) -> bool:
        return self.shards_ok == self.shards_total

    @property
    def coverage(self) -> float:
        """Fraction of shards contributing to the merge (3/4 when one of
        four shards is lost — the corpus fraction actually searched)."""
        return self.shards_ok / max(1, self.shards_total)

    @property
    def code(self) -> Optional[str]:
        return None if self.complete else SHARD_LOST


def validate_shard_result(
    res: RangeResult,
    offset: int,
    shard_rows: int,
    n_total: int,
    radii: np.ndarray,
    atol: float = 1e-4,
    rtol: float = 0.0,
) -> bool:
    """Invariants no honest shard can violate (``res`` already global-id):

    - every valid id lies inside the shard's global row range and the corpus;
    - every valid distance is finite, non-negative, and within the lane's
      radius up to ``atol + rtol * r`` (the relative term because float
      error scales with the radius — see :class:`RetryPolicy`);
    - per-lane counts never exceed the result buffer.

    A shard returning garbage (bit flips, wrong shard's rows, stale radius)
    fails here and is treated like any other transient fault — the merge
    never trusts an unvalidated answer.
    """
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    valid = ids != INVALID_ID
    lo, hi = int(offset), min(int(offset) + int(shard_rows), int(n_total))
    if np.any(valid & ((ids < lo) | (ids >= hi))):
        return False
    d = np.where(valid, dists, 0.0)
    if not np.all(np.isfinite(d)) or np.any(d < 0):
        return False
    r = np.asarray(radii, np.float32).reshape(-1, 1)
    if np.any(valid & (dists > r + (atol + rtol * r))):
        return False
    if np.any(np.asarray(res.count) > ids.shape[1]):
        return False
    return True


def _corrupt_result(res: RangeResult, rng: np.random.Generator) -> RangeResult:
    """Deterministically garble a result the way a sick shard would:
    random out-of-range ids plus a guaranteed-invalid negative distance,
    so validation MUST catch it (no lucky passes)."""
    ids = rng.integers(0, 2**31 - 2, size=np.asarray(res.ids).shape, dtype=np.int32)
    dists = rng.uniform(-1.0, 1.0, size=np.asarray(res.dists).shape).astype(np.float32)
    dists[:, 0] = -1.0  # airtight: a negative distance is never valid
    return dataclasses.replace(
        res, ids=jnp.asarray(ids), dists=jnp.asarray(dists),
        count=jnp.full_like(res.count, ids.shape[1]))


def _search_one_shard(corpus: ShardedCorpus, s: int, queries, radii, cfg,
                      es_vec, tombstones,
                      label_filter: Optional[LabelFilter] = None) -> RangeResult:
    """Exact per-shard search with shard-local ids remapped to global —
    the same per-shard program the collective path runs, minus the mesh.
    A tiered corpus composes shard ``s``'s host store back onto its slice
    of the stacked device arm, so the per-shard rerank fetches that
    shard's raw rows (shard-local slot space) before the global remap."""
    shard_pts = jax.tree.map(lambda x: x[s], corpus.points)
    tiers = getattr(corpus, "tiers", None)
    if tiers is not None:
        shard_pts = tiers[s].with_device(shard_pts)
    res = range_search_fused(
        corpus=shard_pts, graph=Graph(neighbors=corpus.neighbors[s]),
        queries=queries, start_ids=corpus.start_ids[s], r=radii, cfg=cfg,
        es_radius=es_vec,
        tombstones=None if tombstones is None else tombstones[s],
        labels=None if label_filter is None else corpus.labels[s],
        label_filter=label_filter)
    gids = _remap_global(res.ids, corpus.offsets[s], corpus.n_total)
    return dataclasses.replace(
        res, ids=gids,
        dists=jnp.where(gids == INVALID_ID, jnp.inf, res.dists),
        count=jnp.sum(gids != INVALID_ID, axis=1).astype(jnp.int32))


def merge_shard_results(per_shard: List[Optional[RangeResult]],
                        shard_ok: np.ndarray, n_q: int,
                        cap: int) -> RangeResult:
    """Union-merge surviving shards' results, in shard order.

    The merge is a pure function of the surviving results and their shard
    order — never of which thread or replica produced them — which is what
    makes the concurrent/replicated paths bitwise-identical to the serial
    single-replica reference.
    """
    ok = [per_shard[s] for s in range(len(per_shard)) if shard_ok[s]]
    if not ok:  # every shard lost: an empty (but well-formed) result
        return RangeResult(
            ids=jnp.full((n_q, cap), INVALID_ID, jnp.int32),
            dists=jnp.full((n_q, cap), jnp.inf, jnp.float32),
            count=jnp.zeros(n_q, jnp.int32),
            overflow=jnp.zeros(n_q, bool),
            n_visited=jnp.zeros(n_q, jnp.int32),
            n_dist=jnp.zeros(n_q, jnp.int32),
            es_stopped=jnp.zeros(n_q, bool),
            phase2=jnp.zeros(n_q, bool),
            n_rerank=jnp.zeros(n_q, jnp.int32),
        )
    ids = jnp.concatenate([p.ids for p in ok], axis=1)
    dists = jnp.concatenate([p.dists for p in ok], axis=1)
    if ids.shape[1] < cap:  # fewer candidates than the cap: pad the merge
        pad = cap - ids.shape[1]
        ids = jnp.concatenate(
            [ids, jnp.full((n_q, pad), INVALID_ID, ids.dtype)], axis=1)
        dists = jnp.concatenate(
            [dists, jnp.full((n_q, pad), jnp.inf, dists.dtype)], axis=1)
    ids, dists = union_merge(ids, dists, cap)
    total = sum(p.count for p in ok)
    return RangeResult(
        ids=ids,
        dists=dists,
        count=jnp.minimum(total, cap).astype(jnp.int32),
        overflow=jnp.logical_or(
            sum(p.overflow.astype(jnp.int32) for p in ok) > 0,
            total > cap),
        n_visited=sum(p.n_visited for p in ok),
        n_dist=sum(p.n_dist for p in ok),
        es_stopped=sum(p.es_stopped.astype(jnp.int32) for p in ok) > 0,
        phase2=sum(p.phase2.astype(jnp.int32) for p in ok) > 0,
        n_rerank=sum(p.n_rerank for p in ok),
    )


def run_shard_workers(fn: Callable[[int], object], s_total: int,
                      max_workers: Optional[int]) -> List[object]:
    """Run ``fn(s)`` for every shard, returning outcomes indexed by shard.

    ``max_workers=None`` sizes the pool to the shard count; ``0`` runs
    serially on the calling thread — the reference path the determinism
    tests compare the threaded fan-out against.
    """
    if max_workers is None:
        max_workers = s_total
    if max_workers <= 0 or s_total <= 1:
        return [fn(s) for s in range(s_total)]
    with ThreadPoolExecutor(max_workers=min(max_workers, s_total)) as pool:
        return list(pool.map(fn, range(s_total)))


def fault_tolerant_sharded_search(
    *,
    corpus: Optional[ShardedCorpus] = None,
    queries,
    r,
    cfg: RangeConfig,
    es_radius=None,
    tombstones=None,
    label_filter: Optional[LabelFilter] = None,
    injector: Optional[FaultInjector] = None,
    retry: Optional[RetryPolicy] = None,
    sleep: Callable[[float], None] = time.sleep,
    max_workers: Optional[int] = None,
    fleet=None,
    hedge=None,
) -> DegradedResult:
    """Union range search over ``corpus`` that survives shard loss.

    Shards are searched concurrently (host fan-out, one worker per shard;
    ``max_workers=0`` forces the serial reference path). Injected or
    observed faults retry up to ``retry.max_attempts`` with jittered,
    capped exponential backoff; answers are validated before they may join
    the merge, and a shard that exhausts its retries is marked lost rather
    than failing the query. The returned :class:`DegradedResult` carries
    the merged global ``RangeResult`` over surviving shards plus the
    per-shard validity mask / attempt counts; ``coverage`` is
    ``shards_ok / shards_total``.

    ``label_filter`` is a per-query :class:`~repro.core.labels.LabelFilter`
    over the corpus's attached labels (``build_sharded(..., labels=)``);
    each shard evaluates the predicate locally at the result stage, exactly
    as the collective path does.

    With ``fleet=`` (a :class:`~repro.fault.replica.ReplicaFleet`) the
    search runs replicated: per-shard failover across R bitwise-identical
    replicas, optional hedging of slow primaries (``hedge=`` a
    :class:`~repro.fault.replica.HedgePolicy`), and per-replica circuit
    breakers; ``corpus`` is then taken from the fleet and the result is a
    :class:`~repro.fault.replica.ReplicatedResult`.

    With every shard healthy the merge is exact-mode-identical to the
    collective ``sharded_range_search`` (same per-shard program, same
    union merge); with shards lost it equals that healthy merge restricted
    to surviving shards. The threaded fan-out merges in shard order, so it
    is bitwise-identical to the serial loop under every fault script.
    """
    if fleet is not None:
        from .replica import replicated_fan_out
        return replicated_fan_out(
            fleet=fleet, queries=queries, r=r, cfg=cfg, es_radius=es_radius,
            tombstones=tombstones, label_filter=label_filter,
            injector=injector, retry=retry, sleep=sleep,
            max_workers=max_workers, hedge=hedge)
    if corpus is None:
        raise ValueError("pass corpus= (or fleet= for replicated search)")
    retry = retry or RetryPolicy()
    if label_filter is not None and corpus.labels is None:
        raise ValueError(
            "corpus has no labels attached; build_sharded(..., labels=) to "
            "use filtered range search")
    queries = jnp.asarray(queries)
    n_q = queries.shape[0]
    radii = broadcast_radius(r, n_q)
    es_vec = broadcast_radius(es_radius, n_q)
    radii_np = np.asarray(radii)
    s_total = corpus.n_shards
    rows = corpus.shard_size
    cap = cfg.result_cap
    offsets_np = np.asarray(corpus.offsets)

    def run_shard(s: int):
        """One shard's retry loop; returns (ok, result, attempts, fault)."""
        offset = int(offsets_np[s])
        fault: Optional[str] = None
        for attempt in range(retry.max_attempts):
            try:
                kind = (injector.raise_if_faulted(s, attempt)
                        if injector is not None else None)
                res = _search_one_shard(
                    corpus, s, queries, radii, cfg, es_vec, tombstones,
                    label_filter)
                if kind == "garbage":
                    res = _corrupt_result(res, injector.rng(s, attempt))
                if not validate_shard_result(
                        res, offset, rows, corpus.n_total, radii_np,
                        atol=retry.atol, rtol=retry.rtol):
                    fault = "garbage"
                    raise ShardFault("garbage", s, attempt)
                return True, res, attempt + 1, fault
            except (ShardFault, TierFetchError) as e:
                # a failed host-store fetch degrades exactly like a lost
                # shard: retry, then annotate — never crash the batch
                fault = getattr(e, "kind", "tier_fetch")
                if attempt + 1 < retry.max_attempts:
                    d = retry.delay_s(attempt, key=s)
                    if d > 0:
                        sleep(d)
        return False, None, retry.max_attempts, fault

    outcomes = run_shard_workers(run_shard, s_total, max_workers)

    shard_ok = np.zeros(s_total, bool)
    attempts = np.zeros(s_total, np.int32)
    faults: List[Optional[str]] = [None] * s_total
    per_shard: List[Optional[RangeResult]] = [None] * s_total
    for s, (ok, res, n_att, fault) in enumerate(outcomes):
        shard_ok[s] = ok
        per_shard[s] = res
        attempts[s] = n_att
        faults[s] = fault

    merged = merge_shard_results(per_shard, shard_ok, n_q, cap)
    return DegradedResult(result=merged, shard_ok=shard_ok,
                          attempts=attempts, faults=faults)
