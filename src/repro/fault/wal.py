"""Append-only write-ahead log for live-index mutation batches.

Record layout (little-endian), one record per mutation batch::

    [crc32: u32] [payload_len: u32] [seq: u64] [op: u8] [payload: bytes]

``crc32`` covers everything after itself (the 13 header bytes
``payload_len | seq | op`` plus the payload), so a torn or bit-flipped
record fails its checksum as a unit. ``seq`` is the index's monotonically
increasing mutation sequence number (independent of the structural
``epoch``, which can advance more than once inside a single public
mutation). The payload is an ``np.savez`` archive of named arrays; what
the arrays mean depends on ``op``:

- ``insert``  — ``ext_ids (B,) int64``, ``vecs (B, d)`` (corpus dtype),
  plus ``labels (B, W) uint32`` packed label rows when the index is
  labeled (absent otherwise — replay passes None through).
  The logged ``ext_ids`` are the *resolved* ids (auto-assigned ids are
  materialized before logging), so replay never re-derives them.
- ``delete``  — ``ext_ids (B,) int64`` as requested (idempotent on replay).
- ``consolidate`` — empty payload; records an explicit external
  consolidation. Consolidations triggered *inside* ``insert`` are not
  logged: replaying the insert record reproduces them deterministically.

Replay rules (torn-tail tolerance):

1. Records are read in file order; each is accepted only if its header
   parses, the payload is fully present, and the checksum matches.
2. The first record that fails any of these checks ends the replayable
   prefix — it and everything after it are discarded as a torn tail
   (a crash mid-``append``). Nothing before it is affected.
3. ``LiveIndex.restore`` applies the records with ``seq`` strictly greater
   than the checkpoint's ``wal_seq``, in order. Because every mutation is
   deterministic, replaying the surviving prefix reproduces the
   uninterrupted state bit-for-bit up to the last durable record.

Appends ``flush`` + ``fsync`` by default so a record returned from
``append`` is durable; pass ``fsync=False`` for throughput when the
durability point is managed elsewhere (e.g. group commit).
"""
from __future__ import annotations

import dataclasses
import io
import os
import struct
import zlib
from typing import Dict, Iterable, List, Optional

import numpy as np

_HEADER = struct.Struct("<IIQB")  # crc32, payload_len, seq, op
_OPS = {1: "insert", 2: "delete", 3: "consolidate"}
_OP_CODES = {v: k for k, v in _OPS.items()}

#: Ceiling on a single record's payload; a parsed length above this is
#: treated as corruption (ends the replayable prefix) rather than an
#: attempt to allocate garbage.
MAX_PAYLOAD_BYTES = 1 << 30


@dataclasses.dataclass(frozen=True)
class WalRecord:
    """One durable mutation batch: ``(seq, op, named arrays)``."""

    seq: int
    op: str
    arrays: Dict[str, np.ndarray]


def _encode_payload(arrays: Dict[str, np.ndarray]) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **{k: np.asarray(v) for k, v in arrays.items()})
    return buf.getvalue()


def _decode_payload(raw: bytes) -> Dict[str, np.ndarray]:
    if not raw:
        return {}
    with np.load(io.BytesIO(raw)) as z:
        return {k: z[k] for k in z.files}


def encode_record(seq: int, op: str, arrays: Dict[str, np.ndarray]) -> bytes:
    """Serialize one record; the inverse of the reader's per-record parse."""
    if op not in _OP_CODES:
        raise ValueError(f"unknown WAL op {op!r}; expected one of {sorted(_OP_CODES)}")
    payload = _encode_payload(arrays)
    body = _HEADER.pack(0, len(payload), int(seq), _OP_CODES[op])[4:] + payload
    crc = zlib.crc32(body) & 0xFFFFFFFF
    return struct.pack("<I", crc) + body


class WriteAheadLog:
    """Append-only mutation log with checksummed records.

    The write handle stays open in append mode across calls; ``replay``
    opens its own read handle so a live writer and a recovery reader can
    coexist on the same path.
    """

    def __init__(self, path: str, *, fsync: bool = True):
        self.path = str(path)
        self._fsync = bool(fsync)
        self._fh = open(self.path, "ab")

    # -- writing ----------------------------------------------------------
    def append(self, seq: int, op: str, arrays: Optional[Dict[str, np.ndarray]] = None) -> int:
        """Append one record; returns the bytes written.

        Durable on return when ``fsync=True`` (the default): the record is
        flushed and fsynced before control returns to the caller, which is
        what makes logging *before* applying a true write-ahead protocol.
        """
        rec = encode_record(seq, op, arrays or {})
        self._fh.write(rec)
        self._fh.flush()
        if self._fsync:
            os.fsync(self._fh.fileno())
        return len(rec)

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()

    def __enter__(self) -> "WriteAheadLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- reading ----------------------------------------------------------
    def scan(self) -> tuple[List[WalRecord], int, bool]:
        """Parse the log; returns ``(records, durable_bytes, torn)``.

        ``records`` is the longest checksum-valid prefix, ``durable_bytes``
        the file offset just past it, and ``torn`` whether trailing bytes
        beyond the prefix were discarded.
        """
        self._fh.flush()
        try:
            with open(self.path, "rb") as f:
                raw = f.read()
        except FileNotFoundError:
            return [], 0, False
        records: List[WalRecord] = []
        off = 0
        while off + _HEADER.size <= len(raw):
            crc, length, seq, opc = _HEADER.unpack_from(raw, off)
            end = off + _HEADER.size + length
            if length > MAX_PAYLOAD_BYTES or opc not in _OPS or end > len(raw):
                break
            if zlib.crc32(raw[off + 4 : end]) & 0xFFFFFFFF != crc:
                break
            try:
                arrays = _decode_payload(raw[off + _HEADER.size : end])
            except Exception:
                break
            records.append(WalRecord(seq=int(seq), op=_OPS[opc], arrays=arrays))
            off = end
        return records, off, off < len(raw)

    def replay(self, after_seq: int = -1) -> Iterable[WalRecord]:
        """Yield the checksum-valid records with ``seq > after_seq``."""
        records, _, _ = self.scan()
        return [r for r in records if r.seq > after_seq]

    @property
    def last_seq(self) -> int:
        """Sequence number of the last durable record (-1 if empty)."""
        records, _, _ = self.scan()
        return records[-1].seq if records else -1

    # -- maintenance ------------------------------------------------------
    def truncate_torn_tail(self) -> bool:
        """Drop any torn tail in place; returns whether bytes were removed.

        Call before resuming appends on a log recovered from a crash, so
        new records land after the durable prefix instead of after garbage
        (which would otherwise shadow them from every future replay).
        """
        _, durable, torn = self.scan()
        if torn:
            self._fh.close()
            with open(self.path, "rb+") as f:
                f.truncate(durable)
                f.flush()
                os.fsync(f.fileno())
            self._fh = open(self.path, "ab")
        return torn

    def prune_through(self, seq: int) -> int:
        """Atomically rewrite the log keeping only records with ``seq >``.

        Run after a durable checkpoint at ``wal_seq == seq`` to bound log
        growth; returns the number of records dropped. The rewrite goes
        through a temp file + ``os.replace`` so a crash mid-prune leaves
        either the old or the new log, never a hybrid.
        """
        records, _, _ = self.scan()
        keep = [r for r in records if r.seq > seq]
        dropped = len(records) - len(keep)
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            for r in keep:
                f.write(encode_record(r.seq, r.op, r.arrays))
            f.flush()
            os.fsync(f.fileno())
        self._fh.close()
        os.replace(tmp, self.path)
        dirfd = os.open(os.path.dirname(os.path.abspath(self.path)), os.O_RDONLY)
        try:
            os.fsync(dirfd)
        finally:
            os.close(dirfd)
        self._fh = open(self.path, "ab")
        return dropped
