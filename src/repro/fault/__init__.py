"""Fault tolerance: deadlines, shard-loss degradation, crash-safe WAL,
replication.

Independent pieces, threaded through serving and the live index:

- :mod:`repro.fault.errors` — the error-code taxonomy shared by every
  degraded-response path (queue rejection, deadline expiry, shard loss,
  replica loss).
- :mod:`repro.fault.wal` — an append-only, checksummed write-ahead log for
  live-index mutation batches, with a torn-tail-tolerant reader.
- :mod:`repro.fault.injector` — a seeded, deterministic fault injector for
  (shard, replica)-level chaos testing (timeouts, errors, garbage, slow).
- :mod:`repro.fault.degraded` — fault-tolerant sharded range search:
  concurrent host fan-out over shards with per-shard validation, retry
  with jittered capped backoff, and a per-shard validity mask on the
  merged result.
- :mod:`repro.fault.replica` — R-way shard replication: bitwise-identical
  replica sets, hedged reads off the per-shard latency histogram,
  per-replica circuit breakers, and background replica recovery.
"""
from .degraded import (
    DegradedResult,
    RetryPolicy,
    fault_tolerant_sharded_search,
    merge_shard_results,
    validate_shard_result,
)
from .errors import DEADLINE_EXPIRED, ERROR_CODES, QUEUE_FULL, REPLICA_LOST, SHARD_LOST
from .injector import FaultInjector, ShardError, ShardFault, ShardTimeout
from .replica import (
    BreakerConfig,
    CircuitBreaker,
    HedgePolicy,
    ReplicaFleet,
    ReplicaLost,
    ReplicatedCorpus,
    ReplicatedResult,
    replicated_fan_out,
)
from .wal import WalRecord, WriteAheadLog

__all__ = [
    "DEADLINE_EXPIRED",
    "ERROR_CODES",
    "QUEUE_FULL",
    "REPLICA_LOST",
    "SHARD_LOST",
    "BreakerConfig",
    "CircuitBreaker",
    "DegradedResult",
    "FaultInjector",
    "HedgePolicy",
    "ReplicaFleet",
    "ReplicaLost",
    "ReplicatedCorpus",
    "ReplicatedResult",
    "RetryPolicy",
    "ShardError",
    "ShardFault",
    "ShardTimeout",
    "WalRecord",
    "WriteAheadLog",
    "fault_tolerant_sharded_search",
    "merge_shard_results",
    "replicated_fan_out",
    "validate_shard_result",
]
