"""Fault tolerance: deadlines, shard-loss degradation, crash-safe WAL.

Three independent pieces, threaded through serving and the live index:

- :mod:`repro.fault.errors` — the error-code taxonomy shared by every
  degraded-response path (queue rejection, deadline expiry, shard loss).
- :mod:`repro.fault.wal` — an append-only, checksummed write-ahead log for
  live-index mutation batches, with a torn-tail-tolerant reader.
- :mod:`repro.fault.injector` — a seeded, deterministic fault injector for
  shard-level chaos testing (timeouts, errors, garbage results).
- :mod:`repro.fault.degraded` — fault-tolerant sharded range search: host
  fan-out over shards with per-shard validation, retry with exponential
  backoff, and a per-shard validity mask on the merged result.
"""
from .degraded import (
    DegradedResult,
    RetryPolicy,
    fault_tolerant_sharded_search,
    validate_shard_result,
)
from .errors import DEADLINE_EXPIRED, ERROR_CODES, QUEUE_FULL, SHARD_LOST
from .injector import FaultInjector, ShardError, ShardFault, ShardTimeout
from .wal import WalRecord, WriteAheadLog

__all__ = [
    "DEADLINE_EXPIRED",
    "ERROR_CODES",
    "QUEUE_FULL",
    "SHARD_LOST",
    "DegradedResult",
    "FaultInjector",
    "RetryPolicy",
    "ShardError",
    "ShardFault",
    "ShardTimeout",
    "WalRecord",
    "WriteAheadLog",
    "fault_tolerant_sharded_search",
    "validate_shard_result",
]
