"""R-way shard replication: hedged fan-out, circuit breakers, recovery.

PR 7's degradation contract shrinks the answer when a shard dies
(``coverage < 1.0``) — the wrong trade for dedup/moderation workloads
where a missed duplicate is a correctness failure. This module keeps the
answer whole unless R failures coincide:

- :class:`ReplicatedCorpus` materializes R bitwise-identical copies of a
  :class:`~repro.dist.sharded_engine.ShardedCorpus`. Replicas being
  bit-equal is the load-bearing invariant: *which replica answers is
  unobservable in results*, so failover and hedging are free of
  consistency reasoning.
- :class:`ReplicaFleet` is the control plane: per-(shard, replica)
  availability, per-replica :class:`CircuitBreaker` (consecutive-failure
  trip, half-open probe after a cooldown, injectable clock), per-shard
  latency histograms feeding :class:`HedgePolicy`, and background
  recovery (``maintain()``) that re-admits rebuilt replicas through the
  breaker's half-open state.
- :func:`replicated_fan_out` is the replicated version of
  ``fault_tolerant_sharded_search``: per shard, walk the available
  replicas in rotation — failing over on timeout/error/garbage, hedging
  past slow primaries — and accept the first *validated* answer
  (``validate_shard_result`` defines trustworthy, so hedging composes
  with garbage detection). A shard is lost only when every replica of it
  is exhausted; a complete answer served with replicas down carries
  ``code == "replica_lost"`` (health degraded, results not).

Live (mutable) replication — fanning mutations to every replica of the
owning shard and rebuilding a lost replica from checkpoint manifest + WAL
tail — lives in :mod:`repro.live.sharded`; this module is the static-
corpus data plane plus the shared control plane.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.beam_search import broadcast_radius
from ..core.labels import LabelFilter
from ..core.range_search import RangeConfig, RangeResult
from ..dist.sharded_engine import ShardedCorpus
from ..tier import TierFetchError
from .degraded import (
    DegradedResult,
    RetryPolicy,
    _corrupt_result,
    _search_one_shard,
    merge_shard_results,
    run_shard_workers,
    validate_shard_result,
)
from .errors import REPLICA_LOST, SHARD_LOST
from .injector import FaultInjector, ShardError, ShardFault, ShardTimeout


class ReplicaLost(ShardFault):
    """The targeted replica's data is gone (host down, rebuild pending)."""

    def __init__(self, shard: int, attempt: int, replica: int):
        super().__init__("replica_lost", shard, attempt, replica)


@dataclasses.dataclass
class ReplicatedCorpus:
    """R bitwise-identical copies of a sharded corpus.

    Delegating properties expose replica 0's view, so anything that
    duck-types a ``ShardedCorpus`` (server dtype probes, label checks)
    works unchanged — by the parity invariant any replica would do.
    """

    replicas: List[ShardedCorpus]

    @staticmethod
    def replicate(corpus: ShardedCorpus, n: int) -> "ReplicatedCorpus":
        """Materialize ``n`` bitwise-identical copies (fresh buffers each,
        as distinct hosts would hold them)."""
        if n < 1:
            raise ValueError(f"replicas must be >= 1, got {n}")
        copies = [corpus] + [
            jax.tree.map(lambda x: jnp.array(x, copy=True), corpus)
            for _ in range(n - 1)]
        return ReplicatedCorpus(replicas=copies)

    def replica(self, r: int) -> ShardedCorpus:
        return self.replicas[r]

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def n_shards(self) -> int:
        return self.replicas[0].n_shards

    @property
    def shard_size(self) -> int:
        return self.replicas[0].shard_size

    @property
    def n_total(self) -> int:
        return self.replicas[0].n_total

    @property
    def offsets(self):
        return self.replicas[0].offsets

    @property
    def points(self):
        return self.replicas[0].points

    @property
    def labels(self):
        return self.replicas[0].labels

    def parity_ok(self) -> bool:
        """True iff every replica is bitwise-identical to replica 0."""
        base = jax.tree.leaves(self.replicas[0])
        for rep in self.replicas[1:]:
            for a, b in zip(base, jax.tree.leaves(rep)):
                if not np.array_equal(np.asarray(a), np.asarray(b)):
                    return False
        return True


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Circuit-breaker tuning: trip after ``fail_threshold`` consecutive
    failures; after ``cooldown_s`` admit a single half-open probe."""

    fail_threshold: int = 3
    cooldown_s: float = 30.0


class CircuitBreaker:
    """Per-replica breaker: closed -> open (on consecutive failures) ->
    half-open (after cooldown, one probe in flight) -> closed on probe
    success, re-open on probe failure. ``clock`` is injectable so tests
    drive the cooldown with a fake clock instead of sleeping.
    """

    def __init__(self, cfg: Optional[BreakerConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg or BreakerConfig()
        self.clock = clock
        self.state = "closed"
        self.failures = 0       # consecutive, while closed
        self.opened_at = 0.0
        self.trips = 0
        self._probing = False   # a half-open probe is in flight

    def allow(self) -> bool:
        """May a request be sent to this replica right now? Call only when
        a request WILL be sent on True: in half-open this consumes the
        single probe slot, which only ``record_success`` /
        ``record_failure`` / ``release_probe`` give back."""
        if self.state == "closed":
            return True
        if self.state == "open":
            if self.clock() - self.opened_at < self.cfg.cooldown_s:
                return False
            self.state = "half_open"
            self._probing = False
        # half-open: exactly one probe at a time
        if self._probing:
            return False
        self._probing = True
        return True

    def peek(self) -> bool:
        """Would ``allow()`` return True, without consuming the probe slot
        or transitioning state? (Routing lookahead — e.g. "is there a
        replica to hedge to" — must not burn the half-open probe.)"""
        if self.state == "closed":
            return True
        if self.state == "open":
            return self.clock() - self.opened_at >= self.cfg.cooldown_s
        return not self._probing

    def release_probe(self) -> None:
        """Give back an admitted-but-abandoned half-open probe (the hedged
        slow path walks away from a request it will never resolve)."""
        if self.state == "half_open":
            self._probing = False

    def record_success(self) -> None:
        self.state = "closed"
        self.failures = 0
        self._probing = False

    def record_failure(self) -> bool:
        """Record a failure; returns True iff the breaker tripped open now."""
        if self.state == "half_open":
            self._trip()  # failed probe: straight back to open
            return True
        self.failures += 1
        if self.state == "closed" and self.failures >= self.cfg.fail_threshold:
            self._trip()
            return True
        return False

    def force_open(self) -> None:
        """Trip unconditionally (replica declared lost out-of-band)."""
        if self.state != "open":
            self._trip()

    def to_half_open(self) -> None:
        """Skip the cooldown: next ``allow()`` admits a probe (used when a
        rebuilt replica is re-admitted by recovery)."""
        self.state = "half_open"
        self._probing = False
        self.failures = 0

    def _trip(self) -> None:
        self.state = "open"
        self.opened_at = self.clock()
        self.failures = 0
        self._probing = False
        self.trips += 1


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    """When to fire a hedge at the next replica.

    ``delay_s`` pins a fixed hedge delay; otherwise the delay derives from
    the shard's observed latency distribution: ``factor *
    hist.percentile(percentile)`` (p95 by default — hedges fire for the
    slowest ~5% of primaries, bounding tail latency at ~5% extra load),
    clamped below by ``min_delay_s`` and falling back to ``fallback_s``
    until the histogram has samples.
    """

    delay_s: Optional[float] = None
    percentile: float = 95.0
    factor: float = 1.0
    min_delay_s: float = 1e-3
    fallback_s: float = 0.05

    def delay_for(self, hist) -> float:
        if self.delay_s is not None:
            return self.delay_s
        if hist is None or getattr(hist, "count", 0) == 0:
            return self.fallback_s
        return max(self.min_delay_s,
                   self.factor * float(hist.percentile(self.percentile)))


class ReplicaFleet:
    """Control plane for an R-way replicated corpus.

    Tracks per-(shard, replica) availability and circuit breakers, feeds
    per-shard latency histograms to the hedge policy, and recovers lost
    replicas in the background (``maintain()``). Thread-safe: the fan-out
    runs one worker per shard and they share this state.

    ``recover_fn(shard, replica) -> bool`` customizes recovery (e.g. a
    live rebuild from checkpoint + WAL tail); the default models copying
    the shard's block from any surviving peer, which is always possible
    while at least one replica of the shard is alive — and always yields a
    bit-identical replica, because replicas never diverge. A recovered
    replica re-enters through the breaker's half-open state, so the first
    request after recovery is a probe.
    """

    def __init__(self, corpus, *, breaker: Optional[BreakerConfig] = None,
                 clock: Callable[[], float] = time.monotonic,
                 recover_fn: Optional[Callable[[int, int], bool]] = None):
        if isinstance(corpus, ShardedCorpus):
            corpus = ReplicatedCorpus(replicas=[corpus])
        self.corpus: ReplicatedCorpus = corpus
        self.clock = clock
        self.breaker_cfg = breaker or BreakerConfig()
        self.recover_fn = recover_fn
        self.breakers: Dict[Tuple[int, int], CircuitBreaker] = {
            (s, rep): CircuitBreaker(self.breaker_cfg, clock)
            for s in range(self.n_shards) for rep in range(self.n_replicas)}
        self.lost: Set[Tuple[int, int]] = set()
        self._hists: List[Optional[object]] = [None] * self.n_shards
        self.stats: Dict[str, int] = {
            "hedges_fired": 0, "hedge_wins": 0, "breaker_trips": 0,
            "replicas_lost": 0, "replicas_recovered": 0}
        self._lock = threading.Lock()

    @property
    def n_shards(self) -> int:
        return self.corpus.n_shards

    @property
    def n_replicas(self) -> int:
        return self.corpus.n_replicas

    # -- routing ----------------------------------------------------------

    def order(self, shard: int, start: int) -> List[int]:
        """Live replicas of ``shard`` in rotation starting at ``start`` —
        rotating by attempt spreads load and never re-primaries a replica
        that just failed."""
        n = self.n_replicas
        return [rep for rep in ((start + k) % n for k in range(n))
                if (shard, rep) not in self.lost]

    def allow(self, shard: int, replica: int) -> bool:
        """Admit a request that WILL be sent (consumes a half-open probe)."""
        with self._lock:
            if (shard, replica) in self.lost:
                return False
            return self.breakers[(shard, replica)].allow()

    def would_allow(self, shard: int, replica: int) -> bool:
        """Non-mutating admission check for routing lookahead."""
        with self._lock:
            if (shard, replica) in self.lost:
                return False
            return self.breakers[(shard, replica)].peek()

    def release(self, shard: int, replica: int) -> None:
        """Release an admitted half-open probe that will never resolve."""
        with self._lock:
            self.breakers[(shard, replica)].release_probe()

    def record_success(self, shard: int, replica: int) -> None:
        with self._lock:
            self.breakers[(shard, replica)].record_success()

    def record_failure(self, shard: int, replica: int) -> bool:
        with self._lock:
            tripped = self.breakers[(shard, replica)].record_failure()
            if tripped:
                self.stats["breaker_trips"] += 1
            return tripped

    def healthy(self, shard: int, replica: int) -> bool:
        """Not lost and not breaker-open (half-open counts as healthy-ish:
        it is being probed back in)."""
        with self._lock:
            return ((shard, replica) not in self.lost
                    and self.breakers[(shard, replica)].state != "open")

    # -- latency / hedging ------------------------------------------------

    def hist(self, shard: int):
        h = self._hists[shard]
        if h is None:
            # Lazy import: repro.serve imports repro.fault submodules, so a
            # module-level import here would be circular.
            from ..serve.latency import LatencyHistogram
            h = self._hists[shard] = LatencyHistogram()
        return h

    def record_latency(self, shard: int, seconds: float) -> None:
        with self._lock:
            self.hist(shard).record(seconds)

    def hedge_delay(self, shard: int, policy: HedgePolicy) -> float:
        with self._lock:
            return policy.delay_for(self._hists[shard])

    # -- loss & recovery --------------------------------------------------

    def lose(self, shard: int, replica: int) -> None:
        """Declare a replica's data gone (host died, disk lost). Searches
        skip it; ``maintain()`` rebuilds it."""
        with self._lock:
            if (shard, replica) in self.lost:
                return
            self.lost.add((shard, replica))
            self.stats["replicas_lost"] += 1
            self.breakers[(shard, replica)].force_open()

    def maintain(self) -> int:
        """Background recovery sweep: rebuild each lost replica whose shard
        still has a surviving peer to rebuild from, and re-admit it through
        the breaker's half-open probe. Returns replicas recovered."""
        recovered = 0
        for shard, replica in sorted(self.lost):
            peers = [rep for rep in range(self.n_replicas)
                     if rep != replica and (shard, rep) not in self.lost]
            if not peers:
                continue  # nothing to rebuild from; shard itself is lost
            if self.recover_fn is not None and not self.recover_fn(shard, replica):
                continue  # rebuild still in progress
            with self._lock:
                self.lost.discard((shard, replica))
                self.breakers[(shard, replica)].to_half_open()
                self.stats["replicas_recovered"] += 1
            recovered += 1
        return recovered

    def replica_ok_matrix(self) -> np.ndarray:
        """(S, R) bool — replica neither lost nor breaker-open."""
        return np.array(
            [[self.healthy(s, rep) for rep in range(self.n_replicas)]
             for s in range(self.n_shards)], bool)


@dataclasses.dataclass
class ReplicatedResult(DegradedResult):
    """A DegradedResult plus replica-level health for the batch.

    ``complete``/``coverage`` keep PR 7 semantics but over *shards*: a
    shard counts as ok if ANY replica of it answered, so ``coverage <
    1.0`` only when every replica of some shard was exhausted. ``code``
    refines the contract: ``shard_lost`` beats ``replica_lost`` beats
    ``None`` (fully healthy, full redundancy).
    """

    replica_ok: np.ndarray   # (S, R) bool — healthy at merge time AND did
    #                          not fail unrecovered during this batch
    served_by: np.ndarray    # (S,) int32 — replica that answered, -1 if lost
    hedges_fired: int
    hedge_wins: int
    breaker_trips: int

    @property
    def replicas_total(self) -> int:
        return int(self.replica_ok.size)

    @property
    def replicas_ok(self) -> int:
        return int(self.replica_ok.sum())

    @property
    def code(self) -> Optional[str]:
        if not self.complete:
            return SHARD_LOST
        if self.replicas_ok < self.replicas_total:
            return REPLICA_LOST
        return None


@dataclasses.dataclass
class _ShardOutcome:
    ok: bool = False
    res: Optional[RangeResult] = None
    attempts: int = 0
    fault: Optional[str] = None
    served: int = -1
    hedges: int = 0
    wins: int = 0
    # replicas that failed during this batch and never subsequently
    # succeeded — degraded redundancy even if a peer kept the answer whole
    rep_failed: Set[int] = dataclasses.field(default_factory=set)


def replicated_fan_out(
    *,
    fleet: ReplicaFleet,
    queries,
    r,
    cfg: RangeConfig,
    es_radius=None,
    tombstones=None,
    label_filter: Optional[LabelFilter] = None,
    injector: Optional[FaultInjector] = None,
    retry: Optional[RetryPolicy] = None,
    sleep: Callable[[float], None] = time.sleep,
    max_workers: Optional[int] = None,
    hedge: Optional[HedgePolicy] = None,
    preferred: int = 0,
) -> ReplicatedResult:
    """Replicated fault-tolerant range search (one worker per shard).

    Per shard, per retry attempt: walk the live, breaker-admitted replicas
    in rotation (primary first). Timeout/error/garbage fail over to the
    next replica immediately and count against that replica's breaker; a
    scripted-``slow`` primary is *hedged* — abandoned for the next replica
    without a breaker penalty (slow isn't sick). The first answer that
    passes :func:`validate_shard_result` wins; by the bitwise-parity
    invariant the winner's identity is unobservable in the merged result.

    When no injector scripts timing, hedging is wall-clock: the primary
    runs under a real timer and the hedge fires after
    ``hedge.delay_for(per-shard histogram)`` seconds (see
    :class:`HedgePolicy`), first validated answer wins.

    The merge is ``merge_shard_results`` in shard order — bitwise-identical
    to the single-replica serial reference restricted to surviving shards.
    """
    retry = retry or RetryPolicy()
    corpus0 = fleet.corpus.replica(0)
    if label_filter is not None and corpus0.labels is None:
        raise ValueError(
            "corpus has no labels attached; build_sharded(..., labels=) to "
            "use filtered range search")
    queries = jnp.asarray(queries)
    n_q = queries.shape[0]
    radii = broadcast_radius(r, n_q)
    es_vec = broadcast_radius(es_radius, n_q)
    radii_np = np.asarray(radii)
    s_total = fleet.n_shards
    rows = fleet.corpus.shard_size
    cap = cfg.result_cap
    offsets_np = np.asarray(fleet.corpus.offsets)
    # Real-timing hedges race primary vs. hedge in their own small pool;
    # scripted ("slow") hedges are deterministic and need no timers.
    wall_clock_hedge = hedge is not None and injector is None \
        and fleet.n_replicas > 1
    hedge_pool = ThreadPoolExecutor(
        max_workers=min(32, max(2, s_total * 2))) if wall_clock_hedge else None

    def search_replica(s: int, rep: int, offset: int, attempt: int,
                       kind: Optional[str]) -> RangeResult:
        """One (shard, replica) try: search, maybe corrupt, validate."""
        t0 = time.perf_counter()
        res = _search_one_shard(
            fleet.corpus.replica(rep), s, queries, radii, cfg, es_vec,
            tombstones, label_filter)
        if kind == "garbage":
            res = _corrupt_result(res, injector.rng(s, attempt, rep))
        if not validate_shard_result(res, offset, rows, corpus0.n_total,
                                     radii_np, atol=retry.atol,
                                     rtol=retry.rtol):
            raise ShardFault("garbage", s, attempt, rep)
        fleet.record_latency(s, time.perf_counter() - t0)
        return res

    def walk_scripted(st: _ShardOutcome, s: int, offset: int, attempt: int,
                      order: Sequence[int]) -> bool:
        """Deterministic walk: failover + scripted-slow hedging. Admission
        happens at contact time — ``allow()`` consumes a half-open probe,
        so it must only run for replicas the walk actually reaches."""
        pending_hedge = False
        for k, rep in enumerate(order):
            if not fleet.allow(s, rep):
                continue
            kind = (injector.fault_for(s, attempt, rep)
                    if injector is not None else None)
            if kind == "slow":
                if hedge is not None and any(
                        fleet.would_allow(s, nxt) for nxt in order[k + 1:]):
                    # Primary is past the hedge deadline: fire the next
                    # replica and race ahead. Slow is not a failure — no
                    # breaker penalty (release the probe the abandoned
                    # request held), and the late answer (identical by
                    # parity) would simply lose the race.
                    st.hedges += 1
                    pending_hedge = True
                    fleet.release(s, rep)
                    continue
                kind = None  # nothing to hedge to: just a late success
            try:
                if kind == "timeout":
                    raise ShardTimeout(s, attempt, rep)
                if kind == "error":
                    raise ShardError(s, attempt, rep)
                res = search_replica(s, rep, offset, attempt, kind)
            except (ShardFault, TierFetchError) as e:
                st.fault = getattr(e, "kind", "tier_fetch")
                st.rep_failed.add(rep)
                fleet.record_failure(s, rep)
                continue
            fleet.record_success(s, rep)
            st.rep_failed.discard(rep)
            if pending_hedge:
                st.wins += 1
            st.ok, st.res, st.served = True, res, rep
            return True
        return False

    def walk_timed(st: _ShardOutcome, s: int, offset: int, attempt: int,
                   order: Sequence[int]) -> bool:
        """Wall-clock walk: race primary vs. hedges, first validated wins.
        Replicas are admitted as they are submitted (never pre-filtered:
        ``allow()`` consumes a half-open probe, and every submitted request
        resolves it through ``record_success``/``record_failure``)."""
        delay = fleet.hedge_delay(s, hedge)
        futs: Dict[object, int] = {}
        next_k = 0

        def submit_next() -> Optional[int]:
            nonlocal next_k
            while next_k < len(order):
                rep = order[next_k]
                next_k += 1
                if fleet.allow(s, rep):
                    futs[hedge_pool.submit(
                        search_replica, s, rep, offset, attempt, None)] = rep
                    return rep
            return None

        primary = submit_next()
        while futs:
            done, pending = wait(futs, timeout=delay,
                                 return_when=FIRST_COMPLETED)
            if not done and next_k < len(order):
                if submit_next() is not None:
                    st.hedges += 1
                continue
            if not done:
                continue  # all hedges in flight; keep waiting
            fut = next(iter(done))
            rep = futs.pop(fut)
            try:
                res = fut.result()
            except (ShardFault, TierFetchError) as e:
                st.fault = getattr(e, "kind", "tier_fetch")
                st.rep_failed.add(rep)
                fleet.record_failure(s, rep)
                if not futs:
                    submit_next()  # failover, not a hedge
                continue
            fleet.record_success(s, rep)
            st.rep_failed.discard(rep)
            if rep != primary:
                st.wins += 1
            st.ok, st.res, st.served = True, res, rep
            for f in futs:  # late answers are identical by parity; drop them
                f.cancel()
            return True
        return False

    def run_shard(s: int) -> _ShardOutcome:
        offset = int(offsets_np[s])
        st = _ShardOutcome()
        for attempt in range(retry.max_attempts):
            st.attempts += 1
            order = fleet.order(s, preferred + attempt)
            if order:
                walk = walk_timed if wall_clock_hedge else walk_scripted
                if walk(st, s, offset, attempt, order):
                    return st
            if attempt + 1 < retry.max_attempts:
                d = retry.delay_s(attempt, key=s)
                if d > 0:
                    sleep(d)
        return st

    try:
        outcomes: List[_ShardOutcome] = run_shard_workers(
            run_shard, s_total, max_workers)
    finally:
        if hedge_pool is not None:
            hedge_pool.shutdown(wait=False)

    shard_ok = np.array([st.ok for st in outcomes], bool)
    attempts = np.array([st.attempts for st in outcomes], np.int32)
    faults = [st.fault for st in outcomes]
    per_shard = [st.res for st in outcomes]
    hedges = sum(st.hedges for st in outcomes)
    wins = sum(st.wins for st in outcomes)
    with fleet._lock:
        fleet.stats["hedges_fired"] += hedges
        fleet.stats["hedge_wins"] += wins
        trips_total = fleet.stats["breaker_trips"]

    replica_ok = fleet.replica_ok_matrix()
    for s, st in enumerate(outcomes):
        for rep in st.rep_failed:
            replica_ok[s, rep] = False

    merged = merge_shard_results(per_shard, shard_ok, n_q, cap)
    return ReplicatedResult(
        result=merged, shard_ok=shard_ok, attempts=attempts, faults=faults,
        replica_ok=replica_ok,
        served_by=np.array([st.served for st in outcomes], np.int32),
        hedges_fired=hedges, hedge_wins=wins, breaker_trips=trips_total)
