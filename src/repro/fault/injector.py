"""Seeded, deterministic fault injection for sharded range search.

Faults are decided per ``(shard, attempt)`` pair from a counter-based RNG
(``np.random.default_rng([seed, shard, attempt])``), so two injectors with
the same seed inject the *same* faults regardless of call order, process,
or how many other shards are being searched — the property the chaos
harness relies on to replay a failure deterministically.

Three fault kinds, mirroring how real shards fail:

- ``timeout`` — the shard never answers (raised as :class:`ShardTimeout`).
- ``error``   — the shard's RPC fails outright (:class:`ShardError`).
- ``garbage`` — the shard answers with corrupted results (wrong-range ids,
  out-of-radius distances). Not raised: it exercises the *validation*
  path, which must catch it without trusting the shard.

``down_shards`` marks shards permanently lost: every attempt times out, so
retries exhaust and the merge degrades. ``script`` pins specific
``(shard, attempt) -> kind`` outcomes for exact test scenarios; scripted
entries take precedence over both ``down_shards`` and the probabilistic
draws.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

FAULT_KINDS = ("timeout", "error", "garbage")


class ShardFault(RuntimeError):
    """Base for injected shard failures; carries (kind, shard, attempt)."""

    def __init__(self, kind: str, shard: int, attempt: int):
        super().__init__(f"injected {kind} on shard {shard} (attempt {attempt})")
        self.kind = kind
        self.shard = int(shard)
        self.attempt = int(attempt)


class ShardTimeout(ShardFault):
    def __init__(self, shard: int, attempt: int):
        super().__init__("timeout", shard, attempt)


class ShardError(ShardFault):
    def __init__(self, shard: int, attempt: int):
        super().__init__("error", shard, attempt)


@dataclasses.dataclass
class FaultInjector:
    """Deterministic per-(shard, attempt) fault source."""

    seed: int = 0
    down_shards: Tuple[int, ...] = ()
    p_timeout: float = 0.0
    p_error: float = 0.0
    p_garbage: float = 0.0
    script: Dict[Tuple[int, int], Optional[str]] = dataclasses.field(default_factory=dict)
    #: mutable tally of injected faults by kind (observability, not control)
    injected: Dict[str, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        for k, v in self.script.items():
            if v is not None and v not in FAULT_KINDS:
                raise ValueError(f"script[{k}] = {v!r}; expected None or one of {FAULT_KINDS}")
        if self.p_timeout + self.p_error + self.p_garbage > 1.0:
            raise ValueError("fault probabilities must sum to <= 1")

    def rng(self, shard: int, attempt: int) -> np.random.Generator:
        """Counter-based generator for this (shard, attempt) — order-free."""
        return np.random.default_rng([int(self.seed), int(shard), int(attempt)])

    def fault_for(self, shard: int, attempt: int) -> Optional[str]:
        """The fault to inject for this attempt, or None for a clean call."""
        key = (int(shard), int(attempt))
        if key in self.script:
            kind = self.script[key]
        elif int(shard) in set(self.down_shards):
            kind = "timeout"  # permanently lost: every attempt times out
        else:
            u = self.rng(shard, attempt).random()
            if u < self.p_timeout:
                kind = "timeout"
            elif u < self.p_timeout + self.p_error:
                kind = "error"
            elif u < self.p_timeout + self.p_error + self.p_garbage:
                kind = "garbage"
            else:
                kind = None
        if kind is not None:
            self.injected[kind] = self.injected.get(kind, 0) + 1
        return kind

    def raise_if_faulted(self, shard: int, attempt: int) -> Optional[str]:
        """Raise for timeout/error faults; return "garbage" (or None) otherwise."""
        kind = self.fault_for(shard, attempt)
        if kind == "timeout":
            raise ShardTimeout(shard, attempt)
        if kind == "error":
            raise ShardError(shard, attempt)
        return kind
