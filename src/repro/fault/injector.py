"""Seeded, deterministic fault injection for sharded range search.

Faults are decided per ``(shard, replica, attempt)`` from a counter-based
RNG (``np.random.default_rng([seed, shard, attempt, replica])``), so two
injectors with the same seed inject the *same* faults regardless of call
order, process, or how many other shards are being searched — the property
the chaos harness relies on to replay a failure deterministically.

Four fault kinds, mirroring how real shards fail:

- ``timeout`` — the replica never answers (raised as :class:`ShardTimeout`).
- ``error``   — the replica's RPC fails outright (:class:`ShardError`).
- ``garbage`` — the replica answers with corrupted results (wrong-range ids,
  out-of-radius distances). Not raised: it exercises the *validation*
  path, which must catch it without trusting the shard.
- ``slow``    — the replica answers correctly but past the hedge deadline.
  Not raised and not a failure: it exercises the *hedging* path, which
  fires the next replica instead of waiting. Without hedging (or with no
  replica to hedge to) a slow replica is just a late success.

``down_shards`` marks shards permanently lost — every replica, every
attempt times out, so retries exhaust and the merge degrades.
``down_replicas`` marks individual ``(shard, replica)`` pairs down, the
scenario replication exists to absorb. ``script`` pins specific outcomes
for exact test scenarios; keys are ``(shard, replica, attempt)`` triples
or legacy ``(shard, attempt)`` pairs (which apply to every replica of the
shard). Scripted entries take precedence over ``down_*`` and the
probabilistic draws; triples take precedence over pairs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

FAULT_KINDS = ("timeout", "error", "garbage", "slow")


class ShardFault(RuntimeError):
    """Base for injected shard failures; carries (kind, shard, attempt,
    replica)."""

    def __init__(self, kind: str, shard: int, attempt: int, replica: int = 0):
        super().__init__(
            f"injected {kind} on shard {shard} (attempt {attempt}, "
            f"replica {replica})")
        self.kind = kind
        self.shard = int(shard)
        self.attempt = int(attempt)
        self.replica = int(replica)


class ShardTimeout(ShardFault):
    def __init__(self, shard: int, attempt: int, replica: int = 0):
        super().__init__("timeout", shard, attempt, replica)


class ShardError(ShardFault):
    def __init__(self, shard: int, attempt: int, replica: int = 0):
        super().__init__("error", shard, attempt, replica)


@dataclasses.dataclass
class FaultInjector:
    """Deterministic per-(shard, replica, attempt) fault source."""

    seed: int = 0
    down_shards: Tuple[int, ...] = ()
    down_replicas: Tuple[Tuple[int, int], ...] = ()  # (shard, replica) pairs
    p_timeout: float = 0.0
    p_error: float = 0.0
    p_garbage: float = 0.0
    #: (shard, replica, attempt) or legacy (shard, attempt) -> kind
    script: Dict[Tuple[int, ...], Optional[str]] = dataclasses.field(default_factory=dict)
    #: mutable tally of injected faults by kind (observability, not control)
    injected: Dict[str, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        for k, v in self.script.items():
            if len(k) not in (2, 3):
                raise ValueError(
                    f"script key {k!r}: expected (shard, attempt) or "
                    "(shard, replica, attempt)")
            if v is not None and v not in FAULT_KINDS:
                raise ValueError(f"script[{k}] = {v!r}; expected None or one of {FAULT_KINDS}")
        if self.p_timeout + self.p_error + self.p_garbage > 1.0:
            raise ValueError("fault probabilities must sum to <= 1")

    def rng(self, shard: int, attempt: int, replica: int = 0) -> np.random.Generator:
        """Counter-based generator for this coordinate — order-free.

        Replica 0 keys as ``[seed, shard, attempt]``, bit-for-bit the
        pre-replication stream, so single-replica chaos runs replay
        identically across versions.
        """
        key = [int(self.seed), int(shard), int(attempt)]
        if int(replica) != 0:
            key.append(int(replica))
        return np.random.default_rng(key)

    def fault_for(self, shard: int, attempt: int,
                  replica: int = 0) -> Optional[str]:
        """The fault to inject for this attempt, or None for a clean call."""
        shard, attempt, replica = int(shard), int(attempt), int(replica)
        if (shard, replica, attempt) in self.script:
            kind = self.script[(shard, replica, attempt)]
        elif (shard, attempt) in self.script:
            kind = self.script[(shard, attempt)]
        elif shard in set(self.down_shards):
            kind = "timeout"  # permanently lost: every attempt times out
        elif (shard, replica) in set(self.down_replicas):
            kind = "timeout"  # this replica is down; peers may still answer
        else:
            u = self.rng(shard, attempt, replica).random()
            if u < self.p_timeout:
                kind = "timeout"
            elif u < self.p_timeout + self.p_error:
                kind = "error"
            elif u < self.p_timeout + self.p_error + self.p_garbage:
                kind = "garbage"
            else:
                kind = None
        if kind is not None:
            self.injected[kind] = self.injected.get(kind, 0) + 1
        return kind

    def raise_if_faulted(self, shard: int, attempt: int,
                         replica: int = 0) -> Optional[str]:
        """Raise for timeout/error faults; return "garbage"/"slow" (or None)
        otherwise."""
        kind = self.fault_for(shard, attempt, replica)
        if kind == "timeout":
            raise ShardTimeout(shard, attempt, replica)
        if kind == "error":
            raise ShardError(shard, attempt, replica)
        return kind
