"""Sharded live index: shard-routed mutations + per-shard tombstones.

One ``LiveIndex`` per shard, each with its own pre-allocated capacity,
insert stream, tombstone bitset, and consolidation schedule. The router
owns the global external-id space and the ``ext -> shard`` ownership map:

* **inserts** route to the owning shard — the one with the most free
  capacity (least-loaded placement; contiguous-block ids are a build-time
  artifact the live system drops). The shard assigns slots locally and the
  router records ownership.
* **deletes** route by ownership and tombstone only the owning shard's
  bitset.
* **queries** stack the per-shard snapshots into a ``ShardedCorpus`` (+ a
  stacked ``(S, W)`` tombstone plane) and dispatch one
  ``dist.sharded_range_search`` program: every shard filters its own dead
  slots at the result stage, the union merge sees live candidates only.
  The stacked view is cached per epoch vector, so serving traffic pays the
  stack cost once per mutation batch, not per query.

With ``replicas=R`` each shard is an R-member **replica group**: every
mutation batch fans to all members of the owning group, and because a
``LiveIndex`` mutation is a deterministic function of its state, replicas
that start bitwise-identical *stay* bitwise-identical under churn (pinned
by ``assert_replica_parity``). Queries read replica 0 (any member would
be bit-equal); ``replicated_corpus()`` exposes the stacked per-replica
views to the hedged fan-out (``repro.fault.replica``); a lost replica is
rebuilt from a checkpoint manifest + WAL tail (``rebuild_replica``) —
replay is deterministic, so the rebuilt member rejoins bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.build import BuildConfig
from ..core.range_search import RangeConfig, RangeResult
from ..dist.sharded_engine import ShardedCorpus, sharded_range_search
from .index import LiveConfig, LiveIndex, externalize_ids


def clone_live_index(idx: LiveIndex) -> LiveIndex:
    """A bitwise-identical, independently-mutable copy of a live index.

    Device arrays are shared (jnp arrays are immutable — every mutation
    replaces the reference, so clones can never diverge through aliasing);
    host bookkeeping is copied. The clone has NO WAL attached: in a
    replica group exactly one member (the primary) logs, since replaying
    that one log reproduces every member bit-for-bit.
    """
    clone = LiveIndex(
        points=idx.points, neighbors=idx.neighbors, start_ids=idx.start_ids,
        ext_ids=idx.ext_ids.copy(), tombstones=idx.tombstones,
        live_count=idx.live_count, next_ext_id=idx.next_ext_id,
        epoch=idx.epoch, metric=idx.metric, build_cfg=idx.build_cfg,
        cfg=idx.cfg, dead_slots=set(idx._dead), labels=idx.labels)
    clone.wal_seq = idx.wal_seq  # same mutation history, no log handle
    return clone


class LiveShardedIndex:
    """Router over per-shard ``LiveIndex`` sub-indices (uniform capacity),
    optionally R-way replicated (``replica_groups``)."""

    def __init__(self, shards: list[LiveIndex],
                 replica_groups: Optional[list[list[LiveIndex]]] = None):
        if not shards:
            raise ValueError("need at least one shard")
        if replica_groups is None:
            replica_groups = [[sh] for sh in shards]
        if len(replica_groups) != len(shards) or any(
                g[0] is not sh for g, sh in zip(replica_groups, shards)):
            raise ValueError("replica_groups[s][0] must be shards[s]")
        n_rep = len(replica_groups[0])
        if any(len(g) != n_rep for g in replica_groups):
            raise ValueError("every shard needs the same replica count")
        cap = shards[0].capacity
        deg = shards[0].neighbors.shape[1]
        for g in replica_groups:
            for sh in g:
                if sh.capacity != cap or sh.neighbors.shape[1] != deg:
                    raise ValueError(
                        "shards must share capacity and max degree")
                if sh.metric != shards[0].metric:
                    raise ValueError("shards must share the metric")
        self.shards = shards
        self.groups = replica_groups
        self.next_ext_id = max(sh.next_ext_id for sh in shards)
        self._owner: dict[int, int] = {}
        for si, sh in enumerate(shards):
            for e in sh._slot_of:
                self._owner[e] = si
        self._view_cache: Optional[tuple] = None

    # -- construction --------------------------------------------------------
    @staticmethod
    def create(points, n_shards: int, cfg: LiveConfig,
               build_cfg: Optional[BuildConfig] = None, metric: str = "l2",
               corpus_dtype: str = "float32", seed: int = 0,
               replicas: int = 1) -> "LiveShardedIndex":
        """Partition ``points`` into contiguous blocks, one live sub-index
        per block; ``cfg.capacity`` is the PER-SHARD capacity. With
        ``replicas=R`` each shard is built once and cloned R-1 times (the
        clones are bitwise-identical by construction)."""
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        pts = np.asarray(points, np.float32)
        n = -(-pts.shape[0] // n_shards)
        shards = []
        for s in range(n_shards):
            block = pts[s * n:(s + 1) * n]
            shards.append(LiveIndex.create(
                block, cfg, build_cfg=build_cfg, metric=metric,
                corpus_dtype=corpus_dtype, seed=seed + s,
                first_ext_id=s * n))
        groups = [[sh] + [clone_live_index(sh) for _ in range(replicas - 1)]
                  for sh in shards]
        idx = LiveShardedIndex(shards, replica_groups=groups)
        idx.next_ext_id = pts.shape[0]
        return idx

    # -- introspection -------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_replicas(self) -> int:
        return len(self.groups[0])

    @property
    def n_live(self) -> int:
        return sum(sh.n_live for sh in self.shards)

    def epochs(self) -> tuple:
        return tuple(sh.epoch for sh in self.shards)

    def stats(self) -> dict:
        return dict(n_shards=self.n_shards, n_live=self.n_live,
                    epochs=list(self.epochs()),
                    shards=[sh.stats() for sh in self.shards])

    def live_vectors(self) -> tuple[np.ndarray, np.ndarray]:
        pairs = [sh.live_vectors() for sh in self.shards]
        return (np.concatenate([p[0] for p in pairs]),
                np.concatenate([p[1] for p in pairs]))

    # -- mutation ------------------------------------------------------------
    def insert(self, vecs) -> np.ndarray:
        """Route to the owning (least-loaded) shard; a batch larger than one
        shard's free space splits greedily across shards by free capacity
        (tombstoned slots count as free — the shard's insert reclaims them
        by consolidating when it must)."""
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        k = vecs.shape[0]
        if k == 0:
            return np.zeros((0,), np.int64)
        free = [sh.capacity - sh.n_live for sh in self.shards]
        if sum(free) < k:
            raise ValueError(f"insert of {k} rows exceeds the fleet's free "
                             f"capacity {sum(free)}")
        ext = self.next_ext_id + np.arange(k, dtype=np.int64)
        off = 0
        while off < k:
            si = int(np.argmax(free))
            take = min(k - off, free[si])
            for member in self.groups[si]:  # fan to EVERY replica of the
                member.insert(vecs[off:off + take],  # owning shard
                              ext_ids=ext[off:off + take])
            for e in ext[off:off + take]:
                self._owner[int(e)] = si
            free[si] -= take
            off += take
        self.next_ext_id += k
        return ext

    def delete(self, ext_ids) -> int:
        """Tombstone each id in its owning shard's bitset (every replica)."""
        ext_ids = np.atleast_1d(np.asarray(ext_ids, np.int64))
        per_shard: dict[int, list[int]] = {}
        for e in ext_ids:
            si = self._owner.get(int(e))
            if si is not None:
                per_shard.setdefault(si, []).append(int(e))
        deleted = 0
        for si, ids in per_shard.items():
            for member in self.groups[si]:
                n = member.delete(np.asarray(ids, np.int64))
            deleted += n  # members agree by parity; count once
        return deleted

    def maybe_consolidate(self) -> int:
        """Per-shard threshold check; returns shards consolidated. Replicas
        of a shard consolidate together (the threshold decision is a pure
        function of state they share bitwise)."""
        done = 0
        for g in self.groups:
            ran = [bool(member.maybe_consolidate()) for member in g]
            if any(ran) != all(ran):  # diverged state — parity was broken
                raise AssertionError(
                    "replica group disagreed on consolidation")
            done += int(ran[0])
        return done

    # -- replication ---------------------------------------------------------
    def assert_replica_parity(self) -> None:
        """Every replica of every shard is bitwise-identical to its primary
        (the invariant that makes replica choice unobservable). Raises
        ``AssertionError`` with the diverging field otherwise."""
        for si, g in enumerate(self.groups):
            base = g[0]
            for ri, member in enumerate(g[1:], start=1):
                for field in ("neighbors", "start_ids", "tombstones"):
                    a = np.asarray(getattr(base, field))
                    b = np.asarray(getattr(member, field))
                    if not np.array_equal(a, b):
                        raise AssertionError(
                            f"shard {si} replica {ri}: {field} diverged")
                for a, b in zip(jax.tree.leaves(base.points),
                                jax.tree.leaves(member.points)):
                    if not np.array_equal(np.asarray(a), np.asarray(b)):
                        raise AssertionError(
                            f"shard {si} replica {ri}: points diverged")
                if not np.array_equal(base.ext_ids, member.ext_ids):
                    raise AssertionError(
                        f"shard {si} replica {ri}: ext_ids diverged")
                if (base.live_count, base.epoch, base.next_ext_id) != (
                        member.live_count, member.epoch, member.next_ext_id):
                    raise AssertionError(
                        f"shard {si} replica {ri}: counters diverged")
                if base.labels is not None and not np.array_equal(
                        np.asarray(base.labels), np.asarray(member.labels)):
                    raise AssertionError(
                        f"shard {si} replica {ri}: labels diverged")

    def rebuild_replica(self, shard: int, replica: int, manager, *,
                        step: Optional[int] = None, wal=None) -> LiveIndex:
        """Rebuild a lost replica from a checkpoint + WAL tail and re-admit
        it into its group.

        ``manager`` is the ``CheckpointManager`` holding the shard's last
        ``LiveIndex.save``; ``wal`` (optional) replays the mutation tail
        past the checkpoint's ``wal_seq``. Mutation replay is
        deterministic, so the rebuilt member is bit-identical to its
        surviving peers — re-check with ``assert_replica_parity``. The
        rebuilt replica does not log (the group primary keeps the WAL).
        """
        if replica == 0:
            raise ValueError("replica 0 is the primary; restore the shard "
                             "via LiveIndex.restore instead")
        idx = LiveIndex.restore(manager, step, wal=wal)
        idx.wal = None  # exactly one member of the group logs
        self.groups[shard][replica] = idx
        return idx

    def replicated_corpus(self):
        """Stack each replica column into a ``ShardedCorpus`` and wrap the
        R columns as a ``fault.ReplicatedCorpus`` (+ stacked tombstones and
        flat external ids, as ``_stacked_view`` returns) for the hedged
        host fan-out. Columns are bit-equal by the parity invariant."""
        from ..fault.replica import ReplicatedCorpus  # circular at module level
        corpus0, tomb, flat_ext = self._stacked_view()
        cap = self.shards[0].capacity
        columns = [corpus0]
        for ri in range(1, self.n_replicas):
            col = [g[ri] for g in self.groups]
            columns.append(ShardedCorpus(
                points=jax.tree.map(lambda *xs: jnp.stack(xs),
                                    *[sh.points for sh in col]),
                neighbors=jnp.stack([sh.neighbors for sh in col]),
                start_ids=jnp.stack([sh.start_ids for sh in col]),
                offsets=jnp.arange(self.n_shards, dtype=jnp.int32) * cap,
                n_total=self.n_shards * cap,
            ))
        return ReplicatedCorpus(replicas=columns), tomb, flat_ext

    # -- queries -------------------------------------------------------------
    def _stacked_view(self):
        """(ShardedCorpus, tombstones (S, W), flat ext ids (S*cap,)), cached
        per epoch vector (rebuilt only after a mutation batch)."""
        key = self.epochs()
        if self._view_cache is not None and self._view_cache[0] == key:
            return self._view_cache[1]
        cap = self.shards[0].capacity
        corpus = ShardedCorpus(
            points=jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[sh.points for sh in self.shards]),
            neighbors=jnp.stack([sh.neighbors for sh in self.shards]),
            start_ids=jnp.stack([sh.start_ids for sh in self.shards]),
            offsets=jnp.arange(self.n_shards, dtype=jnp.int32) * cap,
            n_total=self.n_shards * cap,
        )
        tomb = jnp.stack([sh.tombstones for sh in self.shards])
        flat_ext = np.concatenate([sh.ext_ids for sh in self.shards])
        view = (corpus, tomb, flat_ext)
        self._view_cache = (key, view)
        return view

    def range(self, mesh, queries, r, cfg: RangeConfig,
              es_radius=None) -> RangeResult:
        """Union range search over all shards; returned ids are EXTERNAL."""
        corpus, tomb, flat_ext = self._stacked_view()
        res = sharded_range_search(mesh=mesh, corpus=corpus,
                                   queries=jnp.asarray(queries), r=r,
                                   cfg=cfg, es_radius=es_radius,
                                   tombstones=tomb)
        return dataclasses.replace(res,
                                   ids=externalize_ids(flat_ext, res.ids))
