"""Sharded live index: shard-routed mutations + per-shard tombstones.

One ``LiveIndex`` per shard, each with its own pre-allocated capacity,
insert stream, tombstone bitset, and consolidation schedule. The router
owns the global external-id space and the ``ext -> shard`` ownership map:

* **inserts** route to the owning shard — the one with the most free
  capacity (least-loaded placement; contiguous-block ids are a build-time
  artifact the live system drops). The shard assigns slots locally and the
  router records ownership.
* **deletes** route by ownership and tombstone only the owning shard's
  bitset.
* **queries** stack the per-shard snapshots into a ``ShardedCorpus`` (+ a
  stacked ``(S, W)`` tombstone plane) and dispatch one
  ``dist.sharded_range_search`` program: every shard filters its own dead
  slots at the result stage, the union merge sees live candidates only.
  The stacked view is cached per epoch vector, so serving traffic pays the
  stack cost once per mutation batch, not per query.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.build import BuildConfig
from ..core.range_search import RangeConfig, RangeResult
from ..dist.sharded_engine import ShardedCorpus, sharded_range_search
from .index import LiveConfig, LiveIndex, externalize_ids


class LiveShardedIndex:
    """Router over per-shard ``LiveIndex`` sub-indices (uniform capacity)."""

    def __init__(self, shards: list[LiveIndex]):
        if not shards:
            raise ValueError("need at least one shard")
        cap = shards[0].capacity
        deg = shards[0].neighbors.shape[1]
        for sh in shards[1:]:
            if sh.capacity != cap or sh.neighbors.shape[1] != deg:
                raise ValueError("shards must share capacity and max degree")
            if sh.metric != shards[0].metric:
                raise ValueError("shards must share the metric")
        self.shards = shards
        self.next_ext_id = max(sh.next_ext_id for sh in shards)
        self._owner: dict[int, int] = {}
        for si, sh in enumerate(shards):
            for e in sh._slot_of:
                self._owner[e] = si
        self._view_cache: Optional[tuple] = None

    # -- construction --------------------------------------------------------
    @staticmethod
    def create(points, n_shards: int, cfg: LiveConfig,
               build_cfg: Optional[BuildConfig] = None, metric: str = "l2",
               corpus_dtype: str = "float32", seed: int = 0) -> "LiveShardedIndex":
        """Partition ``points`` into contiguous blocks, one live sub-index
        per block; ``cfg.capacity`` is the PER-SHARD capacity."""
        pts = np.asarray(points, np.float32)
        n = -(-pts.shape[0] // n_shards)
        shards = []
        for s in range(n_shards):
            block = pts[s * n:(s + 1) * n]
            shards.append(LiveIndex.create(
                block, cfg, build_cfg=build_cfg, metric=metric,
                corpus_dtype=corpus_dtype, seed=seed + s,
                first_ext_id=s * n))
        idx = LiveShardedIndex(shards)
        idx.next_ext_id = pts.shape[0]
        return idx

    # -- introspection -------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_live(self) -> int:
        return sum(sh.n_live for sh in self.shards)

    def epochs(self) -> tuple:
        return tuple(sh.epoch for sh in self.shards)

    def stats(self) -> dict:
        return dict(n_shards=self.n_shards, n_live=self.n_live,
                    epochs=list(self.epochs()),
                    shards=[sh.stats() for sh in self.shards])

    def live_vectors(self) -> tuple[np.ndarray, np.ndarray]:
        pairs = [sh.live_vectors() for sh in self.shards]
        return (np.concatenate([p[0] for p in pairs]),
                np.concatenate([p[1] for p in pairs]))

    # -- mutation ------------------------------------------------------------
    def insert(self, vecs) -> np.ndarray:
        """Route to the owning (least-loaded) shard; a batch larger than one
        shard's free space splits greedily across shards by free capacity
        (tombstoned slots count as free — the shard's insert reclaims them
        by consolidating when it must)."""
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        k = vecs.shape[0]
        if k == 0:
            return np.zeros((0,), np.int64)
        free = [sh.capacity - sh.n_live for sh in self.shards]
        if sum(free) < k:
            raise ValueError(f"insert of {k} rows exceeds the fleet's free "
                             f"capacity {sum(free)}")
        ext = self.next_ext_id + np.arange(k, dtype=np.int64)
        off = 0
        while off < k:
            si = int(np.argmax(free))
            take = min(k - off, free[si])
            self.shards[si].insert(vecs[off:off + take],
                                   ext_ids=ext[off:off + take])
            for e in ext[off:off + take]:
                self._owner[int(e)] = si
            free[si] -= take
            off += take
        self.next_ext_id += k
        return ext

    def delete(self, ext_ids) -> int:
        """Tombstone each id in its owning shard's bitset."""
        ext_ids = np.atleast_1d(np.asarray(ext_ids, np.int64))
        per_shard: dict[int, list[int]] = {}
        for e in ext_ids:
            si = self._owner.get(int(e))
            if si is not None:
                per_shard.setdefault(si, []).append(int(e))
        return sum(self.shards[si].delete(np.asarray(ids, np.int64))
                   for si, ids in per_shard.items())

    def maybe_consolidate(self) -> int:
        """Per-shard threshold check; returns shards consolidated."""
        return sum(int(sh.maybe_consolidate()) for sh in self.shards)

    # -- queries -------------------------------------------------------------
    def _stacked_view(self):
        """(ShardedCorpus, tombstones (S, W), flat ext ids (S*cap,)), cached
        per epoch vector (rebuilt only after a mutation batch)."""
        key = self.epochs()
        if self._view_cache is not None and self._view_cache[0] == key:
            return self._view_cache[1]
        cap = self.shards[0].capacity
        corpus = ShardedCorpus(
            points=jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[sh.points for sh in self.shards]),
            neighbors=jnp.stack([sh.neighbors for sh in self.shards]),
            start_ids=jnp.stack([sh.start_ids for sh in self.shards]),
            offsets=jnp.arange(self.n_shards, dtype=jnp.int32) * cap,
            n_total=self.n_shards * cap,
        )
        tomb = jnp.stack([sh.tombstones for sh in self.shards])
        flat_ext = np.concatenate([sh.ext_ids for sh in self.shards])
        view = (corpus, tomb, flat_ext)
        self._view_cache = (key, view)
        return view

    def range(self, mesh, queries, r, cfg: RangeConfig,
              es_radius=None) -> RangeResult:
        """Union range search over all shards; returned ids are EXTERNAL."""
        corpus, tomb, flat_ext = self._stacked_view()
        res = sharded_range_search(mesh=mesh, corpus=corpus,
                                   queries=jnp.asarray(queries), r=r,
                                   cfg=cfg, es_radius=es_radius,
                                   tombstones=tomb)
        return dataclasses.replace(res,
                                   ids=externalize_ids(flat_ext, res.ids))
