"""Live-update index subsystem: streaming inserts, tombstoned deletes, and
background consolidation over the frozen range-retrieval engine."""
from .consolidate import consolidate_index
from .index import FAR, LiveConfig, LiveIndex, LiveSnapshot, externalize_ids
from .sharded import LiveShardedIndex, clone_live_index

__all__ = [
    "FAR",
    "LiveConfig",
    "LiveIndex",
    "LiveSnapshot",
    "LiveShardedIndex",
    "clone_live_index",
    "consolidate_index",
    "externalize_ids",
]
