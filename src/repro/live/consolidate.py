"""Background consolidation: rewire around tombstones, then compact.

FreshDiskANN-style delete processing, adapted to the fixed-shape batch
idiom of ``core.build``:

1. **Delete-aware rewiring.** For every live node with at least one
   tombstoned out-neighbor, the new candidate set is its live one-hop
   neighbors plus the live neighbors of each dead neighbor (the patch-
   through that preserves graph navigability when a routing node leaves).
   Candidate sets that still fit the degree bound are kept verbatim;
   overflowing ones go through RobustPrune (α-domination) against exact
   distances — the same pruning the offline build and the insert path use.
   Only the rows that actually touch a tombstone are processed, compacted
   to power-of-two buckets so jit compiles O(log N) variants (the
   query-compaction trick from ``range_search``).

2. **Compaction.** Live rows move to the front of the capacity (slots
   change, external ids — owned by ``LiveIndex`` — do not), neighbor ids
   are remapped, freed slots return to the unborn-sentinel state, entry
   points are re-selected over the surviving rows, and the tombstone bitset
   resets to empty.

Two consecutive tombstoned hops are not patched through (single-hop
patching, as in FreshDiskANN): the occasional lost edge costs a little
recall until the next insert/consolidation, never correctness — results
are filtered against the exact live set regardless.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.build import BuildConfig, robust_prune
from ..core.corpus import (
    Corpus,
    corpus_raw,
    corpus_size,
    corpus_take_rows,
    corpus_with_capacity,
)
from ..core.distances import gather_dist
from ..core.graph import start_points
from ..utils import INVALID_ID, next_pow2


@partial(jax.jit, static_argnames=("cfg",))
def _prune_rows(points: jnp.ndarray, node_ids: jnp.ndarray,
                cand: jnp.ndarray, cfg: BuildConfig) -> jnp.ndarray:
    """RobustPrune a (P, C) candidate batch down to (P, R) rows.

    ``cand`` rows are already deduped/self-free/live-only (host side);
    distances are computed exactly here. Chunked ``lax.map`` bounds the
    O(C^2) dedup matrix RobustPrune builds internally."""
    def one(args):
        nid, row = args
        pvec = jnp.take(points, nid, axis=0)
        dists = gather_dist(points, row, pvec, cfg.metric)
        return robust_prune(points, pvec, row, dists, cfg.alpha,
                            cfg.max_degree, cfg.metric, self_id=nid)
    return jax.lax.map(one, (node_ids, cand), batch_size=64)


def _rewire(nbrs: np.ndarray, dead: np.ndarray, live_count: int,
            points: jnp.ndarray, cfg: BuildConfig) -> tuple[np.ndarray, dict]:
    """Replace dead out-neighbors by patching through to their live
    neighbors. Pure-numpy candidate construction; pruning on device."""
    n_cap, R = nbrs.shape
    valid = nbrs != INVALID_ID
    safe = np.where(valid, nbrs, 0)
    nbr_dead = valid & dead[safe]
    born = np.arange(n_cap) < live_count
    patch = born & ~dead & nbr_dead.any(axis=1)
    idx = np.nonzero(patch)[0]
    if idx.size == 0:
        return nbrs, dict(n_rewired=0, n_pruned=0)

    sub = nbrs[idx]                                   # (P, R)
    sub_valid = sub != INVALID_ID
    sub_safe = np.where(sub_valid, sub, 0)
    sub_dead = sub_valid & dead[sub_safe]
    one_hop = np.where(sub_valid & ~sub_dead, sub, INVALID_ID)
    # live neighbors of each dead neighbor (two-dead hops dropped)
    hop2 = nbrs[sub_safe]                             # (P, R, R)
    hop2 = np.where(sub_dead[:, :, None], hop2, INVALID_ID).reshape(idx.size, -1)
    h_valid = hop2 != INVALID_ID
    hop2 = np.where(h_valid & ~dead[np.where(h_valid, hop2, 0)],
                    hop2, INVALID_ID)
    cand = np.concatenate([one_hop, hop2], axis=1)    # (P, R + R*R)
    cand = np.where(cand == idx[:, None], INVALID_ID, cand)  # drop self
    # per-row dedup, first occurrence wins (stable sort + adjacent compare)
    order = np.argsort(cand, axis=1, kind="stable")
    srt = np.take_along_axis(cand, order, axis=1)
    dup_sorted = np.zeros_like(srt, bool)
    dup_sorted[:, 1:] = (srt[:, 1:] == srt[:, :-1]) & (srt[:, 1:] != INVALID_ID)
    dup = np.zeros_like(dup_sorted)
    np.put_along_axis(dup, order, dup_sorted, axis=1)
    cand = np.where(dup, INVALID_ID, cand)
    counts = (cand != INVALID_ID).sum(axis=1)

    out = nbrs.copy()
    # rows that still fit: keep verbatim (valid ids packed to the front)
    fits = counts <= R
    packed = np.sort(cand[fits], axis=1)[:, :R]       # INVALID sorts last
    out[idx[fits]] = packed
    # overflowing rows: RobustPrune on device, pow2-bucketed
    over = np.nonzero(~fits)[0]
    if over.size:
        bucket = next_pow2(over.size)
        sel = np.concatenate([over, np.repeat(over[:1], bucket - over.size)])
        pruned = np.asarray(_prune_rows(
            points, jnp.asarray(idx[sel], jnp.int32),
            jnp.asarray(cand[sel], jnp.int32), cfg))
        out[idx[over]] = pruned[:over.size]
    return out, dict(n_rewired=int(idx.size), n_pruned=int(over.size))


def consolidate_index(points: Corpus, neighbors: jnp.ndarray,
                      dead: np.ndarray, live_count: int, cfg: BuildConfig,
                      metric: str, n_starts: int, far: float = 1e30):
    """Full consolidation pass.

    Returns ``(points, neighbors, start_ids, perm, stats)`` where ``perm``
    (n_live,) lists the OLD slots of the surviving rows in their new slot
    order (new slot i holds old slot perm[i]) — the caller remaps its
    slot-keyed metadata (external ids) with it.
    """
    capacity = corpus_size(points)
    raw = corpus_raw(points)
    nbrs = np.asarray(neighbors)
    rewired, stats = _rewire(nbrs, dead, live_count, raw, cfg)

    born = np.arange(capacity) < live_count
    perm = np.nonzero(born & ~dead)[0]
    n_live = perm.shape[0]
    if n_live == 0:
        raise ValueError("consolidation would empty the index")
    mapping = np.full(capacity, INVALID_ID, np.int32)
    mapping[perm] = np.arange(n_live, dtype=np.int32)

    sub = rewired[perm]
    sub_valid = sub != INVALID_ID
    new_rows = np.where(sub_valid, mapping[np.where(sub_valid, sub, 0)],
                        INVALID_ID)  # dead/unborn targets -> INVALID (defense)
    new_nbrs = np.full((capacity, nbrs.shape[1]), INVALID_ID, np.int32)
    new_nbrs[:n_live] = new_rows

    live_pts = corpus_take_rows(points, jnp.asarray(perm, jnp.int32))
    new_points = corpus_with_capacity(live_pts, capacity, far)
    new_starts = start_points(corpus_raw(live_pts).astype(jnp.float32),
                              metric, n_starts)
    return (new_points, jnp.asarray(new_nbrs), new_starts, perm, stats)
