"""LiveIndex: a mutable range-retrieval index over a pre-allocated capacity.

The paper's engine (and every layer above it) assumes a frozen Vamana graph;
the stated applications — duplicate detection, facial recognition — churn
continuously. This module makes the index mutable without giving up any of
the fixed-shape jitted machinery:

* **Capacity + watermark.** The corpus and adjacency are pre-allocated at a
  fixed ``capacity`` (``N_cap`` rows); ``live_count`` is the high-water mark.
  Rows past the watermark are unreachable sentinels (no in-edges, ``far``
  coordinates — the same convention as the sharded pad rows), so the search
  programs never recompile as the index grows: every mutation step runs at
  the same shapes.

* **Streaming inserts** reuse the offline build's batch machinery
  (``core.build.insert_batch_step``: beam search + RobustPrune + reverse-edge
  patching with overflow pruning) as incremental steps — one jitted program
  compiled once per (capacity, insert_batch) pair, executed per batch of
  inserts. New rows are written behind the watermark first (quantized on the
  way in for int8 corpora, with exact per-row ``err`` metadata), then wired
  into the graph. External ids are assigned monotonically and survive
  consolidation; internal slots are an implementation detail.

* **Labels** (optional) live in a capacity-sized packed (N_cap, W) uint32
  store next to the corpus: inserts carry per-row label rows (riding the
  WAL with the vectors), consolidation moves rows with their slots, and
  snapshots accept per-query ``filter=`` predicates evaluated at the same
  result stage as the tombstone filter.

* **Lazy deletes** set bits in a packed tombstone bitset (``core.bitset``,
  sized exactly over the capacity — never hashed, a false positive would
  drop live results). Deleted nodes keep their vectors and edges: the
  traversal routes *through* them unperturbed (FreshDiskANN semantics), and
  ``core.range_search.filter_tombstoned`` drops them at the result stage.

* **Background consolidation** (``repro.live.consolidate``) rewires the
  in-graph around tombstoned nodes with delete-aware RobustPrune and
  compacts the live rows to the front of the capacity, reclaiming slots,
  once the tombstone fraction crosses ``LiveConfig.consolidate_at``.

* **Epoch/snapshot layer.** Every mutation batch bumps ``epoch`` and (being
  functional ``jnp`` updates) yields fresh arrays; ``snapshot()`` captures a
  consistent ``(graph, corpus, tombstones, epoch)`` view that stays valid —
  and immutable — no matter how the index mutates afterwards. The server
  refreshes its view only at micro-batch boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.build import BuildConfig, build_vamana, insert_batch_step
from ..core.corpus import (
    Corpus,
    corpus_cast,
    corpus_dtype_name,
    corpus_raw,
    corpus_set_rows,
    corpus_with_capacity,
)
from ..core.engine import RangeSearchEngine
from ..core.graph import Graph, start_points
from ..core.range_search import (
    RangeConfig,
    RangeResult,
    range_search_compacted,
    range_search_fused,
)
from ..core.beam_search import SearchConfig
from ..utils import INVALID_ID, cdiv
from .consolidate import consolidate_index

# Sentinel coordinate for unborn rows (matches dist.sharded_engine._FAR).
FAR = 1e30

_set_rows = jax.jit(corpus_set_rows)


def externalize_ids(ext_ids: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Map a result buffer of slot ids to external ids (INVALID passes
    through). Shared by the single-index snapshot and the sharded router —
    any change to the clamping/INVALID handling belongs here."""
    ids = np.asarray(ids)
    valid = ids != INVALID_ID
    return np.where(valid,
                    np.asarray(ext_ids)[np.where(valid, ids, 0)],
                    np.int64(INVALID_ID)).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class LiveConfig:
    """Static configuration of a live index."""

    capacity: int                 # N_cap: pre-allocated corpus rows
    insert_batch: int = 128       # fixed width of the jitted insert step
    consolidate_at: float = 0.25  # tombstone fraction that triggers rewiring
    n_starts: int = 4             # search entry points

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError("capacity must be >= 1")
        if self.insert_batch < 1:
            raise ValueError("insert_batch must be >= 1")
        if not (0.0 < self.consolidate_at <= 1.0):
            raise ValueError("consolidate_at must be in (0, 1]")


@dataclasses.dataclass(frozen=True)
class LiveSnapshot:
    """An immutable, consistent view of the index at one epoch.

    Everything a query needs travels together: search the snapshot and the
    answer is coherent even while the owning ``LiveIndex`` keeps mutating
    (jnp arrays are immutable; mutations produce new arrays)."""

    points: Corpus            # (N_cap, d) corpus (rows past watermark: FAR)
    graph: Graph              # (N_cap, R) adjacency
    start_ids: jnp.ndarray    # (S,) entry slots
    tombstones: jnp.ndarray   # (W,) uint32 exact dead-slot bitset
    ext_ids: np.ndarray       # (N_cap,) int64 slot -> external id (host)
    live_count: int           # watermark (born slots, incl. tombstoned)
    n_dead: int               # tombstoned slots
    epoch: int
    metric: str
    # (N_cap, W) uint32 packed per-slot label rows, or None (unlabeled).
    # Unborn/reclaimed slots carry zero rows — matched by no non-trivial
    # predicate, and unreachable regardless.
    labels: Optional[jnp.ndarray] = None

    @property
    def n_live(self) -> int:
        return self.live_count - self.n_dead

    def range(self, queries, r, *, cfg: Optional[RangeConfig] = None,
              es_radius=None, compacted: bool = True,
              filter=None) -> RangeResult:
        """Range search over the live set; returned ids are EXTERNAL ids.

        Tombstoned slots still route the walk (the filter is result-stage
        only) and unborn slots are unreachable, so the traversal is the
        frozen engine's program at the snapshot's shapes. ``filter`` is a
        per-query :class:`~repro.core.labels.LabelFilter` over the
        snapshot's attached ``labels`` (filtered-out points route but never
        answer, same as tombstones). Arguments past ``(queries, r)`` are
        keyword-only (shared order with ``engine.range``)."""
        cfg = cfg or RangeConfig(search=SearchConfig(metric=self.metric))
        if cfg.search.metric != self.metric:
            cfg = dataclasses.replace(cfg, search=dataclasses.replace(
                cfg.search, metric=self.metric))
        if filter is not None and self.labels is None:
            raise ValueError(
                "snapshot has no labels attached; create the LiveIndex with "
                "labels= to use filtered range search")
        fn = range_search_compacted if compacted else range_search_fused
        res = fn(corpus=self.points, graph=self.graph,
                 queries=jnp.asarray(queries), start_ids=self.start_ids,
                 r=r, cfg=cfg, es_radius=es_radius,
                 tombstones=self.tombstones,
                 labels=None if filter is None else self.labels,
                 label_filter=filter)
        return self._externalize(res)

    def _externalize(self, res: RangeResult) -> RangeResult:
        return dataclasses.replace(res,
                                   ids=externalize_ids(self.ext_ids, res.ids))

    def as_engine(self) -> RangeSearchEngine:
        """Slot-id engine view (introspection / stats); queries through the
        engine see slot ids and NO tombstone filter — use ``range``."""
        return RangeSearchEngine(points=self.points, graph=self.graph,
                                 start_ids=self.start_ids, labels=self.labels,
                                 metric=self.metric)


class LiveIndex:
    """Mutable wrapper around the immutable engine state (host orchestrator).

    All array state is functional (every mutation produces new jnp arrays),
    so any ``snapshot()`` taken earlier remains consistent. The host keeps
    two pieces of bookkeeping the arrays cannot answer in O(1): the
    ``ext -> slot`` hash index for delete routing, and the dead-slot set for
    idempotent deletes.
    """

    def __init__(self, *, points: Corpus, neighbors: jnp.ndarray,
                 start_ids: jnp.ndarray, ext_ids: np.ndarray,
                 tombstones: jnp.ndarray, live_count: int, next_ext_id: int,
                 epoch: int, metric: str, build_cfg: BuildConfig,
                 cfg: LiveConfig, dead_slots: Optional[set] = None,
                 labels: Optional[jnp.ndarray] = None):
        self.points = points
        self.labels = labels
        self.neighbors = neighbors
        self.start_ids = start_ids
        self.ext_ids = ext_ids
        self.tombstones = tombstones
        self.live_count = int(live_count)
        self.next_ext_id = int(next_ext_id)
        self.epoch = int(epoch)
        self.metric = metric
        self.build_cfg = build_cfg
        self.cfg = cfg
        self._dead: set[int] = set() if dead_slots is None else set(dead_slots)
        self._slot_of: dict[int, int] = {
            int(ext_ids[s]): s for s in range(self.live_count)
            if ext_ids[s] != INVALID_ID}
        # crash safety (repro.fault.wal): when a WAL is attached, every
        # public mutation batch logs one checksummed record BEFORE applying;
        # wal_seq is the mutation sequence number — distinct from epoch,
        # which can advance more than once inside a single insert (internal
        # consolidation). _replaying/_suppress_log gate re-logging during
        # WAL replay and insert-internal consolidations (the latter are
        # reproduced deterministically by replaying the insert record).
        self.wal = None
        self.wal_seq = 0
        self._replaying = False
        self._suppress_log = False

    # -- write-ahead log -----------------------------------------------------
    def attach_wal(self, wal) -> None:
        """Log every subsequent mutation batch to ``wal``
        (``repro.fault.WriteAheadLog``) before it applies. Any torn tail
        from a previous crash is truncated first so new records land after
        the durable prefix; ``wal_seq`` resumes past the log's last
        record."""
        wal.truncate_torn_tail()
        self.wal = wal
        self.wal_seq = max(self.wal_seq, wal.last_seq)

    def _log(self, op: str, arrays: Optional[dict] = None) -> None:
        if self.wal is None or self._replaying or self._suppress_log:
            return
        self.wal_seq += 1
        self.wal.append(self.wal_seq, op, arrays or {})

    def _apply_record(self, rec) -> None:
        """Replay one WAL record through the public mutation path — the
        same deterministic code that produced it, minus the re-logging."""
        if rec.op == "insert":
            self.insert(rec.arrays["vecs"], ext_ids=rec.arrays["ext_ids"],
                        labels=rec.arrays.get("labels"))
        elif rec.op == "delete":
            self.delete(rec.arrays["ext_ids"])
        elif rec.op == "consolidate":
            self.consolidate()
        else:
            raise ValueError(f"unknown WAL op {rec.op!r}")

    # -- construction --------------------------------------------------------
    @staticmethod
    def create(points, cfg: LiveConfig,
               build_cfg: Optional[BuildConfig] = None, metric: str = "l2",
               corpus_dtype: str = "float32", seed: int = 0,
               first_ext_id: int = 0,
               graph: Optional[Graph] = None,
               labels=None,
               tier: bool = False,
               resident_mb: Optional[float] = None) -> "LiveIndex":
        """Build the initial frozen index, then pre-allocate it to capacity.

        ``first_ext_id`` offsets external-id assignment (the sharded router
        hands each shard a disjoint id space). Passing ``graph`` skips the
        Vamana build and promotes an existing frozen index to a live one
        (it must have been built on these exact ``points``).

        ``labels`` (optional) is the (n0, W) packed label matrix
        (``core.labels.pack_labels``) for the initial rows; attaching it
        makes the index labeled — inserts may then carry per-row label rows
        and snapshots accept ``filter=`` predicates. The label store is
        pre-allocated to capacity alongside the corpus (zero rows for
        unborn slots).

        ``tier=True`` splits the capacity-padded corpus into a
        ``repro.tier.TieredCorpus``: codes/meta (or the cast array) stay
        device-resident while the raw rerank rows — including the FAR
        sentinel rows of unborn slots — live in a host-RAM row store that
        inserts write through and consolidation compacts. ``resident_mb``
        caps the device row cache."""
        pts = jnp.asarray(points, jnp.float32)
        n0 = pts.shape[0]
        if n0 > cfg.capacity:
            raise ValueError(f"initial corpus {n0} exceeds capacity "
                             f"{cfg.capacity}")
        bcfg = build_cfg or BuildConfig(metric=metric)
        if graph is None:
            graph = build_vamana(pts, bcfg, seed=seed)
        elif graph.num_nodes != n0:
            raise ValueError("graph was not built on these points")
        starts = start_points(pts, metric, cfg.n_starts)
        stored = corpus_with_capacity(corpus_cast(pts, corpus_dtype),
                                      cfg.capacity, FAR)
        if corpus_dtype == "int8":
            corpus_raw(stored)  # live int8 requires raw vectors — fail early
        if tier:
            # deferred import: live stays importable without repro.tier
            from ..tier import tiered_corpus
            stored = tiered_corpus(stored, corpus_dtype=corpus_dtype,
                                   resident_mb=resident_mb)
        nbrs = jnp.concatenate(
            [graph.neighbors,
             jnp.full((cfg.capacity - n0, graph.max_degree), INVALID_ID,
                      jnp.int32)]) if cfg.capacity > n0 else graph.neighbors
        ext = np.full(cfg.capacity, INVALID_ID, np.int64)
        ext[:n0] = first_ext_id + np.arange(n0)
        lab = None
        if labels is not None:
            labels = np.asarray(labels, np.uint32)
            if labels.shape[0] != n0:
                raise ValueError(
                    f"labels rows ({labels.shape[0]}) != initial corpus "
                    f"size ({n0})")
            lab = np.zeros((cfg.capacity, labels.shape[1]), np.uint32)
            lab[:n0] = labels
            lab = jnp.asarray(lab)
        return LiveIndex(
            points=stored, neighbors=nbrs, start_ids=starts, ext_ids=ext,
            tombstones=jnp.zeros((cdiv(cfg.capacity, 32),), jnp.uint32),
            live_count=n0, next_ext_id=first_ext_id + n0, epoch=0,
            metric=metric, build_cfg=bcfg, cfg=cfg, labels=lab)

    # -- introspection -------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.cfg.capacity

    @property
    def n_dead(self) -> int:
        return len(self._dead)

    @property
    def n_live(self) -> int:
        return self.live_count - self.n_dead

    @property
    def free_slots(self) -> int:
        return self.capacity - self.live_count

    def tombstone_frac(self) -> float:
        return self.n_dead / max(self.live_count, 1)

    def corpus_dtype(self) -> str:
        return corpus_dtype_name(self.points)

    def stats(self) -> dict:
        return dict(capacity=self.capacity, live_count=self.live_count,
                    n_live=self.n_live, n_dead=self.n_dead,
                    free_slots=self.free_slots, epoch=self.epoch,
                    tombstone_frac=round(self.tombstone_frac(), 4),
                    metric=self.metric, corpus_dtype=self.corpus_dtype())

    def live_vectors(self) -> tuple[np.ndarray, np.ndarray]:
        """(external ids (M,), exact f32 vectors (M, d)) of the live set —
        the reference the churn-vs-oracle harness scans."""
        slots = np.array([s for s in range(self.live_count)
                          if s not in self._dead], np.int64)
        raw = np.asarray(corpus_raw(self.points), np.float32)
        if slots.size == 0:
            return slots, np.zeros((0, raw.shape[1]), np.float32)
        return self.ext_ids[slots], raw[slots]

    # -- snapshots -----------------------------------------------------------
    def snapshot(self) -> LiveSnapshot:
        return LiveSnapshot(points=self.points, graph=Graph(self.neighbors),
                            start_ids=self.start_ids,
                            tombstones=self.tombstones,
                            ext_ids=self.ext_ids.copy(),
                            live_count=self.live_count, n_dead=self.n_dead,
                            epoch=self.epoch, metric=self.metric,
                            labels=self.labels)

    def range(self, queries, r, *, cfg: Optional[RangeConfig] = None,
              es_radius=None, compacted: bool = True,
              filter=None) -> RangeResult:
        return self.snapshot().range(queries, r, cfg=cfg,
                                     es_radius=es_radius, compacted=compacted,
                                     filter=filter)

    # -- mutation: inserts ---------------------------------------------------
    def insert(self, vecs, ext_ids=None, labels=None) -> np.ndarray:
        """Insert ``vecs`` (k, d); returns their assigned external ids.

        Rows are written behind the watermark (quantized on the way in when
        the corpus is int8), then wired into the graph by the shared
        fixed-shape build step in ``insert_batch`` chunks — reverse edges
        included, overflowing rows RobustPruned. One epoch per call.

        ``labels`` (labeled index only) is the (k, W) packed label rows for
        the inserted vectors; omitted rows get zero labels (matched by no
        non-trivial predicate).

        With a WAL attached, the batch logs (resolved ext_ids + vecs +
        label rows) after validation but before ANY state change —
        validation runs first so a record is never logged for an insert
        that raises, and the log-then-apply order means a crash at any
        later point replays to the same state. An insert-internal
        consolidation (capacity reclaim) is not logged separately:
        replaying the insert record reproduces it."""
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        k = vecs.shape[0]
        if k == 0:
            return np.zeros((0,), np.int64)
        if self.n_live + k > self.capacity:
            raise ValueError(
                f"insert of {k} rows exceeds capacity {self.capacity} "
                f"(live_count={self.live_count}); consolidation could not "
                f"reclaim enough slots")
        if ext_ids is None:
            ext_ids = self.next_ext_id + np.arange(k, dtype=np.int64)
        else:
            ext_ids = np.asarray(ext_ids, np.int64)
            if ext_ids.shape != (k,):
                raise ValueError("ext_ids must have one id per inserted row")
            dup = [int(e) for e in ext_ids if int(e) in self._slot_of]
            if dup:
                raise ValueError(f"external ids already present: {dup[:5]}")
        if labels is not None and self.labels is None:
            raise ValueError(
                "index has no labels attached; create(..., labels=) to "
                "insert labeled rows")
        lab_rows = None
        if self.labels is not None:
            w = self.labels.shape[1]
            if labels is None:
                lab_rows = np.zeros((k, w), np.uint32)
            else:
                lab_rows = np.asarray(labels, np.uint32)
                if lab_rows.shape != (k, w):
                    raise ValueError(
                        f"labels shape {lab_rows.shape} != ({k}, {w})")
        rec = dict(ext_ids=ext_ids, vecs=vecs)
        if lab_rows is not None:
            rec["labels"] = lab_rows
        self._log("insert", rec)
        if self.live_count + k > self.capacity and self._dead:
            # reclaim tombstoned slots before giving up; unlogged — replay
            # of the insert record re-triggers it deterministically
            self._suppress_log = True
            try:
                self.consolidate()
            finally:
                self._suppress_log = False
        B = self.cfg.insert_batch
        d = vecs.shape[1]
        for off in range(0, k, B):
            chunk = vecs[off:off + B]
            b = chunk.shape[0]
            slots = np.arange(self.live_count, self.live_count + b,
                              dtype=np.int32)
            # fixed-width padded write (inactive lanes scatter-dropped)
            slots_p = np.zeros(B, np.int32)
            slots_p[:b] = slots
            vecs_p = np.zeros((B, d), np.float32)
            vecs_p[:b] = chunk
            active = np.arange(B) < b
            if getattr(self.points, "is_tiered", False):
                # hot arm updates through the same jitted step; the raw
                # rows write through to the host store. Fresh slots sit
                # behind every published snapshot's watermark (and past
                # consolidation's fresh cache), but invalidate anyway so
                # a stale cache line can never alias a rewritten row.
                t = self.points
                dev = _set_rows(t.device, jnp.asarray(slots_p),
                                jnp.asarray(vecs_p), jnp.asarray(active))
                t.store.write(slots, chunk)
                t.cache.invalidate(slots)
                self.points = t.with_device(dev)
            else:
                self.points = _set_rows(self.points, jnp.asarray(slots_p),
                                        jnp.asarray(vecs_p),
                                        jnp.asarray(active))
            if lab_rows is not None:
                self.labels = self.labels.at[jnp.asarray(slots)].set(
                    jnp.asarray(lab_rows[off:off + b]))
            batch = np.full(B, INVALID_ID, np.int32)
            batch[:b] = slots
            self.neighbors = insert_batch_step(
                corpus_raw(self.points), self.neighbors, jnp.asarray(batch),
                self.start_ids, self.build_cfg, self.build_cfg.alpha)
            for j, s in enumerate(slots):
                e = int(ext_ids[off + j])
                self.ext_ids[s] = e
                self._slot_of[e] = int(s)
            self.live_count += b
        self.next_ext_id = max(self.next_ext_id, int(ext_ids.max()) + 1)
        self.epoch += 1
        return ext_ids

    # -- mutation: deletes ---------------------------------------------------
    def delete(self, ext_ids) -> int:
        """Tombstone the given external ids (lazy delete). Unknown or
        already-deleted ids are skipped; returns how many were newly
        tombstoned. The vectors and edges stay until consolidation, so
        deleted nodes keep routing searches."""
        ext_ids = np.atleast_1d(np.asarray(ext_ids, np.int64))
        slots, seen = [], set()
        for e in ext_ids:
            s = self._slot_of.get(int(e))
            if s is not None and s not in self._dead and s not in seen:
                slots.append(s)
                seen.add(s)
        if slots:
            # log the REQUESTED ids before applying (idempotent on replay)
            self._log("delete", dict(ext_ids=ext_ids))
            from ..core.bitset import bitset_add  # local: avoid cycle at import
            self._dead.update(slots)
            sl = jnp.asarray(np.asarray(slots, np.int32))
            # fresh unique slots with clear bits: the add is exact
            self.tombstones = bitset_add(self.tombstones, sl,
                                         jnp.ones(sl.shape, bool))
            self.epoch += 1
        return len(slots)

    # -- consolidation -------------------------------------------------------
    def maybe_consolidate(self) -> bool:
        """Consolidate iff the tombstone fraction crossed the threshold."""
        if (self._dead and self.n_live > 0
                and self.tombstone_frac() >= self.cfg.consolidate_at):
            self.consolidate()
            return True
        return False

    def consolidate(self) -> dict:
        """Rewire around tombstoned nodes (delete-aware RobustPrune) and
        compact live rows to the front of the capacity. External ids are
        stable; slots move. One epoch.

        A fully-deleted index is a no-op (nothing live to rebuild entry
        points from; the tombstones keep filtering every result) — the
        serving path must never crash on legitimate delete-everything
        traffic."""
        if not self._dead or self.n_live == 0:
            return dict(n_rewired=0, n_live=self.n_live, reclaimed=0)
        self._log("consolidate")
        dead = np.zeros(self.capacity, bool)
        dead[np.asarray(sorted(self._dead), np.int64)] = True
        tier = self.points if getattr(self.points, "is_tiered", False) else None
        pts = self.points
        if tier is not None:
            # compose a temporary resident corpus (device hot arm + host
            # store raw) for the rewiring pass; re-split below
            pts = (dataclasses.replace(tier.device, raw=tier.raw_array())
                   if tier.quantized else tier.device)
        out = consolidate_index(
            pts, self.neighbors, dead, self.live_count,
            self.build_cfg, self.metric, self.cfg.n_starts, far=FAR)
        new_points, new_neighbors, new_starts, perm, stats = out
        reclaimed = self.live_count - perm.shape[0]
        if tier is not None:
            from ..tier import DeviceRowCache, HostRowStore, TieredCorpus
            if tier.quantized:
                raw_np = np.asarray(jax.device_get(new_points.raw), np.float32)
                dev = dataclasses.replace(new_points, raw=None)
            else:
                raw_np = np.asarray(jax.device_get(new_points), np.float32)
                dev = new_points
            # compaction moved slots, so stale cache lines would alias old
            # rows: the rebuilt tier starts with an empty cache over a NEW
            # store (the old store stays valid for old snapshots)
            new_points = TieredCorpus(
                dev, HostRowStore(raw_np),
                DeviceRowCache(tier.cache.dim, tier.cache.capacity),
                tier.counters, tier.fetch_bucket)
        self.points = new_points
        self.neighbors = new_neighbors
        self.start_ids = new_starts
        ext = np.full(self.capacity, INVALID_ID, np.int64)
        ext[:perm.shape[0]] = self.ext_ids[perm]
        self.ext_ids = ext
        if self.labels is not None:  # labels move with their rows
            lab = np.asarray(self.labels)
            new_lab = np.zeros_like(lab)
            new_lab[:perm.shape[0]] = lab[np.asarray(perm)]
            self.labels = jnp.asarray(new_lab)
        self.live_count = int(perm.shape[0])
        self.tombstones = jnp.zeros_like(self.tombstones)
        self._dead = set()
        self._slot_of = {int(ext[s]): s for s in range(self.live_count)}
        self.epoch += 1
        return dict(reclaimed=reclaimed, n_live=self.live_count, **stats)

    # -- checkpoint round-trip ----------------------------------------------
    def save(self, manager, step: Optional[int] = None) -> str:
        """Write the full mutable state through ``train.CheckpointManager``
        (atomic + fsynced, keep-k). ``step`` defaults to the current epoch.
        ``counters`` records ``wal_seq`` so ``restore`` replays only the WAL
        tail past this snapshot; after the save returns (durable), the WAL
        may be pruned through that sequence (``wal.prune_through``)."""
        from ..core.corpus import QuantizedCorpus
        state = dict(
            neighbors=self.neighbors,
            start_ids=self.start_ids,
            tombstones=self.tombstones,
            ext_ids=self.ext_ids,
            counters=np.asarray(
                [self.live_count, self.next_ext_id, self.epoch,
                 self.wal_seq], np.int64),
        )
        tier = self.points if getattr(self.points, "is_tiered", False) else None
        pts = tier.device if tier is not None else self.points
        if isinstance(pts, QuantizedCorpus):
            state["codes"] = pts.codes
            state["meta"] = pts.meta
            # tiered: raw comes straight from the host store — the SAME
            # bytes queries rerank against, so store and manifest can
            # never disagree about what a restored index answers
            state["raw"] = (np.ascontiguousarray(tier.store.to_array())
                            if tier is not None else pts.raw)
        else:
            state["points"] = pts
            if tier is not None:  # degenerate float tier: store rides too
                state["raw"] = np.ascontiguousarray(tier.store.to_array())
        if self.labels is not None:
            state["labels"] = self.labels
        extra = dict(
            kind="live_index", metric=self.metric,
            corpus_dtype=self.corpus_dtype(),
            live=dataclasses.asdict(self.cfg),
            build=dataclasses.asdict(self.build_cfg),
        )
        if tier is not None:
            extra["tier"] = dict(cache_rows=int(tier.cache.capacity),
                                 fetch_bucket=int(tier.fetch_bucket))
        return manager.save(self.epoch if step is None else step, state,
                            extra=extra)

    @staticmethod
    def restore(manager, step: Optional[int] = None,
                *, wal=None) -> "LiveIndex":
        """Rebuild a ``LiveIndex`` from a checkpoint written by ``save``.

        Host-side bookkeeping (the ext->slot hash index and the dead-slot
        set) is reconstructed from the arrays.

        ``wal`` (a ``repro.fault.WriteAheadLog``) enables crash recovery:
        the checksum-valid records with ``seq`` past the checkpoint's
        ``wal_seq`` replay through the public mutation path (any torn tail
        from the crash is dropped by the reader, then truncated so the log
        can take new appends), and the WAL stays attached for subsequent
        mutations. Because every mutation is deterministic, the recovered
        state is bit-identical to an uninterrupted run over the durable
        records."""
        from ..core.bitset import bitset_contains
        from ..core.corpus import QuantizedCorpus
        tier_extra = manager.manifest(step)["extra"].get("tier")
        # tiered checkpoints restore the raw rows as a copy-on-write
        # memory map that backs the host store directly — never HBM
        flat, manifest = manager.restore_flat(
            step, mmap=("raw",) if tier_extra is not None else None)
        extra = manifest["extra"]
        if extra.get("kind") != "live_index":
            raise ValueError("checkpoint was not written by LiveIndex.save")
        if "points" in flat:
            points = flat["points"]
        else:
            points = QuantizedCorpus(
                codes=flat["codes"], meta=flat["meta"],
                raw=None if tier_extra is not None else flat["raw"])
        if tier_extra is not None:
            from ..tier import DeviceRowCache, HostRowStore, TieredCorpus
            raw = flat["raw"]
            points = TieredCorpus(
                points, HostRowStore(raw, copy=False),
                DeviceRowCache(raw.shape[1], tier_extra["cache_rows"]),
                fetch_bucket=tier_extra["fetch_bucket"])
        counters = [int(x) for x in np.asarray(flat["counters"])]
        # pre-WAL checkpoints carry 3 counters; wal_seq defaults to 0
        live_count, next_ext_id, epoch = counters[:3]
        wal_seq = counters[3] if len(counters) > 3 else 0
        tomb = jnp.asarray(flat["tombstones"], jnp.uint32)
        born = jnp.arange(live_count, dtype=jnp.int32)
        dead = set(np.nonzero(np.asarray(
            bitset_contains(tomb, born)))[0].tolist()) if live_count else set()
        idx = LiveIndex(
            points=points,
            neighbors=jnp.asarray(flat["neighbors"], jnp.int32),
            start_ids=jnp.asarray(flat["start_ids"], jnp.int32),
            ext_ids=np.asarray(flat["ext_ids"], np.int64),
            tombstones=tomb, live_count=live_count, next_ext_id=next_ext_id,
            epoch=epoch, metric=extra["metric"],
            build_cfg=BuildConfig(**extra["build"]),
            cfg=LiveConfig(**extra["live"]), dead_slots=dead,
            # pre-label checkpoints simply have no "labels" entry
            labels=(jnp.asarray(flat["labels"], jnp.uint32)
                    if "labels" in flat else None))
        idx.wal_seq = wal_seq
        if wal is not None:
            idx._replaying = True
            try:
                for rec in wal.replay(after_seq=wal_seq):
                    idx._apply_record(rec)
                    idx.wal_seq = rec.seq
            finally:
                idx._replaying = False
            idx.attach_wal(wal)
        return idx
