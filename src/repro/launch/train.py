"""Training launcher: real end-to-end runs on whatever devices exist.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-27b --smoke \\
      --steps 200 --ckpt-dir /tmp/ckpt

``--smoke`` uses the arch's reduced() config (CPU-trainable geometry of the
same family); without it the full published config is used (requires real
accelerators). The loop is the fault-tolerant Trainer: atomic checkpoints,
resume-from-latest, SIGTERM-safe.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import sys

import jax
import jax.numpy as jnp

from ..configs import get_arch
from ..data.graphs import make_sbm_graph
from ..data.lm import LMDataConfig, lm_batches
from ..data.recsys import RecsysDataConfig, recsys_batches
from ..models import gcn as gcn_mod
from ..models import recsys as rec_mod
from ..models import transformer as tf_mod
from ..train import Trainer, TrainerConfig


def build_training(arch_id: str, smoke: bool, batch: int, seq_len: int,
                   seed: int = 0):
    arch = get_arch(arch_id)
    cfg = arch.reduced() if smoke else arch.model_cfg
    key = jax.random.PRNGKey(seed)
    if arch.family == "lm":
        params = tf_mod.init_transformer(key, cfg)
        loss = functools.partial(tf_mod.loss_fn, cfg=cfg)
        data = lm_batches(LMDataConfig(vocab=cfg.vocab, seq_len=seq_len,
                                       batch=batch, seed=seed))
        return params, loss, data
    if arch.family == "gnn":
        g = make_sbm_graph(400, cfg.n_classes, cfg.d_feat, avg_degree=8,
                           seed=seed)
        params = gcn_mod.init_gcn(key, cfg)
        loss = functools.partial(gcn_mod.gcn_loss, cfg=cfg)

        def batches():
            b = {"feats": jnp.asarray(g.feats),
                 "edge_src": jnp.asarray(g.edge_src),
                 "edge_dst": jnp.asarray(g.edge_dst),
                 "labels": jnp.asarray(g.labels)}
            while True:
                yield b
        return params, loss, batches()
    if arch.family == "recsys":
        params = rec_mod.init_recsys(key, cfg)
        loss = functools.partial(rec_mod.recsys_loss, cfg=cfg)
        dcfg = RecsysDataConfig(
            n_dense=cfg.n_dense, n_sparse=cfg.n_sparse, vocab=cfg.vocab,
            batch=batch, seed=seed, two_tower=cfg.kind == "two_tower",
            n_sparse_item=cfg.n_sparse_item)
        return params, loss, recsys_batches(dcfg)
    raise ValueError(f"{arch_id}: family {arch.family} has no train driver")


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--lr", type=float, default=None)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--metrics", default=None)
    p.add_argument("--resume", action="store_true")
    args = p.parse_args(argv)

    arch = get_arch(args.arch)
    params, loss, data = build_training(args.arch, args.smoke, args.batch,
                                        args.seq_len)
    opt_cfg = arch.opt_cfg
    if args.lr is not None:
        opt_cfg = dataclasses.replace(opt_cfg, lr=args.lr)
    opt_cfg = dataclasses.replace(opt_cfg, total_steps=args.steps)
    tr = Trainer(loss, params, opt_cfg,
                 TrainerConfig(total_steps=args.steps,
                               ckpt_every=args.ckpt_every, log_every=10,
                               ckpt_dir=args.ckpt_dir,
                               metrics_path=args.metrics))
    if args.resume and tr.maybe_restore():
        print(f"[train] resumed from step {tr.step}")
    out = tr.fit(data, verbose=True)
    print(f"[train] done at step {out['final_step']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
