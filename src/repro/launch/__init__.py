"""Launchers: mesh.py, steps.py (cell builder), dryrun.py, train.py, serve.py.

Deliberately empty of imports: ``python -m repro.launch.dryrun`` imports
this package BEFORE dryrun's first lines run, and dryrun must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before anything
touches jax.
"""
