import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the first import in the process (the two lines above run before any
other import — jax locks the device count at first init).

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 cells, 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod # 2x16x16

Per cell: jit(step).lower(*ShapeDtypeStructs).compile() under the
production mesh; prints memory_analysis (fits?) and cost_analysis
(FLOPs/bytes for §Roofline); parses the HLO for collective bytes; appends a
RooflineReport row to --report (JSON).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

from ..analysis.hlo import analyze_module  # noqa: E402
from ..analysis.roofline import analytic_model_flops, make_report  # noqa: E402
from ..configs import all_cells, get_arch  # noqa: E402
from ..dist.sharding import activation_sharding  # noqa: E402
from .mesh import make_production_mesh, mesh_devices  # noqa: E402
from .steps import build_cell  # noqa: E402


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             verbose: bool = True):
    arch = get_arch(arch_id)
    shape = arch.shapes[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh_devices(mesh)
    t0 = time.time()
    with mesh, activation_sharding(mesh):
        cell = build_cell(arch, shape_name, mesh)
        lowered = cell.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    analysis = analyze_module(compiled.as_text())
    params_abstract = cell.args[0]
    model_flops = analytic_model_flops(arch, shape, params_abstract)
    report = make_report(arch, shape, mesh_name, chips, cost, mem, analysis,
                         model_flops)
    if verbose:
        print(f"== {arch_id} x {shape_name} on {mesh_name} "
              f"({chips} chips)  [lower {t_lower:.1f}s compile {t_compile:.1f}s]")
        print(f"   memory_analysis: {mem}")
        print(f"   cost_analysis: flops={cost.get('flops', 0):.4g} "
              f"bytes={cost.get('bytes accessed', 0):.4g}")
        print(f"   collectives: {analysis.collectives.summary()}")
        print(f"   whiles={analysis.n_while} max_trip={analysis.max_trip} dot_flops/dev={analysis.dot_flops:.4g}")
        print(f"   roofline: {report.row()}")
    return report


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--include-engine", action="store_true")
    p.add_argument("--report", default=None, help="append JSON reports here")
    p.add_argument("--keep-going", action="store_true")
    args = p.parse_args(argv)

    if args.all:
        cells = all_cells(include_engine=args.include_engine)
    elif args.arch and args.shape:
        cells = [(args.arch, args.shape)]
    elif args.arch:
        cells = [(args.arch, s) for s in get_arch(args.arch).shapes]
    else:
        p.error("need --arch [--shape] or --all")

    reports, failures = [], []
    for arch_id, shape_name in cells:
        try:
            reports.append(run_cell(arch_id, shape_name, args.multi_pod))
        except Exception as e:
            failures.append((arch_id, shape_name, repr(e)))
            print(f"!! FAILED {arch_id} x {shape_name}: {e}")
            traceback.print_exc()
            if not args.keep_going:
                break
    if args.report and reports:
        existing = []
        if os.path.exists(args.report):
            with open(args.report) as f:
                existing = json.load(f)
        with open(args.report, "w") as f:
            json.dump(existing + [r.to_json() for r in reports], f, indent=1)
    print(f"\n{len(reports)} cells OK, {len(failures)} failed")
    for a, s, e in failures:
        print(f"  FAIL {a} x {s}: {e}")
    return 0 if not failures else 1


if __name__ == "__main__":
    sys.exit(main())
