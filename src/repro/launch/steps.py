"""Cell builder: (ArchSpec, ShapeSpec, mesh) -> lowerable step function.

For every one of the 40 assigned (arch x shape) cells (+ the engine's own),
this produces:

* ``fn`` — the step function (train_step / prefill / decode_step / serve /
  retrieval scoring / graph train / sharded range search),
* ``args`` — ShapeDtypeStruct stand-ins for every input (params, optimizer
  state, batches, KV caches): weak-type-correct, shardable, **zero
  allocation**,
* ``in_shardings`` — NamedShardings bound from the arch's rule table plus
  the per-shape activation/cache layout decisions documented inline,
* ``donate`` — donated argnums (params/opt for train, cache for decode) so
  memory_analysis reflects steady-state HBM, not double-buffered peaks.

The dry-run lowers ``jit(fn, in_shardings=...)`` with these; benchmarks and
examples call the same builders with real arrays.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.common import ArchSpec, ShapeSpec
from ..dist.sharding import bind_shardings, mesh_axes, spec_tree
from ..layers.common import cast_tree
from ..models import gcn as gcn_mod
from ..models import recsys as rec_mod
from ..models import transformer as tf_mod
from ..optim.adamw import init_adamw, make_train_step


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any = None  # pinned for train cells: params/opt return
                               # in their sharded layout (grads reduce-
                               # scatter instead of all-reduce+replicate)
    donate: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)

    def jitted(self):
        kw = {}
        if self.out_shardings is not None:
            kw["out_shardings"] = self.out_shardings
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       donate_argnums=self.donate, **kw)

    def lower(self):
        return self.jitted().lower(*self.args)


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _abstract_params(arch: ArchSpec, init_fn) -> Any:
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: cast_tree(init_fn(k), arch.param_dtype), key)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cache_spec(cfg, batch: int, mesh: Mesh):
    """Decode-cache layout policy (DESIGN.md §5):
    * batch shards over dp when divisible;
    * GQA: kv heads shard over tp when there are enough heads, else the
      *sequence* axis shards over tp (flash-decoding style partial softmax);
    * MLA: latent dim shards over tp (512 / 16 = 32).
    * tiny-batch long-context (long_500k): sequence shards over dp too.
    """
    dp, tp = mesh_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in (dp if isinstance(dp, tuple) else (dp,))]))
    tp_size = mesh.shape[tp]
    batch_ax = dp if batch % dp_size == 0 and batch >= dp_size else None
    seq_dp = None if batch_ax is not None else dp
    if cfg.attn_kind == "mla":
        return P(None, batch_ax, seq_dp, tp), P(None, batch_ax, seq_dp, None)
    if cfg.n_kv % tp_size == 0 and cfg.n_kv >= tp_size:
        spec = P(None, batch_ax, seq_dp, tp, None)
    else:  # few kv heads: shard the sequence axis over tp instead
        if seq_dp is None:
            seq_ax = tp
        else:
            dp_axes = seq_dp if isinstance(seq_dp, tuple) else (seq_dp,)
            seq_ax = dp_axes + (tp,)
        spec = P(None, batch_ax, seq_ax, None, None)
    return spec, spec


def build_lm_cell(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> Cell:
    cfg = arch.model_cfg
    dp, tp = mesh_axes(mesh)
    params = _abstract_params(arch, lambda k: tf_mod.init_transformer(k, cfg))
    p_shard = bind_shardings(mesh, spec_tree(params, arch.rules, mesh))
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        loss = partial(tf_mod.loss_fn, cfg=cfg)
        step = make_train_step(loss, arch.opt_cfg,
                               accum_steps=arch.accum_steps)
        opt = jax.eval_shape(partial(init_adamw, cfg=arch.opt_cfg), params)
        o_shard = {"m": p_shard, "v": p_shard,
                   "step": _ns(mesh)}
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        b_shard = {"tokens": _ns(mesh, dp, None), "labels": _ns(mesh, dp, None)}
        return Cell(arch.arch_id, shape.name, step, (params, opt, batch),
                    (p_shard, o_shard, b_shard),
                    out_shardings=(p_shard, o_shard, None),
                    donate=(0, 1), meta={"tokens": b * s})

    if shape.kind == "prefill":
        fn = partial(tf_mod.prefill, cfg=cfg, max_len=s)
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return Cell(arch.arch_id, shape.name, fn, (params, tokens),
                    (p_shard, _ns(mesh, dp, None)),
                    meta={"tokens": b * s})

    if shape.kind == "decode":
        fn = partial(tf_mod.decode_step, cfg=cfg)
        ck, cv = tf_mod.cache_shapes(cfg, b, s)
        cache = tf_mod.KVCache(k=ck, v=cv)
        k_spec, v_spec = _lm_cache_spec(cfg, b, mesh)
        c_shard = tf_mod.KVCache(k=NamedSharding(mesh, k_spec),
                                 v=NamedSharding(mesh, v_spec))
        batch_ax = dp if b % _dp_size(mesh) == 0 and b >= _dp_size(mesh) else None
        token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return Cell(arch.arch_id, shape.name, fn,
                    (params, token, cache, pos),
                    (p_shard, _ns(mesh, batch_ax, None), c_shard, _ns(mesh)),
                    out_shardings=(None, c_shard),
                    donate=(2,),
                    meta={"tokens": b, "kv_len": s})

    raise ValueError(shape.kind)


def _dp_size(mesh: Mesh) -> int:
    dp, _ = mesh_axes(mesh)
    axes = dp if isinstance(dp, tuple) else (dp,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _pad_to(x: int, mult: int) -> int:
    """Round up to a sharding-divisible size (data pipelines pad; the
    models mask padding via -1 sentinels)."""
    return -(-x // mult) * mult


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gcn_variant(cfg: gcn_mod.GCNConfig, shape: ShapeSpec) -> gcn_mod.GCNConfig:
    """Same 2-layer/16-hidden geometry, input/output dims per dataset."""
    d_feat = shape.d_feat or cfg.d_feat
    n_classes = {"full_graph_sm": 7, "minibatch_lg": 41,
                 "ogb_products": 47, "molecule": 2}.get(shape.name, cfg.n_classes)
    return dataclasses.replace(cfg, d_feat=d_feat, n_classes=n_classes)


def sampled_caps(shape: ShapeSpec) -> tuple[int, int]:
    """(max_nodes, max_edges) of the fanout-sampled subgraph."""
    n, e, front = shape.batch_nodes, 0, shape.batch_nodes
    for f in shape.fanout:
        e += front * f
        front = front * f
        n += front
    return n, e


def build_gnn_cell(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> Cell:
    dp, tp = mesh_axes(mesh)
    all_ax = (dp, tp) if not isinstance(dp, tuple) else dp + (tp,)

    if shape.kind == "graph_batched":
        cfg = _gcn_variant(dataclasses.replace(arch.model_cfg, d_feat=16), shape)
        params = _abstract_params(arch, lambda k: gcn_mod.init_gcn(k, cfg))
        p_shard = bind_shardings(mesh, spec_tree(params, arch.rules, mesh))

        def loss(params_, batch_):
            logits = gcn_mod.gcn_batched_graphs(
                params_, batch_["feats"], batch_["edge_src"], batch_["edge_dst"], cfg)
            labels = batch_["labels"]
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
            return jnp.mean(lse - ll), {}

        step = make_train_step(loss, arch.opt_cfg)
        opt = jax.eval_shape(partial(init_adamw, cfg=arch.opt_cfg), params)
        o_shard = {"m": p_shard, "v": p_shard, "step": _ns(mesh)}
        g, npg, epg = shape.n_graphs, shape.nodes_per_graph, shape.edges_per_graph
        batch = {
            "feats": jax.ShapeDtypeStruct((g, npg, cfg.d_feat), jnp.float32),
            "edge_src": jax.ShapeDtypeStruct((g, epg), jnp.int32),
            "edge_dst": jax.ShapeDtypeStruct((g, epg), jnp.int32),
            "labels": jax.ShapeDtypeStruct((g,), jnp.int32),
        }
        b_shard = {"feats": _ns(mesh, dp, None, None),
                   "edge_src": _ns(mesh, dp, None),
                   "edge_dst": _ns(mesh, dp, None),
                   "labels": _ns(mesh, dp)}
        return Cell(arch.arch_id, shape.name, step, (params, opt, batch),
                    (p_shard, o_shard, b_shard), donate=(0, 1),
                    meta={"edges": g * epg, "nodes": g * npg})

    cfg = _gcn_variant(arch.model_cfg, shape)
    params = _abstract_params(arch, lambda k: gcn_mod.init_gcn(k, cfg))
    p_shard = bind_shardings(mesh, spec_tree(params, arch.rules, mesh))
    loss = partial(gcn_mod.gcn_loss, cfg=cfg)
    step = make_train_step(loss, arch.opt_cfg)
    opt = jax.eval_shape(partial(init_adamw, cfg=arch.opt_cfg), params)
    o_shard = {"m": p_shard, "v": p_shard, "step": _ns(mesh)}

    if shape.kind == "graph_sampled":
        n, e = sampled_caps(shape)
    else:
        n, e = shape.n_nodes, shape.n_edges
    n = _pad_to(n, _dp_size(mesh))
    e = _pad_to(e, _dp_size(mesh) * mesh.shape[tp])
    batch = {
        "feats": jax.ShapeDtypeStruct((n, cfg.d_feat), jnp.float32),
        "edge_src": jax.ShapeDtypeStruct((e,), jnp.int32),
        "edge_dst": jax.ShapeDtypeStruct((e,), jnp.int32),
        "labels": jax.ShapeDtypeStruct((n,), jnp.int32),
    }
    # nodes shard over dp; the edge list (the big array) over the whole mesh
    b_shard = {"feats": _ns(mesh, dp, None),
               "edge_src": _ns(mesh, all_ax),
               "edge_dst": _ns(mesh, all_ax),
               "labels": _ns(mesh, dp)}
    return Cell(arch.arch_id, shape.name, step, (params, opt, batch),
                (p_shard, o_shard, b_shard), donate=(0, 1),
                meta={"edges": e, "nodes": n})


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def build_recsys_cell(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> Cell:
    cfg = arch.model_cfg
    dp, tp = mesh_axes(mesh)
    all_ax = (dp, tp) if not isinstance(dp, tuple) else dp + (tp,)
    params = _abstract_params(arch, lambda k: rec_mod.init_recsys(k, cfg))
    p_shard = bind_shardings(mesh, spec_tree(params, arch.rules, mesh))
    b = shape.global_batch
    two_tower = cfg.kind == "two_tower"

    def batch_specs(bsz, ax):
        if two_tower:
            batch = {"user_sparse": jax.ShapeDtypeStruct((bsz, cfg.n_sparse), jnp.int32),
                     "item_sparse": jax.ShapeDtypeStruct((bsz, cfg.n_sparse_item), jnp.int32),
                     "log_q": jax.ShapeDtypeStruct((bsz,), jnp.float32)}
            shard = {"user_sparse": _ns(mesh, ax, None),
                     "item_sparse": _ns(mesh, ax, None),
                     "log_q": _ns(mesh, ax)}
        else:
            batch = {"sparse": jax.ShapeDtypeStruct((bsz, cfg.n_sparse), jnp.int32),
                     "label": jax.ShapeDtypeStruct((bsz,), jnp.float32)}
            shard = {"sparse": _ns(mesh, ax, None), "label": _ns(mesh, ax)}
            if cfg.n_dense:
                batch["dense"] = jax.ShapeDtypeStruct((bsz, cfg.n_dense), jnp.float32)
                shard["dense"] = _ns(mesh, ax, None)
        return batch, shard

    if shape.kind == "train":
        loss = partial(rec_mod.recsys_loss, cfg=cfg)
        step = make_train_step(loss, arch.opt_cfg)
        opt = jax.eval_shape(partial(init_adamw, cfg=arch.opt_cfg), params)
        o_shard = {"m": p_shard, "v": p_shard, "step": _ns(mesh)}
        batch, b_shard = batch_specs(b, dp)
        return Cell(arch.arch_id, shape.name, step, (params, opt, batch),
                    (p_shard, o_shard, b_shard), donate=(0, 1),
                    meta={"examples": b})

    if shape.kind == "serve":
        if two_tower:
            def fn(params_, user_sparse):
                return rec_mod.tower(params_["user"], user_sparse, cfg,
                                     len(cfg.mlp_dims) + 1)
            args = (params, jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32))
            shard = (p_shard, _ns(mesh, dp, None))
        else:
            def fn(params_, batch_):
                return rec_mod.recsys_forward(params_, batch_, cfg)
            batch, b_shard = batch_specs(b, dp)
            batch.pop("label"); b_shard.pop("label")
            args = (params, batch)
            shard = (p_shard, b_shard)
        return Cell(arch.arch_id, shape.name, fn, args, shard,
                    meta={"examples": b})

    if shape.kind == "retrieval":
        nc = _pad_to(shape.n_candidates, _dp_size(mesh) * mesh.shape[tp])
        if two_tower:
            # one user scored against 1M precomputed item embeddings:
            # the rangescan-kernel shape (brute force) — the graph engine
            # serves the same corpus sub-linearly (benchmarks/qps_precision)
            def fn(params_, user_sparse, cand_emb):
                u = rec_mod.tower(params_["user"], user_sparse, cfg,
                                  len(cfg.mlp_dims) + 1)
                return rec_mod.retrieval_topk(u, cand_emb, k=1000)
            args = (params,
                    jax.ShapeDtypeStruct((1, cfg.n_sparse), jnp.int32),
                    jax.ShapeDtypeStruct((nc, cfg.d_out), jnp.float32))
            shard = (p_shard, _ns(mesh, None, None), _ns(mesh, all_ax, None))
        else:
            # bulk-score 1M candidate rows for one context
            def fn(params_, batch_):
                return rec_mod.recsys_forward(params_, batch_, cfg)
            batch, b_shard = batch_specs(nc, all_ax)
            batch.pop("label"); b_shard.pop("label")
            args = (params, batch)
            shard = (p_shard, b_shard)
        return Cell(arch.arch_id, shape.name, fn, args, shard,
                    meta={"examples": nc})

    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Range-engine cells (the paper's own system)
# ---------------------------------------------------------------------------

def build_engine_cell(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> Cell:
    from ..dist.sharded_engine import ShardedCorpus, sharded_range_search
    dp, tp = mesh_axes(mesh)
    ecfg = arch.model_cfg
    s_shards = mesh.shape[tp]
    n, d, r_deg = ecfg.shard_corpus, ecfg.dim, ecfg.max_degree
    cdt = getattr(ecfg, "corpus_dtype", "float32")
    if cdt == "int8":
        # quantized deploy: per-shard int8 codes + metadata + the raw f32
        # vectors the boundary rerank gathers from (core.corpus layout)
        from ..core.corpus import QuantizedCorpus
        pts_struct = QuantizedCorpus(
            codes=jax.ShapeDtypeStruct((s_shards, n, d), jnp.int8),
            meta=jax.ShapeDtypeStruct((s_shards, n, 3), jnp.float32),
            raw=jax.ShapeDtypeStruct((s_shards, n, d), jnp.float32))
    else:
        pts_struct = jax.ShapeDtypeStruct((s_shards, n, d), jnp.dtype(cdt))
    corpus = ShardedCorpus(
        points=pts_struct,
        neighbors=jax.ShapeDtypeStruct((s_shards, n, r_deg), jnp.int32),
        start_ids=jax.ShapeDtypeStruct((s_shards, 1), jnp.int32),
        offsets=jax.ShapeDtypeStruct((s_shards,), jnp.int32),
        n_total=s_shards * n)

    def fn(points, neighbors, start_ids, offsets, queries):
        c = ShardedCorpus(points=points, neighbors=neighbors,
                          start_ids=start_ids, offsets=offsets,
                          n_total=s_shards * n)
        # per-query radius vector (serving traffic mixes radii per batch);
        # the dry-run thereby lowers the data-sharded radii operand too
        radii = jnp.full((queries.shape[0],), 1.0, jnp.float32)
        res = sharded_range_search(mesh=mesh, corpus=c, queries=queries,
                                   r=radii, cfg=ecfg.range_cfg,
                                   model_axis=tp, data_axis=dp)
        return res.ids, res.dists, res.count

    q = jax.ShapeDtypeStruct((shape.global_batch, d), jnp.float32)
    args = (corpus.points, corpus.neighbors, corpus.start_ids,
            corpus.offsets, q)
    # per-leaf shardings so the quantized corpus pytree (leaves of mixed
    # rank) lays its shard axis along tp exactly like the plain array
    pts_shard = jax.tree.map(
        lambda leaf: _ns(mesh, tp, *([None] * (leaf.ndim - 1))),
        corpus.points)
    shard = (pts_shard, _ns(mesh, tp, None, None),
             _ns(mesh, tp, None), _ns(mesh, tp), _ns(mesh, dp, None))
    return Cell(arch.arch_id, shape.name, fn, args, shard,
                meta={"queries": shape.global_batch,
                      "corpus": s_shards * n})


# ---------------------------------------------------------------------------

def build_cell(arch: ArchSpec, shape_name: str, mesh: Mesh) -> Cell:
    shape = arch.shapes[shape_name]
    if arch.family == "lm":
        return build_lm_cell(arch, shape, mesh)
    if arch.family == "gnn":
        return build_gnn_cell(arch, shape, mesh)
    if arch.family == "recsys":
        return build_recsys_cell(arch, shape, mesh)
    if arch.family == "engine":
        return build_engine_cell(arch, shape, mesh)
    raise ValueError(arch.family)
