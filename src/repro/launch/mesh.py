"""Production meshes (brief: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module constant — importing this module never touches
jax device state (device count is locked at first jax init, and only
dryrun.py sets the 512-device XLA flag).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over forced host devices (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_devices(mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
