"""Serving launcher: build an index over a corpus and serve range queries.

  PYTHONPATH=src python -m repro.launch.serve --profile bigann-like \\
      --n 20000 --queries 512 --mode greedy --early-stop --mixed-radius
  PYTHONPATH=src python -m repro.launch.serve --n 20000 --churn 0.1

Builds the synthetic corpus, selects a radius with the paper's Sec.-3
methodology, builds the Vamana index, starts the RangeServer and drives a
batch of requests through it, reporting QPS / AP / early-stop stats.
``--shards S`` serves through the fault-tolerant host fan-out; add
``--replicas R`` (plus optionally ``--hedge-ms`` and ``--down-replicas``)
to serve an R-way replicated fleet with hedged reads, circuit breakers,
and background replica recovery — coverage stays 1.0 while any replica of
every shard survives.
``--mixed-radius`` spreads per-request radii across the corpus's match
distribution (real traffic mixes duplicate-detection-tight and
recommendation-wide thresholds); the server batches them together and
answers each request at its own radius. ``--churn FRAC`` serves from a
**live** index instead of a frozen one: insert and delete requests for
FRAC of the corpus interleave with the queries in the same admission
queue, the server applies them between micro-batches (epoch snapshots),
and AP is scored against the exact oracle on the FINAL live set.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax.numpy as jnp
import numpy as np

from ..configs.range_engine import EngineDeployConfig
from ..core import (
    BuildConfig, RangeSearchEngine, average_precision, exact_range_search,
    pack_labels,
)
from ..core.beam_search import ES_D_VISITED
from ..core.radius import default_grid, select_radius, sweep
from ..data.synthetic import make_corpus
from ..live import LiveConfig, LiveIndex
from ..serve import RangeServer, Request, ServerConfig
from ..utils import INVALID_ID


def _replicated_main(args) -> int:
    """Sharded/replicated traffic driver: host fan-out serving with R-way
    replication, hedged reads, and scripted replica loss."""
    from ..core.build import build_vamana, medoid
    from ..dist.sharded_engine import build_sharded
    from ..fault import FaultInjector, HedgePolicy, RetryPolicy

    n_shards = max(args.shards, 1)
    print(f"[serve] SHARDED corpus {args.profile} n={args.n} "
          f"shards={n_shards} replicas={args.replicas}")
    ds = make_corpus(args.profile, n=args.n, n_queries=args.queries)
    pts = np.asarray(ds.points, np.float32)
    qs = ds.queries

    grid = default_grid(ds.points, ds.queries, ds.metric, num=24)
    prof = sweep(jnp.asarray(pts), jnp.asarray(qs), grid, ds.metric)
    r, gi = select_radius(prof, robustness_weight=0.2)
    print(f"[serve] selected radius {r:.4g} "
          f"(zero-result frac {prof.zero_frac[gi]:.2f})")

    bcfg = BuildConfig(max_degree=32, beam=64, metric=ds.metric)
    t0 = time.perf_counter()
    corpus = build_sharded(
        pts, n_shards,
        lambda p: (build_vamana(jnp.asarray(p), bcfg), medoid(p)[None]),
        corpus_dtype=args.corpus_dtype,
        tier=args.tier, resident_mb=args.resident_mb)
    print(f"[serve] {n_shards}-shard index built in "
          f"{time.perf_counter() - t0:.1f}s")
    if args.tier:
        print(f"[serve] tiered shards: "
              f"{[t.budget().as_dict() for t in corpus.tiers]}")

    down = []
    if args.down_replicas:
        down = [tuple(int(x) for x in pair.split(":"))
                for pair in args.down_replicas.split(",")]
        print(f"[serve] scripted replica loss: {down}")
    injector = FaultInjector(seed=0, down_replicas=tuple(down)) if down else None
    hedge = (HedgePolicy(delay_s=args.hedge_ms / 1e3)
             if args.hedge_ms > 0 else None)

    rcfg = EngineDeployConfig().overrides(
        metric=ds.metric,
        beam=args.beam, max_beam=args.beam, visit_cap=512,
        expand_width=args.expand_width, corpus_dtype=args.corpus_dtype,
        mode=args.mode, result_cap=2048).range_cfg
    srv = RangeServer(None, rcfg, ServerConfig(max_batch=args.max_batch),
                      sharded=corpus, replicas=args.replicas,
                      injector=injector, hedge=hedge,
                      retry=RetryPolicy(backoff_s=0.01))

    t0 = time.perf_counter()
    resp = []
    for i in range(args.queries):
        rq = Request(req_id=i, query=qs[i], radius=float(r))
        while srv.submit(rq) is not None:
            resp.extend(srv.step())
    resp.extend(srv.run_until_drained())
    dt = time.perf_counter() - t0

    gt_ids, _, gt_counts = exact_range_search(
        jnp.asarray(pts), jnp.asarray(qs), float(r), ds.metric)
    res_ids = np.full((args.queries, 4096), 2**31 - 1, np.int64)
    counts = np.zeros(args.queries, np.int64)
    for rp in resp:
        k = min(len(rp.ids), 4096)
        res_ids[rp.req_id, :k] = rp.ids[:k]
        counts[rp.req_id] = k
    ap = average_precision(np.asarray(gt_ids), np.asarray(gt_counts),
                           res_ids, counts)
    cov = min(rp.coverage for rp in resp)
    codes = {rp.code for rp in resp}
    print(f"[serve] {args.queries} queries in {dt:.3f}s = "
          f"{args.queries / dt:.0f} QPS; AP={ap:.4f}; "
          f"min coverage={cov:.2f} codes={codes}")
    st = srv.stats
    print(f"[serve] replication: hedges_fired={st['hedges_fired']} "
          f"hedge_wins={st['hedge_wins']} breaker_trips={st['breaker_trips']} "
          f"replicas_lost={st['replicas_lost']} "
          f"replicas_recovered={st['replicas_recovered']} "
          f"shards_lost={st['shards_lost']} "
          f"degraded_batches={st['degraded_batches']}")
    if args.tier:
        print(f"[serve] tier fetch path (shard 0): "
              f"{corpus.tiers[0].counters.as_dict()}")
    return 0


def _churn_main(args) -> int:
    """Live-engine traffic driver: interleaved insert/delete/query requests
    through one admission queue, AP scored on the final live set."""
    n, k = args.n, max(int(args.churn * args.n), 1)
    print(f"[serve] LIVE corpus {args.profile} n={n} churn={args.churn} "
          f"({k} inserts + {k} deletes interleaved with {args.queries} queries)")
    ds = make_corpus(args.profile, n=n + k, n_queries=args.queries)
    pts_all = np.asarray(ds.points, np.float32)
    init, stream = pts_all[:n], pts_all[n:]
    qs = ds.queries

    raw_labels = None
    if args.filter_frac > 0:
        # label the full stream (initial corpus + future inserts) up front
        # so inserted points carry predicates the moment they land
        lrng = np.random.default_rng(7)
        raw_labels = [list(lrng.choice(args.num_labels,
                                       size=int(lrng.integers(1, 4)),
                                       replace=False))
                      for _ in range(n + k)]
        print(f"[serve] labeled live corpus: {args.num_labels}-label "
              f"vocabulary, 1-3 labels/point (inserts carry labels)")

    grid = default_grid(init, ds.queries, ds.metric, num=24)
    prof = sweep(jnp.asarray(init), jnp.asarray(qs), grid, ds.metric)
    r, gi = select_radius(prof, robustness_weight=0.2)
    print(f"[serve] selected radius {r:.4g} "
          f"(zero-result frac {prof.zero_frac[gi]:.2f})")

    t0 = time.perf_counter()
    live = LiveIndex.create(
        init, LiveConfig(capacity=n + k, insert_batch=128),
        BuildConfig(max_degree=32, beam=64, metric=ds.metric),
        metric=ds.metric, corpus_dtype=args.corpus_dtype,
        labels=None if raw_labels is None
        else pack_labels(raw_labels[:n], args.num_labels),
        tier=args.tier, resident_mb=args.resident_mb)
    print(f"[serve] live index built in {time.perf_counter() - t0:.1f}s "
          f"{live.stats()}")
    if args.tier:
        print(f"[serve] tiered live corpus: "
              f"{live.points.budget().as_dict()}")

    rcfg = EngineDeployConfig().overrides(
        metric=ds.metric,
        beam=args.beam, max_beam=args.beam, visit_cap=512,
        expand_width=args.expand_width, corpus_dtype=args.corpus_dtype,
        mode=args.mode, result_cap=2048).range_cfg
    srv = RangeServer(None, rcfg,
                      ServerConfig(max_batch=args.max_batch,
                                   continuous=args.continuous,
                                   lanes=args.lanes,
                                   slice_rounds=args.slice_rounds),
                      live=live)

    rng = np.random.default_rng(0)
    doomed = rng.choice(n, size=k, replace=False)  # initial ids to delete
    filt_of = [None] * args.queries
    fmode = ["and"] * args.queries
    if args.filter_frac > 0:
        # same predicate mix as the static path: mostly single-label AND,
        # every fourth lane a two-label OR
        nf = max(int(args.filter_frac * args.queries), 1)
        for qi in rng.choice(args.queries, nf, replace=False):
            if qi % 4 == 3:
                filt_of[qi] = [int(x) for x in
                               rng.choice(args.num_labels, 2, replace=False)]
                fmode[qi] = "or"
            else:
                filt_of[qi] = [int(rng.integers(args.num_labels))]
        print(f"[serve] filtered traffic: {nf}/{args.queries} requests "
              f"carry label predicates")
    reqs = (
        [Request(req_id=i, query=qs[i], radius=float(r),
                 filter_labels=filt_of[i], filter_mode=fmode[i])
         for i in range(args.queries)]
        + [Request(req_id=args.queries + i, op="insert", query=stream[i],
                   labels=None if raw_labels is None
                   else np.asarray(raw_labels[n + i]))
           for i in range(k)]
        + [Request(req_id=args.queries + k + i, op="delete",
                   delete_ids=np.asarray([doomed[i]]))
           for i in range(k)]
    )
    rng.shuffle(reqs)  # interleave mutations with query traffic
    t0 = time.perf_counter()
    resp = []
    for rq in reqs:
        while srv.submit(rq) is not None:  # queue_full: serve under
            resp.extend(srv.step())        # backpressure, then retry
    resp.extend(srv.run_until_drained())
    dt = time.perf_counter() - t0
    n_req = len(reqs)
    print(f"[serve] {n_req} requests ({args.queries} queries, {k} inserts, "
          f"{k} deletes) in {dt:.3f}s = {n_req / dt:.0f} req/s; "
          f"epoch={srv.stats['epoch']} "
          f"consolidations={srv.stats['consolidations']}")

    # score queries against the exact oracle on the FINAL live set (each
    # query was answered at some intermediate epoch: with shuffled traffic
    # the early/late disagreement shows up as a small AP haircut, which is
    # the honest serving-consistency number)
    ext, vecs = live.live_vectors()
    gt = exact_range_search(jnp.asarray(vecs), jnp.asarray(qs),
                            float(r), ds.metric)
    if raw_labels is not None:
        # filtered lanes score against the POST-FILTERED oracle over the
        # final live set (rows index vecs; labels key off external ids)
        gt_ids_f = np.asarray(gt[0]).copy()
        gt_counts_f = np.asarray(gt[2]).copy()
        lab_sets = [set(raw_labels[int(e)]) for e in ext]
        for qi in range(args.queries):
            if filt_of[qi] is None:
                continue
            pred = set(filt_of[qi])
            keep = [int(x) for x in gt_ids_f[qi][:gt_counts_f[qi]]
                    if (pred <= lab_sets[int(x)] if fmode[qi] == "and"
                        else bool(pred & lab_sets[int(x)]))]
            gt_ids_f[qi] = INVALID_ID
            gt_ids_f[qi, :len(keep)] = keep
            gt_counts_f[qi] = len(keep)
        gt = (gt_ids_f, gt[1], gt_counts_f)
    lut = np.full(live.next_ext_id + 1, INVALID_ID, np.int64)
    lut[ext] = np.arange(len(ext))
    res_ids = np.full((args.queries, 4096), INVALID_ID, np.int64)
    counts = np.zeros(args.queries, np.int64)
    qresp = [rp for rp in resp if rp.op == "range"]
    for rp in qresp:
        rows = lut[np.minimum(rp.ids, live.next_ext_id)][:4096]
        res_ids[rp.req_id, :len(rows)] = rows
        counts[rp.req_id] = len(rows)
    ap = average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                           res_ids, counts)
    lat = sorted(rp.latency_s for rp in qresp)
    print(f"[serve] AP vs final live set = {ap:.4f}; latency "
          f"p50={lat[len(lat) // 2] * 1e3:.1f}ms "
          f"p99={lat[int(len(lat) * 0.99)] * 1e3:.1f}ms")
    print(f"[serve] stats={srv.stats}")
    if args.filter_frac > 0:
        st = srv.stats
        print(f"[serve] filtered: requests={st['filtered_requests']} "
              f"batches={st['filtered_batches']}/{st['batches']} "
              f"(AP above scored vs the post-filtered oracle on the final "
              f"live set)")
    print(f"[serve] final live index: {live.stats()}")
    if args.tier:
        print(f"[serve] tier fetch path: "
              f"{live.points.counters.as_dict()}")
    return 0


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--profile", default="bigann-like")
    p.add_argument("--n", type=int, default=20_000)
    p.add_argument("--queries", type=int, default=512)
    p.add_argument("--mode", default="greedy",
                   choices=["beam", "doubling", "greedy"])
    p.add_argument("--beam", type=int, default=32)
    p.add_argument("--expand-width", type=int, default=4,
                   help="frontier nodes expanded per search iteration")
    p.add_argument("--corpus-dtype", default="float32",
                   choices=["float32", "bfloat16", "int8"],
                   help="corpus storage dtype: int8 runs the quantized "
                        "two-pass pipeline (guard-banded search + exact "
                        "boundary rerank)")
    p.add_argument("--tier", action="store_true",
                   help="tiered corpus: keep only codes+meta device-resident "
                        "and serve the guard-band rerank from a host-RAM "
                        "raw-row store (implies --corpus-dtype int8)")
    p.add_argument("--resident-mb", type=float, default=None,
                   help="device row-cache budget for --tier, in MB "
                        "(default: n/8 rows)")
    p.add_argument("--early-stop", action="store_true")
    p.add_argument("--max-batch", type=int, default=128)
    p.add_argument("--mixed-radius", action="store_true",
                   help="per-request radii spread across the match "
                        "distribution instead of one shared radius")
    p.add_argument("--churn", type=float, default=0.0,
                   help="serve from a live index with this fraction of the "
                        "corpus inserted AND deleted during the run "
                        "(interleaved with the query traffic)")
    p.add_argument("--continuous", action="store_true",
                   help="continuous batching: saturated lanes ride a "
                        "persistent pool instead of lockstepping their "
                        "micro-batch (greedy mode only)")
    p.add_argument("--lanes", type=int, default=32,
                   help="continuous-mode lane pool width (rounded to pow2)")
    p.add_argument("--slice-rounds", type=int, default=8,
                   help="greedy expansions per pooled lane per server step")
    p.add_argument("--effort", action="store_true",
                   help="fit an effort regressor on a workload sample and "
                        "split admissions into cheap/heavy dispatches")
    p.add_argument("--heavy-frac", type=float, default=0.0,
                   help="fraction of requests given a dense-region radius "
                        "(tail-latency workload)")
    p.add_argument("--filter-frac", type=float, default=0.0,
                   help="fraction of range requests carrying a label "
                        "predicate (the corpus gets synthetic per-point "
                        "labels; AP is scored against the post-filtered "
                        "oracle)")
    p.add_argument("--num-labels", type=int, default=16,
                   help="synthetic label vocabulary size for --filter-frac")
    p.add_argument("--shards", type=int, default=0,
                   help="serve through the fault-tolerant host fan-out over "
                        "this many shards (0 = single frozen index)")
    p.add_argument("--replicas", type=int, default=1,
                   help="R-way shard replication (implies --shards serving; "
                        "coverage stays 1.0 under loss of R-1 replicas of "
                        "any shard)")
    p.add_argument("--hedge-ms", type=float, default=0.0,
                   help="hedge delay in ms: fire the next replica when the "
                        "primary is slower than this (0 disables hedging)")
    p.add_argument("--down-replicas", default="",
                   help="scripted replica loss, e.g. '0:0,1:1' downs shard "
                        "0's replica 0 and shard 1's replica 1")
    args = p.parse_args(argv)
    if args.tier:
        args.corpus_dtype = "int8"  # tiering exists for the quantized split

    if args.churn > 0:
        return _churn_main(args)
    if args.shards > 0 or args.replicas > 1:
        return _replicated_main(args)

    print(f"[serve] corpus {args.profile} n={args.n}")
    ds = make_corpus(args.profile, n=args.n, n_queries=args.queries)
    pts = jnp.asarray(ds.points)
    qs = ds.queries

    grid = default_grid(ds.points, ds.queries, ds.metric, num=24)
    prof = sweep(pts, jnp.asarray(qs), grid, ds.metric)
    r, gi = select_radius(prof, robustness_weight=0.2)
    print(f"[serve] selected radius {r:.4g} "
          f"(zero-result frac {prof.zero_frac[gi]:.2f})")

    raw_labels = None
    labels_packed = None
    if args.filter_frac > 0:
        # synthetic per-point labels: 1-3 ids each from a small vocabulary
        # (the category/attribute tags real filtered-search corpora carry)
        lrng = np.random.default_rng(7)
        raw_labels = [list(lrng.choice(args.num_labels,
                                       size=int(lrng.integers(1, 4)),
                                       replace=False))
                      for _ in range(args.n)]
        labels_packed = pack_labels(raw_labels, args.num_labels)
        print(f"[serve] labeled corpus: {args.num_labels}-label vocabulary, "
              f"1-3 labels/point")

    t0 = time.perf_counter()
    eng = RangeSearchEngine.build(
        pts, BuildConfig(max_degree=32, beam=64, metric=ds.metric),
        metric=ds.metric, corpus_dtype=args.corpus_dtype,
        labels=labels_packed, tier=args.tier, resident_mb=args.resident_mb)
    print(f"[serve] index built in {time.perf_counter() - t0:.1f}s "
          f"{eng.stats()}")
    if args.tier:
        bud = eng.points.budget()
        print(f"[serve] tiered corpus: device={bud.device_total} B "
              f"({bud.device_bytes_per_vector(args.n):.1f} B/vec) "
              f"host={bud.host_total} B; breakdown={bud.as_dict()}")

    rng = np.random.default_rng(0)
    if args.mixed_radius:
        # spread per-request radii across the sweep grid around the selected
        # radius: tight (near-duplicate) through wide (recommendation) lanes
        # interleaved in the same micro-batches
        lo = float(prof.radii[max(gi - 6, 0)])
        hi = float(prof.radii[min(gi + 4, len(prof.radii) - 1)])
        radii = np.linspace(lo, hi, args.queries).astype(np.float32)
        rng.shuffle(radii)  # mix radii *within* batches, not across them
        print(f"[serve] mixed radii in [{lo:.4g}, {hi:.4g}]")
    else:
        radii = np.full(args.queries, r, np.float32)
    if args.heavy_frac > 0:
        # tail-latency workload: a slice of the traffic queries at the top
        # of the sweep grid (dense-region, phase-2-bound) while the rest
        # stay point-like — the regime continuous batching exists for
        hi = float(prof.radii[-1])
        nh = max(int(args.heavy_frac * args.queries), 1)
        radii[rng.choice(args.queries, nh, replace=False)] = hi
        print(f"[serve] heavy traffic: {nh} requests at radius {hi:.4g}")
    filt_of = [None] * args.queries
    fmode = ["and"] * args.queries
    if args.filter_frac > 0:
        # a slice of the traffic filters: mostly single-label AND lanes,
        # every fourth a two-label OR (broader posting list) — filtered and
        # plain requests deliberately share micro-batches
        nf = max(int(args.filter_frac * args.queries), 1)
        for qi in rng.choice(args.queries, nf, replace=False):
            if qi % 4 == 3:
                filt_of[qi] = [int(x) for x in
                               rng.choice(args.num_labels, 2, replace=False)]
                fmode[qi] = "or"
            else:
                filt_of[qi] = [int(rng.integers(args.num_labels))]
        print(f"[serve] filtered traffic: {nf}/{args.queries} requests "
              f"carry label predicates")

    rcfg = EngineDeployConfig().overrides(
        metric=ds.metric,
        beam=args.beam,
        max_beam=args.beam * (8 if args.mode == "doubling" else 1),
        visit_cap=512,
        es_metric=ES_D_VISITED if args.early_stop else 0,
        es_visit_limit=20,
        expand_width=args.expand_width,
        corpus_dtype=args.corpus_dtype,
        mode=args.mode, result_cap=2048).range_cfg
    effort = None
    if args.effort:
        # calibrate the admission regressor on exact match counts for a
        # sample of the workload (production: observed counts of answered
        # traffic; here the oracle is cheap)
        from ..models.effort import EffortPredictor
        samp = min(256, args.queries)
        _, _, c = exact_range_search(pts, jnp.asarray(qs[:samp]),
                                     jnp.asarray(radii[:samp]), ds.metric)
        effort = EffortPredictor.fit(qs[:samp], radii[:samp], np.asarray(c))
        print(f"[serve] effort regressor fitted on {samp} samples")
    srv = RangeServer(eng, rcfg,
                      ServerConfig(max_batch=args.max_batch,
                                   es_radius_factor=1.5 if args.early_stop else 0.0,
                                   continuous=args.continuous,
                                   lanes=args.lanes,
                                   slice_rounds=args.slice_rounds),
                      effort=effort)
    t0 = time.perf_counter()
    resp = []
    for i in range(args.queries):
        rq = Request(req_id=i, query=qs[i], radius=float(radii[i]),
                     filter_labels=filt_of[i], filter_mode=fmode[i])
        while srv.submit(rq) is not None:  # queue_full: serve under
            resp.extend(srv.step())        # backpressure, then retry
    resp.extend(srv.run_until_drained())
    dt = time.perf_counter() - t0
    qps = args.queries / dt

    gt_ids, _, gt_counts = exact_range_search(pts, jnp.asarray(qs),
                                              jnp.asarray(radii), ds.metric)
    if args.filter_frac > 0:
        # filtered lanes score against the POST-FILTERED oracle: the exact
        # in-radius set restricted to predicate-matching points
        gt_ids = np.asarray(gt_ids).copy()
        gt_counts = np.asarray(gt_counts).copy()
        lab_sets = [set(l) for l in raw_labels]
        for qi in range(args.queries):
            if filt_of[qi] is None:
                continue
            pred = set(filt_of[qi])
            keep = [int(x) for x in gt_ids[qi][:gt_counts[qi]]
                    if (pred <= lab_sets[int(x)] if fmode[qi] == "and"
                        else bool(pred & lab_sets[int(x)]))]
            gt_ids[qi] = INVALID_ID
            gt_ids[qi, :len(keep)] = keep
            gt_counts[qi] = len(keep)
    res_ids = np.full((args.queries, 4096), 2**31 - 1, np.int64)
    counts = np.zeros(args.queries, np.int64)
    for rp in resp:
        k = min(len(rp.ids), 4096)
        res_ids[rp.req_id, :k] = rp.ids[:k]
        counts[rp.req_id] = k
    ap = average_precision(np.asarray(gt_ids), np.asarray(gt_counts),
                           res_ids, counts)
    lat = sorted(rp.latency_s for rp in resp)
    print(f"[serve] {args.queries} queries in {dt:.3f}s = {qps:.0f} QPS "
          f"(batched); AP={ap:.4f}")
    print(f"[serve] latency p50={lat[len(lat)//2]*1e3:.1f}ms "
          f"p99={lat[int(len(lat)*0.99)]*1e3:.1f}ms; stats={srv.stats}")
    hs = srv.latency_summary()
    print(f"[serve] histogram p50/p95/p99 (ms): "
          + " ".join(f"{op}={h['p50_ms']:.1f}/{h['p95_ms']:.1f}/{h['p99_ms']:.1f}"
                     for op, h in hs.items() if h["count"]))
    if args.continuous:
        st = srv.stats
        print(f"[serve] pool: admitted={st['pool_admitted']} "
              f"oneshot={st['pool_oneshot']} ticks={st['pool_ticks']} "
              f"rotations={st['pool_rotations']} "
              f"buckets cheap/heavy={st['bucket_cheap']}/{st['bucket_heavy']}")
    if args.filter_frac > 0:
        st = srv.stats
        print(f"[serve] filtered: requests={st['filtered_requests']} "
              f"batches={st['filtered_batches']}/{st['batches']} "
              f"(AP above scored vs the post-filtered oracle)")
    disp = srv.radius_dispersion()
    print(f"[serve] radius dispersion mean={disp['mean']:.4g} "
          f"std={disp['std']:.4g} range=[{disp['min']:.4g}, {disp['max']:.4g}] "
          f"mixed_batches={disp['mixed_radius_batches']}")
    if args.corpus_dtype == "int8":
        served = max(srv.stats["served"], 1)
        print(f"[serve] quantized corpus: "
              f"{eng.stats()['hot_bytes_per_vector']} hot bytes/vector "
              f"(f32: {4 * ds.points.shape[1]}), "
              f"guard-band reranks/query="
              f"{srv.stats['reranked'] / served:.2f}")
    if args.tier:
        print(f"[serve] tier fetch path: {eng.points.counters.as_dict()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
