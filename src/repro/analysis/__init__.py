from .hlo import CollectiveStats, count_op, fusion_count, parse_collectives
from .roofline import (
    HBM_BW, ICI_BW, PEAK_FLOPS, RooflineReport, analytic_model_flops,
    load_reports, make_report, save_reports,
)

__all__ = [k for k in dir() if not k.startswith("_")]
