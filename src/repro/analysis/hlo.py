"""Trip-count-aware HLO module analysis.

``compiled.cost_analysis()`` counts a while-loop body ONCE (verified
empirically — a 10-step scan of a matmul reports 1 matmul of FLOPs), so a
scan-over-layers transformer under-reports by ~n_layers. Unrolled compiles
at 27B x 256 devices are minutes each — too slow for 80+ dry-run cells.

Instead we analyze the *compiled, partitioned* HLO text directly:

1. split the module into computations; build the call graph
   (``body=``/``condition=`` edges carry the while's ``known_trip_count``
   from backend_config; ``calls=``/``to_apply=`` edges carry weight 1);
2. propagate execution multipliers from ENTRY;
3. FLOPs: every ``dot`` instruction contributes
   2 * prod(result_dims) * prod(contracting_dims) * multiplier
   (operand shapes resolved from the instruction table);
4. HBM bytes: for instructions at the top level of non-fused computations,
   result + operand bytes * multiplier (fusion sub-computations are
   on-chip and excluded) — the standard traffic approximation;
5. collectives: result-shape bytes * multiplier per op class, plus a
   ring wire-bytes estimate from the replica-group size.

Everything is per-device (the partitioned module is per-device).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_ARR_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")


def _parse_instr_line(line: str, comp: str):
    """Parse '%name = <shape> <opcode>(rest' with balanced-paren shape
    handling (tuple shapes contain '/*index=N*/' comments with '=')."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):  # tuple shape: find the matching close paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        else:
            return None
        shape, tail = rest[: i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, tail = rest[:sp], rest[sp:]
    om = re.match(r"\s*([\w\-]+)\(", tail)
    if not om:
        return None
    opcode = om.group(1)
    return Instr(name=name, shape=shape, opcode=opcode,
                 rest=tail[om.end():], comp=comp)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_ARR_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_ARR_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        return max(len([x for x in first.split(",") if x.strip() != ""]), 1)
    return 1


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str
    comp: str


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    operand_bytes: dict
    wire_bytes: dict

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def summary(self) -> str:
        rows = []
        for op in sorted(self.counts):
            rows.append(f"{op}: n={self.counts[op]:.0f} "
                        f"bytes={self.operand_bytes[op]:.3e} "
                        f"wire/dev={self.wire_bytes[op]:.3e}")
        return "; ".join(rows) if rows else "no collectives"


@dataclasses.dataclass
class ModuleAnalysis:
    dot_flops: float
    hbm_bytes: float
    collectives: CollectiveStats
    n_while: int
    max_trip: int
    dot_count: float  # trip-weighted


def _parse_computations(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: str | None = None
    for line in text.splitlines():
        if cur is None:
            is_hdr = ("->" in line and line.rstrip().endswith("{")
                      and not line.lstrip().startswith("//")
                      and not line.lstrip().startswith("HloModule"))
            m = _COMP_HEAD_RE.match(line.strip()) if is_hdr else None
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        ins = _parse_instr_line(line, cur)
        if ins is not None:
            comps[cur].append(ins)
    return comps


def analyze_module(text: str) -> ModuleAnalysis:
    comps = _parse_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEAD_RE.match(line.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:  # fall back: computation named main-ish
        entry = next((c for c in comps if "main" in c), next(iter(comps), ""))

    shapes: dict[str, str] = {}
    for instrs in comps.values():
        for ins in instrs:
            shapes[ins.name] = ins.shape

    # ---- call graph with edge weights --------------------------------------
    edges: dict[str, list[tuple[str, float, str]]] = defaultdict(list)
    n_while, max_trip = 0, 1
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.opcode == "while":
                n_while += 1
                trip = 1
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                    max_trip = max(max_trip, trip)
                bm = _BODY_RE.search(ins.rest)
                cm = _COND_RE.search(ins.rest)
                if bm:
                    edges[cname].append((bm.group(1), float(trip), "body"))
                if cm:
                    edges[cname].append((cm.group(1), float(trip + 1), "cond"))
            else:
                for regex, kind in ((_CALLS_RE, "fusion"), (_TO_APPLY_RE, "apply")):
                    m = regex.search(ins.rest)
                    if m:
                        edges[cname].append((m.group(1), 1.0, kind))
                bm = _BRANCHES_RE.search(ins.rest)
                if bm:
                    for b in _OPERAND_RE.findall(bm.group(1)):
                        edges[cname].append((b, 1.0, "branch"))

    # HLO call graphs are DAGs; propagate multipliers callers -> callees by
    # iterating full recompute passes to a fixpoint (depth <= #computations).
    mult: dict[str, float] = {entry: 1.0}
    fused_only: dict[str, bool] = {entry: False}
    for _ in range(len(comps) + 2):
        new_mult: dict[str, float] = defaultdict(float)
        new_mult[entry] = 1.0
        new_fused: dict[str, bool] = {entry: False}
        for src, outs in edges.items():
            sm = mult.get(src, 0.0)
            if sm == 0.0:
                continue
            for dst, w, kind in outs:
                new_mult[dst] += sm * w
                if kind in ("body", "cond", "branch"):
                    # executed-at-top-level iff the caller is
                    if not fused_only.get(src, True):
                        new_fused[dst] = False
                new_fused.setdefault(dst, True)
        new_mult = dict(new_mult)
        if new_mult == dict(mult) and new_fused == fused_only:
            break
        mult, fused_only = new_mult, new_fused

    # ---- walk instructions ---------------------------------------------------
    dot_flops = 0.0
    dot_count = 0.0
    hbm = 0.0
    ccounts: dict = defaultdict(float)
    cbytes: dict = defaultdict(float)
    cwire: dict = defaultdict(float)

    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fused = fused_only.get(cname, True)
        # HBM traffic model per executed computation: every value is
        # written once (its producer's result bytes) and every *external*
        # operand (parameter / cross-computation ref) is read once.
        if not in_fused:
            # values "defined" by real compute are written once; operands
            # produced by parameters/constants are external reads (counted
            # once); gte/tuple/bitcast operands are views of loop state —
            # excluded so a scanned layer stack isn't charged the full
            # stacked-weights array every iteration.
            producer = {i.name: i.opcode for i in instrs}
            real = {n for n, op in producer.items()
                    if op not in ("parameter", "constant", "get-tuple-element",
                                  "tuple", "bitcast", "while", "conditional")}
            read_once: set[str] = set()
            for ins in instrs:
                if ins.name not in real:
                    continue
                hbm += _shape_bytes(ins.shape) * m
                for o in _OPERAND_RE.findall(ins.rest.split("),", 1)[0])[:8]:
                    if o in real or o in read_once:
                        continue
                    if producer.get(o) in ("parameter", "constant"):
                        read_once.add(o)
                        hbm += _shape_bytes(shapes.get(o, "")) * m
        for ins in instrs:
            if ins.opcode in ("dot", "dot_general") or ins.opcode.startswith("dot"):
                res_dims = _first_shape_dims(ins.shape)
                k = 1
                cm = _CONTRACT_RE.search(ins.rest)
                ops = _OPERAND_RE.findall(ins.rest.split(")", 1)[0])
                if cm and ops:
                    lhs_shape = _first_shape_dims(shapes.get(ops[0], ""))
                    for d in cm.group(1).split(","):
                        if d and int(d) < len(lhs_shape):
                            k *= lhs_shape[int(d)]
                flops = 2.0 * k
                for d in res_dims:
                    flops *= d
                dot_flops += flops * m
                dot_count += m
            op = ins.opcode.replace("-start", "")
            if op in COLLECTIVES:
                b = _shape_bytes(ins.shape)
                n = _group_size(ins.rest)
                ccounts[op] += m
                cbytes[op] += b * m
                if op == "all-reduce":
                    w = 2 * (n - 1) / max(n, 1) * b
                elif op in ("all-gather", "reduce-scatter", "all-to-all"):
                    w = (n - 1) / max(n, 1) * b
                else:
                    w = b
                cwire[op] += w * m

    coll = CollectiveStats(counts=dict(ccounts), operand_bytes=dict(cbytes),
                           wire_bytes=dict(cwire))
    return ModuleAnalysis(dot_flops=dot_flops, hbm_bytes=hbm,
                          collectives=coll, n_while=n_while,
                          max_trip=max_trip, dot_count=dot_count)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Trip-count-aware collective stats (see analyze_module)."""
    return analyze_module(hlo_text).collectives


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


def fusion_count(hlo_text: str) -> int:
    return count_op(hlo_text, "fusion")
