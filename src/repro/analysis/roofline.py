"""Roofline terms per (arch x shape x mesh) from the compiled dry-run.

Hardware constants (TPU v5e, per brief): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.

Terms (seconds):
  compute    = HLO_FLOPs            / (chips * 197e12)
  memory     = HLO_bytes_accessed   / (chips * 819e9)
  collective = collective_bytes     / (chips * 50e9)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (whole-program,
all devices); collective_bytes from the HLO-text parse (analysis/hlo.py).
MODEL_FLOPS is the analytic useful-work count — 6·N·D for dense training,
6·N_active·D for MoE (brief), 2·N·D for inference passes, with the GNN /
recsys analogues documented in ``analytic_model_flops``. The
MODEL_FLOPS / HLO_FLOPs ratio exposes remat recompute and redundancy.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

import numpy as np

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link


# ---------------------------------------------------------------------------
# Corpus-gather roofline (the search loop's dominant term)
# ---------------------------------------------------------------------------

def corpus_bytes_per_distance(dim: int, corpus_dtype: str = "float32") -> float:
    """HBM bytes gathered per in-loop distance computation.

    f32/bf16 rows stream ``itemsize * dim``; the int8 quantized corpus
    streams 1-byte codes plus the [scale, |x_hat|^2, err] metadata row
    (``core.corpus.META_BYTES`` — the same constant
    ``core.corpus.bytes_per_vector`` uses). This is the denominator of the
    search loop's arithmetic intensity — the number the quantized pipeline
    exists to shrink."""
    if corpus_dtype == "int8":
        from ..core.corpus import META_BYTES
        return dim + float(META_BYTES)
    return float(jnp_itemsize(corpus_dtype)) * dim


def search_arithmetic_intensity(dim: int,
                                corpus_dtype: str = "float32") -> float:
    """FLOPs per HBM byte for the in-loop distance (l2 matmul form: one MXU
    dot (2d) + the rank-1 norm correction (~3 flops)). TPU v5e's machine
    balance is ``PEAK_FLOPS / HBM_BW`` ~ 240 flops/byte, so the gather term
    stays memory-bound at every storage dtype — which is why bytes-per-
    distance, not FLOPs, sets the QPS ceiling, and why int8's ~4x byte cut
    is worth a guard-band rerank."""
    flops = 2.0 * dim + 3.0
    return flops / corpus_bytes_per_distance(dim, corpus_dtype)


def jnp_itemsize(dtype_name: str) -> int:
    return {"float32": 4, "bfloat16": 2, "int8": 1}[dtype_name]


@dataclasses.dataclass
class RooflineReport:
    arch_id: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float          # operand-bytes metric (brief)
    collective_wire_bytes: float     # ring wire estimate / device
    collective_summary: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    step_time_s: float               # max of the three terms (bound)
    mfu: float                       # model_flops / (chips*peak*step_time)
    memory_per_device: dict
    note: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def row(self) -> str:
        return (f"{self.arch_id:22s} {self.shape:14s} {self.mesh:10s} "
                f"c={self.compute_s:.3e} m={self.memory_s:.3e} "
                f"x={self.collective_s:.3e} dom={self.dominant:10s} "
                f"useful={self.useful_ratio:.2f} mfu~{self.mfu:.2%}")


def _count_params(tree, scale_moe: float = 1.0) -> float:
    """Matmul-participating parameter count; expert tensors scaled by
    (top_k/n_experts) when ``scale_moe`` < 1."""
    from ..layers.common import flatten_paths
    total = 0.0
    for path, leaf in flatten_paths(tree).items():
        size = float(np.prod(leaf.shape)) if leaf.shape else 1.0
        if "/moe/" in f"/{path}/" and "router" not in path and "shared" not in path:
            size *= scale_moe
        total += size
    return total


def analytic_model_flops(arch, shape, params_abstract) -> float:
    """Useful-work FLOPs per step (see module docstring)."""
    fam = arch.family
    if fam == "lm":
        cfg = arch.model_cfg
        scale = (cfg.top_k / cfg.n_experts) if cfg.is_moe else 1.0
        n_active = _count_params(params_abstract, scale_moe=scale)
        if shape.kind == "train":
            return 6.0 * n_active * shape.global_batch * shape.seq_len
        if shape.kind == "prefill":
            return 2.0 * n_active * shape.global_batch * shape.seq_len
        # decode: one token/seq forward + KV-cache attention reads
        kv_flops = 4.0 * shape.global_batch * shape.seq_len * \
            cfg.n_heads * (cfg.d_head if cfg.attn_kind == "gqa" else cfg.v_head_dim)
        return 2.0 * n_active * shape.global_batch + kv_flops
    if fam == "gnn":
        cfg = arch.model_cfg
        if shape.kind == "graph_batched":
            n = shape.n_graphs * shape.nodes_per_graph
            e = shape.n_graphs * shape.edges_per_graph
            d_in = 16
        elif shape.kind == "graph_sampled":
            from ..launch.steps import sampled_caps
            n, e = sampled_caps(shape)
            d_in = shape.d_feat
        else:
            n, e = shape.n_nodes, shape.n_edges
            d_in = shape.d_feat
        dims = [d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [7]
        dense = sum(2.0 * n * dims[i] * dims[i + 1] for i in range(cfg.n_layers))
        msg = sum(2.0 * e * dims[i + 1] for i in range(cfg.n_layers))
        mult = 3.0 if "train" in ("train",) else 1.0  # all GNN cells train: fwd+bwd
        return 3.0 * (dense + msg)
    if fam == "recsys":
        import re
        from ..layers.common import flatten_paths
        emb_re = re.compile(r"(^|/)(tables|wide)(/|$)")
        n_mlp = sum(
            float(np.prod(leaf.shape)) for path, leaf in
            flatten_paths(params_abstract).items() if not emb_re.search(path))
        b = shape.n_candidates or shape.global_batch
        mult = 6.0 if shape.kind == "train" else 2.0
        flops = mult * n_mlp * b
        if shape.kind == "retrieval" and arch.model_cfg.kind == "two_tower":
            flops = 2.0 * n_mlp * 1 + 2.0 * shape.n_candidates * arch.model_cfg.d_out
        return flops
    if fam == "engine":
        cfg = arch.model_cfg
        # per query: ~visit_cap expansions x max_degree neighbors x 2d flops
        sc = cfg.range_cfg.search
        return (2.0 * shape.global_batch * sc.visit_cap * cfg.max_degree * cfg.dim)
    return 0.0


def make_report(arch, shape, mesh_name: str, chips: int, cost: dict,
                mem: Any, analysis, model_flops: float,
                note: str = "") -> RooflineReport:
    # compiled.cost_analysis() and the HLO text describe the PARTITIONED
    # per-device module; whole-program totals are x chips. The brief's
    # "HLO_FLOPs / (chips * peak)" therefore reduces to per-device / peak.
    #
    # cost_analysis counts while bodies ONCE (verified) — for scanned
    # programs we use the trip-count-aware HLO walk (analysis.dot_flops /
    # hbm_bytes, analysis/hlo.py) instead. dot_flops excludes elementwise
    # FLOPs (matmuls dominate); hbm_bytes is the operand+result traffic
    # approximation (slightly conservative).
    coll = analysis.collectives
    flops_dev = max(float(cost.get("flops", 0.0)), analysis.dot_flops)
    bytes_cost = float(cost.get("bytes accessed", 0.0))
    bytes_dev = max(bytes_cost, analysis.hbm_bytes) if analysis.max_trip > 4 \
        else bytes_cost
    cbytes_dev = float(coll.total_operand_bytes)
    flops = flops_dev * chips
    byts = bytes_dev * chips
    cbytes = cbytes_dev * chips
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = cbytes_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step = max(compute_s, memory_s, collective_s)
    mfu = model_flops / (chips * PEAK_FLOPS * step) if step > 0 else 0.0
    mem_d = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                mem_d[f] = int(v)
    return RooflineReport(
        arch_id=arch.arch_id, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, collective_bytes=cbytes,
        collective_wire_bytes=float(coll.total_wire_bytes),
        collective_summary=coll.summary(),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        useful_ratio=(model_flops / flops) if flops else 0.0,
        step_time_s=step, mfu=mfu, memory_per_device=mem_d, note=note)


def save_reports(reports: list[RooflineReport], path: str):
    with open(path, "w") as f:
        json.dump([r.to_json() for r in reports], f, indent=1)


def load_reports(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)
