"""Synthetic embedding corpora with paper-matched range characteristics.

The paper's nine corpora (BIGANN, DEEP, MSTuring, GIST, SSNPP, OpenAI,
Text2Image, Wikipedia, MSMARCO) are multi-GB downloads unavailable offline.
What the paper's experiments actually depend on is the *shape* of each
dataset's range structure (Sec. 3):

* the percent-captured curve's steepness around the chosen radius
  ("robust" vs "perturbable" — Fig. 3),
* the match-size frequency distribution (Pareto: most queries zero results,
  few huge outliers — Fig. 4),
* match density growth with corpus size (Fig. 7).

We generate mixtures of Gaussian clusters with power-law cluster sizes plus a
uniform background, and draw queries as a mix of near-cluster probes (produce
matches) and background probes (produce zero matches). Each profile below is
tuned to reproduce one paper dataset's qualitative signature; benchmarks
sweep them exactly like the paper sweeps its corpora.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusProfile:
    """Generator knobs for one dataset signature."""

    name: str
    dim: int
    metric: str            # "l2" | "ip"
    n_clusters: int        # per 100k points
    zipf_a: float          # cluster-size power law (lower = heavier outliers)
    cluster_std: float     # intra-cluster spread (vs unit inter-cluster scale)
    background_frac: float # fraction of corpus drawn as unclustered noise
    query_hit_frac: float  # fraction of queries aimed at clusters
    query_std: float       # query offset from its cluster center
    latent_dim: int = 16   # intrinsic dimensionality: points live on a
                           # low-dim manifold linearly embedded in `dim`
                           # (real embeddings are low-intrinsic-dim; full-rank
                           # Gaussian shells are un-navigable and unrealistic)
    notes: str = ""


# Signatures mirror Figs. 3/4: robust-radius sets (bigann/deep/gist/wikipedia/
# msmarco) get tight, well-separated clusters; perturbable sets (ssnpp,
# text2image, msturing) get wide overlapping clusters; gist-like gets a few
# enormous clusters (its Fig. 4 row has hundreds of >1e4 outliers).
PROFILES: dict[str, CorpusProfile] = {
    p.name: p
    for p in [
        CorpusProfile("bigann-like", 128, "l2", 160, 2.2, 0.035, 0.55, 0.92, 0.05,
                      notes="robust radius; strong zero/nonzero separation"),
        CorpusProfile("deep-like", 96, "l2", 200, 2.4, 0.035, 0.60, 0.95, 0.05,
                      notes="robust; sparse matches"),
        CorpusProfile("msturing-like", 100, "l2", 120, 2.0, 0.08, 0.50, 0.96, 0.09,
                      notes="perturbable; mostly tiny result sets"),
        CorpusProfile("gist-like", 256, "l2", 24, 1.3, 0.06, 0.25, 0.15, 0.03,
                      latent_dim=20,
                      notes="few enormous clusters + few cluster-centered "
                            "queries -> most queries zero, outliers >1e3"),
        CorpusProfile("ssnpp-like", 200, "l2", 80, 2.0, 0.10, 0.40, 0.93, 0.11,
                      notes="dense, density grows fast with scale"),
        CorpusProfile("openai-like", 384, "l2", 100, 1.9, 0.05, 0.45, 0.70, 0.06,
                      latent_dim=24,
                      notes="moderate tail, many 1-10-result queries"),
        CorpusProfile("text2image-like", 200, "ip", 140, 2.3, 0.06, 0.55, 0.985, 0.10,
                      notes="IP metric; extremely skewed to zero results"),
        CorpusProfile("wikipedia-like", 256, "ip", 90, 2.1, 0.05, 0.45, 0.55, 0.06,
                      notes="IP; flatter distribution, many small result sets"),
        CorpusProfile("msmarco-like", 256, "ip", 110, 2.0, 0.05, 0.50, 0.70, 0.06,
                      notes="IP; early-stop separation exists (Fig. 5a)"),
    ]
}


@dataclasses.dataclass
class RangeDataset:
    name: str
    metric: str
    points: np.ndarray   # (N, d) float32
    queries: np.ndarray  # (Q, d) float32
    radius: Optional[float] = None  # filled by radius selection


def _zipf_sizes(rng: np.random.Generator, n_items: int, n_clusters: int, a: float) -> np.ndarray:
    w = rng.zipf(a, size=n_clusters).astype(np.float64)
    w = w / w.sum()
    sizes = np.floor(w * n_items).astype(np.int64)
    sizes[0] += n_items - sizes.sum()
    return sizes


def make_corpus(
    profile: str | CorpusProfile,
    n: int = 100_000,
    n_queries: int = 2_000,
    seed: int = 0,
) -> RangeDataset:
    """Low-intrinsic-dim corpus: all structure lives in a ``latent_dim``
    subspace, linearly embedded into ``dim`` by a random orthonormal map
    (+ tiny ambient noise) — the geometry real embedding models produce,
    and the geometry graph indices are navigable on."""
    p = PROFILES[profile] if isinstance(profile, str) else profile
    # Independent streams so the *distribution* (centers, basis) is identical
    # at every corpus size — Fig. 7's "larger sample from the same
    # distribution" semantics — and queries are reusable across scales.
    rng_dist = np.random.default_rng(seed * 7919 + 1)
    rng = np.random.default_rng(seed * 7919 + 2)
    rng_q = np.random.default_rng(seed * 7919 + 3)
    ld = min(p.latent_dim, p.dim)
    n_clusters = max(4, p.n_clusters // 4)
    centers = rng_dist.standard_normal((n_clusters, ld)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)  # unit shell

    n_bg = int(n * p.background_frac)
    n_cl = n - n_bg
    sizes = _zipf_sizes(rng_dist, n_cl, n_clusters, p.zipf_a)
    assign = np.repeat(np.arange(n_clusters), sizes)
    lat_cl = centers[assign] + (p.cluster_std * rng.standard_normal((n_cl, ld))).astype(np.float32)
    lat_bg = rng.standard_normal((n_bg, ld)).astype(np.float32)
    lat_bg /= np.linalg.norm(lat_bg, axis=1, keepdims=True)
    latent = np.concatenate([lat_cl, lat_bg]).astype(np.float32)
    rng.shuffle(latent, axis=0)

    n_hit = int(n_queries * p.query_hit_frac)
    # hit queries target clusters proportionally to size (big clusters produce
    # the paper's huge-result outliers)
    probs = sizes / sizes.sum()
    q_assign = rng_q.choice(n_clusters, size=n_hit, p=probs)
    q_hit = centers[q_assign] + (p.query_std * rng_q.standard_normal((n_hit, ld))).astype(np.float32)
    q_bg = rng_q.standard_normal((n_queries - n_hit, ld)).astype(np.float32)
    q_bg /= np.linalg.norm(q_bg, axis=1, keepdims=True)
    q_bg *= 1.25  # push background queries off the data shell -> zero results
    q_latent = np.concatenate([q_hit, q_bg]).astype(np.float32)
    rng_q.shuffle(q_latent, axis=0)

    if p.metric == "ip":
        # IP corpora: scale points by a lognormal "importance" so inner
        # products have the heavy positive tail real MIPS sets show
        scale = rng.lognormal(mean=0.0, sigma=0.25, size=(latent.shape[0], 1)).astype(np.float32)
        latent = latent * scale

    # random orthonormal embedding latent -> ambient + small ambient noise
    basis, _ = np.linalg.qr(rng_dist.standard_normal((p.dim, ld)))
    basis = basis.astype(np.float32)
    points = latent @ basis.T
    points += (0.01 * p.cluster_std) * rng.standard_normal(points.shape).astype(np.float32)
    queries = q_latent @ basis.T
    return RangeDataset(name=p.name, metric=p.metric, points=points, queries=queries)


def dataset_names() -> list[str]:
    return list(PROFILES)
