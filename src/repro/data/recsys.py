"""Synthetic recsys batch generator (Criteo-shaped clicks, two-tower pairs)."""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class RecsysDataConfig:
    n_dense: int = 13
    n_sparse: int = 26
    vocab: int = 100_000
    batch: int = 4096
    zipf_a: float = 1.2     # id popularity skew (real CTR ids are heavy-tailed)
    seed: int = 0
    two_tower: bool = False
    n_sparse_item: int = 0


def _ids(rng, shape, vocab, a):
    z = rng.zipf(a, size=shape)
    return np.minimum(z - 1, vocab - 1).astype(np.int32)


def recsys_batch(cfg: RecsysDataConfig, step: int) -> dict:
    rng = np.random.default_rng(cfg.seed * 999_983 + step)
    if cfg.two_tower:
        fu, fi = cfg.n_sparse, cfg.n_sparse_item or cfg.n_sparse
        user = _ids(rng, (cfg.batch, fu), cfg.vocab, cfg.zipf_a)
        # positive item correlates with user's first field (learnable signal)
        item = _ids(rng, (cfg.batch, fi), cfg.vocab, cfg.zipf_a)
        item[:, 0] = (user[:, 0] * 13 + 5) % cfg.vocab
        logq = np.log(1.0 / cfg.vocab) * np.ones((cfg.batch,), np.float32)
        return {"user_sparse": user, "item_sparse": item, "log_q": logq}
    sparse = _ids(rng, (cfg.batch, cfg.n_sparse), cfg.vocab, cfg.zipf_a)
    dense = rng.standard_normal((cfg.batch, cfg.n_dense)).astype(np.float32) \
        if cfg.n_dense else np.zeros((cfg.batch, 0), np.float32)
    # clicks depend on a hash of two sparse fields + one dense feature
    signal = ((sparse[:, 0] + sparse[:, min(1, cfg.n_sparse - 1)]) % 7 < 2)
    if cfg.n_dense:
        signal = signal | (dense[:, 0] > 1.2)
    noise = rng.random(cfg.batch) < 0.05
    label = (signal ^ noise).astype(np.float32)
    out = {"sparse": sparse, "label": label}
    if cfg.n_dense:
        out["dense"] = dense
    return out


def recsys_batches(cfg: RecsysDataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield recsys_batch(cfg, step)
        step += 1
