"""Graph datasets + the fanout neighbor sampler (GraphSAGE-style).

Synthetic stochastic-block-model graphs stand in for Cora / ogbn-products
(offline container). CSR layout on the host; the sampler produces padded
fixed-shape subgraph batches for jit. A ``range_graph`` source builds the
GNN input graph with the paper's own engine (DESIGN.md §6: the range /
k-NN graph *is* a graph dataset).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class GraphData:
    feats: np.ndarray      # (N, d) float32
    edge_src: np.ndarray   # (E,) int32
    edge_dst: np.ndarray   # (E,) int32
    labels: np.ndarray     # (N,) int32
    n_classes: int

    @property
    def n_nodes(self) -> int:
        return self.feats.shape[0]

    @property
    def n_edges(self) -> int:
        return self.edge_src.shape[0]


def make_sbm_graph(n_nodes: int, n_classes: int, d_feat: int, avg_degree: int,
                   p_in: float = 0.8, seed: int = 0) -> GraphData:
    """Stochastic block model with class-correlated features."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    centers = rng.standard_normal((n_classes, d_feat)).astype(np.float32)
    feats = centers[labels] + 0.5 * rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
    e = n_nodes * avg_degree
    src = rng.integers(0, n_nodes, e).astype(np.int32)
    same = rng.random(e) < p_in
    # destination: same-class node (homophily) or random
    perm_by_class = {c: np.nonzero(labels == c)[0] for c in range(n_classes)}
    dst = rng.integers(0, n_nodes, e).astype(np.int32)
    for c, nodes in perm_by_class.items():
        m = same & (labels[src] == c)
        dst[m] = nodes[rng.integers(0, len(nodes), int(m.sum()))]
    return GraphData(feats=feats, edge_src=src, edge_dst=dst, labels=labels,
                     n_classes=n_classes)


def to_csr(n_nodes: int, edge_src: np.ndarray, edge_dst: np.ndarray):
    """(indptr, indices): incoming neighbors of each node (dst -> srcs)."""
    order = np.argsort(edge_dst, kind="stable")
    sorted_dst = edge_dst[order]
    sorted_src = edge_src[order]
    counts = np.bincount(sorted_dst, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, sorted_src


@dataclasses.dataclass
class SampledBatch:
    """Padded layered subgraph: seed nodes + fanout-sampled neighborhoods."""
    node_ids: np.ndarray    # (N_sub,) global ids (-1 pad)
    feats: np.ndarray       # (N_sub, d)
    edge_src: np.ndarray    # (E_sub,) local ids (-1 pad)
    edge_dst: np.ndarray    # (E_sub,)
    labels: np.ndarray      # (N_sub,) -1 for non-seed
    seed_mask: np.ndarray   # (N_sub,) bool


class NeighborSampler:
    """Uniform fanout sampler over CSR (GraphSAGE). Fixed output shapes."""

    def __init__(self, data: GraphData, fanouts: tuple[int, ...] = (15, 10),
                 batch_nodes: int = 1024, seed: int = 0):
        self.data = data
        self.fanouts = fanouts
        self.batch_nodes = batch_nodes
        self.indptr, self.indices = to_csr(data.n_nodes, data.edge_src, data.edge_dst)
        self.rng = np.random.default_rng(seed)
        # fixed caps
        self.max_nodes = batch_nodes
        f = 1
        self.max_edges = 0
        for fo in fanouts:
            self.max_edges += self.max_nodes * fo if not self.max_edges else 0
        n, e = batch_nodes, 0
        total_n = batch_nodes
        for fo in fanouts:
            e += n * fo
            n = n * fo
            total_n += n
        self.max_nodes = total_n
        self.max_edges = e

    def sample(self) -> SampledBatch:
        d = self.data
        seeds = self.rng.integers(0, d.n_nodes, self.batch_nodes).astype(np.int64)
        nodes = [seeds]
        edges_src, edges_dst = [], []
        frontier = seeds
        # local id = position in the concatenated node list
        id_map = {}
        for nid in seeds:
            if nid not in id_map:
                id_map[nid] = len(id_map)
        all_nodes = list(dict.fromkeys(seeds.tolist()))
        frontier_local = [id_map[n] for n in seeds.tolist()]
        for fo in self.fanouts:
            nxt, nxt_local = [], []
            for local, nid in zip(frontier_local, frontier.tolist()):
                lo, hi = self.indptr[nid], self.indptr[nid + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = self.rng.integers(lo, hi, min(fo, int(deg)))
                for t in self.indices[take]:
                    t = int(t)
                    if t not in id_map:
                        id_map[t] = len(id_map)
                        all_nodes.append(t)
                    edges_src.append(id_map[t])
                    edges_dst.append(local)
                    nxt.append(t)
                    nxt_local.append(id_map[t])
            frontier = np.asarray(nxt, np.int64) if nxt else np.zeros(0, np.int64)
            frontier_local = nxt_local
            if len(frontier) == 0:
                break

        n_sub = len(all_nodes)
        e_sub = len(edges_src)
        node_ids = np.full(self.max_nodes, -1, np.int32)
        node_ids[:n_sub] = np.asarray(all_nodes, np.int32)[: self.max_nodes]
        feats = np.zeros((self.max_nodes, d.feats.shape[1]), np.float32)
        feats[:n_sub] = d.feats[np.asarray(all_nodes)[: self.max_nodes]]
        es = np.full(self.max_edges, -1, np.int32)
        ed = np.full(self.max_edges, -1, np.int32)
        es[:e_sub] = np.asarray(edges_src, np.int32)[: self.max_edges]
        ed[:e_sub] = np.asarray(edges_dst, np.int32)[: self.max_edges]
        labels = np.full(self.max_nodes, -1, np.int32)
        labels[: self.batch_nodes] = d.labels[seeds][: self.max_nodes]
        seed_mask = np.zeros(self.max_nodes, bool)
        seed_mask[: self.batch_nodes] = True
        return SampledBatch(node_ids=node_ids, feats=feats, edge_src=es,
                            edge_dst=ed, labels=labels, seed_mask=seed_mask)


def range_graph_dataset(points: np.ndarray, labels: np.ndarray, n_classes: int,
                        k: int = 8) -> GraphData:
    """Build a GNN dataset whose edges come from the paper's k-NN engine."""
    import jax.numpy as jnp

    from ..core.build import build_knn_graph
    from ..utils import INVALID_ID

    g = build_knn_graph(jnp.asarray(points), k=k)
    nbrs = np.asarray(g.neighbors)
    n = points.shape[0]
    src = nbrs.reshape(-1)
    dst = np.repeat(np.arange(n, dtype=np.int32), nbrs.shape[1])
    ok = src != INVALID_ID
    return GraphData(feats=points.astype(np.float32), edge_src=src[ok].astype(np.int32),
                     edge_dst=dst[ok], labels=labels.astype(np.int32),
                     n_classes=n_classes)
