"""Synthetic LM token pipeline: deterministic, shardable, restart-safe.

A Zipf-distributed token stream with induced bigram structure (so the loss
actually falls during the example runs). The iterator is seeded by
(global) step so an elastic restart resumes mid-stream deterministically —
batch ``i`` is identical regardless of how many hosts produce it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int = 1000
    seq_len: int = 128
    batch: int = 8
    zipf_a: float = 1.3
    seed: int = 0


def _zipf_tokens(rng, n, vocab, a):
    z = rng.zipf(a, size=n)
    return np.minimum(z - 1, vocab - 1).astype(np.int32)


def lm_batch(cfg: LMDataConfig, step: int) -> dict:
    """Batch ``step`` of the stream (pure function of (cfg, step))."""
    rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
    toks = _zipf_tokens(rng, (cfg.batch * (cfg.seq_len + 1)), cfg.vocab, cfg.zipf_a)
    toks = toks.reshape(cfg.batch, cfg.seq_len + 1)
    # induce learnable structure: token t+1 = f(token t) half the time
    flip = rng.random((cfg.batch, cfg.seq_len)) < 0.5
    mapped = (toks[:, :-1] * 31 + 7) % cfg.vocab
    toks[:, 1:] = np.where(flip, mapped, toks[:, 1:])
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def lm_batches(cfg: LMDataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield lm_batch(cfg, step)
        step += 1
