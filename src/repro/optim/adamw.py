"""AdamW + schedules + global-norm clipping + gradient accumulation.

Self-contained functional optimizer (no optax dependency). Moments are kept
in the *param dtype* by default; ``moment_dtype`` lets big-model configs
(deepseek-v2 on 16 GB v5e chips) trade precision for HBM headroom — the
memory accounting shows up directly in the dry-run memory_analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Optional[Any] = None   # None -> match param dtype
    accum_dtype: Optional[Any] = None    # grad-accumulation dtype
                                         # (None -> fp32; the 236B uses bf16
                                         # to fit 16 GB/chip HBM)
    schedule: str = "cosine"             # cosine | linear | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((s - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    else:
        decay = jnp.ones(())
    return cfg.lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), gn


def init_adamw(params, cfg: AdamWConfig) -> dict:
    def zeros_like(p):
        dt = cfg.moment_dtype or p.dtype
        return jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros_like, params),
        "v": jax.tree.map(zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state: dict, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = mf / b1c
        vhat = vf / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gn, "lr": lr}


def make_train_step(
    loss_fn: Callable,
    opt_cfg: AdamWConfig,
    *,
    accum_steps: int = 1,
    grad_transform: Optional[Callable] = None,   # e.g. compressed cross-replica psum
):
    """Builds ``train_step(params, opt_state, batch) -> (params, state, metrics)``.

    ``accum_steps > 1`` scans microbatches (batch's leading axis is split),
    summing grads — the standard fixed-memory large-batch recipe.
    """
    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if accum_steps > 1:
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), b)
            mb = micro(batch)

            def body(carry, xs):
                gacc, lacc = carry
                loss, _, grads = grads_of(params, xs)
                gacc = jax.tree.map(
                    lambda a, g: (a + g.astype(a.dtype)), gacc, grads)
                return (gacc, lacc + loss), None

            adt = opt_cfg.accum_dtype or jnp.float32
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step
