from .adamw import (
    AdamWConfig, adamw_update, clip_by_global_norm, global_norm, init_adamw,
    make_train_step, schedule_lr,
)

__all__ = [k for k in dir() if not k.startswith("_")]
