"""RangeServer: the serving layer around the range engine.

Production anatomy (single-process simulation of the real service):

* **admission queue** — requests land with an id + deadline; the batcher
  drains up to ``max_batch`` or until ``max_wait_s`` passes (micro-batching:
  the standard accelerator-serving latency/throughput knob). Radii are
  per-request: a micro-batch freely mixes radii, each lane answered at its
  own (the paper's queries are radius-heterogeneous by nature). Admission is
  **bounded**: beyond ``max_queue`` pending requests, ``submit`` rejects
  (and counts) instead of growing the deque without limit — queue growth
  under overload is a latency bomb, load shedding is the production answer.
* **bucketed dispatch** — batches are padded to power-of-two sizes so jit
  compiles O(log B) programs total.
* **lockstep execution** (default) — one ``range_search_compacted`` program
  per micro-batch: phase 1 (uniform beam) over the batch, compacted
  survivors run the greedy/doubling phase, the whole batch returns together.
* **continuous batching** (``ServerConfig.continuous``) — the tail-latency
  mode. Phase 1 still runs per micro-batch, but λ-saturated lanes hand
  their ``GreedyState`` checkpoints to a persistent ``LaneScheduler`` pool
  advanced ``slice_rounds`` expansions per step; cheap lanes answer at
  phase 1 and leave immediately. A dense-region straggler occupies one pool
  slot while point queries flow past it — it no longer sets the batch's
  critical path. An optional ``EffortPredictor`` splits each drain into a
  cheap wide-batch dispatch and a separate heavy dispatch (predicted match
  count vs ``effort_threshold``); prediction shapes batch composition only,
  results are identical either way.
* **latency accounting** — every response carries ``timings``
  (queue/service/total) and feeds per-op + end-to-end log-bucket
  histograms (``latency_summary()``); tails, not means, are the SLO.
* **multi-shard** — given a mesh + ShardedCorpus, dispatch goes through
  dist.sharded_range_search and merges per-shard unions (lockstep only).
* **live mutation** — given a ``repro.live.LiveIndex``, requests may carry
  ``op="insert"`` / ``op="delete"`` alongside range queries in the same
  admission queue. The batcher applies a micro-batch's mutations first
  (coalesced in arrival order), triggers threshold consolidation, then
  refreshes its **epoch snapshot** and answers the batch's queries against
  that one consistent ``(graph, corpus, tombstones, epoch)`` view — queries
  never observe a half-applied mutation batch. In continuous mode the pool
  drains to completion against the old snapshot before mutations apply
  (consolidation permutes slots; a checkpoint must not cross an epoch).
  Returned ids are external ids.
* **filtered range retrieval** — range requests may carry ``filter_labels``
  (+ ``filter_mode``) when the served corpus is labeled; filtered and
  unfiltered requests share micro-batches (unfiltered/pad lanes ride an
  all-pass predicate, which is bitwise-neutral), inserts may tag their
  vector with labels, and ``stats["filtered_batches"]`` counts batches
  that carried at least one predicate lane.
* per-request stats (visited, distance comps, early-stopped) surface in the
  response for monitoring.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core.corpus import corpus_dtype_name
from ..core.engine import RangeSearchEngine
from ..core.labels import LabelFilter, make_label_filter, make_mask
from ..core.range_search import (
    RangeConfig, RangeResult, _maybe_rerank_host, _tier_of, finalize_results,
    greedy_coverage, greedy_lane_done, greedy_resume_batch, greedy_seed_batch,
    range_phase1, range_search_compacted,
)
from ..dist.sharded_engine import ShardedCorpus, sharded_range_search
from ..fault.degraded import RetryPolicy, fault_tolerant_sharded_search
from ..fault.replica import HedgePolicy, ReplicaFleet, ReplicatedCorpus
from ..fault.errors import DEADLINE_EXPIRED, QUEUE_FULL, SHARD_LOST
from ..fault.injector import FaultInjector
from ..utils import INVALID_ID, next_pow2
from .latency import LatencyHistogram
from .scheduler import LaneScheduler, _gather_lanes

#: ops a Request may carry. "count" is the aggregate-only query shape:
#: |S_r(q)| as a per-lane certified match count (post-rerank, the same
#: number a range answer's ``count`` field carries) with NO ids/dists
#: payload — the paper's dedup/count workload. Count requests ride the
#: same admission queue, micro-batches, and search programs as range
#: requests; only the response materialization differs.
REQUEST_OPS = ("range", "count", "insert", "delete")


@dataclasses.dataclass(kw_only=True)
class Request:
    """One unit of admitted work, op-tagged. Construct by keyword.

    ``deadline_s`` is a latency budget in seconds, measured from
    ``submit``: a range request still queued past its budget is shed with
    ``code="deadline_expired"``; one whose phase-2 lane is mid-search is
    force-finalized into a certified partial answer (``complete=False``)
    instead of resumed. ``None`` means no budget (never expires).

    ``filter_labels`` (range op, labeled corpus only) restricts the answer
    to points carrying those labels — ``filter_mode="and"`` requires all of
    them, ``"or"`` any. Filtered and unfiltered requests batch together
    freely (unfiltered lanes ride an all-pass predicate). ``labels``
    (insert op) tags the inserted vector with label ids."""
    req_id: int
    op: str = "range"                   # range | count | insert | delete
    query: Optional[np.ndarray] = None  # range/count/insert: the vector
    radius: Optional[float] = None      # per-request; batches mix radii freely
    deadline_s: Optional[float] = None  # latency budget (seconds from submit)
    delete_ids: Optional[np.ndarray] = None  # delete: external ids to remove
    filter_labels: Optional[np.ndarray] = None  # range: predicate label ids
    filter_mode: str = "and"            # range: "and" | "or" over filter_labels
    labels: Optional[np.ndarray] = None  # insert: label ids of the new vector


@dataclasses.dataclass(kw_only=True)
class Response:
    """Op-tagged answer. ``timings`` decomposes ``latency_s`` into
    queue (submit→drain) and service (drain→response) seconds.

    Degradation surface (``repro.fault``): ``complete`` is False when the
    answer is a certified partial — deadline-truncated search or shard
    loss. ``coverage`` estimates the searched fraction (visited-frontier
    fraction for deadline truncation, ``shards_ok/shards_total`` for shard
    loss; 1.0 when complete). ``code`` carries the machine-readable reason
    from :mod:`repro.fault.errors` (``queue_full`` / ``deadline_expired``
    / ``shard_lost``; None when healthy). Partial results are truncated,
    never corrupted: every returned id is exact-distance-certified within
    the request radius."""
    req_id: int
    op: str = "range"               # range | count | insert | delete | error
    ids: np.ndarray = None          # count op: empty (count-only payload)
    dists: np.ndarray = None
    count: int = 0
    overflow: bool = False
    es_stopped: bool = False
    latency_s: float = 0.0
    radius: float = float("nan")  # the radius this request was answered at
    epoch: int = 0                # index epoch the request was served/applied at
    timings: Optional[dict] = None  # {"queue_s", "service_s", "total_s"}
    complete: bool = True           # False: partial (deadline / shard loss)
    coverage: float = 1.0           # searched fraction estimate (1.0 = full)
    code: Optional[str] = None      # fault.errors taxonomy; None = healthy
    shards_ok: Optional[int] = None     # sharded serving: shards merged
    shards_total: Optional[int] = None  # sharded serving: shards configured
    replicas_ok: Optional[int] = None     # replicated serving: healthy replicas
    replicas_total: Optional[int] = None  # replicated serving: S * R
    filtered: bool = False          # answered under a label predicate


@dataclasses.dataclass
class ServerConfig:
    max_batch: int = 256
    max_wait_s: float = 0.005
    default_radius: float = 1.0
    es_radius_factor: float = 0.0   # >0 enables early stopping at factor*r
    expand_width: int = 0           # DEPRECATED: deploy-time search overrides
                                    # belong on EngineDeployConfig.overrides()
    max_queue: int = 8192           # admission bound; 0 disables admission
                                    # entirely (drain-only maintenance mode)
    auto_consolidate: bool = True   # live engines: threshold consolidation
                                    # between micro-batches
    # -- continuous batching (tail-latency mode) ----------------------------
    continuous: bool = False        # persistent-lane phase-2 scheduling
    lanes: int = 32                 # pool width (rounded up to pow2)
    slice_rounds: int = 8           # greedy expansions per lane per tick
    effort_threshold: float = 64.0  # predicted matches >= this -> heavy bucket

    def __post_init__(self):
        if self.expand_width > 0:
            warnings.warn(
                "ServerConfig.expand_width is deprecated; deploy-time "
                "search overrides belong on "
                "EngineDeployConfig.overrides(expand_width=...)",
                DeprecationWarning, stacklevel=3)


class RangeServer:
    def __init__(
        self,
        engine: Optional[RangeSearchEngine],
        cfg: RangeConfig,
        server_cfg: ServerConfig = ServerConfig(),
        *,
        mesh=None,
        sharded=None,
        live=None,
        effort=None,
        injector: Optional[FaultInjector] = None,
        retry: Optional[RetryPolicy] = None,
        replicas: int = 1,
        hedge: Optional[HedgePolicy] = None,
        clock=time.perf_counter,
    ):
        """``live`` is a ``repro.live.LiveIndex``; it supersedes ``engine``
        (pass ``engine=None``) and enables insert/delete requests.
        ``effort`` is a fitted ``repro.models.EffortPredictor``; continuous
        mode uses it to split each drain into cheap/heavy dispatches.

        Sharded serving without a ``mesh`` (or with an ``injector``) goes
        through the fault-tolerant host fan-out
        (``fault.fault_tolerant_sharded_search``): per-shard retries with
        ``retry`` backoff, validated answers, and graceful degradation on
        permanent shard loss (responses annotated ``shards_ok/shards_total``,
        ``code="shard_lost"``). ``injector`` is a seeded
        ``fault.FaultInjector`` for chaos testing. ``clock`` is the
        monotonic time source for queueing/deadline decisions — injectable
        so deadline tests advance a fake clock deterministically.

        ``replicas=R`` (R > 1) serves ``sharded`` R-way replicated through
        the hedged fan-out (``sharded`` may equivalently be a pre-built
        ``fault.ReplicatedCorpus`` or a ``fault.ReplicaFleet`` to share
        breaker state); ``hedge`` is a ``fault.HedgePolicy`` deriving the
        hedge delay from the fleet's per-shard latency histograms. Replica
        health rides the completeness contract: ``coverage < 1.0`` only
        when every replica of a shard is exhausted, ``code="replica_lost"``
        when the answer is whole but redundancy is degraded. ``step()``
        runs one fleet recovery sweep per micro-batch."""
        if replicas > 1 and sharded is None:
            raise ValueError("replicas > 1 needs a sharded corpus")
        if engine is None and live is None and sharded is None:
            raise ValueError("need an engine, a sharded corpus, or a live index")
        if injector is not None and sharded is None:
            raise ValueError("fault injection targets shards; pass sharded=")
        self.fleet: Optional[ReplicaFleet] = None
        if isinstance(sharded, ReplicaFleet):
            self.fleet = sharded
        elif isinstance(sharded, ReplicatedCorpus):
            self.fleet = ReplicaFleet(sharded)
        elif replicas > 1:
            if sharded is None:
                raise ValueError("replicas > 1 needs a sharded corpus")
            self.fleet = ReplicaFleet(ReplicatedCorpus.replicate(
                sharded, replicas))
        if self.fleet is not None:
            if mesh is not None:
                raise ValueError("replicated serving is host fan-out; "
                                 "drop mesh= or serve unreplicated")
            sharded = self.fleet.corpus.replica(0)
        self.hedge = hedge
        self.engine = engine
        self.live = live
        if server_cfg.expand_width > 0:
            cfg = dataclasses.replace(cfg, search=dataclasses.replace(
                cfg.search, expand_width=server_cfg.expand_width))
        # the declarative SearchConfig.corpus_dtype is a deploy contract:
        # what the config promises must be what the served corpus actually
        # stores (an f32 corpus behind an "int8" config would silently
        # serve at 4x the planned HBM budget, and vice versa would skip
        # the planned rerank stage)
        if live is not None:
            served = live.points
        elif sharded is not None:
            served = sharded.points
        else:
            served = engine.points
        actual = corpus_dtype_name(served)
        if cfg.search.corpus_dtype != actual:
            raise ValueError(
                f"SearchConfig.corpus_dtype={cfg.search.corpus_dtype!r} but "
                f"the served corpus stores {actual!r}")
        self.cfg = cfg
        self.scfg = server_cfg
        self.mesh = mesh
        self.sharded = sharded
        self.effort = effort
        self.injector = injector
        self.retry = retry or RetryPolicy()
        self._clock = clock
        self.queue: deque[tuple[Request, float]] = deque()
        self._view = live.snapshot() if live is not None else None
        self._pool: Optional[LaneScheduler] = None
        if server_cfg.continuous:
            if sharded is not None or mesh is not None:
                raise ValueError("continuous batching is single-shard; "
                                 "drop continuous=True for sharded serving")
            if cfg.mode != "greedy":
                raise ValueError("continuous batching schedules the greedy "
                                 f"phase; cfg.mode={cfg.mode!r}")
            self._pool = LaneScheduler(self._device_corpus(), self._graph(), cfg,
                                       server_cfg.lanes,
                                       server_cfg.slice_rounds)
        self.hist = {"all": LatencyHistogram(),
                     "service": LatencyHistogram()}
        self.stats = {
            "served": 0, "batches": 0, "es_stopped": 0, "overflow": 0,
            # bounded admission: requests shed at the queue limit (the
            # overload signal capacity planning alarms on)
            "rejected": 0,
            # live mutation counters; epoch mirrors the served snapshot
            "inserts": 0, "deletes": 0, "consolidations": 0, "epoch": 0,
            # quantized-corpus two-pass: candidates that fell in the radius
            # guard band and were exact-reranked (0 on f32/bf16 corpora);
            # the band hit rate is what capacity planning watches — a wide
            # band means the corpus scales are too coarse for the traffic's
            # radii
            "reranked": 0,
            # radius-dispersion counters: mixed-radius batches are the
            # heterogeneous-traffic regime the per-query radius path exists
            # for; the running moments let dashboards derive mean/std
            "mixed_radius_batches": 0,
            "radius_min": float("inf"), "radius_max": float("-inf"),
            "radius_sum": 0.0, "radius_sumsq": 0.0,
            # continuous-batching counters: pool_rotations counts retire
            # events that freed slots while OTHER lanes stayed in flight —
            # the lockstep-break actually happening, not just configured
            "pool_admitted": 0, "pool_retired": 0, "pool_ticks": 0,
            "pool_rotations": 0, "pool_oneshot": 0,
            "bucket_cheap": 0, "bucket_heavy": 0,
            # fault-tolerance counters: deadline_shed = expired while still
            # queued (no results), deadline_partial = force-finalized lanes
            # (certified partials); shard_retries / shards_lost come from
            # the degraded fan-out path
            "deadline_shed": 0, "deadline_partial": 0,
            "shard_retries": 0, "shards_lost": 0, "degraded_batches": 0,
            # replication counters (mirrors of ReplicaFleet.stats):
            # hedges_fired/hedge_wins = hedged reads launched / won the
            # race, breaker_trips = circuit breakers opened, replicas_lost/
            # recovered = fleet membership churn
            "hedges_fired": 0, "hedge_wins": 0, "breaker_trips": 0,
            "replicas_lost": 0, "replicas_recovered": 0,
            # filtered range retrieval: micro-batches that carried at least
            # one label-predicate lane (filtered + unfiltered lanes batch
            # together; unfiltered lanes ride an all-pass predicate)
            "filtered_batches": 0, "filtered_requests": 0,
            # aggregate-only workload: op="count" requests served (certified
            # per-lane match counts, no ids/dists payload)
            "count_requests": 0,
        }

    # -- served view ---------------------------------------------------------
    def _corpus(self):
        return self._view.points if self.live is not None else self.engine.points

    def _device_corpus(self):
        """The jit-safe hot arm of the served corpus: a `TieredCorpus` never
        enters a jitted walk — phase 1 / greedy resume run on its device
        codes; `_finalize` hands the full tier to the host rerank."""
        pts = self._corpus()
        tier = _tier_of(pts)
        return tier.device if tier is not None else pts

    def _finalize(self, qj, rj, res, lf):
        """`finalize_results` (tombstones, label predicate, fused resident
        rerank) plus the tiered corpus's host-fetched guard-band rerank —
        the continuous-path twin of `_walk_compacted`'s finish()."""
        res = finalize_results(self._device_corpus(), qj, rj, res, self.cfg,
                               self._tombstones(),
                               None if lf is None else self._labels(), lf)
        pts = self._corpus()
        if _tier_of(pts) is not None:
            res = _maybe_rerank_host(pts, qj, rj, res, self.cfg)
        return res

    def _graph(self):
        return self._view.graph if self.live is not None else self.engine.graph

    def _start_ids(self):
        return (self._view.start_ids if self.live is not None
                else self.engine.start_ids)

    def _tombstones(self):
        return self._view.tombstones if self.live is not None else None

    def _labels(self):
        """Packed label store of the served view (slot space), or None for
        an unlabeled corpus. Sharded serving keeps labels per shard — the
        store here is only a capability/width probe; per-shard evaluation
        happens inside the fan-out."""
        if self.live is not None:
            return self._view.labels
        if self.sharded is not None:
            return self.sharded.labels
        return self.engine.labels if self.engine is not None else None

    def _num_labels(self) -> int:
        """Label-id space the packed store can represent (32 per word)."""
        lab = self._labels()
        return 0 if lab is None else 32 * int(lab.shape[-1])

    def _batch_filter(self, reqs, bucket: int) -> Optional[LabelFilter]:
        """Per-lane predicate for one padded micro-batch, or None when no
        lane filters. Unfiltered and pad lanes get the all-pass predicate
        (AND over the empty mask), which is bitwise-neutral."""
        if all(rq.filter_labels is None for rq in reqs):
            return None
        pad = bucket - len(reqs)
        entries = [rq.filter_labels for rq in reqs] + [None] * pad
        modes = [rq.filter_mode for rq in reqs] + ["and"] * pad
        return make_label_filter(entries, self._num_labels(), modes=modes)

    def _epoch(self) -> int:
        return self._view.epoch if self._view is not None else 0

    def _externalize(self, ids: np.ndarray) -> np.ndarray:
        if self.live is None:
            return ids
        from ..live.index import externalize_ids
        return externalize_ids(self._view.ext_ids, ids)

    # -- admission -------------------------------------------------------
    def submit(self, req: Request) -> Optional[Response]:
        """Admit a request; returns ``None`` on admission, or a structured
        rejection ``Response(op="error", code="queue_full")`` when the
        queue is at ``max_queue`` — the shed is counted AND delivered, so
        drivers see every rejected request instead of silently dropping it.
        Malformed requests are rejected HERE, at the client's call site —
        one bad request admitted into a micro-batch would otherwise take
        down every other request batched with it."""
        if req.op not in REQUEST_OPS:
            raise ValueError(f"unknown op {req.op!r}")
        if req.op in ("insert", "delete") and self.live is None:
            raise ValueError(f"{req.op!r} requests need a live index")
        if req.op == "delete":
            if req.delete_ids is None:
                raise ValueError("delete requests need delete_ids")
        elif req.query is None:
            raise ValueError(f"{req.op!r} requests need a query vector")
        if req.filter_labels is not None:
            if req.op not in ("range", "count"):
                raise ValueError(
                    "filter_labels applies to range/count requests")
            if self._labels() is None:
                raise ValueError(
                    "served corpus has no labels attached; filtered range "
                    "requests need a labeled engine/index")
            if req.filter_mode not in ("and", "or"):
                raise ValueError(f"filter_mode must be 'and' or 'or', "
                                 f"got {req.filter_mode!r}")
            fl = np.atleast_1d(np.asarray(req.filter_labels))
            if fl.size and int(fl.max()) >= self._num_labels():
                raise ValueError(
                    f"filter label id {int(fl.max())} out of range for a "
                    f"{self._num_labels()}-label corpus")
        if req.labels is not None:
            if req.op != "insert":
                raise ValueError("labels= applies to insert requests")
            if self.live is None or self.live.labels is None:
                raise ValueError(
                    "labeled inserts need a labeled live index")
        if req.deadline_s is not None and req.deadline_s < 0:
            raise ValueError("deadline_s must be >= 0 (or None for no budget)")
        if len(self.queue) >= self.scfg.max_queue:
            self.stats["rejected"] += 1
            return self._record(self._error_response(
                req, QUEUE_FULL, latency_s=0.0))
        self.queue.append((req, self._clock()))
        return None

    @staticmethod
    def _error_response(req: Request, code: str,
                        latency_s: float = 0.0, timings=None) -> Response:
        return Response(
            req_id=req.req_id, op="error", ids=np.zeros(0, np.int64),
            dists=np.zeros(0, np.float32), count=0,
            latency_s=latency_s, timings=timings,
            radius=float("nan") if req.radius is None else float(req.radius),
            complete=False, coverage=0.0, code=code)

    @staticmethod
    def _deadline_at(req: Request, arrive: float) -> float:
        return (float("inf") if req.deadline_s is None
                else arrive + req.deadline_s)

    def _shed_expired(self, batch, svc0: float):
        """Split a drained micro-batch into (alive, expired-error responses).

        Only query (range/count) requests expire — a mutation's effect is
        wanted no matter how late it applies. Expiry is strict
        (``now > deadline``) so a zero budget still gets the work done at
        the instant of submission under a frozen test clock."""
        alive, out = [], []
        for rq, arrive in batch:
            if (rq.op in ("range", "count")
                    and svc0 > self._deadline_at(rq, arrive)):
                self.stats["deadline_shed"] += 1
                out.append(self._record(self._error_response(
                    rq, DEADLINE_EXPIRED, latency_s=svc0 - arrive,
                    timings=self._timings(arrive, svc0, svc0))))
            else:
                alive.append((rq, arrive))
        return alive, out

    def pending(self) -> int:
        return len(self.queue)

    def in_flight(self) -> int:
        """Lanes checkpointed in the continuous pool (0 in lockstep mode)."""
        return self._pool.occupancy if self._pool is not None else 0

    # -- batching ------------------------------------------------------------
    def _drain(self) -> list[tuple[Request, float]]:
        out = []
        t0 = self._clock()
        while self.queue and len(out) < self.scfg.max_batch:
            out.append(self.queue.popleft())
            if not self.queue and (self._clock() - t0) < self.scfg.max_wait_s:
                time.sleep(0)  # yield; more requests may land in a real server
                break
        return out

    # -- response plumbing ---------------------------------------------------
    def _record(self, resp: Response) -> Response:
        self.hist["all"].record(resp.latency_s)
        if resp.timings is not None:
            self.hist["service"].record(resp.timings["service_s"])
        if resp.op not in self.hist:
            self.hist[resp.op] = LatencyHistogram()
        self.hist[resp.op].record(resp.latency_s)
        return resp

    def latency_summary(self) -> dict:
        """Per-op + end-to-end latency quantiles (ms); see LatencyHistogram."""
        return {k: h.summary() for k, h in self.hist.items()}

    @staticmethod
    def _timings(arrive: float, svc0: float, now: float) -> dict:
        return {"queue_s": svc0 - arrive, "service_s": now - svc0,
                "total_s": now - arrive}

    def _track_radii(self, radii: np.ndarray) -> None:
        rb = np.asarray(radii, np.float64)
        if rb.size == 0:
            return
        self.stats["mixed_radius_batches"] += int(rb.min() != rb.max())
        self.stats["radius_min"] = min(self.stats["radius_min"], float(rb.min()))
        self.stats["radius_max"] = max(self.stats["radius_max"], float(rb.max()))
        self.stats["radius_sum"] += float(rb.sum())
        self.stats["radius_sumsq"] += float((rb * rb).sum())

    # -- mutation ------------------------------------------------------------
    def _apply_mutations(self, muts: list[tuple[Request, float]],
                         svc0: float) -> list[Response]:
        """Apply a micro-batch's mutations: ONE coalesced insert batch, then
        ONE coalesced delete batch.

        Reordering within the micro-batch is sound because external ids are
        never reused: insert-then-delete of the same id inside one batch
        lands in the same final state either way, and a delete can never
        precede "its" insert across the reorder (the id did not exist when
        the delete was submitted). Coalescing is what makes churn traffic
        cheap — each batch pays one fixed-shape insert step and one bitset
        update instead of one dispatch per request."""
        out = []
        ins = [(rq, t) for rq, t in muts if rq.op == "insert"]
        dels = [(rq, t) for rq, t in muts if rq.op == "delete"]
        if ins:
            lab = None
            if self.live.labels is not None:
                nl = 32 * int(self.live.labels.shape[1])
                lab = np.stack([
                    make_mask([] if rq.labels is None else rq.labels, nl)
                    for rq, _ in ins])
            ext = self.live.insert(np.stack([rq.query for rq, _ in ins]),
                                   labels=lab)
            self.stats["inserts"] += len(ins)
            now = self._clock()
            for (rq, arrive), e in zip(ins, ext):
                ids = np.asarray([e], np.int64)
                out.append(self._record(Response(
                    req_id=rq.req_id, ids=ids,
                    dists=np.zeros(1, np.float32), count=1,
                    overflow=False, es_stopped=False,
                    latency_s=now - arrive, op="insert",
                    epoch=self.live.epoch,
                    timings=self._timings(arrive, svc0, now))))
        if dels:
            per_req = [np.atleast_1d(np.asarray(rq.delete_ids, np.int64))
                       for rq, _ in dels]
            self.stats["deletes"] += self.live.delete(np.concatenate(per_req))
            now = self._clock()
            for (rq, arrive), ids in zip(dels, per_req):
                out.append(self._record(Response(
                    req_id=rq.req_id, ids=ids,
                    dists=np.zeros(len(ids), np.float32), count=len(ids),
                    overflow=False, es_stopped=False,
                    latency_s=now - arrive, op="delete",
                    epoch=self.live.epoch,
                    timings=self._timings(arrive, svc0, now))))
        return out

    # -- lockstep execution --------------------------------------------------
    def _execute(self, queries: np.ndarray, radii: np.ndarray,
                 label_filter: Optional[LabelFilter] = None):
        """Dispatch one padded batch; returns ``(RangeResult, DegradedResult
        | None)`` — the second element is populated only on the
        fault-tolerant sharded path (no mesh, or an injector present).
        ``label_filter`` (optional) covers every padded lane; each dispatch
        path evaluates it at its own result stage."""
        es = (self.scfg.es_radius_factor * jnp.asarray(radii)
              if self.scfg.es_radius_factor > 0 else None)
        qs = jnp.asarray(queries)
        rs = jnp.asarray(radii)
        if self.live is not None:
            return self._view.range(qs, rs, cfg=self.cfg, es_radius=es,
                                    filter=label_filter), None
        if self.sharded is not None:
            if (self.mesh is not None and self.injector is None
                    and self.fleet is None
                    and getattr(self.sharded, "tiers", None) is None):
                return sharded_range_search(
                    mesh=self.mesh, corpus=self.sharded, queries=qs, r=rs,
                    cfg=self.cfg, es_radius=es,
                    label_filter=label_filter), None
            d = fault_tolerant_sharded_search(
                corpus=self.sharded, queries=qs, r=rs, cfg=self.cfg,
                es_radius=es, label_filter=label_filter,
                injector=self.injector, retry=self.retry,
                fleet=self.fleet, hedge=self.hedge)
            self.stats["degraded_batches"] += int(not d.complete)
            self.stats["shard_retries"] += int(d.attempts.sum()) - d.shards_total
            self.stats["shards_lost"] += d.shards_total - d.shards_ok
            if self.fleet is not None:
                self.stats.update(self.fleet.stats)  # running fleet totals
            return d.result, d
        return range_search_compacted(
            corpus=self.engine.points, graph=self.engine.graph, queries=qs,
            start_ids=self.engine.start_ids, r=rs, cfg=self.cfg, es_radius=es,
            labels=None if label_filter is None else self.engine.labels,
            label_filter=label_filter), None

    def step(self) -> list[Response]:
        """Serve one micro-batch from the queue.

        Mutations in the batch apply first (in arrival order); the epoch
        snapshot then advances ONCE and every query in the batch is answered
        against that view — a consistent ``(graph, corpus, tombstones,
        epoch)`` even as later batches keep mutating. Requests batch
        regardless of radius: the radius vector rides alongside the query
        matrix (padded identically), and every layer below answers each lane
        at its own radius. In continuous mode a step additionally advances
        the persistent lane pool one tick and retires finished lanes.
        """
        if self._pool is not None:
            return self._step_continuous()
        if self.fleet is not None:
            # background recovery sweep: rebuild lost replicas and re-admit
            # them through the breaker's half-open probe
            self.fleet.maintain()
            self.stats.update(self.fleet.stats)
        batch = self._drain()
        if not batch:
            return []
        svc0 = self._clock()
        out = []
        if self.live is not None:
            muts = [b for b in batch if b[0].op in ("insert", "delete")]
            batch = [b for b in batch if b[0].op in ("range", "count")]
            if muts:
                out.extend(self._apply_mutations(muts, svc0))
                if (self.scfg.auto_consolidate
                        and self.live.maybe_consolidate()):
                    self.stats["consolidations"] += 1
                self._view = self.live.snapshot()
            self.stats["epoch"] = self._view.epoch
            self.stats["batches"] += 1 if (muts and not batch) else 0
        batch, shed = self._shed_expired(batch, svc0)
        out.extend(shed)
        if not batch:
            return out
        reqs = [b[0] for b in batch]
        arrive = [b[1] for b in batch]
        n = len(reqs)
        bucket = next_pow2(n)
        q = np.stack([rq.query for rq in reqs])
        radii = np.asarray(
            [self.scfg.default_radius if rq.radius is None else rq.radius
             for rq in reqs], np.float32)
        if bucket > n:  # pad to bucket with repeats (masked out of responses)
            q = np.concatenate([q, np.repeat(q[:1], bucket - n, axis=0)])
            radii = np.concatenate([radii, np.repeat(radii[:1], bucket - n)])
        lf = self._batch_filter(reqs, bucket)
        n_filtered = sum(rq.filter_labels is not None for rq in reqs)
        res, degraded = self._execute(q, radii, lf)
        now = self._clock()
        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        counts = np.asarray(res.count)
        over = np.asarray(res.overflow)
        ess = np.asarray(res.es_stopped)
        epoch = self._epoch()
        dkw = {}
        if degraded is not None:  # annotate shard health on every response
            dkw = dict(shards_ok=degraded.shards_ok,
                       shards_total=degraded.shards_total,
                       complete=degraded.complete,
                       coverage=degraded.coverage,
                       code=degraded.code)
            if hasattr(degraded, "replica_ok"):  # replicated fan-out
                dkw.update(replicas_ok=degraded.replicas_ok,
                           replicas_total=degraded.replicas_total)
        for i, rq in enumerate(reqs):
            row = ids[i]
            valid = row != INVALID_ID
            if rq.op == "count":  # certified count only, no payload
                r_ids = np.zeros(0, row.dtype)
                r_dists = np.zeros(0, np.float32)
            else:
                r_ids, r_dists = row[valid], dists[i][valid]
            out.append(self._record(Response(
                req_id=rq.req_id,
                op=rq.op,
                ids=r_ids,
                dists=r_dists,
                count=int(counts[i]),
                overflow=bool(over[i]),
                es_stopped=bool(ess[i]),
                latency_s=now - arrive[i],
                radius=float(radii[i]),
                epoch=epoch,
                timings=self._timings(arrive[i], svc0, now),
                filtered=rq.filter_labels is not None,
                **dkw,
            )))
        self.stats["served"] += n
        self.stats["count_requests"] += sum(rq.op == "count" for rq in reqs)
        self.stats["batches"] += 1
        self.stats["filtered_batches"] += int(lf is not None)
        self.stats["filtered_requests"] += n_filtered
        self.stats["es_stopped"] += int(ess[:n].sum())
        self.stats["overflow"] += int(over[:n].sum())
        self.stats["reranked"] += int(np.asarray(res.n_rerank)[:n].sum())
        self._track_radii(radii[:n])
        return out

    # -- continuous execution ------------------------------------------------
    def _step_continuous(self) -> list[Response]:
        """One continuous-batching step: drain, (mutations), effort-split
        phase-1 dispatches, pool tick, retirements. Point queries answered
        at phase 1 return from the step they were drained in; saturated
        lanes ride the pool across steps."""
        out = []
        batch = self._drain()
        svc0 = self._clock()
        if self.live is not None:
            muts = [b for b in batch if b[0].op in ("insert", "delete")]
            batch = [b for b in batch if b[0].op in ("range", "count")]
            if muts:
                # in-flight checkpoints must not cross an epoch: finish them
                # against the snapshot they were admitted under, THEN mutate
                out.extend(self._finish_pool())
                out.extend(self._apply_mutations(muts, svc0))
                if (self.scfg.auto_consolidate
                        and self.live.maybe_consolidate()):
                    self.stats["consolidations"] += 1
                self._view = self.live.snapshot()
                self._pool.rebind(self._device_corpus(), self._graph())
            self.stats["epoch"] = self._view.epoch
        batch, shed = self._shed_expired(batch, svc0)
        out.extend(shed)
        if batch:
            reqs = [b[0] for b in batch]
            arrive = [b[1] for b in batch]
            q = np.stack([rq.query for rq in reqs])
            radii = np.asarray(
                [self.scfg.default_radius if rq.radius is None else rq.radius
                 for rq in reqs], np.float32)
            heavy = np.zeros(len(reqs), bool)
            if self.effort is not None and len(reqs) > 1:
                pred = self.effort.predict(q, radii)
                heavy = pred >= self.scfg.effort_threshold
            self.stats["bucket_cheap"] += int((~heavy).sum())
            self.stats["bucket_heavy"] += int(heavy.sum())
            # cheap bucket first: point queries keep their relative order
            # and never queue behind the heavy dispatch
            for sel in (np.nonzero(~heavy)[0], np.nonzero(heavy)[0]):
                if len(sel):
                    out.extend(self._dispatch_phase1(
                        [reqs[i] for i in sel], [arrive[i] for i in sel],
                        q[sel], radii[sel], svc0))
            self._track_radii(radii)
            self.stats["batches"] += 1
            nf = sum(rq.filter_labels is not None for rq in reqs)
            self.stats["filtered_batches"] += int(nf > 0)
            self.stats["filtered_requests"] += nf
        # deadline check BEFORE the tick: a lane past its budget is
        # finalized from its current GreedyState checkpoint instead of
        # resumed — a certified partial (truncated, never corrupted) that
        # frees the slot so the pool can never stall on one slow lane
        expired = self._pool.expired(self._clock())
        if len(expired):
            out.extend(self._respond_greedy(*self._pool.retire(expired),
                                            expired=True))
        before = self._pool.occupancy
        finished = self._pool.tick()
        self.stats["pool_ticks"] = self._pool.ticks
        if before > len(finished):
            # at least one lane survived the tick while the server kept
            # serving around it — the scheduler rotated past a straggler
            self.stats["pool_rotations"] += 1
        if len(finished):
            out.extend(self._respond_greedy(*self._pool.retire(finished)))
        return out

    def _dispatch_phase1(self, reqs, arrive, q, radii, svc0) -> list[Response]:
        """Run one pow2-padded phase-1 batch; answer unsaturated lanes now,
        seed saturated ones into the pool (overflow runs one-shot)."""
        n = len(reqs)
        bucket = next_pow2(n)
        if bucket > n:
            q = np.concatenate([q, np.repeat(q[:1], bucket - n, axis=0)])
            radii = np.concatenate([radii, np.repeat(radii[:1], bucket - n)])
        qj = jnp.asarray(q)
        rj = jnp.asarray(radii)
        es = (self.scfg.es_radius_factor * rj
              if self.scfg.es_radius_factor > 0 else None)
        st, res, need = range_phase1(self._device_corpus(), self._graph(), qj,
                                     self._start_ids(), rj, self.cfg,
                                     es_radius=es)
        need_h = np.array(need)
        need_h[n:] = False
        out = []
        # phase 1 walks unfiltered (predicates are result-stage only); the
        # batch predicate applies at both finalize sites — here for direct
        # lanes, and at retirement (_respond_greedy) for pooled lanes
        lf = self._batch_filter(reqs, bucket)
        direct = np.nonzero(~need_h[:n])[0]
        if len(direct):
            fin = self._finalize(qj, rj, res, lf)
            out.extend(self._emit_range(fin, direct, reqs, arrive, radii,
                                        svc0, phase2=False))
        lanes = np.nonzero(need_h)[0]
        if len(lanes):
            seeded = greedy_seed_batch(self._device_corpus(), st, rj,
                                       self.cfg.result_cap, self.cfg.search)
            nv1 = np.asarray(st.n_visited)
            nd1 = np.asarray(st.n_dist)
            es1 = np.asarray(st.es_stopped)
            metas = [dict(req=reqs[i], arrive=arrive[i], svc0=svc0,
                          radius=float(radii[i]),
                          deadline_at=self._deadline_at(reqs[i], arrive[i]),
                          n_visited=int(nv1[i]), n_dist=int(nd1[i]),
                          es=bool(es1[i]))
                     for i in lanes]
            fit = min(len(lanes), len(self._pool.free_slots()))
            if fit:
                self._pool.admit(seeded, lanes[:fit], qj, rj, metas[:fit])
                self.stats["pool_admitted"] += fit
            if fit < len(lanes):
                # pool full: run the overflow lanes to completion in one
                # slice (identical results — the slice width is a latency
                # knob, not a semantic one)
                out.extend(self._oneshot(seeded, lanes[fit:], qj, rj,
                                         metas[fit:]))
        return out

    def _oneshot(self, seeded, sel, qj, rj, metas) -> list[Response]:
        k = len(sel)
        P = next_pow2(k)
        sel_p = np.concatenate([sel, np.repeat(sel[:1], P - k)])
        g, qs, rs = _gather_lanes((seeded, qj, rj), jnp.asarray(sel_p))
        g = greedy_resume_batch(
            self._device_corpus(), self._graph(), qs, rs, g, jnp.ones(P, bool),
            self.cfg.result_cap, self.cfg.frontier_rounds,
            self.cfg.frontier_rounds, self.cfg.search)
        _, over = greedy_lane_done(g, self.cfg.frontier_rounds)
        self.stats["pool_oneshot"] += k
        return self._respond_greedy(g, qs, rs, over, metas)

    def _respond_greedy(self, g, qs, rs, over, metas, *,
                        expired: bool = False) -> list[Response]:
        """Finalize retired greedy lanes (pool or one-shot) into Responses.
        Device arrays are pow2-padded past ``len(metas)``; pad lanes are
        finalized (fixed shapes) but never answered.

        ``expired=True`` marks deadline force-retirements: the lanes'
        checkpoints are finalized as-is (the greedy loop only ever appends
        in-range nodes, and ``finalize_results`` still tombstone-filters
        and exact-reranks), so the partial answer is certified — every
        returned id verifiably within radius — just possibly short.
        ``coverage`` is the visited-frontier fraction from the checkpoint."""
        k = len(metas)
        P = int(np.asarray(g.res_count).shape[0])
        nv = np.zeros(P, np.int32)
        nd = np.zeros(P, np.int32)
        esf = np.zeros(P, bool)
        for i, m in enumerate(metas):
            nv[i], nd[i], esf[i] = m["n_visited"], m["n_dist"], m["es"]
        res = RangeResult(
            ids=g.res_ids, dists=g.res_dists, count=g.res_count,
            overflow=jnp.asarray(over),
            n_visited=jnp.asarray(nv),
            n_dist=jnp.asarray(nd) + g.n_dist,
            es_stopped=jnp.asarray(esf),
            phase2=jnp.ones(P, bool),
            n_rerank=jnp.zeros(P, jnp.int32))
        extras = None
        if expired:
            cov = greedy_coverage(g)
            extras = [dict(complete=False, coverage=float(cov[i]),
                           code=DEADLINE_EXPIRED) for i in range(k)]
            self.stats["deadline_partial"] += k
        lf = None
        if any(m["req"].filter_labels is not None for m in metas):
            # rebuild the retired lanes' predicates (pad lanes all-pass)
            entries = ([m["req"].filter_labels for m in metas]
                       + [None] * (P - k))
            modes = ([m["req"].filter_mode for m in metas]
                     + ["and"] * (P - k))
            lf = make_label_filter(entries, self._num_labels(), modes=modes)
        res = self._finalize(qs, rs, res, lf)
        self.stats["pool_retired"] += k
        reqs = [m["req"] for m in metas]
        arrive = [m["arrive"] for m in metas]
        radii = np.asarray([m["radius"] for m in metas], np.float32)
        return self._emit_range(res, np.arange(k), reqs, arrive, radii,
                                metas[0]["svc0"] if k else 0.0, phase2=True,
                                svc0s=[m["svc0"] for m in metas],
                                extras=extras)

    def _emit_range(self, res: RangeResult, rows, reqs, arrive, radii,
                    svc0, *, phase2: bool, svc0s=None,
                    extras=None) -> list[Response]:
        """Turn result rows into recorded Responses. ``rows`` indexes the
        (padded) result arrays; ``reqs``/``arrive``/``radii`` are indexed
        the same way for phase-1 emission and positionally (row i ->
        meta i) for greedy retirement. ``extras`` (positional, one dict
        per emitted row) merges degradation fields (complete/coverage/
        code) into the Response."""
        now = self._clock()
        ids = self._externalize(np.asarray(res.ids))
        dists = np.asarray(res.dists)
        counts = np.asarray(res.count)
        over = np.asarray(res.overflow)
        ess = np.asarray(res.es_stopped)
        epoch = self._epoch()
        out = []
        for j, i in enumerate(rows):
            row = ids[i]
            valid = row != INVALID_ID
            a = arrive[i] if svc0s is None else arrive[j]
            s0 = svc0 if svc0s is None else svc0s[j]
            rq = reqs[i] if svc0s is None else reqs[j]
            rad = radii[i] if svc0s is None else radii[j]
            if rq.op == "count":  # certified count only, no payload
                r_ids = np.zeros(0, row.dtype)
                r_dists = np.zeros(0, np.float32)
                self.stats["count_requests"] += 1
            else:
                r_ids, r_dists = row[valid], dists[i][valid]
            out.append(self._record(Response(
                req_id=rq.req_id,
                op=rq.op,
                ids=r_ids,
                dists=r_dists,
                count=int(counts[i]),
                overflow=bool(over[i]),
                es_stopped=bool(ess[i]),
                latency_s=now - a,
                radius=float(rad),
                epoch=epoch,
                timings=self._timings(a, s0, now),
                filtered=rq.filter_labels is not None,
                **(extras[j] if extras is not None else {}),
            )))
            self.stats["es_stopped"] += int(ess[i])
            self.stats["overflow"] += int(over[i])
            self.stats["reranked"] += int(np.asarray(res.n_rerank)[i])
        self.stats["served"] += len(out)
        return out

    def _finish_pool(self) -> list[Response]:
        """Tick the pool to empty (epoch barrier / final drain). Deadlines
        stay live during the barrier: expired lanes finalize as certified
        partials between ticks, same as in the steady state."""
        out = []
        while self._pool.occupancy:
            expired = self._pool.expired(self._clock())
            if len(expired):
                out.extend(self._respond_greedy(*self._pool.retire(expired),
                                                expired=True))
                continue
            finished = self._pool.tick()
            self.stats["pool_ticks"] = self._pool.ticks
            if len(finished):
                out.extend(self._respond_greedy(*self._pool.retire(finished)))
        return out

    # -- monitoring / drain --------------------------------------------------
    def radius_dispersion(self) -> dict:
        """Mean/std/min/max of served radii + mixed-batch count (monitoring)."""
        n = max(self.stats["served"], 1)
        mean = self.stats["radius_sum"] / n
        var = max(self.stats["radius_sumsq"] / n - mean * mean, 0.0)
        return dict(mean=mean, std=var ** 0.5,
                    min=self.stats["radius_min"], max=self.stats["radius_max"],
                    mixed_radius_batches=self.stats["mixed_radius_batches"])

    def run_until_drained(self) -> list[Response]:
        out = []
        while self.queue or self.in_flight():
            out.extend(self.step())
        return out
