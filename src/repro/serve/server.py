"""RangeServer: the serving layer around the range engine.

Production anatomy (single-process simulation of the real service):

* **admission queue** — requests land with an id + deadline; the batcher
  drains up to ``max_batch`` or until ``max_wait_s`` passes (micro-batching:
  the standard accelerator-serving latency/throughput knob). Radii are
  per-request: a micro-batch freely mixes radii, each lane answered at its
  own (the paper's queries are radius-heterogeneous by nature). Admission is
  **bounded**: beyond ``max_queue`` pending requests, ``submit`` rejects
  (and counts) instead of growing the deque without limit — queue growth
  under overload is a latency bomb, load shedding is the production answer.
* **bucketed dispatch** — batches are padded to power-of-two sizes so jit
  compiles O(log B) programs total.
* **two-phase compaction execution** — phase 1 (uniform beam search) over
  the batch; zero-result queries exit; the compacted survivors run the
  greedy/doubling phase (core.range_search_compacted).
* **multi-shard** — given a mesh + ShardedCorpus, dispatch goes through
  dist.sharded_range_search and merges per-shard unions.
* **live mutation** — given a ``repro.live.LiveIndex``, requests may carry
  ``op="insert"`` / ``op="delete"`` alongside queries in the same admission
  queue. The batcher applies a micro-batch's mutations first (coalesced in
  arrival order), triggers threshold consolidation, then refreshes its
  **epoch snapshot** and answers the batch's queries against that one
  consistent ``(graph, corpus, tombstones, epoch)`` view — queries never
  observe a half-applied mutation batch. Returned ids are external ids.
* per-request stats (visited, distance comps, early-stopped) surface in the
  response for monitoring.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core.corpus import corpus_dtype_name
from ..core.engine import RangeSearchEngine
from ..core.range_search import RangeConfig, range_search_compacted
from ..dist.sharded_engine import ShardedCorpus, sharded_range_search
from ..utils import INVALID_ID, next_pow2


@dataclasses.dataclass
class Request:
    req_id: int
    query: Optional[np.ndarray] = None  # query/insert: the vector
    radius: Optional[float] = None      # per-request; batches mix radii freely
    deadline: float = float("inf")
    op: str = "query"                   # query | insert | delete
    delete_ids: Optional[np.ndarray] = None  # delete: external ids to remove


@dataclasses.dataclass
class Response:
    req_id: int
    ids: np.ndarray
    dists: np.ndarray
    count: int
    overflow: bool
    es_stopped: bool
    latency_s: float
    radius: float = float("nan")  # the radius this request was answered at
    op: str = "query"
    epoch: int = 0                # index epoch the request was served/applied at


@dataclasses.dataclass
class ServerConfig:
    max_batch: int = 256
    max_wait_s: float = 0.005
    default_radius: float = 1.0
    es_radius_factor: float = 0.0   # >0 enables early stopping at factor*r
    expand_width: int = 0           # >0 overrides SearchConfig.expand_width
                                    # (ops knob: retune the frontier width
                                    # without rebuilding the engine config)
    max_queue: int = 8192           # admission bound; 0 disables admission
                                    # entirely (drain-only maintenance mode)
    auto_consolidate: bool = True   # live engines: threshold consolidation
                                    # between micro-batches


class RangeServer:
    def __init__(
        self,
        engine: Optional[RangeSearchEngine],
        cfg: RangeConfig,
        server_cfg: ServerConfig = ServerConfig(),
        *,
        mesh=None,
        sharded: Optional[ShardedCorpus] = None,
        live=None,
    ):
        """``live`` is a ``repro.live.LiveIndex``; it supersedes ``engine``
        (pass ``engine=None``) and enables insert/delete requests."""
        if engine is None and live is None:
            raise ValueError("need an engine or a live index")
        self.engine = engine
        self.live = live
        if server_cfg.expand_width > 0:
            cfg = dataclasses.replace(cfg, search=dataclasses.replace(
                cfg.search, expand_width=server_cfg.expand_width))
        # the declarative SearchConfig.corpus_dtype is a deploy contract:
        # what the config promises must be what the served corpus actually
        # stores (an f32 corpus behind an "int8" config would silently
        # serve at 4x the planned HBM budget, and vice versa would skip
        # the planned rerank stage)
        if live is not None:
            served = live.points
        elif sharded is not None:
            served = sharded.points
        else:
            served = engine.points
        actual = corpus_dtype_name(served)
        if cfg.search.corpus_dtype != actual:
            raise ValueError(
                f"SearchConfig.corpus_dtype={cfg.search.corpus_dtype!r} but "
                f"the served corpus stores {actual!r}")
        self.cfg = cfg
        self.scfg = server_cfg
        self.mesh = mesh
        self.sharded = sharded
        self.queue: deque[tuple[Request, float]] = deque()
        self._view = live.snapshot() if live is not None else None
        self.stats = {
            "served": 0, "batches": 0, "es_stopped": 0, "overflow": 0,
            # bounded admission: requests shed at the queue limit (the
            # overload signal capacity planning alarms on)
            "rejected": 0,
            # live mutation counters; epoch mirrors the served snapshot
            "inserts": 0, "deletes": 0, "consolidations": 0, "epoch": 0,
            # quantized-corpus two-pass: candidates that fell in the radius
            # guard band and were exact-reranked (0 on f32/bf16 corpora);
            # the band hit rate is what capacity planning watches — a wide
            # band means the corpus scales are too coarse for the traffic's
            # radii
            "reranked": 0,
            # radius-dispersion counters: mixed-radius batches are the
            # heterogeneous-traffic regime the per-query radius path exists
            # for; the running moments let dashboards derive mean/std
            "mixed_radius_batches": 0,
            "radius_min": float("inf"), "radius_max": float("-inf"),
            "radius_sum": 0.0, "radius_sumsq": 0.0,
        }

    # -- admission -------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Admit a request; returns False (and counts the shed) when the
        queue is at ``max_queue``. Malformed requests are rejected HERE, at
        the client's call site — one bad request admitted into a micro-batch
        would otherwise take down every other request batched with it."""
        if req.op not in ("query", "insert", "delete"):
            raise ValueError(f"unknown op {req.op!r}")
        if req.op in ("insert", "delete") and self.live is None:
            raise ValueError(f"{req.op!r} requests need a live index")
        if req.op == "delete":
            if req.delete_ids is None:
                raise ValueError("delete requests need delete_ids")
        elif req.query is None:
            raise ValueError(f"{req.op!r} requests need a query vector")
        if len(self.queue) >= self.scfg.max_queue:
            self.stats["rejected"] += 1
            return False
        self.queue.append((req, time.perf_counter()))
        return True

    def pending(self) -> int:
        return len(self.queue)

    # -- batching ------------------------------------------------------------
    def _drain(self) -> list[tuple[Request, float]]:
        out = []
        t0 = time.perf_counter()
        while self.queue and len(out) < self.scfg.max_batch:
            out.append(self.queue.popleft())
            if not self.queue and (time.perf_counter() - t0) < self.scfg.max_wait_s:
                time.sleep(0)  # yield; more requests may land in a real server
                break
        return out

    # -- mutation ------------------------------------------------------------
    def _apply_mutations(self, muts: list[tuple[Request, float]]) -> list[Response]:
        """Apply a micro-batch's mutations: ONE coalesced insert batch, then
        ONE coalesced delete batch.

        Reordering within the micro-batch is sound because external ids are
        never reused: insert-then-delete of the same id inside one batch
        lands in the same final state either way, and a delete can never
        precede "its" insert across the reorder (the id did not exist when
        the delete was submitted). Coalescing is what makes churn traffic
        cheap — each batch pays one fixed-shape insert step and one bitset
        update instead of one dispatch per request."""
        out = []
        ins = [(rq, t) for rq, t in muts if rq.op == "insert"]
        dels = [(rq, t) for rq, t in muts if rq.op == "delete"]
        if ins:
            ext = self.live.insert(np.stack([rq.query for rq, _ in ins]))
            self.stats["inserts"] += len(ins)
            now = time.perf_counter()
            for (rq, arrive), e in zip(ins, ext):
                ids = np.asarray([e], np.int64)
                out.append(Response(
                    req_id=rq.req_id, ids=ids,
                    dists=np.zeros(1, np.float32), count=1,
                    overflow=False, es_stopped=False,
                    latency_s=now - arrive, op="insert",
                    epoch=self.live.epoch))
        if dels:
            per_req = [np.atleast_1d(np.asarray(rq.delete_ids, np.int64))
                       for rq, _ in dels]
            self.stats["deletes"] += self.live.delete(np.concatenate(per_req))
            now = time.perf_counter()
            for (rq, arrive), ids in zip(dels, per_req):
                out.append(Response(
                    req_id=rq.req_id, ids=ids,
                    dists=np.zeros(len(ids), np.float32), count=len(ids),
                    overflow=False, es_stopped=False,
                    latency_s=now - arrive, op="delete",
                    epoch=self.live.epoch))
        return out

    # -- execution -----------------------------------------------------------
    def _execute(self, queries: np.ndarray, radii: np.ndarray):
        es = (self.scfg.es_radius_factor * jnp.asarray(radii)
              if self.scfg.es_radius_factor > 0 else None)
        qs = jnp.asarray(queries)
        rs = jnp.asarray(radii)
        if self.live is not None:
            return self._view.range(qs, rs, self.cfg, es)
        if self.sharded is not None and self.mesh is not None:
            return sharded_range_search(self.mesh, self.sharded, qs, rs, self.cfg, es)
        return range_search_compacted(self.engine.points, self.engine.graph, qs,
                                      self.engine.start_ids, rs, self.cfg, es)

    def step(self) -> list[Response]:
        """Serve one micro-batch from the queue.

        Mutations in the batch apply first (in arrival order); the epoch
        snapshot then advances ONCE and every query in the batch is answered
        against that view — a consistent ``(graph, corpus, tombstones,
        epoch)`` even as later batches keep mutating. Requests batch
        regardless of radius: the radius vector rides alongside the query
        matrix (padded identically), and every layer below answers each lane
        at its own radius.
        """
        batch = self._drain()
        if not batch:
            return []
        out = []
        if self.live is not None:
            muts = [b for b in batch if b[0].op != "query"]
            batch = [b for b in batch if b[0].op == "query"]
            if muts:
                out.extend(self._apply_mutations(muts))
                if (self.scfg.auto_consolidate
                        and self.live.maybe_consolidate()):
                    self.stats["consolidations"] += 1
                self._view = self.live.snapshot()
            self.stats["epoch"] = self._view.epoch
            self.stats["batches"] += 1 if (muts and not batch) else 0
        if not batch:
            return out
        reqs = [b[0] for b in batch]
        arrive = [b[1] for b in batch]
        n = len(reqs)
        bucket = next_pow2(n)
        q = np.stack([rq.query for rq in reqs])
        radii = np.asarray(
            [self.scfg.default_radius if rq.radius is None else rq.radius
             for rq in reqs], np.float32)
        if bucket > n:  # pad to bucket with repeats (masked out of responses)
            q = np.concatenate([q, np.repeat(q[:1], bucket - n, axis=0)])
            radii = np.concatenate([radii, np.repeat(radii[:1], bucket - n)])
        res = self._execute(q, radii)
        now = time.perf_counter()
        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        counts = np.asarray(res.count)
        over = np.asarray(res.overflow)
        ess = np.asarray(res.es_stopped)
        epoch = self._view.epoch if self._view is not None else 0
        for i, rq in enumerate(reqs):
            row = ids[i]
            valid = row != INVALID_ID
            out.append(Response(
                req_id=rq.req_id,
                ids=row[valid],
                dists=dists[i][valid],
                count=int(counts[i]),
                overflow=bool(over[i]),
                es_stopped=bool(ess[i]),
                latency_s=now - arrive[i],
                radius=float(radii[i]),
                epoch=epoch,
            ))
        self.stats["served"] += n
        self.stats["batches"] += 1
        self.stats["es_stopped"] += int(ess[:n].sum())
        self.stats["overflow"] += int(over[:n].sum())
        self.stats["reranked"] += int(np.asarray(res.n_rerank)[:n].sum())
        rb = radii[:n].astype(np.float64)
        self.stats["mixed_radius_batches"] += int(rb.min() != rb.max())
        self.stats["radius_min"] = min(self.stats["radius_min"], float(rb.min()))
        self.stats["radius_max"] = max(self.stats["radius_max"], float(rb.max()))
        self.stats["radius_sum"] += float(rb.sum())
        self.stats["radius_sumsq"] += float((rb * rb).sum())
        return out

    def radius_dispersion(self) -> dict:
        """Mean/std/min/max of served radii + mixed-batch count (monitoring)."""
        n = max(self.stats["served"], 1)
        mean = self.stats["radius_sum"] / n
        var = max(self.stats["radius_sumsq"] / n - mean * mean, 0.0)
        return dict(mean=mean, std=var ** 0.5,
                    min=self.stats["radius_min"], max=self.stats["radius_max"],
                    mixed_radius_batches=self.stats["mixed_radius_batches"])

    def run_until_drained(self) -> list[Response]:
        out = []
        while self.queue:
            out.extend(self.step())
        return out
