"""LaneScheduler: a persistent pool of resumable greedy lanes.

The lockstep path (``range_search_compacted``) answers a micro-batch by
running every saturated lane's greedy phase to completion inside one device
program — the whole batch waits for its slowest member, and a point query
unlucky enough to share a batch with a dense-region query inherits that
query's tail. Continuous batching breaks the lockstep: phase-2 work lives
in a fixed-width pool of ``GreedyState`` checkpoints, advanced
``slice_rounds`` expansions per tick. Finished lanes retire and free their
slot; newly admitted queries scatter into free slots *between* ticks, so a
straggler lane never blocks anyone — it just keeps its one slot while
traffic flows around it.

Shape discipline: the pool width ``L`` is fixed (pow2), so the resume step
compiles exactly once; admission scatters and retirement gathers pad their
index vectors to pow2 lengths (out-of-range indices drop), so each is a
O(log L) family of compiled programs. ``greedy_resume_batch``'s checkpoint
semantics guarantee sliced execution returns bit-identical results to the
one-shot path — the scheduler changes *when* work happens, never *what* is
computed.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.corpus import corpus_dim
from ..core.range_search import (
    RangeConfig, greedy_lane_done, greedy_resume_batch,
)
from ..utils import next_pow2


@jax.jit
def _scatter_lanes(pool, new, slots):
    """Place ``new`` lane rows at ``slots`` across the pool pytree; padded
    slots point past the pool and drop."""
    return jax.tree.map(lambda p, n: p.at[slots].set(n, mode="drop"),
                        pool, new)


@jax.jit
def _gather_lanes(pool, idx):
    return jax.tree.map(lambda p: p[idx], pool)


class LaneScheduler:
    """Fixed-width pool of checkpointed greedy lanes over one corpus view.

    Device state is three parallel buffers — the batched ``GreedyState``,
    the (L, d) query matrix, and the (L,) radius vector; host state is the
    occupancy mask plus one opaque metadata slot per lane (the server parks
    request identity and phase-1 stats there). ``rebind`` swaps the corpus
    view (live-index epoch advance) and is only legal on an empty pool —
    consolidation permutes slots, so an in-flight checkpoint must never
    cross an epoch.
    """

    def __init__(self, corpus, graph, cfg: RangeConfig, n_lanes: int,
                 slice_rounds: int):
        if cfg.mode != "greedy":
            raise ValueError("the lane pool schedules greedy phase-2 work; "
                             f"cfg.mode={cfg.mode!r}")
        self.corpus = corpus
        self.graph = graph
        self.cfg = cfg
        self.n_lanes = next_pow2(max(int(n_lanes), 1))
        self.slice_rounds = max(int(slice_rounds), 1)
        L = self.n_lanes
        self.queries = jnp.zeros((L, corpus_dim(corpus)), jnp.float32)
        self.radii = jnp.zeros((L,), jnp.float32)
        self.gs = None                      # lazily shaped from first admit
        self.active = np.zeros(L, bool)
        self.meta: list = [None] * L
        self.ticks = 0

    # -- occupancy -----------------------------------------------------------
    @property
    def occupancy(self) -> int:
        return int(self.active.sum())

    def free_slots(self) -> np.ndarray:
        return np.nonzero(~self.active)[0]

    # -- epoch advance -------------------------------------------------------
    def rebind(self, corpus, graph) -> None:
        if self.occupancy:
            raise RuntimeError("rebind with in-flight lanes: drain the pool "
                               "before advancing the corpus epoch")
        self.corpus = corpus
        self.graph = graph

    # -- admission -----------------------------------------------------------
    def admit(self, seeded, sel, queries, radii, metas) -> np.ndarray:
        """Scatter lanes ``sel`` of a seeded batch into free slots.

        ``seeded`` is the batched ``GreedyState`` from ``greedy_seed_batch``
        over a phase-1 dispatch; ``sel`` indexes the lanes that need phase 2.
        Returns the assigned slot ids (callers must check ``free_slots``
        first — admission never evicts)."""
        k = len(sel)
        slots = self.free_slots()[:k]
        if len(slots) < k:
            raise RuntimeError(f"admit of {k} lanes into {len(slots)} free slots")
        P = next_pow2(max(k, 1))
        sel_p = np.concatenate([sel, np.zeros(P - k, np.int64)])
        slots_p = np.full(P, self.n_lanes, np.int32)  # pad -> dropped
        slots_p[:k] = slots
        new = _gather_lanes((seeded, jnp.asarray(queries), jnp.asarray(radii)),
                            jnp.asarray(sel_p))
        if self.gs is None:
            L = self.n_lanes
            self.gs = jax.tree.map(
                lambda x: jnp.zeros((L,) + x.shape[1:], x.dtype), new[0])
        self.gs, self.queries, self.radii = _scatter_lanes(
            (self.gs, self.queries, self.radii), new, jnp.asarray(slots_p))
        self.active[slots] = True
        for s, m in zip(slots, metas):
            self.meta[s] = m
        return slots

    # -- deadlines -----------------------------------------------------------
    def expired(self, now: float) -> np.ndarray:
        """Active slots whose lane deadline (``meta["deadline_at"]``, absolute
        seconds on the server's clock; +inf when absent) has strictly
        passed. The server retires these BEFORE the next tick, finalizing
        each lane's checkpoint into a certified partial response instead of
        resuming it — the mechanism that keeps one over-budget lane from
        holding its pool slot forever."""
        out = [int(s) for s in np.nonzero(self.active)[0]
               if now > (self.meta[s] or {}).get("deadline_at", float("inf"))]
        return np.asarray(out, np.int64)

    # -- execution -----------------------------------------------------------
    def tick(self) -> np.ndarray:
        """Advance every active lane ``slice_rounds`` expansions; returns
        the slots whose lanes finished (frontier empty or budget spent)."""
        if not self.occupancy:
            return np.zeros(0, np.int64)
        self.gs = greedy_resume_batch(
            self.corpus, self.graph, self.queries, self.radii, self.gs,
            jnp.asarray(self.active), self.cfg.result_cap,
            self.cfg.frontier_rounds, self.slice_rounds, self.cfg.search)
        self.ticks += 1
        done, _ = greedy_lane_done(self.gs, self.cfg.frontier_rounds)
        return np.nonzero(self.active & done)[0]

    def retire(self, slots) -> tuple:
        """Pull finished lanes out of the pool and free their slots.

        Returns ``(gs, queries, radii, overflow, metas)`` where the device
        arrays are pow2-padded to ``>= len(slots)`` lanes (pad lanes repeat
        lane 0; callers slice responses to ``len(slots)``) and ``overflow``
        carries the one-shot path's end-of-budget bit."""
        slots = np.asarray(slots, np.int64)
        k = len(slots)
        P = next_pow2(max(k, 1))
        idx = np.full(P, slots[0] if k else 0, np.int64)
        idx[:k] = slots
        g, qs, rs = _gather_lanes((self.gs, self.queries, self.radii),
                                  jnp.asarray(idx))
        _, over = greedy_lane_done(g, self.cfg.frontier_rounds)
        metas = [self.meta[s] for s in slots]
        self.active[slots] = False
        for s in slots:
            self.meta[s] = None
        return g, qs, rs, over, metas
