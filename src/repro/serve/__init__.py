from .latency import LatencyHistogram
from .scheduler import LaneScheduler
from .server import REQUEST_OPS, RangeServer, Request, Response, ServerConfig

__all__ = ["LaneScheduler", "LatencyHistogram", "RangeServer", "Request",
           "Response", "ServerConfig", "REQUEST_OPS"]
