from .server import RangeServer, Request, Response, ServerConfig

__all__ = ["RangeServer", "Request", "Response", "ServerConfig"]
