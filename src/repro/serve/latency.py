"""Log-bucketed latency histograms for the serving layer.

Serving dashboards care about tail quantiles (p95/p99), and tails are
exactly what a running mean destroys. The standard production answer is a
fixed-bucket histogram: O(1) record, O(buckets) quantile, mergeable across
workers, and bounded memory no matter how many requests pass through.
Buckets are geometric (equal width in log-latency) so relative error is
uniform from 10us to 10s — the same shape Prometheus/HdrHistogram deploys
use. Exact percentiles over a retained sample window belong in benchmarks
(see ``benchmarks.run``); the server keeps only the histogram.
"""
from __future__ import annotations

import numpy as np


class LatencyHistogram:
    """Geometric-bucket latency histogram over ``[lo_s, hi_s]`` seconds.

    ``record`` is O(1) per sample; quantiles interpolate inside the owning
    bucket, so their relative error is bounded by the bucket ratio
    (~12% at the default 20 buckets/decade). Min/max/sum are tracked
    exactly alongside.
    """

    def __init__(self, lo_s: float = 1e-5, hi_s: float = 10.0,
                 buckets_per_decade: int = 20):
        if not (0 < lo_s < hi_s):
            raise ValueError("need 0 < lo_s < hi_s")
        decades = np.log10(hi_s / lo_s)
        n = int(np.ceil(decades * buckets_per_decade))
        # edges[i] .. edges[i+1] bounds bucket i; +2 catchall buckets for
        # samples below lo_s / above hi_s so nothing is ever dropped
        self.edges = lo_s * (hi_s / lo_s) ** (np.arange(n + 1) / n)
        self.counts = np.zeros(n + 2, np.int64)
        self.n = 0
        self.sum_s = 0.0
        self.min_s = float("inf")
        self.max_s = 0.0

    def record(self, seconds) -> None:
        s = np.atleast_1d(np.asarray(seconds, np.float64))
        if s.size == 0:
            return
        idx = np.searchsorted(self.edges, s, side="right")  # 0 => below lo
        np.add.at(self.counts, idx, 1)
        self.n += int(s.size)
        self.sum_s += float(s.sum())
        self.min_s = min(self.min_s, float(s.min()))
        self.max_s = max(self.max_s, float(s.max()))

    def percentile(self, p: float) -> float:
        """Approximate p-th percentile in seconds (nan when empty)."""
        if self.n == 0:
            return float("nan")
        rank = (p / 100.0) * self.n
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, max(rank, 1), side="left"))
        if b == 0:  # below-range catchall: bounded above by lo_s
            return float(min(self.edges[0], self.max_s))
        if b >= len(self.counts) - 1:  # above-range catchall
            return float(self.max_s)
        # linear interpolation inside bucket b (edges[b-1] .. edges[b])
        lo, hi = self.edges[b - 1], self.edges[b]
        prev = cum[b - 1]
        frac = (rank - prev) / max(self.counts[b], 1)
        val = lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return float(min(max(val, self.min_s), self.max_s))

    def summary(self) -> dict:
        """The dashboard row: count/mean and the tail quantiles, in ms."""
        if self.n == 0:
            return dict(count=0, mean_ms=float("nan"), p50_ms=float("nan"),
                        p95_ms=float("nan"), p99_ms=float("nan"),
                        max_ms=float("nan"))
        return dict(
            count=self.n,
            mean_ms=1e3 * self.sum_s / self.n,
            p50_ms=1e3 * self.percentile(50),
            p95_ms=1e3 * self.percentile(95),
            p99_ms=1e3 * self.percentile(99),
            max_ms=1e3 * self.max_s,
        )
