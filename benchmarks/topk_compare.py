"""Paper Sec. 5 closing note: range search vs top-10 search on the same
index — range benchmarking is 'an easier problem' (higher QPS at matched
accuracy)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    RangeConfig, SearchConfig, exact_topk, recall_at_k,
)
from repro.utils import block_until_ready
from .common import QUICK_PROFILES, ap_of, get_dataset, get_engine, print_table


def run(n: int = 10_000):
    rows = []
    for prof_name in QUICK_PROFILES[:2]:
        ds, pts, qs, r, _, gt = get_dataset(prof_name, n)
        eng = get_engine(prof_name, n)
        # top-10 QPS at its achieved recall
        gt10, _ = exact_topk(pts, qs, k=10, metric=ds.metric)
        cfg10 = SearchConfig(beam=40, max_beam=40, visit_cap=160,
                             metric=ds.metric)
        fn = lambda: eng.topk(qs, k=10, cfg=cfg10)
        block_until_ready(fn())
        t0 = time.perf_counter(); ids, _ = fn(); block_until_ready(ids)
        qps_topk = qs.shape[0] / (time.perf_counter() - t0)
        rec = recall_at_k(np.asarray(gt10), np.asarray(ids), 10)
        # range QPS at comparable precision
        rcfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32,
                                               visit_cap=128,
                                               metric=ds.metric),
                           mode="greedy", result_cap=2048)
        from .common import run_range
        qps_range, res = run_range(eng, qs, r, rcfg)
        rows.append([prof_name, qps_topk, rec, qps_range, ap_of(res, gt)])
    print_table("Sec5: top-10 vs range on the same index",
                ["profile", "topk_qps", "recall@10", "range_qps", "range_ap"],
                rows)
    return rows


if __name__ == "__main__":
    run()
