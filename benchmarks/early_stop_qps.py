"""Paper Figs. 9/14/15: QPS vs AP with and without early stopping.

The paper's claim: early stopping helps where the zero-vs-some metric
distributions separate (bigann/deep-like), and is neutral-to-harmful where
they overlap. We report per-profile (qps, ap) pairs for greedy and
doubling, es on/off.
"""
from __future__ import annotations


import numpy as np

from repro.core import ES_D_VISITED, RangeConfig, SearchConfig
from .common import (
    ALL_PROFILES, QUICK_PROFILES, ap_of, get_dataset, get_engine,
    print_table, run_range,
)


def run(n: int = 10_000, quick: bool = True, beam: int = 32):
    rows = []
    profiles = QUICK_PROFILES if quick else ALL_PROFILES
    for prof_name in profiles:
        ds, pts, qs, r, _, gt = get_dataset(prof_name, n)
        eng = get_engine(prof_name, n)
        for mode in ("greedy", "doubling"):
            for es in (False, True):
                scfg = SearchConfig(
                    beam=beam,
                    max_beam=beam * (16 if mode == "doubling" else 1),
                    visit_cap=(16 if mode == "doubling" else 4) * beam,
                    metric=ds.metric,
                    es_metric=ES_D_VISITED if es else 0, es_visit_limit=15)
                cfg = RangeConfig(search=scfg, mode=mode, result_cap=2048)
                qps, res = run_range(eng, qs, r, cfg,
                                     es_radius=1.5 * r if es else None)
                rows.append([prof_name, mode, "es" if es else "no-es", qps,
                             ap_of(res, gt),
                             int(np.asarray(res.es_stopped).sum()),
                             float(np.asarray(res.n_visited).mean())])
    print_table("Fig9/14/15: early stopping on/off",
                ["profile", "mode", "es", "qps", "ap", "n_es_stopped",
                 "mean_visited"], rows)
    return rows


if __name__ == "__main__":
    run()
