"""Paper Fig. 3: radius vs percent-captured curves per dataset profile.

Validates the Sec.-3 claim structure: 'robust' profiles (bigann/deep/
wikipedia/msmarco-like) have flat capture curves near the working radius;
'perturbable' ones (ssnpp/msturing/text2image-like) are steep.
"""
from __future__ import annotations

import numpy as np

from .common import ALL_PROFILES, QUICK_PROFILES, get_dataset, print_table


def run(n: int = 10_000, quick: bool = True):
    rows = []
    profiles = QUICK_PROFILES if quick else ALL_PROFILES
    for prof_name in profiles:
        ds, pts, qs, r, prof, gt = get_dataset(prof_name, n)
        # local log-slope of capture at the selected radius = robustness
        gi = int(np.argmin(np.abs(prof.radii - r)))
        rows.append([prof_name, ds.metric, f"{r:.4g}",
                     float(prof.percent_captured[gi]),
                     float(prof.zero_frac[gi]),
                     float(prof.robustness[gi])])
    print_table("Fig3: radius capture (percent_captured / zero_frac / "
                "robustness slope at selected radius)",
                ["profile", "metric", "radius", "captured", "zero_frac",
                 "slope"], rows)
    return rows


if __name__ == "__main__":
    run()
