"""Shared benchmark plumbing: corpus/index cache, radius pick, timing, CSV."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BuildConfig, RangeConfig, RangeSearchEngine, average_precision, exact_range_search,
)
from repro.core.radius import default_grid, select_radius, sweep
from repro.data.synthetic import make_corpus
from repro.utils import block_until_ready

_CACHE: dict = {}


def get_dataset(profile: str, n: int, n_queries: int = 256, seed: int = 0):
    key = ("ds", profile, n, n_queries, seed)
    if key not in _CACHE:
        ds = make_corpus(profile, n=n, n_queries=n_queries, seed=seed)
        pts = jnp.asarray(ds.points)
        qs = jnp.asarray(ds.queries)
        grid = default_grid(ds.points, ds.queries, ds.metric, num=24)
        prof = sweep(pts, qs, grid, ds.metric)
        r, gi = select_radius(prof, robustness_weight=0.2)
        gt = exact_range_search(pts, qs, r, ds.metric)
        _CACHE[key] = (ds, pts, qs, float(r), prof, gt)
    return _CACHE[key]


def get_engine(profile: str, n: int, seed: int = 0, max_degree: int = 24,
               build_beam: int = 48) -> RangeSearchEngine:
    key = ("eng", profile, n, seed, max_degree, build_beam)
    if key not in _CACHE:
        ds, pts, _, _, _, _ = get_dataset(profile, n, seed=seed)
        t0 = time.perf_counter()
        eng = RangeSearchEngine.build(
            pts, BuildConfig(max_degree=max_degree, beam=build_beam,
                             insert_batch=512, metric=ds.metric),
            metric=ds.metric)
        print(f"    [build {profile} n={n}: {time.perf_counter()-t0:.1f}s]")
        _CACHE[key] = eng
    return _CACHE[key]


def run_range(eng, qs, r, cfg: RangeConfig, es_radius=None, iters: int = 2,
              filter=None):
    """(qps, ap_inputs, result) — median wall time over iters (after warmup)."""
    fn = lambda: eng.range(qs, r, cfg=cfg, es_radius=es_radius, filter=filter)
    block_until_ready(fn())
    times = []
    res = None
    for _ in range(iters):
        t0 = time.perf_counter()
        res = fn()
        block_until_ready(res)
        times.append(time.perf_counter() - t0)
    qps = qs.shape[0] / float(np.median(times))
    return qps, res


def ap_of(res, gt) -> float:
    return average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                             np.asarray(res.ids), np.asarray(res.count))


def print_table(title: str, header: list[str], rows: list[list]):
    print(f"\n### {title}")
    print(",".join(header))
    for r in rows:
        print(",".join(f"{x:.4g}" if isinstance(x, float) else str(x) for x in r))


def make_heavy_tailed(n: int, d: int = 32, n_queries: int = 128,
                      n_clusters: int = 48, sigma: float = 1.8,
                      void_frac: float = 0.8, seed: int = 0):
    """Planted-cluster corpus with lognormal (heavy-tailed) populations.

    Cluster sizes are drawn lognormal(0, sigma): a couple of giant clusters
    hold most of the mass while the median cluster is tiny — the Pareto
    match-count shape of the paper's Fig. 4 (most queries zero results, a
    few enormous outliers), pushed harder than the quantile-matched
    synthetic profiles. ``void_frac`` of the queries land in empty space
    (zero matches at any sub-separation radius); the rest sit on cluster
    centers, so their match count inherits the cluster-size tail directly.
    Returns ``(points, queries)`` as float32 numpy arrays, l2 metric."""
    rng = np.random.default_rng(seed)
    sizes = rng.lognormal(mean=0.0, sigma=sigma, size=n_clusters)
    sizes = np.maximum(1, np.round(sizes / sizes.sum() * n)).astype(np.int64)
    # rounding drift -> exactly n points, absorbed by the largest cluster
    sizes[int(np.argmax(sizes))] += n - int(sizes.sum())
    centers = rng.normal(0.0, 4.0, (n_clusters, d))
    assign = np.repeat(np.arange(n_clusters), sizes)
    points = (centers[assign] +
              rng.normal(0.0, 0.05, (n, d))).astype(np.float32)

    n_void = int(round(void_frac * n_queries))
    q_void = rng.normal(0.0, 4.0, (n_void, d))  # ~surely inter-cluster space
    q_hit = centers[rng.integers(0, n_clusters, n_queries - n_void)]
    queries = np.concatenate([q_void, q_hit]).astype(np.float32)
    return points, queries


QUICK_PROFILES = ["bigann-like", "gist-like", "msmarco-like"]
ALL_PROFILES = ["bigann-like", "deep-like", "msturing-like", "gist-like",
                "ssnpp-like", "openai-like", "text2image-like",
                "wikipedia-like", "msmarco-like"]
