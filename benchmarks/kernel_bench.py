"""Kernel microbench: rangescan / gatherdist / flashattn.

Wall-clock on CPU is meaningless for TPU kernels, so this reports two
things per shape: (a) XLA-path wall time (the ref oracle jit'd — a real
measurement of the fallback used on CPU), and (b) the v5e roofline-term
ESTIMATE for the Pallas kernel (FLOPs / bytes analytically from the tiling,
against 197 TFLOP/s + 819 GB/s), which is what the TPU deployment would be
bounded by.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import HBM_BW, PEAK_FLOPS
from repro.kernels import flash_attention_ref, gatherdist_ref, rangescan_ref
from repro.utils import block_until_ready
from .common import print_table


def _wall(fn, iters=3):
    block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # rangescan: retrieval_cand-ish shapes
    for (q, n, d) in [(16, 100_000, 128), (1, 1_000_000, 256)]:
        qs = jax.random.normal(key, (q, d), jnp.float32)
        xs = jax.random.normal(key, (n, d), jnp.float32)
        f = jax.jit(lambda a, b: rangescan_ref(a, b, jnp.float32(1.0), k=128))
        t = _wall(lambda: f(qs, xs))
        flops = 2.0 * q * n * d
        byts = 4.0 * (q * d + n * d + q * n)
        rows.append(["rangescan", f"{q}x{n}x{d}", t * 1e3,
                     flops / PEAK_FLOPS * 1e6, byts / HBM_BW * 1e6])

    # gatherdist: beam expansion shapes
    for (q, r, n, d) in [(256, 32, 100_000, 128), (1024, 64, 100_000, 96)]:
        pts = jax.random.normal(key, (n, d), jnp.float32)
        qs = jax.random.normal(key, (q, d), jnp.float32)
        ids = jax.random.randint(key, (q, r), 0, n, jnp.int32)
        f = jax.jit(lambda p, i, u: gatherdist_ref(p, i, u))
        t = _wall(lambda: f(pts, ids, qs))
        flops = 3.0 * q * r * d
        byts = 4.0 * (q * r * d + q * d + q * r)
        rows.append(["gatherdist", f"{q}x{r}x{d}", t * 1e3,
                     flops / PEAK_FLOPS * 1e6, byts / HBM_BW * 1e6])

    # flashattn: prefill + decode shapes (small batch; CPU wall time)
    for (b, hq, hkv, sq, skv, dh) in [(1, 8, 2, 1024, 1024, 128),
                                      (4, 8, 2, 1, 8192, 128)]:
        q = jax.random.normal(key, (b, hq, sq, dh), jnp.bfloat16)
        k = jax.random.normal(key, (b, hkv, skv, dh), jnp.bfloat16)
        v = jax.random.normal(key, (b, hkv, skv, dh), jnp.bfloat16)
        f = jax.jit(lambda a, c, e: flash_attention_ref(a, c, e))
        t = _wall(lambda: f(q, k, v))
        flops = 4.0 * b * hq * sq * skv * dh
        byts = 2.0 * (b * hq * sq * dh + 2 * b * hkv * skv * dh)
        rows.append(["flashattn", f"b{b}h{hq}/{hkv}s{sq}/{skv}", t * 1e3,
                     flops / PEAK_FLOPS * 1e6, byts / HBM_BW * 1e6])

    print_table("kernel bench: CPU-XLA wall ms + v5e roofline-term estimate",
                ["kernel", "shape", "cpu_ms", "v5e_compute_us", "v5e_mem_us"],
                rows)
    return rows


if __name__ == "__main__":
    run()
