"""Kernel microbench: rangescan / gatherdist / expand / flashattn.

Wall-clock on CPU is meaningless for TPU kernels, so this reports two
things per shape: (a) XLA-path wall time (the ref oracle jit'd — a real
measurement of the fallback used on CPU), and (b) the v5e roofline-term
ESTIMATE for the Pallas kernel (FLOPs / bytes analytically from the tiling,
against 197 TFLOP/s + 819 GB/s), which is what the TPU deployment would be
bounded by. The expand section additionally times the *unfused* expansion
dataflow (adjacency gather + vector gather + distance + broadcast dedups —
what the search loop ran before the fused path) against the fused oracle,
and runs the Pallas kernel itself in interpret mode on CPU (compiled on a
real TPU) as a correctness-exercising smoke measurement.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import HBM_BW, PEAK_FLOPS, corpus_bytes_per_distance
from repro.core import quantize_corpus
from repro.kernels import (
    expand_frontier, expand_frontier_ref, flash_attention_ref,
    gatherdist_ref, rangescan_ref,
)
from repro.utils import INVALID_ID, block_until_ready
from .common import print_table


def _wall(fn, iters=3):
    block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # rangescan: retrieval_cand-ish shapes
    for (q, n, d) in [(16, 100_000, 128), (1, 1_000_000, 256)]:
        qs = jax.random.normal(key, (q, d), jnp.float32)
        xs = jax.random.normal(key, (n, d), jnp.float32)
        f = jax.jit(lambda a, b: rangescan_ref(a, b, jnp.float32(1.0), k=128))
        t = _wall(lambda: f(qs, xs))
        flops = 2.0 * q * n * d
        byts = 4.0 * (q * d + n * d + q * n)
        rows.append(["rangescan", f"{q}x{n}x{d}", t * 1e3,
                     flops / PEAK_FLOPS * 1e6, byts / HBM_BW * 1e6])

    # gatherdist: beam expansion shapes, f32 rows vs the int8 quantized
    # corpus (codes + 12B metadata — the v5e memory term drops ~4x; that
    # roofline column, not the CPU wall ms, is the claim of record)
    for (q, r, n, d) in [(256, 32, 100_000, 128), (1024, 64, 100_000, 96)]:
        pts = jax.random.normal(key, (n, d), jnp.float32)
        qs = jax.random.normal(key, (q, d), jnp.float32)
        ids = jax.random.randint(key, (q, r), 0, n, jnp.int32)
        f = jax.jit(lambda p, i, u: gatherdist_ref(p, i, u))
        t = _wall(lambda: f(pts, ids, qs))
        flops = 3.0 * q * r * d
        byts = 4.0 * (q * r * d + q * d + q * r)
        rows.append(["gatherdist", f"{q}x{r}x{d}", t * 1e3,
                     flops / PEAK_FLOPS * 1e6, byts / HBM_BW * 1e6])
        qc = quantize_corpus(pts)
        f8 = jax.jit(lambda i, u: gatherdist_ref(qc, i, u))
        t8 = _wall(lambda: f8(ids, qs))
        byts8 = (q * r * corpus_bytes_per_distance(d, "int8")
                 + 4.0 * (q * d + q * r))
        rows.append(["gatherdist(int8)", f"{q}x{r}x{d}", t8 * 1e3,
                     flops / PEAK_FLOPS * 1e6, byts8 / HBM_BW * 1e6])

    # expand: fused multi-node frontier expansion vs the unfused dataflow
    def unfused_expand(points, neighbors, frontier, queries):
        """The pre-fusion search-loop expansion: row gather, vector gather,
        distance, then three O(T^2)-ish broadcast dedups."""
        n = points.shape[0]
        f_ok = (frontier >= 0) & (frontier < n)
        rows = jnp.take(neighbors, jnp.where(f_ok, frontier, 0), axis=0)
        flat = jnp.where(f_ok[..., None], rows, INVALID_ID)
        flat = flat.reshape(frontier.shape[0], -1)              # (Q, E*R)
        d = gatherdist_ref(points, flat, queries)
        t = jnp.arange(flat.shape[1])
        dup = jnp.any((flat[:, :, None] == flat[:, None, :])
                      & (t[None, None, :] < t[None, :, None])
                      & (flat[:, :, None] != INVALID_ID), axis=2)
        return jnp.where(dup, INVALID_ID, flat), jnp.where(dup, jnp.inf, d)

    for (q, e, n, r, d) in [(256, 4, 100_000, 64, 128), (64, 8, 100_000, 32, 96)]:
        pts = jax.random.normal(key, (n, d), jnp.float32)
        nbrs = jax.random.randint(key, (n, r), 0, n, jnp.int32)
        qs = jax.random.normal(key, (q, d), jnp.float32)
        fr = jax.random.randint(jax.random.PRNGKey(e), (q, e), 0, n, jnp.int32)
        f_fused = jax.jit(lambda p, g, f, u: expand_frontier_ref(p, g, f, u))
        f_unfused = jax.jit(unfused_expand)
        t_f = _wall(lambda: f_fused(pts, nbrs, fr, qs))
        t_u = _wall(lambda: f_unfused(pts, nbrs, fr, qs))
        flops = 3.0 * q * e * r * d
        byts = 4.0 * (q * e * r * d + q * d + q * e * r * 2)
        rows.append(["expand(fused)", f"{q}x{e}x{r}x{d}", t_f * 1e3,
                     flops / PEAK_FLOPS * 1e6, byts / HBM_BW * 1e6])
        rows.append(["expand(unfused)", f"{q}x{e}x{r}x{d}", t_u * 1e3,
                     flops / PEAK_FLOPS * 1e6, byts / HBM_BW * 1e6])
        # int8 corpus through both dataflows (certified lower-bound
        # distances): the unfused-int8 row routes unfused_expand through
        # the same quantized gather, so fused-vs-unfused at int8 isolates
        # the fusion while int8-vs-f32 per dataflow isolates the dtype
        qc = quantize_corpus(pts)
        f_fused8 = jax.jit(lambda g, f, u: expand_frontier_ref(qc, g, f, u))
        f_unfused8 = jax.jit(lambda g, f, u: unfused_expand(qc, g, f, u))
        t_f8 = _wall(lambda: f_fused8(nbrs, fr, qs))
        t_u8 = _wall(lambda: f_unfused8(nbrs, fr, qs))
        byts8 = (q * e * r * corpus_bytes_per_distance(d, "int8")
                 + 4.0 * (q * d + q * e * r * 2))
        rows.append(["expand(fused,int8)", f"{q}x{e}x{r}x{d}", t_f8 * 1e3,
                     flops / PEAK_FLOPS * 1e6, byts8 / HBM_BW * 1e6])
        rows.append(["expand(unfused,int8)", f"{q}x{e}x{r}x{d}", t_u8 * 1e3,
                     flops / PEAK_FLOPS * 1e6, byts8 / HBM_BW * 1e6])

    # the Pallas expand kernel itself: interpret mode on CPU (the DMAs are
    # emulated — wall time is an upper bound, not a TPU prediction)
    pts = jax.random.normal(key, (2_000, 64), jnp.float32)
    nbrs = jax.random.randint(key, (2_000, 16), 0, 2_000, jnp.int32)
    qs = jax.random.normal(key, (4, 64), jnp.float32)
    fr = jax.random.randint(jax.random.PRNGKey(7), (4, 4), 0, 2_000, jnp.int32)
    interp = jax.default_backend() != "tpu"  # compiled only where it lowers
    t_k = _wall(lambda: expand_frontier(pts, nbrs, fr, qs, use_pallas=True,
                                        interpret=interp), iters=1)
    flops = 3.0 * 4 * 4 * 16 * 64
    byts = 4.0 * (4 * 4 * 16 * 64 + 4 * 64 + 4 * 4 * 16 * 2)
    rows.append(["expand(pallas)" + ("[interp]" if interp else ""),
                 "4x4x16x64", t_k * 1e3,
                 flops / PEAK_FLOPS * 1e6, byts / HBM_BW * 1e6])
    # the int8 Pallas expand kernel (MXU int8 matmul + accumulator dequant),
    # same interpret-mode caveat
    qc_small = quantize_corpus(pts)
    t_k8 = _wall(lambda: expand_frontier(qc_small, nbrs, fr, qs,
                                         use_pallas=True, interpret=interp),
                 iters=1)
    byts8 = (4 * 4 * 16 * corpus_bytes_per_distance(64, "int8")
             + 4.0 * (4 * 64 + 4 * 4 * 16 * 2))
    rows.append(["expand(pallas,int8)" + ("[interp]" if interp else ""),
                 "4x4x16x64", t_k8 * 1e3,
                 flops / PEAK_FLOPS * 1e6, byts8 / HBM_BW * 1e6])

    # flashattn: prefill + decode shapes (small batch; CPU wall time)
    for (b, hq, hkv, sq, skv, dh) in [(1, 8, 2, 1024, 1024, 128),
                                      (4, 8, 2, 1, 8192, 128)]:
        q = jax.random.normal(key, (b, hq, sq, dh), jnp.bfloat16)
        k = jax.random.normal(key, (b, hkv, skv, dh), jnp.bfloat16)
        v = jax.random.normal(key, (b, hkv, skv, dh), jnp.bfloat16)
        f = jax.jit(lambda a, c, e: flash_attention_ref(a, c, e))
        t = _wall(lambda: f(q, k, v))
        flops = 4.0 * b * hq * sq * skv * dh
        byts = 2.0 * (b * hq * sq * dh + 2 * b * hkv * skv * dh)
        rows.append(["flashattn", f"b{b}h{hq}/{hkv}s{sq}/{skv}", t * 1e3,
                     flops / PEAK_FLOPS * 1e6, byts / HBM_BW * 1e6])

    print_table("kernel bench: CPU-XLA wall ms + v5e roofline-term estimate",
                ["kernel", "shape", "cpu_ms", "v5e_compute_us", "v5e_mem_us"],
                rows)
    return rows


if __name__ == "__main__":
    run()
