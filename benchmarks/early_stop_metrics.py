"""Paper Fig. 5 / Appendix A: early-stopping metric separation.

At step vl of a beam-100 search, histogram d_visited / d_top1 / d_top10 /
d_top10/d_start for queries grouped by true result count (0, 1-2, >=3),
EXCLUDING searches that already found an in-range candidate (the paper's
Fig. 5b criterion). Reports a separation score (Cohen's d between the
zero-result and >=3-result groups) per metric — positive separation is what
licenses early stopping on a dataset.
"""
from __future__ import annotations


import numpy as np

from repro.core import SearchConfig, beam_search_batch
from .common import ALL_PROFILES, QUICK_PROFILES, get_dataset, get_engine, print_table

import jax.numpy as jnp


def collect_metrics(profile: str, n: int, step: int = 20, beam: int = 100):
    ds, pts, qs, r, _, gt = get_dataset(profile, n)
    eng = get_engine(profile, n)
    cfg = SearchConfig(beam=beam, max_beam=beam, visit_cap=step, metric=ds.metric)
    st = beam_search_batch(pts, eng.graph, qs, eng.start_ids,
                           jnp.asarray(np.inf, jnp.float32), cfg)
    counts = np.asarray(gt[2])
    found = np.asarray(st.dists[:, 0]) <= r   # already has a candidate -> excluded
    d_visited = np.asarray(st.d_visited)
    d_top1 = np.asarray(st.dists[:, 0])
    d_top10 = np.asarray(st.dists[:, 9])
    d_start = np.asarray(st.d_start)
    ratio = d_top10 / np.maximum(d_start, 1e-30)
    groups = {"zero": (counts == 0) & ~found,
              "small": (counts > 0) & (counts <= 2) & ~found,
              "large": (counts >= 3) & ~found}
    return {"d_visited": d_visited, "d_top1": d_top1, "d_top10": d_top10,
            "d_top10/d_start": ratio}, groups


def _cohens_d(a: np.ndarray, b: np.ndarray) -> float:
    if len(a) < 2 or len(b) < 2:
        return float("nan")
    s = np.sqrt((a.var() + b.var()) / 2)
    return float((a.mean() - b.mean()) / max(s, 1e-12))


def run(n: int = 10_000, quick: bool = True):
    rows = []
    profiles = QUICK_PROFILES if quick else ALL_PROFILES
    for prof_name in profiles:
        metrics, groups = collect_metrics(prof_name, n)
        for mname, vals in metrics.items():
            sep = _cohens_d(vals[groups["zero"]], vals[groups["large"]])
            rows.append([prof_name, mname, int(groups["zero"].sum()),
                         int(groups["large"].sum()), sep])
    print_table("Fig5: early-stop metric separation (Cohen's d, "
                "zero-result vs >=3-result queries, found-excluded)",
                ["profile", "metric", "n_zero", "n_large", "separation"], rows)
    return rows


if __name__ == "__main__":
    run()
