"""Paper Fig. 4: frequency distribution of per-query match counts
(buckets 0 / <=10 / <=100 / <=1e3 / <=1e4 / <=1e5), incl. the size-scaling
observation (same radius, denser corpus -> fatter tail)."""
from __future__ import annotations

import numpy as np

from repro.core import exact_range_search
from repro.core.radius import match_histogram
from .common import ALL_PROFILES, QUICK_PROFILES, get_dataset, print_table

import jax.numpy as jnp


def run(n: int = 10_000, quick: bool = True):
    rows = []
    profiles = QUICK_PROFILES if quick else ALL_PROFILES
    for prof_name in profiles:
        ds, pts, qs, r, prof, gt = get_dataset(prof_name, n)
        h = match_histogram(np.asarray(gt[2]))
        rows.append([prof_name] + list(h.values()))
    header = ["profile", "0", "<=1e1", "<=1e2", "<=1e3", "<=1e4", "<=1e5"]
    print_table("Fig4: match-size distribution", header, rows)

    # scaling: same radius on 1x and 3x corpus (paper: density grows)
    scale_rows = []
    for prof_name in profiles[:2]:
        ds1, pts1, qs1, r1, _, gt1 = get_dataset(prof_name, n)
        ds3, _, _, _, _, _ = get_dataset(prof_name, 3 * n)
        pts3 = jnp.asarray(ds3.points)
        gt3 = exact_range_search(pts3, qs1, r1, ds1.metric)
        scale_rows.append([prof_name, float(np.asarray(gt1[2]).mean()),
                           float(np.asarray(gt3[2]).mean())])
    print_table("Fig4b: mean matches/query at 1x vs 3x corpus (same radius)",
                ["profile", "mean_1x", "mean_3x"], scale_rows)
    return rows


if __name__ == "__main__":
    run()
