"""Paper Fig. 8: time breakdown — initial beam-search phase vs second phase
(greedy / doubling), with and without early stopping.

We time phase 1 alone (the shared beam search) and the full pipeline; the
difference is phase-2 cost. Run per profile at a fixed configuration.

The int8 quantized corpus adds a third phase: the exact rerank of the
radius guard band. Its cost is isolated as ``t(rerank on) - t(rerank off)``
(``RangeConfig.rerank`` toggles only that stage), so the two-pass split is
visible in the same table — quantized rows carry the corpus dtype in the
profile column and a nonzero ``rerank_s``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import (
    ES_D_VISITED, RangeConfig, RangeSearchEngine, SearchConfig,
    beam_search_batch,
)
from repro.utils import block_until_ready
from .common import QUICK_PROFILES, ap_of, get_dataset, get_engine, print_table

import jax.numpy as jnp


def _time(fn, iters=2):
    block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(n: int = 10_000, beam: int = 32):
    rows = []
    for prof_name in QUICK_PROFILES:
        ds, pts, qs, r, _, gt = get_dataset(prof_name, n)
        eng = get_engine(prof_name, n)
        for es in (False, True):
            scfg = SearchConfig(beam=beam, max_beam=beam, visit_cap=4 * beam,
                                metric=ds.metric,
                                es_metric=ES_D_VISITED if es else 0,
                                es_visit_limit=15)
            esr = 1.5 * r if es else None
            t_phase1 = _time(lambda: beam_search_batch(
                pts, eng.graph, qs, eng.start_ids, jnp.asarray(r, jnp.float32),
                scfg, None if esr is None else jnp.asarray(esr, jnp.float32)))
            for mode in ("greedy", "doubling"):
                cfg = RangeConfig(
                    search=dataclasses.replace(
                        scfg, max_beam=beam * (16 if mode == "doubling" else 1),
                        visit_cap=16 * beam if mode == "doubling" else 4 * beam),
                    mode=mode, result_cap=2048)
                t_full = _time(lambda: eng.range(qs, r, cfg=cfg, es_radius=esr))
                _, res = (None, eng.range(qs, r, cfg=cfg, es_radius=esr))
                rows.append([prof_name, mode, "es" if es else "no-es",
                             t_phase1, max(t_full - t_phase1, 0.0), 0.0,
                             t_full, ap_of(res, gt)])

    # quantized two-pass rows (first quick profile): rerank phase isolated
    # by toggling RangeConfig.rerank — searches are identical either way
    prof_name = QUICK_PROFILES[0]
    ds, pts, qs, r, _, gt = get_dataset(prof_name, n)
    eng = get_engine(prof_name, n)
    eng8 = dataclasses.replace(
        RangeSearchEngine.from_graph(pts, eng.graph, metric=ds.metric,
                                     corpus_dtype="int8"),
        start_ids=eng.start_ids)
    scfg = SearchConfig(beam=beam, max_beam=beam, visit_cap=4 * beam,
                        metric=ds.metric)
    t_phase1 = _time(lambda: beam_search_batch(
        eng8.points, eng8.graph, qs, eng8.start_ids,
        jnp.asarray(r, jnp.float32), scfg))
    for mode in ("greedy", "doubling"):
        cfg = RangeConfig(
            search=dataclasses.replace(
                scfg, max_beam=beam * (16 if mode == "doubling" else 1),
                visit_cap=16 * beam if mode == "doubling" else 4 * beam),
            mode=mode, result_cap=2048)
        t_norr = _time(lambda: eng8.range(
            qs, r, dataclasses.replace(cfg, rerank=False)))
        t_full = _time(lambda: eng8.range(qs, r, cfg=cfg))
        res = eng8.range(qs, r, cfg=cfg)
        rows.append([f"{prof_name}[int8]", mode, "no-es",
                     t_phase1, max(t_norr - t_phase1, 0.0),
                     max(t_full - t_norr, 0.0), t_full, ap_of(res, gt)])
    print_table("Fig8: phase time breakdown (seconds, batch of "
                f"{256} queries)",
                ["profile", "mode", "early_stop", "phase1_s", "phase2_s",
                 "rerank_s", "total_s", "ap"], rows)
    return rows


if __name__ == "__main__":
    run()
