"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick (3 profiles)
  PYTHONPATH=src python -m benchmarks.run --full     # all 9 profiles
  PYTHONPATH=src python -m benchmarks.run --scale    # + Fig7 densification

Corpora are synthetic with paper-matched range characteristics
(data/synthetic.py); absolute QPS is CPU-scale, the paper's *qualitative*
claims (speedup ordering, early-stop separation, greedy-vs-doubling
crossover) are what each section validates.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--full", action="store_true", help="all 9 dataset profiles")
    p.add_argument("--scale", action="store_true", help="include Fig7 scaling")
    p.add_argument("--n", type=int, default=10_000)
    args = p.parse_args(argv)
    quick = not args.full

    from . import (
        early_stop_metrics, early_stop_qps, kernel_bench, match_distribution,
        qps_precision, radius_capture, time_breakdown, topk_compare,
    )

    t0 = time.time()
    print("== repro benchmarks (paper: Range Retrieval with Graph-Based "
          "Indices) ==")
    radius_capture.run(n=args.n, quick=quick)
    match_distribution.run(n=args.n, quick=quick)
    qps_precision.run(n=args.n, quick=quick)
    early_stop_metrics.run(n=args.n, quick=quick)
    early_stop_qps.run(n=args.n, quick=quick)
    time_breakdown.run(n=args.n)
    topk_compare.run(n=args.n)
    kernel_bench.run()
    if args.scale:
        qps_precision.run_scaling(n=max(args.n // 2, 4000))
    print(f"\n== done in {time.time() - t0:.0f}s ==")
    return 0


if __name__ == "__main__":
    sys.exit(main())
