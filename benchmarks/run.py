"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick (3 profiles)
  PYTHONPATH=src python -m benchmarks.run --full     # all 9 profiles
  PYTHONPATH=src python -m benchmarks.run --scale    # + Fig7 densification

Corpora are synthetic with paper-matched range characteristics
(data/synthetic.py); absolute QPS is CPU-scale, the paper's *qualitative*
claims (speedup ordering, early-stop separation, greedy-vs-doubling
crossover) are what each section validates.
"""
from __future__ import annotations

import argparse
import sys
import time


def smoke(n: int, min_qps: float, min_ap: float) -> int:
    """CI gate: one tiny corpus through ``range_search_compacted``; exits
    nonzero when QPS falls below ``min_qps`` (order-of-magnitude regression
    guard — CI boxes are slow, so the floor is deliberately conservative)
    or AP below ``min_ap``."""
    from repro.core import RangeConfig, SearchConfig

    from .common import ap_of, get_dataset, get_engine, run_range

    # default n_queries so get_engine's internal get_dataset is a cache hit
    # (a different n_queries would rebuild the grid sweep + ground truth)
    ds, _, qs, r, _, gt = get_dataset("bigann-like", n)
    qs, gt = qs[:128], tuple(g[:128] for g in gt)
    eng = get_engine("bigann-like", n)
    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32, visit_cap=128,
                                          metric=ds.metric),
                      mode="greedy", result_cap=1024)
    qps, res = run_range(eng, qs, r, cfg)
    ap = ap_of(res, gt)
    print(f"[smoke] range_search_compacted: n={n} qps={qps:.1f} ap={ap:.4f} "
          f"(floors: qps>={min_qps}, ap>={min_ap})")
    if qps < min_qps or ap < min_ap:
        print("[smoke] FAIL: below regression floor")
        return 1
    return 0


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--full", action="store_true", help="all 9 dataset profiles")
    p.add_argument("--scale", action="store_true", help="include Fig7 scaling")
    p.add_argument("--n", type=int, default=10_000)
    p.add_argument("--smoke", action="store_true",
                   help="tiny-corpus QPS/AP regression gate (CI)")
    p.add_argument("--min-qps", type=float, default=5.0)
    p.add_argument("--min-ap", type=float, default=0.6)
    args = p.parse_args(argv)
    quick = not args.full

    if args.smoke:
        return smoke(min(args.n, 4_000), args.min_qps, args.min_ap)

    from . import (
        early_stop_metrics, early_stop_qps, kernel_bench, match_distribution,
        qps_precision, radius_capture, time_breakdown, topk_compare,
    )

    t0 = time.time()
    print("== repro benchmarks (paper: Range Retrieval with Graph-Based "
          "Indices) ==")
    radius_capture.run(n=args.n, quick=quick)
    match_distribution.run(n=args.n, quick=quick)
    qps_precision.run(n=args.n, quick=quick)
    early_stop_metrics.run(n=args.n, quick=quick)
    early_stop_qps.run(n=args.n, quick=quick)
    time_breakdown.run(n=args.n)
    topk_compare.run(n=args.n)
    kernel_bench.run()
    if args.scale:
        qps_precision.run_scaling(n=max(args.n // 2, 4000))
    print(f"\n== done in {time.time() - t0:.0f}s ==")
    return 0


if __name__ == "__main__":
    sys.exit(main())
