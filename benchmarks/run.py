"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick (3 profiles)
  PYTHONPATH=src python -m benchmarks.run --full     # all 9 profiles
  PYTHONPATH=src python -m benchmarks.run --scale    # + Fig7 densification

Corpora are synthetic with paper-matched range characteristics
(data/synthetic.py); absolute QPS is CPU-scale, the paper's *qualitative*
claims (speedup ordering, early-stop separation, greedy-vs-doubling
crossover) are what each section validates.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

# machine-readable perf trajectory, one record per CI run (uploaded as an
# artifact so QPS/AP are comparable across PRs without log scraping)
SMOKE_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_smoke.json")


# mixed-radius gate: max AP a heterogeneous batch may lose vs dispatching
# each radius level as its own homogeneous batch (recorded in floors too)
MAX_MIXED_AP_GAP = 0.005

# quantized-corpus gates. The AP gap bounds what int8 storage + guard-band
# rerank may cost in result quality end to end — it is deterministic on the
# fixed smoke corpus and the real correctness contract (the oracle tests
# additionally prove exact post-rerank sets). The perf gate is the
# *roofline term* the quantization exists for: hot-loop corpus bytes per
# distance must drop >= 3x (int8 codes + 12B metadata vs 4d f32 — the
# binding constraint of the TPU deployment, README "Memory footprint &
# quantization"). Wall-clock QPS ratios (end-to-end and hot-path) are
# RECORDED but not gated: across repeated runs on shared 2-core CI boxes
# they swing ~0.7-1.8x with the cache regime and noisy neighbors (measured;
# see the record's note), which would make any fixed floor flaky. On the
# XLA CPU backend the e2e ratio hovers around 0.9-1.0x — the loop is
# dominated by dtype-independent merge/scatter work and gathers stay
# cache-resident at smoke scale; the e2e payoff belongs to the TPU path
# (Pallas int8 kernels + the HBM cut this gate pins).
MAX_QUANTIZED_AP_GAP = 0.01
MIN_QUANTIZED_BYTES_REDUCTION = 3.0

# tiered-corpus gates. The tier moves the raw f32 rerank rows off device
# (host-RAM row store) while int8 codes + 12B meta stay resident, so the
# two gated claims are (a) STRUCTURAL: device corpus bytes per vector
# (codes + meta + the bounded row cache, from the measured MemoryBudget)
# must drop >= 3x vs f32-resident, with the row cache pinned to <= 25% of
# the raw-row bytes it replaces (else the "tier" is quietly re-residenting
# the corpus); and (b) BITWISE: results must be identical to the resident
# int8 engine on the same graph — ids, dists, count, every bit. Not an AP
# gap of zero, actual array equality: the tiered exact_pairs contract is
# that cache state, fetch bucketing, and eviction history can never change
# a result bit. Fetch-path telemetry (dedup ratio, cache hit rate, rows/
# bytes fetched) is recorded for trajectory tracking, not gated (it shifts
# with REPRO_TIER_CACHE_ROWS, which the CI memcap job deliberately
# shrinks).
MIN_TIER_DEVICE_BYTES_REDUCTION = 3.0
MAX_TIER_CACHE_FRAC_OF_RAW = 0.25

# live-churn gate: after 10% churn (inserts + tombstoned deletes) and a
# consolidation pass, AP on the live set may trail a FRESH static rebuild of
# the same live set by at most this much — the acceptance bound on what
# streaming mutation costs versus batch reindexing. Deterministic on the
# fixed smoke corpus; wall-clock mutation rates are recorded, not gated
# (same CI-noise rationale as the quantized row).
MAX_CHURN_AP_GAP = 0.02

# tail-latency gates: on a mixed point+heavy workload (every lockstep
# micro-batch carries one dense-region straggler), continuous batching must
# cut the POINT queries' p99 to at most this fraction of the lockstep
# baseline's — the lockstep-break claim itself, measured as a ratio so the
# gate survives CI wall-clock noise (both sides run on the same box seconds
# apart). The AP gap gate pins that the latency win is not bought with
# accuracy: sliced pool execution must answer within this of lockstep.
MAX_TAIL_P99_RATIO = 0.5
MAX_TAIL_AP_GAP = 0.005

# degraded-serving gates. Shard loss: permanently losing 1 of 4 shards must
# keep AP at >= this fraction of the healthy run's (the corpus partitions
# ~uniformly, so 3/4 coverage holds ~75% of the matches; 0.70 leaves
# distribution skew headroom), with the degradation honestly annotated
# (coverage 0.75, shards_ok 3/4, code shard_lost). Deadline: lanes that
# COMPLETE under a p50-latency deadline return full (bitwise-identical to
# no-deadline) answers, so their AP must hold this fraction of the healthy
# run's AP over the SAME lanes (bitwise identity makes the true ratio 1.0;
# the floor leaves only float/accounting headroom) — which lanes complete
# varies with CI wall clock, but each complete lane's answer does not, so
# only a certification bug (a corrupted result stamped complete) can trip
# it. Expired lanes return certified partials and are recorded (coverage),
# not gated — their count is wall-clock dependent.
MIN_DEGRADED_AP_FRAC = 0.70
MIN_DEADLINE_COMPLETE_AP_FRAC = 0.90

# filtered-retrieval gate: AP of predicate push-down search (scored against
# the post-filtered brute-force oracle) may trail the unfiltered AP (scored
# against the unfiltered oracle) by at most this much. The filtered walk is
# the unfiltered walk with a result-stage gate — filtering never changes
# routing on the fused path and can only improve it on the compacted path
# (entry reseeding from the posting list) — so any larger gap means the
# predicate is leaking into the traversal. The selective-lane fallback
# speedup is RECORDED, not gated (CI wall-clock noise; the structural fact
# that fallback lanes bypass the graph IS gated via n_visited == 0).
MAX_FILTERED_AP_GAP = 0.01


def smoke(n: int, min_qps: float, min_ap: float) -> int:
    """CI gate: one tiny corpus through ``range_search_compacted``; exits
    nonzero when QPS falls below ``min_qps`` (order-of-magnitude regression
    guard — CI boxes are slow, so the floor is deliberately conservative)
    or AP below ``min_ap``. Runs the multi-node expansion config (E=4)
    against the single-node baseline (E=1) and records both in
    ``BENCH_smoke.json``; the gate applies to the E=4 numbers.

    The radius targets ~128 matches/query (picked off the sweep grid), the
    paper's match-dense regime (SSNPP/Fig. 4): range retrieval's cost there
    is dominated by the greedy result-expansion phase, which is exactly what
    the multi-node/bitset rework accelerates — and what serving traffic pays
    for. (At near-zero match counts the search is gather-bandwidth-bound and
    E barely matters; that regime is covered by qps_precision.py.)"""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        RangeConfig, SearchConfig, average_precision, exact_range_search,
    )

    from .common import ap_of, get_dataset, get_engine, run_range

    # default n_queries so get_engine's internal get_dataset is a cache hit
    # (a different n_queries would rebuild the grid sweep + ground truth)
    ds, pts, qs, _, prof, _ = get_dataset("bigann-like", n)
    qs = qs[:128]
    mean_counts = np.asarray(prof.counts).mean(axis=0)
    r = float(prof.radii[int(np.argmin(np.abs(mean_counts - 128.0)))])
    gt = exact_range_search(pts, qs, r, ds.metric)
    eng = get_engine("bigann-like", n)

    def measure(expand_width: int):
        cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32,
                                              visit_cap=128, metric=ds.metric,
                                              expand_width=expand_width),
                          mode="greedy", result_cap=1024)
        qps, res = run_range(eng, qs, r, cfg)
        return cfg, dict(
            qps=round(qps, 2),
            ap=round(ap_of(res, gt), 4),
            mean_n_dist=round(float(np.asarray(res.n_dist).mean()), 1),
            mean_n_visited=round(float(np.asarray(res.n_visited).mean()), 1),
        )

    cfg, rec = measure(expand_width=4)
    _, base = measure(expand_width=1)
    speedup = rec["qps"] / max(base["qps"], 1e-9)
    print(f"[smoke] range_search_compacted: n={n} expand_width=4 "
          f"qps={rec['qps']:.1f} ap={rec['ap']:.4f} "
          f"(floors: qps>={min_qps}, ap>={min_ap})")
    print(f"[smoke] expand_width=1 baseline: qps={base['qps']:.1f} "
          f"ap={base['ap']:.4f} -> E=4 speedup {speedup:.2f}x")

    # -- mixed-radius row: heterogeneous batches are the serving regime -----
    # per-query radii log-spaced across the match distribution (from the
    # capture-curve sweep: the span whose mean counts cover ~2..~512
    # matches/query), round-robin across lanes so every micro-batch mixes
    # near-duplicate-tight and recommendation-wide radii
    lo_i = int(np.argmin(np.abs(mean_counts - 2.0)))
    hi_i = int(np.argmin(np.abs(mean_counts - 512.0)))
    n_distinct = 8
    levels = np.geomspace(float(prof.radii[lo_i]), float(prof.radii[hi_i]),
                          n_distinct).astype(np.float32)
    radii = levels[np.arange(qs.shape[0]) % n_distinct]
    gt_mix = exact_range_search(pts, qs, jnp.asarray(radii), ds.metric)
    mix_cfg = cfg  # same E=4 config as the main row: the two stay comparable
    mix_qps, mix_res = run_range(eng, qs, jnp.asarray(radii), mix_cfg)
    mix_ap = ap_of(mix_res, gt_mix)
    # homogeneous-dispatch reference: each radius level served in its own
    # batch (what a radius-bucketing server would do); the mixed batch must
    # match its AP — heterogeneity is free accuracy-wise
    hom_ids = np.zeros_like(np.asarray(mix_res.ids))
    hom_counts = np.zeros_like(np.asarray(mix_res.count))
    for k, lv in enumerate(levels):
        lanes = np.nonzero(np.arange(qs.shape[0]) % n_distinct == k)[0]
        sub = eng.range(qs[lanes], float(lv), cfg=mix_cfg)
        hom_ids[lanes] = np.asarray(sub.ids)
        hom_counts[lanes] = np.asarray(sub.count)
    hom_ap = average_precision(np.asarray(gt_mix[0]), np.asarray(gt_mix[2]),
                               hom_ids, hom_counts)
    ap_gap = abs(mix_ap - hom_ap)
    mixed = dict(
        qps=round(mix_qps, 2), ap=round(mix_ap, 4),
        ap_homogeneous=round(hom_ap, 4), ap_gap=round(ap_gap, 5),
        radius_lo=float(levels[0]), radius_hi=float(levels[-1]),
        n_distinct_radii=n_distinct,
        mean_matches=round(float(np.asarray(gt_mix[2]).mean()), 1),
    )
    print(f"[smoke] mixed-radius batch: qps={mix_qps:.1f} ap={mix_ap:.4f} "
          f"(homogeneous dispatch ap={hom_ap:.4f}, gap={ap_gap:.5f}; "
          f"radii {levels[0]:.3g}..{levels[-1]:.3g})")

    # -- churn row: live mutation vs a fresh static rebuild ------------------
    churn = _churn_row(n)
    print(f"[smoke] churn 10%: live ap={churn['ap_live']:.4f} vs fresh "
          f"rebuild ap={churn['ap_rebuild']:.4f} "
          f"(gap {churn['ap_gap']:+.4f}, floor {MAX_CHURN_AP_GAP}); "
          f"query qps live {churn['qps_live']:.1f} vs static "
          f"{churn['qps_static']:.1f}; "
          f"{churn['inserts_per_s']:.0f} inserts/s, "
          f"{churn['deletes_per_s']:.0f} deletes/s, consolidation "
          f"{churn['consolidate_s']:.2f}s")

    # -- quantized-corpus row: int8 two-pass vs f32, same graph --------------
    # measured on gist-like (d=256): the gather-bound regime the quantized
    # pipeline targets — corpus bytes per distance dominate as d grows
    quantized = _quantized_row(n)
    print(f"[smoke] quantized (gist-like d={quantized['dim']}): "
          f"e2e int8 {quantized['engine']['qps_int8']:.1f} qps vs f32 "
          f"{quantized['engine']['qps_f32']:.1f} "
          f"({quantized['engine']['speedup']:.2f}x), "
          f"ap gap {quantized['engine']['ap_gap']:+.4f}, "
          f"rerank band {quantized['engine']['rerank_per_query']:.1f}/query")
    print(f"[smoke] quantized hot path (bulk gather+distance): int8 "
          f"{quantized['hot_path']['speedup']:.2f}x f32 "
          f"({quantized['hot_path']['bytes_per_dist_f32']:.0f} -> "
          f"{quantized['hot_path']['bytes_per_dist_int8']:.0f} "
          f"bytes/distance)")

    # -- tiered row: host-RAM raw rows, device codes + bounded cache ---------
    tiered = _tiered_row(n)
    tm, tf = tiered["memory"], tiered["fetch"]
    print(f"[smoke] tiered (gist-like d={tiered['dim']}): device "
          f"{tm['device_bytes_per_vector']:.0f} B/vec vs f32-resident "
          f"{tm['f32_resident_bytes'] // n} -> "
          f"{tm['device_bytes_reduction_vs_f32']:.2f}x "
          f"(floor {MIN_TIER_DEVICE_BYTES_REDUCTION}); cache "
          f"{tm['cache_rows']} rows = {tm['cache_frac_of_raw']:.3f} of raw "
          f"(cap {MAX_TIER_CACHE_FRAC_OF_RAW}); bitwise_identical="
          f"{tiered['bitwise_identical']}")
    print(f"[smoke] tiered fetch path: dedup {tf['dedup_ratio']:.2f}x "
          f"({tf['pairs']} pairs -> {tf['unique_rows']} unique), cache hit "
          f"rate {tf['cache_hit_rate']:.3f}, {tf['fetched_rows']} rows / "
          f"{tf['fetch_batches']} buckets fetched; qps ratio vs resident "
          f"int8 {tiered['qps_ratio']:.2f}x")

    # -- heavy-tail row: radius methodology on an adversarial workload -------
    heavy = _heavy_tail_row(min(n, 4_000))
    print(f"[smoke] heavy-tail radius (recorded): zero_frac="
          f"{heavy['zero_frac']:.3f} max_count={heavy['max_count']} "
          f"median_nonzero={heavy['median_nonzero']} top-10% queries hold "
          f"{heavy['top10pct_match_mass']:.2f} of all matches; "
          f"hist={heavy['histogram']}")

    # -- tail-latency row: continuous batching vs lockstep -------------------
    tail = _tail_latency_row(n)
    print(f"[smoke] tail latency (point queries, {tail['n_point']} of "
          f"{tail['n_queries']}): continuous p99 "
          f"{tail['continuous']['point_p99_ms']:.1f}ms vs lockstep "
          f"{tail['lockstep']['point_p99_ms']:.1f}ms -> ratio "
          f"{tail['point_p99_ratio']:.3f} (floor {MAX_TAIL_P99_RATIO}); "
          f"ap {tail['continuous']['ap']:.4f} vs "
          f"{tail['lockstep']['ap']:.4f} (gap {tail['ap_gap']:.5f})")

    # -- degraded row: shard loss + deadline partials ------------------------
    degraded = _degraded_row(n)
    sl, dl = degraded["shard_loss"], degraded["deadline"]
    print(f"[smoke] shard loss (1 of {sl['shards_total']}): degraded "
          f"ap={sl['ap_degraded']:.4f} vs healthy {sl['ap_healthy']:.4f} "
          f"-> frac {sl['ap_frac']:.3f} (floor {MIN_DEGRADED_AP_FRAC}); "
          f"coverage={sl['coverage']} shards_ok={sl['shards_ok']}/"
          f"{sl['shards_total']} code={sl['code']}")
    dl_frac = dl["ap_frac"]
    print(f"[smoke] deadline at p50 ({dl['deadline_s'] * 1e3:.1f}ms): "
          f"{dl['n_complete']}/{dl['n_queries']} lanes complete, "
          f"ap(complete)={dl['ap_complete_lanes']} vs healthy same-lane "
          f"{dl['ap_healthy_same_lanes']} -> frac "
          f"{'n/a' if dl_frac is None else f'{dl_frac:.4f}'} "
          f"(floor {MIN_DEADLINE_COMPLETE_AP_FRAC}); "
          f"{dl['n_partial']} certified partials, mean coverage "
          f"{dl['mean_partial_coverage']}")

    # -- replicated row: R=2 absorbs replica loss; hedging hides slowness ----
    replicated = _replicated_row(n)
    rl, rh = replicated["replica_loss"], replicated["hedged"]
    print(f"[smoke] replicated (R={replicated['replicas']}, one replica of "
          f"each shard down): coverage={rl['coverage']} code={rl['code']} "
          f"bitwise_identical={rl['bitwise_identical']} replicas_ok="
          f"{rl['replicas_ok']}/{rl['replicas_total']}; "
          f"R=1 baseline coverage={replicated['baseline_r1_coverage']}")
    print(f"[smoke] hedged (scripted-slow primaries, delay=0): "
          f"hedges_fired={rh['hedges_fired']} hedge_wins={rh['hedge_wins']} "
          f"bitwise_identical={rh['bitwise_identical']} "
          f"ap_gap={rh['ap_gap']:+.5f}")

    # -- filtered row: predicate push-down vs the post-filtered oracle -------
    filtered = _filtered_row(n)
    print(f"[smoke] filtered (selective AND ~{filtered['selective_frac']:.2f}"
          f" / broad OR ~{filtered['broad_frac']:.2f} of corpus): "
          f"ap={filtered['ap_filtered']:.4f} vs unfiltered "
          f"{filtered['ap_unfiltered']:.4f} "
          f"(gap {filtered['ap_gap']:+.4f}, floor {MAX_FILTERED_AP_GAP}); "
          f"fallback on {filtered['n_fallback_lanes']} selective lanes -> "
          f"{filtered['fallback_speedup']:.2f}x walk qps")

    record = dict(
        bench="smoke", n=n, n_queries=int(qs.shape[0]), radius=float(r),
        mean_matches=round(float(np.asarray(gt[2]).mean()), 1),
        config=dataclasses.asdict(cfg), **rec,
        baseline_expand1=base, speedup_vs_expand1=round(speedup, 3),
        mixed_radius=mixed,
        quantized=quantized,
        tiered=tiered,
        heavy_tail=heavy,
        churn=churn,
        tail_latency=tail,
        degraded=degraded,
        replicated=replicated,
        filtered=filtered,
        floors=dict(min_qps=min_qps, min_ap=min_ap,
                    max_mixed_ap_gap=MAX_MIXED_AP_GAP,
                    max_quantized_ap_gap=MAX_QUANTIZED_AP_GAP,
                    min_quantized_bytes_reduction=MIN_QUANTIZED_BYTES_REDUCTION,
                    min_tier_device_bytes_reduction=MIN_TIER_DEVICE_BYTES_REDUCTION,
                    max_tier_cache_frac_of_raw=MAX_TIER_CACHE_FRAC_OF_RAW,
                    tier_bitwise_identical=True,
                    max_churn_ap_gap=MAX_CHURN_AP_GAP,
                    max_tail_p99_ratio=MAX_TAIL_P99_RATIO,
                    max_tail_ap_gap=MAX_TAIL_AP_GAP,
                    min_degraded_ap_frac=MIN_DEGRADED_AP_FRAC,
                    min_deadline_complete_ap_frac=MIN_DEADLINE_COMPLETE_AP_FRAC,
                    replicated_coverage=1.0, min_hedges_fired=1,
                    max_filtered_ap_gap=MAX_FILTERED_AP_GAP),
        timestamp=time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    )
    with open(SMOKE_JSON, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"[smoke] trajectory record -> {SMOKE_JSON}")

    if rec["qps"] < min_qps or rec["ap"] < min_ap:
        print("[smoke] FAIL: below regression floor")
        return 1
    if ap_gap > MAX_MIXED_AP_GAP:
        print("[smoke] FAIL: mixed-radius batch AP deviates from "
              "homogeneous dispatch")
        return 1
    if quantized["engine"]["ap_gap"] > MAX_QUANTIZED_AP_GAP:
        print("[smoke] FAIL: quantized-corpus AP gap above floor")
        return 1
    hp = quantized["hot_path"]
    if (hp["bytes_per_dist_f32"] / hp["bytes_per_dist_int8"]
            < MIN_QUANTIZED_BYTES_REDUCTION):
        print("[smoke] FAIL: int8 bytes-per-distance reduction below floor")
        return 1
    if not tiered["bitwise_identical"]:
        print("[smoke] FAIL: tiered results deviate from the resident int8 "
              "engine — the exact_pairs bitwise-parity contract is broken")
        return 1
    if (tiered["memory"]["device_bytes_reduction_vs_f32"]
            < MIN_TIER_DEVICE_BYTES_REDUCTION):
        print("[smoke] FAIL: tiered device bytes/vector reduction vs "
              "f32-resident below floor")
        return 1
    if tiered["memory"]["cache_frac_of_raw"] > MAX_TIER_CACHE_FRAC_OF_RAW:
        print("[smoke] FAIL: tiered row cache exceeds the resident-bytes "
              "cap — the tier is re-residenting the corpus")
        return 1
    if churn["ap_gap"] > MAX_CHURN_AP_GAP:
        print("[smoke] FAIL: churned live index trails a fresh rebuild by "
              "more than the AP floor")
        return 1
    if tail["point_p99_ratio"] > MAX_TAIL_P99_RATIO:
        print("[smoke] FAIL: continuous batching did not cut point-query "
              "p99 below the lockstep-ratio floor")
        return 1
    if tail["ap_gap"] > MAX_TAIL_AP_GAP:
        print("[smoke] FAIL: continuous batching AP deviates from lockstep")
        return 1
    if sl["ap_frac"] < MIN_DEGRADED_AP_FRAC:
        print("[smoke] FAIL: 1-of-4 shard loss dropped AP below the "
              "degraded floor")
        return 1
    if sl["shards_ok"] != 3 or sl["coverage"] != 0.75 or \
            sl["code"] != "shard_lost":
        print("[smoke] FAIL: shard-loss degradation not annotated "
              "(coverage/shards_ok/code)")
        return 1
    if dl_frac is not None and dl_frac < MIN_DEADLINE_COMPLETE_AP_FRAC:
        print("[smoke] FAIL: lanes marked complete under a deadline "
              "returned degraded answers (certification bug)")
        return 1
    if rl["coverage"] != 1.0 or rl["code"] != "replica_lost" or \
            not rl["bitwise_identical"]:
        print("[smoke] FAIL: R=2 did not absorb one-replica-per-shard loss "
              "(expected coverage 1.0, code replica_lost, bitwise-identical "
              "results)")
        return 1
    if rh["hedges_fired"] < 1 or not rh["bitwise_identical"]:
        print("[smoke] FAIL: hedge path not exercised or hedged results "
              "deviate from the healthy run")
        return 1
    if filtered["ap_gap"] > MAX_FILTERED_AP_GAP:
        print("[smoke] FAIL: filtered AP (vs post-filtered oracle) trails "
              "unfiltered AP beyond the floor — predicate is leaking into "
              "the traversal")
        return 1
    if filtered["n_fallback_lanes"] == 0:
        print("[smoke] FAIL: selective predicates never engaged the "
              "brute-scan fallback (n_visited stayed nonzero)")
        return 1
    return 0


def _filtered_row(n: int) -> dict:
    """Filtered-retrieval smoke: predicate push-down vs the post-filtered
    brute-force oracle, on the same corpus/graph/radius as the main row.

    Labels are synthetic (1-2 of 16 per point, seeded); lanes alternate a
    selective single-label AND (~9% of the corpus matches) and a broad
    4-label OR (~35%). Filtered AP is scored against the post-filtered
    oracle, unfiltered AP against the plain oracle — the gap is gated at
    MAX_FILTERED_AP_GAP. The selective lanes are then re-run with
    ``filter_threshold`` above their selectivity so the per-lane brute-scan
    fallback engages (proven via n_visited == 0); its speedup over the walk
    path on the same lanes is recorded."""
    import dataclasses as dc

    import numpy as np

    from repro.core import (
        RangeConfig, RangeSearchEngine, SearchConfig, average_precision,
        exact_range_search, label_match_counts, make_label_filter,
        pack_labels,
    )
    from repro.utils import INVALID_ID

    from .common import get_dataset, get_engine, run_range

    ds, pts, qs, _, prof, _ = get_dataset("bigann-like", n)
    qs = qs[:128]
    nq = qs.shape[0]
    mean_counts = np.asarray(prof.counts).mean(axis=0)
    r = float(prof.radii[int(np.argmin(np.abs(mean_counts - 128.0)))])
    gt = exact_range_search(pts, qs, r, ds.metric)
    base = get_engine("bigann-like", n)
    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32, visit_cap=128,
                                          metric=ds.metric, expand_width=4),
                      mode="greedy", result_cap=1024)

    num_labels = 16
    rng = np.random.default_rng(17)
    raw = [sorted(int(x) for x in
                  rng.choice(num_labels, size=int(rng.integers(1, 3)),
                             replace=False))
           for _ in range(int(pts.shape[0]))]
    eng = RangeSearchEngine(points=base.points, graph=base.graph,
                            start_ids=base.start_ids,
                            labels=pack_labels(raw, num_labels),
                            metric=base.metric)

    entries = [[q % num_labels] if q % 2 == 0
               else [(q + j) % num_labels for j in range(4)]
               for q in range(nq)]
    modes = ["and" if q % 2 == 0 else "or" for q in range(nq)]
    filt = make_label_filter(entries, num_labels, modes=modes)

    # post-filtered oracle: drop non-matching ids from the exact ground truth
    sets = [set(x) for x in raw]
    gt_ids = np.asarray(gt[0])
    gt_f_ids = np.full_like(gt_ids, INVALID_ID)
    gt_f_counts = np.zeros(nq, np.int64)
    for q in range(nq):
        pred = set(entries[q])
        keep = [int(i) for i in gt_ids[q][gt_ids[q] != INVALID_ID]
                if (pred <= sets[int(i)] if modes[q] == "and"
                    else bool(pred & sets[int(i)]))]
        gt_f_ids[q, :len(keep)] = keep
        gt_f_counts[q] = len(keep)

    qps_u, res_u = run_range(eng, qs, r, cfg)
    ap_u = float(average_precision(gt_ids, np.asarray(gt[2]),
                                   np.asarray(res_u.ids),
                                   np.asarray(res_u.count)))
    qps_f, res_f = run_range(eng, qs, r, cfg, filter=filt)
    ap_f = float(average_precision(gt_f_ids, gt_f_counts,
                                   np.asarray(res_f.ids),
                                   np.asarray(res_f.count)))

    # selectivity actually realized (posting-list fraction per lane kind)
    match = np.asarray(label_match_counts(eng.labels, filt)) / pts.shape[0]
    sel_frac = float(match[::2].mean())
    broad_frac = float(match[1::2].mean())

    # fallback speedup: selective lanes only, threshold above their
    # selectivity (x1.5 headroom) so every lane takes the brute scan
    sel = np.arange(0, nq, 2)
    qs_sel = qs[sel]
    filt_sel = make_label_filter([entries[i] for i in sel], num_labels,
                                 modes="and")
    thr = min(0.999, float(match[::2].max()) * 1.5)
    qps_walk, _ = run_range(eng, qs_sel, r, cfg, filter=filt_sel)
    qps_fb, res_fb = run_range(
        eng, qs_sel, r, dc.replace(cfg, filter_threshold=thr),
        filter=filt_sel)
    n_fallback = int((np.asarray(res_fb.n_visited) == 0).sum())

    return dict(
        num_labels=num_labels,
        selective_frac=round(sel_frac, 4), broad_frac=round(broad_frac, 4),
        qps_unfiltered=round(qps_u, 2), qps_filtered=round(qps_f, 2),
        ap_unfiltered=round(ap_u, 4), ap_filtered=round(ap_f, 4),
        ap_gap=round(ap_u - ap_f, 5),
        mean_matches_postfilter=round(float(gt_f_counts.mean()), 1),
        fallback_threshold=round(thr, 4),
        n_fallback_lanes=n_fallback, n_selective_lanes=int(sel.shape[0]),
        qps_selective_walk=round(qps_walk, 2),
        qps_selective_fallback=round(qps_fb, 2),
        fallback_speedup=round(qps_fb / max(qps_walk, 1e-9), 3),
    )


def _degraded_row(n: int) -> dict:
    """Fault-tolerant serving smoke: shard loss + deadline partials.

    Shard loss: 4-shard corpus through ``fault_tolerant_sharded_search``
    healthy, then with shard 1 permanently down (every attempt times out).
    The degraded merge is exact over surviving shards, so its AP tracks
    the surviving corpus fraction — gated at MIN_DEGRADED_AP_FRAC of the
    healthy AP, with the coverage/shards_ok/code annotations pinned.

    Deadline: the continuous server re-serves the smoke workload with each
    request's ``deadline_s`` set to the healthy run's p50 latency. Lanes
    that complete carry full answers (certified complete ⇒ bitwise equal
    to the no-deadline run), so AP restricted to them must hold
    MIN_DEADLINE_COMPLETE_AP_FRAC of the healthy run's AP over the same
    lanes; expired lanes come back as certified partials whose coverage is
    recorded, not gated (how many expire is CI wall-clock dependent, what
    each one contains is not)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        BuildConfig, RangeConfig, SearchConfig, average_precision,
        build_vamana, exact_range_search,
    )
    from repro.core.graph import medoid
    from repro.dist.sharded_engine import build_sharded
    from repro.fault import (
        FaultInjector, RetryPolicy, fault_tolerant_sharded_search,
    )
    from repro.serve import RangeServer, Request, ServerConfig
    from repro.utils import INVALID_ID

    from .common import get_dataset, get_engine

    ds, pts, qs, _, prof, _ = get_dataset("bigann-like", n)
    qs = qs[:128]
    qs_np = np.asarray(qs)
    nq = qs_np.shape[0]
    mean_counts = np.asarray(prof.counts).mean(axis=0)
    r = float(prof.radii[int(np.argmin(np.abs(mean_counts - 128.0)))])
    gt = exact_range_search(pts, qs, r, ds.metric)
    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32, visit_cap=128,
                                          metric=ds.metric, expand_width=4),
                      mode="greedy", result_cap=1024)

    # -- shard loss: healthy vs 1-of-4 permanently down ----------------------
    # per-shard Vamana (not kNN): the smoke corpus is clustered, and a kNN
    # graph over well-separated clusters is disconnected — a medoid entry
    # point would strand most of the shard and crater the healthy baseline
    bcfg = BuildConfig(max_degree=24, beam=48, insert_batch=256,
                       two_pass=True, metric=ds.metric)
    corpus = build_sharded(np.asarray(pts), 4,
                           lambda p: (build_vamana(jnp.asarray(p), bcfg),
                                      medoid(p)[None]))

    def ap_of_res(res):
        return float(average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                                       np.asarray(res.ids),
                                       np.asarray(res.count)))

    fast_retry = RetryPolicy(max_attempts=2, backoff_s=0.0)
    healthy = fault_tolerant_sharded_search(corpus=corpus, queries=qs, r=r,
                                            cfg=cfg, retry=fast_retry)
    lost = fault_tolerant_sharded_search(
        corpus=corpus, queries=qs, r=r, cfg=cfg,
        injector=FaultInjector(seed=0, down_shards=(1,)), retry=fast_retry)
    ap_h, ap_d = ap_of_res(healthy.result), ap_of_res(lost.result)
    shard_loss = dict(
        shards_total=lost.shards_total, down_shards=[1],
        ap_healthy=round(ap_h, 4), ap_degraded=round(ap_d, 4),
        ap_frac=round(ap_d / max(ap_h, 1e-9), 4),
        coverage=round(lost.coverage, 4), shards_ok=lost.shards_ok,
        code=lost.code, attempts=np.asarray(lost.attempts).tolist(),
    )

    # -- deadline at the healthy run's p50 latency ---------------------------
    eng = get_engine("bigann-like", n)
    scfg = ServerConfig(max_batch=16, continuous=True, lanes=16,
                        slice_rounds=8)

    def drive(deadline_s=None):
        srv = RangeServer(eng, cfg, scfg)
        for i in range(nq):
            srv.submit(Request(req_id=i, query=qs_np[i], radius=r,
                               deadline_s=deadline_s))
        return srv.run_until_drained()

    drive()                 # warmup: compile phase1/pool/retire programs
    resp_h = drive()        # healthy pass: measures the p50 the deadline pins
    lat = sorted(rp.latency_s for rp in resp_h)
    p50 = lat[len(lat) // 2]
    resp_d = drive(deadline_s=p50)
    complete = [rp for rp in resp_d if rp.op == "range" and rp.complete]
    partial = [rp for rp in resp_d if not rp.complete]
    cap = cfg.result_cap

    def pack(resps, mask):
        ids = np.full((nq, cap), INVALID_ID, np.int64)
        counts = np.zeros(nq, np.int64)
        for rp in resps:
            if not mask[rp.req_id]:
                continue
            k = min(len(rp.ids), cap)
            ids[rp.req_id, :k] = np.asarray(rp.ids[:k])
            counts[rp.req_id] = k
        return (float(average_precision(np.asarray(gt[0])[mask],
                                        np.asarray(gt[2])[mask],
                                        ids[mask], counts[mask]))
                if mask.any() else None)

    mask = np.zeros(nq, bool)
    for rp in complete:
        mask[rp.req_id] = True
    # complete lanes are bitwise-identical to the no-deadline run, so AP
    # over them must match the healthy run's AP over the SAME lanes — the
    # gate is that ratio, immune to which lanes the wall clock let finish
    ap_complete = pack(resp_d, mask)
    ap_healthy_lanes = pack(resp_h, mask)
    ap_frac = (None if ap_complete is None
               else round(ap_complete / max(ap_healthy_lanes, 1e-9), 4))
    deadline = dict(
        n_queries=nq, deadline_s=round(p50, 5),
        n_complete=len(complete), n_partial=len(partial),
        ap_complete_lanes=(None if ap_complete is None
                           else round(ap_complete, 4)),
        ap_healthy_same_lanes=(None if ap_healthy_lanes is None
                               else round(ap_healthy_lanes, 4)),
        ap_frac=ap_frac,
        mean_partial_coverage=(
            round(float(np.mean([rp.coverage for rp in partial])), 4)
            if partial else None),
        note="ap_frac (complete lanes vs the healthy run on the same "
             "lanes) is the gated claim (deterministic per lane); the "
             "complete/partial split depends on CI wall clock and is "
             "recorded for trajectory tracking only",
    )
    return dict(n=n, radius=r, shard_loss=shard_loss, deadline=deadline)


def _replicated_row(n: int) -> dict:
    """Replicated-serving smoke: R=2 keeps the answer whole where R=1
    degrades, and hedging hides slow primaries at zero answer cost.

    Replica loss: the same 4-shard corpus as the degraded row, replicated
    2-way, searched with one replica of EVERY shard scripted down
    (alternating, so both replica slots are exercised). The surviving
    replica of each shard is bitwise-identical — replica choice is
    unobservable — so the gate is structural, not statistical:
    ``coverage == 1.0``, results bitwise-equal to the healthy
    single-replica run, and the response annotated ``replica_lost``
    (redundancy degraded, answer not). PR 7's shard-loss row stays as the
    R=1 baseline: same loss pattern without replication costs 25% of the
    corpus (coverage 0.75).

    Hedging: a fresh fleet with every shard's primary scripted ``slow``
    and a zero hedge delay — each shard fires exactly one hedge, the
    secondary wins, and the merged result is again bitwise-identical
    (zero AP gap by construction, asserted bitwise rather than via a
    float floor)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        BuildConfig, RangeConfig, SearchConfig, average_precision,
        build_vamana, exact_range_search,
    )
    from repro.core.graph import medoid
    from repro.dist.sharded_engine import build_sharded
    from repro.fault import (
        FaultInjector, HedgePolicy, ReplicaFleet, ReplicatedCorpus,
        RetryPolicy, fault_tolerant_sharded_search,
    )

    from .common import get_dataset

    ds, pts, qs, _, prof, _ = get_dataset("bigann-like", n)
    qs = qs[:128]
    mean_counts = np.asarray(prof.counts).mean(axis=0)
    r = float(prof.radii[int(np.argmin(np.abs(mean_counts - 128.0)))])
    gt = exact_range_search(pts, qs, r, ds.metric)
    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32, visit_cap=128,
                                          metric=ds.metric, expand_width=4),
                      mode="greedy", result_cap=1024)
    bcfg = BuildConfig(max_degree=24, beam=48, insert_batch=256,
                       two_pass=True, metric=ds.metric)
    corpus = build_sharded(np.asarray(pts), 4,
                           lambda p: (build_vamana(jnp.asarray(p), bcfg),
                                      medoid(p)[None]))

    def ap_of(res):
        return float(average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                                       np.asarray(res.ids),
                                       np.asarray(res.count)))

    def bitwise(a, b):
        return bool(np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
                    and np.array_equal(np.asarray(a.dists),
                                       np.asarray(b.dists))
                    and np.array_equal(np.asarray(a.count),
                                       np.asarray(b.count)))

    fast_retry = RetryPolicy(max_attempts=2, backoff_s=0.0)
    healthy = fault_tolerant_sharded_search(corpus=corpus, queries=qs, r=r,
                                            cfg=cfg, retry=fast_retry)
    ap_h = ap_of(healthy.result)
    rep = ReplicatedCorpus.replicate(corpus, 2)

    # -- one replica of every shard down: R=2 keeps coverage at 1.0 ----------
    down = ((0, 0), (1, 1), (2, 0), (3, 1))
    fleet = ReplicaFleet(rep)
    lost = fault_tolerant_sharded_search(
        fleet=fleet, queries=qs, r=r, cfg=cfg,
        injector=FaultInjector(seed=0, down_replicas=down), retry=fast_retry)
    ap_l = ap_of(lost.result)
    replica_loss = dict(
        down_replicas=[list(p) for p in down],
        coverage=round(lost.coverage, 4), shards_ok=lost.shards_ok,
        code=lost.code, bitwise_identical=bitwise(lost.result, healthy.result),
        replicas_ok=lost.replicas_ok, replicas_total=lost.replicas_total,
        ap_healthy=round(ap_h, 4), ap_replicated=round(ap_l, 4),
        served_by=np.asarray(lost.served_by).tolist(),
    )

    # -- scripted-slow primaries + zero hedge delay: hedges win, zero gap ----
    fleet_h = ReplicaFleet(rep)
    hedged = fault_tolerant_sharded_search(
        fleet=fleet_h, queries=qs, r=r, cfg=cfg,
        injector=FaultInjector(
            seed=0, script={(s, 0, 0): "slow" for s in range(4)}),
        retry=fast_retry, hedge=HedgePolicy(delay_s=0.0))
    ap_hg = ap_of(hedged.result)
    hedged_row = dict(
        hedges_fired=int(fleet_h.stats["hedges_fired"]),
        hedge_wins=int(fleet_h.stats["hedge_wins"]),
        bitwise_identical=bitwise(hedged.result, healthy.result),
        ap_gap=round(ap_h - ap_hg, 6), code=hedged.code,
        served_by=np.asarray(hedged.served_by).tolist(),
    )

    return dict(n=n, radius=r, replicas=2,
                baseline_r1_coverage=0.75,
                replica_loss=replica_loss, hedged=hedged_row)


def _tail_latency_row(n: int) -> dict:
    """Continuous batching vs lockstep on a mixed point+heavy workload.

    128 bigann-like queries: 120 point-like (~4 matches) and 8 dense-region
    (~512 matches), one heavy lane leading each micro-batch of 16 — the
    adversarial case for lockstep execution, where every batch's point
    queries wait for the straggler's greedy phase. Both servers run the
    identical engine/config/workload seconds apart; a throwaway pass per
    mode warms the jit caches so the timed pass measures steady-state
    serving, not compilation. Percentiles here are EXACT (np.percentile
    over the retained per-response latencies) — the gate must not inherit
    the serving histogram's bucket quantization; the servers' log-bucket
    summaries are recorded alongside for the dashboard shape."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        RangeConfig, SearchConfig, average_precision, exact_range_search,
    )
    from repro.serve import RangeServer, Request, ServerConfig
    from repro.utils import INVALID_ID

    from .common import get_dataset, get_engine

    ds, pts, qs, _, prof, _ = get_dataset("bigann-like", n)
    qs_np = np.asarray(qs[:128])
    nq = qs_np.shape[0]
    mean_counts = np.asarray(prof.counts).mean(axis=0)
    r_point = float(prof.radii[int(np.argmin(np.abs(mean_counts - 4.0)))])
    r_heavy = float(prof.radii[int(np.argmin(np.abs(mean_counts - 512.0)))])
    radii = np.full(nq, r_point, np.float32)
    radii[::16] = r_heavy
    point = radii == r_point
    gt = exact_range_search(pts, jnp.asarray(qs_np), jnp.asarray(radii),
                            ds.metric)
    eng = get_engine("bigann-like", n)
    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32, visit_cap=128,
                                          metric=ds.metric, expand_width=4),
                      mode="greedy", result_cap=1024)

    def drive(scfg):
        srv = RangeServer(eng, cfg, scfg)
        for i in range(nq):
            srv.submit(Request(req_id=i, query=qs_np[i],
                               radius=float(radii[i])))
        return srv, srv.run_until_drained()

    def score(srv, resp):
        cap = cfg.result_cap
        ids = np.full((nq, cap), INVALID_ID, np.int64)
        counts = np.zeros(nq, np.int64)
        lat = np.zeros(nq)
        for rp in resp:
            k = min(len(rp.ids), cap)
            ids[rp.req_id, :k] = np.asarray(rp.ids[:k])
            counts[rp.req_id] = k
            lat[rp.req_id] = rp.latency_s
        ap = average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                               ids, counts)
        return dict(
            ap=round(float(ap), 4),
            point_p50_ms=round(float(np.percentile(lat[point], 50)) * 1e3, 2),
            point_p95_ms=round(float(np.percentile(lat[point], 95)) * 1e3, 2),
            point_p99_ms=round(float(np.percentile(lat[point], 99)) * 1e3, 2),
            heavy_p99_ms=round(float(np.percentile(lat[~point], 99)) * 1e3, 2),
            histograms=srv.latency_summary(),
        )

    lock_cfg = ServerConfig(max_batch=16)
    cont_cfg = ServerConfig(max_batch=16, continuous=True, lanes=16,
                            slice_rounds=8)
    drive(lock_cfg)                      # warmup: compile the lockstep path
    drive(cont_cfg)                      # warmup: phase1/pool/retire programs
    srv_l, resp_l = drive(lock_cfg)
    srv_c, resp_c = drive(cont_cfg)
    lock = score(srv_l, resp_l)
    cont = score(srv_c, resp_c)
    cont["pool"] = {k: srv_c.stats[k] for k in
                    ("pool_admitted", "pool_retired", "pool_ticks",
                     "pool_rotations", "pool_oneshot")}
    return dict(
        n=n, n_queries=nq, n_point=int(point.sum()),
        radius_point=r_point, radius_heavy=r_heavy,
        lockstep=lock, continuous=cont,
        point_p99_ratio=round(cont["point_p99_ms"]
                              / max(lock["point_p99_ms"], 1e-9), 4),
        ap_gap=round(abs(lock["ap"] - cont["ap"]), 5),
        note="point_p99_ratio (continuous/lockstep, same box seconds apart) "
             "and ap_gap are the gated claims; heavy-lane p99 rises in "
             "continuous mode by design (stragglers trade their own "
             "latency for everyone else's tail)",
    )


def _churn_row(n: int) -> dict:
    """10% churn against the live index, scored vs a fresh static rebuild.

    Starting from the cached static engine's graph: insert n/10 fresh
    vectors, tombstone n/10 of the originals, consolidate, then compare AP
    on the exact live-set oracle against an engine REBUILT from scratch on
    the same live set — the gap is what streaming mutation costs vs batch
    reindexing (gated at MAX_CHURN_AP_GAP). Mutation rates and query QPS
    under tombstones are recorded alongside."""
    import time as _time

    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        RangeConfig, RangeSearchEngine, SearchConfig, average_precision,
        exact_range_search,
    )
    from repro.live import LiveConfig, LiveIndex
    from repro.utils import INVALID_ID, block_until_ready

    from .common import get_dataset, run_range

    ds, pts, qs, _, prof, _ = get_dataset("bigann-like", n)
    qs = qs[:128]
    mean_counts = np.asarray(prof.counts).mean(axis=0)
    r = float(prof.radii[int(np.argmin(np.abs(mean_counts - 128.0)))])
    k = max(n // 10, 1)

    # two-pass builds on BOTH sides: the single-pass batch build leaves
    # ~10% zero-in-degree (unreachable) nodes, and which points end up
    # orphaned is a per-build roll — at ap ~0.87 that seed variance (~0.03)
    # swamps the ~0.01 churn effect this gate exists to measure. The second
    # α pass reattaches orphans (both graphs reach ap ~0.99), so the gap is
    # churn damage, not orphan luck.
    live = LiveIndex.create(pts, LiveConfig(capacity=n + k, insert_batch=128),
                            _churn_build_cfg(ds.metric), metric=ds.metric)
    rng = np.random.default_rng(0)
    fresh = (np.asarray(pts)[rng.integers(0, n, k)]
             + rng.standard_normal((k, pts.shape[1])).astype(np.float32)
             * 0.05 * np.asarray(pts).std())
    t0 = _time.perf_counter()
    live.insert(fresh)
    t_ins = _time.perf_counter() - t0
    t0 = _time.perf_counter()
    live.delete(rng.choice(n, k, replace=False))
    t_del = _time.perf_counter() - t0
    t0 = _time.perf_counter()
    live.consolidate()
    t_cons = _time.perf_counter() - t0

    # exact oracle on the live set; both contenders answer in ext-id space
    ext, vecs = live.live_vectors()
    gt = exact_range_search(jnp.asarray(vecs), qs, r, ds.metric)
    lut = np.full(live.next_ext_id + 1, INVALID_ID, np.int64)
    lut[ext] = np.arange(len(ext))
    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32, visit_cap=128,
                                          metric=ds.metric, expand_width=4),
                      mode="greedy", result_cap=1024)

    def live_qps():
        fn = lambda: live.range(qs, r, cfg=cfg)
        block_until_ready(fn().dists)
        ts = []
        res = None
        for _ in range(2):
            t0 = _time.perf_counter()
            res = fn()
            block_until_ready(res.dists)
            ts.append(_time.perf_counter() - t0)
        return qs.shape[0] / float(np.median(ts)), res

    qps_live, res_live = live_qps()
    ids_live = np.asarray(res_live.ids)
    rows_live = np.where(ids_live != INVALID_ID,
                         lut[np.minimum(ids_live, live.next_ext_id)],
                         np.int64(INVALID_ID))
    ap_live = average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                                rows_live, np.asarray(res_live.count))

    # fresh static rebuild on the same live set (row ids == oracle ids)
    t0 = _time.perf_counter()
    eng_fresh = RangeSearchEngine.build(jnp.asarray(vecs),
                                        _churn_build_cfg(ds.metric),
                                        metric=ds.metric)
    t_rebuild = _time.perf_counter() - t0
    qps_static, res_fresh = run_range(eng_fresh, qs, r, cfg)
    ap_rebuild = average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                                   np.asarray(res_fresh.ids),
                                   np.asarray(res_fresh.count))
    return dict(
        n=n, churn_frac=round(k / n, 3), radius=r,
        ap_live=round(ap_live, 4), ap_rebuild=round(ap_rebuild, 4),
        ap_gap=round(ap_rebuild - ap_live, 5),
        qps_live=round(qps_live, 2), qps_static=round(qps_static, 2),
        inserts_per_s=round(k / max(t_ins, 1e-9), 1),
        deletes_per_s=round(k / max(t_del, 1e-9), 1),
        consolidate_s=round(t_cons, 3),
        rebuild_s=round(t_rebuild, 3),
        epochs=live.epoch,
        note="ap_gap (live vs fresh rebuild on the identical live set) is "
             "the gated claim; mutation rates and the QPS pair are "
             "recorded for trajectory tracking, not gated (CI wall-clock "
             "noise)",
    )


def _churn_build_cfg(metric: str):
    """Build config shared by the churn row's initial live graph AND its
    fresh-rebuild contender (the comparison must hold everything but the
    mutation path fixed). two_pass: see the note in _churn_row."""
    from repro.core import BuildConfig
    return BuildConfig(max_degree=24, beam=48, insert_batch=512,
                       metric=metric, two_pass=True)


def _quantized_row(n: int) -> dict:
    """Int8-corpus two-pass vs f32 on the same graph: e2e QPS + AP gap +
    rerank-band rate, plus the bulk gather+distance hot-path ratio and the
    bytes-per-distance table (see the MIN_QUANTIZED_BYTES_REDUCTION note
    for why the byte cut, not a wall-clock ratio, is the gated claim)."""
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.roofline import corpus_bytes_per_distance
    from repro.core import (
        RangeConfig, RangeSearchEngine, SearchConfig, exact_range_search,
    )
    from repro.kernels import gatherdist_ref
    from repro.utils import block_until_ready

    from .common import ap_of, get_dataset, get_engine, run_range

    profile = "gist-like"
    ds, pts, qs, _, prof, _ = get_dataset(profile, n)
    qs = qs[:128]
    mean_counts = np.asarray(prof.counts).mean(axis=0)
    r = float(prof.radii[int(np.argmin(np.abs(mean_counts - 128.0)))])
    gt = exact_range_search(pts, qs, r, ds.metric)
    eng = get_engine(profile, n)
    # same graph and entry points; only the corpus storage differs
    eng_i8 = _dc.replace(
        RangeSearchEngine.from_graph(pts, eng.graph, metric=ds.metric,
                                     corpus_dtype="int8"),
        start_ids=eng.start_ids)
    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32, visit_cap=128,
                                          metric=ds.metric, expand_width=4),
                      mode="greedy", result_cap=2048)
    qps_f, res_f = run_range(eng, qs, r, cfg)
    qps_q, res_q = run_range(eng_i8, qs, r, cfg)
    ap_f, ap_q = ap_of(res_f, gt), ap_of(res_q, gt)

    # hot path: the in-loop bulk gather+distance op (tile shapes of the
    # fused expand: Q lanes x E*R candidates each), f32 rows vs int8
    # codes+metadata — the corpus-bytes roofline term itself
    t_tile = 128
    ids = jax.random.randint(jax.random.PRNGKey(0), (qs.shape[0], t_tile),
                             0, pts.shape[0], jnp.int32)
    f_f32 = jax.jit(lambda i, q: gatherdist_ref(pts, i, q, metric=ds.metric))
    f_i8 = jax.jit(lambda i, q: gatherdist_ref(eng_i8.points, i, q,
                                               metric=ds.metric))
    def wall(fn):
        block_until_ready(fn())
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))
    t_f = wall(lambda: f_f32(ids, qs))
    t_q = wall(lambda: f_i8(ids, qs))
    d = int(pts.shape[1])
    return dict(
        profile=profile, dim=d, radius=r,
        engine=dict(
            qps_f32=round(qps_f, 2), qps_int8=round(qps_q, 2),
            speedup=round(qps_q / max(qps_f, 1e-9), 3),
            ap_f32=round(ap_f, 4), ap_int8=round(ap_q, 4),
            ap_gap=round(ap_f - ap_q, 5),
            rerank_per_query=round(
                float(np.asarray(res_q.n_rerank).mean()), 1),
            mean_count=round(float(np.asarray(res_q.count).mean()), 1),
        ),
        hot_path=dict(
            tile=f"{qs.shape[0]}x{t_tile}x{d}",
            ms_f32=round(t_f * 1e3, 3), ms_int8=round(t_q * 1e3, 3),
            speedup=round(t_f / max(t_q, 1e-9), 3),
            bytes_per_dist_f32=corpus_bytes_per_distance(d, "float32"),
            bytes_per_dist_int8=corpus_bytes_per_distance(d, "int8"),
            note="wall ratios are cache-regime/noise dependent on CPU CI "
                 "(measured swing ~0.7-1.8x run to run) and are recorded, "
                 "not gated; the gated perf claim is the bytes/distance "
                 "roofline cut, which the Pallas int8 kernels realize on "
                 "TPU HBM",
        ),
    )


def _tiered_row(n: int) -> dict:
    """Tiered corpus vs resident int8 on the same graph: the device-bytes
    cut the tier exists for, proven at BITWISE result identity (see the
    MIN_TIER_DEVICE_BYTES_REDUCTION note). Same gist-like profile and
    config as _quantized_row so the f32 -> int8 -> tiered progression
    reads off one table."""
    import dataclasses as _dc

    import numpy as np

    from repro.core import (
        RangeConfig, RangeSearchEngine, SearchConfig, exact_range_search,
    )
    from repro.tier import tiered_corpus

    from .common import ap_of, get_dataset, get_engine, run_range

    profile = "gist-like"
    ds, pts, qs, _, prof, _ = get_dataset(profile, n)
    qs = qs[:128]
    mean_counts = np.asarray(prof.counts).mean(axis=0)
    r = float(prof.radii[int(np.argmin(np.abs(mean_counts - 128.0)))])
    gt = exact_range_search(pts, qs, r, ds.metric)
    eng = get_engine(profile, n)
    # resident int8 reference: same graph/entries, raw rows on device
    eng_i8 = _dc.replace(
        RangeSearchEngine.from_graph(pts, eng.graph, metric=ds.metric,
                                     corpus_dtype="int8"),
        start_ids=eng.start_ids)
    # tiered contender: identical codes (split from the SAME QuantizedCorpus,
    # raw rows move to the host store). Cache default n/32 rows (~3% of raw
    # bytes); the CI memcap env may shrink it further — parity must survive.
    cache_rows = int(os.environ.get("REPRO_TIER_CACHE_ROWS",
                                    max(1, n // 32)))
    tier = tiered_corpus(eng_i8.points, cache_rows=cache_rows)
    eng_tier = _dc.replace(eng_i8, points=tier)

    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32, visit_cap=128,
                                          metric=ds.metric, expand_width=4),
                      mode="greedy", result_cap=2048)
    qps_i8, res_i8 = run_range(eng_i8, qs, r, cfg)
    qps_t, res_t = run_range(eng_tier, qs, r, cfg)
    bitwise = bool(
        np.array_equal(np.asarray(res_t.ids), np.asarray(res_i8.ids)) and
        np.array_equal(np.asarray(res_t.dists), np.asarray(res_i8.dists)) and
        np.array_equal(np.asarray(res_t.count), np.asarray(res_i8.count)))

    d = int(pts.shape[1])
    budget = tier.budget()
    f32_resident = 4 * d * n  # the raw rows a resident f32 corpus parks in HBM
    reduction = f32_resident / max(1, budget.device_total)
    cache_frac = budget.device["row_cache"] / max(1, budget.host["row_store"])
    return dict(
        profile=profile, dim=d, radius=r,
        qps_int8=round(qps_i8, 2), qps_tiered=round(qps_t, 2),
        qps_ratio=round(qps_t / max(qps_i8, 1e-9), 3),
        ap_tiered=round(ap_of(res_t, gt), 4),
        bitwise_identical=bitwise,
        rerank_per_query=round(float(np.asarray(res_t.n_rerank).mean()), 1),
        memory=dict(
            **budget.as_dict(),
            device_bytes_per_vector=round(budget.device_bytes_per_vector(n), 1),
            f32_resident_bytes=f32_resident,
            device_bytes_reduction_vs_f32=round(reduction, 3),
            cache_rows=int(tier.cache.capacity),
            cache_frac_of_raw=round(cache_frac, 4),
        ),
        fetch=tier.counters.as_dict(),
        note="bitwise identity to resident int8 and the measured device-"
             "bytes cut are the gated claims; QPS ratio and fetch telemetry "
             "(dedup ratio, cache hit rate) are recorded for trajectory "
             "tracking, not gated",
    )


def _heavy_tail_row(n: int) -> dict:
    """RECORDED, not gated: the radius methodology (core/radius.py) on a
    lognormal planted-cluster corpus whose match counts are far heavier-
    tailed than the quantile-matched profiles — most queries zero matches,
    a few queries matching entire giant clusters. Exercises sweep /
    select_radius / match_histogram end to end and records the Fig. 4
    bucket table; wall-clock-free and deterministic, kept ungated because
    it validates the *methodology's* behavior on an adversarial input, not
    a perf or quality floor of the engine."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.radius import (
        default_grid, match_histogram, select_radius, sweep,
    )

    from .common import make_heavy_tailed

    pts, qs = make_heavy_tailed(n, d=32, n_queries=128, seed=0)
    grid = default_grid(pts, qs, "l2", num=32)
    prof = sweep(jnp.asarray(pts), jnp.asarray(qs), grid, "l2")
    r, gi = select_radius(prof, target_zero_frac=0.85, robustness_weight=0.2)
    counts = np.asarray(prof.counts)[:, gi]
    nz = np.sort(counts[counts > 0])
    # tail mass: fraction of ALL matches held by the top 10% of queries —
    # ~1.0 for a true heavy tail, ~0.1 for a uniform workload
    k = max(1, counts.size // 10)
    tail_mass = float(np.sort(counts)[-k:].sum() / max(1, counts.sum()))
    return dict(
        n=n, dim=32, radius=float(r),
        zero_frac=round(float(prof.zero_frac[gi]), 4),
        histogram=match_histogram(counts),
        mean_count=round(float(counts.mean()), 1),
        max_count=int(counts.max()),
        median_nonzero=0 if nz.size == 0 else int(np.median(nz)),
        top10pct_match_mass=round(tail_mass, 4),
        note="recorded only — validates radius selection + Fig. 4 "
             "bucketing on a heavy-tailed workload",
    )


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--full", action="store_true", help="all 9 dataset profiles")
    p.add_argument("--scale", action="store_true", help="include Fig7 scaling")
    p.add_argument("--n", type=int, default=10_000)
    p.add_argument("--smoke", action="store_true",
                   help="tiny-corpus QPS/AP regression gate (CI)")
    p.add_argument("--min-qps", type=float, default=5.0)
    p.add_argument("--min-ap", type=float, default=0.6)
    args = p.parse_args(argv)
    quick = not args.full

    if args.smoke:
        return smoke(min(args.n, 4_000), args.min_qps, args.min_ap)

    from . import (
        early_stop_metrics, early_stop_qps, kernel_bench, match_distribution,
        qps_precision, radius_capture, time_breakdown, topk_compare,
    )

    t0 = time.time()
    print("== repro benchmarks (paper: Range Retrieval with Graph-Based "
          "Indices) ==")
    radius_capture.run(n=args.n, quick=quick)
    match_distribution.run(n=args.n, quick=quick)
    qps_precision.run(n=args.n, quick=quick)
    early_stop_metrics.run(n=args.n, quick=quick)
    early_stop_qps.run(n=args.n, quick=quick)
    time_breakdown.run(n=args.n)
    topk_compare.run(n=args.n)
    kernel_bench.run()
    if args.scale:
        qps_precision.run_scaling(n=max(args.n // 2, 4000))
    print(f"\n== done in {time.time() - t0:.0f}s ==")
    return 0


if __name__ == "__main__":
    sys.exit(main())
