"""Paper Figs. 6/7/16: QPS vs average precision for the three algorithms.

Sweeps the starting beam per algorithm and reports (beam, QPS, AP) points;
the Pareto frontier over beams is the paper's reported curve. ``--scale``
reruns one profile at 1x/3x/9x corpus size with a FIXED radius (Fig. 7's
densification study), where greedy's advantage over doubling must grow.
"""
from __future__ import annotations


import numpy as np

from repro.core import RangeConfig, SearchConfig
from .common import (
    ALL_PROFILES, QUICK_PROFILES, ap_of, get_dataset, get_engine,
    print_table, run_range,
)


def _cfgs(beam: int, metric: str):
    return {
        "beam": RangeConfig(search=SearchConfig(
            beam=beam, max_beam=beam, visit_cap=4 * beam, metric=metric),
            mode="beam", result_cap=2048),
        "doubling": RangeConfig(search=SearchConfig(
            beam=beam, max_beam=16 * beam, visit_cap=16 * beam, metric=metric),
            mode="doubling", result_cap=2048),
        "greedy": RangeConfig(search=SearchConfig(
            beam=beam, max_beam=beam, visit_cap=4 * beam, metric=metric),
            mode="greedy", result_cap=2048, frontier_rounds=4096),
    }


def run(n: int = 10_000, quick: bool = True, beams=(8, 16, 32, 64)):
    profiles = QUICK_PROFILES if quick else ALL_PROFILES
    rows = []
    for prof_name in profiles:
        ds, pts, qs, r, _, gt = get_dataset(prof_name, n)
        eng = get_engine(prof_name, n)
        for beam in beams:
            for mode, cfg in _cfgs(beam, ds.metric).items():
                qps, res = run_range(eng, qs, r, cfg)
                rows.append([prof_name, mode, beam, qps, ap_of(res, gt)])
    print_table("Fig6: QPS vs AP (beam sweep x 3 algorithms)",
                ["profile", "mode", "beam", "qps", "ap"], rows)

    # headline: best QPS at AP >= 0.9 per mode (speedup over beam baseline)
    summary = []
    for prof_name in profiles:
        per_mode = {}
        for p, m, b, q, a in rows:
            if p == prof_name and a >= 0.85:
                per_mode[m] = max(per_mode.get(m, 0.0), q)
        if "beam" in per_mode:
            base = per_mode["beam"]
            summary.append([prof_name] + [
                f"{per_mode.get(m, float('nan')) / base:.2f}x"
                for m in ("beam", "doubling", "greedy")])
        elif per_mode:
            summary.append([prof_name, "beam<0.85AP"] + [
                f"{per_mode.get(m, 0):.0f}qps" for m in ("doubling", "greedy")])
    print_table("Fig6 summary: speedup over beam baseline at AP>=0.85",
                ["profile", "beam", "doubling", "greedy"], summary)
    return rows


def run_scaling(profile: str = "ssnpp-like", n: int = 6_000, beams=(16, 32)):
    """Fig. 7: fixed radius, growing corpus -> greedy overtakes doubling."""
    import jax.numpy as jnp
    from repro.core import exact_range_search
    ds1, pts1, qs, r, _, _ = get_dataset(profile, n)
    rows = []
    for scale in (1, 3, 9):
        ds = get_dataset(profile, scale * n)[0]
        pts = jnp.asarray(ds.points)
        gt = exact_range_search(pts, qs, r, ds.metric)
        eng = get_engine(profile, scale * n)
        mean_matches = float(np.asarray(gt[2]).mean())
        for beam in beams:
            for mode, cfg in _cfgs(beam, ds.metric).items():
                if mode == "beam":
                    continue
                qps, res = run_range(eng, qs, r, cfg)
                rows.append([profile, scale, f"{mean_matches:.1f}", mode,
                             beam, qps, ap_of(res, gt)])
    print_table("Fig7: size scaling at fixed radius",
                ["profile", "scale", "mean_matches", "mode", "beam", "qps",
                 "ap"], rows)
    return rows


if __name__ == "__main__":
    run()
    run_scaling()
