"""End-to-end serving driver (the paper's kind of system is a retrieval
service): build an index, start the RangeServer, drive batched requests
through admission -> micro-batching -> two-phase search -> responses.

  PYTHONPATH=src python examples/serve_range.py [--n 20000 --queries 512]
  PYTHONPATH=src python examples/serve_range.py --mixed-radius
  PYTHONPATH=src python examples/serve_range.py --churn 0.1

``--mixed-radius`` submits requests whose radii span the corpus's match
distribution — the server micro-batches them together and answers each
request at its own radius (the paper's radius-heterogeneous traffic).

``--churn 0.1`` demos the LIVE engine (repro.live): insert and delete
requests for 10% of the corpus ride the same admission queue as the query
traffic; the server coalesces each micro-batch's mutations, consolidates
when the tombstone fraction crosses the threshold, and answers queries
against consistent epoch snapshots. AP is scored against the exact oracle
on the final live set.

This is a thin CLI over repro.launch.serve; see that module for the knobs.
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
