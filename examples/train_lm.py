"""End-to-end LM training driver with checkpoint/restart.

  PYTHONPATH=src python examples/train_lm.py --steps 200          # ~10M smoke
  PYTHONPATH=src python examples/train_lm.py --full-100m --steps 300

``--full-100m`` instantiates a ~100M-param model (d_model=768, 12 layers,
32k vocab) — the brief's end-to-end scale for accelerator runs; the default
is a CPU-sized model of the same family. Interrupting with Ctrl-C
checkpoints; rerunning with --resume continues.
"""
import argparse
import functools
import sys

import jax
import jax.numpy as jnp

from repro.data.lm import LMDataConfig, lm_batches
from repro.models import TransformerConfig, init_transformer, loss_fn
from repro.layers.common import param_count
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--full-100m", action="store_true")
    p.add_argument("--resume", action="store_true")
    p.add_argument("--ckpt-dir", default="/tmp/repro_lm_example")
    args = p.parse_args(argv)

    if args.full_100m:
        cfg = TransformerConfig(name="lm-100m", n_layers=12, d_model=768,
                                n_heads=12, n_kv=4, d_head=64, d_ff=2048,
                                vocab=32_000, qk_norm=True,
                                dtype=jnp.bfloat16, remat=True)
    else:
        cfg = TransformerConfig(name="lm-smoke", n_layers=4, d_model=128,
                                n_heads=4, n_kv=2, d_head=32, d_ff=512,
                                vocab=2_000, dtype=jnp.float32, remat=False,
                                loss_chunk=64)
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    print(f"[train_lm] {cfg.name}: {param_count(params) / 1e6:.1f}M params")

    tr = Trainer(functools.partial(loss_fn, cfg=cfg), params,
                 AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
                 TrainerConfig(total_steps=args.steps, ckpt_every=50,
                               log_every=10, ckpt_dir=args.ckpt_dir,
                               metrics_path=f"{args.ckpt_dir}/metrics.jsonl"))
    start = 0
    if args.resume and tr.maybe_restore():
        start = tr.step
        print(f"[train_lm] resumed at step {start}")
    dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq_len, batch=args.batch)
    out = tr.fit(lm_batches(dcfg, start_step=start), verbose=True)
    print(f"[train_lm] finished at step {out['final_step']}; "
          f"loss {out['history'][0]['loss']:.3f} -> "
          f"{out['history'][-1]['loss']:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
