"""Quickstart: the paper's pipeline end to end on a synthetic corpus.

  PYTHONPATH=src python examples/quickstart.py

1. generate a BIGANN-like corpus (low intrinsic dim, Pareto match sizes);
2. select a range radius with the paper's Sec.-3 sweep;
3. build a Vamana graph index;
4. answer the same query batch with the three algorithms
   (beam baseline / doubling / greedy) +- early stopping;
5. report QPS and average precision against the exact oracle.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (
    ES_D_VISITED, BuildConfig, RangeConfig, RangeSearchEngine, SearchConfig,
    average_precision, exact_range_search,
)
from repro.core.radius import default_grid, select_radius, sweep
from repro.data.synthetic import make_corpus
from repro.utils import block_until_ready


def main():
    print("1) corpus")
    ds = make_corpus("bigann-like", n=20_000, n_queries=256, seed=0)
    pts, qs = jnp.asarray(ds.points), jnp.asarray(ds.queries)

    print("2) radius selection (paper Sec. 3)")
    prof = sweep(pts, qs, default_grid(ds.points, ds.queries, ds.metric, 32),
                 ds.metric)
    r, gi = select_radius(prof, robustness_weight=0.1)
    gt = exact_range_search(pts, qs, r, ds.metric)
    counts = np.asarray(gt[2])
    print(f"   radius={r:.4g}: {int((counts == 0).sum())}/256 queries have "
          f"zero results, max={counts.max()}")

    print("3) Vamana build")
    t0 = time.perf_counter()
    eng = RangeSearchEngine.build(
        pts, BuildConfig(max_degree=32, beam=64, metric=ds.metric),
        metric=ds.metric)
    print(f"   built in {time.perf_counter() - t0:.1f}s: {eng.stats()}")

    print("4) three range algorithms (paper Sec. 4)")
    variants = {
        "beam (baseline)": (RangeConfig(search=SearchConfig(
            beam=64, max_beam=64, visit_cap=256, metric=ds.metric),
            mode="beam", result_cap=2048), None),
        "doubling": (RangeConfig(search=SearchConfig(
            beam=16, max_beam=256, visit_cap=512, metric=ds.metric),
            mode="doubling", result_cap=2048), None),
        "greedy": (RangeConfig(search=SearchConfig(
            beam=16, max_beam=16, visit_cap=64, metric=ds.metric),
            mode="greedy", result_cap=2048), None),
        "greedy + early-stop": (RangeConfig(search=SearchConfig(
            beam=16, max_beam=16, visit_cap=64, metric=ds.metric,
            es_metric=ES_D_VISITED, es_visit_limit=10),
            mode="greedy", result_cap=2048), 1.5 * r),
    }
    for name, (cfg, esr) in variants.items():
        block_until_ready(eng.range(qs, r, cfg=cfg, es_radius=esr))  # warmup
        t0 = time.perf_counter()
        res = eng.range(qs, r, cfg=cfg, es_radius=esr)
        block_until_ready(res)
        dt = time.perf_counter() - t0
        ap = average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                               np.asarray(res.ids), np.asarray(res.count))
        print(f"   {name:22s} QPS={256 / dt:8.0f}  AP={ap:.4f}  "
              f"mean_visited={float(np.asarray(res.n_visited).mean()):5.1f}  "
              f"es_stopped={int(np.asarray(res.es_stopped).sum())}")


if __name__ == "__main__":
    main()
