"""The paper's technique composed with an assigned architecture: train a
(reduced) two-tower retrieval model, index its item embeddings with the
range engine, and serve retrieval both ways:

  brute force  — the rangescan kernel shape (exact, O(N) per query);
  graph engine — the paper's algorithms (approximate, sub-linear).

  PYTHONPATH=src python examples/two_tower_range.py
"""
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import (
    BuildConfig, RangeConfig, RangeSearchEngine, SearchConfig,
    average_precision, exact_range_search,
)
from repro.data.recsys import RecsysDataConfig, recsys_batches
from repro.kernels import rangescan
from repro.models.recsys import embed_items, init_recsys, recsys_loss, tower
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig
from repro.utils import block_until_ready


def main():
    arch = get_arch("two-tower-retrieval")
    cfg = arch.reduced()
    print(f"1) train reduced two-tower ({cfg.n_sparse}+{cfg.n_sparse_item} "
          f"fields, d_out={cfg.d_out}) for 60 steps")
    dcfg = RecsysDataConfig(n_sparse=cfg.n_sparse, vocab=cfg.vocab, batch=256,
                            two_tower=True, n_sparse_item=cfg.n_sparse_item)
    tr = Trainer(functools.partial(recsys_loss, cfg=cfg),
                 init_recsys(jax.random.PRNGKey(0), cfg),
                 AdamWConfig(lr=3e-3, warmup_steps=5, schedule="constant"),
                 TrainerConfig(total_steps=60, ckpt_every=1000, log_every=20,
                               ckpt_dir="/tmp/tt_example"))
    out = tr.fit(recsys_batches(dcfg), verbose=True)
    params = tr.params

    print("2) embed an item corpus with the item tower")
    rng = np.random.default_rng(1)
    n_items = 20_000
    item_sparse = jnp.asarray(
        rng.integers(0, cfg.vocab, (n_items, cfg.n_sparse_item)), jnp.int32)
    item_emb = embed_items(params, item_sparse, cfg)

    print("3) index item embeddings with the range engine")
    eng = RangeSearchEngine.build(
        item_emb, BuildConfig(max_degree=24, beam=48, metric="ip"),
        metric="ip")

    print("4) serve queries: brute force (rangescan) vs graph engine")
    user_sparse = jnp.asarray(
        rng.integers(0, cfg.vocab, (128, cfg.n_sparse)), jnp.int32)
    q_emb = tower(params["user"], user_sparse, cfg, len(cfg.mlp_dims) + 1)
    r = -0.85  # dot >= 0.85 counts as a retrieval match
    gt = exact_range_search(item_emb, q_emb, r, "ip")
    print(f"   ground truth: mean {float(np.asarray(gt[2]).mean()):.1f} "
          f"matches/query")

    # brute force via the rangescan kernel (XLA path on CPU)
    t0 = time.perf_counter()
    ids_bf, d_bf, counts_bf = rangescan(q_emb, item_emb, jnp.float32(r),
                                        k=256, metric="ip", use_pallas=False)
    block_until_ready(counts_bf)
    t_bf = time.perf_counter() - t0
    ap_bf = average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                              np.asarray(ids_bf), np.asarray(counts_bf))
    print(f"   brute force : {128 / t_bf:7.0f} QPS  AP={ap_bf:.4f}")

    cfg_r = RangeConfig(search=SearchConfig(beam=32, max_beam=32,
                                            visit_cap=128, metric="ip"),
                        mode="greedy", result_cap=512)
    block_until_ready(eng.range(q_emb, r, cfg=cfg_r))
    t0 = time.perf_counter()
    res = eng.range(q_emb, r, cfg=cfg_r)
    block_until_ready(res)
    t_g = time.perf_counter() - t0
    ap_g = average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                             np.asarray(res.ids), np.asarray(res.count))
    print(f"   graph engine: {128 / t_g:7.0f} QPS  AP={ap_g:.4f}  "
          f"(mean distance comps "
          f"{float(np.asarray(res.n_dist).mean()):.0f} vs {n_items} brute)")


if __name__ == "__main__":
    main()
