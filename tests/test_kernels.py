"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize_corpus, query_quant_err
from repro.kernels import (
    expand_frontier, expand_frontier_ref, flash_attention, flash_attention_ref,
    gatherdist, gatherdist_ref, rangescan, rangescan_ref,
)
from repro.utils import INVALID_ID


def _int8_tol(pts, qs, d_ref, metric):
    """Allowed kernel-vs-ref gap for int8 distances: the kernel quantizes
    the query (and subtracts its exact error), the XLA ref keeps it f32 —
    both certified lower bounds, differing by at most ~2 * err_q *
    (sqrt(d_max) + err_q) per candidate in the l2 sqrt domain, and
    ~2 * err_q * max||x|| for ip."""
    eq = float(np.max(np.asarray(query_quant_err(qs))))
    if metric == "ip":
        nmax = float(np.max(np.linalg.norm(np.asarray(pts), axis=1)))
        return 2.5 * eq * nmax + 1e-4
    dmax = float(np.nanmax(np.where(np.isfinite(d_ref), np.abs(d_ref), 0.0)))
    return 4.0 * eq * (np.sqrt(max(dmax, 1e-9)) + eq) + 1e-4


# ---------------------------------------------------------------------------
# rangescan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("q,n,d,k,bq,bn", [
    (20, 300, 64, 16, 8, 128),
    (7, 100, 33, 8, 8, 64),      # non-divisible everything
    (1, 512, 128, 32, 8, 256),   # single query
    (33, 64, 16, 64, 16, 64),    # k > in-range count
])
def test_rangescan_matches_ref(metric, q, n, d, k, bq, bn):
    kq = jax.random.PRNGKey(q * 7 + n)
    queries = jax.random.normal(kq, (q, d), jnp.float32)
    points = jax.random.normal(jax.random.PRNGKey(1), (n, d), jnp.float32)
    r = jnp.float32(1.1 * d * 0.5 if metric == "l2" else -0.2)
    ids, dd, c = rangescan(queries, points, r, k=k, block_q=bq, block_n=bn,
                           metric=metric, interpret=True)
    rids, rd, rc = rangescan_ref(queries, points, r, k=k, metric=metric)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(rc))
    np.testing.assert_allclose(np.asarray(dd), np.asarray(rd), rtol=1e-5, atol=1e-5)
    fin = np.isfinite(np.asarray(rd))
    np.testing.assert_array_equal(np.asarray(ids)[fin], np.asarray(rids)[fin])


def test_rangescan_bf16_inputs():
    q = jax.random.normal(jax.random.PRNGKey(0), (8, 32), jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 32), jnp.bfloat16)
    ids, dd, c = rangescan(q, x, jnp.float32(20.0), k=8, block_q=8,
                           block_n=64, interpret=True)
    rids, rd, rc = rangescan_ref(q, x, jnp.float32(20.0), k=8)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(rc))
    np.testing.assert_allclose(np.asarray(dd), np.asarray(rd), rtol=2e-2, atol=2e-2)


def test_rangescan_counts_exceed_k():
    """counts must be exact even when more than k points are in range."""
    x = jnp.zeros((256, 8), jnp.float32)
    q = jnp.zeros((4, 8), jnp.float32)
    ids, dd, c = rangescan(q, x, jnp.float32(1.0), k=16, block_q=4,
                           block_n=64, interpret=True)
    assert (np.asarray(c) == 256).all()
    assert (np.asarray(ids) != INVALID_ID).sum() == 4 * 16


# ---------------------------------------------------------------------------
# gatherdist
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("n,d,q,r", [(100, 32, 6, 9), (64, 7, 3, 5), (17, 128, 1, 4)])
def test_gatherdist_matches_ref(metric, n, d, q, r):
    pts = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)
    qs = jax.random.normal(jax.random.PRNGKey(1), (q, d), jnp.float32)
    ids = jax.random.randint(jax.random.PRNGKey(2), (q, r), 0, n, jnp.int32)
    ids = ids.at[0, 0].set(INVALID_ID)
    got = gatherdist(pts, ids, qs, metric=metric, interpret=True)
    want = gatherdist_ref(pts, ids, qs, metric=metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=1e-5)


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("n,d,q,r", [(100, 32, 6, 9), (64, 16, 3, 5)])
def test_gatherdist_int8_matches_ref(metric, n, d, q, r):
    """Int8 kernel vs int8 XLA ref: ids/masking identical; distances agree
    within the query-quantization envelope (the kernel quantizes the query,
    the ref does not — both certified lower bounds)."""
    pts = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)
    qs = jax.random.normal(jax.random.PRNGKey(1), (q, d), jnp.float32)
    ids = jax.random.randint(jax.random.PRNGKey(2), (q, r), 0, n, jnp.int32)
    ids = ids.at[0, 0].set(INVALID_ID)
    qc = quantize_corpus(pts)
    got = np.asarray(gatherdist(qc, ids, qs, metric=metric, interpret=True))
    want = np.asarray(gatherdist_ref(qc, ids, qs, metric=metric))
    np.testing.assert_array_equal(np.isfinite(got), np.isfinite(want))
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin],
                               atol=_int8_tol(pts, qs, want, metric),
                               rtol=1e-3)


def test_gatherdist_int8_certified_lower_bound():
    """Both int8 paths must lower-bound the exact f32 distances — the
    contract every in-loop `dist <= r` test relies on."""
    pts = jax.random.normal(jax.random.PRNGKey(3), (80, 24), jnp.float32)
    qs = jax.random.normal(jax.random.PRNGKey(4), (5, 24), jnp.float32)
    ids = jax.random.randint(jax.random.PRNGKey(5), (5, 7), 0, 80, jnp.int32)
    qc = quantize_corpus(pts)
    for metric in ("l2", "ip"):
        exact = np.asarray(gatherdist_ref(pts, ids, qs, metric=metric))
        for lb in (np.asarray(gatherdist_ref(qc, ids, qs, metric=metric)),
                   np.asarray(gatherdist(qc, ids, qs, metric=metric,
                                         interpret=True))):
            assert np.all(lb <= exact + 1e-5), metric


# ---------------------------------------------------------------------------
# expand (fused frontier expansion)
# ---------------------------------------------------------------------------

def _expand_fixture(n, r, d, q, e, seed=0):
    pts = jax.random.normal(jax.random.PRNGKey(seed), (n, d), jnp.float32)
    adj = np.array(jax.random.randint(jax.random.PRNGKey(seed + 1),
                                      (n, r), 0, n, jnp.int32))
    adj[:, -max(1, r // 4):] = INVALID_ID      # INVALID-padded adjacency rows
    if r >= 2:
        adj[0, 1] = adj[0, 0]                  # duplicate neighbor in-row
        adj[1, :2] = adj[0, :2]                # duplicates across rows
    qs = jax.random.normal(jax.random.PRNGKey(seed + 2), (q, d), jnp.float32)
    fr = np.array(jax.random.randint(jax.random.PRNGKey(seed + 3),
                                     (q, e), 0, n, jnp.int32))
    if e >= 2:
        fr[0, 1] = fr[0, 0]                    # duplicate frontier node
        fr[-1, -1] = INVALID_ID                # padded frontier lane
    return pts, jnp.asarray(adj), jnp.asarray(fr), qs


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("n,r,d,q,e", [
    (150, 8, 32, 6, 4),
    (64, 5, 17, 3, 2),    # ragged degree/dim
    (40, 4, 16, 1, 6),    # E > eligible variety, single query
])
def test_expand_matches_ref(metric, n, r, d, q, e):
    pts, adj, fr, qs = _expand_fixture(n, r, d, q, e)
    ids, dd, nd = expand_frontier(pts, adj, fr, qs, metric=metric,
                                  use_pallas=True, interpret=True)
    rids, rd, rnd = expand_frontier_ref(pts, adj, fr, qs, metric=metric)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(rids))
    # kernel uses the matmul (MXU) distance form; ref uses the diff form
    np.testing.assert_allclose(np.asarray(dd), np.asarray(rd),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(nd), np.asarray(rnd))


def test_expand_dedups_within_tile():
    """Duplicate adjacency entries and duplicate frontier nodes must survive
    exactly once across the whole E*R tile."""
    pts, adj, fr, qs = _expand_fixture(100, 6, 16, 4, 3)
    ids, dd, _ = expand_frontier(pts, adj, fr, qs, use_pallas=True,
                                 interpret=True)
    for row in np.asarray(ids):
        live = row[row != INVALID_ID]
        assert len(np.unique(live)) == len(live)
    # invalid frontier lane contributes an all-INVALID row
    last = np.asarray(ids)[-1].reshape(3, -1)[-1]
    assert (last == INVALID_ID).all()


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("n,r,d,q,e", [
    (150, 8, 32, 6, 4),
    (64, 5, 17, 3, 2),    # ragged degree/dim
])
def test_expand_int8_matches_ref(metric, n, r, d, q, e):
    """Int8 expand kernel (MXU int8 matmul + accumulator dequant) vs the
    int8 XLA ref: identical ids/dedup/n_dist; distances within the
    query-quantization envelope."""
    pts, adj, fr, qs = _expand_fixture(n, r, d, q, e)
    qc = quantize_corpus(pts)
    ids, dd, nd = expand_frontier(qc, adj, fr, qs, metric=metric,
                                  use_pallas=True, interpret=True)
    rids, rd, rnd = expand_frontier_ref(qc, adj, fr, qs, metric=metric)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(rids))
    np.testing.assert_array_equal(np.asarray(nd), np.asarray(rnd))
    got, want = np.asarray(dd), np.asarray(rd)
    np.testing.assert_array_equal(np.isfinite(got), np.isfinite(want))
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin],
                               atol=_int8_tol(pts, qs, want, metric),
                               rtol=1e-3)


def test_expand_int8_dedups_and_lower_bounds():
    """Dedup semantics carry over to the int8 kernel, and its distances
    lower-bound the exact f32 ones."""
    pts, adj, fr, qs = _expand_fixture(100, 6, 16, 4, 3)
    qc = quantize_corpus(pts)
    ids, dd, _ = expand_frontier(qc, adj, fr, qs, use_pallas=True,
                                 interpret=True)
    for row in np.asarray(ids):
        live = row[row != INVALID_ID]
        assert len(np.unique(live)) == len(live)
    exact_ids, exact_dd, _ = expand_frontier_ref(pts, adj, fr, qs)
    # same surviving ids as the f32 path (dedup is distance-independent)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(exact_ids))
    fin = np.isfinite(np.asarray(exact_dd))
    assert np.all(np.asarray(dd)[fin] <= np.asarray(exact_dd)[fin] + 1e-5)


def test_expand_bf16_corpus():
    pts, adj, fr, qs = _expand_fixture(80, 6, 32, 4, 2)
    a = expand_frontier(pts.astype(jnp.bfloat16), adj, fr, qs,
                        use_pallas=True, interpret=True)
    b = expand_frontier_ref(pts.astype(jnp.bfloat16), adj, fr, qs)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# flashattn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,sq,skv,dh,causal,window,cap,qoff", [
    (2, 4, 2, 64, 64, 32, True, 0, 0.0, 0),
    (1, 8, 2, 37, 37, 16, True, 0, 50.0, 0),      # softcap, ragged len
    (1, 4, 4, 16, 128, 32, True, 64, 0.0, 112),   # decode w/ window+offset
    (2, 2, 1, 33, 65, 64, False, 0, 0.0, 0),      # non-causal MQA
    (1, 6, 3, 128, 128, 64, True, 32, 30.0, 0),   # window + softcap
])
def test_flash_matches_ref(b, hq, hkv, sq, skv, dh, causal, window, cap, qoff):
    q = jax.random.normal(jax.random.PRNGKey(5), (b, hq, sq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(6), (b, hkv, skv, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(7), (b, hkv, skv, dh), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                        q_offset=qoff, block_q=32, block_k=32, interpret=True)
    ro = flash_attention_ref(q, k, v, causal=causal, window=window,
                             softcap=cap, q_offset=qoff)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ro), rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 64, 32), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 64, 32), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 64, 32), jnp.bfloat16)
    o = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    ro = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(ro, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_xla_fallback_matches():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 32, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 32, 16), jnp.float32)
    a = flash_attention(q, k, v, use_pallas=False)
    b = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
