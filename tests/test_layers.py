"""Layer-level unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.layers import (
    BagConfig, FieldAttnConfig, GQAConfig, MLAConfig, MoEConfig, apply_rope, dot_interaction, embedding_bag, field_attention, fm_interaction, gather_scatter, gqa_attention, init_field_attention, init_gqa, init_mla, init_moe, mla_attention, moe_layer, multi_field_lookup, rms_norm, sym_norm_weights,
)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

@given(st.integers(1, 4), st.integers(2, 64))
@settings(max_examples=20, deadline=None)
def test_rms_norm_unit_variance(b, d):
    x = jax.random.normal(jax.random.PRNGKey(b * 100 + d), (b, d)) * 7 + 3
    y = rms_norm(x, jnp.ones((d,)))
    ms = np.asarray(jnp.mean(y.astype(jnp.float32) ** 2, axis=-1))
    np.testing.assert_allclose(ms, 1.0, rtol=1e-3, atol=1e-2)


def test_rope_preserves_norm_and_relative_positions():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 2, 32))
    pos = jnp.arange(16)[None, :]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)
    # inner products depend only on relative offset
    q = apply_rope(jnp.broadcast_to(x[:, :1], x.shape), pos)
    k = apply_rope(jnp.broadcast_to(x[:, 1:2], x.shape), pos)
    dots = np.asarray(jnp.einsum("bshd,bshd->bsh", q, k))
    # constant offset 0: all positions give the same q.k
    np.testing.assert_allclose(dots[0, 1:], dots[0, :-1], rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def test_gqa_attention_causal_ignores_future():
    cfg = GQAConfig(d_model=32, n_heads=4, n_kv=2, d_head=8)
    p = init_gqa(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, 32))
    pos = jnp.broadcast_to(jnp.arange(10)[None], (1, 10))
    y1, _ = gqa_attention(p, x, cfg, positions=pos, rope_theta=1e4, window=0)
    x2 = x.at[:, 5:].set(0.0)  # changing the future
    y2, _ = gqa_attention(p, x2, cfg, positions=pos, rope_theta=1e4, window=0)
    np.testing.assert_allclose(np.asarray(y1[:, :5]), np.asarray(y2[:, :5]),
                               rtol=1e-4, atol=1e-5)


def test_gqa_sliding_window_limits_context():
    cfg = GQAConfig(d_model=32, n_heads=2, n_kv=2, d_head=16)
    p = init_gqa(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 32))
    pos = jnp.broadcast_to(jnp.arange(12)[None], (1, 12))
    y_w, _ = gqa_attention(p, x, cfg, positions=pos, rope_theta=1e4, window=3)
    x2 = x.at[:, 0].set(9.0)  # perturb a token outside everyone's window >3
    y2_w, _ = gqa_attention(p, x2, cfg, positions=pos, rope_theta=1e4, window=3)
    np.testing.assert_allclose(np.asarray(y_w[:, 6:]), np.asarray(y2_w[:, 6:]),
                               rtol=1e-4, atol=1e-5)


def test_mla_cache_stores_compressed_latent():
    cfg = MLAConfig(d_model=32, n_heads=4, q_lora=16, kv_lora=8,
                    qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8)
    p = init_mla(jax.random.PRNGKey(0), cfg)
    from repro.layers.attention import KVCache
    cache = KVCache(k=jnp.zeros((1, 16, 8)), v=jnp.zeros((1, 16, 4)))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(4)[None], (1, 4))
    y, nc = mla_attention(p, x, cfg, positions=pos, rope_theta=1e4, window=0,
                          cache=cache, cache_pos=jnp.asarray(0),
                          kv_valid_len=jnp.asarray(4))
    assert nc.k.shape == (1, 16, 8) and nc.v.shape == (1, 16, 4)
    assert np.abs(np.asarray(nc.k[:, :4])).sum() > 0
    assert np.abs(np.asarray(nc.k[:, 4:])).sum() == 0  # untouched tail


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_dense_ref(params, x, cfg):
    """All-experts dense reference: y = sum_k w_k * expert_{i_k}(x)."""
    t, d = x.shape
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.top_k)
    if cfg.normalize_weights:
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        g = jax.nn.silu(x @ params["w_gate"][e])
        u = x @ params["w_up"][e]
        outs.append((g * u) @ params["w_down"][e])
    outs = jnp.stack(outs)  # (E, T, D)
    y = jnp.zeros_like(x)
    for k in range(cfg.top_k):
        y += top_w[:, k][:, None] * jnp.take_along_axis(
            outs, top_i[:, k][None, :, None], axis=0)[0]
    return y


@pytest.mark.parametrize("e,k,alloc", [(8, 2, 8), (6, 2, 8), (5, 1, 8)])
def test_moe_matches_dense_reference(e, k, alloc):
    cfg = MoEConfig(d_model=16, n_experts=e, top_k=k, d_expert=8,
                    n_experts_alloc=alloc, capacity_factor=8.0)  # no drops
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 16), jnp.float32)
    y, aux = moe_layer(p, x, cfg)
    want = _moe_dense_ref(p, x[0], cfg)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
    assert float(aux["dropped_frac"]) == 0.0


def test_moe_capacity_drops_are_reported():
    cfg = MoEConfig(d_model=8, n_experts=4, top_k=2, d_expert=4,
                    capacity_factor=0.01)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8), jnp.float32)
    y, aux = moe_layer(p, x, cfg)
    assert float(aux["dropped_frac"]) > 0.0
    assert np.isfinite(np.asarray(y)).all()


def test_moe_shared_experts_contribute():
    cfg = MoEConfig(d_model=8, n_experts=4, top_k=1, d_expert=4, n_shared=2)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8), jnp.float32)
    y1, _ = moe_layer(p, x, cfg)
    p2 = dict(p, shared=jax.tree.map(jnp.zeros_like, p["shared"]))
    y2, _ = moe_layer(p2, x, cfg)
    assert np.abs(np.asarray(y1) - np.asarray(y2)).max() > 1e-4


# ---------------------------------------------------------------------------
# EmbeddingBag / segment ops
# ---------------------------------------------------------------------------

@given(st.integers(1, 8), st.integers(1, 10), st.integers(2, 50))
@settings(max_examples=20, deadline=None)
def test_embedding_bag_matches_loop(b, l, v):
    rng = np.random.default_rng(b * 31 + l)
    table = jnp.asarray(rng.standard_normal((v, 4)), jnp.float32)
    idx = rng.integers(-1, v, (b, l)).astype(np.int32)
    got = embedding_bag(table, jnp.asarray(idx))
    want = np.zeros((b, 4), np.float32)
    for i in range(b):
        for j in range(l):
            if idx[i, j] >= 0:
                want[i] += np.asarray(table)[idx[i, j]]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_embedding_bag_mean_mode():
    table = jnp.eye(4, dtype=jnp.float32)
    idx = jnp.asarray([[0, 1, -1]], jnp.int32)
    got = embedding_bag(table, idx, BagConfig(mode="mean"))
    np.testing.assert_allclose(np.asarray(got)[0], [0.5, 0.5, 0, 0])


def test_gather_scatter_agg_modes():
    feats = jnp.asarray([[1.0], [2.0], [4.0]])
    src = jnp.asarray([0, 1, 2, -1], jnp.int32)
    dst = jnp.asarray([2, 2, 0, -1], jnp.int32)
    s = gather_scatter(feats, src, dst, 3, agg="sum")
    np.testing.assert_allclose(np.asarray(s)[:, 0], [4, 0, 3])
    m = gather_scatter(feats, src, dst, 3, agg="mean")
    np.testing.assert_allclose(np.asarray(m)[:, 0], [4, 0, 1.5])
    mx = gather_scatter(feats, src, dst, 3, agg="max")
    np.testing.assert_allclose(np.asarray(mx)[:, 0], [4, 0, 2])


def test_sym_norm_weights_match_gcn_formula():
    src = jnp.asarray([0, 1], jnp.int32)
    dst = jnp.asarray([1, 0], jnp.int32)
    w = np.asarray(sym_norm_weights(src, dst, 2))
    np.testing.assert_allclose(w, [0.5, 0.5])  # deg+1 = 2 each side


# ---------------------------------------------------------------------------
# interactions
# ---------------------------------------------------------------------------

def test_dot_interaction_matches_manual():
    f = jax.random.normal(jax.random.PRNGKey(0), (3, 4, 8))
    got = np.asarray(dot_interaction(f))
    want = []
    fa = np.asarray(f)
    for b in range(3):
        row = []
        for i in range(4):
            for j in range(i + 1, 4):
                row.append(fa[b, i] @ fa[b, j])
        want.append(row)
    # note: triu order is row-major over (i, j)
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-5)


def test_fm_identity():
    f = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 3))
    got = np.asarray(fm_interaction(f))
    fa = np.asarray(f)
    want = np.array([sum(fa[b, i] @ fa[b, j] for i in range(5)
                         for j in range(i + 1, 5)) for b in range(2)])
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_field_attention_shapes():
    cfg = FieldAttnConfig(n_fields=5, d_embed=8, n_layers=2, n_heads=2, d_attn=16)
    p = init_field_attention(jax.random.PRNGKey(0), cfg)
    f = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 8))
    out = field_attention(p, f, cfg)
    assert out.shape == (3, 5 * 16)
    assert np.isfinite(np.asarray(out)).all()


def test_multi_field_lookup():
    tables = jnp.asarray(np.arange(2 * 3 * 2).reshape(2, 3, 2), jnp.float32)
    idx = jnp.asarray([[0, 2], [1, 1]], jnp.int32)
    out = np.asarray(multi_field_lookup(tables, idx))
    np.testing.assert_allclose(out[0, 0], np.asarray(tables)[0, 0])
    np.testing.assert_allclose(out[0, 1], np.asarray(tables)[1, 2])
