"""System-behaviour + property tests for the paper's range-search core."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ES_D_VISITED, BuildConfig, RangeConfig, RangeSearchEngine, SearchConfig, average_precision, beam_search_batch, build_vamana, exact_range_search, exact_topk, from_lists, recall_at_k, robust_prune, zero_result_accuracy,
)
from repro.core.radius import default_grid, match_histogram, select_radius, sweep
from repro.utils import INVALID_ID


def _toy(n=800, d=12, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((8, d)).astype(np.float32) * 3
    pts = (centers[rng.integers(0, 8, n)] +
           rng.standard_normal((n, d)).astype(np.float32) * 0.4)
    return jnp.asarray(pts)


@pytest.fixture(scope="module")
def corpus():
    # Vamana, not plain kNN: a directed kNN graph on clustered data is
    # disconnected across clusters — navigability is exactly what the
    # alpha-pruned build provides (and what the paper's index assumes).
    pts = _toy()
    graph = build_vamana(pts, BuildConfig(max_degree=16, beam=32,
                                          insert_batch=256, two_pass=True))
    eng = RangeSearchEngine.from_graph(pts, graph)
    qs = pts[:64] + 0.01
    return pts, graph, eng, qs


# ---------------------------------------------------------------------------
# exact oracles
# ---------------------------------------------------------------------------

def test_exact_range_counts_match_bruteforce(corpus):
    pts, _, _, qs = corpus
    r = 2.0
    ids, dists, counts = exact_range_search(pts, qs, r)
    pd = np.asarray(((np.asarray(qs)[:, None, :] - np.asarray(pts)[None]) ** 2).sum(-1))
    np.testing.assert_array_equal(np.asarray(counts), (pd <= r).sum(1))
    # returned dists sorted ascending and within radius
    dd = np.asarray(dists)
    assert all((np.diff(row[np.isfinite(row)]) >= -1e-6).all() for row in dd)
    assert np.nanmax(np.where(np.isfinite(dd), dd, 0)) <= r + 1e-6


def test_exact_topk_matches_numpy(corpus):
    pts, _, _, qs = corpus
    ids, dists = exact_topk(pts, qs, k=5)
    pd = np.asarray(((np.asarray(qs)[:, None, :] - np.asarray(pts)[None]) ** 2).sum(-1))
    want = np.sort(pd, axis=1)[:, :5]
    # matmul-form distances (|q|^2+|x|^2-2qx) carry ~|q||x|*eps absolute
    # error, which dominates for near-zero distances
    np.testing.assert_allclose(np.asarray(dists), want, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# beam search invariants
# ---------------------------------------------------------------------------

def test_beam_finds_nearest_on_connected_graph(corpus):
    pts, graph, eng, qs = corpus
    cfg = SearchConfig(beam=48, max_beam=48, visit_cap=256)
    st_ = beam_search_batch(pts, graph, qs, eng.start_ids,
                            jnp.asarray(np.inf, jnp.float32), cfg)
    gt_ids, _ = exact_topk(pts, qs, k=1)
    got = np.asarray(st_.ids[:, 0])
    assert (got == np.asarray(gt_ids[:, 0])).mean() > 0.9


def test_beam_monotone_in_width(corpus):
    """Recall@10 must not decrease when the beam widens (paper's QPS knob)."""
    pts, graph, eng, qs = corpus
    gt_ids, _ = exact_topk(pts, qs, k=10)
    recalls = []
    for b in (8, 16, 32, 64):
        cfg = SearchConfig(beam=b, max_beam=b, visit_cap=4 * b)
        st_ = beam_search_batch(pts, graph, qs, eng.start_ids,
                                jnp.asarray(np.inf, jnp.float32), cfg)
        recalls.append(recall_at_k(np.asarray(gt_ids), np.asarray(st_.ids), 10))
    assert all(b >= a - 0.02 for a, b in zip(recalls, recalls[1:])), recalls
    assert recalls[-1] > 0.85


def test_beam_never_revisits(corpus):
    pts, graph, eng, qs = corpus
    cfg = SearchConfig(beam=32, max_beam=32, visit_cap=128)
    st_ = beam_search_batch(pts, graph, qs[:8], eng.start_ids,
                            jnp.asarray(np.inf, jnp.float32), cfg)
    for row, n in zip(np.asarray(st_.visited_ids), np.asarray(st_.n_visited)):
        v = row[: min(n, row.shape[0])]
        v = v[v != INVALID_ID]
        assert len(np.unique(v)) == len(v)


# ---------------------------------------------------------------------------
# range modes: beam <= doubling <= exact; greedy completes clusters
# ---------------------------------------------------------------------------

def _ap(eng, qs, r, cfg, gt, es=None):
    res = eng.range(qs, r, cfg=cfg, es_radius=es)
    return average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                             np.asarray(res.ids), np.asarray(res.count)), res


def test_mode_ordering(corpus):
    pts, graph, eng, qs = corpus
    r = 2.5
    gt = exact_range_search(pts, qs, r)
    ap_beam, _ = _ap(eng, qs, r, RangeConfig(
        search=SearchConfig(beam=16, max_beam=16, visit_cap=128), mode="beam"), gt)
    ap_dbl, _ = _ap(eng, qs, r, RangeConfig(
        search=SearchConfig(beam=16, max_beam=128, visit_cap=512), mode="doubling"), gt)
    ap_greedy, _ = _ap(eng, qs, r, RangeConfig(
        search=SearchConfig(beam=16, max_beam=16, visit_cap=128), mode="greedy"), gt)
    assert ap_dbl >= ap_beam - 0.02
    assert ap_greedy >= ap_beam - 0.02
    assert ap_greedy > 0.5


def test_greedy_results_all_in_range(corpus):
    pts, graph, eng, qs = corpus
    r = 2.5
    cfg = RangeConfig(search=SearchConfig(beam=16, max_beam=16, visit_cap=128),
                      mode="greedy")
    res = eng.range(qs, r, cfg=cfg)
    dd = np.asarray(res.dists)
    ids = np.asarray(res.ids)
    assert np.all(dd[ids != INVALID_ID] <= r + 1e-5)
    # count equals number of valid ids when no overflow
    valid = (ids != INVALID_ID).sum(1)
    no_of = ~np.asarray(res.overflow)
    np.testing.assert_array_equal(valid[no_of], np.asarray(res.count)[no_of])


def test_fused_equals_compacted(corpus):
    pts, graph, eng, qs = corpus
    r = 2.5
    cfg = RangeConfig(search=SearchConfig(beam=16, max_beam=16, visit_cap=128),
                      mode="greedy")
    a = eng.range(qs, r, cfg=cfg, compacted=True)
    b = eng.range(qs, r, cfg=cfg, compacted=False)
    np.testing.assert_array_equal(np.asarray(a.count), np.asarray(b.count))
    for ra, rb in zip(np.asarray(a.ids), np.asarray(b.ids)):
        assert set(ra[ra != INVALID_ID]) == set(rb[rb != INVALID_ID])


def test_early_stopping_cuts_work_not_results(corpus):
    pts, graph, eng, qs_near = corpus
    rng = np.random.default_rng(3)
    far = jnp.asarray(rng.standard_normal((64, pts.shape[1])).astype(np.float32) * 20)
    qs = jnp.concatenate([qs_near, far])
    r = 2.5
    gt = exact_range_search(pts, qs, r)
    base_cfg = SearchConfig(beam=32, max_beam=32, visit_cap=256)
    es_cfg = dataclasses.replace(base_cfg, es_metric=ES_D_VISITED, es_visit_limit=8)
    ap0, res0 = _ap(eng, qs, r, RangeConfig(search=base_cfg, mode="greedy"), gt)
    ap1, res1 = _ap(eng, qs, r, RangeConfig(search=es_cfg, mode="greedy"), gt, es=2.0 * r)
    assert np.asarray(res1.n_visited).sum() < np.asarray(res0.n_visited).sum()
    assert int(np.asarray(res1.es_stopped).sum()) > 0
    assert ap1 >= ap0 - 0.05
    # far queries answer zero results either way
    assert zero_result_accuracy(np.asarray(gt[2]), np.asarray(res1.count)) > 0.9


_EXPAND_CORPUS: dict = {}


def _expand_corpus():
    """Small cached Vamana index for the expand-width property test (the
    hypothesis stub can't drive pytest fixtures)."""
    if not _EXPAND_CORPUS:
        pts = _toy(500, seed=7)
        graph = build_vamana(pts, BuildConfig(max_degree=12, beam=24,
                                              insert_batch=256))
        _EXPAND_CORPUS["v"] = (pts, RangeSearchEngine.from_graph(pts, graph),
                               pts[:48] + 0.01)
    return _EXPAND_CORPUS["v"]


@given(st.integers(2, 8), st.floats(2.0, 3.5))
@settings(max_examples=6, deadline=None)
def test_expand_width_matches_single_node_ap(e, r):
    """Multi-node expansion (fused path) must match the single-node
    reference loop's AP within tolerance — E is a perf knob, not an
    accuracy knob."""
    pts, eng, qs = _expand_corpus()
    gt = exact_range_search(pts, qs, r)
    aps = {}
    for ew in (1, e):
        cfg = RangeConfig(search=SearchConfig(beam=16, max_beam=16,
                                              visit_cap=128, expand_width=ew),
                          mode="greedy")
        aps[ew], _ = _ap(eng, qs, r, cfg, gt)
    assert aps[e] >= aps[1] - 0.02, aps


# ---------------------------------------------------------------------------
# Vamana build
# ---------------------------------------------------------------------------

def test_vamana_beats_random_graph():
    pts = _toy(600)
    qs = pts[:48] + 0.01
    g = build_vamana(pts, BuildConfig(max_degree=16, beam=32, insert_batch=256))
    eng = RangeSearchEngine.from_graph(pts, g)
    ids, _ = eng.topk(qs, k=10)
    gt_ids, _ = exact_topk(pts, qs, k=10)
    assert recall_at_k(np.asarray(gt_ids), np.asarray(ids), 10) > 0.8
    deg = np.asarray(g.degrees())
    assert deg.max() <= 16 and deg.mean() > 2


def test_robust_prune_selects_closest_and_diverse():
    pts = jnp.asarray(np.random.default_rng(0).standard_normal((50, 8)), jnp.float32)
    p = pts[0]
    cand = jnp.arange(1, 50, dtype=jnp.int32)
    d = jnp.sum((pts[cand] - p) ** 2, axis=-1)
    out = robust_prune(pts, p, cand, d, alpha=1.2, R=8)
    out = np.asarray(out)
    sel = out[out != INVALID_ID]
    assert len(sel) > 0 and len(np.unique(sel)) == len(sel)
    # the closest candidate always survives
    assert int(cand[np.argmin(np.asarray(d))]) in sel


# ---------------------------------------------------------------------------
# metrics + radius methodology properties (hypothesis)
# ---------------------------------------------------------------------------

@given(st.integers(1, 30), st.integers(0, 29), st.integers(1, 1000))
@settings(max_examples=25, deadline=None)
def test_ap_bounds_and_perfection(n_gt, n_hit, seed):
    rng = np.random.default_rng(seed)
    n_hit = min(n_hit, n_gt)
    gt = rng.choice(10_000, size=n_gt, replace=False).astype(np.int64)
    res = np.concatenate([gt[:n_hit], 10_000 + np.arange(5)])
    cap = max(n_gt, len(res))
    gt_ids = np.full((1, cap), INVALID_ID, np.int64)
    gt_ids[0, :n_gt] = gt
    res_ids = np.full((1, cap), INVALID_ID, np.int64)
    res_ids[0, :len(res)] = res
    ap = average_precision(gt_ids, np.array([n_gt]), res_ids, np.array([len(res)]))
    assert 0.0 <= ap <= 1.0
    np.testing.assert_allclose(ap, n_hit / n_gt)


@given(st.floats(0.5, 0.99))
@settings(max_examples=10, deadline=None)
def test_radius_selection_hits_target(target):
    pts = _toy(500, seed=2)
    qs = pts[:64] + 0.01
    grid = default_grid(np.asarray(pts), np.asarray(qs), "l2", num=24)
    prof = sweep(pts, qs, grid)
    r, gi = select_radius(prof, target_zero_frac=target, robustness_weight=0.0)
    assert grid[0] <= r <= grid[-1]
    # zero fraction monotonically decreases as radius grows
    zf = prof.zero_frac
    assert all(b <= a + 1e-9 for a, b in zip(zf, zf[1:]))


def test_match_histogram_buckets():
    h = match_histogram(np.array([0, 0, 3, 11, 99, 1000, 99999]))
    assert h["0"] == 2 and h["<=1e1"] == 1 and h["<=1e2"] == 2
    assert h["<=1e3"] == 1 and h["<=1e5"] == 1


def test_match_histogram_overflow_bucket_sums_to_total():
    """Regression: counts past the paper's last printed column (>1e5) used
    to vanish from the table. They must land in the terminal overflow
    bucket, and the buckets must always partition the queries."""
    counts = np.array([0, 5, 100_000, 100_001, 250_000, 10**7])
    h = match_histogram(counts)
    assert h[">1e5"] == 3
    assert h["<=1e5"] == 1  # 100_000 is inclusive in the last printed column
    assert sum(h.values()) == len(counts)


@given(st.lists(st.integers(0, 10**7), min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_match_histogram_partitions_any_counts(counts):
    h = match_histogram(np.array(counts))
    assert sum(h.values()) == len(counts)
    assert all(v >= 0 for v in h.values())


def test_select_radius_raises_on_infeasible_grid():
    """Regression: an all-infeasible grid (every radius → zero matches for
    every query) argmin'd to index 0 and silently blessed a vacuous
    benchmark radius; it must raise instead. The single-radius grid also
    exercises the np.gradient guard in sweep(), which crashed on < 2
    samples."""
    pts = _toy(64, seed=5)
    qs = np.asarray(pts[:8]) + 100.0  # far from every corpus point
    prof = sweep(pts, jnp.asarray(qs), np.array([1e-6], np.float32))
    assert prof.robustness.shape == (1,) and prof.robustness[0] == 0.0
    assert (prof.zero_frac == 1.0).all()
    with pytest.raises(ValueError, match="no feasible radius"):
        select_radius(prof)


def test_select_radius_single_feasible_grid_point():
    """A one-point grid with matches is degenerate but legal: sweep() must
    not crash on the gradient and select_radius must return that point."""
    pts = _toy(64, seed=5)
    qs = np.asarray(pts[:8]) + 0.01
    prof = sweep(pts, jnp.asarray(qs), np.array([10.0], np.float32))
    r, gi = select_radius(prof, target_zero_frac=0.5)
    assert gi == 0 and r == np.float32(10.0)


# ---------------------------------------------------------------------------
# graph container
# ---------------------------------------------------------------------------

def test_graph_out_neighbors_invalid_safe():
    g = from_lists([[1, 2], [0], [0, 1]])
    rows = g.out_neighbors(jnp.asarray([0, INVALID_ID], jnp.int32))
    assert np.asarray(rows)[1].tolist() == [INVALID_ID, INVALID_ID]


def test_graph_lane_padded():
    g = from_lists([[1, 2], [0], [0, 1]])
    gp = g.lane_padded(8)
    assert gp.max_degree == 8 and gp.num_nodes == g.num_nodes
    np.testing.assert_array_equal(np.asarray(gp.neighbors[:, :2]),
                                  np.asarray(g.neighbors))
    assert (np.asarray(gp.neighbors[:, 2:]) == INVALID_ID).all()
    assert g.lane_padded(2) is g  # already aligned -> no copy


@given(st.integers(2, 40), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_random_regular_no_self_loops(n, deg):
    g = __import__("repro.core.graph", fromlist=["random_regular"]).random_regular(
        jax.random.PRNGKey(n), n, deg)
    nbrs = np.asarray(g.neighbors)
    row = np.arange(n)[:, None]
    assert not (nbrs == row).any()
