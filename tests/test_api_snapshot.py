"""Public-API snapshot: the exported surface of ``repro.core``,
``repro.serve``, ``repro.live``, and ``repro.fault`` — symbol names, kinds,
and callable signatures — is pinned to ``tests/api_snapshot.json``.

The unified query API (op-tagged ``Request``/``Response``, keyword-only
``range_search_*`` signatures, ``EngineDeployConfig.overrides``) is a
compatibility contract: this test makes any drift — a renamed keyword, a
reordered parameter, a dropped export — an explicit, reviewed diff instead
of a silent break for downstream callers.

Intentional API changes regenerate the snapshot:

    PYTHONPATH=src python tests/test_api_snapshot.py --update

and the resulting ``api_snapshot.json`` diff is reviewed with the code.
"""
import importlib
import inspect
import json
import pathlib

MODULES = ("repro.core", "repro.serve", "repro.live", "repro.fault")
SNAPSHOT = pathlib.Path(__file__).parent / "api_snapshot.json"


def _describe(obj):
    if inspect.isclass(obj):
        kind = "class"
    elif callable(obj):
        kind = "function"
    else:
        return {"kind": type(obj).__name__}
    try:
        sig = str(inspect.signature(obj))
    except (ValueError, TypeError):  # builtins / odd callables
        sig = None
    return {"kind": kind, "signature": sig}


def current_api():
    out = {}
    for modname in MODULES:
        mod = importlib.import_module(modname)
        names = sorted(getattr(mod, "__all__", None)
                       or [n for n in dir(mod) if not n.startswith("_")])
        out[modname] = {n: _describe(getattr(mod, n)) for n in names}
    return out


def test_public_api_matches_snapshot():
    assert SNAPSHOT.exists(), (
        "tests/api_snapshot.json missing — regenerate with "
        "`PYTHONPATH=src python tests/test_api_snapshot.py --update`")
    want = json.loads(SNAPSHOT.read_text())
    got = current_api()
    problems = []
    for modname in MODULES:
        w, g = want.get(modname, {}), got.get(modname, {})
        for name in sorted(set(w) | set(g)):
            if name not in g:
                problems.append(f"{modname}.{name}: removed from public API")
            elif name not in w:
                problems.append(f"{modname}.{name}: new export not in "
                                "snapshot")
            elif w[name] != g[name]:
                problems.append(f"{modname}.{name}: {w[name]} -> {g[name]}")
    assert not problems, (
        "public API drifted from tests/api_snapshot.json:\n  "
        + "\n  ".join(problems)
        + "\nIf intentional, regenerate: PYTHONPATH=src python "
        "tests/test_api_snapshot.py --update")


if __name__ == "__main__":
    import sys
    if "--update" in sys.argv:
        SNAPSHOT.write_text(json.dumps(current_api(), indent=2,
                                       sort_keys=True) + "\n")
        print(f"wrote {SNAPSHOT}")
    else:
        print(json.dumps(current_api(), indent=2, sort_keys=True))
