"""Live-update subsystem tests: churn vs the exact oracle.

The backbone invariant: after ANY interleaving of inserts and deletes, the
live index's range results (external ids) equal ``exact_range_search``
restricted to the live set — fused == compacted == sharded, on f32 and int8
corpora, with mixed per-query radii. The corpus is clustered and the graph
two-pass-built so greedy range search recovers exact in-range sets (the same
well-navigable recipe the server oracle tests rely on); equality is then a
meaningful, non-flaky assertion.

Heavier randomized interleavings run under the ``slow`` marker (pyproject
addopts keep them off the fast path; CI runs them in their own step).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import BuildConfig, RangeConfig, SearchConfig, build_vamana
from repro.core.distances import point_dist
from repro.live import LiveConfig, LiveIndex, LiveShardedIndex
from repro.train import CheckpointManager
from repro.utils import INVALID_ID

D = 10
BCFG = BuildConfig(max_degree=24, beam=48, insert_batch=256, two_pass=True)
LCFG = LiveConfig(capacity=1024, insert_batch=64, consolidate_at=0.25)
CFG = RangeConfig(search=SearchConfig(beam=64, max_beam=64, visit_cap=256),
                  mode="greedy", result_cap=512)


def _clustered(n, seed=0, scale=0.4):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((8, D)).astype(np.float32) * 3
    return (centers[rng.integers(0, 8, n)]
            + rng.standard_normal((n, D)).astype(np.float32) * scale)


_BASE: dict = {}


def _base():
    """(initial points (700, D), prebuilt graph, stream points (120, D)),
    built once — every test creates its own cheap LiveIndex from the cached
    graph so mutations never leak between tests."""
    if not _BASE:
        pts = _clustered(700, seed=0)
        _BASE["pts"] = pts
        _BASE["graph"] = build_vamana(jnp.asarray(pts), BCFG)
        _BASE["stream"] = _clustered(120, seed=7)
    return _BASE["pts"], _BASE["graph"], _BASE["stream"]


def _live(corpus_dtype="float32"):
    pts, graph, _ = _base()
    return LiveIndex.create(pts, LCFG, BCFG, corpus_dtype=corpus_dtype,
                            graph=graph)


def _sets(res):
    ids = np.asarray(res.ids)
    return [set(row[row != INVALID_ID].tolist()) for row in ids]


def _oracle_sets(live, qs, radii):
    """Exact diff-form oracle restricted to the live set, keyed by ext id."""
    ext, vecs = live.live_vectors()
    exact = np.asarray(point_dist(vecs[None], np.asarray(qs)[:, None], "l2"))
    return [set(ext[exact[i] <= radii[i]].tolist()) for i in range(len(qs))]


def _mixed_radii(qs, lo=1.0, hi=6.0, seed=3):
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, len(qs)).astype(np.float32)


# ---------------------------------------------------------------------------
# acceptance invariants
# ---------------------------------------------------------------------------

def test_insert_then_query_finds_new_point_at_exact_distance():
    live = _live()
    _, _, stream = _base()
    new = stream[:40]
    ids = live.insert(new)
    assert ids.shape == (40,) and live.n_live == 740
    qs = new[:8] + 0.001
    res = live.range(qs, 0.5, cfg=CFG)
    res_f = live.range(qs, 0.5, cfg=CFG, compacted=False)
    got, got_f = _sets(res), _sets(res_f)
    d_exact = np.sum((new[:8] - qs) ** 2, axis=1)
    rows_ids = np.asarray(res.ids)
    rows_d = np.asarray(res.dists)
    for i in range(8):
        assert ids[i] in got[i], f"lane {i}: fresh insert not found"
        assert got[i] == got_f[i]
        j = int(np.nonzero(rows_ids[i] == ids[i])[0][0])
        np.testing.assert_allclose(rows_d[i, j], d_exact[i], atol=1e-5)


def test_delete_then_query_never_returns_deleted():
    live = _live()
    pts, _, _ = _base()
    doomed = np.arange(0, 50)
    assert live.delete(doomed) == 50
    assert live.delete(doomed) == 0  # idempotent
    qs = pts[:16] + 0.01  # query AT deleted points: their slots must route,
    res = live.range(qs, _mixed_radii(qs), cfg=CFG)  # never answer
    for i, got in enumerate(_sets(res)):
        assert not (got & set(doomed.tolist())), f"lane {i}"
    # tombstoned nodes still ROUTE: results equal the live-set oracle even
    # though the query's nearest neighbors (its own deleted copies) are gone
    radii = _mixed_radii(qs)
    want = _oracle_sets(live, qs, radii)
    got = _sets(live.range(qs, jnp.asarray(radii), cfg=CFG))
    over = np.asarray(live.range(qs, jnp.asarray(radii), cfg=CFG).overflow)
    for i in range(len(qs)):
        if not over[i]:
            assert got[i] == want[i], f"lane {i}"


@pytest.mark.parametrize("corpus_dtype", ("float32", "int8"))
def test_churn_oracle_equivalence(corpus_dtype):
    """Interleaved inserts/deletes; results == oracle on the live set at
    mixed per-query radii; fused == compacted."""
    live = _live(corpus_dtype)
    pts, _, stream = _base()
    rng = np.random.default_rng(11)
    ids0 = live.insert(stream[:30])
    live.delete(rng.choice(700, 40, replace=False))
    ids1 = live.insert(stream[30:60])
    live.delete(ids0[:10])                      # delete some fresh inserts
    live.delete(rng.choice(700, 30, replace=False))
    assert live.epoch == 5
    qs = np.concatenate([pts[100:116] + 0.01, stream[30:38] + 0.01])
    radii = _mixed_radii(qs)
    res_c = live.range(qs, jnp.asarray(radii), cfg=CFG)
    res_f = live.range(qs, jnp.asarray(radii), cfg=CFG, compacted=False)
    want = _oracle_sets(live, qs, radii)
    got_c, got_f = _sets(res_c), _sets(res_f)
    over = np.asarray(res_c.overflow)
    for i in range(len(qs)):
        assert got_c[i] == got_f[i], f"lane {i}: fused != compacted"
        if not over[i]:
            assert got_c[i] == want[i], f"lane {i}: oracle mismatch"
    # the surviving fresh inserts answer; the deleted ones never do
    all_got = set().union(*got_c)
    assert not (all_got & set(ids0[:10].tolist()))
    assert set(ids1.tolist()) & all_got


def test_sharded_churn_matches_oracle():
    """Per-shard tombstones + shard-routed mutations through the shard_map
    union merge (single device, 2 shards along the model axis)."""
    pts, _, stream = _base()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sl = LiveShardedIndex.create(
        pts, 2, LiveConfig(capacity=512, insert_batch=64), BCFG)
    rng = np.random.default_rng(5)
    new_ids = sl.insert(stream[:40])
    assert sl.delete(np.concatenate([new_ids[:8],
                                     rng.choice(700, 50, replace=False)])) == 58
    qs = np.concatenate([pts[200:212] + 0.01, stream[8:12] + 0.01])
    radii = _mixed_radii(qs)
    res = sl.range(mesh, qs, jnp.asarray(radii), CFG)
    want = _oracle_sets(sl, qs, radii)
    got = _sets(res)
    over = np.asarray(res.overflow)
    for i in range(len(qs)):
        if not over[i]:
            assert got[i] == want[i], f"lane {i}"
    # the batch routed to ONE owning shard; deletes hit their owners' bitsets
    owners = {sl._owner[int(e)] for e in new_ids}
    assert len(owners) == 1
    owner = owners.pop()
    assert sl.shards[owner].live_count == 350 + 40
    assert sum(sh.n_dead for sh in sl.shards) == 58


def test_sharded_insert_splits_across_shards_when_one_fills():
    """A batch larger than the owning shard's free capacity splits greedily
    across shards instead of failing (regression: the router used to hand
    the whole batch to one shard)."""
    pts, _, stream = _base()
    sl = LiveShardedIndex.create(
        pts, 2, LiveConfig(capacity=400, insert_batch=64), BCFG)
    # each shard holds 350, free 50 -> a 90-row batch MUST span both
    ids = sl.insert(np.concatenate([stream, _clustered(90, seed=9)])[:90])
    owners = {sl._owner[int(e)] for e in ids}
    assert owners == {0, 1}
    assert sl.n_live == 790
    with pytest.raises(ValueError, match="free capacity"):
        sl.insert(_clustered(50, seed=10))  # fleet has only 10 free


def test_consolidation_rewires_compacts_and_preserves_results():
    live = _live()
    pts, _, stream = _base()
    rng = np.random.default_rng(2)
    live.insert(stream[:50])
    live.delete(rng.choice(700, 200, replace=False))  # 26.7% > threshold
    qs = pts[300:316] + 0.01
    radii = _mixed_radii(qs)
    want = _oracle_sets(live, qs, radii)
    before = live.live_vectors()
    assert live.maybe_consolidate()           # frac crossed consolidate_at
    assert not live.maybe_consolidate()       # tombstones all reclaimed
    st = live.stats()
    assert st["n_dead"] == 0 and st["live_count"] == 550
    assert st["free_slots"] == LCFG.capacity - 550  # slots reclaimed
    after = live.live_vectors()
    np.testing.assert_array_equal(np.sort(before[0]), np.sort(after[0]))
    got = _sets(live.range(qs, jnp.asarray(radii), cfg=CFG))
    over = np.asarray(live.range(qs, jnp.asarray(radii), cfg=CFG).overflow)
    for i in range(len(qs)):
        if not over[i]:
            assert got[i] == want[i], f"lane {i}: results moved under consolidation"


def test_insert_beyond_capacity_consolidates_or_raises():
    pts, graph, stream = _base()
    live = LiveIndex.create(pts, LiveConfig(capacity=720, insert_batch=64),
                            BCFG, graph=graph)
    with pytest.raises(ValueError, match="capacity"):
        live.insert(stream[:40])              # no tombstones to reclaim
    live.delete(np.arange(100))
    ids = live.insert(stream[:40])            # auto-consolidation freed slots
    assert live.live_count == 640 and live.n_live == 640
    got = set().union(*_sets(live.range(stream[:4] + 0.001, 0.5, cfg=CFG)))
    assert set(ids[:4].tolist()) <= got


def test_delete_everything_never_crashes_consolidation():
    """Legitimate delete-everything traffic: consolidation no-ops on an
    empty live set (regression: it used to raise, killing the server's
    auto-consolidate path), tombstones keep filtering, queries answer
    empty."""
    pts, graph, _ = _base()
    live = LiveIndex.create(pts, LCFG, BCFG, graph=graph)
    assert live.delete(np.arange(700)) == 700
    assert live.n_live == 0 and live.tombstone_frac() == 1.0
    assert not live.maybe_consolidate()          # skipped, not crashed
    assert live.consolidate()["reclaimed"] == 0  # explicit call: no-op
    res = live.range(pts[:4] + 0.01, 10.0, cfg=CFG)
    assert int(np.asarray(res.count).sum()) == 0


def test_live_checkpoint_roundtrip(tmp_path):
    """Mutable state (watermark, tombstones, ext ids, int8 corpus) survives
    the atomic checkpoint; the restored index answers bitwise-identically
    and keeps mutating from where it left off."""
    live = _live("int8")
    pts, _, stream = _base()
    live.insert(stream[:30])
    live.delete(np.arange(40))
    cm = CheckpointManager(str(tmp_path), keep=2)
    live.save(cm)
    live2 = LiveIndex.restore(cm)
    assert live2.stats() == live.stats()
    qs = pts[:12] + 0.01
    radii = _mixed_radii(qs)
    r1 = live.range(qs, jnp.asarray(radii), cfg=CFG)
    r2 = live2.range(qs, jnp.asarray(radii), cfg=CFG)
    for name in ("ids", "dists", "count", "overflow", "n_rerank"):
        np.testing.assert_array_equal(np.asarray(getattr(r1, name)),
                                      np.asarray(getattr(r2, name)), name)
    ids_a = live.insert(stream[30:40])
    ids_b = live2.insert(stream[30:40])
    np.testing.assert_array_equal(ids_a, ids_b)  # same id stream continues
    assert live2.delete(ids_b[:3]) == 3


def test_frozen_engine_unaffected_by_tombstone_arg_absence():
    """The tombstones plumbing is strictly additive: a frozen engine search
    (tombstones=None) and a live search with ZERO tombstones agree."""
    pts, graph, _ = _base()
    live = _live()
    qs = pts[:8] + 0.01
    radii = _mixed_radii(qs)
    from repro.core import RangeSearchEngine
    eng = RangeSearchEngine.from_graph(jnp.asarray(pts), graph)
    res_e = eng.range(qs, jnp.asarray(radii), cfg=CFG)
    res_l = live.range(qs, jnp.asarray(radii), cfg=CFG)
    for a, b in zip(_sets(res_e), _sets(res_l)):
        assert a == b


# ---------------------------------------------------------------------------
# randomized interleavings (hypothesis; the stub provides seeded draws)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@given(st.integers(0, 2**31 - 1), st.integers(2, 6))
@settings(max_examples=6, deadline=None)
def test_slow_random_interleavings(seed, n_ops):
    """Any random interleaving of insert/delete batches keeps the oracle
    equality (modulo overflow lanes) on both corpus dtypes."""
    rng = np.random.default_rng(seed)
    dtype = ("float32", "int8")[seed % 2]
    live = _live(dtype)
    _, _, stream = _base()
    fresh: list[int] = []
    off = 0
    for _ in range(n_ops):
        if rng.random() < 0.5 and off < 100:
            take = int(rng.integers(5, 20))
            ids = live.insert(_clustered(take, seed=int(rng.integers(1 << 30))))
            fresh.extend(ids.tolist())
            off += take
        else:
            pool = np.asarray(live.live_vectors()[0])
            live.delete(rng.choice(pool, size=min(15, len(pool)),
                                   replace=False))
    qs = live.live_vectors()[1][rng.integers(0, live.n_live, 10)] + 0.01
    radii = _mixed_radii(qs, seed=seed % 100)
    res = live.range(qs, jnp.asarray(radii), cfg=CFG)
    want = _oracle_sets(live, qs, radii)
    got = _sets(res)
    over = np.asarray(res.overflow)
    for i in range(len(qs)):
        if not over[i]:
            assert got[i] == want[i], f"lane {i}"
