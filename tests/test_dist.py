"""Multi-device distribution tests.

These need >1 device, which requires XLA_FLAGS before jax's first import —
forbidden in conftest (smoke tests must see 1 device, per brief). Each test
therefore runs a short script in a subprocess with the flag set.
"""
import os
import subprocess
import sys
import textwrap


ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def run_sub(body: str):
    script = textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script], env=ENV,
                       capture_output=True, text=True, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_compressed_psum_and_collective_matmul():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from repro.dist.compat import shard_map  # jax<0.6: no jax.shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.compression import compressed_psum_mean
        from repro.dist.collective_matmul import allgather_matmul, matmul_reducescatter
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 1000), jnp.float32)
        f = shard_map(partial(compressed_psum_mean, axis_name="model", n=4),
                      mesh=mesh, in_specs=P(None, "model"),
                      out_specs=P(None, "model"), check_vma=False)
        got = np.asarray(f(x)).reshape(8, 4, 250)
        want = np.asarray(x).reshape(8, 4, 250).mean(axis=1)
        for s in range(4):
            np.testing.assert_allclose(got[:, s], want, rtol=0.05, atol=0.02)
        xx = jax.random.normal(jax.random.PRNGKey(1), (16, 12), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(2), (12, 6), jnp.float32)
        f2 = shard_map(partial(allgather_matmul, axis_name="model", n=4),
                       mesh=mesh, in_specs=(P("model", None), P(None, None)),
                       out_specs=P(None, None), check_vma=False)
        np.testing.assert_allclose(np.asarray(f2(xx, w)), np.asarray(xx @ w),
                                   rtol=1e-5, atol=1e-5)
        x3 = jax.random.normal(jax.random.PRNGKey(3), (16, 20), jnp.float32)
        w3 = jax.random.normal(jax.random.PRNGKey(4), (20, 6), jnp.float32)
        f3 = shard_map(partial(matmul_reducescatter, axis_name="model", n=4),
                       mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
                       out_specs=P("model", None), check_vma=False)
        np.testing.assert_allclose(np.asarray(f3(x3, w3)), np.asarray(x3 @ w3),
                                   rtol=1e-4, atol=1e-4)
        print("OK")
    """)


def test_sharded_embedding_and_engine():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.dist.embedding import sharded_lookup
        from repro.dist.sharded_engine import build_sharded, sharded_range_search
        from repro.core import (RangeConfig, SearchConfig, build_knn_graph,
                                exact_range_search, average_precision)
        from repro.core.graph import medoid
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        tables = jax.random.normal(jax.random.PRNGKey(5), (3, 64, 8), jnp.float32)
        idx = jax.random.randint(jax.random.PRNGKey(6), (10, 3), 0, 64)
        got = sharded_lookup(mesh, tables, idx, axis=("data", "model"))
        want = jax.vmap(lambda t, i: jnp.take(t, i, axis=0), in_axes=(0, 1),
                        out_axes=1)(tables, idx)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

        pts = jnp.asarray(np.random.default_rng(0).standard_normal((2000, 16)),
                          jnp.float32)
        qs = np.asarray(pts[:32]) + 0.01
        rcfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32,
                                               visit_cap=128,
                                               expand_width=4),
                           mode="greedy", result_cap=256)
        corpus = build_sharded(np.asarray(pts), 4,
                               lambda p: (build_knn_graph(p, k=12), medoid(p)[None]))
        res = sharded_range_search(mesh=mesh, corpus=corpus, queries=jnp.asarray(qs), r=4.0, cfg=rcfg)
        gt = exact_range_search(pts, jnp.asarray(qs), 4.0)
        ap = average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                               np.asarray(res.ids), np.asarray(res.count))
        assert ap > 0.8, ap
        print("OK")
    """)


def test_sharded_trainer_elastic_restore():
    run_sub("""
        import functools, shutil
        import numpy as np, jax, jax.numpy as jnp
        from repro.models import TransformerConfig, init_transformer, loss_fn
        from repro.optim import AdamWConfig
        from repro.train import Trainer, TrainerConfig
        from repro.data.lm import LMDataConfig, lm_batches
        from repro.dist.sharding import LM_RULES
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                                n_kv=4, d_head=16, d_ff=64, vocab=64,
                                dtype=jnp.float32, loss_chunk=16, remat=False)
        dcfg = LMDataConfig(vocab=64, seq_len=16, batch=4)
        loss = functools.partial(loss_fn, cfg=cfg)
        shutil.rmtree("/tmp/elastic_t", ignore_errors=True)
        # phase 1: unsharded (single-device) training -> checkpoint
        tr1 = Trainer(loss, init_transformer(jax.random.PRNGKey(0), cfg),
                      AdamWConfig(lr=1e-2, warmup_steps=2),
                      TrainerConfig(total_steps=10, ckpt_every=5, log_every=5,
                                    ckpt_dir="/tmp/elastic_t"))
        tr1.fit(lm_batches(dcfg))
        # phase 2: restore onto an 8-device mesh (elastic reshard)
        tr2 = Trainer(loss, init_transformer(jax.random.PRNGKey(1), cfg),
                      AdamWConfig(lr=1e-2, warmup_steps=2),
                      TrainerConfig(total_steps=14, ckpt_every=50, log_every=2,
                                    ckpt_dir="/tmp/elastic_t"),
                      mesh=mesh, param_rules=LM_RULES)
        assert tr2.maybe_restore() and tr2.step == 10
        out = tr2.fit(lm_batches(dcfg, start_step=10))
        assert out["final_step"] == 14
        assert np.isfinite(out["history"][-1]["loss"])
        print("OK")
    """)


def test_sharded_matches_host_union_exactly():
    """Parity beyond AP: sharded_range_search must equal running the same
    per-shard searches on the host and union-merging — same ids, counts."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import RangeConfig, SearchConfig, build_knn_graph
        from repro.core.graph import Graph, medoid
        from repro.core.range_search import range_search_fused
        from repro.dist.sharded_engine import build_sharded, sharded_range_search
        from repro.utils import INVALID_ID
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pts = jnp.asarray(np.random.default_rng(1).standard_normal((1600, 8)),
                          jnp.float32)
        qs = jnp.asarray(np.asarray(pts[:16]) + 0.02)
        rcfg = RangeConfig(search=SearchConfig(beam=16, max_beam=16,
                                               visit_cap=64,
                                               expand_width=2),
                           mode="greedy", result_cap=128)
        corpus = build_sharded(np.asarray(pts), 4,
                               lambda p: (build_knn_graph(p, k=8), medoid(p)[None]))
        res = sharded_range_search(mesh=mesh, corpus=corpus, queries=qs, r=2.5, cfg=rcfg)

        # host reference: same per-shard fused searches, numpy union-merge
        all_ids, all_dists, total = [], [], 0
        for s in range(4):
            r = range_search_fused(corpus=corpus.points[s],
                                   graph=Graph(neighbors=corpus.neighbors[s]),
                                   queries=qs, start_ids=corpus.start_ids[s],
                                   r=2.5, cfg=rcfg)
            gids = np.where(np.asarray(r.ids) == INVALID_ID, INVALID_ID,
                            np.asarray(r.ids) + int(corpus.offsets[s]))
            all_ids.append(gids); all_dists.append(np.asarray(r.dists))
            total = total + np.asarray(r.count)
        ids = np.concatenate(all_ids, axis=1)
        dists = np.concatenate(all_dists, axis=1)
        order = np.argsort(dists, axis=1, kind="stable")
        ids = np.take_along_axis(ids, order, axis=1)[:, :rcfg.result_cap]
        want_count = np.minimum(total, rcfg.result_cap)

        np.testing.assert_array_equal(np.asarray(res.count), want_count)
        got_ids = np.asarray(res.ids)
        for q in range(ids.shape[0]):
            k = want_count[q]
            assert set(got_ids[q, :k]) == set(ids[q, :k]), q
            assert (got_ids[q, k:] == INVALID_ID).all()
        assert int(want_count.sum()) > 0  # the check is not vacuous
        print("OK")
    """)


def test_sharded_mixed_radius_per_lane():
    """Per-query radii through the shard_map program: a mixed-radius batch
    must answer each lane exactly as a homogeneous batch at that lane's
    radius does, and an all-equal radius vector must be bitwise-identical
    to the scalar call (the radius vector shards along data with its
    queries and broadcasts to every model-axis shard)."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import RangeConfig, SearchConfig, build_knn_graph
        from repro.core.graph import medoid
        from repro.dist.sharded_engine import build_sharded, sharded_range_search
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pts = jnp.asarray(np.random.default_rng(2).standard_normal((1600, 8)),
                          jnp.float32)
        qs = jnp.asarray(np.asarray(pts[:16]) + 0.02)
        rcfg = RangeConfig(search=SearchConfig(beam=16, max_beam=16,
                                               visit_cap=64, expand_width=2),
                           mode="greedy", result_cap=128)
        corpus = build_sharded(np.asarray(pts), 4,
                               lambda p: (build_knn_graph(p, k=8), medoid(p)[None]))
        r_a, r_b = 1.5, 3.5
        radii = jnp.asarray(np.where(np.arange(16) % 2, r_b, r_a), jnp.float32)
        mixed = sharded_range_search(mesh=mesh, corpus=corpus, queries=qs, r=radii, cfg=rcfg)
        hom_a = sharded_range_search(mesh=mesh, corpus=corpus, queries=qs, r=r_a, cfg=rcfg)
        hom_b = sharded_range_search(mesh=mesh, corpus=corpus, queries=qs, r=r_b, cfg=rcfg)
        for name in ("ids", "dists", "count", "overflow"):
            got = np.asarray(getattr(mixed, name))
            wa = np.asarray(getattr(hom_a, name))
            wb = np.asarray(getattr(hom_b, name))
            for q in range(16):
                want = wb[q] if q % 2 else wa[q]
                np.testing.assert_array_equal(got[q], want, err_msg=f"{name}[{q}]")
        assert int(np.asarray(mixed.count).sum()) > 0  # not vacuous
        # all-equal vector == scalar, bitwise, across every result field
        vec = sharded_range_search(mesh=mesh, corpus=corpus, queries=qs, r=jnp.full((16,), r_a), cfg=rcfg)
        for name in ("ids", "dists", "count", "overflow", "n_visited",
                     "n_dist", "es_stopped", "phase2"):
            np.testing.assert_array_equal(np.asarray(getattr(vec, name)),
                                          np.asarray(getattr(hom_a, name)),
                                          err_msg=name)
        print("OK")
    """)


def test_sharded_quantized_two_pass():
    """Locally-quantized int8 shards through the shard_map program: the
    union result must contain only exactly-in-range ids (post-rerank, per
    the brute-force oracle) and must equal running the same per-shard
    quantized two-pass searches on the host (tree-sliced shards) with a
    numpy union-merge — including the summed rerank-band counters."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import RangeConfig, SearchConfig, build_knn_graph
        from repro.core.graph import Graph, medoid
        from repro.core.range_search import range_search_fused
        from repro.dist.sharded_engine import build_sharded, sharded_range_search
        from repro.utils import INVALID_ID
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pts = jnp.asarray(np.random.default_rng(3).standard_normal((1600, 8)),
                          jnp.float32)
        qs = jnp.asarray(np.asarray(pts[:16]) + 0.02)
        rcfg = RangeConfig(search=SearchConfig(beam=16, max_beam=16,
                                               visit_cap=64, expand_width=2),
                           mode="greedy", result_cap=128)
        corpus = build_sharded(np.asarray(pts), 4,
                               lambda p: (build_knn_graph(p, k=8), medoid(p)[None]),
                               corpus_dtype="int8")
        r = 2.5
        res = sharded_range_search(mesh=mesh, corpus=corpus, queries=qs, r=r, cfg=rcfg)
        ids = np.asarray(res.ids); cnt = np.asarray(res.count)
        d2 = np.sum((np.asarray(pts)[None, :, :]
                     - np.asarray(qs)[:, None, :]) ** 2, axis=-1)
        for q in range(16):  # zero false positives after the in-shard rerank
            got = ids[q][ids[q] != INVALID_ID]
            assert np.all(d2[q, got] <= r + 1e-5), q
        assert int(cnt.sum()) > 0
        assert int(np.asarray(res.n_rerank).sum()) >= 0

        # host reference: per-shard fused searches on tree-sliced shards
        all_ids, all_dists, total, nrr = [], [], 0, 0
        for s in range(4):
            shard = jax.tree.map(lambda x: x[s], corpus.points)
            rr = range_search_fused(corpus=shard,
                                    graph=Graph(neighbors=corpus.neighbors[s]),
                                    queries=qs, start_ids=corpus.start_ids[s],
                                    r=r, cfg=rcfg)
            gids = np.where(np.asarray(rr.ids) == INVALID_ID, INVALID_ID,
                            np.asarray(rr.ids) + int(corpus.offsets[s]))
            all_ids.append(gids); all_dists.append(np.asarray(rr.dists))
            total = total + np.asarray(rr.count)
            nrr = nrr + np.asarray(rr.n_rerank)
        hids = np.concatenate(all_ids, axis=1)
        hdists = np.concatenate(all_dists, axis=1)
        order = np.argsort(hdists, axis=1, kind="stable")
        hids = np.take_along_axis(hids, order, axis=1)[:, :rcfg.result_cap]
        want_count = np.minimum(total, rcfg.result_cap)
        np.testing.assert_array_equal(cnt, want_count)
        np.testing.assert_array_equal(np.asarray(res.n_rerank), nrr)
        for q in range(16):
            k = want_count[q]
            assert set(ids[q, :k]) == set(hids[q, :k]), q
            assert (ids[q, k:] == INVALID_ID).all()
        print("OK")
    """)


def test_spec_tree_divisibility_fallback():
    run_sub("""
        import jax, jax.numpy as jnp
        from repro.dist.sharding import LM_RULES, spec_tree, DP, TP
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = {"layers": {"attn": {"wk": jnp.zeros((6, 32, 3, 16))}},
                  "b3": jnp.zeros((1,))}
        specs = spec_tree(params, LM_RULES, mesh)
        # 3 kv heads don't divide model=4 -> TP dropped (KV replication)
        assert specs["layers"]["attn"]["wk"][2] is None, specs
        assert specs["layers"]["attn"]["wk"][1] == DP
        print("OK")
    """)


def test_sharded_filtered_matches_postfiltered_oracle():
    """Filtered sharded range search: every shard evaluates the per-query
    predicate locally before its rows join the union merge, so the merged
    result equals the post-filtered brute-force oracle. Also: the all-pass
    filter is bitwise-identical to running without one."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import RangeConfig, SearchConfig, build_knn_graph
        from repro.core import all_pass_filter, make_label_filter, pack_labels
        from repro.core.graph import medoid
        from repro.dist.sharded_engine import build_sharded, sharded_range_search
        from repro.utils import INVALID_ID
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        NL = 8
        rng = np.random.default_rng(5)
        pts = jnp.asarray(rng.standard_normal((1600, 8)), jnp.float32)
        raw = [sorted(int(x) for x in
                      rng.choice(NL, size=int(rng.integers(1, 3)),
                                 replace=False))
               for _ in range(1600)]
        qs = jnp.asarray(np.asarray(pts[:16]) + 0.02)
        rcfg = RangeConfig(search=SearchConfig(beam=16, max_beam=16,
                                               visit_cap=64, expand_width=2),
                           mode="greedy", result_cap=128)
        corpus = build_sharded(
            np.asarray(pts), 4,
            lambda p: (build_knn_graph(p, k=8), medoid(p)[None]),
            labels=pack_labels(raw, NL))
        entries = [[q % NL] if q % 2 == 0 else [q % NL, (q + 3) % NL]
                   for q in range(16)]
        modes = ["and" if q % 2 == 0 else "or" for q in range(16)]
        filt = make_label_filter(entries, NL, modes=modes)
        plain = sharded_range_search(mesh=mesh, corpus=corpus, queries=qs,
                                     r=2.5, cfg=rcfg)
        res = sharded_range_search(mesh=mesh, corpus=corpus, queries=qs,
                                   r=2.5, cfg=rcfg, label_filter=filt)

        # oracle: post-filter the unfiltered sharded result. The filtered
        # traversal is identical to the unfiltered one on the collective
        # path (no entry reseeding under shard_map), so set equality holds.
        ids_p = np.asarray(plain.ids)
        ids_f = np.asarray(res.ids)
        sets = [set(r) for r in raw]
        nonempty = 0
        for q in range(16):
            pred = set(entries[q])
            keep = (lambda i: pred <= sets[i]) if modes[q] == "and" \\
                else (lambda i: bool(pred & sets[i]))
            want = {int(i) for i in ids_p[q][ids_p[q] != INVALID_ID]
                    if keep(int(i))}
            got = {int(i) for i in ids_f[q][ids_f[q] != INVALID_ID]}
            assert got == want, (q, sorted(got ^ want)[:5])
            assert int(np.asarray(res.count)[q]) == len(want)
            nonempty += bool(want)
        assert nonempty >= 8  # the check is not vacuous

        # all-pass filter: bitwise identity with the unfiltered run
        ap = sharded_range_search(mesh=mesh, corpus=corpus, queries=qs,
                                  r=2.5, cfg=rcfg,
                                  label_filter=all_pass_filter(16, NL))
        for f in ("ids", "dists", "count", "overflow", "n_visited", "n_dist",
                  "es_stopped", "phase2", "n_rerank"):
            np.testing.assert_array_equal(np.asarray(getattr(ap, f)),
                                          np.asarray(getattr(plain, f)), f)
        print("OK")
    """)
