"""Brute-force oracle + property/metamorphic harness for range retrieval.

Everything here exercises the *per-query radius* contract: each query in a
batch carries its own radius, and every layer must answer each lane at its
own r. Two oracles back the checks:

* ``exact_range_search`` (core.ground_truth) — the blocked matmul-form
  exact scan; source of AP ground truth and counts.
* a diff-form ``point_dist`` scan — bit-identical to the arithmetic the
  search's ``gather_dist`` uses, so membership and returned-distance checks
  hold to 1e-5 instead of the matmul form's ~1e-3 cancellation error.

The harness's backbone invariant: a radius *vector* with all-equal entries
must reproduce the scalar-radius outputs **bitwise** (scalar call sites
normalize through the same broadcast, so hetero- and homogeneous batches run
the same program).

Heavier randomized sweeps are marked ``slow`` and excluded from the default
pytest run (see pyproject addopts); CI runs them in a dedicated step.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BuildConfig, RangeConfig, RangeSearchEngine, SearchConfig,
    average_precision, beam_search_batch, build_vamana, exact_range_search,
    greedy_search, quantize_corpus,
)
from repro.core.distances import point_dist
from repro.core.range_search import _needs_phase2
from repro.utils import INVALID_ID

MODES = ("beam", "doubling", "greedy")
METRICS = ("l2", "ip")
EXPAND_WIDTHS = (1, 4)

# AP-vs-oracle floors, calibrated on the fixed corpus below with margin
# (beam is the paper's weak baseline by design; ip graphs navigate worse)
AP_FLOOR = {
    ("beam", "l2"): 0.30, ("doubling", "l2"): 0.70, ("greedy", "l2"): 0.70,
    ("beam", "ip"): 0.28, ("doubling", "ip"): 0.42, ("greedy", "ip"): 0.40,
}


def _toy(n=600, d=10, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((8, d)).astype(np.float32) * 3
    pts = (centers[rng.integers(0, 8, n)] +
           rng.standard_normal((n, d)).astype(np.float32) * 0.4)
    return jnp.asarray(pts)


_CORPUS: dict = {}


def _corpus(metric):
    """(pts, engine, queries, exact (Q, N) diff-form dists), cached per
    metric. Module-level cache instead of a fixture so the hypothesis stub
    (plain-function wrappers) can share it too."""
    if metric not in _CORPUS:
        pts = _toy()
        graph = build_vamana(pts, BuildConfig(max_degree=16, beam=32,
                                              insert_batch=256, metric=metric))
        eng = RangeSearchEngine.from_graph(pts, graph, metric=metric)
        qs = pts[:32] + 0.01
        exact = np.asarray(point_dist(pts[None, :, :],
                                      np.asarray(qs)[:, None, :], metric))
        _CORPUS[metric] = (pts, eng, qs, exact)
    return _CORPUS[metric]


def _mixed_radii(exact, lo_q=0.02, hi_q=0.10):
    """Per-query radii at per-lane quantiles of that lane's own distance
    distribution — every lane targets a different match count."""
    q = exact.shape[0]
    quant = np.linspace(lo_q, hi_q, q)
    return np.array([np.quantile(exact[i], quant[i]) for i in range(q)],
                    np.float32)


def _cfg(mode, metric, expand_width, result_cap=512):
    return RangeConfig(
        search=SearchConfig(beam=16, max_beam=64 if mode == "doubling" else 16,
                            visit_cap=128, metric=metric,
                            expand_width=expand_width),
        mode=mode, result_cap=result_cap)


def _rows(res):
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    return ids, dists, np.asarray(res.count), np.asarray(res.overflow)


def _check_invariants(res, exact, radii, atol=1e-5):
    """(a) membership, (b) exact distances, (c) count bookkeeping.

    Tolerance is 1e-5 absolute plus 1e-6 relative: the oracle's broadcast
    scan and the search's gathered tiles sum f32 terms in different orders,
    which costs ~1 ulp — O(1e-7) relative, visible only at ip's O(100)
    magnitudes."""
    ids, dists, count, over = _rows(res)
    for i in range(ids.shape[0]):
        valid = ids[i] != INVALID_ID
        got = ids[i][valid]
        # (a) every returned id is truly in range (diff-form, same arithmetic
        # as the search's own decisions)
        tol = atol + 1e-6 * abs(float(radii[i]))
        assert np.all(exact[i, got] <= radii[i] + tol), (
            f"lane {i}: out-of-range ids at r={radii[i]}")
        # (b) returned dists are the exact distances
        np.testing.assert_allclose(dists[i][valid], exact[i, got], rtol=1e-6,
                                   atol=atol)
        # (c) count == number of valid rows (overflow lanes cap the buffer,
        # count still equals the rows actually returned)
        if not over[i]:
            assert count[i] == valid.sum(), f"lane {i}"
        else:
            assert valid.sum() <= count[i]


def _assert_bitwise_equal(a, b, context=""):
    for name in ("ids", "dists", "count", "overflow", "n_visited", "n_dist",
                 "es_stopped", "phase2", "n_rerank"):
        av, bv = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        assert np.array_equal(av, bv), f"{context}: {name} differs"


# ---------------------------------------------------------------------------
# oracle invariants: all modes x metrics x expand widths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("expand_width", EXPAND_WIDTHS)
def test_oracle_invariants(mode, metric, expand_width):
    pts, eng, qs, exact = _corpus(metric)
    radii = _mixed_radii(exact)
    cfg = _cfg(mode, metric, expand_width)
    res = eng.range(qs, jnp.asarray(radii), cfg=cfg)
    _check_invariants(res, exact, radii)

    # (d) AP against the exact oracle clears the mode floor
    gt = exact_range_search(pts, qs, jnp.asarray(radii), metric)
    ap = average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                           np.asarray(res.ids), np.asarray(res.count))
    assert ap >= AP_FLOOR[(mode, metric)], (mode, metric, expand_width, ap)

    # (e) all-equal radius vector is bitwise-identical to the scalar call
    r0 = float(np.median(radii))
    res_s = eng.range(qs, r0, cfg=cfg)
    res_v = eng.range(qs, jnp.full(qs.shape[0], r0, jnp.float32), cfg=cfg)
    _assert_bitwise_equal(res_s, res_v, f"{mode}/{metric}/E={expand_width}")


@pytest.mark.parametrize("mode", MODES)
def test_fused_matches_compacted_mixed_radii(mode):
    """The single-program path answers mixed-radius batches like the
    host-compacted path (same sets; compaction is a perf decision)."""
    pts, eng, qs, exact = _corpus("l2")
    radii = jnp.asarray(_mixed_radii(exact))
    cfg = _cfg(mode, "l2", 4)
    a = eng.range(qs, radii, cfg=cfg, compacted=True)
    b = eng.range(qs, radii, cfg=cfg, compacted=False)
    np.testing.assert_array_equal(np.asarray(a.count), np.asarray(b.count))
    for ra, rb in zip(np.asarray(a.ids), np.asarray(b.ids)):
        assert set(ra[ra != INVALID_ID]) == set(rb[rb != INVALID_ID])


# ---------------------------------------------------------------------------
# quantized corpus: guard-band two-pass oracle
# ---------------------------------------------------------------------------

_QENGINE: dict = {}


def _qengine(metric):
    """Int8 engine sharing the f32 engine's graph and entry points, so the
    only difference under test is corpus storage + the two-pass pipeline."""
    pts, eng, qs, exact = _corpus(metric)
    if metric not in _QENGINE:
        _QENGINE[metric] = RangeSearchEngine(
            points=quantize_corpus(pts), graph=eng.graph,
            start_ids=eng.start_ids, metric=metric)
    return pts, eng, _QENGINE[metric], qs, exact


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("compacted", (True, False))
def test_quantized_guard_band_oracle(mode, metric, compacted):
    """The quantized two-pass contract, against the exact-distance oracle,
    with mixed per-query radii:

    (a) *membership superset before rerank*: the post-rerank set is a
        subset of the rerank-disabled (certified keep-band) set;
    (b) *zero false positives after rerank*: every returned id is exactly
        in range;
    (c) *zero false negatives inside the guard band*: the post-rerank set
        EQUALS the keep-band set filtered by the exact oracle — no true
        member the approximate search discovered is ever dropped;
    (d) returned distances never exceed the exact distance (they are
        certified lower bounds, replaced by exact values in the band);
    (e) AP stays within the quantization budget of the f32 engine on the
        same graph.
    """
    pts, eng_f, eng_q, qs, exact = _qengine(metric)
    radii = _mixed_radii(exact)
    cfg = _cfg(mode, metric, 4)
    res = eng_q.range(qs, jnp.asarray(radii), cfg=cfg, compacted=compacted)
    res_pre = eng_q.range(qs, jnp.asarray(radii),
                          cfg=dataclasses.replace(cfg, rerank=False),
                          compacted=compacted)
    ids, dists, count, over = _rows(res)
    ids_pre, _, _, over_pre = _rows(res_pre)
    assert np.asarray(res.n_rerank).sum() > 0  # the band is exercised
    for i in range(ids.shape[0]):
        got = ids[i][ids[i] != INVALID_ID]
        tol = 1e-5 + 1e-6 * abs(float(radii[i]))
        # (b) exact membership
        assert np.all(exact[i, got] <= radii[i] + tol), f"lane {i}"
        # (d) lower-bound property of returned distances
        d_i = dists[i][ids[i] != INVALID_ID]
        assert np.all(d_i <= exact[i, got] + tol), f"lane {i}"
        if over[i] or over_pre[i]:
            continue  # capped buffers may drop members legitimately
        s_post = set(got.tolist())
        s_pre = set(ids_pre[i][ids_pre[i] != INVALID_ID].tolist())
        # (a) superset before rerank
        assert s_post <= s_pre, f"lane {i}"
        # (c) exact set equality after rerank
        want = {j for j in s_pre if exact[i, j] <= radii[i] + tol}
        assert s_post == want, f"lane {i}: {sorted(s_post ^ want)}"
        assert count[i] == len(s_post)

    # (e) AP parity with the f32 engine on the same graph
    gt = exact_range_search(pts, qs, jnp.asarray(radii), metric)
    res_f = eng_f.range(qs, jnp.asarray(radii), cfg=cfg, compacted=compacted)
    ap_q = average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                             np.asarray(res.ids), np.asarray(res.count))
    ap_f = average_precision(np.asarray(gt[0]), np.asarray(gt[2]),
                             np.asarray(res_f.ids), np.asarray(res_f.count))
    assert ap_q >= ap_f - 0.01, (mode, metric, ap_q, ap_f)


def test_quantized_fused_matches_compacted():
    """Both rerank implementations (in-program full-buffer vs host-side
    pair compaction) produce the same sets — compaction is a perf choice."""
    pts, _, eng_q, qs, exact = _qengine("l2")
    radii = jnp.asarray(_mixed_radii(exact))
    cfg = _cfg("greedy", "l2", 4)
    a = eng_q.range(qs, radii, cfg=cfg, compacted=True)
    b = eng_q.range(qs, radii, cfg=cfg, compacted=False)
    np.testing.assert_array_equal(np.asarray(a.count), np.asarray(b.count))
    np.testing.assert_array_equal(np.asarray(a.n_rerank),
                                  np.asarray(b.n_rerank))
    for ra, rb in zip(np.asarray(a.ids), np.asarray(b.ids)):
        assert set(ra[ra != INVALID_ID]) == set(rb[rb != INVALID_ID])


@pytest.mark.parametrize("quantized", (False, True))
def test_greedy_reference_matches_fused(quantized):
    """Pins the greedy E=1 reference dataflow (including its exact-bitset
    membership fast path — see _greedy_step_reference) to the fused E>=2
    path: from identical phase-1 states, both must produce identical result
    SETS (append order may differ), so ``expand_width=1`` stays a valid
    baseline under f32 and quantized corpora alike."""
    pts, eng_f, eng_q, qs, exact = _qengine("l2")
    eng = eng_q if quantized else eng_f
    radii = jnp.asarray(_mixed_radii(exact))
    cap, rounds = 2048, 8192  # ample: no cap/budget overflow in the toy set
    scfg4 = SearchConfig(beam=16, max_beam=16, visit_cap=128, metric="l2",
                         expand_width=4)
    scfg1 = dataclasses.replace(scfg4, expand_width=1)
    st = beam_search_batch(eng.points, eng.graph, qs, eng.start_ids,
                           radii, scfg4)
    active = jax.vmap(lambda st_, r_: _needs_phase2(st_, r_, 1.0))(st, radii)
    run = lambda scfg: jax.vmap(
        lambda q_, r_, st_, a_: greedy_search(
            eng.points, eng.graph, q_, r_, st_, cap, rounds, scfg, a_)
    )(qs, radii, st, active)
    g1, g4 = run(scfg1), run(scfg4)
    np.testing.assert_array_equal(np.asarray(g1.res_count),
                                  np.asarray(g4.res_count))
    np.testing.assert_array_equal(np.asarray(g1.overflow),
                                  np.asarray(g4.overflow))
    # active lanes must finish within cap/budget for set equality to be the
    # contract (inactive lanes no-op and keep their seed buffers)
    assert not (np.asarray(g1.overflow) & np.asarray(active)).any()
    assert np.asarray(active).any()
    ids1, ids4 = np.asarray(g1.res_ids), np.asarray(g4.res_ids)
    for i in range(ids1.shape[0]):
        assert (set(ids1[i][ids1[i] != INVALID_ID])
                == set(ids4[i][ids4[i] != INVALID_ID])), f"lane {i}"


# ---------------------------------------------------------------------------
# metamorphic properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ("beam", "greedy"))
def test_radius_monotonicity(mode):
    """r1 <= r2 per lane => result set at r1 is a subset of the set at r2,
    up to result_cap/budget overflow (flagged lanes are exempt: a capped
    buffer legitimately drops members). Doubling is excluded by design —
    its widening schedule changes the traversal itself with r."""
    pts, eng, qs, exact = _corpus("l2")
    r1 = _mixed_radii(exact, 0.02, 0.06)
    r2 = (r1 * 1.5).astype(np.float32)
    cfg = _cfg(mode, "l2", 4)
    a = eng.range(qs, jnp.asarray(r1), cfg=cfg)
    b = eng.range(qs, jnp.asarray(r2), cfg=cfg)
    ids_a, _, _, _ = _rows(a)
    ids_b, _, _, over_b = _rows(b)
    for i in range(ids_a.shape[0]):
        if over_b[i]:
            continue
        sa = set(ids_a[i][ids_a[i] != INVALID_ID])
        sb = set(ids_b[i][ids_b[i] != INVALID_ID])
        assert sa <= sb, f"lane {i}: {sorted(sa - sb)} lost when r grew"


def test_lane_permutation_invariance():
    """Shuffling (queries, radii) shuffles the outputs identically — no lane
    reads another lane's radius."""
    pts, eng, qs, exact = _corpus("l2")
    radii = _mixed_radii(exact)
    cfg = _cfg("greedy", "l2", 4)
    res = eng.range(qs, jnp.asarray(radii), cfg=cfg)
    perm = np.random.default_rng(1).permutation(qs.shape[0])
    res_p = eng.range(qs[perm], jnp.asarray(radii[perm]), cfg=cfg)
    for name in ("ids", "dists", "count", "overflow"):
        np.testing.assert_array_equal(np.asarray(getattr(res, name))[perm],
                                      np.asarray(getattr(res_p, name)),
                                      err_msg=name)


def test_padding_invariance():
    """Appending pad lanes (the server's bucket padding) never perturbs the
    real lanes' outputs."""
    pts, eng, qs, exact = _corpus("l2")
    radii = _mixed_radii(exact)
    n = qs.shape[0]
    cfg = _cfg("greedy", "l2", 4)
    res = eng.range(qs, jnp.asarray(radii), cfg=cfg)
    q_pad = jnp.concatenate([qs, jnp.broadcast_to(qs[:1], (5,) + qs.shape[1:])])
    r_pad = np.concatenate([radii, np.repeat(radii[:1], 5)])
    res_p = eng.range(q_pad, jnp.asarray(r_pad), cfg=cfg)
    for name in ("ids", "dists", "count", "overflow"):
        np.testing.assert_array_equal(np.asarray(getattr(res, name)),
                                      np.asarray(getattr(res_p, name))[:n],
                                      err_msg=name)


# ---------------------------------------------------------------------------
# randomized property sweeps (hypothesis; the stub provides seeded draws)
# ---------------------------------------------------------------------------

@given(st.floats(0.01, 0.12), st.floats(1.1, 2.5), st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_random_radii_invariants(lo_q, spread, seed):
    """Random per-lane radius assignments keep the oracle invariants."""
    pts, eng, qs, exact = _corpus("l2")
    rng = np.random.default_rng(seed)
    base = np.quantile(exact, lo_q, axis=1)
    radii = (base * rng.uniform(1.0, spread, qs.shape[0])).astype(np.float32)
    cfg = _cfg("greedy", "l2", 4)
    res = eng.range(qs, jnp.asarray(radii), cfg=cfg)
    _check_invariants(res, exact, radii)


@pytest.mark.slow
@given(st.integers(0, 2), st.integers(0, 1), st.floats(0.01, 0.15),
       st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_slow_sweep_all_modes(mode_i, metric_i, lo_q, seed):
    """Heavier randomized sweep over modes x metrics (off the fast path)."""
    mode, metric = MODES[mode_i], METRICS[metric_i]
    pts, eng, qs, exact = _corpus(metric)
    rng = np.random.default_rng(seed)
    base = np.quantile(exact, max(lo_q, 1.5 / exact.shape[1]), axis=1)
    radii = (base * rng.uniform(1.0, 1.5, qs.shape[0])).astype(np.float32)
    cfg = _cfg(mode, metric, int(rng.integers(1, 6)))
    res = eng.range(qs, jnp.asarray(radii), cfg=cfg)
    _check_invariants(res, exact, radii)
    # scalar/vector bitwise equivalence at a random shared radius
    r0 = float(np.median(radii))
    _assert_bitwise_equal(
        eng.range(qs, r0, cfg=cfg),
        eng.range(qs, jnp.full(qs.shape[0], r0, jnp.float32), cfg=cfg),
        f"slow {mode}/{metric}")


# ---------------------------------------------------------------------------
# continuous serving vs the oracle (effort-bucketed admission)
# ---------------------------------------------------------------------------

def test_effort_bucketed_continuous_batch_matches_oracle():
    """A mixed cheap/heavy batch served through the continuous pool with
    effort-predicted admission equals the brute-force oracle per request.

    The effort split only changes *batch composition* (which phase-1
    dispatch a request rides), never results — so every response must carry
    exactly the in-range set, and the stats must prove both buckets and the
    lane pool actually ran (a vacuous pass with pool_admitted == 0 would
    test nothing)."""
    from repro.models import EffortPredictor
    from repro.serve import RangeServer, Request, ServerConfig

    pts = _toy(n=1200, d=10, seed=3)
    graph = build_vamana(pts, BuildConfig(max_degree=24, beam=48,
                                          insert_batch=256, two_pass=True))
    eng = RangeSearchEngine.from_graph(pts, graph)
    qs = np.asarray(pts[:32]) + 0.01
    exact = np.asarray(point_dist(pts[None, :, :], qs[:, None, :], "l2"))

    # radii in SQUARED-distance units: heavy lanes target 96 matches
    # (saturating a beam of 48 -> phase 2 -> the lane pool), cheap lanes 3.
    # Each radius sits midway between the k-th and (k+1)-th nearest
    # distances so the in-range set is unambiguous at f32 precision (a
    # quantile can land within float noise of an actual distance, turning
    # the oracle comparison into a knife-edge membership call).
    srt = np.sort(exact, axis=1)
    r_heavy = (srt[:, 95] + srt[:, 96]) / 2
    r_point = (srt[:, 2] + srt[:, 3]) / 2
    radii = np.where(np.arange(32) % 4 == 0, r_heavy, r_point)
    radii = radii.astype(np.float32)

    # fit the effort regressor on held-out traffic with exact counts
    tq = np.asarray(pts[200:456])
    t_exact = np.asarray(point_dist(pts[None, :, :], tq[:, None, :], "l2"))
    t_srt = np.sort(t_exact, axis=1)
    t_radii = np.concatenate([(t_srt[:128, 95] + t_srt[:128, 96]) / 2,
                              (t_srt[128:, 2] + t_srt[128:, 3]) / 2,
                              ]).astype(np.float32)
    t_counts = (t_exact <= t_radii[:, None]).sum(axis=1)
    effort = EffortPredictor.fit(tq, t_radii, t_counts)

    cfg = RangeConfig(search=SearchConfig(beam=48, max_beam=48,
                                          visit_cap=384),
                      mode="greedy", result_cap=512)
    srv = RangeServer(eng, cfg,
                      ServerConfig(max_batch=16, continuous=True, lanes=4,
                                   slice_rounds=4, effort_threshold=16.0),
                      effort=effort)
    for i in range(32):
        srv.submit(Request(req_id=i, query=qs[i], radius=float(radii[i])))
    resp = {r.req_id: r for r in srv.run_until_drained()}
    assert len(resp) == 32

    for i in range(32):
        want = set(np.nonzero(exact[i] <= radii[i])[0].tolist())
        got = set(resp[i].ids.tolist())
        assert not resp[i].overflow
        assert got == want, (f"req {i} (r={radii[i]:.3f}): "
                             f"missing {sorted(want - got)[:5]}, "
                             f"extra {sorted(got - want)[:5]}")
        np.testing.assert_allclose(np.asarray(resp[i].dists),
                                   exact[i, np.asarray(resp[i].ids)],
                                   rtol=1e-6, atol=1e-5)
    # the split and the pool genuinely ran
    assert srv.stats["bucket_cheap"] > 0 and srv.stats["bucket_heavy"] > 0
    assert srv.stats["pool_admitted"] > 0
    # every greedy lane retires exactly once (pool lanes + the one-shot
    # fallback for saturated lanes that arrived at a full pool)
    assert (srv.stats["pool_retired"] ==
            srv.stats["pool_admitted"] + srv.stats["pool_oneshot"])


# ---------------------------------------------------------------------------
# filtered range retrieval vs the post-filtered brute-force oracle
# ---------------------------------------------------------------------------

N_LABELS = 8
_FILTER_RIG: dict = {}


def _filter_rig():
    """Labeled exact-recovery rig: well-built two_pass graph, beam >= ball
    size, and radii midway between consecutive sorted distances — the
    unfiltered walk recovers each in-range set exactly (same recipe as the
    continuous-batch oracle test above), so filtered results are provable
    EQUAL to the post-filtered brute-force oracle rather than merely close.
    Returns (pts, raw label lists, f32 engine, int8 engine sharing the
    graph, queries, exact dists (Q, N), mixed radii (Q,))."""
    if not _FILTER_RIG:
        from repro.core import pack_labels

        pts = _toy(n=1200, d=10, seed=3)
        graph = build_vamana(pts, BuildConfig(max_degree=24, beam=48,
                                              insert_batch=256,
                                              two_pass=True))
        rng = np.random.default_rng(11)
        raw = [sorted(int(x) for x in
                      rng.choice(N_LABELS, size=int(rng.integers(1, 3)),
                                 replace=False))
               for _ in range(pts.shape[0])]
        eng = RangeSearchEngine.from_graph(pts, graph,
                                           labels=pack_labels(raw, N_LABELS))
        eng_q = RangeSearchEngine(points=quantize_corpus(pts),
                                  graph=eng.graph, start_ids=eng.start_ids,
                                  labels=eng.labels, metric="l2")
        qs = jnp.asarray(np.asarray(pts[:24]) + 0.01)
        exact = np.asarray(point_dist(pts[None, :, :],
                                      np.asarray(qs)[:, None, :], "l2"))
        # mixed radii: lane i targets between 16 and 96 matches, each radius
        # midway between the k-th and (k+1)-th sorted distances so the
        # in-range set is unambiguous at f32 precision
        srt = np.sort(exact, axis=1)
        ks = np.linspace(16, 96, qs.shape[0]).astype(int)
        lanes = np.arange(qs.shape[0])
        radii = ((srt[lanes, ks] + srt[lanes, ks + 1]) / 2).astype(np.float32)
        _FILTER_RIG.update(pts=pts, raw=raw, eng=eng, eng_q=eng_q, qs=qs,
                           exact=exact, radii=radii)
    r = _FILTER_RIG
    return (r["pts"], r["raw"], r["eng"], r["eng_q"], r["qs"], r["exact"],
            r["radii"])


def _rig_cfg(**kw):
    return RangeConfig(search=SearchConfig(beam=48, max_beam=48,
                                           visit_cap=384),
                       mode="greedy", result_cap=512, **kw)


def _rig_filter(n_queries):
    """Per-lane predicates mixing both modes and both selectivity regimes:
    even lanes AND a single label (narrow posting list — entry seeding /
    fallback territory), odd lanes OR two labels (broad)."""
    from repro.core import make_label_filter

    entries, modes = [], []
    for q in range(n_queries):
        if q % 2 == 0:
            entries.append([q % N_LABELS])
            modes.append("and")
        else:
            entries.append([q % N_LABELS, (q + 3) % N_LABELS])
            modes.append("or")
    return make_label_filter(entries, N_LABELS, modes=modes), entries, modes


def _matches(raw, entries, modes, q, i):
    lab = set(raw[i])
    pred = set(entries[q])
    return pred <= lab if modes[q] == "and" else bool(pred & lab)


def _oracle_postfilter(raw, exact, radii, entries, modes, q):
    ball = np.nonzero(exact[q] <= radii[q])[0]
    return {int(i) for i in ball if _matches(raw, entries, modes, q, int(i))}


@pytest.mark.parametrize("quantized", (False, True))
@pytest.mark.parametrize("compacted", (True, False))
def test_filtered_matches_postfiltered_oracle(quantized, compacted):
    """Filtered range search == brute-force oracle post-filter: same ids
    and consistent counts for f32 and int8 corpora, mixed per-query radii,
    and both execution paths. Filtered-out points may still route the walk
    but must never surface. Distances are exact on the f32 engine; on the
    quantized engine they honor the guard-band contract — certified lower
    bounds, replaced by exact values inside the rerank band."""
    pts, raw, eng, eng_q, qs, exact, radii = _filter_rig()
    e = eng_q if quantized else eng
    filt, entries, modes = _rig_filter(qs.shape[0])
    res = e.range(qs, jnp.asarray(radii), cfg=_rig_cfg(),
                  compacted=compacted, filter=filt)
    ids, dists, count, over = _rows(res)
    assert not over.any()
    for q in range(qs.shape[0]):
        valid = ids[q] != INVALID_ID
        got = ids[q][valid]
        want = _oracle_postfilter(raw, exact, radii, entries, modes, q)
        assert set(got.tolist()) == want, (
            f"lane {q}: missing {sorted(want - set(got))[:5]}, "
            f"extra {sorted(set(got) - want)[:5]}")
        assert count[q] == len(want)
        if quantized:  # lower-bound property, exact inside the rerank band
            assert np.all(dists[q][valid] <= exact[q, got] + 1e-5), f"lane {q}"
        else:
            np.testing.assert_allclose(dists[q][valid], exact[q, got],
                                       rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("compacted", (True, False))
def test_filtered_allpass_bitwise_identical_to_unfiltered(compacted):
    """The all-pass predicate (AND over the empty mask) is bitwise-neutral
    on every RangeResult field — attaching labels to an engine can never
    change unfiltered answers."""
    from repro.core import all_pass_filter

    pts, raw, eng, eng_q, qs, exact, radii = _filter_rig()
    rv = jnp.asarray(radii)
    ap = all_pass_filter(qs.shape[0], N_LABELS)
    a = eng.range(qs, rv, cfg=_rig_cfg(), compacted=compacted)
    b = eng.range(qs, rv, cfg=_rig_cfg(), compacted=compacted, filter=ap)
    _assert_bitwise_equal(a, b, f"all-pass compacted={compacted}")


def test_filtered_superset_predicate_monotonicity():
    """Widening a predicate can only grow the result set: OR over a
    superset of labels is a superset result; AND over a superset of labels
    is a subset result. Structural on the fused path — the traversal is
    predicate-independent, only the result gate moves."""
    from repro.core import make_label_filter

    pts, raw, eng, eng_q, qs, exact, radii = _filter_rig()
    n = qs.shape[0]
    rv = jnp.asarray(radii)
    la = [[q % N_LABELS] for q in range(n)]
    lb = [[q % N_LABELS, (q + 1) % N_LABELS] for q in range(n)]
    f_or_a = make_label_filter(la, N_LABELS, modes="or")
    f_or_b = make_label_filter(lb, N_LABELS, modes="or")
    f_and_a = make_label_filter(la, N_LABELS, modes="and")
    f_and_b = make_label_filter(lb, N_LABELS, modes="and")
    get = lambda f: _rows(eng.range(qs, rv, cfg=_rig_cfg(),
                                    compacted=False, filter=f))[0]
    or_a, or_b = get(f_or_a), get(f_or_b)
    and_a, and_b = get(f_and_a), get(f_and_b)
    for q in range(n):
        s = lambda ids: set(ids[q][ids[q] != INVALID_ID].tolist())
        assert s(or_a) <= s(or_b), f"lane {q}: OR shrank under more labels"
        assert s(and_b) <= s(and_a), f"lane {q}: AND grew under more labels"
        # the two modes agree on single-label predicates
        assert s(or_a) == s(and_a), f"lane {q}"


def test_filtered_selectivity_fallback_matches_walk():
    """With ``filter_threshold`` high enough to reroute the narrow AND
    lanes, the per-lane brute-scan fallback returns exactly the walk
    path's sets (both equal the post-filtered oracle) — and its lanes
    visibly bypass the graph (n_visited == 0), proving the dispatch
    actually took the fallback."""
    pts, raw, eng, eng_q, qs, exact, radii = _filter_rig()
    rv = jnp.asarray(radii)
    filt, entries, modes = _rig_filter(qs.shape[0])
    walk = eng.range(qs, rv, cfg=_rig_cfg(filter_threshold=0.0),
                     compacted=True, filter=filt)
    fb = eng.range(qs, rv, cfg=_rig_cfg(filter_threshold=0.25),
                   compacted=True, filter=filt)
    ids_w, _, cnt_w, _ = _rows(walk)
    ids_f, dists_f, cnt_f, _ = _rows(fb)
    nv = np.asarray(fb.n_visited)
    # narrow single-label AND lanes (~19% of the corpus matches) fall
    # back; broad two-label OR lanes (~36%) stay on the walk
    assert (nv[::2] == 0).all(), "fallback lanes should not touch the graph"
    assert (nv[1::2] > 0).all(), "walk lanes should traverse"
    np.testing.assert_array_equal(cnt_w, cnt_f)
    for q in range(qs.shape[0]):
        sw = set(ids_w[q][ids_w[q] != INVALID_ID].tolist())
        sf = set(ids_f[q][ids_f[q] != INVALID_ID].tolist())
        assert sw == sf, f"lane {q}"
        want = _oracle_postfilter(raw, exact, radii, entries, modes, q)
        assert sf == want, f"lane {q} vs oracle"
        valid = ids_f[q] != INVALID_ID
        np.testing.assert_allclose(dists_f[q][valid],
                                   exact[q, ids_f[q][valid]],
                                   rtol=1e-6, atol=1e-5)


def test_filtered_composes_with_tombstones():
    """Labels and tombstones gate the same result stage independently:
    filtered search over a tombstoned corpus returns (oracle ball minus
    dead) post-filtered — deleted points neither answer nor break the
    predicate bookkeeping."""
    from repro.core.bitset import bitset_add

    pts, raw, eng, eng_q, qs, exact, radii = _filter_rig()
    n = pts.shape[0]
    filt, entries, modes = _rig_filter(qs.shape[0])
    dead = np.arange(0, n, 7, dtype=np.int32)  # kill every 7th point
    tomb = bitset_add(jnp.zeros(((n + 31) // 32,), jnp.uint32),
                      jnp.asarray(dead), jnp.ones(dead.shape, bool))
    res = eng.range(qs, jnp.asarray(radii), cfg=_rig_cfg(), compacted=False,
                    tombstones=tomb, filter=filt)
    ids, _, count, _ = _rows(res)
    dead_set = set(dead.tolist())
    for q in range(qs.shape[0]):
        got = set(ids[q][ids[q] != INVALID_ID].tolist())
        want = _oracle_postfilter(raw, exact, radii, entries, modes, q)
        want -= dead_set
        assert got == want, f"lane {q}"
        assert count[q] == len(want)
