"""Data pipelines: determinism, paper-matched corpus signatures."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import exact_range_search
from repro.core.radius import default_grid, match_histogram, select_radius, sweep
from repro.data.lm import LMDataConfig, lm_batch
from repro.data.recsys import RecsysDataConfig, recsys_batch
from repro.data.synthetic import PROFILES, dataset_names, make_corpus


def test_lm_batches_deterministic_by_step():
    cfg = LMDataConfig(vocab=100, seq_len=8, batch=2, seed=7)
    a, b = lm_batch(cfg, 5), lm_batch(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_batch(cfg, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < 100 and a["tokens"].min() >= 0


def test_recsys_batches_deterministic_and_shaped():
    cfg = RecsysDataConfig(n_dense=3, n_sparse=5, vocab=50, batch=16)
    a = recsys_batch(cfg, 0)
    b = recsys_batch(cfg, 0)
    np.testing.assert_array_equal(a["sparse"], b["sparse"])
    assert a["sparse"].shape == (16, 5) and a["dense"].shape == (16, 3)
    assert set(np.unique(a["label"])) <= {0.0, 1.0}
    tt = recsys_batch(RecsysDataConfig(n_sparse=4, vocab=50, batch=8,
                                       two_tower=True, n_sparse_item=4), 0)
    assert tt["user_sparse"].shape == (8, 4) and "log_q" in tt


def test_all_nine_profiles_exist():
    names = dataset_names()
    assert len(names) == 9
    assert {PROFILES[n].metric for n in names} == {"l2", "ip"}


@pytest.mark.parametrize("profile", ["bigann-like", "msmarco-like"])
def test_corpus_pareto_signature(profile):
    """Sec. 3: most queries zero results, a few large outliers."""
    ds = make_corpus(profile, n=4000, n_queries=256, seed=0)
    pts, qs = jnp.asarray(ds.points), jnp.asarray(ds.queries)
    grid = default_grid(ds.points, ds.queries, ds.metric, num=32)
    prof = sweep(pts, qs, grid, ds.metric)
    r, gi = select_radius(prof, robustness_weight=0.1)
    counts = np.asarray(exact_range_search(pts, qs, r, ds.metric)[2])
    h = match_histogram(counts)
    assert h["0"] > 0.3 * len(counts)           # majority-ish zero
    assert counts.max() >= 3                    # some real result sets
    # capture curve is monotone in radius
    assert all(b >= a - 1e-12 for a, b in
               zip(prof.percent_captured, prof.percent_captured[1:]))


def test_gist_profile_has_huge_outliers():
    """Fig. 4's GIST row: hundreds of queries with >1e3 results."""
    ds = make_corpus("gist-like", n=4000, n_queries=256, seed=0)
    pts, qs = jnp.asarray(ds.points), jnp.asarray(ds.queries)
    grid = default_grid(ds.points, ds.queries, ds.metric, num=32)
    prof = sweep(pts, qs, grid, ds.metric)
    r, _ = select_radius(prof, robustness_weight=0.1)
    counts = np.asarray(exact_range_search(pts, qs, r, ds.metric)[2])
    assert (counts > 1000).sum() >= 5
    assert (counts == 0).sum() > 100


def test_scaling_densifies_at_fixed_radius():
    """Fig. 7 premise: same radius, larger corpus -> more matches/query."""
    ds1 = make_corpus("ssnpp-like", n=3000, n_queries=128, seed=0)
    ds3 = make_corpus("ssnpp-like", n=9000, n_queries=128, seed=0)
    pts1, qs = jnp.asarray(ds1.points), jnp.asarray(ds1.queries)
    grid = default_grid(ds1.points, ds1.queries, "l2", num=24)
    prof = sweep(pts1, qs, grid)
    r, _ = select_radius(prof, robustness_weight=0.1)
    c1 = np.asarray(exact_range_search(pts1, qs, r)[2]).mean()
    c3 = np.asarray(exact_range_search(jnp.asarray(ds3.points), qs, r)[2]).mean()
    assert c3 > c1
