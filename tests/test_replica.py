"""Replication suite for ``repro.fault.replica`` + live replica groups.

Four families of claims, each against a deterministic oracle:

- **Parity** — replicas are bitwise-identical at build time and stay so
  under live churn; which replica answers is therefore unobservable in
  results (varying the preferred replica never changes a merged bit).
- **Fan-out determinism** — the threaded, replicated fan-out merges in
  shard order, so it is bitwise-identical to the serial single-replica
  reference under every fault script, for f32 and int8 corpora.
- **Breakers & hedging** — consecutive failures trip a per-replica
  breaker (fake clock drives cooldown -> half-open probe -> close or
  re-trip); scripted-slow primaries are hedged with no breaker penalty
  and zero answer cost.
- **Loss & recovery** — one replica of every shard can die and coverage
  stays 1.0 (annotated ``replica_lost``, never ``shard_lost``);
  ``maintain()`` rebuilds from a surviving peer, a live replica rebuilds
  from checkpoint + WAL tail, and both re-enter through half-open.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    BuildConfig, RangeConfig, SearchConfig, build_knn_graph,
)
from repro.dist.sharded_engine import build_sharded
from repro.fault import (
    ERROR_CODES, REPLICA_LOST, SHARD_LOST, BreakerConfig, CircuitBreaker,
    FaultInjector, HedgePolicy, ReplicaFleet, ReplicatedCorpus, RetryPolicy,
    fault_tolerant_sharded_search, replicated_fan_out,
)
from repro.live import LiveConfig, LiveShardedIndex, clone_live_index
from repro.live.sharded import LiveIndex
from repro.serve import RangeServer, Request, ServerConfig
from repro.train import CheckpointManager

FAST = RetryPolicy(max_attempts=3, backoff_s=0.0)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _corpus(corpus_dtype="float32"):
    rng = np.random.default_rng(0)
    centers = rng.standard_normal((8, 8)).astype(np.float32) * 3
    pts = (centers[rng.integers(0, 8, 800)]
           + rng.standard_normal((800, 8)).astype(np.float32) * 0.3)
    centers_j = jnp.asarray(centers)

    def _builder(p):
        # one entry point per cluster: a kNN graph over separated clusters
        # is disconnected, a lone medoid start would strand 7 of 8 clusters
        lab = np.asarray(jnp.argmin(
            jnp.sum((p[:, None] - centers_j[None]) ** 2, -1), axis=1))
        starts = np.asarray([np.flatnonzero(lab == c)[0] for c in range(8)],
                            np.int32)
        return build_knn_graph(p, k=10), jnp.asarray(starts)

    corpus = build_sharded(pts, 4, _builder, corpus_dtype=corpus_dtype)
    qs = jnp.asarray(pts[:24] + 0.01)
    cfg = RangeConfig(search=SearchConfig(beam=32, max_beam=32, visit_cap=128,
                                          expand_width=4),
                      mode="greedy", result_cap=512)
    return pts, corpus, qs, cfg


@pytest.fixture(scope="module")
def setup_f32():
    return _corpus()


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    np.testing.assert_array_equal(np.asarray(a.count), np.asarray(b.count))


# ---------------------------------------------------------------------------
# replica parity: bitwise-identical copies, unobservable choice
# ---------------------------------------------------------------------------

def test_replicated_corpus_parity_and_delegation(setup_f32):
    _, corpus, _, _ = setup_f32
    rc = ReplicatedCorpus.replicate(corpus, 3)
    assert rc.n_replicas == 3 and rc.parity_ok()
    # fresh buffers, not aliases of the original
    assert rc.replica(1).neighbors is not corpus.neighbors
    # replica-0 delegation: anything duck-typing a ShardedCorpus works
    assert rc.n_shards == corpus.n_shards
    assert rc.n_total == corpus.n_total
    assert rc.shard_size == corpus.shard_size
    np.testing.assert_array_equal(np.asarray(rc.offsets),
                                  np.asarray(corpus.offsets))
    with pytest.raises(ValueError, match="replicas"):
        ReplicatedCorpus.replicate(corpus, 0)


def test_replica_choice_is_unobservable(setup_f32):
    """Serving from any replica (vary ``preferred``) yields the same bits —
    the invariant that frees failover and hedging from consistency
    reasoning."""
    _, corpus, qs, cfg = setup_f32
    rc = ReplicatedCorpus.replicate(corpus, 3)
    runs = [replicated_fan_out(fleet=ReplicaFleet(rc), queries=qs, r=2.0,
                               cfg=cfg, retry=FAST, preferred=p)
            for p in range(3)]
    for p, run in enumerate(runs):
        assert run.complete and run.code is None
        assert set(np.asarray(run.served_by).tolist()) == {p}
    _assert_bitwise(runs[0].result, runs[1].result)
    _assert_bitwise(runs[0].result, runs[2].result)


# ---------------------------------------------------------------------------
# threaded vs serial: bitwise determinism under fault scripts (satellite)
# ---------------------------------------------------------------------------

_SCRIPTS = {
    "healthy": lambda: None,
    "one_shard_lost": lambda: FaultInjector(seed=0, down_shards=(1,)),
    "all_shards_lost": lambda: FaultInjector(seed=0, down_shards=(0, 1, 2, 3)),
    "garbage_mid_retry": lambda: FaultInjector(
        seed=0, script={(2, 0): "garbage", (0, 1): "garbage"}),
}


@pytest.mark.parametrize("corpus_dtype", ["float32", "int8"])
@pytest.mark.parametrize("scenario", sorted(_SCRIPTS))
def test_threaded_fanout_bitwise_equals_serial(corpus_dtype, scenario):
    """The concurrent fan-out merges in shard order, never completion
    order: under every fault script the threaded result is bitwise-equal
    to the serial (max_workers=0) reference, f32 and int8 alike."""
    _, corpus, qs, cfg = _corpus(corpus_dtype)
    kw = dict(corpus=corpus, queries=qs, r=2.0, cfg=cfg, retry=FAST)
    serial = fault_tolerant_sharded_search(
        injector=_SCRIPTS[scenario](), max_workers=0, **kw)
    threaded = fault_tolerant_sharded_search(
        injector=_SCRIPTS[scenario](), max_workers=None, **kw)
    _assert_bitwise(serial.result, threaded.result)
    np.testing.assert_array_equal(serial.shard_ok, threaded.shard_ok)
    np.testing.assert_array_equal(serial.attempts, threaded.attempts)
    assert serial.faults == threaded.faults
    assert serial.code == threaded.code
    if scenario == "healthy":
        assert serial.complete and int(np.asarray(serial.result.count).sum())
    if scenario == "all_shards_lost":
        assert serial.coverage == 0.0


def test_replicated_fanout_threaded_equals_serial(setup_f32):
    _, corpus, qs, cfg = setup_f32
    rc = ReplicatedCorpus.replicate(corpus, 2)
    inj = lambda: FaultInjector(seed=0, down_replicas=((0, 0), (2, 1)))
    serial = replicated_fan_out(fleet=ReplicaFleet(rc), queries=qs, r=2.0,
                                cfg=cfg, retry=FAST, injector=inj(),
                                max_workers=0)
    threaded = replicated_fan_out(fleet=ReplicaFleet(rc), queries=qs, r=2.0,
                                  cfg=cfg, retry=FAST, injector=inj())
    _assert_bitwise(serial.result, threaded.result)
    np.testing.assert_array_equal(serial.served_by, threaded.served_by)
    assert serial.code == threaded.code == REPLICA_LOST


# ---------------------------------------------------------------------------
# circuit breaker: trip, cooldown, half-open probe (fake clock)
# ---------------------------------------------------------------------------

def test_breaker_trip_halfopen_recovery_roundtrip():
    clock = FakeClock()
    br = CircuitBreaker(BreakerConfig(fail_threshold=3, cooldown_s=30.0),
                        clock=clock)
    assert br.state == "closed" and br.allow()
    assert not br.record_failure() and not br.record_failure()
    assert br.allow()  # two consecutive failures: still closed
    assert br.record_failure()  # third trips
    assert br.state == "open" and br.trips == 1
    assert not br.allow()
    clock.advance(29.9)
    assert not br.allow()  # cooldown not elapsed
    clock.advance(0.2)
    assert br.allow()  # half-open: one probe admitted
    assert br.state == "half_open"
    assert not br.allow()  # ...and only one
    assert br.record_failure()  # failed probe: straight back to open
    assert br.state == "open" and br.trips == 2
    clock.advance(30.1)
    assert br.allow()
    br.record_success()  # successful probe closes
    assert br.state == "closed" and br.failures == 0 and br.allow()
    # a success between failures resets the consecutive count
    br.record_failure()
    br.record_failure()
    br.record_success()
    assert not br.record_failure() and br.state == "closed"


def test_breaker_force_open_and_half_open_readmit():
    clock = FakeClock()
    br = CircuitBreaker(BreakerConfig(cooldown_s=1e9), clock=clock)
    br.force_open()
    assert br.state == "open" and not br.allow()
    br.to_half_open()  # recovery re-admits without waiting the cooldown
    assert br.allow() and br.state == "half_open"
    br.record_success()
    assert br.state == "closed"


def test_breaker_trips_in_fanout_then_recovers(setup_f32):
    """A persistently-down primary accumulates consecutive failures across
    searches until its breaker trips; past the cooldown, the next healthy
    search probes it half-open and closes the breaker."""
    _, corpus, qs, cfg = setup_f32
    clock = FakeClock()
    fleet = ReplicaFleet(ReplicatedCorpus.replicate(corpus, 2), clock=clock,
                         breaker=BreakerConfig(fail_threshold=3,
                                               cooldown_s=30.0))
    down = FaultInjector(seed=0, down_replicas=((2, 0),))
    healthy = replicated_fan_out(fleet=ReplicaFleet(
        ReplicatedCorpus(replicas=[corpus])), queries=qs, r=2.0, cfg=cfg,
        retry=FAST)
    for i in range(3):  # one failure on (2, 0) per search
        res = replicated_fan_out(fleet=fleet, queries=qs, r=2.0, cfg=cfg,
                                 retry=FAST, injector=down)
        assert res.complete and res.code == REPLICA_LOST
        _assert_bitwise(res.result, healthy.result)
    assert fleet.breakers[(2, 0)].state == "open"
    assert fleet.stats["breaker_trips"] == 1
    # breaker open: the replica is skipped entirely (no injector needed for
    # the answer to stay whole), and health reports it down
    res = replicated_fan_out(fleet=fleet, queries=qs, r=2.0, cfg=cfg,
                             retry=FAST)
    assert res.code == REPLICA_LOST and not res.replica_ok[2, 0]
    clock.advance(31.0)
    res = replicated_fan_out(fleet=fleet, queries=qs, r=2.0, cfg=cfg,
                             retry=FAST)  # half-open probe succeeds
    assert fleet.breakers[(2, 0)].state == "closed"
    assert res.code is None and res.replica_ok.all()
    _assert_bitwise(res.result, healthy.result)


# ---------------------------------------------------------------------------
# replica loss: coverage stays whole; shard loss still degrades
# ---------------------------------------------------------------------------

def test_one_replica_per_shard_down_keeps_coverage(setup_f32):
    """The headline contract: R=2 with one replica of EVERY shard down
    serves the full answer (coverage 1.0, bitwise-identical to healthy),
    annotated replica_lost — coverage < 1.0 requires every replica of a
    shard to be exhausted."""
    _, corpus, qs, cfg = setup_f32
    healthy = fault_tolerant_sharded_search(corpus=corpus, queries=qs, r=2.0,
                                            cfg=cfg, retry=FAST)
    rc = ReplicatedCorpus.replicate(corpus, 2)
    lost = fault_tolerant_sharded_search(
        fleet=ReplicaFleet(rc), queries=qs, r=2.0, cfg=cfg, retry=FAST,
        injector=FaultInjector(
            seed=0, down_replicas=((0, 0), (1, 1), (2, 0), (3, 1))))
    assert lost.complete and lost.coverage == 1.0
    assert lost.code == REPLICA_LOST
    assert REPLICA_LOST in ERROR_CODES
    assert lost.replicas_ok < lost.replicas_total == 8
    assert np.asarray(lost.served_by).tolist() == [1, 0, 1, 0]
    _assert_bitwise(lost.result, healthy.result)


def test_whole_shard_down_still_degrades_with_replicas(setup_f32):
    """down_shards kills every replica of the shard: R=2 cannot save it,
    and shard_lost (the stronger code) wins over replica_lost."""
    _, corpus, qs, cfg = setup_f32
    fleet = ReplicaFleet(ReplicatedCorpus.replicate(corpus, 2))
    lost = fault_tolerant_sharded_search(
        fleet=fleet, queries=qs, r=2.0, cfg=cfg, retry=FAST,
        injector=FaultInjector(seed=0, down_shards=(1,)))
    assert not lost.complete and lost.coverage == 0.75
    assert lost.code == SHARD_LOST
    assert int(lost.served_by[1]) == -1


# ---------------------------------------------------------------------------
# hedging: scripted-slow primaries, wall-clock path
# ---------------------------------------------------------------------------

def test_hedge_policy_delay():
    class Hist:
        count = 4

        @staticmethod
        def percentile(p):
            return 0.2

    assert HedgePolicy(delay_s=0.0).delay_for(Hist) == 0.0
    assert HedgePolicy().delay_for(None) == 0.05  # no samples: fallback
    assert HedgePolicy().delay_for(Hist) == pytest.approx(0.2)  # p95
    assert HedgePolicy(factor=0.5).delay_for(Hist) == pytest.approx(0.1)
    assert HedgePolicy(min_delay_s=0.5).delay_for(Hist) == 0.5  # clamped


def test_scripted_slow_primaries_are_hedged(setup_f32):
    """Every primary scripted slow: each shard fires one hedge, the
    secondary wins, the answer is bitwise-identical (parity!) and slow
    costs no breaker penalty — slow is not sick."""
    _, corpus, qs, cfg = setup_f32
    healthy = fault_tolerant_sharded_search(corpus=corpus, queries=qs, r=2.0,
                                            cfg=cfg, retry=FAST)
    fleet = ReplicaFleet(ReplicatedCorpus.replicate(corpus, 2))
    hedged = fault_tolerant_sharded_search(
        fleet=fleet, queries=qs, r=2.0, cfg=cfg, retry=FAST,
        injector=FaultInjector(seed=0,
                               script={(s, 0, 0): "slow" for s in range(4)}),
        hedge=HedgePolicy(delay_s=0.0))
    assert hedged.hedges_fired == 4 and hedged.hedge_wins == 4
    assert hedged.complete and hedged.code is None  # full redundancy kept
    assert fleet.stats["hedges_fired"] == 4
    assert fleet.stats["breaker_trips"] == 0
    assert all(br.failures == 0 for br in fleet.breakers.values())
    _assert_bitwise(hedged.result, healthy.result)


def test_slow_without_hedge_or_peer_is_late_success(setup_f32):
    """No hedge policy (or nothing to hedge to): a slow replica is just a
    late success, never a fault."""
    _, corpus, qs, cfg = setup_f32
    healthy = fault_tolerant_sharded_search(corpus=corpus, queries=qs, r=2.0,
                                            cfg=cfg, retry=FAST)
    slow = FaultInjector(seed=0, script={(s, 0, 0): "slow" for s in range(4)})
    no_hedge = fault_tolerant_sharded_search(
        fleet=ReplicaFleet(ReplicatedCorpus.replicate(corpus, 2)),
        queries=qs, r=2.0, cfg=cfg, retry=FAST, injector=slow)
    assert no_hedge.hedges_fired == 0 and no_hedge.code is None
    _assert_bitwise(no_hedge.result, healthy.result)
    # R=1: hedging requested but no peer exists
    r1 = fault_tolerant_sharded_search(
        fleet=ReplicaFleet(corpus), queries=qs, r=2.0, cfg=cfg, retry=FAST,
        injector=slow, hedge=HedgePolicy(delay_s=0.0))
    assert r1.hedges_fired == 0 and r1.code is None
    _assert_bitwise(r1.result, healthy.result)


def test_wall_clock_hedge_path_is_bitwise(setup_f32):
    """The real-timer hedge race (no injector): with an aggressive delay
    hedges actually fire, and first-validated-wins cannot change a bit of
    the answer."""
    _, corpus, qs, cfg = setup_f32
    healthy = fault_tolerant_sharded_search(corpus=corpus, queries=qs, r=2.0,
                                            cfg=cfg, retry=FAST)
    fleet = ReplicaFleet(ReplicatedCorpus.replicate(corpus, 2))
    raced = fault_tolerant_sharded_search(
        fleet=fleet, queries=qs, r=2.0, cfg=cfg, retry=FAST,
        hedge=HedgePolicy(delay_s=0.0))
    assert raced.complete and raced.code is None
    assert raced.hedges_fired >= 0  # timing-dependent; the answer is not:
    _assert_bitwise(raced.result, healthy.result)


# ---------------------------------------------------------------------------
# loss & recovery: maintain() rebuilds from a surviving peer
# ---------------------------------------------------------------------------

def test_fleet_lose_maintain_recovery_roundtrip(setup_f32):
    _, corpus, qs, cfg = setup_f32
    fleet = ReplicaFleet(ReplicatedCorpus.replicate(corpus, 2))
    fleet.lose(2, 1)
    fleet.lose(2, 1)  # idempotent
    assert fleet.stats["replicas_lost"] == 1
    res = fault_tolerant_sharded_search(fleet=fleet, queries=qs, r=2.0,
                                        cfg=cfg, retry=FAST)
    assert res.complete and res.code == REPLICA_LOST
    assert not res.replica_ok[2, 1] and res.replicas_ok == 7
    assert fleet.maintain() == 1
    assert fleet.stats["replicas_recovered"] == 1 and not fleet.lost
    # recovered replica re-enters via half-open: first request is a probe
    assert fleet.breakers[(2, 1)].state == "half_open"
    res = fault_tolerant_sharded_search(fleet=fleet, queries=qs, r=2.0,
                                        cfg=cfg, retry=FAST)
    assert res.code is None and res.replica_ok.all()
    # aim traffic at the recovered replica: the probe succeeds and closes
    res = replicated_fan_out(fleet=fleet, queries=qs, r=2.0, cfg=cfg,
                             retry=FAST, preferred=1)
    assert res.code is None
    assert fleet.breakers[(2, 1)].state == "closed"


def test_maintain_needs_surviving_peer_and_respects_recover_fn(setup_f32):
    _, corpus, qs, cfg = setup_f32
    fleet = ReplicaFleet(ReplicatedCorpus.replicate(corpus, 2))
    fleet.lose(1, 0)
    fleet.lose(1, 1)  # whole shard gone: nothing to rebuild from
    assert fleet.maintain() == 0 and len(fleet.lost) == 2
    res = fault_tolerant_sharded_search(fleet=fleet, queries=qs, r=2.0,
                                        cfg=cfg, retry=FAST)
    assert res.code == SHARD_LOST and res.coverage == 0.75

    slow_rebuild = ReplicaFleet(ReplicatedCorpus.replicate(corpus, 2),
                                recover_fn=lambda s, rep: False)
    slow_rebuild.lose(3, 0)
    assert slow_rebuild.maintain() == 0  # rebuild still in progress
    slow_rebuild.recover_fn = lambda s, rep: True
    assert slow_rebuild.maintain() == 1


# ---------------------------------------------------------------------------
# live replica groups: parity under churn, rebuild from checkpoint + WAL
# ---------------------------------------------------------------------------

_LCFG = LiveConfig(capacity=96, insert_batch=16)
_LBUILD = BuildConfig(max_degree=8, beam=16, insert_batch=32)


def _churn(idx, seed, n_ops=10):
    rng = np.random.default_rng(seed)
    for _ in range(n_ops):
        roll = rng.random()
        if roll < 0.5:
            idx.insert(rng.standard_normal(
                (int(rng.integers(1, 4)), 8)).astype(np.float32))
        elif roll < 0.9:
            idx.delete(rng.integers(0, idx.next_ext_id,
                                    size=int(rng.integers(1, 4))))
        else:
            idx.maybe_consolidate()


def test_live_replicas_stay_bitwise_under_churn():
    pts = np.random.default_rng(1).standard_normal((128, 8)).astype(np.float32)
    idx = LiveShardedIndex.create(pts, 2, _LCFG, build_cfg=_LBUILD,
                                  replicas=2)
    assert idx.n_replicas == 2
    idx.assert_replica_parity()
    _churn(idx, seed=2)
    for sh in idx.shards:
        sh.consolidate()  # force the heavy mutation on every primary...
    for g in idx.groups:
        for member in g[1:]:
            member.consolidate()  # ...and every secondary
    idx.assert_replica_parity()
    rc, tomb, flat_ext = idx.replicated_corpus()
    assert rc.n_replicas == 2 and rc.parity_ok()
    # the replicated columns serve queries identically to the primary view
    cfg = RangeConfig(search=SearchConfig(beam=16, max_beam=16, visit_cap=64),
                      mode="greedy", result_cap=128)
    qs = jnp.asarray(pts[:8] + 0.01)
    a = fault_tolerant_sharded_search(corpus=rc.replica(0), queries=qs, r=2.0,
                                      cfg=cfg, retry=FAST, tombstones=tomb)
    b = replicated_fan_out(
        fleet=ReplicaFleet(rc), queries=qs, r=2.0, cfg=cfg, retry=FAST,
        tombstones=tomb, preferred=1)  # serve everything from replica 1
    _assert_bitwise(a.result, b.result)


def test_live_rebuild_replica_from_checkpoint_and_wal(tmp_path):
    """Lose a live replica mid-churn and rebuild it from the primary's
    checkpoint + WAL tail: deterministic replay rejoins it bit-identical
    (assert_replica_parity), with no WAL handle of its own."""
    from repro.fault import WriteAheadLog

    pts = np.random.default_rng(3).standard_normal((96, 8)).astype(np.float32)
    idx = LiveShardedIndex.create(pts, 2, _LCFG, build_cfg=_LBUILD,
                                  replicas=2)
    wal = WriteAheadLog(str(tmp_path / "shard0.wal"))
    idx.groups[0][0].attach_wal(wal)  # exactly one group member logs
    cm = CheckpointManager(str(tmp_path / "ck"))
    _churn(idx, seed=4, n_ops=5)
    idx.groups[0][0].save(cm)
    _churn(idx, seed=5, n_ops=5)  # the tail the WAL must carry
    idx.assert_replica_parity()
    # replica (0, 1) dies; rebuild from manifest + WAL tail
    idx.groups[0][1] = None
    rebuilt = idx.rebuild_replica(0, 1, cm,
                                  wal=WriteAheadLog(str(tmp_path / "shard0.wal")))
    assert rebuilt.wal is None  # the primary keeps the only log handle
    idx.assert_replica_parity()
    with pytest.raises(ValueError, match="primary"):
        idx.rebuild_replica(0, 0, cm)


def test_clone_live_index_is_independent():
    pts = np.random.default_rng(5).standard_normal((64, 8)).astype(np.float32)
    a = LiveIndex.create(pts, _LCFG, _LBUILD, metric="l2")
    b = clone_live_index(a)
    a.insert(np.ones((2, 8), np.float32))
    assert a.n_live == b.n_live + 2  # clone did not see the insert
    assert a.next_ext_id != b.next_ext_id


def test_live_replica_group_validation():
    pts = np.random.default_rng(6).standard_normal((64, 8)).astype(np.float32)
    sh = LiveIndex.create(pts, _LCFG, _LBUILD, metric="l2")
    other = clone_live_index(sh)
    with pytest.raises(ValueError, match="replica_groups"):
        LiveShardedIndex([sh], replica_groups=[[other, sh]])
    with pytest.raises(ValueError, match="replicas"):
        LiveShardedIndex.create(pts, 2, _LCFG, build_cfg=_LBUILD, replicas=0)


# ---------------------------------------------------------------------------
# serving integration: RangeServer(replicas=, hedge=)
# ---------------------------------------------------------------------------

def test_server_replicated_annotations_and_stats(setup_f32):
    _, corpus, qs, cfg = setup_f32
    qs_np = np.asarray(qs)
    retry = RetryPolicy(max_attempts=2, backoff_s=0.0)

    def drive(srv, n=6):
        for i in range(n):
            srv.submit(Request(req_id=i, query=qs_np[i], radius=2.0))
        return sorted(srv.run_until_drained(), key=lambda r: r.req_id)

    base = drive(RangeServer(None, cfg, ServerConfig(max_batch=8),
                             sharded=corpus, retry=retry))
    assert all(r.replicas_ok is None and r.replicas_total is None
               for r in base)  # unreplicated: no replica annotations

    srv = RangeServer(None, cfg, ServerConfig(max_batch=8), sharded=corpus,
                      replicas=2, retry=retry,
                      injector=FaultInjector(
                          seed=0,
                          down_replicas=((0, 0), (1, 1), (2, 0), (3, 1))))
    resp = drive(srv)
    for r, r0 in zip(resp, base):
        assert r.complete and r.coverage == 1.0 and r.code == REPLICA_LOST
        assert r.replicas_total == 8 and r.replicas_ok < 8
        np.testing.assert_array_equal(r.ids, r0.ids)  # R=2 loss == healthy
        np.testing.assert_array_equal(r.dists, r0.dists)
    assert srv.stats["replicas_lost"] == 0  # down, not declared lost
    assert srv.stats["degraded_batches"] == 0  # the answer stayed whole

    hedged = RangeServer(None, cfg, ServerConfig(max_batch=8), sharded=corpus,
                         replicas=2, retry=retry,
                         hedge=HedgePolicy(delay_s=0.0),
                         injector=FaultInjector(
                             seed=0,
                             script={(s, 0, 0): "slow" for s in range(4)}))
    resp = drive(hedged)
    assert all(r.complete and r.code is None for r in resp)
    assert hedged.stats["hedges_fired"] > 0
    assert hedged.stats["hedge_wins"] == hedged.stats["hedges_fired"]

    with pytest.raises(ValueError, match="replicas"):
        RangeServer(None, cfg, replicas=2)


def test_server_maintain_recovers_lost_replica(setup_f32):
    """step() runs the fleet's maintenance sweep: a replica declared lost
    is rebuilt between batches and the next response regains full
    redundancy."""
    _, corpus, qs, cfg = setup_f32
    qs_np = np.asarray(qs)
    srv = RangeServer(None, cfg, ServerConfig(max_batch=4), sharded=corpus,
                      replicas=2, retry=FAST)
    srv.fleet.lose(1, 1)
    srv.submit(Request(req_id=0, query=qs_np[0], radius=2.0))
    (r0,) = srv.run_until_drained()
    # maintain() ran before the batch, so recovery already happened; the
    # lost replica was re-admitted through half-open and probed clean
    assert srv.stats["replicas_lost"] == 1
    assert srv.stats["replicas_recovered"] == 1
    srv.submit(Request(req_id=1, query=qs_np[1], radius=2.0))
    (r1,) = srv.run_until_drained()
    assert r1.code is None and r1.replicas_ok == r1.replicas_total == 8
