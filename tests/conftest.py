# NOTE (per brief): XLA_FLAGS / device-count forcing is deliberately NOT set
# here — smoke tests and benches must see 1 device. Multi-device tests
# (tests/test_dist.py) spawn subprocesses that set the flag themselves.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
