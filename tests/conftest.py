# NOTE (per brief): XLA_FLAGS / device-count forcing is deliberately NOT set
# here — smoke tests and benches must see 1 device. Multi-device tests
# (tests/test_dist.py) spawn subprocesses that set the flag themselves.
import os
import sys

import numpy as np
import pytest

try:  # slim CI images may lack hypothesis; fall back to the local stub
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))


@pytest.fixture
def rng():
    return np.random.default_rng(0)
