"""Minimal, deterministic stand-in for the ``hypothesis`` API the tests use.

Loaded by conftest.py ONLY when the real package is unavailable (the CI
image pins a slim dependency set). Covers ``given`` + ``settings`` +
``st.integers`` / ``st.floats`` / ``st.lists``: each decorated test runs ``max_examples``
times over a seeded sample stream, so property tests stay property tests —
just with reproducible draws instead of shrinking ones.
"""
from __future__ import annotations

import numpy as np


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        return _Strategy(
            lambda rng: [elements.sample(rng)
                         for _ in range(int(rng.integers(min_size,
                                                         max_size + 1)))])


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        def wrapper():
            # read at call time: @settings may sit above OR below @given
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 20))
            rng = np.random.default_rng(0)
            for _ in range(n):
                fn(*(s.sample(rng) for s in strats))

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
